"""Sharded serving tier: piece-grid artifacts + ShardedPredictor.

Exactness pins (acceptance criteria):
* export_artifact_sharded -> load_artifact_sharded reassembles the model
  BITWISE (tables, lsh params, normalization) — slicing + concatenation is
  lossless by construction, and the test keeps it that way;
* ShardedPredictor on a model-unsharded mesh BITWISE-matches the
  single-host Predictor (same readout program modulo the data-axis
  collectives, which in broadcast mode add exact zeros), 1-RHS and
  multi-RHS alike; on a model-sharded (2x2) mesh the instance-mean psum
  reorders f32 additions, so the pin is <= 1e-5 (ISSUE acceptance bound);
* failure modes REFUSE loudly: a mesh-mismatched manifest, a torn per-shard
  save (invisible to ``latest_step``), and a mixed-generation piece grid
  all raise at load — nothing mixed or partial ever assembles.
"""
import json
import os

import jax
import numpy as np
import pytest

from repro.core import (WLSHKernelSpec, get_bucket_fn, make_operator,
                        wlsh_krr_fit)
from repro.core.distributed import query_shard_touch
from repro.serve import (Normalization, Predictor, ShardedPredictor,
                         export_artifact, export_artifact_sharded,
                         load_artifact_sharded, parse_mesh_shape)
from repro.serve.artifact import MANIFEST_NAME
from repro.serve.cache import BucketKeyFn
from repro.testing import killed_checkpoint_writer
from repro.testing.faults import FaultInjected

needs_multi = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs >= 2 devices (CI serving-multidevice job sets "
           "XLA_FLAGS=--xla_force_host_platform_device_count=4)")

needs_4 = pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="needs >= 4 devices (CI serving-multidevice job sets "
           "XLA_FLAGS=--xla_force_host_platform_device_count=4)")


def _fit(key, n=256, d=4, m=16, k_rhs=0):
    x = jax.random.uniform(key, (n, d)) * 2.0
    y = jax.random.normal(jax.random.fold_in(key, 1),
                          (n, k_rhs) if k_rhs else (n,))
    spec = WLSHKernelSpec(bucket=get_bucket_fn("rect"))
    model = wlsh_krr_fit(jax.random.fold_in(key, 2), x, y, spec, m=m,
                         lam=0.5, maxiter=100, backend="reference")
    return model, np.asarray(x, np.float32)


@pytest.fixture(scope="module")
def fitted():
    return _fit(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def fitted_k3():
    return _fit(jax.random.PRNGKey(3), k_rhs=3)


# ---------------------------------------------------------------------------
# sharded artifact: round-trip + refusal modes
# ---------------------------------------------------------------------------

def test_sharded_roundtrip_bitwise(fitted, tmp_path):
    model, _ = _fit(jax.random.PRNGKey(0))
    norm = Normalization(x_mean=np.full(4, 0.5, np.float32),
                         x_std=np.full(4, 2.0, np.float32),
                         y_mean=0.25, y_std=1.5)
    export_artifact_sharded(str(tmp_path), model, mesh_shape=(2, 2),
                            norm=norm)
    loaded = load_artifact_sharded(str(tmp_path), mesh_shape=(2, 2))
    # slicing + concatenation is lossless: every array reassembles bitwise
    np.testing.assert_array_equal(np.asarray(loaded.model.tables),
                                  np.asarray(model.tables))
    for name in ("w", "z", "r1", "r2"):
        np.testing.assert_array_equal(
            np.asarray(getattr(loaded.model.lsh, name)),
            np.asarray(getattr(model.lsh, name)))
    # beta never travels in a serving export
    assert loaded.model.beta.shape[0] == 0
    np.testing.assert_array_equal(loaded.norm.x_mean, norm.x_mean)
    np.testing.assert_array_equal(loaded.norm.x_std, norm.x_std)
    assert loaded.norm.y_mean == np.float32(norm.y_mean)
    assert loaded.norm.y_std == np.float32(norm.y_std)
    assert loaded.mesh_shape == (2, 2)


def test_sharded_roundtrip_multirhs(fitted_k3, tmp_path):
    model, _ = fitted_k3
    export_artifact_sharded(str(tmp_path), model, mesh_shape=(2, 2))
    loaded = load_artifact_sharded(str(tmp_path), mesh_shape=(2, 2))
    assert loaded.model.tables.ndim == 3
    np.testing.assert_array_equal(np.asarray(loaded.model.tables),
                                  np.asarray(model.tables))
    assert loaded.model.beta.shape == (0, 3)


def test_export_refuses_indivisible_grid(fitted, tmp_path):
    model, _ = fitted          # m=16, table_size power of two
    with pytest.raises(ValueError, match="not divisible"):
        export_artifact_sharded(str(tmp_path), model, mesh_shape=(3, 2))
    with pytest.raises(ValueError, match="not divisible"):
        export_artifact_sharded(str(tmp_path), model, mesh_shape=(2, 3))


def test_load_refuses_mesh_mismatch(fitted, tmp_path):
    model, _ = fitted
    export_artifact_sharded(str(tmp_path), model, mesh_shape=(2, 2))
    for target in ((1, 2), (2, 4), (4, 2)):
        with pytest.raises(ValueError, match="re-export"):
            load_artifact_sharded(str(tmp_path), mesh_shape=target)
    # the recorded grid still loads
    load_artifact_sharded(str(tmp_path), mesh_shape=(2, 2))


def test_load_refuses_newer_format(fitted, tmp_path):
    model, _ = fitted
    export_artifact_sharded(str(tmp_path), model, mesh_shape=(1, 2))
    path = os.path.join(str(tmp_path), MANIFEST_NAME)
    with open(path) as fh:
        manifest = json.load(fh)
    manifest["format"] = manifest["format"] + 1
    with open(path, "w") as fh:
        json.dump(manifest, fh)
    with pytest.raises(ValueError, match="newer"):
        load_artifact_sharded(str(tmp_path), mesh_shape=(1, 2))


def test_torn_first_export_loads_nothing(fitted, tmp_path):
    """A writer killed mid-piece on a FIRST export leaves no manifest (it is
    written last) and a piece .tmp dir invisible to ``latest_step`` — the
    loader sees an empty directory, not a partial artifact."""
    model, _ = fitted
    with killed_checkpoint_writer(after_saves=2):
        with pytest.raises(FaultInjected):
            export_artifact_sharded(str(tmp_path), model, mesh_shape=(2, 2))
    assert not os.path.exists(os.path.join(str(tmp_path), MANIFEST_NAME))
    with pytest.raises(FileNotFoundError):
        load_artifact_sharded(str(tmp_path), mesh_shape=(2, 2))


def test_torn_reexport_never_assembles_mixed(fitted, tmp_path):
    """A re-export killed mid-grid leaves the OLD manifest next to some NEW
    pieces; the export-version cross-check refuses to assemble the mix
    instead of silently serving half-swapped tables."""
    model, _ = fitted
    export_artifact_sharded(str(tmp_path), model, mesh_shape=(2, 2))
    with killed_checkpoint_writer(after_saves=2):
        with pytest.raises(FaultInjected):
            export_artifact_sharded(str(tmp_path), model, mesh_shape=(2, 2))
    with pytest.raises(ValueError, match="mixed or torn"):
        load_artifact_sharded(str(tmp_path), mesh_shape=(2, 2))
    # a clean re-export heals the grid: it rewrites EVERY piece at the next
    # version past the last PUBLISHED manifest (a crashed export never
    # publishes, so it never consumes a version number)
    export_artifact_sharded(str(tmp_path), model, mesh_shape=(2, 2))
    loaded = load_artifact_sharded(str(tmp_path), mesh_shape=(2, 2))
    assert loaded.manifest["export_version"] == 2


def test_load_refuses_poisoned_piece(fitted, tmp_path):
    model, _ = fitted
    export_artifact_sharded(str(tmp_path), model, mesh_shape=(1, 2))
    # corrupt one piece's payload in place (same shape, NaN entries)
    pdir = os.path.join(str(tmp_path), "shard_0_1")
    step = [n for n in os.listdir(pdir) if n.startswith("step_")][0]
    npz = os.path.join(pdir, step, "arrays.npz")
    with np.load(npz) as f:
        arrays = {k: f[k] for k in f.files}
    # keys are checkpoint-store keystr paths, e.g. "['tables']"
    tkey = next(k for k in arrays if "tables" in k)
    arrays[tkey] = np.full_like(arrays[tkey], np.nan)
    np.savez(npz, **arrays)
    with pytest.raises(ValueError, match="non-finite"):
        load_artifact_sharded(str(tmp_path), mesh_shape=(1, 2))


# ---------------------------------------------------------------------------
# per-shard cache keys: touch sets
# ---------------------------------------------------------------------------

def test_keys_with_touch_matches_operator_slots(fitted):
    """The cache key's touch set must agree with the authoritative slot
    layout (``query_shard_touch`` over the operator's own slots) — the two
    are computed independently (numpy hash pass vs jit featurize)."""
    model, x = fitted
    f = get_bucket_fn(model.bucket_name)
    keyfn = BucketKeyFn(model.lsh, f)
    op = make_operator(model.lsh, f, int(model.table_size),
                       backend="reference")
    q = x[:32]
    idx = op.build_index(op.featurize(q), blocked=False)
    slots = np.asarray(idx.slot).T                      # (n, m)
    for n_shards in (2, 4, 8):
        touch = query_shard_touch(slots, int(model.table_size), n_shards)
        keys = keyfn.keys_with_touch(q, table_size=int(model.table_size),
                                     n_shards=n_shards)
        for i, (_, touched) in enumerate(keys):
            assert tuple(np.nonzero(touch[i])[0].tolist()) == touched


def test_keys_with_touch_bad_rows_touch_everything(fitted):
    model, x = fitted
    keyfn = BucketKeyFn(model.lsh, get_bucket_fn(model.bucket_name))
    q = x[:4].copy()
    q[2, 0] = np.inf
    keys = keyfn.keys_with_touch(q, table_size=int(model.table_size),
                                 n_shards=4)
    assert keys[2][0].startswith(b"!raw")
    assert keys[2][1] == (0, 1, 2, 3)
    for i in (0, 1, 3):
        assert not keys[i][0].startswith(b"!raw")


def test_query_shard_touch_validates():
    with pytest.raises(ValueError, match="not divisible"):
        query_shard_touch(np.zeros((2, 3), np.int64), 10, 4)


# ---------------------------------------------------------------------------
# ShardedPredictor: parity, placement, cache, health
# ---------------------------------------------------------------------------

def test_parse_mesh_shape():
    assert parse_mesh_shape("2x2") == (2, 2)
    assert parse_mesh_shape("8X32") == (8, 32)
    for bad in ("2", "2x", "ax2", "0x2", "2x-1"):
        with pytest.raises(ValueError):
            parse_mesh_shape(bad)


def test_sharded_predictor_1x1_bitwise_vs_single_host(fitted, tmp_path):
    """On a model-unsharded mesh the broadcast route adds only exact zeros:
    the sharded warm path is BITWISE the single-host warm path."""
    model, x = fitted
    export_artifact(str(tmp_path / "single"), model)
    export_artifact_sharded(str(tmp_path / "grid"), model, mesh_shape=(1, 1))
    single = Predictor(cache_entries=0)
    single.load(str(tmp_path / "single"))
    sharded = ShardedPredictor(mesh_shape=(1, 1), cache_entries=0)
    sharded.load(str(tmp_path / "grid"))
    q = x[:33]
    np.testing.assert_array_equal(sharded.predict(q, use_cache=False),
                                  single.predict(q, use_cache=False))
    # single-row path too
    np.testing.assert_array_equal(sharded.predict(q[0], use_cache=False),
                                  single.predict(q[0], use_cache=False))


def test_sharded_predictor_1x1_multirhs_bitwise(fitted_k3, tmp_path):
    model, x = fitted_k3
    export_artifact(str(tmp_path / "single"), model)
    export_artifact_sharded(str(tmp_path / "grid"), model, mesh_shape=(1, 1))
    single = Predictor(cache_entries=0)
    single.load(str(tmp_path / "single"))
    sharded = ShardedPredictor(mesh_shape=(1, 1), cache_entries=0)
    sharded.load(str(tmp_path / "grid"))
    q = x[:17]
    out = sharded.predict(q, use_cache=False)
    assert out.shape == (17, 3)
    np.testing.assert_array_equal(out, single.predict(q, use_cache=False))


def test_sharded_predictor_1x1_norm_one_ulp(fitted, tmp_path):
    """Host-side normalization (sharded) vs the in-jit one (single): the
    f32 ops are the same, but XLA fuses the ``out*y_std + y_mean`` denorm
    into an FMA while numpy rounds the product first — agreement is
    within 1 ulp, not bitwise (the un-normalized paths ARE bitwise, see
    above)."""
    model, x = fitted
    norm = Normalization(x_mean=x.mean(0), x_std=x.std(0) + 0.5,
                         y_mean=0.3, y_std=1.7)
    export_artifact(str(tmp_path / "single"), model, norm=norm)
    export_artifact_sharded(str(tmp_path / "grid"), model, mesh_shape=(1, 1),
                            norm=norm)
    single = Predictor(cache_entries=0)
    single.load(str(tmp_path / "single"))
    sharded = ShardedPredictor(mesh_shape=(1, 1), cache_entries=0)
    sharded.load(str(tmp_path / "grid"))
    q = x[:16]
    np.testing.assert_allclose(sharded.predict(q, use_cache=False),
                               single.predict(q, use_cache=False),
                               rtol=3e-7, atol=1e-7)


@needs_4
def test_sharded_predictor_2x2_parity(fitted, fitted_k3, tmp_path):
    """Model-sharded mesh: the instance-mean psum reorders f32 adds, so the
    pin is the ISSUE acceptance bound <= 1e-5 (observed ~3e-8)."""
    for tag, (model, x) in (("k1", fitted), ("k3", fitted_k3)):
        export_artifact(str(tmp_path / f"single_{tag}"), model)
        export_artifact_sharded(str(tmp_path / f"grid_{tag}"), model,
                                mesh_shape=(2, 2))
        single = Predictor(cache_entries=0)
        single.load(str(tmp_path / f"single_{tag}"))
        sharded = ShardedPredictor(mesh_shape=(2, 2), cache_entries=0)
        sharded.load(str(tmp_path / f"grid_{tag}"))
        q = x[:64]
        np.testing.assert_allclose(sharded.predict(q, use_cache=False),
                                   single.predict(q, use_cache=False),
                                   atol=1e-5, rtol=0)


@needs_4
def test_sharded_predictor_placement_co_serving(fitted, tmp_path):
    """A (1, 2)-exported model placed on rows [1, 2) of a 2x2 mesh serves
    identically to the same export on its own 1x2 mesh."""
    model, x = fitted
    export_artifact_sharded(str(tmp_path / "grid"), model, mesh_shape=(1, 2))
    whole = ShardedPredictor(mesh_shape=(1, 2), cache_entries=0)
    whole.load(str(tmp_path / "grid"))
    placed = ShardedPredictor(mesh_shape=(2, 2), cache_entries=0)
    placed.load(str(tmp_path / "grid"), placement=(1, 2))
    q = x[:32]
    np.testing.assert_array_equal(placed.predict(q, use_cache=False),
                                  whole.predict(q, use_cache=False))
    assert placed.health()["shards"]["grid"]["placement"] == [1, 2]


def test_sharded_predictor_placement_validation(fitted, tmp_path):
    model, _ = fitted
    export_artifact_sharded(str(tmp_path), model, mesh_shape=(1, 1))
    pred = ShardedPredictor(mesh_shape=(1, 1))
    loaded = load_artifact_sharded(str(tmp_path), mesh_shape=(1, 1))
    with pytest.raises(ValueError, match="outside model axis"):
        pred.add_model(loaded, placement=(0, 2))
    with pytest.raises(ValueError, match="power of two"):
        ShardedPredictor(mesh_shape=(1, 3))
    with pytest.raises(ValueError, match="max_batch"):
        ShardedPredictor(mesh_shape=(1, 1), max_batch=48)


def test_sharded_cache_replay_and_bump(fitted, tmp_path):
    """Cache hits replay the cold path bitwise; bumping a shard's piece
    version invalidates exactly the entries touching it (on a 1-data-shard
    mesh every entry touches shard 0, so a bump empties the hit path)."""
    model, x = fitted
    export_artifact_sharded(str(tmp_path), model, mesh_shape=(1, 1))
    pred = ShardedPredictor(mesh_shape=(1, 1), cache_entries=1024)
    pred.load(str(tmp_path))
    q = x[:8]
    cold = pred.predict(q)
    np.testing.assert_array_equal(pred.predict(q), cold)   # hit, bitwise
    stats = pred.cache_stats()
    assert stats["hits"] >= len(q)
    before = stats["misses"]
    pred.bump_shard_version(0)
    np.testing.assert_array_equal(pred.predict(q), cold)   # recompute, equal
    assert pred.cache_stats()["misses"] > before
    with pytest.raises(ValueError, match="outside"):
        pred.bump_shard_version(1)
    assert pred.health()["shards"][pred.artifact_ids[0]][
        "piece_versions"] == [1]


@needs_multi
def test_sharded_overflow_counters(fitted, tmp_path):
    """dedup=True with a starved capacity must ACCOUNT dropped buckets in
    health(), never silently return short — the broadcast default cannot
    overflow at all."""
    model, x = fitted
    export_artifact_sharded(str(tmp_path), model, mesh_shape=(1, 2))
    starved = ShardedPredictor(mesh_shape=(1, 2), dedup=True,
                               cap_factor=0.001)
    starved.load(str(tmp_path))
    starved.predict(x[:64], use_cache=False)
    overflow = starved.health()["shards"][
        starved.artifact_ids[0]]["overflow"]
    assert sum(overflow) > 0
    # broadcast mode on the same export: exact, and overflow stays zero
    bcast = ShardedPredictor(mesh_shape=(1, 2))
    bcast.load(str(tmp_path))
    bcast.predict(x[:64], use_cache=False)
    aid = bcast.artifact_ids[0]
    assert bcast.health()["shards"][aid]["overflow"] == [0, 0]


@needs_multi
def test_sharded_predictor_1x2_parity_and_chunking(fitted, tmp_path):
    """Data-only sharding: <= 1e-5 vs the single-host path (XLA reassociates
    the owner-sum x instance-sum reduction once the owner axis is real, so
    a few ulps, not bitwise), including batches above max_batch (chunked
    with a ragged tail)."""
    model, x = fitted
    export_artifact(str(tmp_path / "single"), model)
    export_artifact_sharded(str(tmp_path / "grid"), model, mesh_shape=(1, 2))
    single = Predictor(cache_entries=0)
    single.load(str(tmp_path / "single"))
    sharded = ShardedPredictor(mesh_shape=(1, 2), cache_entries=0,
                               max_batch=16)
    sharded.load(str(tmp_path / "grid"))
    q = x[:50]                       # 16+16+16+2 chunks, ragged tail
    np.testing.assert_allclose(sharded.predict(q, use_cache=False),
                               single.predict(q, use_cache=False),
                               atol=1e-5, rtol=0)


def test_sharded_predictor_rejects_nonfinite(fitted, tmp_path):
    from repro.errors import InvalidRequest

    model, x = fitted
    export_artifact_sharded(str(tmp_path), model, mesh_shape=(1, 1))
    pred = ShardedPredictor(mesh_shape=(1, 1))
    pred.load(str(tmp_path))
    q = x[:4].copy()
    q[1, 2] = np.nan
    with pytest.raises(InvalidRequest):
        pred.predict(q)
    assert pred.health()["errors"] == 1


def test_sharded_predictor_bucket_compile_bound(fitted, tmp_path):
    """Ragged sizes within one padding bucket never recompile (same pin as
    the single-host predictor, via the jit cache size)."""
    model, x = fitted
    export_artifact_sharded(str(tmp_path), model, mesh_shape=(1, 1))
    pred = ShardedPredictor(mesh_shape=(1, 1), max_batch=64)
    pred.load(str(tmp_path))
    pred.warmup(sizes=(1, 16))
    n0 = pred.compile_count()
    for b in (9, 12, 16, 3, 1):      # buckets 16, 16, 16, 4(new), 1
        pred.predict(x[:b], use_cache=False)
    assert pred.compile_count() == n0 + 1    # only bucket 4 was new
