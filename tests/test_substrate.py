"""Substrate tests: data determinism, optimizer, gradient compression,
checkpointing (atomicity, async, elastic), fault-tolerant loop."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_checkpoint, restore_resharded,
                              save_checkpoint)
from repro.data import make_regression_dataset, synthetic_lm_batch
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         cosine_schedule, dequantize_int8, global_norm,
                         quantize_int8)
from repro.runtime import FailureInjector, RestartableLoop, StragglerWatchdog


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_synthetic_batches_deterministic_and_resumable():
    a = synthetic_lm_batch(7, 42, batch=4, seq=32, vocab=101)
    b = synthetic_lm_batch(7, 42, batch=4, seq=32, vocab=101)
    assert bool(jnp.all(a["tokens"] == b["tokens"]))
    c = synthetic_lm_batch(8, 42, batch=4, seq=32, vocab=101)
    assert not bool(jnp.all(a["tokens"] == c["tokens"]))
    # labels are next tokens with masked tail
    assert bool(jnp.all(a["labels"][:, :-1] == a["tokens"][:, 1:]))
    assert bool(jnp.all(a["labels"][:, -1] == -1))


def test_synthetic_stream_is_learnable_structure():
    """Most transitions follow the affine recurrence (noise=0.1)."""
    b = synthetic_lm_batch(0, 0, batch=8, seq=256, vocab=997, noise=0.1)
    t = b["tokens"]
    pred = (t[:, :-1] * 4097 + 1231) % 997
    frac = float(jnp.mean((pred == t[:, 1:]).astype(jnp.float32)))
    assert 0.8 < frac < 0.95, frac


def test_regression_datasets_standardized():
    xtr, ytr, xte, yte = make_regression_dataset("insurance", scale=0.05)
    assert xtr.shape[1] == 85
    assert abs(float(jnp.mean(ytr))) < 1e-3
    np.testing.assert_allclose(float(jnp.std(ytr)), 1.0, atol=1e-3)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_converges_on_quadratic():
    params = {"w": jnp.full((8,), 5.0)}
    st = adamw_init(params)
    cfg = AdamWConfig(lr=0.3, weight_decay=0.0, warmup_steps=0,
                      total_steps=200, clip_norm=100.0)
    for _ in range(150):
        grads = jax.tree.map(lambda p: 2 * p, params)
        params, st, _ = adamw_update(cfg, grads, st, params)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.05


def test_cosine_schedule_endpoints():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                      min_lr_frac=0.1)
    assert float(cosine_schedule(cfg, jnp.asarray(0))) == 0.0
    np.testing.assert_allclose(float(cosine_schedule(cfg, jnp.asarray(10))),
                               1.0, atol=0.01)
    np.testing.assert_allclose(float(cosine_schedule(cfg, jnp.asarray(110))),
                               0.1, atol=0.01)


def test_grad_clipping_caps_update_norm():
    params = {"w": jnp.zeros((4,))}
    st = adamw_init(params)
    cfg = AdamWConfig(lr=1.0, clip_norm=1.0, warmup_steps=0, total_steps=10,
                      weight_decay=0.0)
    _, _, metrics = adamw_update(cfg, {"w": jnp.full((4,), 100.0)}, st, params)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)


def test_int8_quantization_unbiased_and_bounded(rng):
    x = jax.random.normal(rng, (4096,)) * 3.0
    q, scale = quantize_int8(x, rng)
    err = dequantize_int8(q, scale) - x
    assert float(jnp.max(jnp.abs(err))) <= float(scale) + 1e-6
    reps = jnp.stack([dequantize_int8(*quantize_int8(
        x, jax.random.fold_in(rng, i))) for i in range(128)])
    bias = jnp.mean(reps, 0) - x
    assert float(jnp.max(jnp.abs(bias))) < 4 * float(scale) / np.sqrt(128)


def test_compressed_psum_matches_exact_within_quantization():
    """compressed_psum == true sum up to bounded quantization error (runs on a
    1-device mesh via shard_map over a size-1 axis)."""
    from functools import partial
    from repro.compat import make_mesh, shard_map
    from repro.optim import compressed_psum
    mesh = make_mesh((1,), ("pod",))
    x = jnp.linspace(-2.0, 2.0, 256)

    @partial(shard_map, mesh=mesh, in_specs=jax.sharding.PartitionSpec(),
             out_specs=jax.sharding.PartitionSpec())
    def run(v):
        return compressed_psum(v, "pod", jax.random.PRNGKey(0))

    out = run(x)
    scale = float(jnp.max(jnp.abs(x))) / 127.0
    assert float(jnp.max(jnp.abs(out - x))) <= scale + 1e-6


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def _state(v=0.0):
    return {"a": jnp.full((4, 3), v), "nested": {"b": jnp.asarray(int(v))}}


def test_checkpoint_roundtrip():
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 7, _state(3.0), meta={"note": "x"})
        state, step, meta = restore_checkpoint(d, _state())
        assert step == 7 and meta["note"] == "x"
        np.testing.assert_allclose(state["a"], 3.0)


def test_latest_step_ignores_incomplete():
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 5, _state())
        os.makedirs(os.path.join(d, "step_9.tmp"))       # crashed write
        os.makedirs(os.path.join(d, "step_11"))          # missing meta.json
        assert latest_step(d) == 5


def test_checkpoint_shape_mismatch_raises():
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, _state())
        bad_template = {"a": jnp.zeros((2, 2)), "nested": {"b": jnp.asarray(0)}}
        with pytest.raises(ValueError):
            restore_checkpoint(d, bad_template)


def test_manager_async_save_and_gc():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, _state(float(s)))
        mgr.wait()
        steps = sorted(int(n.split("_")[1]) for n in os.listdir(d)
                       if n.startswith("step_") and not n.endswith(".tmp"))
        assert steps == [3, 4]


def test_latest_step_ignores_killed_writer_tmp():
    """A crash-window .tmp dir left by a writer killed mid-save — even one
    with a complete-looking payload inside — must be invisible to discovery
    and to restore."""
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 3, _state(1.0))
        # simulate a killed writer: full payload, but never renamed
        crash = os.path.join(d, "step_9.tmp")
        os.makedirs(crash)
        np.savez(os.path.join(crash, "arrays.npz"), x=np.zeros(2))
        with open(os.path.join(crash, "meta.json"), "w") as fh:
            fh.write('{"step": 9}')
        assert latest_step(d) == 3
        state, step, _ = restore_checkpoint(d, _state())
        assert step == 3
        np.testing.assert_allclose(state["a"], 1.0)
        # age the leftover past the staleness window (a FRESH tmp dir could
        # be another writer's in-flight save and must survive gc)
        old = os.path.getmtime(crash) - CheckpointManager.STALE_TMP_SECONDS - 1
        os.utime(crash, (old, old))
        # the next managed save sweeps the stale tmp dir
        mgr = CheckpointManager(d, keep=2)
        mgr.save(4, _state(2.0))
        mgr.flush()
        assert not os.path.exists(crash)
        assert latest_step(d) == 4


def test_manager_flush_is_wait_and_propagates_errors():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        mgr.save(1, _state(1.0))
        mgr.flush()                          # alias of wait()
        assert latest_step(d) == 1
        # an async write failure must surface at the join, not vanish in the
        # daemon thread: make the target directory un-creatable
        blocker = os.path.join(d, "blocked")
        with open(blocker, "w") as fh:
            fh.write("file where the checkpoint dir should go")
        bad = CheckpointManager(blocker, keep=2)
        bad.save(2, _state())
        with pytest.raises(OSError):
            bad.wait()
        bad.wait()                           # error is consumed, not sticky


def test_manager_blocking_save_raises_inline():
    with tempfile.TemporaryDirectory() as d:
        blocker = os.path.join(d, "blocked")
        with open(blocker, "w") as fh:
            fh.write("x")
        mgr = CheckpointManager(blocker, keep=2)
        with pytest.raises(OSError):
            mgr.save(1, _state(), blocking=True)


def test_elastic_restore_places_with_target_sharding():
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.compat import make_mesh
    mesh = make_mesh((1,), ("data",))
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 3, _state(2.0))
        sh = {"a": NamedSharding(mesh, P("data", None)),
              "nested": {"b": NamedSharding(mesh, P())}}
        state, step, _ = restore_resharded(d, _state(), sh)
        assert step == 3
        assert state["a"].sharding == sh["a"]


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_restartable_loop_exactly_once_semantics():
    with tempfile.TemporaryDirectory() as d:
        def step_fn(state, step):
            return {"x": state["x"] + 1.0,
                    "acc": state["acc"] + step}, {"step": step}

        loop = RestartableLoop(step_fn, d, checkpoint_every=4,
                               injector=FailureInjector(at_steps=(5, 6, 11)))
        res = loop.run({"x": jnp.zeros(()), "acc": jnp.zeros(())}, 16)
        assert float(res.state["x"]) == 16.0
        assert float(res.state["acc"]) == sum(range(16))
        assert loop.restarts == 3


def test_restartable_loop_gives_up_after_max_restarts():
    with tempfile.TemporaryDirectory() as d:
        def bad_step(state, step):
            raise RuntimeError("always broken")

        loop = RestartableLoop(bad_step, d, max_restarts=2)
        with pytest.raises(RuntimeError):
            loop.run({"x": jnp.zeros(())}, 4)


def test_straggler_watchdog_flags_outliers():
    wd = StragglerWatchdog(slow_factor=3.0)
    for i in range(20):
        wd.observe(i, 0.1)
    wd.observe(20, 1.0)
    assert len(wd.stragglers) == 1
    with pytest.raises(TimeoutError):
        StragglerWatchdog(hard_timeout_s=0.5).observe(0, 1.0)
