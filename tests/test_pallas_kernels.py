"""Pallas kernel validation (interpret=True): shape/dtype sweeps against the
pure-jnp oracles, per the kernels/<name>/{kernel,ops,ref}.py contract."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GammaPDF, get_bucket_fn, sample_lsh_params
from repro.core.lsh import featurize as featurize_jnp
from repro.core.wlsh import build_table_index, table_matvec
from repro.kernels.binning import (bin_gather_pallas, bin_gather_ref,
                                   bin_scatter_pallas, bin_scatter_ref)
from repro.kernels.featurize import featurize_op
from repro.kernels.flash_decode import flash_decode_pallas, flash_decode_ref


# ---------------------------------------------------------------------------
# featurize
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,d,m", [(128, 1, 1), (300, 5, 3), (512, 11, 2),
                                   (257, 64, 1), (96, 200, 1)])
@pytest.mark.parametrize("fname", ["rect", "tent", "smooth"])
def test_featurize_kernel_matches_ref(n, d, m, fname):
    key = jax.random.PRNGKey(n + d + m)
    x = jax.random.uniform(key, (n, d)) * 4.0 - 2.0
    params = sample_lsh_params(jax.random.fold_in(key, 1), m, d,
                               GammaPDF(2.0, 1.0))
    f = get_bucket_fn(fname)
    ref = featurize_jnp(params, f, x)
    out = featurize_op(params, f, x, use_kernel=True, interpret=True)
    assert bool(jnp.all(out.key1 == ref.key1))
    assert bool(jnp.all(out.key2 == ref.key2))
    np.testing.assert_allclose(out.weight, ref.weight, atol=2e-6)
    assert bool(jnp.all(out.sign == ref.sign))


def test_featurize_kernel_f32_input_dtypes():
    key = jax.random.PRNGKey(0)
    x64 = np.random.RandomState(0).uniform(size=(256, 3)) * 2.0  # f64 numpy
    params = sample_lsh_params(key, 2, 3, GammaPDF(2.0, 1.0))
    f = get_bucket_fn("rect")
    out = featurize_op(params, f, jnp.asarray(x64), interpret=True)
    ref = featurize_jnp(params, f, jnp.asarray(x64, jnp.float32))
    assert bool(jnp.all(out.key1 == ref.key1))


# ---------------------------------------------------------------------------
# binning (scatter / gather as one-hot MXU matmuls)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n,b", [(1, 128, 512), (3, 1024, 1024),
                                   (2, 256, 2048), (2, 1024, 512)])
def test_bin_scatter_gather_match_ref(m, n, b):
    key = jax.random.PRNGKey(m * n)
    slot = jax.random.randint(key, (m, n), 0, b, dtype=jnp.int32)
    contrib = jax.random.normal(jax.random.fold_in(key, 1), (m, n))
    t_k = bin_scatter_pallas(slot, contrib, table_size=b, interpret=True,
                             block_n=min(1024, n), block_t=min(512, b))
    t_r = bin_scatter_ref(slot, contrib, table_size=b)
    np.testing.assert_allclose(t_k, t_r, atol=1e-4)
    g_k = bin_gather_pallas(slot, t_k, interpret=True,
                            block_n=min(1024, n), block_t=min(512, b))
    np.testing.assert_allclose(g_k, bin_gather_ref(slot, t_r), atol=1e-4)


def test_table_matvec_op_matches_core(rng):
    from repro.kernels.binning.ops import table_matvec_op
    n, d, m, b = 300, 3, 6, 1024
    x = jax.random.uniform(rng, (n, d)) * 2.0
    params = sample_lsh_params(jax.random.fold_in(rng, 1), m, d,
                               GammaPDF(2.0, 1.0))
    feats = featurize_jnp(params, get_bucket_fn("rect"), x)
    idx = build_table_index(feats, b)
    beta = jax.random.normal(jax.random.fold_in(rng, 2), (n,))
    np.testing.assert_allclose(table_matvec_op(idx, beta, interpret=True),
                               table_matvec(idx, beta), atol=1e-4)


# ---------------------------------------------------------------------------
# flash decode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,kv,g,d,t", [(1, 1, 1, 64, 256), (2, 2, 3, 64, 512),
                                        (2, 4, 1, 128, 256), (2, 1, 4, 128, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_matches_ref(b, kv, g, d, t, dtype):
    key = jax.random.PRNGKey(b * t + d)
    q = jax.random.normal(key, (b, kv, g, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, t, kv, d)).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, t, kv, d)).astype(dtype)
    lens = jax.random.randint(jax.random.fold_in(key, 3), (b, 1), 1, t + 1)
    valid = (jnp.arange(t)[None, :] < lens).astype(jnp.int32)
    out_k = flash_decode_pallas(q, k, v, valid, interpret=True, block_t=256)
    out_r = flash_decode_ref(q, k, v, valid)
    np.testing.assert_allclose(out_k, out_r, atol=3e-6 if dtype == jnp.float32
                               else 3e-3)


def test_flash_decode_single_valid_row():
    """Degenerate mask (one valid key) must return exactly that value row."""
    b, kv, g, d, t = 2, 1, 2, 32, 128
    key = jax.random.PRNGKey(9)
    q = jax.random.normal(key, (b, kv, g, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, t, kv, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, t, kv, d))
    valid = jnp.zeros((b, t), jnp.int32).at[:, 0].set(1)
    out = flash_decode_pallas(q, k, v, valid, interpret=True, block_t=64)
    np.testing.assert_allclose(out, jnp.broadcast_to(
        v[:, 0][:, :, None, :], out.shape), atol=1e-5)
