"""Chaos suite: every injected fault ends in a documented recovery or a
structured error — never a hang, never a silently-wrong result.

The injection harness is src/repro/testing/faults.py; the recovery ladder it
exercises is DESIGN.md §9.  Everything here is DETERMINISTIC: FaultPlan masks
come from fixed PRNG seeds, preemptions fire after exact checkpoint counts,
and the assertions pin exact recovery behavior (counter values, error types,
resume tolerances), not coin flips.

CI runs this file both in the default single-device job and nightly under a
4-device mesh (XLA_FLAGS=--xla_force_host_platform_device_count=4) — the
chaos job in .github/workflows/ci.yml.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import WLSHKernelSpec, get_bucket_fn, wlsh_krr_fit
from repro.core.krr import pcg_solve
from repro.errors import (FaultInjected, NonFiniteError, SolveDivergedError,
                          WireOverflowError)
from repro.testing import (FaultPlan, killed_checkpoint_writer, poison_matvec,
                           preempt_after)


# ---------------------------------------------------------------------------
# problem factories
# ---------------------------------------------------------------------------

def _spd_problem(n=64, k=3, seed=0):
    """Small SPD system for direct pcg_solve tests."""
    key = jax.random.PRNGKey(seed)
    a = jax.random.normal(key, (n, n))
    a = a @ a.T / n + jnp.eye(n)
    b = jax.random.normal(jax.random.fold_in(key, 1), (n, k))
    return (lambda v: a @ v), a, b


def _fit_problem(n=384, d=3, seed=0):
    key = jax.random.PRNGKey(seed)
    x = jax.random.uniform(key, (n, d)) * 2.0
    y = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    spec = WLSHKernelSpec(bucket=get_bucket_fn("rect"))
    return key, x, y, spec


def _hj_setup(**cfg_kw):
    from repro.compat import make_mesh
    from repro.core import GammaPDF, sample_lsh_params
    from repro.core.distributed import KRRStepConfig
    key = jax.random.PRNGKey(6)
    x = jax.random.uniform(key, (192, 3)) * 2.0
    y = jax.random.normal(jax.random.fold_in(key, 1), (192,))
    lsh = sample_lsh_params(jax.random.fold_in(key, 2), 4, 3,
                            GammaPDF(2.0, 1.0))
    f = get_bucket_fn("rect")
    mesh = make_mesh((1, 1, 1), ("pod", "data", "model"))
    cfg = KRRStepConfig(m=4, table_size=512, lam=0.5, cg_iters=15,
                        data_axes=("pod", "data"), model_axis="model",
                        backend="reference", **cfg_kw)
    return mesh, cfg, f, x, y, lsh


# ---------------------------------------------------------------------------
# solver sentinels: poisoned matvec, NaN targets, chunked-loop parity
# ---------------------------------------------------------------------------

def test_poisoned_matvec_deactivates_column_others_converge():
    """NaN in one matvec output column: that column freezes at its last
    finite iterate with a NaN resnorm SENTINEL; the healthy columns converge
    exactly as they would alone."""
    mv, a, b = _spd_problem()
    clean = pcg_solve(mv, b, 0.5, tol=1e-8, maxiter=100)
    res = pcg_solve(poison_matvec(mv, column=1), b, 0.5, tol=1e-8,
                    maxiter=100)
    assert bool(jnp.isfinite(res.x).all())          # never garbage iterates
    assert not bool(jnp.isfinite(res.resnorm[1]))   # sentinel on the column
    for j in (0, 2):                                # healthy columns clean
        assert bool(jnp.isfinite(res.resnorm[j]))
        np.testing.assert_allclose(np.asarray(res.x[:, j]),
                                   np.asarray(clean.x[:, j]), atol=1e-6)


def test_pcg_chunked_checkpointing_matches_single_shot():
    """checkpoint_every chunks the while_loop on the host; the math must be
    IDENTICAL to the historical single while_loop — same body, same order."""
    mv, a, b = _spd_problem()
    one = pcg_solve(mv, b, 0.5, tol=1e-8, maxiter=60)
    seen = []
    chunked = pcg_solve(mv, b, 0.5, tol=1e-8, maxiter=60,
                        checkpoint_every=7, on_checkpoint=seen.append)
    np.testing.assert_array_equal(np.asarray(one.x), np.asarray(chunked.x))
    np.testing.assert_array_equal(np.asarray(one.resnorm),
                                  np.asarray(chunked.resnorm))
    assert len(seen) >= 2                           # it really chunked
    assert int(seen[0].it) == 7


def test_nan_training_target_raises_structured():
    key, x, y, spec = _fit_problem()
    y = y.at[5].set(jnp.nan)
    with pytest.raises(NonFiniteError) as ei:
        wlsh_krr_fit(key, x, y, spec, m=32, lam=0.5, backend="reference")
    assert ei.value.where == "y"
    assert ei.value.count == 1


def test_nan_target_deactivate_mode_freezes_column():
    """nonfinite_targets='deactivate': the poisoned column reports a NaN
    resnorm, beta stays finite, the clean column matches a clean fit."""
    key, x, y, spec = _fit_problem()
    yk = jnp.stack([y, y], axis=1).at[5, 1].set(jnp.nan)
    model = wlsh_krr_fit(key, x, yk, spec, m=32, lam=0.5,
                         backend="reference", maxiter=40,
                         nonfinite_targets="deactivate")
    assert bool(jnp.isfinite(model.beta).all())
    assert bool(jnp.isfinite(model.cg_resnorm[0]))
    assert not bool(jnp.isfinite(model.cg_resnorm[1]))
    clean = wlsh_krr_fit(key, x, y, spec, m=32, lam=0.5,
                         backend="reference", maxiter=40)
    # block matvec regroups sums vs the single-RHS path; ulps amplify over
    # 40 CG iterations (same band the multi-RHS parity tests pin)
    np.testing.assert_allclose(np.asarray(model.beta[:, 0]),
                               np.asarray(clean.beta), atol=1e-4)


def test_broken_preconditioner_falls_back_to_identity(monkeypatch):
    """A preconditioner whose apply returns NaN diverges the first solve;
    the fit restarts ONCE with the identity preconditioner, records the
    fallback on the model, and matches an unpreconditioned fit."""
    import repro.core.krr as krr_mod

    class _Poisoned:
        def apply(self, r):
            return r * jnp.nan

    key, x, y, spec = _fit_problem()
    clean = wlsh_krr_fit(key, x, y, spec, m=32, lam=0.5,
                         backend="reference", maxiter=40)
    monkeypatch.setattr(krr_mod, "make_preconditioner",
                        lambda *a, **kw: _Poisoned())
    with pytest.warns(RuntimeWarning, match="identity"):
        model = wlsh_krr_fit(key, x, y, spec, m=32, lam=0.5,
                             backend="reference", maxiter=40,
                             precond="jacobi")
    assert model.solve_fallback == "precond:jacobi->identity"
    assert bool(jnp.isfinite(model.beta).all())
    np.testing.assert_allclose(np.asarray(model.beta),
                               np.asarray(clean.beta), atol=1e-6)


# ---------------------------------------------------------------------------
# PCG checkpoint / resume (acceptance: preempted fit resumes within 1e-6)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_preempted_fit_resumes_within_tolerance(backend, tmp_path):
    """ACCEPTANCE: a fit killed at a checkpoint boundary, re-run with the
    same arguments, resumes from the persisted SolveState and lands within
    1e-6 relative L2 of the uninterrupted solve — on both backends."""
    key, x, y, spec = _fit_problem()
    kw = dict(m=32, lam=0.5, backend=backend, maxiter=40, tol=1e-10)
    clean = wlsh_krr_fit(key, x, y, spec, **kw)
    ckdir = str(tmp_path / "solve_ck")
    with pytest.raises(FaultInjected):
        wlsh_krr_fit(key, x, y, spec, **kw, solve_checkpoint_dir=ckdir,
                     solve_checkpoint_every=5,
                     on_solve_checkpoint=preempt_after(2))
    # the kill left a usable state on disk, partway through the solve
    from repro.checkpoint.store import latest_step
    it_saved = latest_step(ckdir)
    assert it_saved is not None and 0 < it_saved < 40
    resumed = wlsh_krr_fit(key, x, y, spec, **kw,
                           solve_checkpoint_dir=ckdir,
                           solve_checkpoint_every=5)
    ref = np.asarray(clean.beta)
    got = np.asarray(resumed.beta)
    rel = float(np.linalg.norm(got - ref) / np.linalg.norm(ref))
    assert rel <= 1e-6, f"resume drifted {rel} from uninterrupted solve"


def test_killed_checkpoint_writer_leaves_no_half_checkpoint(tmp_path):
    """A writer killed between arrays.npz and the rename leaves a .tmp dir
    that latest_step ignores; the NEXT save lands cleanly and restore reads
    it — the crash window can delay progress but never corrupt it."""
    from repro.checkpoint.store import (latest_step, restore_checkpoint,
                                        save_checkpoint)
    state = {"a": np.arange(6, dtype=np.float32)}
    d = str(tmp_path / "ck")
    save_checkpoint(d, 1, state)
    with killed_checkpoint_writer():
        with pytest.raises(FaultInjected):
            save_checkpoint(d, 2, {"a": np.zeros(6, np.float32)})
    assert os.path.isdir(os.path.join(d, "step_2.tmp"))   # SIGKILL debris
    assert latest_step(d) == 1                  # half-save is invisible
    save_checkpoint(d, 2, {"a": np.full(6, 7.0, np.float32)})
    got, step, _ = restore_checkpoint(d, {"a": np.zeros(6, np.float32)})
    assert step == 2
    np.testing.assert_array_equal(got["a"], np.full(6, 7.0, np.float32))


def test_preemption_mid_save_resumes_from_previous_chunk(tmp_path):
    """Composition: the checkpoint WRITER dies mid-save during a fit.  The
    fit surfaces the failure (CheckpointManager re-raises on blocking saves);
    the re-run resumes from the last COMPLETE chunk, not the torn one."""
    key, x, y, spec = _fit_problem()
    kw = dict(m=32, lam=0.5, backend="reference", maxiter=40, tol=1e-10)
    ckdir = str(tmp_path / "solve_ck")
    with killed_checkpoint_writer(after_saves=2):
        with pytest.raises(FaultInjected):
            wlsh_krr_fit(key, x, y, spec, **kw, solve_checkpoint_dir=ckdir,
                         solve_checkpoint_every=5)
    from repro.checkpoint.store import latest_step
    assert latest_step(ckdir) == 10             # two complete chunks of 5
    clean = wlsh_krr_fit(key, x, y, spec, **kw)
    resumed = wlsh_krr_fit(key, x, y, spec, **kw,
                           solve_checkpoint_dir=ckdir,
                           solve_checkpoint_every=5)
    rel = float(np.linalg.norm(np.asarray(resumed.beta - clean.beta))
                / np.linalg.norm(np.asarray(clean.beta)))
    assert rel <= 1e-6


# ---------------------------------------------------------------------------
# hash-join wire faults: drops, NaN poisoning, the bf16->f32 retry ladder
# ---------------------------------------------------------------------------

def test_wire_drop_stays_finite_and_close():
    """Dropped wire cells lose mass (like capacity overflow) but can never
    destabilize the solve: beta stays finite and near the clean solve."""
    from repro.core.distributed import make_krr_step_hashjoin
    mesh, cfg, f, x, y, lsh = _hj_setup()
    b0, _, _, _ = jax.jit(make_krr_step_hashjoin(
        mesh, cfg, f, payload_dtype=jnp.float32))(x, y, lsh)
    cfg_drop = cfg._replace(fault_plan=FaultPlan(wire_drop_frac=0.05,
                                                 seed=3))
    b1, r1, _, _ = jax.jit(make_krr_step_hashjoin(
        mesh, cfg_drop, f, payload_dtype=jnp.float32))(x, y, lsh)
    assert bool(jnp.isfinite(b1).all())
    assert bool(jnp.isfinite(r1).all())
    rel = float(jnp.linalg.norm(b1 - b0) / jnp.linalg.norm(b0))
    assert 0.0 < rel < 0.5                      # perturbed, not destroyed


def test_bf16_poison_recovers_via_f32_wire_retry():
    """RECOVERY: NaN poisoning restricted to bf16 payloads diverges the
    default wire; run_krr_step_resilient detects the NaN resnorm sentinel,
    retries once on an f32 wire, and returns a finite solve."""
    from repro.core.distributed import (make_krr_step_hashjoin,
                                        run_krr_step_resilient)
    mesh, cfg, f, x, y, lsh = _hj_setup(
        fault_plan=FaultPlan(wire_nan_frac=0.2, wire_nan_bf16_only=True,
                             seed=5))
    # the bf16 wire really is poisoned...
    _, r_bf16, _, _ = jax.jit(make_krr_step_hashjoin(mesh, cfg, f))(x, y,
                                                                    lsh)
    assert not bool(jnp.isfinite(r_bf16).all())
    # ...and the resilient runner climbs to f32 and lands finite
    with pytest.warns(RuntimeWarning, match="f32"):
        beta, resnorm, tables, stats = run_krr_step_resilient(
            mesh, cfg, f, x, y, lsh)
    assert bool(jnp.isfinite(beta).all())
    assert bool(jnp.isfinite(resnorm).all())
    assert bool(jnp.isfinite(tables).all())


def test_unrecoverable_wire_poison_raises_structured():
    """NaN poisoning on EVERY wire dtype exhausts the ladder: the runner
    raises SolveDivergedError naming the fallback it tried — a structured
    error, never a silently-NaN beta."""
    from repro.core.distributed import run_krr_step_resilient
    mesh, cfg, f, x, y, lsh = _hj_setup(
        fault_plan=FaultPlan(wire_nan_frac=0.2, seed=5))
    with pytest.warns(RuntimeWarning):
        with pytest.raises(SolveDivergedError) as ei:
            run_krr_step_resilient(mesh, cfg, f, x, y, lsh)
    assert "wire:bf16->f32" in ei.value.fallbacks


def test_overflow_policy_raise_warn_allow():
    """cap_factor=0.05 forces drops; the SAME counters drive all three
    policies: raise -> WireOverflowError with the count, warn -> RuntimeWarning,
    allow -> silent (but still counted)."""
    from repro.core.distributed import run_krr_step_resilient
    mesh, cfg, f, x, y, lsh = _hj_setup()
    with pytest.raises(WireOverflowError) as ei:
        run_krr_step_resilient(mesh, cfg._replace(overflow="raise"), f,
                               x, y, lsh, cap_factor=0.05,
                               payload_dtype=jnp.float32)
    assert ei.value.dropped > 0
    with pytest.warns(RuntimeWarning, match="dropped"):
        _, _, _, stats = run_krr_step_resilient(
            mesh, cfg._replace(overflow="warn"), f, x, y, lsh,
            cap_factor=0.05, payload_dtype=jnp.float32)
    assert int(stats.overflow_dropped) == ei.value.dropped  # deterministic
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error")                # allow must stay silent
        _, _, _, stats2 = run_krr_step_resilient(
            mesh, cfg._replace(overflow="allow"), f, x, y, lsh,
            cap_factor=0.05, payload_dtype=jnp.float32)
    assert int(stats2.overflow_dropped) == ei.value.dropped


def test_overflow_policy_rejects_unknown():
    from repro.core.distributed import StepStats, check_step_stats
    stats = StepStats(overflow_dropped=np.int32(0),
                      wire_nonfinite=np.int32(0))
    with pytest.raises(ValueError, match="overflow"):
        check_step_stats(stats, overflow="panic")


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs >= 2 devices (CI chaos job sets "
                           "XLA_FLAGS=--xla_force_host_platform_device_count=4)")
def test_wire_poison_recovery_on_real_mesh():
    """The bf16->f32 retry ladder over REAL all_to_alls (2-way data mesh):
    the same FaultPlan poisons the same cells on every shard, the sentinel
    fires globally (psum'd counters), and the f32 retry lands finite."""
    from repro.compat import make_mesh
    from repro.core import GammaPDF, sample_lsh_params
    from repro.core.distributed import KRRStepConfig, run_krr_step_resilient
    key = jax.random.PRNGKey(6)
    x = jax.random.uniform(key, (256, 3)) * 2.0
    y = jax.random.normal(jax.random.fold_in(key, 1), (256,))
    lsh = sample_lsh_params(jax.random.fold_in(key, 2), 4, 3,
                            GammaPDF(2.0, 1.0))
    mesh = make_mesh((1, 2, 1), ("pod", "data", "model"))
    cfg = KRRStepConfig(m=4, table_size=1024, lam=0.5, cg_iters=15,
                        data_axes=("pod", "data"), model_axis="model",
                        backend="reference",
                        fault_plan=FaultPlan(wire_nan_frac=0.2,
                                             wire_nan_bf16_only=True,
                                             seed=5))
    with pytest.warns(RuntimeWarning, match="f32"):
        beta, resnorm, tables, stats = run_krr_step_resilient(
            mesh, cfg, get_bucket_fn("rect"), x, y, lsh, cap_factor=4.0)
    assert bool(jnp.isfinite(beta).all())
    assert bool(jnp.isfinite(resnorm).all())


def test_stalled_shard_holds_up_the_step_wall_clock():
    """A stalled shard delays every collective it participates in: the step
    with a 0.4s stall takes >= 0.4s wall clock.  The detection signal in CI
    is pytest-timeout on the chaos job; here we pin the injection works."""
    import time
    from repro.core.distributed import make_krr_step_hashjoin
    mesh, cfg, f, x, y, lsh = _hj_setup(
        fault_plan=FaultPlan(stall_shard=0, stall_s=0.4))
    step = jax.jit(make_krr_step_hashjoin(mesh, cfg, f,
                                          payload_dtype=jnp.float32))
    jax.block_until_ready(step(x, y, lsh))      # compile outside the clock
    t0 = time.perf_counter()
    jax.block_until_ready(step(x, y, lsh))
    assert time.perf_counter() - t0 >= 0.4
