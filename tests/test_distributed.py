"""Distributed KRR vs single-device reference.  Runs in a SUBPROCESS with 8
fake CPU devices (the flag must be set before jax initializes, which pytest's
main process has already done)."""
import subprocess
import sys

import pytest

_SCRIPT = r"""
import jax, jax.numpy as jnp
from repro.compat import make_mesh
from repro.core import sample_lsh_params, GammaPDF, get_bucket_fn, featurize
from repro.core.wlsh import build_table_index, table_matvec
from repro.core.krr import cg_solve
from repro.core.distributed import KRRStepConfig, make_krr_step, make_krr_predict

assert len(jax.devices()) == 8
mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
n, d, m, B = 256, 4, 8, 512
key = jax.random.PRNGKey(0)
x = jax.random.uniform(key, (n, d)) * 2.0
y = jax.random.normal(jax.random.PRNGKey(1), (n,))
lsh = sample_lsh_params(jax.random.PRNGKey(2), m, d, GammaPDF(2.0, 1.0))
f = get_bucket_fn("rect")
cfg = KRRStepConfig(m=m, table_size=B, lam=0.5, cg_iters=25,
                    data_axes=("pod", "data"), model_axis="model")
beta, resnorm, tables = jax.jit(make_krr_step(mesh, cfg, f))(x, y, lsh)

feats = featurize(lsh, f, x)
idx = build_table_index(feats, B)
ref = cg_solve(lambda v: table_matvec(idx, v), y, 0.5, tol=0.0, maxiter=25)
err = float(jnp.max(jnp.abs(jax.device_get(beta) - ref.x)))
assert err < 1e-3, f"beta mismatch {err}"

pred = jax.jit(make_krr_predict(mesh, cfg, f))(x, lsh, tables)
err2 = float(jnp.max(jnp.abs(pred - table_matvec(idx, ref.x))))
assert err2 < 1e-3, f"predict mismatch {err2}"
print("DISTRIBUTED_OK", err, err2)
"""


@pytest.mark.slow
def test_distributed_krr_matches_reference():
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        env={"PYTHONPATH": "src", "JAX_PLATFORMS": "cpu",
             "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
             "PATH": "/usr/bin:/bin"},
        capture_output=True, text=True, cwd=".", timeout=420)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "DISTRIBUTED_OK" in proc.stdout


_DP_SCRIPT = r"""
import jax, jax.numpy as jnp
from functools import partial
from jax.sharding import PartitionSpec as P
from repro.compat import make_mesh, shard_map
from repro.optim import compressed_psum

assert len(jax.devices()) == 8
mesh = make_mesh((8,), ("pod",))
x = jnp.arange(8 * 64, dtype=jnp.float32).reshape(8, 64) / 100.0

@partial(shard_map, mesh=mesh, in_specs=P("pod"), out_specs=P("pod"))
def summed(v):
    local = v[0]
    return compressed_psum(local, "pod", jax.random.PRNGKey(0))[None]

out = summed(x)
exact = jnp.sum(x, axis=0)
err = float(jnp.max(jnp.abs(out[0] - exact)))
scale = float(jnp.max(jnp.abs(x))) / 127.0
assert err <= 8 * scale + 1e-6, (err, scale)
print("COMPRESSED_PSUM_OK", err)
"""


@pytest.mark.slow
def test_compressed_psum_across_8_devices():
    proc = subprocess.run(
        [sys.executable, "-c", _DP_SCRIPT],
        env={"PYTHONPATH": "src", "JAX_PLATFORMS": "cpu",
             "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
             "PATH": "/usr/bin:/bin"},
        capture_output=True, text=True, cwd=".", timeout=420)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "COMPRESSED_PSUM_OK" in proc.stdout


_HJ_SCRIPT = r"""
import jax, jax.numpy as jnp
from repro.compat import make_mesh
from repro.core import sample_lsh_params, GammaPDF, get_bucket_fn
from repro.core.distributed import (KRRStepConfig, make_krr_step,
                                    make_krr_step_hashjoin)

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
n, d, m, B = 512, 5, 8, 1024
key = jax.random.PRNGKey(0)
x = jax.random.uniform(key, (n, d)) * 2.0
y = jax.random.normal(jax.random.PRNGKey(1), (n,))
lsh = sample_lsh_params(jax.random.PRNGKey(2), m, d, GammaPDF(2.0, 1.0))
f = get_bucket_fn("rect")
cfg = KRRStepConfig(m=m, table_size=B, lam=0.5, cg_iters=25,
                    data_axes=("pod", "data"), model_axis="model")
b1, r1, _ = jax.jit(make_krr_step(mesh, cfg, f))(x, y, lsh)
b2, r2, _ = jax.jit(make_krr_step_hashjoin(mesh, cfg, f, cap_factor=8.0))(
    x, y, lsh)
err = float(jnp.max(jnp.abs(jax.device_get(b1) - jax.device_get(b2))))
assert err < 1e-4, f"hashjoin != psum: {err}"
print("HASHJOIN_OK", err)
"""


@pytest.mark.slow
def test_hashjoin_krr_matches_psum_mode():
    """The beyond-paper hash-join table mode solves the same system as the
    paper-faithful psum mode (generous routing capacity => no drops)."""
    proc = subprocess.run(
        [sys.executable, "-c", _HJ_SCRIPT],
        env={"PYTHONPATH": "src", "JAX_PLATFORMS": "cpu",
             "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
             "PATH": "/usr/bin:/bin"},
        capture_output=True, text=True, cwd=".", timeout=420)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "HASHJOIN_OK" in proc.stdout
