"""Distributed KRR vs single-device reference.

Two tiers:

* **in-process** — the tests below run directly whenever the pytest process
  already sees >= 2 devices (the CI ``multidevice`` job sets
  ``XLA_FLAGS=--xla_force_host_platform_device_count=2`` before pytest
  starts), so the sharded psum/collective paths are exercised for real, not
  only under subprocess mocks.  With one device they skip.
* **subprocess** (slow tier) — 8 fake CPU devices spawned per test (the
  flag must be set before jax initializes, which pytest's main process has
  already done when it only sees one device).
"""
import functools
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

needs_multi = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs >= 2 devices (CI multidevice job sets "
           "XLA_FLAGS=--xla_force_host_platform_device_count=2)")

needs_4 = pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="needs >= 4 devices (CI multidevice job sets "
           "XLA_FLAGS=--xla_force_host_platform_device_count=4)")


def _mesh_2shard():
    from repro.compat import make_mesh
    return make_mesh((1, 2, 1), ("pod", "data", "model"))


def _problem(n=256, d=4, m=4, table_size=1024):
    from repro.core import GammaPDF, featurize, get_bucket_fn, \
        sample_lsh_params
    from repro.core.wlsh import build_table_index
    key = jax.random.PRNGKey(0)
    x = jax.random.uniform(key, (n, d)) * 2.0
    beta = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    lsh = sample_lsh_params(jax.random.fold_in(key, 2), m, d,
                            GammaPDF(2.0, 1.0))
    f = get_bucket_fn("rect")
    idx = build_table_index(featurize(lsh, f, x), table_size)
    return x, beta, lsh, f, idx


@needs_multi
@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_psum_matvec_2shards_matches_single_device(backend):
    """Satellite acceptance: a 2-shard CPU-mesh psum matvec matches the
    single-device split matvec <= 1e-6 — on the pallas backend through the
    blocked visit-list split kernels (the index carries the layout)."""
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    from repro.core.distributed import (KRRStepConfig,
                                        _shard_operator,
                                        make_distributed_matvec)
    from repro.core.lsh import LSHParams
    from repro.core.wlsh import table_matvec
    n, m, table_size = 256, 4, 1024
    x, beta, lsh, f, idx = _problem(n=n, m=m, table_size=table_size)
    mesh = _mesh_2shard()
    cfg = KRRStepConfig(m=m, table_size=table_size, lam=0.5, cg_iters=5,
                        data_axes=("pod", "data"), model_axis="model",
                        backend=backend)
    lsh_specs = LSHParams(w=P("model", None), z=P("model", None),
                          r1=P("model", None), r2=P("model", None))

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(("pod", "data"), None), P(("pod", "data")), lsh_specs),
        out_specs=P(("pod", "data")))
    def mv(x_local, beta_local, lsh_local):
        op = _shard_operator(cfg, f, lsh_local, fused=False)
        i = op.build_index(op.featurize(x_local),
                           blocked=backend == "pallas")
        return make_distributed_matvec(cfg, op, n_data_shards=2)(
            i, beta_local)

    got = jax.jit(mv)(x, beta, lsh)
    want = table_matvec(idx, beta)
    assert float(jnp.max(jnp.abs(got - want))) <= 1e-6


@needs_multi
def test_krr_step_2shards_blocked_split_matches_cross_product():
    """cfg.blocked_split toggles only the kernel schedule, not the math:
    the 2-shard pallas step agrees with the cross-product step and with the
    reference step.  Converged solves (cg_iters=50, resnorm ~1e-7) — a
    fixed-iteration CG amplifies ulp-level matvec differences to residual
    scale before convergence, so mid-solve betas are not comparable."""
    from repro.core.distributed import KRRStepConfig, make_krr_step
    n, m, table_size = 256, 4, 1024
    x, _, lsh, f, _ = _problem(n=n, m=m, table_size=table_size)
    y = jax.random.normal(jax.random.PRNGKey(3), (n,))
    mesh = _mesh_2shard()
    base = KRRStepConfig(m=m, table_size=table_size, lam=0.5, cg_iters=50,
                         data_axes=("pod", "data"), model_axis="model",
                         backend="pallas")
    b_blk, _, t_blk = jax.jit(make_krr_step(mesh, base, f))(x, y, lsh)
    b_x, _, t_x = jax.jit(make_krr_step(
        mesh, base._replace(blocked_split=False), f))(x, y, lsh)
    b_ref, _, _ = jax.jit(make_krr_step(
        mesh, base._replace(backend="reference"), f))(x, y, lsh)
    np.testing.assert_allclose(np.asarray(b_blk), np.asarray(b_x),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(t_blk), np.asarray(t_x),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(b_blk), np.asarray(b_ref),
                               atol=1e-4)


@needs_multi
def test_psum_matvec_2shards_multi_rhs():
    """An (n, k) RHS block through the 2-shard psum sandwich (blocked split
    kernels) matches k single-device matvec columns <= 1e-6."""
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    from repro.core.distributed import (KRRStepConfig,
                                        _shard_operator,
                                        make_distributed_matvec)
    from repro.core.lsh import LSHParams
    from repro.core.wlsh import table_matvec
    n, m, table_size, k = 256, 4, 1024, 3
    x, _, lsh, f, idx = _problem(n=n, m=m, table_size=table_size)
    bk = jax.random.normal(jax.random.PRNGKey(5), (n, k))
    mesh = _mesh_2shard()
    cfg = KRRStepConfig(m=m, table_size=table_size, lam=0.5, cg_iters=5,
                        data_axes=("pod", "data"), model_axis="model",
                        backend="pallas")
    lsh_specs = LSHParams(w=P("model", None), z=P("model", None),
                          r1=P("model", None), r2=P("model", None))

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(("pod", "data"), None), P(("pod", "data"), None),
                  lsh_specs),
        out_specs=P(("pod", "data"), None))
    def mv(x_local, bk_local, lsh_local):
        op = _shard_operator(cfg, f, lsh_local, fused=False)
        i = op.build_index(op.featurize(x_local), blocked=True)
        return make_distributed_matvec(cfg, op, n_data_shards=2)(
            i, bk_local)

    got = jax.jit(mv)(x, bk, lsh)
    want = table_matvec(idx, bk)
    # k columns accumulate k× the summation-order noise of the 1e-6
    # single-RHS bound
    assert float(jnp.max(jnp.abs(got - want))) <= 2e-6

_SCRIPT = r"""
import jax, jax.numpy as jnp
from repro.compat import make_mesh
from repro.core import sample_lsh_params, GammaPDF, get_bucket_fn, featurize
from repro.core.wlsh import build_table_index, table_matvec
from repro.core.krr import cg_solve
from repro.core.distributed import KRRStepConfig, make_krr_step, make_krr_predict

assert len(jax.devices()) == 8
mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
n, d, m, B = 256, 4, 8, 512
key = jax.random.PRNGKey(0)
x = jax.random.uniform(key, (n, d)) * 2.0
y = jax.random.normal(jax.random.PRNGKey(1), (n,))
lsh = sample_lsh_params(jax.random.PRNGKey(2), m, d, GammaPDF(2.0, 1.0))
f = get_bucket_fn("rect")
cfg = KRRStepConfig(m=m, table_size=B, lam=0.5, cg_iters=25,
                    data_axes=("pod", "data"), model_axis="model")
beta, resnorm, tables = jax.jit(make_krr_step(mesh, cfg, f))(x, y, lsh)

feats = featurize(lsh, f, x)
idx = build_table_index(feats, B)
ref = cg_solve(lambda v: table_matvec(idx, v), y, 0.5, tol=0.0, maxiter=25)
err = float(jnp.max(jnp.abs(jax.device_get(beta) - ref.x)))
assert err < 1e-3, f"beta mismatch {err}"

pred = jax.jit(make_krr_predict(mesh, cfg, f))(x, lsh, tables)
err2 = float(jnp.max(jnp.abs(pred - table_matvec(idx, ref.x))))
assert err2 < 1e-3, f"predict mismatch {err2}"
print("DISTRIBUTED_OK", err, err2)
"""


@pytest.mark.slow
def test_distributed_krr_matches_reference():
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        env={"PYTHONPATH": "src", "JAX_PLATFORMS": "cpu",
             "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
             "PATH": "/usr/bin:/bin"},
        capture_output=True, text=True, cwd=".", timeout=420)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "DISTRIBUTED_OK" in proc.stdout


_DP_SCRIPT = r"""
import jax, jax.numpy as jnp
from functools import partial
from jax.sharding import PartitionSpec as P
from repro.compat import make_mesh, shard_map
from repro.optim import compressed_psum

assert len(jax.devices()) == 8
mesh = make_mesh((8,), ("pod",))
x = jnp.arange(8 * 64, dtype=jnp.float32).reshape(8, 64) / 100.0

@partial(shard_map, mesh=mesh, in_specs=P("pod"), out_specs=P("pod"))
def summed(v):
    local = v[0]
    return compressed_psum(local, "pod", jax.random.PRNGKey(0))[None]

out = summed(x)
exact = jnp.sum(x, axis=0)
err = float(jnp.max(jnp.abs(out[0] - exact)))
scale = float(jnp.max(jnp.abs(x))) / 127.0
assert err <= 8 * scale + 1e-6, (err, scale)
print("COMPRESSED_PSUM_OK", err)
"""


@pytest.mark.slow
def test_compressed_psum_across_8_devices():
    proc = subprocess.run(
        [sys.executable, "-c", _DP_SCRIPT],
        env={"PYTHONPATH": "src", "JAX_PLATFORMS": "cpu",
             "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
             "PATH": "/usr/bin:/bin"},
        capture_output=True, text=True, cwd=".", timeout=420)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "COMPRESSED_PSUM_OK" in proc.stdout


_HJ_SCRIPT = r"""
import jax, jax.numpy as jnp
from repro.compat import make_mesh
from repro.core import sample_lsh_params, GammaPDF, get_bucket_fn
from repro.core.distributed import (KRRStepConfig, make_krr_step,
                                    make_krr_step_hashjoin)

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
n, d, m, B = 512, 5, 8, 1024
key = jax.random.PRNGKey(0)
x = jax.random.uniform(key, (n, d)) * 2.0
y = jax.random.normal(jax.random.PRNGKey(1), (n,))
lsh = sample_lsh_params(jax.random.PRNGKey(2), m, d, GammaPDF(2.0, 1.0))
f = get_bucket_fn("rect")
cfg = KRRStepConfig(m=m, table_size=B, lam=0.5, cg_iters=25,
                    data_axes=("pod", "data"), model_axis="model")
b1, r1, _ = jax.jit(make_krr_step(mesh, cfg, f))(x, y, lsh)
b2, r2, _, _ = jax.jit(make_krr_step_hashjoin(mesh, cfg, f, cap_factor=8.0,
                                              payload_dtype=jnp.float32))(
    x, y, lsh)
err = float(jnp.max(jnp.abs(jax.device_get(b1) - jax.device_get(b2))))
assert err < 1e-4, f"hashjoin != psum: {err}"
# the default bf16 wire stays within the pinned accuracy band of the f32 run
b3, _, _, _ = jax.jit(make_krr_step_hashjoin(mesh, cfg, f, cap_factor=8.0))(
    x, y, lsh)
b2h, b3h = jax.device_get(b2), jax.device_get(b3)
rel = float(jnp.linalg.norm(b3h - b2h) / jnp.linalg.norm(b2h))
assert rel < 1e-2, f"bf16 wire drift {rel}"
print("HASHJOIN_OK", err, rel)
"""


def _hj_problem(n=192, d=3, m=4, table_size=512):
    from repro.core import GammaPDF, get_bucket_fn, sample_lsh_params
    key = jax.random.PRNGKey(6)
    x = jax.random.uniform(key, (n, d)) * 2.0
    y = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    lsh = sample_lsh_params(jax.random.fold_in(key, 2), m, d,
                            GammaPDF(2.0, 1.0))
    return x, y, lsh, get_bucket_fn("rect")


def _hj_cfg(m=4, table_size=512, **kw):
    from repro.core.distributed import KRRStepConfig
    return KRRStepConfig(m=m, table_size=table_size, lam=0.5, cg_iters=15,
                         data_axes=("pod", "data"), model_axis="model",
                         backend="reference", **kw)


def _mesh_1():
    from repro.compat import make_mesh
    return make_mesh((1, 1, 1), ("pod", "data", "model"))


def test_hashjoin_bf16_wire_accuracy_pinned():
    """The default bfloat16 wire (f32 accumulate, one rounding per hop)
    stays within 1% relative L2 of the f32-wire solve — the pinned accuracy
    bound for halving the all_to_all bytes."""
    from repro.core.distributed import make_krr_step_hashjoin
    x, y, lsh, f = _hj_problem()
    mesh, cfg = _mesh_1(), _hj_cfg()
    b_f32, _, _, _ = jax.jit(make_krr_step_hashjoin(
        mesh, cfg, f, payload_dtype=jnp.float32))(x, y, lsh)
    b_bf16, _, _, _ = jax.jit(make_krr_step_hashjoin(mesh, cfg, f))(x, y,
                                                                    lsh)
    rel = float(jnp.linalg.norm(b_bf16 - b_f32) / jnp.linalg.norm(b_f32))
    assert rel < 1e-2, rel
    assert rel > 0.0          # the wire really is bf16, not silently f32


def test_hashjoin_capacity_overflow_drops_stay_finite():
    """A cap_factor far below 1 forces per-destination capacity overflow:
    excess buckets are DROPPED (sentinel-routed), never misrouted — the
    solve stays finite and in the neighborhood of the exact-table solve
    (the estimator loses mass but not stability)."""
    from repro.core.distributed import make_krr_step, make_krr_step_hashjoin
    x, y, lsh, f = _hj_problem()
    mesh, cfg = _mesh_1(), _hj_cfg()
    b_ps, _, _ = jax.jit(make_krr_step(mesh, cfg, f))(x, y, lsh)
    b_ov, res, _, stats = jax.jit(make_krr_step_hashjoin(
        mesh, cfg, f, cap_factor=0.05, payload_dtype=jnp.float32))(x, y, lsh)
    assert bool(jnp.isfinite(b_ov).all())
    assert bool(jnp.isfinite(res).all())
    # the drops are ACCOUNTED, not silent: the same pack pass that routes
    # cells counts the ones past capacity
    assert int(stats.overflow_dropped) > 0
    rel = float(jnp.linalg.norm(b_ov - b_ps) / jnp.linalg.norm(b_ps))
    assert rel < 0.5, rel     # degraded, but still the same system


def test_hashjoin_overflow_counter_zero_at_ample_capacity():
    """At cap_factor=1.25 the per-destination capacity exceeds the max
    possible distinct cells per owner on this problem — the overflow counter
    must be EXACTLY zero (the accounting has no false positives)."""
    from repro.core.distributed import make_krr_step_hashjoin
    x, y, lsh, f = _hj_problem()
    mesh, cfg = _mesh_1(), _hj_cfg()
    _, _, _, stats = jax.jit(make_krr_step_hashjoin(
        mesh, cfg, f, cap_factor=1.25, payload_dtype=jnp.float32))(x, y, lsh)
    assert int(stats.overflow_dropped) == 0
    assert int(stats.wire_nonfinite) == 0


def test_hashjoin_nan_wire_cell_detected_never_silent():
    """A NaN-poisoned wire cell must surface as a NaN resnorm sentinel (the
    CG loop propagates it into detection) — never as a silently-finite,
    silently-wrong beta next to an all-clean residual report."""
    from repro.core.distributed import make_krr_step_hashjoin
    from repro.testing import FaultPlan
    x, y, lsh, f = _hj_problem()
    mesh = _mesh_1()
    cfg = _hj_cfg(fault_plan=FaultPlan(wire_nan_frac=0.3, seed=7))
    b, res, _, stats = jax.jit(make_krr_step_hashjoin(
        mesh, cfg, f, payload_dtype=jnp.float32))(x, y, lsh)
    assert not bool(jnp.isfinite(res).all())   # sentinel fired
    assert int(stats.wire_nonfinite) > 0       # and the wire count saw it


def test_hashjoin_multi_rhs_matches_psum_block():
    """An (n, k) RHS block through the hash-join step matches the psum
    step's block solve: the k columns ride (cells, k) payloads — one
    routing build and two all_to_alls per iteration for all k."""
    from repro.core.distributed import make_krr_step, make_krr_step_hashjoin
    x, _, lsh, f = _hj_problem()
    yk = jax.random.normal(jax.random.PRNGKey(11), (x.shape[0], 3))
    mesh, cfg = _mesh_1(), _hj_cfg()
    bk_ps, _, t_ps = jax.jit(make_krr_step(mesh, cfg, f))(x, yk, lsh)
    bk_hj, _, t_hj, _ = jax.jit(make_krr_step_hashjoin(
        mesh, cfg, f, payload_dtype=jnp.float32))(x, yk, lsh)
    np.testing.assert_allclose(np.asarray(bk_hj), np.asarray(bk_ps),
                               atol=1e-5)
    assert t_hj.shape == (4, 512, 3)   # sharded table keeps the RHS axis


def test_hashjoin_jacobi_matches_psum_jacobi():
    """precond='jacobi' rides the hash-join step (diagonal via model psum,
    apply shard-local) and matches the psum step's PCG trajectory."""
    from repro.core.distributed import make_krr_step, make_krr_step_hashjoin
    x, y, lsh, f = _hj_problem()
    mesh, cfg = _mesh_1(), _hj_cfg(precond="jacobi")
    b_ps, _, _ = jax.jit(make_krr_step(mesh, cfg, f))(x, y, lsh)
    b_hj, _, _, _ = jax.jit(make_krr_step_hashjoin(
        mesh, cfg, f, payload_dtype=jnp.float32))(x, y, lsh)
    np.testing.assert_allclose(np.asarray(b_hj), np.asarray(b_ps), atol=1e-5)


def test_hashjoin_nystrom_rejected():
    from repro.core.distributed import make_krr_step_hashjoin
    with pytest.raises(ValueError, match="nystrom"):
        make_krr_step_hashjoin(_mesh_1(), _hj_cfg(precond="nystrom"),
                               _hj_problem()[3])


def test_hashjoin_predict_sharded_table_matches_psum_predict():
    """make_krr_predict_hashjoin consumes the step's data-SHARDED table
    (readout-half routing: slot requests to owner shards) and matches the
    psum predict on the replicated tables."""
    from repro.core.distributed import (make_krr_predict,
                                        make_krr_predict_hashjoin,
                                        make_krr_step,
                                        make_krr_step_hashjoin)
    x, y, lsh, f = _hj_problem()
    xt = jax.random.uniform(jax.random.PRNGKey(13), (64, x.shape[1])) * 2.0
    mesh, cfg = _mesh_1(), _hj_cfg()
    _, _, t_ps = jax.jit(make_krr_step(mesh, cfg, f))(x, y, lsh)
    _, _, t_hj, _ = jax.jit(make_krr_step_hashjoin(
        mesh, cfg, f, payload_dtype=jnp.float32))(x, y, lsh)
    p_ps = jax.jit(make_krr_predict(mesh, cfg, f))(xt, lsh, t_ps)
    p_hj = jax.jit(make_krr_predict_hashjoin(
        mesh, cfg, f, payload_dtype=jnp.float32))(xt, lsh, t_hj)
    np.testing.assert_allclose(np.asarray(p_hj), np.asarray(p_ps), atol=1e-5)


@needs_4
def test_hashjoin_step_4shards_matches_psum_in_process():
    """4-way data-sharded hash-join parity, in-process (CI multidevice job):
    real all_to_alls over 4 shards, f32 wire, <= 1e-4 against the psum
    step on the same mesh."""
    from repro.compat import make_mesh
    from repro.core.distributed import make_krr_step, make_krr_step_hashjoin
    x, y, lsh, f = _hj_problem(n=256, table_size=1024)
    mesh = make_mesh((1, 4, 1), ("pod", "data", "model"))
    cfg = _hj_cfg(table_size=1024)
    b_ps, _, _ = jax.jit(make_krr_step(mesh, cfg, f))(x, y, lsh)
    b_hj, _, _, _ = jax.jit(make_krr_step_hashjoin(
        mesh, cfg, f, cap_factor=4.0, payload_dtype=jnp.float32))(x, y, lsh)
    err = float(jnp.max(jnp.abs(b_hj - b_ps)))
    assert err <= 1e-4, err


_BLOCKED_SCRIPT = r"""
import jax, jax.numpy as jnp
from repro.compat import make_mesh
from repro.core import sample_lsh_params, GammaPDF, get_bucket_fn, featurize
from repro.core.wlsh import build_table_index, table_matvec
from repro.core.krr import cg_solve
from repro.core.distributed import KRRStepConfig, make_krr_step

assert len(jax.devices()) == 2
mesh = make_mesh((1, 2, 1), ("pod", "data", "model"))
n, d, m, B = 256, 4, 4, 1024
key = jax.random.PRNGKey(0)
x = jax.random.uniform(key, (n, d)) * 2.0
y = jax.random.normal(jax.random.PRNGKey(1), (n,))
lsh = sample_lsh_params(jax.random.PRNGKey(2), m, d, GammaPDF(2.0, 1.0))
f = get_bucket_fn("rect")
cfg = KRRStepConfig(m=m, table_size=B, lam=0.5, cg_iters=20,
                    data_axes=("pod", "data"), model_axis="model",
                    backend="pallas", blocked_split=True)
beta, resnorm, tables = jax.jit(make_krr_step(mesh, cfg, f))(x, y, lsh)
idx = build_table_index(featurize(lsh, f, x), B)
ref = cg_solve(lambda v: table_matvec(idx, v), y, 0.5, tol=0.0, maxiter=20)
err = float(jnp.max(jnp.abs(jax.device_get(beta) - ref.x)))
assert err < 1e-4, f"blocked-split sharded step mismatch {err}"
print("BLOCKED_SPLIT_OK", err)
"""


@pytest.mark.slow
def test_blocked_split_krr_step_two_shards_subprocess():
    """The pallas blocked-split psum path on a real 2-device data mesh
    agrees with the single-device reference solve (subprocess tier, so it
    also runs where the pytest process only sees one device)."""
    proc = subprocess.run(
        [sys.executable, "-c", _BLOCKED_SCRIPT],
        env={"PYTHONPATH": "src", "JAX_PLATFORMS": "cpu",
             "XLA_FLAGS": "--xla_force_host_platform_device_count=2",
             "PATH": "/usr/bin:/bin"},
        capture_output=True, text=True, cwd=".", timeout=420)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "BLOCKED_SPLIT_OK" in proc.stdout


@pytest.mark.slow
def test_hashjoin_krr_matches_psum_mode():
    """The beyond-paper hash-join table mode solves the same system as the
    paper-faithful psum mode (generous routing capacity => no drops)."""
    proc = subprocess.run(
        [sys.executable, "-c", _HJ_SCRIPT],
        env={"PYTHONPATH": "src", "JAX_PLATFORMS": "cpu",
             "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
             "PATH": "/usr/bin:/bin"},
        capture_output=True, text=True, cwd=".", timeout=420)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "HASHJOIN_OK" in proc.stdout
