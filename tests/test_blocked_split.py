"""Blocked distributed matvec: visit-list split kernels + slot-sort routing.

Pins PR 5's acceptance criteria: the blocked split scatter/gather match the
unblocked split path on both backends (odd n, m=1, non-dividing tiles, k=8
multi-RHS) — bitwise for the gather, ulp-level for the scatter (the one-hot
dot reduces a block's same-slot contributions in tree order where the
sequential scatter-add chains them; same operands, different association) —
explicit zeroing of table tiles no point hashes into, the per-pass
O(n/bn + B/bt) visit schedules, and the hash-join routing build containing
NO sort (it rides the slot-blocked layout's one stable argsort).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (GammaPDF, get_bucket_fn, make_operator,
                        sample_lsh_params)
from repro.core.distributed import _routing_maps
from repro.core.wlsh import (BLOCKED_SPLIT_N, BLOCKED_SPLIT_T, TableIndex,
                             build_blocked_layout, build_table_index,
                             table_loads, table_matvec, table_readout)
from repro.hlo_analysis import count_ops
from repro.kernels.binning import (bin_loads_blocked_op, bin_loads_op,
                                   bin_readout_blocked_op)


def _setup(key, n, d, m, table_size, block_n=64, block_t=512):
    x = jax.random.uniform(key, (n, d)) * 2.0
    lsh = sample_lsh_params(jax.random.fold_in(key, 1), m, d,
                            GammaPDF(2.0, 1.0))
    beta = jax.random.normal(jax.random.fold_in(key, 2), (n,))
    op = make_operator(lsh, get_bucket_fn("rect"), table_size,
                       backend="reference", fused=False)
    feats = op.featurize(x)
    idx = build_table_index(feats, table_size)
    lay = build_blocked_layout(idx.slot, idx.coeff, table_size,
                               block_n=block_n, block_t=block_t,
                               parts="pallas")
    return beta, idx, idx._replace(blocked=lay)


# odd n, n < block_n, m=1, table sizes from one tile up, non-dividing tiles
@pytest.mark.parametrize("n,d,m,table_size,bn,bt",
                         [(97, 3, 2, 512, 64, 512),
                          (300, 5, 4, 1024, 128, 384),
                          (128, 2, 1, 256, 64, 512),
                          (257, 3, 3, 2048, 64, 512)])
def test_blocked_split_matches_unblocked_split(n, d, m, table_size, bn, bt):
    key = jax.random.PRNGKey(n + d + m)
    beta, idx, bidx = _setup(key, n, d, m, table_size, bn, bt)
    want = table_loads(idx, beta)                    # reference split scatter
    got = bin_loads_blocked_op(bidx, beta, interpret=True)
    got_cross = bin_loads_op(idx, beta, interpret=True)
    assert got.shape == want.shape                   # psum contract unchanged
    np.testing.assert_allclose(got, want, atol=1e-5)
    np.testing.assert_allclose(got, got_cross, atol=1e-5)
    # gather is pure selection — bitwise against both split paths
    out_want = table_readout(idx, want)
    out_got = bin_readout_blocked_op(bidx, jnp.asarray(want), interpret=True)
    np.testing.assert_array_equal(np.asarray(out_got), np.asarray(out_want))
    # sum mode (the distributed model-axis contribution)
    np.testing.assert_array_equal(
        np.asarray(bin_readout_blocked_op(bidx, jnp.asarray(want),
                                          average=False, interpret=True)),
        np.asarray(table_readout(idx, want, average=False)))


def test_blocked_split_multi_rhs_k8():
    """A (n, 8) RHS block rides the same visit schedule: (m, B, k) tables
    bitwise-shaped like the per-column split path, values within an ulp."""
    n, d, m, table_size, k = 300, 4, 3, 1024, 8
    key = jax.random.PRNGKey(7)
    _, idx, bidx = _setup(key, n, d, m, table_size)
    bk = jax.random.normal(jax.random.fold_in(key, 3), (n, k))
    want = table_loads(idx, bk)                      # (m, B, k)
    got = bin_loads_blocked_op(bidx, bk, interpret=True)
    got_cross = bin_loads_op(idx, bk, interpret=True)
    assert got.shape == want.shape == (m, table_size, k)
    np.testing.assert_allclose(got, want, atol=1e-5)
    np.testing.assert_allclose(got, got_cross, atol=1e-5)
    out_want = table_readout(idx, want)
    out_got = bin_readout_blocked_op(bidx, jnp.asarray(want), interpret=True)
    assert out_got.shape == (n, k)
    np.testing.assert_array_equal(np.asarray(out_got), np.asarray(out_want))


def test_blocked_split_matvec_through_operator():
    """The fused=False pallas operator takes the visit-list kernels whenever
    the index carries the layout — same matvec as the reference split."""
    n, d, m, table_size = 300, 3, 4, 1024
    key = jax.random.PRNGKey(11)
    x = jax.random.uniform(key, (n, d)) * 2.0
    lsh = sample_lsh_params(jax.random.fold_in(key, 1), m, d,
                            GammaPDF(2.0, 1.0))
    beta = jax.random.normal(jax.random.fold_in(key, 2), (n,))
    op = make_operator(lsh, get_bucket_fn("rect"), table_size,
                       backend="pallas", fused=False)
    feats = op.featurize(x)
    bidx = op.build_index(feats, blocked=True)       # split-tuned geometry
    assert bidx.blocked is not None
    assert bidx.blocked.block_n == BLOCKED_SPLIT_N
    assert bidx.blocked.block_t == BLOCKED_SPLIT_T
    ref = make_operator(lsh, get_bucket_fn("rect"), table_size,
                        backend="reference", fused=False)
    ridx = ref.build_index(feats, blocked=False)
    want = ref.matvec(ridx, beta)
    np.testing.assert_allclose(op.matvec(bidx, beta), want, atol=1e-5)
    np.testing.assert_allclose(
        op.matvec(bidx, beta, average=False),
        ref.matvec(ridx, beta, average=False), atol=1e-4)


def test_blocked_scatter_zeroes_unvisited_tiles():
    """A table tile no point hashes into must come back EXACTLY zero: the
    scatter schedule gives it one visit against the all-padding block, which
    zeroes its HBM tile and adds nothing."""
    m, n, table_size, bt = 2, 64, 1024, 256          # 4 tiles of 256
    # every slot in tile 0 or tile 2 — tiles 1 and 3 are never hit
    key = jax.random.PRNGKey(3)
    raw = jax.random.randint(key, (m, n), 0, 256)
    slot = jnp.where(jnp.arange(n)[None, :] % 2 == 0, raw, raw + 512)
    coeff = jax.random.normal(jax.random.fold_in(key, 1), (m, n))
    idx = TableIndex(slot=slot.astype(jnp.int32), sign=jnp.sign(coeff),
                     weight=jnp.abs(coeff), coeff=coeff,
                     table_size=table_size)
    lay = build_blocked_layout(idx.slot, idx.coeff, table_size,
                               block_n=64, block_t=bt, parts="pallas")
    # the scatter schedule still covers every tile at least once
    for s in range(m):
        assert set(np.asarray(lay.vs_tile[s])) == set(range(4))
    bidx = idx._replace(blocked=lay)
    beta = jax.random.normal(jax.random.fold_in(key, 2), (n,))
    tables = bin_loads_blocked_op(bidx, beta, interpret=True)
    assert bool(jnp.all(tables[:, 256:512] == 0.0))
    assert bool(jnp.all(tables[:, 768:] == 0.0))
    np.testing.assert_allclose(tables, table_loads(idx, beta), atol=1e-5)
    # full round trip through the gather stays exact
    np.testing.assert_allclose(
        bin_readout_blocked_op(bidx, tables, interpret=True),
        table_matvec(idx, beta), atol=1e-5)


def test_split_schedule_is_O_n_per_pass():
    """Each split pass is NB = n/bn + ceil(B/bt) visits per instance — not
    the (n/bn)·(B/bt) cross product — and the scatter schedule's tiles are
    ascending with every tile present (the zero-init contract)."""
    n, d, m, table_size = 4096, 4, 3, 16384
    bn, bt = 64, 512
    key = jax.random.PRNGKey(5)
    x = jax.random.uniform(key, (n, d)) * 2.0
    lsh = sample_lsh_params(jax.random.fold_in(key, 1), m, d,
                            GammaPDF(2.0, 1.0))
    op = make_operator(lsh, get_bucket_fn("rect"), table_size,
                       backend="reference")
    idx = op.build_index(op.featurize(x), blocked=False)
    lay = build_blocked_layout(idx.slot, idx.coeff, table_size,
                               block_n=bn, block_t=bt, parts="pallas")
    nb = n // bn + table_size // bt
    assert lay.vs_block.shape == (m, nb)
    assert lay.vs_tile.shape == (m, nb)
    assert lay.vg_tile.shape == (m, nb)
    assert nb < (n // bn) * (table_size // bt) / 8   # cross product
    vt = np.asarray(lay.vs_tile)
    assert (np.diff(vt, axis=1) >= 0).all()          # ascending, contiguous
    for s in range(m):
        assert set(vt[s]) == set(range(table_size // bt))


def test_routing_maps_contains_no_sort():
    """Acceptance criterion: the hash-join routing build rides the blocked
    layout's slot sort — its own lowering contains ZERO sort ops."""
    m, n, table_size, n_shards = 3, 200, 1024, 4
    key = jax.random.PRNGKey(9)
    slot = jax.random.randint(key, (m, n), 0, table_size).astype(jnp.int32)
    coeff = jax.random.normal(jax.random.fold_in(key, 1), (m, n))
    lay = build_blocked_layout(slot, coeff, table_size, parts="reference")
    fn = jax.jit(lambda s, la: _routing_maps(s, la, n_shards, table_size,
                                             2.0))
    hlo = fn.lower(slot, lay).compile().as_text()
    assert count_ops(hlo, "sort") == 0
    # ... and the layout build itself is exactly the one stable argsort
    lay_fn = jax.jit(lambda s, c: build_blocked_layout(s, c, table_size,
                                                       parts="reference"))
    hlo_lay = lay_fn.lower(slot, coeff).compile().as_text()
    assert count_ops(hlo_lay, "sort") == 1


def test_hashjoin_step_single_device_matches_psum_and_single_sort():
    """On a trivial mesh the hash-join step must agree with the psum step
    (dedup exact: cap is bounded by the owner's m·spp distinct cells), and
    its whole lowered program must contain exactly ONE sort — the layout's."""
    from repro.compat import make_mesh
    from repro.core.distributed import (KRRStepConfig, make_krr_step,
                                        make_krr_step_hashjoin)
    mesh = make_mesh((1, 1, 1), ("pod", "data", "model"))
    n, d, m, table_size = 192, 3, 4, 512
    key = jax.random.PRNGKey(6)
    x = jax.random.uniform(key, (n, d)) * 2.0
    y = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    lsh = sample_lsh_params(jax.random.fold_in(key, 2), m, d,
                            GammaPDF(2.0, 1.0))
    f = get_bucket_fn("rect")
    cfg = KRRStepConfig(m=m, table_size=table_size, lam=0.5, cg_iters=15,
                        data_axes=("pod", "data"), model_axis="model",
                        backend="reference")
    b_ref, _, _ = jax.jit(make_krr_step(mesh, cfg, f))(x, y, lsh)
    hj = jax.jit(make_krr_step_hashjoin(mesh, cfg, f,
                                        payload_dtype=jnp.float32))
    b_hj, _, _, _ = hj(x, y, lsh)
    np.testing.assert_allclose(np.asarray(b_hj), np.asarray(b_ref),
                               atol=1e-5)
    hlo = hj.lower(x, y, lsh).compile().as_text()
    assert count_ops(hlo, "sort") == 1


# ---------------------------------------------------------------------------
# hash-join route kernels (PR 6): pack/unpack vs the flat-XLA scatter/gather
# ---------------------------------------------------------------------------

def _route_setup(m=3, n=200, table_size=1024, n_shards=2, cap_factor=2.0,
                 seed=9):
    from repro.core.distributed import (_make_route_plan, _routing_maps)
    key = jax.random.PRNGKey(seed)
    slot = jax.random.randint(key, (m, n), 0, table_size).astype(jnp.int32)
    coeff = jax.random.normal(jax.random.fold_in(key, 1), (m, n))
    lay = build_blocked_layout(slot, coeff, table_size,
                               block_n=BLOCKED_SPLIT_N,
                               block_t=BLOCKED_SPLIT_T, parts="both")
    pt_cell, _, spp, cap, _, _ = _routing_maps(slot, lay, n_shards,
                                               table_size, cap_factor)
    nb = n_shards * cap
    plan = _make_route_plan(pt_cell, lay, nb)
    return lay, pt_cell, plan, nb, coeff


@pytest.mark.parametrize("k", [None, 1, 4])
def test_route_pack_kernel_matches_flat_scatter(k):
    """The Pallas route-pack kernel reproduces the flat scatter-add through
    pt_cell exactly (bucket segment-sum inside the one-hot accumulation;
    dropped points land on the sentinel and vanish)."""
    from repro.kernels.binning import route_pack_pallas
    lay, pt_cell, plan, nb, coeff = _route_setup()
    key = jax.random.PRNGKey(3)
    shape = (200,) if k is None else (200, k)
    beta = jax.random.normal(key, shape)
    tail = beta.shape[1:]
    contrib = (coeff[:, :, None] * beta[None] if k is not None
               else coeff * beta[None, :])
    want = jnp.zeros((nb + 1,) + tail).at[pt_cell.reshape(-1)].add(
        contrib.reshape((-1,) + tail))[:nb]
    sched = plan.sched
    pad = jnp.zeros((1,) + tail)
    beta_lay = jnp.concatenate([beta, pad])[lay.src]
    if k is not None:
        contrib_lay = lay.coeff_lay[:, None, :] * jnp.swapaxes(beta_lay, 1, 2)
    else:
        contrib_lay = lay.coeff_lay * beta_lay
    packed = route_pack_pallas(
        sched.p_inst, sched.p_block, sched.p_tile, sched.p_flag,
        plan.cell_lay, contrib_lay, num_cell_tiles=sched.num_cell_tiles,
        block_n=lay.block_n, block_t=sched.block_t, interpret=True)
    got = packed[:, :nb].T if k is not None else packed[0, :nb]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


@pytest.mark.parametrize("k", [None, 4])
def test_route_unpack_kernel_matches_flat_gather(k):
    """The Pallas route-unpack kernel reproduces the flat gather + coeff
    product through pt_cell (sentinel cells read zero; every layout block is
    written, including blocks with no real cells)."""
    from repro.kernels.binning import route_unpack_pallas
    lay, pt_cell, plan, nb, coeff = _route_setup()
    key = jax.random.PRNGKey(4)
    m = coeff.shape[0]
    tail = () if k is None else (k,)
    back = jax.random.normal(key, (nb,) + tail)
    back_pad = jnp.concatenate([back, jnp.zeros((1,) + tail)])
    vals = back_pad[pt_cell]
    contrib = coeff[:, :, None] * vals if k is not None else coeff * vals
    want = jnp.sum(contrib, axis=0)
    sched = plan.sched
    cbbt = sched.num_cell_tiles * sched.block_t
    buf = jnp.pad(back, ((0, cbbt - nb),) + ((0, 0),) * len(tail))
    buf = buf.T if k is not None else buf[None]
    out_lay = route_unpack_pallas(
        sched.u_block, sched.u_tile, sched.u_flag, plan.cell_lay,
        lay.coeff_lay, buf, block_n=lay.block_n, block_t=sched.block_t,
        interpret=True)
    rows = jnp.arange(m)[:, None]
    if k is not None:
        got = jnp.swapaxes(out_lay, 1, 2)[rows, lay.inv_pos].sum(axis=0)
    else:
        got = out_lay[rows, lay.inv_pos].sum(axis=0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_route_schedule_contains_no_sort():
    """The route-kernel schedule build (cells -> visit lists) is cumsum /
    searchsorted only — the single-sort-per-step pin survives the fused
    kernels."""
    from repro.core.distributed import _make_route_plan, _routing_maps
    m, n, table_size, n_shards = 3, 200, 1024, 4
    key = jax.random.PRNGKey(9)
    slot = jax.random.randint(key, (m, n), 0, table_size).astype(jnp.int32)
    coeff = jax.random.normal(jax.random.fold_in(key, 1), (m, n))
    lay = build_blocked_layout(slot, coeff, table_size,
                               block_n=BLOCKED_SPLIT_N,
                               block_t=BLOCKED_SPLIT_T, parts="both")

    def plan_fn(s):
        # lay closed over (its block geometry fields are static ints)
        pt_cell, _, _, cap, _, _ = _routing_maps(s, lay, n_shards,
                                                 table_size, 2.0)
        return _make_route_plan(pt_cell, lay, n_shards * cap)

    hlo = jax.jit(plan_fn).lower(slot).compile().as_text()
    assert count_ops(hlo, "sort") == 0
