"""Telemetry layer tests: registry semantics, exposition format, span
tracing, the live HTTP endpoint, serving/solver integration, and (slow)
the metrics-on overhead pin.

Integration tests read the GLOBAL registry (the instrumented modules write
to it) via value DELTAS, never absolutes — other tests in the session have
already bumped the same counters.
"""
import json
import threading
import time
import urllib.request

import numpy as np
import pytest

import jax

from repro import obs
from repro.obs.registry import MetricsRegistry
from repro.core import WLSHKernelSpec, get_bucket_fn, wlsh_krr_fit
from repro.serve import MicroBatcher, Overloaded


# ---------------------------------------------------------------------------
# registry: counters / gauges / histograms / labels
# ---------------------------------------------------------------------------

def test_counter_gauge_histogram_basics():
    reg = MetricsRegistry()
    c = reg.counter("c_total", "a counter")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1.0)                    # counters are monotonic

    g = reg.gauge("g", "a gauge")
    g.set(7.0)
    g.inc(-2.0)
    assert g.value == 5.0
    g.set_fn(lambda: 41 + 1)           # pull-time callback wins
    assert g.value == 42

    h = reg.histogram("h_us", "a histogram", buckets=(1.0, 10.0, 100.0))
    for v in (0.5, 5.0, 50.0, 500.0):
        h.observe(v)
    cum, total, count = h.state()
    assert cum == [1, 2, 3, 4]         # cumulative incl. +Inf
    assert count == 4 and total == pytest.approx(555.5)


def test_labels_and_kind_mismatch():
    reg = MetricsRegistry()
    fam = reg.counter("hits_total", "hits", labels=("model",))
    fam.labels("a").inc(3)
    fam.labels("b").inc()
    assert fam.labels("a").value == 3.0
    # same name re-registered with the same schema returns the same family
    assert reg.counter("hits_total", labels=("model",)) is fam
    with pytest.raises(ValueError):
        reg.gauge("hits_total")        # kind mismatch
    with pytest.raises(ValueError):
        reg.counter("hits_total", labels=("other",))   # label mismatch
    with pytest.raises(ValueError):
        fam.inc()                      # labeled family needs .labels()


def test_exposition_format_golden():
    reg = MetricsRegistry()
    reg.counter("req_total", "requests", labels=("code",)).labels("200").inc(3)
    reg.gauge("depth", "queue depth").set(2)
    reg.histogram("lat_us", "latency", buckets=(10.0, 100.0)).observe(42.0)
    assert reg.render() == (
        "# HELP depth queue depth\n"
        "# TYPE depth gauge\n"
        "depth 2\n"
        "# HELP lat_us latency\n"
        "# TYPE lat_us histogram\n"
        'lat_us_bucket{le="10"} 0\n'
        'lat_us_bucket{le="100"} 1\n'
        'lat_us_bucket{le="+Inf"} 1\n'
        "lat_us_sum 42\n"
        "lat_us_count 1\n"
        "# HELP req_total requests\n"
        "# TYPE req_total counter\n"
        'req_total{code="200"} 3\n')


def test_jsonl_snapshot(tmp_path):
    reg = MetricsRegistry()
    reg.counter("c_total", "c").inc(5)
    path = str(tmp_path / "metrics.jsonl")
    reg.write_jsonl(path, extra={"run": "t1"})
    reg.write_jsonl(path, extra={"run": "t2"})
    lines = [json.loads(ln) for ln in open(path)]
    assert len(lines) == 2
    assert lines[0]["run"] == "t1" and "ts" in lines[0]
    series = lines[1]["metrics"]["c_total"]["series"]
    assert series == [{"labels": {}, "value": 5.0}]


def test_histogram_observe_many_defers_and_folds():
    reg = MetricsRegistry()
    h = reg.histogram("om_us", "h", buckets=(1.0, 10.0, 100.0))
    h.observe_many([0.5, 5.0, 50.0, 500.0])   # C-speed extend, not yet binned
    h.observe(5.0)                            # singles record immediately
    cum, total, count = h.state()             # read folds the pending batch
    assert cum == [1, 3, 4, 5]
    assert count == 5 and total == pytest.approx(560.5)
    prev = obs.set_enabled(False)
    try:
        h.observe_many([1.0, 2.0])            # disabled drops batches too
    finally:
        obs.set_enabled(prev)
    assert h.state()[2] == 5


def test_timer_pre_bound_samples_and_clear_in_place():
    reg = MetricsRegistry()
    h = reg.histogram("tm_us", "t")
    t = obs.timer("t.timer", to_histogram=h)
    obs.clear_span_samples("t.timer")
    with t():
        pass
    assert len(obs.span_samples_us("t.timer")) == 1
    assert h.state()[2] == 1
    # clearing empties the buffer IN PLACE — the timer's pre-bound
    # reference keeps recording into the same deque afterwards
    obs.clear_span_samples("t.timer")
    assert obs.span_samples_us("t.timer") == []
    with t():
        pass
    assert len(obs.span_samples_us("t.timer")) == 1
    prev = obs.set_tracing(False)
    try:
        with t():                              # noop singleton while off
            pass
    finally:
        obs.set_tracing(prev)
    assert len(obs.span_samples_us("t.timer")) == 1


def test_registry_thread_safety():
    reg = MetricsRegistry()
    c = reg.counter("n_total", "n")
    h = reg.histogram("h_us", "h")

    def work():
        for _ in range(5000):
            c.inc()
            h.observe(1.0)

    threads = [threading.Thread(target=work) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == 40000.0
    assert h.state()[2] == 40000


def test_set_enabled_noops_recording():
    reg = MetricsRegistry()
    c = reg.counter("c_total", "c")
    prev = obs.set_enabled(False)
    try:
        c.inc(10)
        reg.gauge("g", "g").set(5)
        reg.histogram("h_us", "h").observe(1.0)
    finally:
        obs.set_enabled(prev)
    assert c.value == 0.0
    assert reg.histogram("h_us").state()[2] == 0


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------

def test_span_nesting_and_attr_inheritance():
    obs.clear_span_samples("t.outer")
    obs.clear_span_samples("t.inner")
    with obs.span("t.outer", {"model": "a", "shared": 1}) as outer:
        assert obs.current_span() is outer
        assert outer.depth == 0
        with obs.span("t.inner", {"shared": 2}) as inner:
            assert inner.parent is outer
            assert inner.depth == 1
            # own keys win over inherited ones
            assert inner.attrs == {"model": "a", "shared": 2}
            inner.set_attr("extra", True)
            assert inner.attrs["extra"] is True
        assert obs.current_span() is outer
    assert obs.current_span() is None
    assert outer.duration_us >= inner.duration_us > 0.0
    assert len(obs.span_samples_us("t.outer")) == 1
    st = obs.span_stats("t.inner")
    assert st["count"] == 1 and st["p50_us"] == st["max_us"]


def test_span_feeds_histogram_and_disabled_noop():
    reg = MetricsRegistry()
    h = reg.histogram("sp_us", "span hist")
    with obs.span("t.hist", to_histogram=h):
        pass
    assert h.state()[2] == 1

    obs.clear_span_samples("t.off")
    prev = obs.set_tracing(False)
    try:
        with obs.span("t.off", to_histogram=h) as sp:
            pass
        assert sp.attrs == {}          # the no-op singleton
    finally:
        obs.set_tracing(prev)
    assert obs.span_samples_us("t.off") == []
    assert h.state()[2] == 1           # histogram untouched while off


# ---------------------------------------------------------------------------
# HTTP endpoint
# ---------------------------------------------------------------------------

def test_metrics_server_and_healthz():
    obs.counter("endpoint_probe_total", "probe").inc()
    srv = obs.serve_metrics(0)         # port 0: OS-picked
    obs.add_health_provider("probe", lambda: {"ok": True})
    try:
        with urllib.request.urlopen(srv.url + "/metrics", timeout=10) as r:
            body = r.read().decode()
            assert r.headers["Content-Type"].startswith("text/plain")
        assert "# TYPE endpoint_probe_total counter" in body
        with urllib.request.urlopen(srv.url + "/healthz", timeout=10) as r:
            doc = json.loads(r.read().decode())
        assert doc["status"] == "ok"
        assert doc["components"]["probe"] == {"ok": True}
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(srv.url + "/nope", timeout=10)
    finally:
        obs.remove_health_provider("probe")
        srv.close()


def test_healthz_degrades_on_failing_provider():
    srv = obs.serve_metrics(0)
    obs.add_health_provider("boom", lambda: 1 / 0)
    try:
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(srv.url + "/healthz", timeout=10)
        assert ei.value.code == 500
        doc = json.loads(ei.value.read().decode())
        assert doc["status"] == "error"
        assert "ZeroDivisionError" in doc["components"]["boom"]["error"]
    finally:
        obs.remove_health_provider("boom")
        srv.close()


# ---------------------------------------------------------------------------
# batcher integration: hwm / shed counters flow into stats AND the registry
# ---------------------------------------------------------------------------

def test_batcher_hwm_and_shed_metrics():
    shed_before = obs.counter("serve_batcher_shed_total").value
    release = threading.Event()

    def slow_fn(xb):
        release.wait(5.0)
        return np.zeros(len(xb), np.float32)

    with MicroBatcher(slow_fn, max_batch=4, max_wait_us=100,
                      max_queue=2) as mb:
        futs = [mb.submit(np.zeros(3, np.float32))]
        time.sleep(0.05)               # worker picks req 1 up, then blocks
        futs += [mb.submit(np.zeros(3, np.float32)) for _ in range(2)]
        # queue is now at max_queue: these are shed (the future carries the
        # structured Overloaded, submit itself never raises)
        shed_futs = [mb.submit(np.zeros(3, np.float32)) for _ in range(3)]
        release.set()
        for f in futs:
            f.result(timeout=10.0)
        n_shed = 0
        for f in shed_futs:
            with pytest.raises(Overloaded):
                f.result(timeout=10.0)
            n_shed += 1
        st = mb.stats()
    assert n_shed > 0
    assert st["shed"] == n_shed
    assert st["queue_depth_hwm"] >= 2
    assert (obs.counter("serve_batcher_shed_total").value
            == shed_before + n_shed)
    # the worker thread recorded into the registry concurrently with the
    # submit thread — served counter moved by exactly the served requests
    assert obs.gauge("serve_queue_depth_hwm").value >= 2


# ---------------------------------------------------------------------------
# solver integration: PCG residual history without refitting
# ---------------------------------------------------------------------------

def test_fit_telemetry_residual_history():
    key = jax.random.PRNGKey(3)
    x = jax.random.uniform(key, (96, 5)) * 2.0
    y = jax.random.normal(jax.random.fold_in(key, 1), (96,))
    spec = WLSHKernelSpec(bucket=get_bucket_fn("rect"))
    solves_before = obs.counter("fit_solves_total").value
    model = wlsh_krr_fit(jax.random.fold_in(key, 2), x, y, spec, m=8,
                         lam=0.5, maxiter=40)
    tel = model.telemetry
    assert tel is not None
    iters = tel["iters"]
    hist = tel["resnorm_history"]
    assert hist.shape == (iters + 1, 1)
    assert np.isfinite(hist).all()
    # row 0 is the initial residual; the recorded trajectory ends at the
    # solver's reported final residual
    assert hist[-1, 0] == pytest.approx(float(model.cg_resnorm), rel=1e-5)
    assert hist[-1, 0] < hist[0, 0]    # it actually converged downhill
    assert obs.counter("fit_solves_total").value == solves_before + 1
    # telemetry rides outside the pytree contract: _replace still works and
    # drops/keeps it explicitly
    assert model._replace(backend="reference").telemetry is tel


# ---------------------------------------------------------------------------
# overhead pin (slow): metrics-on warm p50 within 5% of metrics-off
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_metrics_overhead_warm_p50(tmp_path):
    # the overhead budget is pinned where it matters: the end-to-end warm
    # request p50 through the production path — 64 single-point submits
    # coalesced by the batcher into one padded jitted warm batch per round,
    # measured submit-to-last-future.  Per-batch timer/counter sites
    # amortize over the coalesced rows, per-row queue-wait recording is a
    # deferred C-speed extend, and everything else runs after the futures
    # resolve; under the GIL, ALL of it still steals wall time from the
    # round, so this measures the TOTAL instrumentation bill per batch.
    # Interleaved min-of-N p50s per arm: shared-container load drifts on
    # the seconds scale, so each arm keeps its quietest repeat.
    from repro.launch.krr_serve import _fit_and_export
    from repro.serve import MicroBatcher, Predictor, bucket_sizes

    _fit_and_export(str(tmp_path / "art"), n=2048, d=8, m=256)
    pred = Predictor(cache_entries=0)
    pred.load(str(tmp_path / "art"))
    pred.warmup(sizes=bucket_sizes(64))
    rng = np.random.default_rng(1)
    rows = [rng.random(8).astype(np.float32) for _ in range(64)]

    with MicroBatcher(pred.predict, max_batch=64, max_wait_us=2000,
                      dim=8) as mb:
        def round_us():
            t0 = time.perf_counter()
            futs = [mb.submit(r) for r in rows]
            for f in futs:
                f.result(timeout=30.0)
            return (time.perf_counter() - t0) * 1e6

        def p50_ratio(n=150):
            # arms alternate ROUND BY ROUND, not block by block — container
            # load drifts on the ~0.1s scale, and per-round interleaving is
            # what cancels it out of the on/off ratio
            on_xs, off_xs = [], []
            for _ in range(n):
                on_xs.append(round_us())
                prev_m = obs.set_enabled(False)
                prev_t = obs.set_tracing(False)
                try:
                    off_xs.append(round_us())
                finally:
                    obs.set_enabled(prev_m)
                    obs.set_tracing(prev_t)
            return sorted(on_xs)[n // 2] / sorted(off_xs)[n // 2]

        for _ in range(10):            # warm both arms' code paths
            round_us()
        ratios = sorted(p50_ratio() for _ in range(3))
    assert ratios[1] <= 1.05, ratios   # median-of-3 interleaved ratios
