"""Self-healing serving runtime (DESIGN.md §12): version discovery, golden
canary, atomic swap + rollback, probation, worker supervision, breakers.

Exactness pins (acceptance criteria):
* a torn publish (killed writer) is INVISIBLE to the watcher — never adopted,
  never an error;
* a version poisoned on disk AFTER export is canary-rejected (its golden
  predictions were recorded pre-poison) with zero disturbance to the serving
  version, and is quarantined — the watcher never retries it;
* a concurrent predict during a swap sees BITWISE exactly the old or the new
  version's output, never a mix;
* a worker crash is no longer terminal: the breaker opens, a half-open probe
  on a restarted worker re-closes it; a crash DURING the probe re-opens it.
"""
import threading
import time

import jax
import numpy as np
import pytest

from repro.core import WLSHKernelSpec, get_bucket_fn, wlsh_krr_fit
from repro.errors import (CircuitOpen, FaultInjected, ServingError,
                          WorkerCrashed)
from repro.serve import (CircuitBreaker, LifecycleConfig, ServingRuntime,
                         SupervisedBatcher, export_artifact,
                         export_artifact_sharded, load_artifact_sharded,
                         version_dir)
from repro.serve.lifecycle import discover_versions
from repro.testing.faults import (FaultPlan, canary_poison,
                                  crash_supervised_workers,
                                  killed_checkpoint_writer,
                                  poison_artifact_tables, torn_publish)

needs_4 = pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="needs 4 devices (run with "
           "XLA_FLAGS=--xla_force_host_platform_device_count=4)")


def _fit(key, n=128, d=4, m=16, backend="reference"):
    x = jax.random.uniform(key, (n, d)) * 2.0
    y = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    spec = WLSHKernelSpec(bucket=get_bucket_fn("rect"))
    model = wlsh_krr_fit(jax.random.fold_in(key, 2), x, y, spec, m=m,
                         lam=0.5, maxiter=50, backend=backend)
    return model, np.asarray(x, np.float32)


@pytest.fixture(scope="module")
def fitted():
    return _fit(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def fitted_b():
    # a genuinely different model (different target draw) — the xor pin
    # needs two versions whose outputs differ
    return _fit(jax.random.PRNGKey(7))


def _runtime(root, **over):
    cfg_kw = dict(probation_s=30.0, probation_min_requests=5,
                  probation_max_error_rate=0.2, retain=2,
                  warm_sizes=(8,))
    cfg_kw.update({k: over.pop(k) for k in list(over)
                   if k in LifecycleConfig._fields})
    return ServingRuntime(str(root), backend="reference", max_batch=8,
                          config=LifecycleConfig(**cfg_kw), **over)


# ---------------------------------------------------------------------------
# version discovery
# ---------------------------------------------------------------------------

def test_discover_versions_flat(tmp_path, fitted):
    model, _ = fitted
    root = tmp_path / "vers"
    assert discover_versions(str(root)) == []          # no root yet
    root.mkdir()
    (root / "scratch").mkdir()                         # non-version noise
    (root / "v9").mkdir()                              # empty: not published
    export_artifact(version_dir(str(root), 2), model)
    export_artifact(version_dir(str(root), 10), model)
    got = discover_versions(str(root))
    assert [v for v, _ in got] == [2, 10]              # sorted, noise ignored


def test_torn_publish_invisible(tmp_path, fitted):
    model, _ = fitted
    root = tmp_path / "vers"
    export_artifact(version_dir(str(root), 1), model)
    torn_publish(version_dir(str(root), 2), model)     # killed mid-write
    assert [v for v, _ in discover_versions(str(root))] == [1]
    rt = _runtime(root)
    assert rt.poll_once()["action"] == "swap"
    assert rt.poll_once()["action"] == "none"          # torn v2 never adopted
    assert rt.active_version == 1


# ---------------------------------------------------------------------------
# circuit breaker state machine
# ---------------------------------------------------------------------------

def test_breaker_opens_and_recovers():
    t = [0.0]
    br = CircuitBreaker(name="t1", failure_threshold=2, cooldown_s=1.0,
                        clock=lambda: t[0])
    br.admit()
    br.record_failure()
    br.admit()                                 # 1 failure: still closed
    br.record_failure()
    assert br.state == "open"
    with pytest.raises(CircuitOpen) as ei:
        br.admit()
    assert 0.0 < ei.value.retry_after_s <= 1.0
    t[0] = 1.5                                 # past the cooldown
    br.admit()                                 # the half-open probe
    assert br.state == "half_open"
    br.record_success()
    assert br.state == "closed"
    assert br.stats()["rejected"] == 1


def test_breaker_probe_failure_reopens():
    t = [0.0]
    br = CircuitBreaker(name="t2", failure_threshold=1, cooldown_s=1.0,
                        clock=lambda: t[0])
    br.record_failure()
    t[0] = 2.0
    br.admit()
    br.record_failure()                        # the probe itself failed
    assert br.state == "open"
    with pytest.raises(CircuitOpen):
        br.admit()                             # cooldown restarted at t=2


def test_breaker_neutral_releases_probe_slot():
    t = [0.0]
    br = CircuitBreaker(name="t3", failure_threshold=1, cooldown_s=1.0,
                        half_open_probes=1, clock=lambda: t[0])
    br.record_failure()
    t[0] = 1.5
    br.admit()                                 # probe slot taken
    with pytest.raises(CircuitOpen):
        br.admit()                             # quota exhausted
    br.record_neutral()                        # probe died of shed/deadline
    br.admit()                                 # slot is back — no deadlock
    br.record_success()
    assert br.state == "closed"


# ---------------------------------------------------------------------------
# supervised batcher
# ---------------------------------------------------------------------------

def _sup(fn, **over):
    kw = dict(name="test", failure_threshold=3, cooldown_s=0.1,
              restart_backoff_s=0.01, max_batch=4, max_wait_us=200, dim=4)
    kw.update(over)
    return SupervisedBatcher(fn, **kw)


def test_supervised_worker_restart(fitted):
    model, x = fitted
    calls = []

    def fn(xb):
        calls.append(len(xb))
        return np.zeros(len(xb), np.float32)

    with _sup(fn) as sup:
        assert sup.predict(x[0], timeout=30.0) == 0.0
        crash_supervised_workers(sup, crashes=2)
        for _ in range(2):                     # each crash fails its batch
            with pytest.raises(WorkerCrashed):
                sup.predict(x[0], timeout=30.0)
        # threshold 3 not reached: breaker still closed, third worker serves
        assert sup.predict(x[0], timeout=30.0) == 0.0
        st = sup.stats()
        assert st["crashes"] == 2 and st["restarts"] == 2
        assert st["breaker"]["state"] == "closed"
        assert st["restart_backoff_s"] == 0.01   # success reset the backoff


def test_crash_during_half_open_probe():
    def fn(xb):
        return np.zeros(len(xb), np.float32)

    with _sup(fn, failure_threshold=1, cooldown_s=0.15) as sup:
        crash_supervised_workers(sup, crashes=2)
        with pytest.raises(WorkerCrashed):
            sup.predict(np.zeros(4, np.float32), timeout=30.0)
        assert sup.breaker.state == "open"
        with pytest.raises(CircuitOpen):       # fast rejection, no worker
            sup.predict(np.zeros(4, np.float32), timeout=30.0)
        time.sleep(0.2)
        # the half-open probe runs on a RESTARTED worker — which crashes
        # too, so the probe fails and the breaker re-opens
        with pytest.raises(WorkerCrashed):
            sup.predict(np.zeros(4, np.float32), timeout=30.0)
        assert sup.breaker.state == "open"
        time.sleep(0.2)
        # third worker is clean: probe succeeds, breaker closes
        assert sup.predict(np.zeros(4, np.float32), timeout=30.0) == 0.0
        assert sup.breaker.state == "closed"
        assert sup.stats()["restarts"] == 2


def test_breaker_trips_on_model_errors_not_client_errors():
    def fn(xb):
        raise FaultInjected("sick model")

    with _sup(fn, failure_threshold=2, cooldown_s=5.0) as sup:
        for _ in range(2):
            with pytest.raises(FaultInjected):
                sup.predict(np.zeros(4, np.float32), timeout=30.0)
        # two model-error batches tripped it — callers now get CircuitOpen
        # without touching the worker
        with pytest.raises(CircuitOpen):
            sup.predict(np.zeros(4, np.float32), timeout=30.0)
        assert sup.breaker.stats()["rejected"] == 1


# ---------------------------------------------------------------------------
# runtime: adopt, canary, swap, quarantine
# ---------------------------------------------------------------------------

def test_runtime_adopts_and_serves(tmp_path, fitted):
    model, x = fitted
    rt = _runtime(tmp_path)
    with pytest.raises(ServingError):
        rt.predict(x[:2])                      # nothing published yet
    export_artifact(version_dir(str(tmp_path), 1), model)
    r = rt.poll_once()
    assert r["action"] == "swap" and r["canary"] == "pass"
    assert r["max_abs_err"] <= 1e-4            # golden agreement, recorded tol
    out = rt.predict(x[:2])
    assert out.shape == (2,) and np.isfinite(out).all()
    h = rt.health()
    assert h["ok"] and h["active_version"] == 1 and h["last_canary"][
        "verdict"] == "pass"


def test_canary_rejects_poisoned_on_disk(tmp_path, fitted):
    model, x = fitted
    export_artifact(version_dir(str(tmp_path), 1), model)
    rt = _runtime(tmp_path)
    rt.poll_once()
    base = rt.predict(x[:4], use_cache=False)
    # v2 exports HEALTHY (golden recorded from the good model), then the
    # bytes rot on disk — structural validation still passes (finite,
    # right shapes), only the canary can catch it
    export_artifact(version_dir(str(tmp_path), 2), model)
    assert poison_artifact_tables(version_dir(str(tmp_path), 2)) >= 1
    r = rt.poll_once()
    assert r["action"] == "canary_reject" and r["version"] == 2
    assert rt.active_version == 1
    np.testing.assert_array_equal(rt.predict(x[:4], use_cache=False), base)
    assert rt.poll_once()["action"] == "none"  # quarantined, never retried
    assert rt.health()["rejected_versions"] == [2]


def test_canary_poison_hook_rejects_clean_version(tmp_path, fitted):
    model, _ = fitted
    export_artifact(version_dir(str(tmp_path), 1), model)
    rt = _runtime(tmp_path)
    rt.poll_once()
    export_artifact(version_dir(str(tmp_path), 2), model)
    with canary_poison(rt, mode="nan"):
        r = rt.poll_once()
    assert r["action"] == "canary_reject"
    assert "non-finite" in r["reason"]
    assert rt.active_version == 1


def test_canary_absent_policy(tmp_path, fitted):
    model, _ = fitted
    # golden capture opted out at export: default policy swaps anyway
    # (verdict "absent"), require_golden rejects
    export_artifact(version_dir(str(tmp_path), 1), model, golden_queries=0)
    rt = _runtime(tmp_path)
    r = rt.poll_once()
    assert r["action"] == "swap" and r["canary"] == "absent"
    strict_root = tmp_path / "strict"
    export_artifact(version_dir(str(strict_root), 1), model,
                    golden_queries=0)
    rt2 = _runtime(strict_root, require_golden=True)
    r = rt2.poll_once()
    assert r["action"] == "canary_reject"
    assert rt2.active_version is None


def test_golden_block_in_meta(tmp_path, fitted):
    model, _ = fitted
    export_artifact(version_dir(str(tmp_path), 1), model)
    from repro.serve.artifact import GOLDEN_QUERIES, _read_meta
    from repro.checkpoint.store import latest_step
    d = version_dir(str(tmp_path), 1)
    meta = _read_meta(d, latest_step(d))
    g = meta["golden"]
    assert len(g["x"]) == GOLDEN_QUERIES == len(g["y"])
    assert np.isfinite(np.asarray(g["y"], np.float64)).all()
    assert g["tol"] > 0
    assert meta["export_version"] == 1
    export_artifact(d, model)                  # re-export bumps the version
    meta2 = _read_meta(d, latest_step(d))
    assert meta2["export_version"] == 2


# ---------------------------------------------------------------------------
# swap atomicity, probation, rollback
# ---------------------------------------------------------------------------

def test_concurrent_predict_during_swap_bitwise_xor(tmp_path, fitted,
                                                    fitted_b):
    model_a, x = fitted
    model_b, _ = fitted_b
    export_artifact(version_dir(str(tmp_path), 1), model_a)
    rt = _runtime(tmp_path)
    rt.poll_once()
    q = x[:3]
    out_a = rt.predict(q, use_cache=False)
    export_artifact(version_dir(str(tmp_path), 2), model_b)
    stop = threading.Event()
    seen, errs = [], []

    def hammer():
        try:
            while not stop.is_set():
                seen.append(rt.predict(q, use_cache=False))
        except Exception as e:  # pragma: no cover - diagnostic
            errs.append(e)

    th = threading.Thread(target=hammer)
    th.start()
    try:
        r = rt.poll_once()                     # swap while requests fly
    finally:
        time.sleep(0.02)
        stop.set()
        th.join()
    assert not errs and r["action"] == "swap"
    out_b = rt.predict(q, use_cache=False)
    assert not np.array_equal(out_a, out_b)    # versions really differ
    assert len(seen) > 0
    for out in seen:                           # exactly old xor new — no mix
        assert (np.array_equal(out, out_a) or np.array_equal(out, out_b))


def test_probation_autorollback_on_error_rate(tmp_path, fitted):
    model, x = fitted
    export_artifact(version_dir(str(tmp_path), 1), model)
    rt = _runtime(tmp_path)
    rt.poll_once()                             # adopt v1 (no probation:
    assert rt.health()["probation"] is False   # nothing to fall back to)
    export_artifact(version_dir(str(tmp_path), 2), model)
    r = rt.poll_once()                         # v1 -> v2 swap arms probation
    assert r["action"] == "swap"
    assert rt.health()["probation"] is True
    rt.predictor.fault_plan = FaultPlan(serve_fail_every=1)
    for _ in range(20):
        try:
            rt.predict(x[:1], use_cache=False)
        except FaultInjected:
            pass
        if rt.active_version != 2:
            break
    rt.predictor.fault_plan = None
    assert rt.active_version == 1              # instant flip to retained v1
    assert rt.health()["probation"] is False
    assert 2 in rt.health()["rejected_versions"]
    assert np.isfinite(rt.predict(x[:2], use_cache=False)).all()
    assert rt.poll_once()["action"] == "none"  # v2 quarantined


def test_probation_nonfinite_trips_immediately(tmp_path, fitted):
    model, x = fitted
    export_artifact(version_dir(str(tmp_path), 1), model)
    rt = _runtime(tmp_path, probation_min_requests=10**6)  # rate gate off
    rt.poll_once()
    export_artifact(version_dir(str(tmp_path), 2), model)
    rt.poll_once()                             # v1 -> v2, probation armed
    assert rt.health()["probation"] is True
    # a single non-finite prediction must trip the rollback with NO
    # error-rate denominator — drive the runtime's own accounting (the
    # serving path feeds exactly these counters on a non-finite output)
    with rt._lock:
        rt._n_requests += 1
        rt._n_nonfinite += 1
    rt._maybe_autoroll()
    assert rt.active_version == 1
    assert 2 in rt.health()["rejected_versions"]
    assert np.isfinite(rt.predict(x[:2], use_cache=False)).all()


def test_rollback_exhausted(tmp_path, fitted):
    model, _ = fitted
    export_artifact(version_dir(str(tmp_path), 1), model)
    rt = _runtime(tmp_path, retain=0)          # nothing kept: no net to fall
    rt.poll_once()
    export_artifact(version_dir(str(tmp_path), 2), model)
    rt.poll_once()
    assert rt.active_version == 2
    assert rt.health()["retained_versions"] == []
    assert rt.rollback("forced") is False      # counted, not crashed
    assert rt.active_version == 2              # still serving the only copy


def test_rollback_depth_two(tmp_path, fitted):
    model, _ = fitted
    rt = _runtime(tmp_path, retain=2, probation_s=0.0)
    for v in (1, 2, 3):
        export_artifact(version_dir(str(tmp_path), v), model)
        rt.poll_once()
    assert rt.active_version == 3
    assert rt.health()["retained_versions"] == [1, 2]
    assert rt.rollback("bad 3") and rt.active_version == 2
    assert rt.rollback("bad 2") and rt.active_version == 1
    assert rt.rollback("bad 1") is False       # retained list exhausted
    assert rt.active_version == 1


def test_watcher_thread_adopts_new_version(tmp_path, fitted):
    model, x = fitted
    export_artifact(version_dir(str(tmp_path), 1), model)
    rt = _runtime(tmp_path)
    rt.poll_once()
    rt.start(interval_s=0.05)
    try:
        export_artifact(version_dir(str(tmp_path), 2), model)
        deadline = time.monotonic() + 30.0
        while rt.active_version != 2 and time.monotonic() < deadline:
            time.sleep(0.05)
        assert rt.active_version == 2          # live swap, no poll_once call
        assert np.isfinite(rt.predict(x[:2])).all()
    finally:
        rt.stop()


# ---------------------------------------------------------------------------
# sharded: transient load retries + mesh-variant lifecycle
# ---------------------------------------------------------------------------

def test_sharded_load_retries_torn_then_published(tmp_path, fitted):
    """A loader racing a publisher: the first read finds no manifest (torn),
    retries with backoff, and succeeds once the background export lands."""
    model, _ = fitted
    d = str(tmp_path / "sh")
    torn_publish(d, model, mesh_shape=(1, 1))  # killed writer: no manifest
    with pytest.raises(FileNotFoundError):
        load_artifact_sharded(d, mesh_shape=(1, 1))      # no retries: fails

    def publisher():
        time.sleep(0.15)
        export_artifact_sharded(d, model, mesh_shape=(1, 1))

    th = threading.Thread(target=publisher)
    th.start()
    try:
        loaded = load_artifact_sharded(d, mesh_shape=(1, 1), retries=40,
                                       retry_backoff_s=0.05)
    finally:
        th.join()
    assert loaded.manifest["kind"] == "wlsh_krr_sharded_artifact"
    assert "golden" in loaded.manifest


def test_sharded_load_retries_exhausted_raises(tmp_path, fitted):
    model, _ = fitted
    d = str(tmp_path / "sh2")
    with killed_checkpoint_writer():
        with pytest.raises(FaultInjected):
            export_artifact_sharded(d, model, mesh_shape=(1, 1))
    t0 = time.monotonic()
    with pytest.raises(FileNotFoundError):
        load_artifact_sharded(d, mesh_shape=(1, 1), retries=3,
                              retry_backoff_s=0.02)
    assert time.monotonic() - t0 >= 0.02 * 3   # it really backed off


@needs_4
def test_sharded_runtime_swap_and_rollback(tmp_path, fitted):
    model, x = fitted
    root = str(tmp_path / "vers")
    export_artifact_sharded(version_dir(root, 1), model, mesh_shape=(2, 2))
    cfg = LifecycleConfig(probation_s=0.0, retain=2, warm_sizes=(4,))
    rt = ServingRuntime(root, mesh_shape=(2, 2), config=cfg)
    assert rt.poll_once()["action"] == "swap"
    base = rt.predict(x[:4], use_cache=False)
    assert np.isfinite(base).all()
    c0 = rt.compile_count()
    # poisoned sharded v2: every piece's tables scaled on disk
    export_artifact_sharded(version_dir(root, 2), model, mesh_shape=(2, 2))
    assert poison_artifact_tables(version_dir(root, 2)) == 4  # 2x2 pieces
    r = rt.poll_once()
    assert r["action"] == "canary_reject" and rt.active_version == 1
    # good v3 swaps with warm buckets intact
    export_artifact_sharded(version_dir(root, 3), model, mesh_shape=(2, 2))
    r = rt.poll_once()
    assert r["action"] == "swap" and rt.active_version == 3
    assert rt.compile_count() == c0
    np.testing.assert_array_equal(rt.predict(x[:4], use_cache=False), base)
    assert rt.rollback("operator") and rt.active_version == 1
    h = rt.health()
    assert h["ok"] and h["rejected_versions"] == [2, 3]


# ---------------------------------------------------------------------------
# health endpoint integration
# ---------------------------------------------------------------------------

def test_healthz_degraded_503_when_runtime_unhealthy(tmp_path):
    import json
    import urllib.error
    import urllib.request

    from repro import obs

    rt = _runtime(tmp_path)                    # no version published: not ok
    assert rt.health()["ok"] is False
    srv = obs.serve_metrics(0)
    obs.add_health_provider("lifecycle", rt.health)
    try:
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(srv.url + "/healthz", timeout=10)
        assert ei.value.code == 503            # degraded, not error
        doc = json.loads(ei.value.read().decode())
        assert doc["status"] == "degraded"
        assert doc["components"]["lifecycle"]["active_version"] is None
    finally:
        obs.remove_health_provider("lifecycle")
        srv.close()
