"""Perf regression gates over the committed BENCH_*.json files (--runslow).

Reruns the matvec benchmark section at the committed sizes and fails when
``reference_us`` or ``fused_us`` regresses more than 1.3x; reruns the
serving warm/cached single-query sections against BENCH_serving.json and
additionally pins the subsystem's two structural speedups (warm >= 5x cold,
cache hit >= 10x warm) — see ``benchmarks/check_regression.py`` for the
standalone CLI form.
"""
import pathlib
import sys

import pytest

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

pytestmark = pytest.mark.slow


def test_matvec_perf_no_regression():
    from benchmarks.check_regression import DEFAULT_BASELINE, check
    assert DEFAULT_BASELINE.exists(), "committed BENCH_matvec.json missing"
    failures, rows = check()
    if not rows:
        pytest.skip("baseline recorded on a different platform")
    assert not failures, "\n".join(failures)


def test_hashjoin_distributed_no_regression():
    """Acceptance pin (PR 6): rerun the distributed benchmark section at the
    committed (n, shards) cells and fail when ``hashjoin_iter_us`` regresses
    >2x, when it is not >= 2x below the carried-forward pre-fusion routing
    cost (``hashjoin_prefuse_iter_us``), or when the k=8 multi-RHS block
    costs >= 2x a single-RHS iteration per column.  Spawns fake-CPU-mesh
    subprocesses — minutes-scale, hence slow-marked."""
    from benchmarks.check_regression import (DEFAULT_BASELINE,
                                             check_distributed)
    assert DEFAULT_BASELINE.exists(), "committed BENCH_matvec.json missing"
    failures, fresh = check_distributed()
    if not fresh:
        pytest.skip("no comparable distributed baseline (platform differs "
                    "or section absent)")
    assert not failures, "\n".join(failures)


def test_serving_latency_no_regression():
    from benchmarks.check_regression import (DEFAULT_SERVING_BASELINE,
                                             check_serving)
    assert DEFAULT_SERVING_BASELINE.exists(), \
        "committed BENCH_serving.json missing"
    failures, best = check_serving()
    if not best:
        pytest.skip("baseline recorded on a different platform")
    assert not failures, "\n".join(failures)


def test_sharded_serving_no_regression():
    """Acceptance pin (PR 8): rerun the sharded 2x2 serving section against
    BENCH_serving.json's ``sharded`` cell and fail when the warm batch-64
    p50 regresses >2x or drifts beyond 3x the single-host warm p50 measured
    in the same child (the ratio is machine-speed immune).  Spawns a
    4-fake-CPU-device subprocess — minutes-scale, hence slow-marked."""
    from benchmarks.check_regression import (DEFAULT_SERVING_BASELINE,
                                             check_sharded_serving)
    assert DEFAULT_SERVING_BASELINE.exists(), \
        "committed BENCH_serving.json missing"
    failures, fresh = check_sharded_serving()
    if not fresh:
        pytest.skip("no comparable sharded baseline (platform differs "
                    "or section absent)")
    assert not failures, "\n".join(failures)


def test_lifecycle_no_regression():
    """Acceptance pin (self-healing runtime): rerun the lifecycle section
    against BENCH_serving.json's ``lifecycle`` cell and fail when a live
    swap recompiles warm buckets (``swap_compile_delta`` != 0), post-swap
    p50 drifts beyond 2x steady p50 (machine-speed-immune ratio), or forced
    rollback-to-first-healthy-prediction regresses >2x the baseline.
    In-process, but fits + exports several versions — hence slow-marked."""
    from benchmarks.check_regression import (DEFAULT_SERVING_BASELINE,
                                             check_lifecycle)
    assert DEFAULT_SERVING_BASELINE.exists(), \
        "committed BENCH_serving.json missing"
    failures, fresh = check_lifecycle()
    if not fresh:
        pytest.skip("no comparable lifecycle baseline (platform differs "
                    "or section absent)")
    if "error" in fresh:
        pytest.skip(f"lifecycle measurement failed: {fresh['error'][:120]}")
    assert not failures, "\n".join(failures)


def test_blocked_split_pallas_speedup():
    """Acceptance pin (PR 5): the visit-list blocked split matvec must beat
    the cross-product split pallas matvec by >= 3x at n=1024 in interpret
    mode (the fused kernel got 9-10x from the same slot-sort trick; the
    split variant keeps the (m, B) table in HBM for the distributed psum,
    so part of that win is spent on the tile round trips).  Measured fresh —
    committed trajectory rides BENCH_matvec.json's
    ``pallas_split_blocked_speedup``."""
    import jax
    if jax.default_backend() not in ("cpu", "tpu"):
        pytest.skip("interpret-mode pin is CPU/TPU only")
    from benchmarks import bench_matvec
    rows = bench_matvec.run(ns=(1024,), with_dense=False, with_pcg=False)
    row = rows[0]
    assert row["pallas_split_blocked_us"] is not None
    speedup = row["pallas_us"] / row["pallas_split_blocked_us"]
    assert speedup >= 3.0, \
        f"blocked split matvec only {speedup:.2f}x over cross-product split"


def test_serving_structural_speedups():
    """Acceptance pins: the warm path must beat the compile-included cold
    first call by >= 5x, and a bucket-exact cache hit must beat the warm
    featurize+readout path by >= 10x.  Best-of-3 on the cache ratio — the
    shared-container timing distribution is bursty and only the quiet mode
    is reproducible (see benchmarks/common.time_fn)."""
    from benchmarks import bench_serving
    res = bench_serving.run(iters=100, batch_requests=0, offered_qps=(),
                            repeats=3)
    warm = res["warm_speedup_vs_cold"]
    cache = res["cache_speedup_vs_warm"]
    assert warm >= 5.0, \
        f"warm path only {warm:.1f}x faster than cold first call"
    assert cache >= 10.0, \
        f"cache hit only {cache:.1f}x faster than warm path"
