"""Perf regression gate over the committed BENCH_matvec.json (--runslow).

Reruns the matvec benchmark section at the committed sizes and fails when
``reference_us`` or ``fused_us`` regresses more than 1.3x — see
``benchmarks/check_regression.py`` for the standalone CLI form.
"""
import pathlib
import sys

import pytest

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

pytestmark = pytest.mark.slow


def test_matvec_perf_no_regression():
    from benchmarks.check_regression import DEFAULT_BASELINE, check
    assert DEFAULT_BASELINE.exists(), "committed BENCH_matvec.json missing"
    failures, rows = check()
    if not rows:
        pytest.skip("baseline recorded on a different platform")
    assert not failures, "\n".join(failures)
