"""KRR solvers: CG, exact, WLSH-approximate, RFF baseline."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (WLSHKernelSpec, cg_solve, exact_krr_fit,
                        exact_krr_predict, gaussian_kernel, get_bucket_fn,
                        laplace_kernel, rff_krr_fit, rff_krr_predict,
                        wlsh_krr_fit, wlsh_krr_predict)
from repro.core.gp import gp_regression_dataset


def test_cg_matches_direct_solve(rng):
    n = 64
    a = jax.random.normal(rng, (n, n))
    psd = a @ a.T / n
    b = jax.random.normal(jax.random.fold_in(rng, 1), (n,))
    lam = 0.3
    res = cg_solve(lambda v: psd @ v, b, lam, tol=1e-10, maxiter=500)
    direct = jnp.linalg.solve(psd + lam * jnp.eye(n), b)
    np.testing.assert_allclose(res.x, direct, atol=1e-4)


def test_exact_krr_interpolates_smooth_function(rng):
    x, y, f = gp_regression_dataset(rng, gaussian_kernel, n=300, d=2,
                                    noise=0.02)
    beta = exact_krr_fit(gaussian_kernel, x, y, lam=0.05)
    pred = exact_krr_predict(gaussian_kernel, x, beta, x)
    rmse = float(jnp.sqrt(jnp.mean((pred - f) ** 2)))
    assert rmse < 0.1, rmse


def test_wlsh_krr_beats_mean_predictor(rng):
    x, y, f = gp_regression_dataset(rng, laplace_kernel, n=600, d=3,
                                    noise=0.05)
    xtr, ytr, xte, fte = x[:400], y[:400], x[400:], f[400:]
    spec = WLSHKernelSpec(bucket=get_bucket_fn("rect"))
    model = wlsh_krr_fit(jax.random.fold_in(rng, 7), xtr, ytr, spec, m=400,
                         lam=0.3)
    pred = wlsh_krr_predict(model, xte)
    rmse = float(jnp.sqrt(jnp.mean((pred - fte) ** 2)))
    base = float(jnp.sqrt(jnp.mean((fte - jnp.mean(ytr)) ** 2)))
    assert rmse < 0.6 * base, (rmse, base)


def test_wlsh_krr_exact_mode_close_to_exact_laplace_krr(rng):
    """With many instances the approximate solution approaches exact KRR on
    the analytically-equal Laplace kernel."""
    x, y, _ = gp_regression_dataset(rng, laplace_kernel, n=200, d=2,
                                    noise=0.05)
    lam = 1.0
    beta_exact = exact_krr_fit(laplace_kernel, x, y, lam=lam)
    pred_exact = exact_krr_predict(laplace_kernel, x, beta_exact, x)
    spec = WLSHKernelSpec(bucket=get_bucket_fn("rect"))
    model = wlsh_krr_fit(jax.random.fold_in(rng, 3), x, y, spec, m=1500,
                         lam=lam, mode="exact")
    pred_appr = exact_krr_predict(laplace_kernel, x, model.beta, x)
    err = float(jnp.max(jnp.abs(pred_appr - pred_exact)))
    assert err < 0.25 * float(jnp.std(y)), err


def test_rff_krr_fits_gaussian_gp(rng):
    x, y, f = gp_regression_dataset(rng, gaussian_kernel, n=400, d=2,
                                    noise=0.05)
    model = rff_krr_fit(jax.random.fold_in(rng, 11), x, y, n_features=512,
                        lam=0.05)
    pred = rff_krr_predict(model, x)
    rmse = float(jnp.sqrt(jnp.mean((pred - f) ** 2)))
    assert rmse < 0.15, rmse


def test_cg_iteration_count_reported(rng):
    n = 32
    b = jax.random.normal(rng, (n,))
    res = cg_solve(lambda v: v, b, lam=1.0, tol=1e-8)  # A = I: converges fast
    assert int(res.iters) <= 3
    assert float(res.resnorm) < 1e-6
