"""Sharding rules engine: divisibility fallback, axis contention, and the
invariant that a PartitionSpec never reuses a mesh axis (property test)."""
import numpy as np
import pytest

# the property test skips individually when hypothesis is absent; the
# example-based rule tests always run
from _hypothesis_compat import given, settings, st
from jax.sharding import PartitionSpec as P

from repro.sharding import RULES, spec_for


class FakeMesh:
    """Duck-typed mesh: axis_names + devices.shape is all spec_for reads."""

    def __init__(self, sizes: dict):
        self.axis_names = tuple(sizes)
        self.devices = np.empty(tuple(sizes.values()), dtype=object)


POD = FakeMesh({"data": 16, "model": 16})
MULTIPOD = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_batch_shards_over_pod_and_data():
    assert spec_for(("batch", None), (256, 4096), MULTIPOD) == \
        P(("pod", "data"), None)
    assert spec_for(("batch", None), (256, 4096), POD) == P("data", None)


def test_divisibility_fallback_heads():
    # qwen3: 40 heads % 16 != 0 -> heads rule falls through, head_dim=128 takes
    spec = spec_for(("embed", "heads", "head_dim"), (5120, 40, 128), POD)
    assert spec == P("data", None, "model")


def test_per_tensor_axis_contention():
    # batch grabs ('pod','data'); seq_shard falls back to 'model'
    spec = spec_for(("batch", "seq_shard", None, None),
                    (128, 32768, 8, 128), MULTIPOD)
    assert spec == P(("pod", "data"), "model", None, None)
    # ...but kv_heads/head_dim outrank seq_shard on a full cache tensor, so
    # ring-cache writes stay shard-local (decode scatter pathology)
    spec = spec_for(("batch", "seq_shard", "kv_heads", "head_dim"),
                    (128, 32768, 8, 128), MULTIPOD)
    assert spec == P(("pod", "data"), None, None, "model")
    # batch=1 not divisible -> seq_shard wins the data axes (long_500k cell)
    spec = spec_for(("batch", "seq_shard", "kv_heads", None),
                    (1, 524288, 1, 256), MULTIPOD)
    assert spec == P(None, ("pod", "data"), None, None)


def test_experts_rule():
    # llama4: 16 experts == model axis -> expert parallelism
    assert spec_for(("experts", "embed", "mlp"), (16, 5120, 8192), POD) == \
        P("model", "data", None)
    # mixtral: 8 experts % 16 != 0 -> falls through; mlp gets model
    assert spec_for(("experts", "embed", "mlp"), (8, 6144, 16384), POD) == \
        P(None, "data", "model")


def test_decision_log():
    decisions = []
    spec_for(("heads",), (40,), POD, decisions)
    assert any("40 % 16" in d for d in decisions)


_LOGICAL = [name for name, _ in RULES if name is not None]


@given(st.lists(st.sampled_from(_LOGICAL + [None]), min_size=1, max_size=5),
       st.lists(st.sampled_from([1, 2, 8, 16, 40, 96, 128, 256, 4096]),
                min_size=1, max_size=5))
@settings(max_examples=100, deadline=None)
def test_spec_never_reuses_mesh_axis(axes, shape):
    n = min(len(axes), len(shape))
    axes, shape = tuple(axes[:n]), tuple(shape[:n])
    for mesh in (POD, MULTIPOD):
        spec = spec_for(axes, shape, mesh)
        used = []
        for entry in spec:
            if entry is None:
                continue
            used.extend(entry if isinstance(entry, tuple) else (entry,))
        assert len(used) == len(set(used)), (axes, shape, spec)
        # every assignment must divide its dim
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        for dim, entry in zip(shape, spec):
            if entry is None:
                continue
            prod = int(np.prod([sizes[a] for a in
                                (entry if isinstance(entry, tuple)
                                 else (entry,))]))
            assert dim % prod == 0, (axes, shape, spec)
