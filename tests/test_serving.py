"""Serving subsystem: artifact round-trip, warm-path predictor padding
buckets, bucket-exact cache, micro-batcher, and the operator predict split.

Exactness pins (acceptance criteria):
* export -> load -> predict is BITWISE against the in-memory model on the
  reference backend (same program, same arrays), <= 1e-6 via pallas;
* the cache-hit path BITWISE-matches the cold path (hits replay the cold
  path's own rows, and for rect any same-bucket query is the same row);
* ragged request sizes within one power-of-two padding bucket never
  recompile (pinned via the jit cache size).
"""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (WLSHKernelSpec, get_bucket_fn, make_operator,
                        sample_lsh_params, wlsh_krr_fit, wlsh_krr_predict)
from repro.core.lsh import GammaPDF, featurize
from repro.serve import (MicroBatcher, Normalization, Predictor, bucket_sizes,
                         export_artifact, load_artifact, padding_bucket)
from repro.serve.cache import BucketKeyFn, PredictionCache


def _fit(key, n=256, d=4, m=16, bucket="rect", k_rhs=0, backend="reference"):
    x = jax.random.uniform(key, (n, d)) * 2.0
    y = jax.random.normal(jax.random.fold_in(key, 1),
                          (n, k_rhs) if k_rhs else (n,))
    spec = WLSHKernelSpec(bucket=get_bucket_fn(bucket))
    model = wlsh_krr_fit(jax.random.fold_in(key, 2), x, y, spec, m=m,
                         lam=0.5, maxiter=100, backend=backend)
    return model, x


@pytest.fixture(scope="module")
def fitted():
    return _fit(jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# operator split: featurize_buckets + predict_from_buckets
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_predict_split_matches_wrapper(backend):
    key = jax.random.PRNGKey(5)
    lsh = sample_lsh_params(key, 6, 3, GammaPDF(2.0, 1.0))
    op = make_operator(lsh, get_bucket_fn("rect"), 512, backend=backend)
    x = jax.random.uniform(jax.random.fold_in(key, 1), (100, 3)) * 2.0
    beta = jax.random.normal(jax.random.fold_in(key, 2), (100,))
    tables = op.loads(op.build_index(op.featurize(x)), beta)
    xq = jax.random.uniform(jax.random.fold_in(key, 3), (33, 3)) * 2.0
    split = op.predict_from_buckets(op.featurize_buckets(xq), tables)
    whole = op.predict_batched(tables, xq)
    # the wrapper IS the composition — identical ops, bitwise on both backends
    np.testing.assert_array_equal(np.asarray(split), np.asarray(whole))


def test_predict_batched_ragged_remainder():
    """n_test not divisible by the block: every remainder shape agrees with
    the unblocked path, 1-D and multi-RHS tables alike."""
    key = jax.random.PRNGKey(6)
    lsh = sample_lsh_params(key, 5, 3, GammaPDF(2.0, 1.0))
    op = make_operator(lsh, get_bucket_fn("rect"), 512, backend="reference")
    x = jax.random.uniform(jax.random.fold_in(key, 1), (120, 3)) * 2.0
    beta1 = jax.random.normal(jax.random.fold_in(key, 2), (120,))
    beta2 = jax.random.normal(jax.random.fold_in(key, 3), (120, 3))
    idx = op.build_index(op.featurize(x))
    for beta in (beta1, beta2):
        tables = op.loads(idx, beta)
        whole = op.predict_batched(tables, x)
        for bs in (7, 32, 119, 120, 121):   # remainder 1, 24, 1, 0, n<bs
            out = op.predict_batched(tables, x, batch_size=bs)
            assert out.shape == whole.shape
            np.testing.assert_allclose(np.asarray(out), np.asarray(whole),
                                       atol=1e-6)


# ---------------------------------------------------------------------------
# artifacts
# ---------------------------------------------------------------------------

def test_artifact_roundtrip_bitwise_reference(fitted, tmp_path):
    model, x = fitted
    export_artifact(str(tmp_path / "art"), model)
    loaded = load_artifact(str(tmp_path / "art"))
    assert loaded.operator.backend == "reference"
    xq = x[:64]
    direct = np.asarray(wlsh_krr_predict(model, xq))
    served = np.asarray(loaded.operator.predict_batched(loaded.model.tables,
                                                        xq))
    np.testing.assert_array_equal(served, direct)
    # the arrays themselves survive npz bitwise
    np.testing.assert_array_equal(np.asarray(loaded.model.beta),
                                  np.asarray(model.beta))
    np.testing.assert_array_equal(np.asarray(loaded.model.lsh.r1),
                                  np.asarray(model.lsh.r1))


def test_artifact_roundtrip_multirhs(tmp_path):
    model, x = _fit(jax.random.PRNGKey(3), k_rhs=3)
    export_artifact(str(tmp_path / "art"), model)
    loaded = load_artifact(str(tmp_path / "art"))
    assert loaded.model.tables.shape == model.tables.shape
    np.testing.assert_array_equal(
        np.asarray(wlsh_krr_predict(loaded.model, x[:32])),
        np.asarray(wlsh_krr_predict(model, x[:32])))


def test_artifact_cross_backend_load(fitted, tmp_path):
    """A reference-fit artifact served by the pallas backend (interpret mode
    on CPU) matches to float tolerance — all backends read the same tables."""
    model, x = fitted
    export_artifact(str(tmp_path / "art"), model)
    loaded = load_artifact(str(tmp_path / "art"), backend="pallas")
    assert loaded.operator.backend == "pallas"
    np.testing.assert_allclose(
        np.asarray(loaded.operator.predict_batched(loaded.model.tables,
                                                   x[:32])),
        np.asarray(wlsh_krr_predict(model, x[:32])), atol=1e-6)


def test_artifact_validates_metadata(fitted, tmp_path):
    import json
    import os
    model, _ = fitted
    art = str(tmp_path / "art")
    export_artifact(art, model)
    step_dir = os.path.join(art, "step_1")
    meta_path = os.path.join(step_dir, "meta.json")
    with open(meta_path) as fh:
        meta = json.load(fh)
    # wrong table size
    bad = dict(meta, table_size=meta["table_size"] * 2)
    with open(meta_path, "w") as fh:
        json.dump(bad, fh)
    with pytest.raises(ValueError, match="tables"):
        load_artifact(art)
    # unknown bucket fn
    bad = dict(meta, bucket_name="nope")
    with open(meta_path, "w") as fh:
        json.dump(bad, fh)
    with pytest.raises(ValueError, match="bucket"):
        load_artifact(art)
    # future format version
    with open(meta_path, "w") as fh:
        json.dump(meta, fh)
    os.rename(step_dir, os.path.join(art, "step_99"))
    with pytest.raises(ValueError, match="format"):
        load_artifact(art)


def test_artifact_missing_dir_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        load_artifact(str(tmp_path / "nothing"))


def test_artifact_normalization_roundtrip(tmp_path):
    model, x = _fit(jax.random.PRNGKey(4))
    norm = Normalization(x_mean=np.full((4,), 0.5, np.float32),
                         x_std=np.full((4,), 2.0, np.float32),
                         y_mean=1.5, y_std=3.0)
    export_artifact(str(tmp_path / "art"), model, norm=norm)
    pred = Predictor()
    pred.load(str(tmp_path / "art"))
    xq = np.asarray(x[:16], np.float32)
    out = pred.predict(xq)
    direct = np.asarray(wlsh_krr_predict(
        model, (jnp.asarray(xq) - 0.5) / 2.0)) * 3.0 + 1.5
    np.testing.assert_allclose(out, direct, atol=1e-5)


def test_artifact_without_beta_serves_identically(fitted, tmp_path):
    """include_beta=False drops the O(n_train) training solution; serving
    never reads it, so predictions are unchanged (and still bitwise)."""
    model, x = fitted
    export_artifact(str(tmp_path / "full"), model)
    export_artifact(str(tmp_path / "lean"), model, include_beta=False)
    full = load_artifact(str(tmp_path / "full"))
    lean = load_artifact(str(tmp_path / "lean"))
    assert lean.model.beta.shape[0] == 0
    assert not lean.meta["has_beta"]
    xq = x[:32]
    np.testing.assert_array_equal(
        np.asarray(lean.operator.predict_batched(lean.model.tables, xq)),
        np.asarray(full.operator.predict_batched(full.model.tables, xq)))


# ---------------------------------------------------------------------------
# predictor: padding buckets + compile pinning
# ---------------------------------------------------------------------------

def test_padding_bucket_selection():
    assert [padding_bucket(b, 64) for b in (1, 2, 3, 5, 8, 9, 64, 200)] == \
        [1, 2, 4, 8, 8, 16, 64, 64]
    assert bucket_sizes(64) == (1, 2, 4, 8, 16, 32, 64)
    assert bucket_sizes(1) == (1,)
    with pytest.raises(ValueError):
        padding_bucket(0, 64)


def test_predictor_no_recompile_within_bucket(fitted, tmp_path):
    """Ragged request sizes inside one power-of-two bucket share one compile
    — pinned via the jit cache-miss count."""
    model, x = fitted
    export_artifact(str(tmp_path / "art"), model)
    pred = Predictor()
    pred.load(str(tmp_path / "art"))
    xq = np.asarray(x, np.float32)
    pred.predict(xq[:5], use_cache=False)           # bucket 8: compile 1
    c0 = pred.compile_count()
    for b in (5, 6, 7, 8):                          # all bucket 8
        pred.predict(xq[:b], use_cache=False)
    assert pred.compile_count() == c0               # zero new compiles
    pred.predict(xq[:9], use_cache=False)           # bucket 16: compile 2
    assert pred.compile_count() == c0 + 1
    pred.predict(xq[:16], use_cache=False)
    assert pred.compile_count() == c0 + 1


def test_predictor_warmup_precompiles(fitted, tmp_path):
    model, x = fitted
    export_artifact(str(tmp_path / "art"), model)
    pred = Predictor()
    pred.load(str(tmp_path / "art"))
    n = pred.warmup(sizes=(1, 4, 64))               # buckets 1, 4, 64
    assert n == 3
    pred.predict(np.asarray(x[:3], np.float32))     # bucket 4: no compile
    assert pred.compile_count() == 3


def test_predictor_chunks_above_max_batch(fitted, tmp_path):
    model, x = fitted
    export_artifact(str(tmp_path / "art"), model)
    pred = Predictor(max_batch=64)
    pred.load(str(tmp_path / "art"))
    xq = np.asarray(x[:200], np.float32)            # 64 + 64 + 64 + 8
    out = pred.predict(xq, use_cache=False)
    assert out.shape == (200,)
    np.testing.assert_allclose(out, np.asarray(wlsh_krr_predict(model, xq)),
                               atol=1e-6)


def test_predictor_hosts_multiple_models(tmp_path):
    m1, x1 = _fit(jax.random.PRNGKey(10))
    m2, _ = _fit(jax.random.PRNGKey(11), m=8)
    export_artifact(str(tmp_path / "a1"), m1)
    export_artifact(str(tmp_path / "a2"), m2)
    pred = Predictor()
    pred.load(str(tmp_path / "a1"))
    pred.load(str(tmp_path / "a2"))
    assert pred.artifact_ids == ["a1", "a2"]
    xq = np.asarray(x1[:32], np.float32)
    np.testing.assert_array_equal(
        pred.predict(xq, artifact_id="a1"),
        np.asarray(wlsh_krr_predict(m1, xq)))
    np.testing.assert_array_equal(
        pred.predict(xq, artifact_id="a2"),
        np.asarray(wlsh_krr_predict(m2, xq)))
    with pytest.raises(KeyError):
        pred.predict(xq, artifact_id="missing")


# ---------------------------------------------------------------------------
# bucket-exact cache
# ---------------------------------------------------------------------------

def test_numpy_bucket_keys_match_jax(fitted):
    model, x = fitted
    keyfn = BucketKeyFn(model.lsh, get_bucket_fn("rect"))
    keys, _, _ = keyfn.bucket_ids(np.asarray(x[:50], np.float32))
    feats = featurize(model.lsh, get_bucket_fn("rect"), x[:50])
    np.testing.assert_array_equal(keys[0].T, np.asarray(feats.key1))
    np.testing.assert_array_equal(keys[1].T, np.asarray(feats.key2))


def test_cache_hit_bitwise_matches_cold_path(fitted, tmp_path):
    model, x = fitted
    export_artifact(str(tmp_path / "art"), model)
    cached = Predictor(cache_entries=1024)
    cold = Predictor(cache_entries=0)
    cached.load(str(tmp_path / "art"))
    cold.load(str(tmp_path / "art"))
    xq = np.asarray(x[:64], np.float32)
    first = cached.predict(xq)                       # misses: warm path
    hits = cached.predict(xq)                        # all bucket-key hits
    stats = cached.cache_stats()
    assert stats["hits"] == 64 and stats["misses"] == 64
    np.testing.assert_array_equal(hits, first)
    np.testing.assert_array_equal(hits, cold.predict(xq))


def test_cache_same_bucket_query_is_exact_for_rect(fitted, tmp_path):
    """rect weight is constant inside a bucket, so a DIFFERENT point in the
    same m buckets must hit AND the replayed value must equal that point's
    own cold-path prediction bitwise — the cache is exact, not approximate."""
    model, x = fitted
    export_artifact(str(tmp_path / "art"), model)
    pred = Predictor(cache_entries=1024)
    pred.load(str(tmp_path / "art"))
    keyfn = BucketKeyFn(model.lsh, get_bucket_fn("rect"))
    x0 = np.asarray(x[:1], np.float32)
    # nudge within the bucket: accept the perturbation only if every one of
    # the m bucket ids is unchanged
    x1 = None
    for eps in (1e-4, 1e-5, 1e-6):
        cand = (x0 + eps).astype(np.float32)
        if keyfn(cand) == keyfn(x0) and not np.array_equal(cand, x0):
            x1 = cand
            break
    assert x1 is not None, "no same-bucket perturbation found"
    cold = np.asarray(pred.predict(x1[0], use_cache=False))
    pred.predict(x0[0])                              # insert x0's row
    st0 = pred.cache_stats()
    out = pred.predict(x1[0])                        # different point, same key
    st1 = pred.cache_stats()
    assert st1["hits"] == st0["hits"] + 1
    np.testing.assert_array_equal(out, cold)


def test_cache_nonrect_requires_identical_point(tmp_path):
    """tent weights vary inside a bucket: the key carries the residual, so a
    same-bucket-different-point query must MISS (a hit there would be wrong)."""
    model, x = _fit(jax.random.PRNGKey(7), bucket="tent")
    keyfn = BucketKeyFn(model.lsh, get_bucket_fn("tent"))
    x0 = np.asarray(x[:1], np.float32)
    x1 = (x0 + 1e-5).astype(np.float32)
    assert keyfn(x0) == keyfn(x0)                    # deterministic
    assert keyfn(x1) != keyfn(x0)
    assert not keyfn.exact_within_bucket


def test_cache_keys_nonfinite_rows_warning_free(fitted):
    """NaN/inf queries fall back to raw-identity keys: distinct garbage rows
    never alias, identical ones still hit — and the f32->int32 cast they
    trigger must not leak a RuntimeWarning into the serving path."""
    import warnings

    model, _ = fitted
    keyfn = BucketKeyFn(model.lsh, get_bucket_fn("rect"))
    bad = np.zeros((3, 4), np.float32)
    bad[0, 0], bad[1, 1], bad[2, 2] = np.nan, np.inf, 3e9   # |h| >= 2^31
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        keys = keyfn(bad)
    assert all(k.startswith(b"!raw") for k in keys)
    assert len(set(keys)) == 3                       # no aliasing
    assert keyfn(bad) == keys                        # deterministic


def test_cache_lru_eviction_and_stats():
    cache = PredictionCache(max_entries=2)
    cache.put_many([b"a", b"b"], [np.float32(1), np.float32(2)])
    assert cache.get_many([b"a"]) == [np.float32(1)]   # refreshes a
    cache.put_many([b"c"], [np.float32(3)])            # evicts b (LRU)
    out = cache.get_many([b"b", b"a", b"c"])
    assert out[0] is None and out[1] == 1 and out[2] == 3
    st = cache.stats()
    assert st["evictions"] == 1 and st["entries"] == 2
    with pytest.raises(ValueError):
        PredictionCache(max_entries=0)


# ---------------------------------------------------------------------------
# micro-batcher
# ---------------------------------------------------------------------------

def test_batcher_roundtrips_and_coalesces(fitted, tmp_path):
    model, x = fitted
    export_artifact(str(tmp_path / "art"), model)
    pred = Predictor(cache_entries=4096)
    pred.load(str(tmp_path / "art"))
    pred.warmup(sizes=bucket_sizes(16))
    xq = np.asarray(x[:50], np.float32)
    expect = {i: np.asarray(pred.predict(xq[i])) for i in range(50)}
    pred.clear_cache()
    with MicroBatcher(lambda xb: pred.predict(xb), max_batch=16,
                      max_wait_us=5000) as mb:
        futures = [mb.submit(xq[i % 50]) for i in range(200)]
        results = [f.result(timeout=30) for f in futures]
        stats = mb.stats()
    assert stats["served"] == 200
    assert stats["batches"] < 200          # actually coalesced
    assert stats["mean_batch"] > 1.0
    assert 0 < stats["p50_us"] <= stats["p99_us"]
    for i, got in enumerate(results):
        np.testing.assert_allclose(np.asarray(got), expect[i % 50], atol=1e-6)


def test_batcher_deadline_flushes_lone_request(fitted, tmp_path):
    model, x = fitted
    export_artifact(str(tmp_path / "art"), model)
    pred = Predictor()
    pred.load(str(tmp_path / "art"))
    pred.warmup(sizes=(1,))
    with MicroBatcher(lambda xb: pred.predict(xb), max_batch=64,
                      max_wait_us=1000) as mb:
        fut = mb.submit(np.asarray(x[0], np.float32))
        out = fut.result(timeout=10)       # resolves without 63 more requests
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(pred.predict(x[0])))


def test_batcher_propagates_predict_errors():
    def boom(xb):
        raise RuntimeError("model exploded")
    with MicroBatcher(boom, max_batch=4, max_wait_us=100) as mb:
        fut = mb.submit(np.zeros((3,), np.float32))
        with pytest.raises(RuntimeError, match="model exploded"):
            fut.result(timeout=10)


def test_batcher_rejects_wrong_dim_without_failing_batch():
    """A malformed request is refused at ITS submit() — the requests already
    coalescing around it still resolve normally."""
    def echo(xb):
        return np.zeros((len(xb),), np.float32)

    with MicroBatcher(echo, max_batch=8, max_wait_us=5000, dim=3) as mb:
        good = [mb.submit(np.zeros((3,), np.float32)) for _ in range(4)]
        with pytest.raises(ValueError, match="features"):
            mb.submit(np.zeros((7,), np.float32))
        assert all(f.result(timeout=10) == 0.0 for f in good)
    # without an explicit dim the first accepted request locks it in
    with MicroBatcher(echo, max_batch=8, max_wait_us=100) as mb:
        mb.submit(np.zeros((5,), np.float32)).result(timeout=10)
        with pytest.raises(ValueError, match="features"):
            mb.submit(np.zeros((4,), np.float32))


def test_batcher_close_drains_and_rejects_new():
    served = []

    def slow(xb):
        served.append(len(xb))
        return np.zeros((len(xb),), np.float32)

    mb = MicroBatcher(slow, max_batch=8, max_wait_us=50)
    futs = [mb.submit(np.zeros((2,), np.float32)) for _ in range(20)]
    mb.close()
    assert all(f.done() for f in futs)
    assert sum(served) == 20
    with pytest.raises(RuntimeError):
        mb.submit(np.zeros((2,), np.float32))


def test_batcher_threaded_submitters(fitted, tmp_path):
    model, x = fitted
    export_artifact(str(tmp_path / "art"), model)
    pred = Predictor(cache_entries=4096)
    pred.load(str(tmp_path / "art"))
    pred.warmup(sizes=bucket_sizes(32))
    xq = np.asarray(x[:40], np.float32)
    expect = np.asarray(pred.predict(xq))
    errs = []
    with MicroBatcher(lambda xb: pred.predict(xb), max_batch=32,
                      max_wait_us=2000) as mb:
        def client(rows):
            try:
                for i in rows:
                    got = mb.submit(xq[i]).result(timeout=30)
                    np.testing.assert_allclose(np.asarray(got), expect[i],
                                               atol=1e-6)
            except Exception as e:          # surfaces in the main thread
                errs.append(e)
        threads = [threading.Thread(target=client,
                                    args=(range(j, 40, 4),))
                   for j in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    assert not errs


# ---------------------------------------------------------------------------
# degraded-mode serving (DESIGN.md §9): crash, shed, deadlines, health
# ---------------------------------------------------------------------------

def test_batcher_worker_crash_fails_all_futures_and_fast_fails_submit():
    """A worker-thread death (injected OUTSIDE the predict try/except) must
    fail every in-flight and queued future with WorkerCrashed and make later
    submits raise immediately — nobody ever hangs on a dead worker."""
    from repro.serve import WorkerCrashed
    from repro.testing import crash_worker
    gate = threading.Event()

    def slow_predict(xb):
        gate.wait(5.0)
        return np.zeros((xb.shape[0],), np.float32)

    mb = MicroBatcher(slow_predict, max_batch=4, max_wait_us=500, dim=2)
    crash_worker(mb)
    futs = [mb.submit(np.zeros(2, np.float32)) for _ in range(6)]
    gate.set()
    for f in futs:
        with pytest.raises(WorkerCrashed):
            f.result(timeout=10.0)
    assert mb.stats()["crashed"]
    with pytest.raises(WorkerCrashed):       # fail-fast, not a queue hang
        mb.submit(np.zeros(2, np.float32))
    mb.close()                               # idempotent after a crash


def test_batcher_load_shedding_returns_overloaded():
    """Submits past max_queue fail at once with Overloaded carrying the
    queue depth; accepted requests still serve correctly afterwards."""
    from repro.serve import Overloaded
    gate = threading.Event()

    def gated_predict(xb):
        gate.wait(10.0)
        return np.arange(xb.shape[0]).astype(np.float32)

    with MicroBatcher(gated_predict, max_batch=1, max_wait_us=100,
                      dim=2, max_queue=2) as mb:
        futs = [mb.submit(np.zeros(2, np.float32)) for _ in range(12)]
        shed = [f for f in futs if f.done()
                and isinstance(f.exception(), Overloaded)]
        assert shed, "nothing shed at queue depth 2 under a blocked worker"
        assert shed[0].exception().queue_depth >= 2
        gate.set()
        served = 0
        for f in futs:
            if f in shed:
                continue
            assert f.result(timeout=10.0) is not None
            served += 1
        stats = mb.stats()
    assert stats["shed"] == len(shed)
    assert stats["shed_rate"] == pytest.approx(len(shed) / 12)
    assert served == 12 - len(shed)


def test_batcher_deadline_expires_queued_requests():
    """A request whose deadline budget elapses while queued fails with
    DeadlineExceeded at flush time, BEFORE costing a predict call."""
    from repro.serve import DeadlineExceeded
    gate = threading.Event()
    calls = []

    def gated_predict(xb):
        calls.append(xb.shape[0])
        gate.wait(10.0)
        return np.zeros((xb.shape[0],), np.float32)

    with MicroBatcher(gated_predict, max_batch=1, max_wait_us=100,
                      dim=2) as mb:
        f1 = mb.submit(np.zeros(2, np.float32))          # occupies worker
        f2 = mb.submit(np.ones(2, np.float32), deadline_us=10_000)
        time.sleep(0.1)                                  # budget burns out
        gate.set()
        assert f1.result(timeout=10.0) is not None
        with pytest.raises(DeadlineExceeded) as ei:
            f2.result(timeout=10.0)
        assert ei.value.waited_s >= 0.01
        stats = mb.stats()
    assert stats["deadline_expired"] == 1
    assert calls.count(1) == 1      # the expired request never ran predict


def test_predictor_rejects_nan_query_structured(fitted, tmp_path):
    """A NaN/Inf query row surfaces as InvalidRequest — never a silently-NaN
    prediction, and never a poisoned cache entry replayed to later calls."""
    from repro.serve import InvalidRequest
    model, x = fitted
    export_artifact(str(tmp_path / "art"), model)
    pred = Predictor(cache_entries=64)
    pred.load(str(tmp_path / "art"))
    bad = np.asarray(x[:4], np.float32).copy()
    bad[2, 0] = np.nan
    with pytest.raises(InvalidRequest, match=r"\[2\]"):
        pred.predict(bad)
    with pytest.raises(InvalidRequest):
        pred.predict(np.full((3,), np.inf, np.float32))
    # the clean rows still serve, and health recorded the rejections
    out = pred.predict(np.asarray(x[:4], np.float32))
    assert np.isfinite(out).all()
    h = pred.health()
    assert h["errors"] == 2 and "InvalidRequest" in h["last_error"]


def test_predictor_health_snapshot_with_batcher(fitted, tmp_path):
    model, x = fitted
    export_artifact(str(tmp_path / "art"), model)
    pred = Predictor()
    aid = pred.load(str(tmp_path / "art"))
    with MicroBatcher(lambda xb: pred.predict(xb), max_batch=8,
                      max_wait_us=500) as mb:
        pred.attach_batcher(mb)
        for row in np.asarray(x[:8], np.float32):
            mb.submit(row).result(timeout=10.0)
        h = pred.health()
    assert h["ok"] and h["models"] == [aid]
    assert h["requests"] >= 1 and h["errors"] == 0
    assert h["batcher"]["queue_depth"] == 0
    assert not h["batcher"]["crashed"]


def test_predictor_fault_plan_drives_serve_failures(fitted, tmp_path):
    """FaultPlan(serve_fail_every=N) fails every Nth warm call with
    FaultInjected — the hook the shed/deadline stress tests hang load on."""
    from repro.errors import FaultInjected
    from repro.testing import FaultPlan
    model, x = fitted
    export_artifact(str(tmp_path / "art"), model)
    pred = Predictor(fault_plan=FaultPlan(serve_fail_every=2))
    pred.load(str(tmp_path / "art"))
    xq = np.asarray(x[:2], np.float32)
    assert np.isfinite(pred.predict(xq)).all()           # call 1 clean
    with pytest.raises(FaultInjected):                   # call 2 injected
        pred.predict(xq)
    assert np.isfinite(pred.predict(xq)).all()           # call 3 clean
    assert pred.health()["errors"] == 1


def test_artifact_load_retries_transient_io(fitted, tmp_path, monkeypatch):
    """Transient I/O failures (flaky filesystem) retry with backoff;
    validation errors never retry.  retries=0 keeps historical behavior."""
    import repro.serve.artifact as art_mod
    model, x = fitted
    export_artifact(str(tmp_path / "art"), model)
    real_once = art_mod._load_artifact_once
    fails = {"n": 2}

    def flaky(directory, **kw):
        if fails["n"] > 0:
            fails["n"] -= 1
            raise OSError("transient read failure")
        return real_once(directory, **kw)

    monkeypatch.setattr(art_mod, "_load_artifact_once", flaky)
    with pytest.raises(OSError):
        load_artifact(str(tmp_path / "art"))             # no retries: raises
    fails["n"] = 2
    loaded = load_artifact(str(tmp_path / "art"), retries=3,
                           retry_backoff_s=0.01)
    assert loaded.artifact_id == "art"
    assert fails["n"] == 0


def test_artifact_rejects_nonfinite_tables(fitted, tmp_path):
    """A poisoned artifact (NaN in the tables) is refused at load — the
    predictor can never host a model that answers NaN to every query."""
    model, x = fitted
    poisoned = model._replace(
        tables=jnp.asarray(model.tables).at[0, 0].set(jnp.nan))
    export_artifact(str(tmp_path / "bad"), poisoned)
    with pytest.raises(ValueError, match="non-finite"):
        load_artifact(str(tmp_path / "bad"))
