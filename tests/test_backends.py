"""Backend parity: the 'reference' (jnp) and 'pallas' (fused kernel)
implementations of the WLSH operator must agree bit-for-bit on hashes/signs
and to float tolerance on weights/tables/matvecs — including the internal
padding paths (n not a multiple of the point block, table_size not a
multiple of the table tile)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.backend import resolve_backend
from repro.core import (GammaPDF, WLSHKernelSpec, get_bucket_fn, make_operator,
                        sample_lsh_params, wlsh_krr_fit, wlsh_krr_predict)
from repro.core.operator import default_table_size


def _ops(key, n, d, m, table_size, bucket="rect"):
    x = jax.random.uniform(key, (n, d)) * 2.0
    lsh = sample_lsh_params(jax.random.fold_in(key, 1), m, d,
                            GammaPDF(2.0, 1.0))
    f = get_bucket_fn(bucket)
    beta = jax.random.normal(jax.random.fold_in(key, 2), (n,))
    ref = make_operator(lsh, f, table_size, backend="reference")
    pal = make_operator(lsh, f, table_size, backend="pallas")
    return x, beta, ref, pal


# n=300 exercises point padding (300 -> 384); n=128 is block-aligned
@pytest.mark.parametrize("n,d,m,table_size", [(128, 2, 3, 256),
                                              (300, 5, 4, 512),
                                              (97, 3, 2, 1024)])
def test_featurize_parity(n, d, m, table_size):
    x, _, ref, pal = _ops(jax.random.PRNGKey(n + d), n, d, m, table_size)
    fr, fp = ref.featurize(x), pal.featurize(x)
    assert fr.key1.shape == fp.key1.shape == (m, n)
    assert bool(jnp.all(fr.key1 == fp.key1))
    assert bool(jnp.all(fr.key2 == fp.key2))
    assert bool(jnp.all(fr.sign == fp.sign))
    np.testing.assert_allclose(fr.weight, fp.weight, atol=2e-6)


@pytest.mark.parametrize("n,table_size", [(300, 512), (128, 256)])
def test_tables_and_matvec_parity(n, table_size):
    x, beta, ref, pal = _ops(jax.random.PRNGKey(7 * n), n, 3, 4, table_size)
    fr = ref.featurize(x)
    idx = ref.build_index(fr)
    tr, tp = ref.loads(idx, beta), pal.loads(idx, beta)
    assert tr.shape == tp.shape == (4, table_size)
    np.testing.assert_allclose(tr, tp, atol=1e-4)
    np.testing.assert_allclose(ref.matvec(idx, beta), pal.matvec(idx, beta),
                               atol=1e-4)
    # sum-mode readout (the distributed path) must agree too
    np.testing.assert_allclose(ref.readout(idx, tr, average=False),
                               pal.readout(idx, tp, average=False), atol=1e-4)


def test_table_tile_padding_path():
    """table_size not a multiple of the table tile: the kernel pads the table
    internally and trims — results must match the reference exactly."""
    from repro.core.wlsh import table_loads, table_readout
    from repro.kernels.binning.ops import bin_loads_op, bin_readout_op
    key = jax.random.PRNGKey(11)
    x, beta, ref, _ = _ops(key, 200, 3, 3, 1024)
    idx = ref.build_index(ref.featurize(x))
    # block_t=384 does not divide 1024 -> internal pad to 1152, trim to 1024
    tk = bin_loads_op(idx, beta, interpret=True, block_t=384)
    tr = table_loads(idx, beta)
    assert tk.shape == tr.shape
    np.testing.assert_allclose(tk, tr, atol=1e-4)
    np.testing.assert_allclose(
        bin_readout_op(idx, tr, interpret=True, block_t=384),
        table_readout(idx, tr), atol=1e-5)


def test_predict_batched_streams_fixed_blocks():
    """Blocked prediction == whole-set prediction, both backends, including a
    final partial block (n_test % batch_size != 0)."""
    key = jax.random.PRNGKey(3)
    x, beta, ref, pal = _ops(key, 260, 4, 5, 512)
    idx = ref.build_index(ref.featurize(x))
    tables = ref.loads(idx, beta)
    whole = ref.predict_batched(tables, x)
    for op in (ref, pal):
        blocked = op.predict_batched(tables, x, batch_size=64)
        np.testing.assert_allclose(blocked, whole, atol=1e-5)


def test_krr_fit_backend_parity():
    """Acceptance criterion: wlsh_krr_fit(..., backend='pallas') and
    backend='reference' agree to <= 1e-5 on predictions."""
    key = jax.random.PRNGKey(0)
    n, d = 300, 3
    x = jax.random.uniform(key, (n, d)) * 2.0
    y = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    spec = WLSHKernelSpec(bucket=get_bucket_fn("rect"))
    # tight CG tol: compare converged solutions, not mid-trajectory iterates —
    # the fused kernels' accumulation grouping differs by ~1e-7 per matvec,
    # which a loose solve amplifies past the 1e-5 acceptance bar
    fit = lambda backend: wlsh_krr_fit(jax.random.fold_in(key, 2), x, y, spec,
                                       m=24, lam=0.5, maxiter=200, tol=1e-7,
                                       backend=backend)
    m_ref, m_pal = fit("reference"), fit("pallas")
    assert m_ref.backend == "reference" and m_pal.backend == "pallas"
    xq = jax.random.uniform(jax.random.fold_in(key, 3), (77, d)) * 2.0
    p_ref = wlsh_krr_predict(m_ref, xq)
    p_pal = wlsh_krr_predict(m_pal, xq)
    np.testing.assert_allclose(p_ref, p_pal, atol=1e-5)
    # cross-backend serving: pallas-fit model served by the reference backend
    np.testing.assert_allclose(wlsh_krr_predict(m_pal, xq, backend="reference"),
                               p_ref, atol=1e-5)


def test_auto_backend_resolution(monkeypatch):
    monkeypatch.delenv("REPRO_WLSH_BACKEND", raising=False)
    assert resolve_backend("reference") == "reference"
    assert resolve_backend("pallas") == "pallas"
    expected = "pallas" if jax.default_backend() == "tpu" else "reference"
    assert resolve_backend("auto") == expected
    assert resolve_backend(None) == expected
    monkeypatch.setenv("REPRO_WLSH_BACKEND", "pallas")
    assert resolve_backend("auto") == "pallas"      # env overrides auto...
    assert resolve_backend("reference") == "reference"  # ...but not explicit
    with pytest.raises(ValueError):
        resolve_backend("mps")


def test_default_table_size_heuristic():
    assert default_table_size(1000) == 4096
    assert default_table_size(1024) == 4096
    assert default_table_size(1025) == 8192
    assert default_table_size(1) == 256   # floor at 2^8
