"""Fused one-pass CountSketch matvec (slot-blocked layout).

Pins the PR's acceptance criteria: parity with the split reference path
(<= 1e-5, including odd n / non-dividing tile sizes / m=1 / zero weights),
the O(n) tile-visit schedule (vs the old (n/bn)·(B/bt) cross product), the
HBM residency claim (the (m, B) table exists in the split program's HLO but
never in the fused one), bitwise stability of the solver across the fused
toggle on the reference backend, and the CG atol floor.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (GammaPDF, WLSHKernelSpec, cg_solve, get_bucket_fn,
                        make_operator, sample_lsh_params, wlsh_krr_fit)
from repro.core.wlsh import (build_blocked_layout, build_table_index,
                             table_matvec, table_matvec_fused)
from repro.hlo_analysis import materializes_shape
from repro.kernels.binning import bin_fused_matvec_op


def _setup(key, n, d, m, table_size, bucket="rect"):
    x = jax.random.uniform(key, (n, d)) * 2.0
    lsh = sample_lsh_params(jax.random.fold_in(key, 1), m, d,
                            GammaPDF(2.0, 1.0))
    beta = jax.random.normal(jax.random.fold_in(key, 2), (n,))
    f = get_bucket_fn(bucket)
    split = make_operator(lsh, f, table_size, backend="reference", fused=False)
    fused_ref = make_operator(lsh, f, table_size, backend="reference")
    fused_pal = make_operator(lsh, f, table_size, backend="pallas")
    feats = split.featurize(x)
    sidx = split.build_index(feats)
    # each backend's build_index materializes only its own layout group
    fidx = fused_ref.build_index(feats)
    fidx_pal = fused_pal.build_index(feats)
    return beta, split, fused_ref, fused_pal, sidx, fidx, fidx_pal


# odd n, n < block_n, m=1, table sizes from one tile up — all padding paths
@pytest.mark.parametrize("n,d,m,table_size", [(97, 3, 2, 512),
                                              (300, 5, 4, 1024),
                                              (128, 2, 1, 256),
                                              (257, 3, 3, 2048)])
def test_fused_matvec_parity(n, d, m, table_size):
    key = jax.random.PRNGKey(n + d + m)
    beta, split, fused_ref, fused_pal, sidx, fidx, fidx_pal = \
        _setup(key, n, d, m, table_size)
    assert sidx.blocked is None and fidx.blocked is not None
    want = split.matvec(sidx, beta)
    got_ref = fused_ref.matvec(fidx, beta)
    got_pal = fused_pal.matvec(fidx_pal, beta)
    np.testing.assert_allclose(got_ref, want, atol=1e-5)
    np.testing.assert_allclose(got_pal, want, atol=1e-5)
    # sum mode (the distributed model-axis contribution) must agree too
    want_sum = split.matvec(sidx, beta, average=False)
    np.testing.assert_allclose(fused_ref.matvec(fidx, beta, average=False),
                               want_sum, atol=1e-4)
    np.testing.assert_allclose(fused_pal.matvec(fidx_pal, beta, average=False),
                               want_sum, atol=1e-4)


def test_fused_kernel_odd_tile_size():
    """table_size not divisible by block_t: the tile grid covers
    ceil(B / bt) tiles and the trailing partial tile just stays sparse."""
    key = jax.random.PRNGKey(11)
    n, d, m, table_size = 200, 3, 3, 1024
    x = jax.random.uniform(key, (n, d)) * 2.0
    lsh = sample_lsh_params(jax.random.fold_in(key, 1), m, d,
                            GammaPDF(2.0, 1.0))
    beta = jax.random.normal(jax.random.fold_in(key, 2), (n,))
    feats = make_operator(lsh, get_bucket_fn("rect"), table_size,
                          backend="reference").featurize(x)
    idx = build_table_index(feats, table_size)
    # 384 does not divide 1024 -> 3 tiles covering [0, 1152)
    lay = build_blocked_layout(idx.slot, idx.coeff, table_size,
                               block_n=128, block_t=384)
    idx = idx._replace(blocked=lay)
    want = table_matvec(idx, beta)
    np.testing.assert_allclose(bin_fused_matvec_op(idx, beta, interpret=True),
                               want, atol=1e-5)
    np.testing.assert_allclose(table_matvec_fused(idx, beta), want, atol=1e-5)


def test_fused_matvec_all_zero_weights():
    """coeff = 0 everywhere -> the matvec is exactly zero on every path."""
    key = jax.random.PRNGKey(5)
    n, d, m, table_size = 130, 2, 2, 512
    x = jax.random.uniform(key, (n, d)) * 2.0
    lsh = sample_lsh_params(jax.random.fold_in(key, 1), m, d,
                            GammaPDF(2.0, 1.0))
    beta = jax.random.normal(jax.random.fold_in(key, 2), (n,))
    op = make_operator(lsh, get_bucket_fn("rect"), table_size,
                       backend="reference")
    feats = op.featurize(x)
    feats = feats._replace(weight=jnp.zeros_like(feats.weight))
    idx = build_table_index(feats, table_size)
    idx = idx._replace(blocked=build_blocked_layout(idx.slot, idx.coeff,
                                                    table_size))
    assert bool(jnp.all(table_matvec_fused(idx, beta) == 0.0))
    assert bool(jnp.all(bin_fused_matvec_op(idx, beta, interpret=True) == 0.0))


def test_blocked_layout_schedules_O_n_tiles():
    """The visit schedule is O(n/bn + B/bt) per instance — linear in n when
    B = Θ(n) — not the (n/bn)·(B/bt) cross product the split grid iterates."""
    key = jax.random.PRNGKey(3)
    n, d, m, table_size = 8192, 4, 4, 32768
    bn, bt = 128, 512
    x = jax.random.uniform(key, (n, d)) * 2.0
    lsh = sample_lsh_params(jax.random.fold_in(key, 1), m, d,
                            GammaPDF(2.0, 1.0))
    op = make_operator(lsh, get_bucket_fn("rect"), table_size,
                       backend="reference")
    idx = op.build_index(op.featurize(x))
    lay = build_blocked_layout(idx.slot, idx.coeff, table_size,
                               block_n=bn, block_t=bt, parts="pallas")
    n_tiles = table_size // bt
    bound = 2 * (n // bn + n_tiles)          # scatter + gather passes
    assert lay.v_block.shape[1] == bound      # static grid is already O(n)
    assert int(jnp.max(lay.n_visits)) <= bound
    cross_product = (n // bn) * n_tiles       # split-kernel visits/instance
    assert bound < cross_product / 4
    # doubling n (with B = 4n) must double the schedule, not quadruple it:
    # build the 2n layout for real and compare static and measured visits
    x2 = jax.random.uniform(jax.random.fold_in(key, 9), (2 * n, d)) * 2.0
    op2 = make_operator(lsh, get_bucket_fn("rect"), 2 * table_size,
                        backend="reference")
    idx2 = op2.build_index(op2.featurize(x2), blocked=False)
    lay2 = build_blocked_layout(idx2.slot, idx2.coeff, 2 * table_size,
                                block_n=bn, block_t=bt, parts="pallas")
    assert lay2.v_block.shape[1] == 2 * bound
    assert int(jnp.max(lay2.n_visits)) <= 2 * bound
    # the cross product would have quadrupled
    assert (2 * n // bn) * (2 * table_size // bt) == 4 * cross_product


def test_fused_matvec_table_never_materialized_to_hbm():
    """Acceptance criterion: the (m, B) table appears in the split program's
    HLO (scatter output round-trips through HBM into the gather) but never
    in the fused program (VMEM scratch tile only)."""
    key = jax.random.PRNGKey(7)
    n, d, m, table_size = 300, 3, 4, 1024
    beta, split, fused_ref, fused_pal, sidx, fidx, fidx_pal = \
        _setup(key, n, d, m, table_size)
    pal_split = make_operator(split.lsh, split.bucket, table_size,
                              backend="pallas", fused=False)
    for op_split, op_fused, idx in ((split, fused_ref, fidx),
                                    (pal_split, fused_pal, fidx_pal)):
        hlo_split = jax.jit(lambda b: op_split.matvec(sidx, b)) \
            .lower(beta).compile().as_text()
        hlo_fused = jax.jit(lambda b: op_fused.matvec(idx, b)) \
            .lower(beta).compile().as_text()
        assert materializes_shape(hlo_split, (m, table_size))
        assert not materializes_shape(hlo_fused, (m, table_size))


def test_wlsh_krr_fit_bitwise_stable_across_fused_toggle():
    """Acceptance criterion: fused vs split solve on the reference backend
    produces bitwise-identical (beta, tables) — the stable slot sort keeps
    every bucket's contributions in the same addition order."""
    key = jax.random.PRNGKey(0)
    n, d = 300, 3
    x = jax.random.uniform(key, (n, d)) * 2.0
    y = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    spec = WLSHKernelSpec(bucket=get_bucket_fn("rect"))
    fit = lambda fused: wlsh_krr_fit(jax.random.fold_in(key, 2), x, y, spec,
                                     m=16, lam=0.5, maxiter=60,
                                     backend="reference", fused=fused)
    m_fused, m_split = fit(True), fit(False)
    np.testing.assert_array_equal(np.asarray(m_fused.beta),
                                  np.asarray(m_split.beta))
    np.testing.assert_array_equal(np.asarray(m_fused.tables),
                                  np.asarray(m_split.tables))
    assert int(m_fused.cg_iters) == int(m_split.cg_iters)


def test_distributed_fused_local_matvec_single_data_shard():
    """Data axes of size 1: make_krr_step takes the fused local-matvec branch
    (no table psum needed) and must be bitwise-equal to the split step —
    same guarantee as the single-host fused toggle."""
    from repro.compat import make_mesh
    from repro.core.distributed import KRRStepConfig, make_krr_step
    mesh = make_mesh((1, 1, 1), ("pod", "data", "model"))
    n, d, m, table_size = 192, 3, 4, 512
    key = jax.random.PRNGKey(6)
    x = jax.random.uniform(key, (n, d)) * 2.0
    y = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    lsh = sample_lsh_params(jax.random.fold_in(key, 2), m, d,
                            GammaPDF(2.0, 1.0))
    f = get_bucket_fn("rect")
    cfg_fused = KRRStepConfig(m=m, table_size=table_size, lam=0.5,
                              cg_iters=15, data_axes=("pod", "data"),
                              model_axis="model", backend="reference",
                              fused=True)
    cfg_split = cfg_fused._replace(fused=False)
    b_f, r_f, t_f = jax.jit(make_krr_step(mesh, cfg_fused, f))(x, y, lsh)
    b_s, r_s, t_s = jax.jit(make_krr_step(mesh, cfg_split, f))(x, y, lsh)
    np.testing.assert_array_equal(np.asarray(b_f), np.asarray(b_s))
    np.testing.assert_array_equal(np.asarray(t_f), np.asarray(t_s))
    assert float(r_f) == float(r_s)


def test_cg_zero_rhs_terminates_immediately():
    """atol floor: b = 0 must not loop maxiter times on thresh = 0."""
    res = cg_solve(lambda v: v, jnp.zeros((16,), jnp.float32), lam=1.0)
    assert int(res.iters) == 0
    assert float(res.resnorm) == 0.0


def test_wlsh_krr_fit_exposes_tol_atol():
    """tol/atol thread through to cg_solve: an all-zero target terminates in
    zero iterations (atol floor), and a loose tol stops earlier than a
    tight one."""
    key = jax.random.PRNGKey(4)
    n, d = 200, 2
    x = jax.random.uniform(key, (n, d)) * 2.0
    y = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    spec = WLSHKernelSpec(bucket=get_bucket_fn("rect"))
    zero = wlsh_krr_fit(jax.random.fold_in(key, 2), x, jnp.zeros_like(y),
                        spec, m=8, lam=0.5, backend="reference")
    assert int(zero.cg_iters) == 0
    loose = wlsh_krr_fit(jax.random.fold_in(key, 2), x, y, spec, m=8,
                         lam=0.5, tol=1e-2, backend="reference")
    tight = wlsh_krr_fit(jax.random.fold_in(key, 2), x, y, spec, m=8,
                         lam=0.5, tol=1e-7, atol=0.0, backend="reference")
    assert int(loose.cg_iters) < int(tight.cg_iters)
