"""HLO analyzer: FLOP counting with while-loop trip counts, collective
parsing, roofline terms."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.hlo_analysis import (Roofline, analyze_compiled, analyze_hlo_text,
                                PEAK_FLOPS)


def test_dot_flops_single():
    m, k, n = 128, 256, 64

    def f(a, b):
        return a @ b

    compiled = jax.jit(f).lower(jax.ShapeDtypeStruct((m, k), jnp.float32),
                                jax.ShapeDtypeStruct((k, n), jnp.float32)
                                ).compile()
    stats = analyze_hlo_text(compiled.as_text())
    assert stats.flops == 2.0 * m * k * n


def test_scan_trip_count_multiplies_flops():
    m, k, n, trips = 64, 64, 64, 12

    def f(a, bs):
        def body(carry, b):
            return carry @ b, None
        out, _ = jax.lax.scan(body, a, bs)
        return out

    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((m, k), jnp.float32),
        jax.ShapeDtypeStruct((trips, k, n), jnp.float32)).compile()
    stats = analyze_hlo_text(compiled.as_text())
    expected = 2.0 * m * k * n * trips
    # XLA may or may not annotate the trip count; when it does we must use it
    assert stats.flops == expected, (stats.flops, expected)


def test_batched_dot_flops():
    b, m, k, n = 4, 32, 64, 16
    compiled = jax.jit(lambda a, c: jnp.einsum("bmk,bkn->bmn", a, c)).lower(
        jax.ShapeDtypeStruct((b, m, k), jnp.float32),
        jax.ShapeDtypeStruct((b, k, n), jnp.float32)).compile()
    stats = analyze_hlo_text(compiled.as_text())
    assert stats.flops == 2.0 * b * m * k * n


def test_roofline_terms_and_dominance():
    r = Roofline(name="x", chips=2, hlo_flops=2 * PEAK_FLOPS,
                 hbm_bytes=0.0, collective_bytes=0.0, model_flops=PEAK_FLOPS)
    assert r.t_compute == 1.0
    assert r.dominant == "compute"
    assert np.isclose(r.roofline_frac, 0.5)
    r2 = Roofline(name="y", chips=1, hlo_flops=0.0, hbm_bytes=819e9 * 2,
                  collective_bytes=50e9, model_flops=0.0)
    assert r2.dominant == "memory"
    assert np.isclose(r2.t_memory, 2.0) and np.isclose(r2.t_collective, 1.0)


def test_analyze_compiled_smoke():
    compiled = jax.jit(lambda a: (a @ a.T).sum()).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    roof = analyze_compiled("t", compiled, chips=1, model_flops=2.0 * 64 ** 3)
    assert roof.hlo_flops >= 2.0 * 64 ** 3
    assert roof.hbm_bytes > 0
    assert roof.collective_bytes == 0.0
