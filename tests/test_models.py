"""Model-layer tests: per-arch smoke (reduced configs), prefill/decode
consistency, mixer oracles (mamba2/rwkv6/moe), windowed ring caches."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import MoESpec
from repro.models import model
from repro.models.mamba2 import mamba2_ref_scan, ssd_chunked
from repro.models.moe import moe_ffn, moe_specs
from repro.models.params import init_params
from repro.models.rwkv6 import wkv_scan, wkv_step

B, S = 2, 12


def _batch(cfg, key, seq=S, batch=B):
    toks = jax.random.randint(key, (batch, seq), 0, cfg.vocab_size)
    out = {"tokens": toks, "labels": jnp.concatenate(
        [toks[:, 1:], jnp.full((batch, 1), -1, jnp.int32)], axis=1)}
    if cfg.encoder is not None:
        out["frames"] = 0.1 * jax.random.normal(
            key, (batch, cfg.encoder.n_frames, cfg.d_model))
    elif cfg.cross_attn_source_len:
        out["patches"] = 0.1 * jax.random.normal(
            key, (batch, cfg.cross_attn_source_len, cfg.d_model))
    return out


def _high_capacity(cfg):
    if cfg.moe is None:
        return cfg
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_arch_smoke_loss_finite(arch, rng):
    cfg = registry.smoke_config(arch)
    params = model.init(cfg, rng)
    loss, metrics = model.loss_fn(cfg, params, _batch(cfg, rng),
                                  dtype=jnp.float32)
    assert bool(jnp.isfinite(loss)), arch
    assert float(loss) > 0.0
    h, _, _ = model.forward(cfg, params, _batch(cfg, rng), dtype=jnp.float32)
    assert h.shape == (B, S, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(h)))


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_arch_grads_finite(arch, rng):
    cfg = registry.smoke_config(arch)
    params = model.init(cfg, rng)
    grads = jax.grad(lambda p: model.loss_fn(cfg, p, _batch(cfg, rng),
                                             dtype=jnp.float32)[0])(params)
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.all(jnp.isfinite(leaf))), arch


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_prefill_decode_consistency(arch, rng):
    """decode_step(prefill(x[:S]), x[S]) == forward(x[:S+1])[-1] — validates
    ring caches, SSM states, token shifts, and cross-attn caches."""
    cfg = _high_capacity(registry.smoke_config(arch))
    params = model.init(cfg, rng)
    full = _batch(cfg, rng, seq=S + 1)
    h, _, _ = model.forward(cfg, params, full, dtype=jnp.float32)
    table = params["embed"]["table"] if cfg.tie_embeddings else \
        params["unembed"]["table"]
    ref = h[:, -1].astype(jnp.float32) @ table.astype(jnp.float32).T

    prompt = {k: (v[:, :S] if k in ("tokens", "labels") else v)
              for k, v in full.items()}
    _, cache, pos = model.prefill(cfg, params, prompt, max_cache_len=S + 4,
                                  dtype=jnp.float32)
    got, _ = model.decode_step(cfg, params, cache, full["tokens"][:, S:S + 1],
                               pos, dtype=jnp.float32)
    scale = float(jnp.max(jnp.abs(ref))) + 1e-9
    assert float(jnp.max(jnp.abs(got - ref))) / scale < 2e-3, arch


def test_ring_cache_matches_prefill_restart(rng):
    """Sliding-window ring cache: decoding T tokens one-by-one equals
    prefilling all T at once (mixtral smoke, window=4 < T)."""
    cfg = _high_capacity(registry.smoke_config("mixtral-8x22b"))
    params = model.init(cfg, rng)
    total = 10
    full = _batch(cfg, rng, seq=total)
    # path A: prefill 0..total-1, then decode token total-1's logits via h
    h, _, _ = model.forward(cfg, params, full, dtype=jnp.float32)
    table = params["unembed"]["table"]
    ref = h[:, -1].astype(jnp.float32) @ table.astype(jnp.float32).T
    # path B: prefill 4 tokens, decode the remaining 6 step by step
    prompt = {"tokens": full["tokens"][:, :4]}
    logits, cache, pos = model.prefill(cfg, params, prompt,
                                       max_cache_len=total, dtype=jnp.float32)
    for t in range(4, total):
        logits, cache = model.decode_step(cfg, params, cache,
                                          full["tokens"][:, t:t + 1], pos,
                                          dtype=jnp.float32)
        pos = pos + 1
    scale = float(jnp.max(jnp.abs(ref))) + 1e-9
    assert float(jnp.max(jnp.abs(logits - ref))) / scale < 2e-3


# ---------------------------------------------------------------------------
# mixer oracles
# ---------------------------------------------------------------------------

def test_ssd_chunked_matches_sequential(rng):
    B_, S_, H, P, N = 2, 256, 3, 8, 4
    ks = jax.random.split(rng, 5)
    x = jax.random.normal(ks[0], (B_, S_, H, P))
    bm = jax.random.normal(ks[1], (B_, S_, N))
    cm = jax.random.normal(ks[2], (B_, S_, N))
    dt = jax.nn.softplus(jax.random.normal(ks[3], (B_, S_, H)))
    a = -jnp.exp(0.5 * jax.random.normal(ks[4], (H,)))
    h0 = jax.random.normal(rng, (B_, H, P, N))
    y1, hf1 = ssd_chunked(x, bm, cm, dt, a, h0=h0, chunk=64)
    y2, hf2 = mamba2_ref_scan(x, bm, cm, dt, a, h0=h0)
    np.testing.assert_allclose(y1, y2, atol=5e-4)
    np.testing.assert_allclose(hf1, hf2, atol=5e-4)


def test_wkv_chunked_matches_step_loop(rng):
    B_, S_, H, D = 2, 48, 2, 8
    ks = jax.random.split(rng, 5)
    r = jax.random.normal(ks[0], (B_, S_, H, D))
    k = jax.random.normal(ks[1], (B_, S_, H, D))
    v = jax.random.normal(ks[2], (B_, S_, H, D))
    logw = -jnp.exp(jax.random.normal(ks[3], (B_, S_, H, D)))
    u = jax.random.normal(ks[4], (H, D))
    y1, s1 = wkv_scan(r, k, v, logw, u, chunk=16)
    st = jnp.zeros((B_, H, D, D))
    ys = []
    for t in range(S_):
        yt, st = wkv_step(st, r[:, t], k[:, t], v[:, t], logw[:, t], u)
        ys.append(yt)
    np.testing.assert_allclose(y1, jnp.stack(ys, 1), atol=1e-5)
    np.testing.assert_allclose(s1, st, atol=1e-5)


def test_moe_matches_dense_oracle(rng):
    spec = MoESpec(n_experts=4, top_k=2, d_ff=32, capacity_factor=8.0)
    params = init_params(moe_specs(16, spec), rng)
    x = jax.random.normal(jax.random.fold_in(rng, 1), (3, 20, 16))
    y, aux = moe_ffn(params, x, spec)
    logits = jnp.einsum("bsd,de->bse", x, params["router"])
    gw, gi = jax.lax.top_k(jax.nn.softmax(logits, -1), 2)
    gw = gw / jnp.sum(gw, -1, keepdims=True)
    ys = jnp.stack([(jax.nn.silu(x @ params["w_gate"][e]) *
                     (x @ params["w_up"][e])) @ params["w_down"][e]
                    for e in range(4)], axis=2)
    oracle = sum(gw[..., k][..., None] * jnp.take_along_axis(
        ys, gi[..., k][..., None, None], axis=2)[..., 0, :] for k in range(2))
    np.testing.assert_allclose(y, oracle, atol=1e-4)
    assert float(aux) > 0.0


def test_moe_capacity_drops_tokens(rng):
    """With tiny capacity some tokens must be dropped (zero contribution)."""
    spec = MoESpec(n_experts=2, top_k=1, d_ff=16, capacity_factor=0.05)
    params = init_params(moe_specs(8, spec), rng)
    x = jax.random.normal(jax.random.fold_in(rng, 2), (1, 64, 8))
    y, _ = moe_ffn(params, x, spec)
    norms = jnp.linalg.norm(y[0], axis=-1)
    assert int(jnp.sum(norms < 1e-7)) > 0, "expected dropped tokens"


def test_train_step_reduces_loss(rng):
    from repro.launch.steps import make_train_step
    from repro.optim import AdamWConfig, adamw_init
    from repro.data import synthetic_lm_batch
    cfg = registry.smoke_config("phi3-mini-3.8b")
    params = model.init(cfg, rng)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(
        cfg, AdamWConfig(lr=5e-3, warmup_steps=5, total_steps=60),
        dtype=jnp.float32))
    losses = []
    for i in range(60):
        batch = synthetic_lm_batch(0, i, batch=8, seq=64, vocab=cfg.vocab_size)
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses[:3] + losses[-3:]


def test_microbatched_train_step_matches_full(rng):
    """Gradient accumulation must give the same update as the full batch."""
    from repro.launch.steps import make_train_step
    from repro.optim import AdamWConfig, adamw_init
    from repro.data import synthetic_lm_batch
    cfg = registry.smoke_config("qwen3-14b")
    params = model.init(cfg, rng)
    batch = synthetic_lm_batch(1, 0, batch=4, seq=16, vocab=cfg.vocab_size)
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    p1, _, m1 = make_train_step(cfg, ocfg, dtype=jnp.float32)(
        params, adamw_init(params), batch)
    p2, _, m2 = make_train_step(cfg, ocfg, dtype=jnp.float32,
                                num_microbatches=2)(
        params, adamw_init(params), batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), atol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        # accumulation reorders the f32 gradient sums; the worst observed
        # leaf deviation is ~3e-5, which is order noise, not a wrong update
        np.testing.assert_allclose(a, b, atol=5e-5)


def test_wlsh_attention_matches_kernel_oracle(rng):
    """BEYOND-PAPER: the paper's estimator as sub-quadratic kernel attention
    converges to explicit kernel attention under the analytic WLSH kernel."""
    from repro.core import GammaPDF, WLSHKernelSpec, get_bucket_fn, \
        make_wlsh_kernel
    from repro.models.wlsh_attention import (kernel_attention_oracle,
                                             sample_wlsh_attn, wlsh_attention)
    B_, S_, H, D, Dv = 2, 32, 2, 16, 8
    q = jax.random.normal(rng, (B_, S_, H, D)) * 0.5
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B_, S_, H, D)) * 0.5
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B_, S_, H, Dv))
    f = get_bucket_fn("rect")
    params = sample_wlsh_attn(jax.random.fold_in(rng, 3), m=3000, d_head=D,
                              d_hash=2, lengthscale=2.0)
    out = wlsh_attention(q, k, v, params, f, table_size=512)
    kern = make_wlsh_kernel(WLSHKernelSpec(bucket=f, pdf=GammaPDF(2.0, 1.0),
                                           lengthscale=2.0))
    oracle = kernel_attention_oracle(q, k, v, kern.k1d, params)
    rel = float(jnp.max(jnp.abs(out - oracle))) / \
        float(jnp.max(jnp.abs(oracle)))
    assert rel < 0.05, rel
