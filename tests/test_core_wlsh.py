"""Paper-core behaviour: bucket functions, WLSH estimator unbiasedness,
matvec data structures, spectral properties (Claims 7/10, Def. 8)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# property tests skip individually when hypothesis is absent; the rest of the
# module (bucket fns, analytic kernels, PSD/spectral checks) always runs
from _hypothesis_compat import given, settings, st

from repro.core import (GammaPDF, WLSHKernelSpec, featurize, get_bucket_fn,
                        laplace_kernel, make_wlsh_kernel, sample_lsh_params)
from repro.core.bucket_fns import BUCKET_FNS
from repro.core.wlsh import (build_exact_index, build_table_index,
                             exact_kernel_matrix, exact_matvec,
                             table_kernel_matrix, table_matvec)


# ---------------------------------------------------------------------------
# bucket-shaping functions f (Def. 6 preconditions)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(BUCKET_FNS))
def test_bucket_fn_l2_normalized(name):
    f = get_bucket_fn(name)
    xs = np.linspace(-0.5, 0.5, 200001)
    val = np.trapezoid(np.asarray(f(jnp.asarray(xs))) ** 2, xs)
    assert abs(val - 1.0) < 1e-3, f"||{name}||_2^2 = {val}"


@pytest.mark.parametrize("name", sorted(BUCKET_FNS))
def test_bucket_fn_even_and_supported(name):
    f = get_bucket_fn(name)
    xs = jnp.linspace(-0.49, 0.49, 101)
    np.testing.assert_allclose(f(xs), f(-xs), atol=1e-6)
    assert float(jnp.max(jnp.abs(f(jnp.asarray([0.51, -0.7, 3.0]))))) == 0.0


@given(st.floats(-2.0, 2.0))
@settings(max_examples=30, deadline=None)
def test_bucket_fn_bounded_by_f_inf(x):
    for name, f in BUCKET_FNS.items():
        assert float(f(jnp.asarray(x))) <= f.f_inf + 1e-6


def test_smooth_fn_has_continuous_derivative():
    f = get_bucket_fn("smooth")
    xs = jnp.linspace(-0.5, 0.5, 20001)
    g = jnp.gradient(f(xs), xs)
    # derivative of (rect*rect_1/4*rect_1/4)(2x) is continuous -> no jumps
    jumps = jnp.max(jnp.abs(jnp.diff(g)))
    assert float(jumps) < 0.05 * float(jnp.max(jnp.abs(g)))


# ---------------------------------------------------------------------------
# analytic kernel (Def. 8): rect + Gamma(2,1) == Laplace exactly
# ---------------------------------------------------------------------------

def test_analytic_wlsh_kernel_matches_laplace(rng):
    spec = WLSHKernelSpec(bucket=get_bucket_fn("rect"), pdf=GammaPDF(2.0, 1.0))
    kern = make_wlsh_kernel(spec)
    x = jax.random.uniform(rng, (64, 3)) * 3.0
    np.testing.assert_allclose(kern(x, x), laplace_kernel(x, x), atol=2e-4)


@pytest.mark.parametrize("name", sorted(BUCKET_FNS))
def test_analytic_kernel_is_valid(name, rng):
    kern = make_wlsh_kernel(WLSHKernelSpec(bucket=get_bucket_fn(name),
                                           pdf=GammaPDF(7.0, 1.0)))
    x = jax.random.uniform(rng, (48, 2)) * 2.0
    k = np.asarray(kern(x, x))
    np.testing.assert_allclose(np.diag(k), 1.0, atol=1e-4)   # k(0) = 1
    np.testing.assert_allclose(k, k.T, atol=1e-6)
    evs = np.linalg.eigvalsh(k)
    assert evs.min() > -1e-3, "analytic WLSH kernel must be PSD"


# ---------------------------------------------------------------------------
# estimator unbiasedness (Claim 22) — statistical, all bucket fns
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("name,pdf", [("rect", GammaPDF(2.0, 1.0)),
                                      ("tent", GammaPDF(2.0, 1.0)),
                                      ("smooth", GammaPDF(7.0, 1.0))])
def test_wlsh_estimator_unbiased(name, pdf, rng):
    f = get_bucket_fn(name)
    n, d, m = 80, 2, 6000
    x = jax.random.uniform(rng, (n, d)) * 2.0
    params = sample_lsh_params(jax.random.fold_in(rng, 1), m, d, pdf)
    k_est = exact_kernel_matrix(featurize(params, f, x))
    kern = make_wlsh_kernel(WLSHKernelSpec(bucket=f, pdf=pdf))
    err = float(jnp.max(jnp.abs(k_est - kern(x, x))))
    # MC error ~ f_inf^(2d)/sqrt(m): generous 5-sigma-ish bound
    assert err < 5.0 * (f.f_inf ** (2 * d)) / np.sqrt(m), err


# ---------------------------------------------------------------------------
# matvec data structures == explicit matrices (the O(n) structure of §4)
# ---------------------------------------------------------------------------

def _check_exact_matvec(n, d, m):
    key = jax.random.PRNGKey(n * 100 + d * 10 + m)
    x = jax.random.uniform(key, (n, d)) * 2.0
    params = sample_lsh_params(jax.random.fold_in(key, 1), m, d,
                               GammaPDF(2.0, 1.0))
    feats = featurize(params, get_bucket_fn("rect"), x)
    beta = jax.random.normal(jax.random.fold_in(key, 2), (n,))
    dense = exact_kernel_matrix(feats) @ beta
    mv = exact_matvec(build_exact_index(feats), beta)
    np.testing.assert_allclose(mv, dense, atol=1e-4)


@given(st.integers(16, 100), st.integers(1, 4), st.integers(1, 24))
@settings(max_examples=12, deadline=None)
def test_exact_matvec_matches_dense(n, d, m):
    _check_exact_matvec(n, d, m)


@pytest.mark.parametrize("n,d,m", [(16, 1, 1), (33, 2, 5), (100, 4, 24)])
def test_exact_matvec_matches_dense_examples(n, d, m):
    """Fixed examples of the property above — run even without hypothesis."""
    _check_exact_matvec(n, d, m)


def _check_table_matvec(n, d):
    key = jax.random.PRNGKey(n * 7 + d)
    x = jax.random.uniform(key, (n, d)) * 2.0
    params = sample_lsh_params(jax.random.fold_in(key, 1), 8, d,
                               GammaPDF(2.0, 1.0))
    feats = featurize(params, get_bucket_fn("tent"), x)
    idx = build_table_index(feats, 256)
    beta = jax.random.normal(jax.random.fold_in(key, 2), (n,))
    dense = table_kernel_matrix(idx) @ beta
    np.testing.assert_allclose(table_matvec(idx, beta), dense, atol=1e-4)


@given(st.integers(16, 80), st.integers(1, 3))
@settings(max_examples=10, deadline=None)
def test_table_matvec_matches_table_matrix(n, d):
    _check_table_matvec(n, d)


@pytest.mark.parametrize("n,d", [(16, 1), (45, 2), (80, 3)])
def test_table_matvec_matches_table_matrix_examples(n, d):
    """Fixed examples of the property above — run even without hypothesis."""
    _check_table_matvec(n, d)


def test_table_kernel_matrix_is_psd(rng):
    """CountSketch mode stays PSD (K~ = (S Phi)(S Phi)^T) — the property the
    OSE argument needs after the TPU adaptation (DESIGN.md §3)."""
    x = jax.random.uniform(rng, (60, 2)) * 2.0
    params = sample_lsh_params(jax.random.fold_in(rng, 3), 12, 2,
                               GammaPDF(2.0, 1.0))
    feats = featurize(params, get_bucket_fn("rect"), x)
    k = np.asarray(table_kernel_matrix(build_table_index(feats, 128)))
    assert np.linalg.eigvalsh(k).min() > -1e-4


def test_claim10_operator_norm_bound(rng):
    """Claim 10: 0 <= K~^s <= n ||f^{x}d||_inf^2 I, per instance."""
    n, d = 40, 2
    x = jax.random.uniform(rng, (n, d)) * 2.0
    for name in BUCKET_FNS:
        f = get_bucket_fn(name)
        params = sample_lsh_params(jax.random.fold_in(rng, 5), 1, d,
                                   GammaPDF(2.0, 1.0))
        k = np.asarray(exact_kernel_matrix(featurize(params, f, x)))
        evs = np.linalg.eigvalsh(k)
        assert evs.min() > -1e-5
        assert evs.max() <= n * f.f_inf ** (2 * d) + 1e-4


def test_ose_concentration_improves_with_m(rng):
    """Spectral error of (K~+lam I) vs (K+lam I) shrinks with m (Thm 11)."""
    n, d, lam = 96, 2, 1.0
    x = jax.random.uniform(rng, (n, d)) * 2.0
    kern = make_wlsh_kernel(WLSHKernelSpec(bucket=get_bucket_fn("rect")))
    k_true = np.asarray(kern(x, x))
    errs = []
    for m in (8, 64, 512):
        params = sample_lsh_params(jax.random.fold_in(rng, m), m, d,
                                   GammaPDF(2.0, 1.0))
        k_est = np.asarray(exact_kernel_matrix(
            featurize(params, get_bucket_fn("rect"), x)))
        a = np.linalg.cholesky(k_true + lam * np.eye(n))
        ainv = np.linalg.inv(a)
        mat = ainv @ (k_est + lam * np.eye(n)) @ ainv.T - np.eye(n)
        errs.append(np.linalg.norm(mat, 2))
    assert errs[2] < errs[0], errs
    assert errs[2] < 0.5, errs
