"""Preconditioned CG: Jacobi + Nyström (core/precond.py, core/krr.py).

Pins the PR's acceptance criterion — Nyström-PCG reaches tol=1e-6 in at
most 1/3 the iterations of unpreconditioned CG on an ill-conditioned
synthetic WLSH-KRR system — plus the algebra each preconditioner is built
on: the Jacobi diagonal is the exact CountSketch operator diagonal, the
Nyström apply inverts P = A Aᵀ + λI exactly, and both leave the solution
unchanged (a preconditioner reshapes the path, not the fixed point).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (GammaPDF, WLSHKernelSpec, get_bucket_fn,
                        make_operator, make_preconditioner, pcg_solve,
                        sample_lsh_params, table_diag, wlsh_krr_fit)
from repro.core.precond import nystrom_factors
from repro.core.wlsh import table_kernel_matrix


def _ill_conditioned_system(key, n=1024, d=3, m=32, lengthscale=4.0):
    """Small-lam WLSH-KRR on a smooth (long-lengthscale) kernel: the gram's
    spectral tail is tiny next to its head, so (K~ + lam I) has condition
    number ~ lam⁻¹ — the regime where preconditioning decides solve time."""
    x = jax.random.uniform(key, (n, d)) * 2.0
    lsh = sample_lsh_params(jax.random.fold_in(key, 1), m, d,
                            GammaPDF(2.0, 1.0), lengthscale=lengthscale)
    op = make_operator(lsh, get_bucket_fn("rect"), 4 * n,
                       backend="reference")
    idx = op.build_index(op.featurize(x))
    y = jax.random.normal(jax.random.fold_in(key, 2), (n,))
    return op, idx, y


def test_jacobi_diag_is_exact_operator_diagonal():
    key = jax.random.PRNGKey(0)
    n, d, m = 150, 2, 8
    x = jax.random.uniform(key, (n, d)) * 2.0
    lsh = sample_lsh_params(jax.random.fold_in(key, 1), m, d,
                            GammaPDF(2.0, 1.0))
    op = make_operator(lsh, get_bucket_fn("rect"), 512, backend="reference")
    idx = op.build_index(op.featurize(x), blocked=False)
    want = jnp.diagonal(table_kernel_matrix(idx))
    np.testing.assert_allclose(table_diag(idx.coeff), want, atol=1e-6)


def test_nystrom_apply_inverts_its_own_preconditioner():
    """apply(r) must invert P = A Aᵀ + λI — the Woodbury identity through
    the two cached triangular solves.  Checked at moderate λ where the
    round trip is well-posed in f32 (at tiny λ the check itself would be
    amplified by cond(P); that regime is covered by the iteration-count
    tests below)."""
    key = jax.random.PRNGKey(5)
    op, idx, y = _ill_conditioned_system(key, n=256, m=16, lengthscale=2.0)
    mv = lambda v: op.matvec(idx, v)
    lam = 1.0
    diag = table_diag(idx.coeff)
    fac = nystrom_factors(mv, diag, lam, rank=32)
    pre = make_preconditioner("nystrom", matvec=mv, diag=diag, lam=lam,
                              rank=32)
    r = jax.random.normal(jax.random.fold_in(key, 3), (256,))
    z = pre.apply(r)
    back = fac.a @ (fac.a.T @ z) + lam * z
    np.testing.assert_allclose(back, r, rtol=1e-3, atol=1e-3)
    # block apply == per-column apply
    rk = jax.random.normal(jax.random.fold_in(key, 4), (256, 3))
    zk = pre.apply(rk)
    for j in range(3):
        np.testing.assert_allclose(zk[:, j], pre.apply(rk[:, j]), atol=1e-6)


def test_preconditioner_preserves_solution():
    """Same fixed point from none/jacobi/nystrom at tight tolerance."""
    key = jax.random.PRNGKey(2)
    op, idx, y = _ill_conditioned_system(key, n=512, m=32)
    mv = lambda v: op.matvec(idx, v)
    lam = 1e-2
    diag = table_diag(idx.coeff)
    sols = {}
    for name in ("none", "jacobi", "nystrom"):
        pre = make_preconditioner(name, matvec=mv, diag=diag, lam=lam,
                                  rank=64)
        sols[name] = pcg_solve(mv, y, lam, precond=pre, tol=1e-8,
                               maxiter=3000)
    scale = float(jnp.max(jnp.abs(sols["none"].x)))
    for name in ("jacobi", "nystrom"):
        np.testing.assert_allclose(sols[name].x, sols["none"].x,
                                   atol=2e-3 * scale)


def test_nystrom_pcg_cuts_iterations_3x():
    """Acceptance criterion: Nyström-PCG reaches tol=1e-6 in <= 1/3 the
    iterations of unpreconditioned CG on the ill-conditioned synthetic
    benchmark (same system, same tolerance, same maxiter budget)."""
    key = jax.random.PRNGKey(0)
    op, idx, y = _ill_conditioned_system(key)
    mv = lambda v: op.matvec(idx, v)
    lam = 1e-3
    tol = 1e-6
    plain = pcg_solve(mv, y, lam, tol=tol, maxiter=1500)
    diag = table_diag(idx.coeff)
    pre = make_preconditioner("nystrom", matvec=mv, diag=diag, lam=lam,
                              rank=128)
    nys = pcg_solve(mv, y, lam, precond=pre, tol=tol, maxiter=1500)
    bnorm = float(jnp.linalg.norm(y))
    assert float(nys.resnorm[0]) <= tol * bnorm * 1.01, "nystrom unconverged"
    it_plain, it_nys = int(plain.iters), int(nys.iters)
    assert it_nys * 3 <= it_plain, (it_plain, it_nys)


def test_wlsh_krr_fit_precond_reduces_iters_same_answer():
    """End-to-end: ``precond='nystrom'`` through wlsh_krr_fit converges in
    fewer iterations to the same beta on a small-lam fit."""
    key = jax.random.PRNGKey(6)
    n, d = 400, 2
    x = jax.random.uniform(key, (n, d)) * 2.0
    y = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    spec = WLSHKernelSpec(bucket=get_bucket_fn("rect"), lengthscale=4.0)
    fit = lambda p: wlsh_krr_fit(jax.random.fold_in(key, 2), x, y, spec,
                                 m=32, lam=1e-2, tol=1e-6, maxiter=1000,
                                 backend="reference", precond=p,
                                 precond_rank=96)
    plain, nys = fit("none"), fit("nystrom")
    assert int(nys.cg_iters) < int(plain.cg_iters)
    scale = float(jnp.max(jnp.abs(plain.beta)))
    np.testing.assert_allclose(nys.beta, plain.beta, atol=5e-3 * scale)
    assert nys.precond == "nystrom"


def test_make_preconditioner_validation():
    with pytest.raises(ValueError):
        make_preconditioner("jacobi")
    with pytest.raises(ValueError):
        make_preconditioner("nystrom", diag=jnp.ones((4,)))
    with pytest.raises(ValueError):
        make_preconditioner("clueless")
    assert make_preconditioner("none").name == "none"


def test_distributed_jacobi_and_nystrom_guard():
    """cfg.precond='jacobi' runs inside shard_map and matches the 'none'
    solution; 'nystrom' on sharded data axes is rejected up front."""
    from repro.compat import make_mesh
    from repro.core.distributed import KRRStepConfig, make_krr_step
    mesh = make_mesh((1, 1, 1), ("pod", "data", "model"))
    n, d, m, table_size = 192, 3, 4, 512
    key = jax.random.PRNGKey(6)
    x = jax.random.uniform(key, (n, d)) * 2.0
    y = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    lsh = sample_lsh_params(jax.random.fold_in(key, 2), m, d,
                            GammaPDF(2.0, 1.0))
    f = get_bucket_fn("rect")
    base = KRRStepConfig(m=m, table_size=table_size, lam=0.5, cg_iters=40,
                         data_axes=("pod", "data"), model_axis="model",
                         backend="reference")
    b0, r0, _ = jax.jit(make_krr_step(mesh, base, f))(x, y, lsh)
    bj, rj, _ = jax.jit(make_krr_step(
        mesh, base._replace(precond="jacobi"), f))(x, y, lsh)
    bn, rn, _ = jax.jit(make_krr_step(
        mesh, base._replace(precond="nystrom", precond_rank=32), f))(
            x, y, lsh)
    scale = float(jnp.max(jnp.abs(b0)))
    np.testing.assert_allclose(bj, b0, atol=1e-3 * scale)
    np.testing.assert_allclose(bn, b0, atol=1e-3 * scale)

    # sharded data axes: nystrom must be rejected at build time.  The mesh
    # is 1x1x1, so fake a sharded count via data axes that multiply to >1
    # on a wider mesh shape when available; otherwise just check the
    # validation branch directly
    import repro.core.distributed as dist
    cfg_bad = base._replace(precond="nystrom")
    real_count = dist._data_shard_count
    try:
        dist._data_shard_count = lambda mesh_, cfg_: 2
        with pytest.raises(ValueError):
            make_krr_step(mesh, cfg_bad, f)
    finally:
        dist._data_shard_count = real_count
