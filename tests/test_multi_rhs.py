"""Multi-RHS matvec + block-PCG (tentpole of the solver PR).

Pins the acceptance criteria: k=1 bitwise-matches the 1-D path on both
backends, multi-RHS parity with per-column single solves (including k that
divides no tile size and odd n), non-contiguous converged-column deflation
in ``pcg_solve``, batched KRR fit/predict, and the wall-clock amortization
claim (k=8 under 3x a single matvec on the reference backend — the block
rides one index walk, it is not a hidden loop).
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (GammaPDF, WLSHKernelSpec, cg_solve, get_bucket_fn,
                        make_operator, pcg_solve, sample_lsh_params,
                        wlsh_krr_fit, wlsh_krr_predict)


def _setup(key, n, d, m, table_size, backend):
    x = jax.random.uniform(key, (n, d)) * 2.0
    lsh = sample_lsh_params(jax.random.fold_in(key, 1), m, d,
                            GammaPDF(2.0, 1.0))
    op = make_operator(lsh, get_bucket_fn("rect"), table_size,
                       backend=backend)
    idx = op.build_index(op.featurize(x))
    return op, idx


# k=1 / k=3 / k=5 never divide bn=128 or bt=512; n=300 exercises padding
@pytest.mark.parametrize("backend", ["reference", "pallas"])
@pytest.mark.parametrize("k", [1, 3, 5])
def test_multi_rhs_matvec_matches_per_column(backend, k):
    key = jax.random.PRNGKey(10 + k)
    n, d, m, table_size = 300, 3, 4, 1024
    op, idx = _setup(key, n, d, m, table_size, backend)
    betas = jax.random.normal(jax.random.fold_in(key, 2), (n, k))
    got = op.matvec(idx, betas)
    assert got.shape == (n, k)
    for j in range(k):
        np.testing.assert_allclose(got[:, j], op.matvec(idx, betas[:, j]),
                                   atol=1e-5)
    # sum mode (the distributed model-axis contribution) must agree too
    got_sum = op.matvec(idx, betas, average=False)
    np.testing.assert_allclose(got_sum[:, 0],
                               op.matvec(idx, betas[:, 0], average=False),
                               atol=1e-4)


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_multi_rhs_k1_bitwise_matches_1d(backend):
    """(n, 1) must be the 1-D path's result bit for bit: same scatter order,
    same tile products — the k axis adds no reassociation anywhere."""
    key = jax.random.PRNGKey(3)
    n, d, m, table_size = 257, 3, 3, 2048
    op, idx = _setup(key, n, d, m, table_size, backend)
    beta = jax.random.normal(jax.random.fold_in(key, 2), (n,))
    np.testing.assert_array_equal(
        np.asarray(op.matvec(idx, beta[:, None])[:, 0]),
        np.asarray(op.matvec(idx, beta)))
    # split loads/readout too (the psum-able distributed path)
    t1 = op.loads(idx, beta)
    tk = op.loads(idx, beta[:, None])
    np.testing.assert_array_equal(np.asarray(tk[..., 0]), np.asarray(t1))
    np.testing.assert_array_equal(
        np.asarray(op.readout(idx, tk)[:, 0]),
        np.asarray(op.readout(idx, t1)))


def test_pcg_block_matches_single_solves():
    """Each column of a block solve follows its own single-RHS trajectory
    (deflation freezes it at ITS convergence point, not the block's)."""
    key = jax.random.PRNGKey(0)
    n = 96
    a = jax.random.normal(key, (n, n))
    psd = a @ a.T / n
    b = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    mv = lambda v: psd @ v
    # columns of wildly different difficulty: zero (0 iters), a multiple of
    # b (same trajectory as b), and a tiny-scale copy (same iters — the
    # relative threshold scales with the column)
    blk = jnp.stack([b, jnp.zeros_like(b), -2.5 * b, 1e-3 * b], axis=1)
    res = pcg_solve(mv, blk, 0.3, tol=1e-8, maxiter=400)
    singles = [cg_solve(mv, blk[:, j], 0.3, tol=1e-8, maxiter=400)
               for j in range(4)]
    for j, s in enumerate(singles):
        np.testing.assert_allclose(res.x[:, j], s.x, rtol=1e-4, atol=1e-6)
    assert int(res.col_iters[1]) == 0          # zero column: deflated at init
    # the dense oracle matmul reassociates between (n, 1) and (n, 4)
    # operands, so iteration counts may differ by a rounding step
    assert abs(int(res.col_iters[0]) - int(singles[0].iters)) <= 1
    assert int(res.iters) == int(jnp.max(res.col_iters))


def test_pcg_noncontiguous_deflation():
    """A column that converges early (aligned with the dominant eigenvector)
    sits BETWEEN two slow columns; its deflation must not perturb them."""
    key = jax.random.PRNGKey(7)
    n = 80
    a = jax.random.normal(key, (n, n))
    psd = a @ a.T / n + jnp.eye(n)
    evals, evecs = jnp.linalg.eigh(psd)
    easy = evecs[:, -1]                        # one Krylov step suffices
    hard1 = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    hard2 = jax.random.normal(jax.random.fold_in(key, 2), (n,))
    blk = jnp.stack([hard1, easy, hard2], axis=1)
    mv = lambda v: psd @ v
    res = pcg_solve(mv, blk, 0.1, tol=1e-7, maxiter=300)
    iters = [int(res.col_iters[j]) for j in range(3)]
    assert iters[1] < iters[0] and iters[1] < iters[2], iters
    direct = jnp.linalg.solve(psd + 0.1 * jnp.eye(n), blk)
    np.testing.assert_allclose(res.x, direct, atol=5e-3)
    assert bool(jnp.all(res.resnorm <= 1e-7 * jnp.linalg.norm(blk, axis=0)
                        + 1e-10))


def test_wlsh_krr_fit_multi_rhs():
    """Batched fit: (n, k) targets -> (n, k) beta, (m, B, k) tables, and
    predictions that match k independent single fits column-for-column."""
    key = jax.random.PRNGKey(4)
    n, d, k = 220, 2, 3
    x = jax.random.uniform(key, (n, d)) * 2.0
    ys = jax.random.normal(jax.random.fold_in(key, 1), (n, k))
    xte = jax.random.uniform(jax.random.fold_in(key, 2), (40, d)) * 2.0
    spec = WLSHKernelSpec(bucket=get_bucket_fn("rect"))
    fit = lambda target: wlsh_krr_fit(jax.random.fold_in(key, 3), x, target,
                                      spec, m=32, lam=0.5, tol=1e-7,
                                      backend="reference")
    mb = fit(ys)
    assert mb.beta.shape == (n, k) and mb.tables.shape[-1] == k
    assert mb.cg_col_iters.shape == (k,)
    pb = wlsh_krr_predict(mb, xte, batch_size=16)
    assert pb.shape == (40, k)
    for j in range(k):
        mj = fit(ys[:, j])
        np.testing.assert_allclose(mb.beta[:, j], mj.beta, atol=1e-5)
        np.testing.assert_allclose(pb[:, j],
                                   wlsh_krr_predict(mj, xte, batch_size=16),
                                   atol=1e-5)


def test_multi_rhs_amortization_under_3x():
    """Acceptance criterion: a k=8 matvec on the reference backend costs
    < 3x a single-RHS matvec in wall-clock — the block shares the sorted
    gather and segment-sum index walk, so it cannot be a hidden k-loop."""
    key = jax.random.PRNGKey(1)
    n, d, m, table_size = 8192, 8, 16, 32768
    op, idx = _setup(key, n, d, m, table_size, "reference")
    b1 = jax.random.normal(jax.random.fold_in(key, 2), (n,))
    b8 = jax.random.normal(jax.random.fold_in(key, 3), (n, 8))
    f = jax.jit(lambda b: op.matvec(idx, b))
    f(b1).block_until_ready()
    f(b8).block_until_ready()

    def best_of(b, reps=7):
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            f(b).block_until_ready()
            ts.append(time.perf_counter() - t0)
        return min(ts)

    # measured headroom is ~2x (k=8 runs 1.2-1.6x single), but shared CPU
    # containers have multi-second noise bursts; re-measure before failing
    for attempt in range(3):
        t1, t8 = best_of(b1), best_of(b8)
        if t8 < 3.0 * t1:
            break
    assert t8 < 3.0 * t1, (t1, t8)
