"""Optional-hypothesis shim.

``from _hypothesis_compat import given, settings, st`` behaves exactly like
importing from hypothesis when it is installed.  When it is not, the
decorated property tests skip individually (via pytest.importorskip) while
every other test in the module keeps running — a module-level importorskip
would throw away the whole file's coverage.
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            def skipper():
                pytest.importorskip("hypothesis")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _Strategies:
        """Accepts any strategy construction; values are never drawn."""

        def __getattr__(self, _name):
            def strategy(*_args, **_kwargs):
                return None
            return strategy

    st = _Strategies()
