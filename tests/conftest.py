"""Shared pytest fixtures.  NOTE: no XLA_FLAGS here — tests must see the
default single CPU device (the dry-run sets its own 512-device flag in its
own process; see src/repro/launch/dryrun.py).

Tests marked ``slow`` (multi-device subprocess runs, large statistical
sweeps) are skipped by default so ``python -m pytest -x -q`` stays fast;
pass ``--runslow`` to include them.
"""
import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def pytest_addoption(parser):
    parser.addoption("--runslow", action="store_true", default=False,
                     help="also run tests marked @pytest.mark.slow")


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running test (skipped unless --runslow)")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip_slow = pytest.mark.skip(reason="slow test: pass --runslow to run")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)
