"""Shared pytest fixtures.  NOTE: no XLA_FLAGS here — tests must see the
default single CPU device (the dry-run sets its own 512-device flag in its
own process; see src/repro/launch/dryrun.py)."""
import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test")
