"""End-to-end LM training with fault injection: trains a reduced gemma3 on
the synthetic token stream for a few hundred steps, killing the process state
twice along the way — the run auto-resumes from checkpoints and still
converges.  (This is the end-to-end driver deliverable; on real hardware drop
--smoke and point the mesh at the pod.)

    PYTHONPATH=src python examples/train_lm.py
"""
import subprocess
import sys

CMD = [
    sys.executable, "-m", "repro.launch.train",
    "--arch", "gemma3-1b", "--smoke",
    "--steps", "200", "--batch", "8", "--seq", "128",
    "--lr", "3e-3", "--ckpt-dir", "/tmp/repro_train_lm_example",
    "--ckpt-every", "25", "--fail-at", "60", "130",
]

if __name__ == "__main__":
    print("+", " ".join(CMD))
    proc = subprocess.run(CMD, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    raise SystemExit(proc.returncode)
