"""Batched serving example: prefill + autoregressive decode with ring-buffer
KV caches on the hybrid zamba2 (Mamba2 states + shared windowed attention).

    PYTHONPATH=src python examples/serve_decode.py
"""
import subprocess
import sys

CMD = [
    sys.executable, "-m", "repro.launch.serve",
    "--arch", "zamba2-7b", "--smoke",
    "--batch", "4", "--prompt-len", "24", "--gen", "16",
    "--temperature", "0.8",
]

if __name__ == "__main__":
    print("+", " ".join(CMD))
    proc = subprocess.run(CMD, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    raise SystemExit(proc.returncode)
