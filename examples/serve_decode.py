"""Online serving example: fit -> export -> micro-batched prediction.

    PYTHONPATH=src python examples/serve_decode.py [--requests N]
        [--mesh MxN]

Fits a small WLSH-KRR model, exports it as a serving artifact, hosts it
behind the warm-path ``Predictor`` (padding buckets + bucket-exact cache)
and pushes a synthetic request stream through the ``MicroBatcher`` — the
same submit -> coalesce -> padded-jit -> future path
``python -m repro.launch.krr_serve`` runs at traffic.

``--mesh MxN`` (e.g. ``--mesh 2x2``) exports a sharded piece grid instead
and serves it with ``ShardedPredictor`` on a (model_shards, data_shards)
device mesh — run with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` to try it on fake
CPU devices.  Default is the single-host path, which runs on one device.
"""
import argparse
import tempfile

import numpy as np

from repro.launch.krr_serve import (_fit_and_export, _synthetic_stream,
                                    serve_stream)
from repro.serve import (Predictor, ShardedPredictor, bucket_sizes,
                         parse_mesh_shape)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=500)
    ap.add_argument("--dup-frac", type=float, default=0.4,
                    help="fraction of requests replaying earlier ones "
                         "(the bucket-exact cache's traffic)")
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--mesh", default=None, metavar="MxN",
                    help="serve sharded on a (model x data) device mesh")
    args = ap.parse_args()
    mesh_shape = parse_mesh_shape(args.mesh) if args.mesh else None

    with tempfile.TemporaryDirectory() as tmp:
        art = tmp + "/artifact"
        print(f"[serve] fitting + exporting demo artifact -> {art}")
        model, xtr = _fit_and_export(art, n=1024, d=8, m=64,
                                     mesh_shape=mesh_shape)
        if mesh_shape is not None:
            predictor = ShardedPredictor(mesh_shape=mesh_shape,
                                         cache_entries=4096)
        else:
            predictor = Predictor(cache_entries=4096)
        predictor.load(art)
        n_compiled = predictor.warmup(sizes=bucket_sizes(args.max_batch))
        print(f"[serve] {n_compiled} padding buckets compiled"
              + (f" (mesh {args.mesh})" if mesh_shape else ""))

        stream = _synthetic_stream(xtr.shape[1], args.requests,
                                   args.dup_frac, seed=1)
        stats = serve_stream(predictor, stream,
                             max_batch=args.max_batch, max_wait_us=1000)
        print(f"[serve] {stats['served']} requests in {stats['wall_s']:.2f}s "
              f"-> {stats['qps']:.0f} QPS "
              f"({stats['batches']} batches, mean "
              f"{stats['mean_batch']:.1f} rows)")
        print(f"[serve] latency p50 {stats['p50_us']:.0f}us "
              f"p99 {stats['p99_us']:.0f}us")
        cache = predictor.cache_stats()
        print(f"[serve] cache hit rate {cache['hit_rate']:.2f} "
              f"({cache['hits']} hits / {cache['misses']} misses)")

        # every batched answer must match the predictor's own direct path
        expect = predictor.predict(stream, use_cache=False)
        err = float(np.abs(stats["results"] - np.asarray(expect)).max())
        print(f"[serve] max |batched - direct| = {err:.2e}")
        health = predictor.health()
        print(f"[serve] health ok={health['ok']} "
              f"requests={health['requests']}")


if __name__ == "__main__":
    main()
