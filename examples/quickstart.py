"""Quickstart: approximate kernel ridge regression with WLSH estimators.

    PYTHONPATH=src python examples/quickstart.py [--backend auto|reference|pallas]

Fits a Laplace-kernel GP sample with (a) exact KRR, (b) WLSH approximate KRR
(the paper's method), and compares accuracy and fit time.  ``--backend``
selects the WLSH operator implementation (see src/repro/core/operator.py):
'reference' is the pure-jnp path, 'pallas' the fused TPU kernels, 'auto'
picks per platform.  Prediction streams through fixed-size batches — the
same code path that serves multi-million-point inference.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import (WLSHKernelSpec, exact_krr_fit, exact_krr_predict,
                        get_bucket_fn, laplace_kernel, wlsh_krr_fit,
                        wlsh_krr_predict)
from repro.core.gp import gp_regression_dataset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "reference", "pallas"])
    ap.add_argument("--fused", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="one-pass slot-blocked CG matvec (--no-fused keeps "
                         "the split scatter->gather path reachable for A/B)")
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    n_train, n_test = 1200, 400
    x, y, f_true = gp_regression_dataset(key, laplace_kernel,
                                         n=n_train + n_test, d=4, noise=0.05)
    xtr, ytr = x[:n_train], y[:n_train]
    xte, fte = x[n_train:], f_true[n_train:]
    lam = 0.3

    t0 = time.time()
    beta = exact_krr_fit(laplace_kernel, xtr, ytr, lam)
    pred_exact = exact_krr_predict(laplace_kernel, xtr, beta, xte)
    t_exact = time.time() - t0
    rmse_exact = float(jnp.sqrt(jnp.mean((pred_exact - fte) ** 2)))

    # WLSH: f = rect + p(w) = w e^{-w}  <=>  the Laplace kernel (Def. 8)
    spec = WLSHKernelSpec(bucket=get_bucket_fn("rect"))
    t0 = time.time()
    model = wlsh_krr_fit(jax.random.fold_in(key, 1), xtr, ytr, spec,
                         m=400, lam=lam, backend=args.backend,
                         fused=args.fused)
    # batch_size streams the test set in fixed memory (O(batch * m) peak)
    pred_wlsh = wlsh_krr_predict(model, xte, batch_size=128)
    t_wlsh = time.time() - t0
    rmse_wlsh = float(jnp.sqrt(jnp.mean((pred_wlsh - fte) ** 2)))

    print(f"exact KRR : rmse={rmse_exact:.4f}  fit+predict={t_exact:.2f}s "
          f"(O(n^3) solve)")
    print(f"WLSH KRR  : rmse={rmse_wlsh:.4f}  fit+predict={t_wlsh:.2f}s "
          f"(backend={model.backend}, m=400 instances, O(n m) per CG "
          f"iteration, {int(model.cg_iters)} iters)")
    assert rmse_wlsh < 2.0 * rmse_exact + 0.05, "WLSH should track exact KRR"
    print("OK")


if __name__ == "__main__":
    main()
