"""Quickstart: approximate kernel ridge regression with WLSH estimators.

    PYTHONPATH=src python examples/quickstart.py [--backend auto|reference|pallas]
        [--precond none|jacobi|nystrom] [--num-rhs K]

Fits a Laplace-kernel GP sample with (a) exact KRR, (b) WLSH approximate KRR
(the paper's method), and compares accuracy and fit time.  ``--backend``
selects the WLSH operator implementation (see src/repro/core/operator.py):
'reference' is the pure-jnp path, 'pallas' the fused TPU kernels, 'auto'
picks per platform.  ``--precond`` runs the solve as preconditioned CG
(core/precond.py; 'nystrom' collapses the iteration count on
ill-conditioned, small-lam problems).  ``--num-rhs K`` with K > 1 draws
K - 1 GP posterior samples alongside the mean via pathwise conditioning —
one batched multi-RHS solve instead of K separate fits (core/gp.py).
Prediction streams through fixed-size batches — the same code path that
serves multi-million-point inference.

``--export DIR`` writes the fitted model as a serving artifact
(src/repro/serve/artifact.py) and ``--serve DIR`` loads it back through the
online Predictor and round-trips a request sample — the export -> serve hop
that `python -m repro.launch.krr_serve --artifact DIR` then runs at traffic.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import (WLSHKernelSpec, exact_krr_fit, exact_krr_predict,
                        get_bucket_fn, laplace_kernel, wlsh_krr_fit,
                        wlsh_krr_predict)
from repro.core.gp import gp_posterior_rhs, gp_regression_dataset
from repro.core.precond import DEFAULT_NYSTROM_RANK


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "reference", "pallas"])
    ap.add_argument("--fused", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="one-pass slot-blocked CG matvec (--no-fused keeps "
                         "the split scatter->gather path reachable for A/B)")
    ap.add_argument("--precond", default="none",
                    choices=["none", "jacobi", "nystrom"],
                    help="PCG preconditioner for the WLSH solve")
    ap.add_argument("--precond-rank", type=int, default=DEFAULT_NYSTROM_RANK)
    ap.add_argument("--num-rhs", type=int, default=1,
                    help="K > 1 adds K-1 pathwise GP posterior samples to "
                         "the solve as extra RHS columns (one batched fit)")
    ap.add_argument("--solve-checkpoint-dir", default=None, metavar="DIR",
                    help="persist the PCG SolveState under DIR during the "
                         "fit; re-running after a preemption resumes the "
                         "solve from the last saved chunk")
    ap.add_argument("--solve-checkpoint-every", type=int, default=0,
                    help="iterations between SolveState saves (0 with a "
                         "dir set = maxiter//10)")
    ap.add_argument("--export", default=None, metavar="DIR",
                    help="write the fitted WLSH model as a serving artifact")
    ap.add_argument("--serve", default=None, metavar="DIR",
                    help="load the artifact back through the online "
                         "Predictor and verify the round-trip (defaults to "
                         "the --export dir when both are wanted)")
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    n_train, n_test = 1200, 400
    noise = 0.05
    x, y, f_true = gp_regression_dataset(key, laplace_kernel,
                                         n=n_train + n_test, d=4, noise=noise)
    xtr, ytr = x[:n_train], y[:n_train]
    xte, fte = x[n_train:], f_true[n_train:]
    lam = 0.3

    t0 = time.time()
    beta = exact_krr_fit(laplace_kernel, xtr, ytr, lam)
    pred_exact = exact_krr_predict(laplace_kernel, xtr, beta, xte)
    t_exact = time.time() - t0
    rmse_exact = float(jnp.sqrt(jnp.mean((pred_exact - fte) ** 2)))

    # WLSH: f = rect + p(w) = w e^{-w}  <=>  the Laplace kernel (Def. 8)
    spec = WLSHKernelSpec(bucket=get_bucket_fn("rect"))
    n_samples = max(args.num_rhs - 1, 0)
    target = ytr
    f_prior = None
    if n_samples:
        # pathwise conditioning: the sample RHS columns solve against the
        # SAME operator as the mean, so the whole batch is one block solve.
        # Matheron's rule needs eps ~ N(0, sigma^2) with sigma^2 = the
        # ridge actually solved against — KRR with lam IS GP regression
        # with assumed noise variance lam, so the samples draw from that
        # model's posterior (not the data-generating noise=0.05)
        target, f_prior = gp_posterior_rhs(
            jax.random.fold_in(key, 2), x, ytr, laplace_kernel,
            n_train=n_train, n_samples=n_samples, noise=float(lam) ** 0.5)
    t0 = time.time()
    model = wlsh_krr_fit(jax.random.fold_in(key, 1), xtr, target, spec,
                         m=400, lam=lam, backend=args.backend,
                         fused=args.fused, precond=args.precond,
                         precond_rank=args.precond_rank,
                         solve_checkpoint_dir=args.solve_checkpoint_dir,
                         solve_checkpoint_every=args.solve_checkpoint_every)
    # batch_size streams the test set in fixed memory (O(batch * m) peak)
    pred_wlsh = wlsh_krr_predict(model, xte, batch_size=128)
    t_wlsh = time.time() - t0
    if n_samples:
        posterior_samples = f_prior[n_train:] + pred_wlsh[:, 1:]
        pred_wlsh = pred_wlsh[:, 0]
        spread = float(jnp.mean(jnp.std(posterior_samples, axis=1)))
    rmse_wlsh = float(jnp.sqrt(jnp.mean((pred_wlsh - fte) ** 2)))

    cg_iters = int(jnp.max(model.cg_col_iters))
    print(f"exact KRR : rmse={rmse_exact:.4f}  fit+predict={t_exact:.2f}s "
          f"(O(n^3) solve)")
    print(f"WLSH KRR  : rmse={rmse_wlsh:.4f}  fit+predict={t_wlsh:.2f}s "
          f"(backend={model.backend}, m=400 instances, O(n m) per CG "
          f"iteration, {cg_iters} iters, precond={model.precond})")
    if n_samples:
        print(f"GP posterior: {n_samples} pathwise samples in the same "
              f"solve; mean test-point std {spread:.4f}")
    assert rmse_wlsh < 2.0 * rmse_exact + 0.05, "WLSH should track exact KRR"

    # ---- serving round-trip: export the fitted model, load it back through
    # the online predictor, and check artifact == in-memory predictions ----
    if args.export:
        from repro.serve import export_artifact
        aid = export_artifact(args.export, model)
        print(f"serving    : exported artifact {aid!r} -> {args.export}")
    serve_dir = args.serve or args.export
    if args.serve is not None or args.export:
        import numpy as np
        from repro.serve import Predictor
        predictor = Predictor(backend=args.backend if args.backend != "auto"
                              else None, cache_entries=4096)
        aid = predictor.load(serve_dir)
        predictor.warmup(sizes=(1, 256))
        # a power-of-two query count keeps the predictor's padded shape equal
        # to the direct path's shape — shape-retiling ulps would otherwise
        # blur the bitwise round-trip signal
        xq = np.asarray(xte[:256], np.float32)
        served = predictor.predict(xq)
        bitwise = False
        compared = bool(args.export) and serve_dir == args.export
        if compared:
            # the artifact IS this run's fit: reference round-trip is
            # bitwise (same arrays, same program); across backends the
            # fused kernels regroup sums -> <=1e-6.  (a --serve-only dir
            # may hold any artifact, so there is nothing to compare then)
            direct = np.asarray(wlsh_krr_predict(model, xte[:256]))
            bitwise = np.array_equal(served, direct)
            assert bitwise or np.allclose(served, direct, atol=1e-6), \
                "served predictions diverged from the in-memory model"
        again = predictor.predict(xq)
        assert np.array_equal(again, served), "cache replay not bitwise"
        t0 = time.time()
        for row in np.asarray(xte[:64], np.float32):
            predictor.predict(row)
        per_query = (time.time() - t0) / 64
        verdict = ("round-trip bitwise" if bitwise else
                   "round-trip <=1e-6" if compared else
                   "served (no in-memory model to compare)")
        print(f"serving    : {verdict} over {len(served)} "
              f"test points; single-query warm+cache path "
              f"{per_query * 1e6:.0f}us/query "
              f"(cache hit rate "
              f"{predictor.cache_stats(artifact_id=aid)['hit_rate']:.2f})")
    print("OK")


if __name__ == "__main__":
    main()
