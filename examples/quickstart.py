"""Quickstart: approximate kernel ridge regression with WLSH estimators.

    PYTHONPATH=src python examples/quickstart.py [--backend auto|reference|pallas]
        [--precond none|jacobi|nystrom] [--num-rhs K]

Fits a Laplace-kernel GP sample with (a) exact KRR, (b) WLSH approximate KRR
(the paper's method), and compares accuracy and fit time.  ``--backend``
selects the WLSH operator implementation (see src/repro/core/operator.py):
'reference' is the pure-jnp path, 'pallas' the fused TPU kernels, 'auto'
picks per platform.  ``--precond`` runs the solve as preconditioned CG
(core/precond.py; 'nystrom' collapses the iteration count on
ill-conditioned, small-lam problems).  ``--num-rhs K`` with K > 1 draws
K - 1 GP posterior samples alongside the mean via pathwise conditioning —
one batched multi-RHS solve instead of K separate fits (core/gp.py).
Prediction streams through fixed-size batches — the same code path that
serves multi-million-point inference.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import (WLSHKernelSpec, exact_krr_fit, exact_krr_predict,
                        get_bucket_fn, laplace_kernel, wlsh_krr_fit,
                        wlsh_krr_predict)
from repro.core.gp import gp_posterior_rhs, gp_regression_dataset
from repro.core.precond import DEFAULT_NYSTROM_RANK


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "reference", "pallas"])
    ap.add_argument("--fused", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="one-pass slot-blocked CG matvec (--no-fused keeps "
                         "the split scatter->gather path reachable for A/B)")
    ap.add_argument("--precond", default="none",
                    choices=["none", "jacobi", "nystrom"],
                    help="PCG preconditioner for the WLSH solve")
    ap.add_argument("--precond-rank", type=int, default=DEFAULT_NYSTROM_RANK)
    ap.add_argument("--num-rhs", type=int, default=1,
                    help="K > 1 adds K-1 pathwise GP posterior samples to "
                         "the solve as extra RHS columns (one batched fit)")
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    n_train, n_test = 1200, 400
    noise = 0.05
    x, y, f_true = gp_regression_dataset(key, laplace_kernel,
                                         n=n_train + n_test, d=4, noise=noise)
    xtr, ytr = x[:n_train], y[:n_train]
    xte, fte = x[n_train:], f_true[n_train:]
    lam = 0.3

    t0 = time.time()
    beta = exact_krr_fit(laplace_kernel, xtr, ytr, lam)
    pred_exact = exact_krr_predict(laplace_kernel, xtr, beta, xte)
    t_exact = time.time() - t0
    rmse_exact = float(jnp.sqrt(jnp.mean((pred_exact - fte) ** 2)))

    # WLSH: f = rect + p(w) = w e^{-w}  <=>  the Laplace kernel (Def. 8)
    spec = WLSHKernelSpec(bucket=get_bucket_fn("rect"))
    n_samples = max(args.num_rhs - 1, 0)
    target = ytr
    f_prior = None
    if n_samples:
        # pathwise conditioning: the sample RHS columns solve against the
        # SAME operator as the mean, so the whole batch is one block solve.
        # Matheron's rule needs eps ~ N(0, sigma^2) with sigma^2 = the
        # ridge actually solved against — KRR with lam IS GP regression
        # with assumed noise variance lam, so the samples draw from that
        # model's posterior (not the data-generating noise=0.05)
        target, f_prior = gp_posterior_rhs(
            jax.random.fold_in(key, 2), x, ytr, laplace_kernel,
            n_train=n_train, n_samples=n_samples, noise=float(lam) ** 0.5)
    t0 = time.time()
    model = wlsh_krr_fit(jax.random.fold_in(key, 1), xtr, target, spec,
                         m=400, lam=lam, backend=args.backend,
                         fused=args.fused, precond=args.precond,
                         precond_rank=args.precond_rank)
    # batch_size streams the test set in fixed memory (O(batch * m) peak)
    pred_wlsh = wlsh_krr_predict(model, xte, batch_size=128)
    t_wlsh = time.time() - t0
    if n_samples:
        posterior_samples = f_prior[n_train:] + pred_wlsh[:, 1:]
        pred_wlsh = pred_wlsh[:, 0]
        spread = float(jnp.mean(jnp.std(posterior_samples, axis=1)))
    rmse_wlsh = float(jnp.sqrt(jnp.mean((pred_wlsh - fte) ** 2)))

    cg_iters = int(jnp.max(model.cg_col_iters))
    print(f"exact KRR : rmse={rmse_exact:.4f}  fit+predict={t_exact:.2f}s "
          f"(O(n^3) solve)")
    print(f"WLSH KRR  : rmse={rmse_wlsh:.4f}  fit+predict={t_wlsh:.2f}s "
          f"(backend={model.backend}, m=400 instances, O(n m) per CG "
          f"iteration, {cg_iters} iters, precond={model.precond})")
    if n_samples:
        print(f"GP posterior: {n_samples} pathwise samples in the same "
              f"solve; mean test-point std {spread:.4f}")
    assert rmse_wlsh < 2.0 * rmse_exact + 0.05, "WLSH should track exact KRR"
    print("OK")


if __name__ == "__main__":
    main()
