"""The paper's workload, distributed: WLSH-KRR on an 8-device mesh (forced
CPU devices), exercising the psum-merged bucket tables and sharded CG that the
multi-pod dry-run lowers for 512 chips.

    python examples/distributed_krr.py      (sets its own XLA_FLAGS)
"""
import os
import subprocess
import sys

CMD = [sys.executable, "-m", "repro.launch.krr_train",
       "--dataset", "forest", "--scale", "0.002", "--m", "64",
       "--lam", "0.5", "--cg-iters", "40"]

if __name__ == "__main__":
    env = dict(os.environ)
    env.update({"PYTHONPATH": "src",
                "XLA_FLAGS": "--xla_force_host_platform_device_count=8"})
    print("+ XLA_FLAGS=--xla_force_host_platform_device_count=8",
          " ".join(CMD))
    raise SystemExit(subprocess.run(CMD, env=env).returncode)
