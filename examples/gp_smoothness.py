"""Paper §3.2 / Table 1: smooth WLSH kernels for GP regression.

Shows the paper's central qualitative claim: plain random binning (f = rect)
gives a NON-smooth kernel (Laplace) that underfits smooth processes, while the
weighted estimator with the smooth bucket f = (rect*rect_1/4*rect_1/4)(2x) and
p(w) = w^6 e^-w / 6! yields a Matern-like smooth kernel — same machinery,
strictly wider kernel family.  Each kernel's lengthscale is selected on a
validation split (the kernels' native scales differ by ~an order of
magnitude, so a fixed lengthscale would compare apples to oranges).

    PYTHONPATH=src python examples/gp_smoothness.py
"""
import jax
import jax.numpy as jnp

from repro.core import (GammaPDF, WLSHKernelSpec, gaussian_kernel,
                        get_bucket_fn, wlsh_krr_fit, wlsh_krr_predict)
from repro.core.gp import gp_regression_dataset


def fit_with_ell_selection(key, xtr, ytr, xval, yval, bucket, pdf, m, lam,
                           ells=(0.125, 0.25, 0.5, 1.0)):
    best = (None, jnp.inf, None)
    for ell in ells:
        spec = WLSHKernelSpec(bucket=get_bucket_fn(bucket), pdf=pdf,
                              lengthscale=ell)
        model = wlsh_krr_fit(key, xtr, ytr, spec, m=m, lam=lam, mode="exact")
        rmse = float(jnp.sqrt(jnp.mean((wlsh_krr_predict(model, xval) -
                                        yval) ** 2)))
        if rmse < best[1]:
            best = (model, rmse, ell)
    return best


def main():
    key = jax.random.PRNGKey(1)
    n_train, n_val, n_test = 1200, 300, 500
    # the ground truth is a SMOOTH process (squared-exponential covariance)
    x, y, f_true = gp_regression_dataset(
        key, gaussian_kernel, n=n_train + n_val + n_test, d=3, noise=0.05)
    xtr, ytr = x[:n_train], y[:n_train]
    xval, yval = x[n_train:n_train + n_val], y[n_train:n_train + n_val]
    xte, fte = x[n_train + n_val:], f_true[n_train + n_val:]

    results = {}
    for label, bucket, pdf in [
            ("rect (plain binning -> Laplace kernel)", "rect",
             GammaPDF(2.0, 1.0)),
            ("smooth (weighted -> Matern-like kernel)", "smooth",
             GammaPDF(7.0, 1.0))]:
        model, _, ell = fit_with_ell_selection(
            jax.random.fold_in(key, len(label)), xtr, ytr, xval, yval,
            bucket, pdf, m=800, lam=0.05)
        pred = wlsh_krr_predict(model, xte)
        results[label] = (float(jnp.sqrt(jnp.mean((pred - fte) ** 2))), ell)

    for label, (rmse, ell) in results.items():
        print(f"{label:45s} test RMSE = {rmse:.4f}  (ell*={ell})")
    smooth_rmse = results["smooth (weighted -> Matern-like kernel)"][0]
    rect_rmse = results["rect (plain binning -> Laplace kernel)"][0]
    print(f"\nsmooth-bucket WLSH vs plain binning on a smooth target: "
          f"{(1 - smooth_rmse / rect_rmse) * 100:+.1f}% RMSE change")


if __name__ == "__main__":
    main()
