"""§4 data-structure claim: K~ beta in O(n) time / O(n) memory.

Times the WLSH matvec through the unified operator stack — exact sort mode
and the CountSketch table mode on each backend ('reference' jnp vs 'pallas'
fused kernels) — across n, against the O(n^2) dense matvec; reports
microseconds per call and the empirical scaling exponent.  ``run`` returns
JSON-able per-(n, backend) rows so the perf trajectory can accumulate in
BENCH_matvec.json (see benchmarks/run.py)."""
from __future__ import annotations

import json

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import (GammaPDF, get_bucket_fn, make_operator,
                        sample_lsh_params)
from repro.core.operator import default_table_size
from repro.core.wlsh import build_exact_index, exact_kernel_matrix, exact_matvec

from .common import emit, time_fn


def run(ns=(1024, 4096, 16384), d: int = 8, m: int = 16, seed: int = 0):
    f = get_bucket_fn("rect")
    on_tpu = jax.default_backend() == "tpu"
    rows = []
    for n in ns:
        key = jax.random.PRNGKey(seed)
        x = jax.random.uniform(key, (n, d)) * 2.0
        lsh = sample_lsh_params(jax.random.fold_in(key, 1), m, d,
                                GammaPDF(2.0, 1.0))
        beta = jax.random.normal(jax.random.fold_in(key, 2), (n,))
        table_size = default_table_size(n, min_pow=10)

        op_ref = make_operator(lsh, f, table_size, backend="reference")
        feats = op_ref.featurize(x)
        tidx = op_ref.build_index(feats)
        eidx = build_exact_index(feats)

        row = {"n": n, "m": m, "d": d, "table_size": table_size,
               "exact_us": time_fn(jax.jit(
                   lambda b: exact_matvec(eidx, b)), beta) * 1e6,
               "reference_us": time_fn(jax.jit(
                   lambda b: op_ref.matvec(tidx, b)), beta) * 1e6}
        if on_tpu or n <= 1024:
            # off-TPU the Pallas kernels run in interpret mode (the kernel
            # body executes in Python) — correctness validation only,
            # meaningless as a wall-clock datapoint, so keep n tiny
            op_pal = make_operator(lsh, f, table_size, backend="pallas")
            row["pallas_us"] = time_fn(jax.jit(
                lambda b: op_pal.matvec(tidx, b)), beta) * 1e6
            row["pallas_interpret"] = op_pal.interpret
        if n <= 4096:  # dense comparison only where the matrix fits
            kmat = exact_kernel_matrix(feats)
            row["dense_us"] = time_fn(jax.jit(lambda b: kmat @ b), beta) * 1e6
        rows.append(row)
    return rows


def main(json_path: str | None = None) -> None:
    rows = run()
    print("n,exact_us,reference_us,pallas_us,dense_us")
    for r in rows:
        print(f"{r['n']},{r['exact_us']:.1f},{r['reference_us']:.1f},"
              f"{r.get('pallas_us', float('nan')):.1f},"
              f"{r.get('dense_us', float('nan')):.1f}")
    # empirical exponent between the LAST two sizes (smaller ones are
    # dominated by dispatch overhead); dense matvec would show ~2.0
    e = np.log(rows[-1]["reference_us"] / rows[-2]["reference_us"]) / \
        np.log(rows[-1]["n"] / rows[-2]["n"])
    if json_path:
        payload = {"bench": "matvec", "platform": jax.default_backend(),
                   "scaling_exponent": float(e), "rows": rows}
        with open(json_path, "w") as fh:
            json.dump(payload, fh, indent=2)
        print(f"[bench_matvec] wrote {json_path}")
    emit("bench_matvec", rows[-1]["reference_us"] * 1e-6,
         f"table_scaling_exponent={e:.2f} (1.0 = linear, dense = 2.0)")


if __name__ == "__main__":
    main()
