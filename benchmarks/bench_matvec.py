"""§4 data-structure claim: K~ beta in O(n) time / O(n) memory.

Times the WLSH matvec through the unified operator stack — exact sort mode,
the split CountSketch scatter→gather, and the fused one-pass slot-blocked
matvec, on each backend ('reference' jnp vs 'pallas' kernels) — across n,
against the O(n^2) dense matvec.  ``run`` returns JSON-able per-n rows with a
**stable schema** (every row carries every key; skipped measurements are
explicit ``None`` + a marker, never silently absent) so the perf trajectory
can accumulate in BENCH_matvec.json (see benchmarks/run.py) and
``benchmarks/check_regression.py`` can diff runs.

The solver section (``pcg_*`` keys) puts preconditioned CG on the same
regression rail: per n it solves an ill-conditioned synthetic KRR system
(long lengthscale, lam = 1e-3) unpreconditioned and with the rank-128
Nyström preconditioner, recording iteration counts and solve wall-clock.
``pcg_us`` includes the preconditioner build — the honest end-to-end cost.
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys

import numpy as np

import jax

from repro import obs
from repro.core import (GammaPDF, get_bucket_fn, make_operator,
                        make_preconditioner, pcg_solve, sample_lsh_params,
                        table_diag)
from repro.core.precond import DEFAULT_NYSTROM_RANK
from repro.core.operator import default_table_size
from repro.core.wlsh import build_exact_index, exact_kernel_matrix, exact_matvec

from .common import emit, time_fn

# dense comparison: build the true kernel matrix where the O(m n^2) featurized
# build fits in memory; above that use a random (n, n) proxy — the matvec cost
# only depends on the shape, and the timing is what the row records
DENSE_EXACT_MAX_N = 4096

# Reference fused-vs-split parity regime (measured, PR 5): at n >= this on
# CPU both paths are ~90% one XLA scatter-add (segment_sum for fused lowers
# to the same scatter loop as the split table scatter — 29ms vs 30ms of a
# ~33/38ms matvec at n=16384), so fused_speedup ~= 1.0 is the expected
# ceiling, NOT a pending win.  The fused path still saves the (m, B) table
# (4x the memory at B = 4n) and wins 1.5x+ at small n where table zeroing
# dominates.  Rows carry ``fused_parity_regime`` so downstream readers stop
# flagging ~1.0x as a regression.
FUSED_PARITY_MIN_N = 4096

# solver section: unpreconditioned CG on the ill-conditioned system needs
# O(1000) iterations — capped at this n so the benchmark stays minutes-scale
# (larger rows carry the explicit "large_n" skip marker instead)
PCG_MAX_N = 4096
PCG_LAM = 1e-3
PCG_LENGTHSCALE = 4.0
PCG_RANK = DEFAULT_NYSTROM_RANK
PCG_TOL = 1e-6
PCG_MAXITER = 2000

PCG_KEYS = ("cg_iters", "cg_us", "pcg_iters", "pcg_us", "pcg_iter_ratio")


def _pcg_section(key, x, m: int, table_size: int, row: dict) -> None:
    """Fill the row's solver keys (in place, always every key)."""
    d = x.shape[1]
    lsh = sample_lsh_params(jax.random.fold_in(key, 11), m, d,
                            GammaPDF(2.0, 1.0), lengthscale=PCG_LENGTHSCALE)
    op = make_operator(lsh, get_bucket_fn("rect"), table_size,
                       backend="reference")
    idx = op.build_index(op.featurize(x))
    mv = lambda v: op.matvec(idx, v)
    y = jax.random.normal(jax.random.fold_in(key, 12), (x.shape[0],))
    diag = table_diag(idx.coeff)

    def plain():
        return pcg_solve(mv, y, PCG_LAM, tol=PCG_TOL, maxiter=PCG_MAXITER)

    def nystrom():
        pre = make_preconditioner("nystrom", matvec=mv, diag=diag,
                                  lam=PCG_LAM, rank=PCG_RANK)
        return pcg_solve(mv, y, PCG_LAM, precond=pre, tol=PCG_TOL,
                         maxiter=PCG_MAXITER)

    def timed_solve(solve):
        solve()                        # warmup: populate compile caches
        with obs.span("bench.pcg_solve"):
            res = jax.block_until_ready(solve())
        return int(res.iters), obs.span_samples_us("bench.pcg_solve")[-1]

    row["cg_iters"], row["cg_us"] = timed_solve(plain)
    row["pcg_iters"], row["pcg_us"] = timed_solve(nystrom)
    row["pcg_iter_ratio"] = row["cg_iters"] / max(row["pcg_iters"], 1)


def run(ns=(1024, 4096, 16384), d: int = 8, m: int = 16, seed: int = 0, *,
        timing_iters: int = 3, timing_stat: str = "median",
        with_dense: bool = True, with_pallas: bool = True,
        with_pcg: bool = True):
    """``timing_iters``/``timing_stat`` select the wall-clock protocol
    (median-of-3 for the committed trajectory; the regression gate uses
    min-of-many — see benchmarks/check_regression.py).  ``with_dense``/
    ``with_pallas``/``with_pcg`` drop the ungated sections for a fast gate
    rerun; dropped measurements stay in the row as explicit None + marker."""
    time_args = {"iters": timing_iters, "stat": timing_stat}
    f = get_bucket_fn("rect")
    on_tpu = jax.default_backend() == "tpu"
    rows = []
    for n in ns:
        key = jax.random.PRNGKey(seed)
        x = jax.random.uniform(key, (n, d)) * 2.0
        lsh = sample_lsh_params(jax.random.fold_in(key, 1), m, d,
                                GammaPDF(2.0, 1.0))
        beta = jax.random.normal(jax.random.fold_in(key, 2), (n,))
        table_size = default_table_size(n, min_pow=10)

        op_ref = make_operator(lsh, f, table_size, backend="reference",
                               fused=False)
        op_fused = make_operator(lsh, f, table_size, backend="reference",
                                 fused=True)
        feats = op_ref.featurize(x)
        tidx = op_ref.build_index(feats)            # split (no layout)
        fidx = op_fused.build_index(feats)          # slot-blocked
        eidx = build_exact_index(feats)

        row = {"n": n, "m": m, "d": d, "table_size": table_size,
               "exact_us": time_fn(jax.jit(
                   lambda b: exact_matvec(eidx, b)), beta, **time_args) * 1e6,
               "reference_us": time_fn(jax.jit(
                   lambda b: op_ref.matvec(tidx, b)), beta, **time_args) * 1e6,
               "fused_us": time_fn(jax.jit(
                   lambda b: op_fused.matvec(fidx, b)), beta,
                   **time_args) * 1e6}
        row["fused_speedup"] = row["reference_us"] / row["fused_us"]
        row["fused_parity_regime"] = (not on_tpu) and n >= FUSED_PARITY_MIN_N

        if with_dense:
            if n <= DENSE_EXACT_MAX_N:
                kmat = exact_kernel_matrix(feats)
                row["dense_proxy"] = False
            else:
                kmat = jax.random.normal(jax.random.fold_in(key, 3), (n, n))
                row["dense_proxy"] = True
            row["dense_us"] = time_fn(jax.jit(lambda b: kmat @ b), beta,
                                      **time_args) * 1e6
            del kmat
        else:
            row["dense_us"] = None
            row["dense_proxy"] = None

        if not with_pallas:
            row["pallas_us"] = None
            row["pallas_fused_us"] = None
            row["pallas_fused_speedup"] = None
            row["pallas_split_blocked_us"] = None
            row["pallas_split_blocked_speedup"] = None
            row["pallas_interpret"] = None
            row["pallas_skipped"] = "disabled"
        elif on_tpu or n <= 1024:
            # off-TPU the Pallas kernels run in interpret mode (the kernel
            # body executes in Python) — correctness validation only,
            # meaningless as a wall-clock datapoint, so keep n tiny
            op_pal = make_operator(lsh, f, table_size, backend="pallas",
                                   fused=False)
            op_pal_fused = make_operator(lsh, f, table_size, backend="pallas",
                                         fused=True)
            fidx_pal = op_pal_fused.build_index(feats)  # pallas layout group
            # split contract (tables in HBM, psum-able) on the visit-list
            # schedule: a blocked index through the fused=False operator
            bidx_pal = op_pal.build_index(feats, blocked=True)
            row["pallas_us"] = time_fn(jax.jit(
                lambda b: op_pal.matvec(tidx, b)), beta, **time_args) * 1e6
            row["pallas_fused_us"] = time_fn(jax.jit(
                lambda b: op_pal_fused.matvec(fidx_pal, b)), beta,
                **time_args) * 1e6
            row["pallas_fused_speedup"] = \
                row["pallas_us"] / row["pallas_fused_us"]
            row["pallas_split_blocked_us"] = time_fn(jax.jit(
                lambda b: op_pal.matvec(bidx_pal, b)), beta,
                **time_args) * 1e6
            row["pallas_split_blocked_speedup"] = \
                row["pallas_us"] / row["pallas_split_blocked_us"]
            row["pallas_interpret"] = op_pal.interpret
            row["pallas_skipped"] = None
        else:
            row["pallas_us"] = None
            row["pallas_fused_us"] = None
            row["pallas_fused_speedup"] = None
            row["pallas_split_blocked_us"] = None
            row["pallas_split_blocked_speedup"] = None
            row["pallas_interpret"] = None
            row["pallas_skipped"] = "interpret"

        if not with_pcg:
            for k in PCG_KEYS:
                row[k] = None
            row["pcg_skipped"] = "disabled"
        elif n > PCG_MAX_N:
            for k in PCG_KEYS:
                row[k] = None
            row["pcg_skipped"] = "large_n"
        else:
            _pcg_section(key, x, m, table_size, row)
            row["pcg_skipped"] = None
        rows.append(row)
    return rows


# ---------------------------------------------------------------------------
# distributed rows: the sharded psum / hash-join paths on a fake-CPU mesh
# ---------------------------------------------------------------------------

DIST_SHARDS = (2, 4)
DIST_NS = (1024, 4096)
DIST_CG_ITERS = 8

_DIST_SCRIPT = r"""
import json, sys, time
import jax, jax.numpy as jnp
from repro.compat import make_mesh
from repro.core import GammaPDF, get_bucket_fn, sample_lsh_params
from repro.core.operator import default_table_size
from repro.core.distributed import (KRRStepConfig, make_krr_step,
                                    make_krr_step_hashjoin)

shards = int(sys.argv[1])
ns = [int(v) for v in sys.argv[2].split(",")]
iters = int(sys.argv[3])
assert len(jax.devices()) == shards, jax.devices()
mesh = make_mesh((1, shards, 1), ("pod", "data", "model"))
f = get_bucket_fn("rect")
rows = []
for n in ns:
    d, m = 8, 16
    key = jax.random.PRNGKey(0)
    x = jax.random.uniform(key, (n, d)) * 2.0
    y = jax.random.normal(jax.random.fold_in(key, 2), (n,))
    lsh = sample_lsh_params(jax.random.fold_in(key, 1), m, d,
                            GammaPDF(2.0, 1.0))
    table_size = default_table_size(n, min_pow=10)
    cfg = KRRStepConfig(m=m, table_size=table_size, lam=0.5, cg_iters=iters,
                        data_axes=("pod", "data"), model_axis="model",
                        backend="reference", fused=False)

    yk = jax.random.normal(jax.random.fold_in(key, 3), (n, 8))

    def best(fn, tgt, reps=3):
        jax.block_until_ready(fn(x, tgt, lsh)[0])
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(x, tgt, lsh)[0])
            ts.append(time.perf_counter() - t0)
        return min(ts)

    def iter_us(make, tgt=y, **kw):
        # isolate the per-CG-iteration (matvec + collectives) cost: the
        # cg_iters=0 step carries the same featurize/index/routing build
        full = best(jax.jit(make(mesh, cfg, f, **kw)), tgt)
        zero = best(jax.jit(make(mesh, cfg._replace(cg_iters=0), f, **kw)),
                    tgt)
        return max(full - zero, 0.0) / iters * 1e6

    # headline hashjoin_iter_us keeps cap_factor=4.0 + f32 wire — directly
    # comparable to the committed pre-fusion baseline
    hj = iter_us(make_krr_step_hashjoin, cap_factor=4.0,
                 payload_dtype=jnp.float32)
    hj_k8 = iter_us(make_krr_step_hashjoin, tgt=yk, cap_factor=4.0,
                    payload_dtype=jnp.float32)
    rows.append({"n": n, "shards": shards, "m": m, "table_size": table_size,
                 "cg_iters": iters, "psum_iter_us": iter_us(make_krr_step),
                 "hashjoin_iter_us": hj,
                 "hashjoin_bf16_iter_us": iter_us(make_krr_step_hashjoin,
                                                  cap_factor=4.0),
                 "hashjoin_k8_iter_us": hj_k8,
                 "hashjoin_k8_percol_ratio": hj_k8 / (8 * hj) if hj > 0
                 else None})
print("DISTROWS:" + json.dumps(rows))
"""


def distributed_rows(ns=DIST_NS, shard_counts=DIST_SHARDS,
                     cg_iters=DIST_CG_ITERS, timeout: float = 900.0):
    """Sharded-path timings, measured in subprocesses (the fake-CPU device
    count must be set before jax initializes, which this process already
    did).  Per (n, shards): the per-CG-iteration cost of the split psum
    matvec and the hash-join all_to_all matvec on a data mesh, isolated as
    (step(K iters) - step(0 iters)) / K so featurize/index/routing builds
    cancel.  Reference backend — interpret-mode Pallas timings are
    meaningless, and the collectives are the thing being recorded.  A
    failed shard count yields an explicit {"shards", "error"} marker row."""
    root = pathlib.Path(__file__).resolve().parent.parent
    env = {"PYTHONPATH": str(root / "src"), "JAX_PLATFORMS": "cpu",
           "PATH": os.environ.get("PATH", "/usr/bin:/bin")}
    out = []
    for s in shard_counts:
        try:
            proc = subprocess.run(
                [sys.executable, "-c", _DIST_SCRIPT, str(s),
                 ",".join(map(str, ns)), str(cg_iters)],
                env={**env, "XLA_FLAGS":
                     f"--xla_force_host_platform_device_count={s}"},
                capture_output=True, text=True, cwd=str(root),
                timeout=timeout)
        except subprocess.TimeoutExpired:
            out.append({"shards": s, "error": "timeout"})
            continue
        line = next((ln for ln in proc.stdout.splitlines()
                     if ln.startswith("DISTROWS:")), None)
        if proc.returncode != 0 or line is None:
            out.append({"shards": s, "error": (proc.stderr or "no output")[-500:]})
            continue
        out.extend(json.loads(line[len("DISTROWS:"):]))
    return out


def _exponent(rows, key):
    """Empirical scaling exponent between the LAST two sizes (smaller ones
    are dominated by dispatch overhead); dense matvec would show ~2.0."""
    return float(np.log(rows[-1][key] / rows[-2][key]) /
                 np.log(rows[-1]["n"] / rows[-2]["n"]))


def calibration_us(iters: int = 10) -> float:
    """Fixed-shape dense matvec timed with the noise-robust min — a
    machine-speed yardstick stored next to the baseline rows so the
    regression gate can normalize away hardware differences between the
    committing machine and the checking one."""
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (2048, 2048))
    v = jax.random.normal(jax.random.fold_in(key, 1), (2048,))
    return time_fn(jax.jit(lambda u: a @ u), v, iters=iters,
                   stat="min") * 1e6


def main(json_path: str | None = None, with_dist: bool = True) -> None:
    rows = run()
    print("n,exact_us,reference_us,fused_us,pallas_us,pallas_fused_us,dense_us")
    for r in rows:
        pal = ("skip" if r["pallas_us"] is None else f"{r['pallas_us']:.1f}")
        palf = ("skip" if r["pallas_fused_us"] is None
                else f"{r['pallas_fused_us']:.1f}")
        print(f"{r['n']},{r['exact_us']:.1f},{r['reference_us']:.1f},"
              f"{r['fused_us']:.1f},{pal},{palf},{r['dense_us']:.1f}")
    for r in rows:
        if r["pallas_split_blocked_us"] is not None:
            print(f"[blocked-split] n={r['n']}: cross-product "
                  f"{r['pallas_us']:.0f}us -> visit-list "
                  f"{r['pallas_split_blocked_us']:.0f}us "
                  f"({r['pallas_split_blocked_speedup']:.1f}x, interpret)")
    for r in rows:
        if r["pcg_iters"] is not None:
            print(f"[pcg] n={r['n']}: cg {r['cg_iters']} iters "
                  f"({r['cg_us']:.0f}us) vs nystrom {r['pcg_iters']} iters "
                  f"({r['pcg_us']:.0f}us incl. build) — "
                  f"{r['pcg_iter_ratio']:.1f}x fewer iterations")
        else:
            print(f"[pcg] n={r['n']}: skipped ({r['pcg_skipped']})")
    dist = distributed_rows() if with_dist else []
    for r in dist:
        if "error" in r:
            print(f"[dist] shards={r['shards']}: FAILED {r['error'][:120]}")
        else:
            ratio = r.get("hashjoin_k8_percol_ratio")
            extra = (f" (bf16 {r['hashjoin_bf16_iter_us']:.0f}us, k=8 "
                     f"per-col {ratio:.2f}x)"
                     if ratio is not None else "")
            print(f"[dist] n={r['n']} shards={r['shards']}: psum "
                  f"{r['psum_iter_us']:.0f}us/iter, hash-join "
                  f"{r['hashjoin_iter_us']:.0f}us/iter{extra}")
    e_split = _exponent(rows, "reference_us")
    e_fused = _exponent(rows, "fused_us")
    if json_path:
        payload = {"bench": "matvec", "platform": jax.default_backend(),
                   "calib_us": calibration_us(),
                   "scaling_exponent": e_split,
                   "fused_scaling_exponent": e_fused, "rows": rows,
                   "distributed": dist}
        with open(json_path, "w") as fh:
            json.dump(payload, fh, indent=2)
        print(f"[bench_matvec] wrote {json_path}")
    # report the fused win where it exists (small n); at large n on CPU
    # parity is the measured ceiling (FUSED_PARITY_MIN_N), not a pending win
    parity = rows[-1]["fused_parity_regime"]
    emit("bench_matvec", rows[-1]["fused_us"] * 1e-6,
         f"scaling_exponent split={e_split:.2f} fused={e_fused:.2f} "
         f"(1.0 = linear, dense = 2.0); "
         f"fused_speedup@n={rows[0]['n']}: {rows[0]['fused_speedup']:.2f}x"
         + (f"; parity expected at n>={FUSED_PARITY_MIN_N} (CPU scatter-add "
            f"bound)" if parity else
            f"; fused_speedup@n={rows[-1]['n']}: "
            f"{rows[-1]['fused_speedup']:.2f}x"))


if __name__ == "__main__":
    main()
