"""§4 data-structure claim: K~ beta in O(n) time / O(n) memory.

Times the WLSH matvec (exact sort mode and CountSketch table mode, both the
jnp path and the Pallas kernel path) across n, against the O(n^2) dense
matvec; reports microseconds per call and the empirical scaling exponent."""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import GammaPDF, featurize, get_bucket_fn, sample_lsh_params
from repro.core.wlsh import (build_exact_index, build_table_index,
                             exact_kernel_matrix, exact_matvec, table_matvec)
from repro.kernels.binning.ops import table_matvec_op

from .common import emit, time_fn


def run(ns=(1024, 4096, 16384), d: int = 8, m: int = 16, seed: int = 0):
    f = get_bucket_fn("rect")
    rows = []
    for n in ns:
        key = jax.random.PRNGKey(seed)
        x = jax.random.uniform(key, (n, d)) * 2.0
        params = sample_lsh_params(jax.random.fold_in(key, 1), m, d,
                                   GammaPDF(2.0, 1.0))
        feats = featurize(params, f, x)
        beta = jax.random.normal(jax.random.fold_in(key, 2), (n,))
        eidx = build_exact_index(feats)
        tidx = build_table_index(feats, 1 << max(10, (2 * n - 1).bit_length()))

        t_exact = time_fn(jax.jit(lambda b: exact_matvec(eidx, b)), beta)
        t_table = time_fn(jax.jit(lambda b: table_matvec(tidx, b)), beta)
        row = {"n": n, "exact_us": t_exact * 1e6, "table_us": t_table * 1e6}
        if n <= 1024:
            # interpret-mode Pallas runs the kernel body in Python — correct-
            # ness validation only, meaningless as a wall-clock datapoint
            row["pallas_us"] = time_fn(
                jax.jit(lambda b: table_matvec_op(tidx, b, interpret=True)),
                beta) * 1e6
        if n <= 4096:  # dense comparison only where the matrix fits
            kmat = exact_kernel_matrix(feats)
            row["dense_us"] = time_fn(jax.jit(lambda b: kmat @ b), beta) * 1e6
        rows.append(row)
    return rows


def main() -> None:
    rows = run()
    print("n,exact_us,table_us,pallas_interp_us,dense_us")
    for r in rows:
        print(f"{r['n']},{r['exact_us']:.1f},{r['table_us']:.1f},"
              f"{r.get('pallas_us', float('nan')):.1f},"
              f"{r.get('dense_us', float('nan')):.1f}")
    # empirical exponent between the LAST two sizes (smaller ones are
    # dominated by dispatch overhead); dense matvec would show ~2.0
    e = np.log(rows[-1]["table_us"] / rows[-2]["table_us"]) / \
        np.log(rows[-1]["n"] / rows[-2]["n"])
    emit("bench_matvec", rows[-1]["table_us"] * 1e-6,
         f"table_scaling_exponent={e:.2f} (1.0 = linear, dense = 2.0)")


if __name__ == "__main__":
    main()
