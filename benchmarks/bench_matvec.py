"""§4 data-structure claim: K~ beta in O(n) time / O(n) memory.

Times the WLSH matvec through the unified operator stack — exact sort mode,
the split CountSketch scatter→gather, and the fused one-pass slot-blocked
matvec, on each backend ('reference' jnp vs 'pallas' kernels) — across n,
against the O(n^2) dense matvec.  ``run`` returns JSON-able per-n rows with a
**stable schema** (every row carries every key; skipped measurements are
explicit ``None`` + a marker, never silently absent) so the perf trajectory
can accumulate in BENCH_matvec.json (see benchmarks/run.py) and
``benchmarks/check_regression.py`` can diff runs.

The solver section (``pcg_*`` keys) puts preconditioned CG on the same
regression rail: per n it solves an ill-conditioned synthetic KRR system
(long lengthscale, lam = 1e-3) unpreconditioned and with the rank-128
Nyström preconditioner, recording iteration counts and solve wall-clock.
``pcg_us`` includes the preconditioner build — the honest end-to-end cost.
"""
from __future__ import annotations

import json
import time

import numpy as np

import jax

from repro.core import (GammaPDF, get_bucket_fn, make_operator,
                        make_preconditioner, pcg_solve, sample_lsh_params,
                        table_diag)
from repro.core.precond import DEFAULT_NYSTROM_RANK
from repro.core.operator import default_table_size
from repro.core.wlsh import build_exact_index, exact_kernel_matrix, exact_matvec

from .common import emit, time_fn

# dense comparison: build the true kernel matrix where the O(m n^2) featurized
# build fits in memory; above that use a random (n, n) proxy — the matvec cost
# only depends on the shape, and the timing is what the row records
DENSE_EXACT_MAX_N = 4096

# solver section: unpreconditioned CG on the ill-conditioned system needs
# O(1000) iterations — capped at this n so the benchmark stays minutes-scale
# (larger rows carry the explicit "large_n" skip marker instead)
PCG_MAX_N = 4096
PCG_LAM = 1e-3
PCG_LENGTHSCALE = 4.0
PCG_RANK = DEFAULT_NYSTROM_RANK
PCG_TOL = 1e-6
PCG_MAXITER = 2000

PCG_KEYS = ("cg_iters", "cg_us", "pcg_iters", "pcg_us", "pcg_iter_ratio")


def _pcg_section(key, x, m: int, table_size: int, row: dict) -> None:
    """Fill the row's solver keys (in place, always every key)."""
    d = x.shape[1]
    lsh = sample_lsh_params(jax.random.fold_in(key, 11), m, d,
                            GammaPDF(2.0, 1.0), lengthscale=PCG_LENGTHSCALE)
    op = make_operator(lsh, get_bucket_fn("rect"), table_size,
                       backend="reference")
    idx = op.build_index(op.featurize(x))
    mv = lambda v: op.matvec(idx, v)
    y = jax.random.normal(jax.random.fold_in(key, 12), (x.shape[0],))
    diag = table_diag(idx.coeff)

    def plain():
        return pcg_solve(mv, y, PCG_LAM, tol=PCG_TOL, maxiter=PCG_MAXITER)

    def nystrom():
        pre = make_preconditioner("nystrom", matvec=mv, diag=diag,
                                  lam=PCG_LAM, rank=PCG_RANK)
        return pcg_solve(mv, y, PCG_LAM, precond=pre, tol=PCG_TOL,
                         maxiter=PCG_MAXITER)

    def timed_solve(solve):
        solve()                        # warmup: populate compile caches
        t0 = time.perf_counter()
        res = jax.block_until_ready(solve())
        return int(res.iters), (time.perf_counter() - t0) * 1e6

    row["cg_iters"], row["cg_us"] = timed_solve(plain)
    row["pcg_iters"], row["pcg_us"] = timed_solve(nystrom)
    row["pcg_iter_ratio"] = row["cg_iters"] / max(row["pcg_iters"], 1)


def run(ns=(1024, 4096, 16384), d: int = 8, m: int = 16, seed: int = 0, *,
        timing_iters: int = 3, timing_stat: str = "median",
        with_dense: bool = True, with_pallas: bool = True,
        with_pcg: bool = True):
    """``timing_iters``/``timing_stat`` select the wall-clock protocol
    (median-of-3 for the committed trajectory; the regression gate uses
    min-of-many — see benchmarks/check_regression.py).  ``with_dense``/
    ``with_pallas``/``with_pcg`` drop the ungated sections for a fast gate
    rerun; dropped measurements stay in the row as explicit None + marker."""
    time_args = {"iters": timing_iters, "stat": timing_stat}
    f = get_bucket_fn("rect")
    on_tpu = jax.default_backend() == "tpu"
    rows = []
    for n in ns:
        key = jax.random.PRNGKey(seed)
        x = jax.random.uniform(key, (n, d)) * 2.0
        lsh = sample_lsh_params(jax.random.fold_in(key, 1), m, d,
                                GammaPDF(2.0, 1.0))
        beta = jax.random.normal(jax.random.fold_in(key, 2), (n,))
        table_size = default_table_size(n, min_pow=10)

        op_ref = make_operator(lsh, f, table_size, backend="reference",
                               fused=False)
        op_fused = make_operator(lsh, f, table_size, backend="reference",
                                 fused=True)
        feats = op_ref.featurize(x)
        tidx = op_ref.build_index(feats)            # split (no layout)
        fidx = op_fused.build_index(feats)          # slot-blocked
        eidx = build_exact_index(feats)

        row = {"n": n, "m": m, "d": d, "table_size": table_size,
               "exact_us": time_fn(jax.jit(
                   lambda b: exact_matvec(eidx, b)), beta, **time_args) * 1e6,
               "reference_us": time_fn(jax.jit(
                   lambda b: op_ref.matvec(tidx, b)), beta, **time_args) * 1e6,
               "fused_us": time_fn(jax.jit(
                   lambda b: op_fused.matvec(fidx, b)), beta,
                   **time_args) * 1e6}
        row["fused_speedup"] = row["reference_us"] / row["fused_us"]

        if with_dense:
            if n <= DENSE_EXACT_MAX_N:
                kmat = exact_kernel_matrix(feats)
                row["dense_proxy"] = False
            else:
                kmat = jax.random.normal(jax.random.fold_in(key, 3), (n, n))
                row["dense_proxy"] = True
            row["dense_us"] = time_fn(jax.jit(lambda b: kmat @ b), beta,
                                      **time_args) * 1e6
            del kmat
        else:
            row["dense_us"] = None
            row["dense_proxy"] = None

        if not with_pallas:
            row["pallas_us"] = None
            row["pallas_fused_us"] = None
            row["pallas_fused_speedup"] = None
            row["pallas_interpret"] = None
            row["pallas_skipped"] = "disabled"
        elif on_tpu or n <= 1024:
            # off-TPU the Pallas kernels run in interpret mode (the kernel
            # body executes in Python) — correctness validation only,
            # meaningless as a wall-clock datapoint, so keep n tiny
            op_pal = make_operator(lsh, f, table_size, backend="pallas",
                                   fused=False)
            op_pal_fused = make_operator(lsh, f, table_size, backend="pallas",
                                         fused=True)
            fidx_pal = op_pal_fused.build_index(feats)  # pallas layout group
            row["pallas_us"] = time_fn(jax.jit(
                lambda b: op_pal.matvec(tidx, b)), beta, **time_args) * 1e6
            row["pallas_fused_us"] = time_fn(jax.jit(
                lambda b: op_pal_fused.matvec(fidx_pal, b)), beta,
                **time_args) * 1e6
            row["pallas_fused_speedup"] = \
                row["pallas_us"] / row["pallas_fused_us"]
            row["pallas_interpret"] = op_pal.interpret
            row["pallas_skipped"] = None
        else:
            row["pallas_us"] = None
            row["pallas_fused_us"] = None
            row["pallas_fused_speedup"] = None
            row["pallas_interpret"] = None
            row["pallas_skipped"] = "interpret"

        if not with_pcg:
            for k in PCG_KEYS:
                row[k] = None
            row["pcg_skipped"] = "disabled"
        elif n > PCG_MAX_N:
            for k in PCG_KEYS:
                row[k] = None
            row["pcg_skipped"] = "large_n"
        else:
            _pcg_section(key, x, m, table_size, row)
            row["pcg_skipped"] = None
        rows.append(row)
    return rows


def _exponent(rows, key):
    """Empirical scaling exponent between the LAST two sizes (smaller ones
    are dominated by dispatch overhead); dense matvec would show ~2.0."""
    return float(np.log(rows[-1][key] / rows[-2][key]) /
                 np.log(rows[-1]["n"] / rows[-2]["n"]))


def calibration_us(iters: int = 10) -> float:
    """Fixed-shape dense matvec timed with the noise-robust min — a
    machine-speed yardstick stored next to the baseline rows so the
    regression gate can normalize away hardware differences between the
    committing machine and the checking one."""
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (2048, 2048))
    v = jax.random.normal(jax.random.fold_in(key, 1), (2048,))
    return time_fn(jax.jit(lambda u: a @ u), v, iters=iters,
                   stat="min") * 1e6


def main(json_path: str | None = None) -> None:
    rows = run()
    print("n,exact_us,reference_us,fused_us,pallas_us,pallas_fused_us,dense_us")
    for r in rows:
        pal = ("skip" if r["pallas_us"] is None else f"{r['pallas_us']:.1f}")
        palf = ("skip" if r["pallas_fused_us"] is None
                else f"{r['pallas_fused_us']:.1f}")
        print(f"{r['n']},{r['exact_us']:.1f},{r['reference_us']:.1f},"
              f"{r['fused_us']:.1f},{pal},{palf},{r['dense_us']:.1f}")
    for r in rows:
        if r["pcg_iters"] is not None:
            print(f"[pcg] n={r['n']}: cg {r['cg_iters']} iters "
                  f"({r['cg_us']:.0f}us) vs nystrom {r['pcg_iters']} iters "
                  f"({r['pcg_us']:.0f}us incl. build) — "
                  f"{r['pcg_iter_ratio']:.1f}x fewer iterations")
        else:
            print(f"[pcg] n={r['n']}: skipped ({r['pcg_skipped']})")
    e_split = _exponent(rows, "reference_us")
    e_fused = _exponent(rows, "fused_us")
    if json_path:
        payload = {"bench": "matvec", "platform": jax.default_backend(),
                   "calib_us": calibration_us(),
                   "scaling_exponent": e_split,
                   "fused_scaling_exponent": e_fused, "rows": rows}
        with open(json_path, "w") as fh:
            json.dump(payload, fh, indent=2)
        print(f"[bench_matvec] wrote {json_path}")
    emit("bench_matvec", rows[-1]["fused_us"] * 1e-6,
         f"scaling_exponent split={e_split:.2f} fused={e_fused:.2f} "
         f"(1.0 = linear, dense = 2.0); "
         f"fused_speedup@n={rows[-1]['n']}: {rows[-1]['fused_speedup']:.2f}x")


if __name__ == "__main__":
    main()
