"""Paper Table 1: test-set RMSE for estimating GPs with different kernels.

Samples eta ~ GP(0, sigma) for sigma in {SqExp, Laplace, Matern-5/2}, fits KRR
with each of {Laplace, SqExp, Matern-5/2, WLSH(smooth, Gamma(7,1))}, reports
test RMSE.  Sizes are scaled from the paper's 3000/1000 via --scale to stay
CPU-friendly; relative ordering is what the experiment checks (the paper's
claim: WLSH tracks the best classical kernel and beats the mismatched ones).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.core import (GammaPDF, WLSHKernelSpec, exact_krr_fit,
                        exact_krr_predict, gaussian_kernel, get_bucket_fn,
                        laplace_kernel, make_wlsh_kernel, matern52_kernel,
                        wlsh_krr_fit, wlsh_krr_predict)
from repro.core.gp import gp_regression_dataset

from .common import emit

COVS = {"sqexp": gaussian_kernel, "laplace": laplace_kernel,
        "matern52": matern52_kernel}


def run(scale: float = 1.0, dims=(5, 30), seed: int = 0, m: int = 450,
        lam: float = 0.05):
    n_train, n_test = int(3000 * scale), int(1000 * scale)
    n_val = max(50, n_test // 4)
    rows = []
    for d in dims:
        # pairwise distances on [0,1]^d concentrate at ~sqrt(d/6); scale every
        # covariance's lengthscale with sqrt(d) so the sampled GP has O(1)
        # correlation structure at ANY d (at unit lengthscale a d=30 GP is
        # white noise and no kernel can learn it)
        ell_d = max(1.0, (d / 6.0) ** 0.5)
        for cov_name, cov0 in COVS.items():
            cov = lambda a, b, k=cov0: k(a, b, lengthscale=ell_d)
            key = jax.random.PRNGKey(seed + d)
            x, y, f_true = gp_regression_dataset(
                key, cov, n=n_train + n_test, d=d, noise=0.05)
            xtr, ytr = x[:n_train], y[:n_train]
            xte, fte = x[n_train:], f_true[n_train:]
            row = {"cov": cov_name, "d": d}
            for fit_name, fit_k0 in COVS.items():
                fit_k = lambda a, b, k=fit_k0: k(a, b, lengthscale=ell_d)
                beta = exact_krr_fit(fit_k, xtr, ytr, lam)
                pred = exact_krr_predict(fit_k, xtr, beta, xte)
                row[fit_name] = float(jnp.sqrt(jnp.mean((pred - fte) ** 2)))
            # WLSH: the paper's smooth bucket fn + p(w) = w^6 e^-w / 6! in low
            # d; rect + Gamma(2,1) in high d — the estimator's variance grows
            # as E[f^4]^d (Thm 11's ||f||_inf^2d factor), so the smooth bucket
            # needs astronomically many instances at d=30 while rect (f==1)
            # stays variance-safe.  Lengthscale selected on a validation split
            # (the WLSH family's native scale is ~w_mean * supp(f*f)).
            bucket, pdf = (("smooth", GammaPDF(7.0, 1.0)) if d <= 10
                           else ("rect", GammaPDF(2.0, 1.0)))
            best = (jnp.inf, None)
            for ell in (0.125 * ell_d, 0.25 * ell_d, 0.5 * ell_d, ell_d):
                spec = WLSHKernelSpec(bucket=get_bucket_fn(bucket),
                                      pdf=pdf, lengthscale=ell)
                mod = wlsh_krr_fit(jax.random.fold_in(key, 1),
                                   xtr[:-n_val], ytr[:-n_val], spec,
                                   m=m, lam=lam, mode="exact")
                vr = float(jnp.sqrt(jnp.mean(
                    (wlsh_krr_predict(mod, xtr[-n_val:]) -
                     ytr[-n_val:]) ** 2)))
                if vr < best[0]:
                    best = (vr, ell)
            spec = WLSHKernelSpec(bucket=get_bucket_fn(bucket),
                                  pdf=pdf, lengthscale=best[1])
            model = wlsh_krr_fit(jax.random.fold_in(key, 1), xtr, ytr, spec,
                                 m=m, lam=lam, mode="exact")
            pred = wlsh_krr_predict(model, xte)
            row["wlsh"] = float(jnp.sqrt(jnp.mean((pred - fte) ** 2)))
            row["wlsh_ell"] = best[1]
            rows.append(row)
    return rows


def main(scale: float = 0.25, m: int = 300) -> None:
    rows = run(scale=scale, m=m)
    print("cov,d,laplace,sqexp,matern52,wlsh")
    ok = True
    for r in rows:
        print(f"{r['cov']},{r['d']},{r['laplace']:.4f},{r['sqexp']:.4f},"
              f"{r['matern52']:.4f},{r['wlsh']:.4f}")
        best_classical = min(r["laplace"], r["sqexp"], r["matern52"])
        if r["d"] <= 10:
            # smooth-bucket WLSH vs the best classical kernel; the CPU-scale
            # instance budget adds MC variance, hence the slack
            ok = ok and r["wlsh"] < 2.0 * best_classical + 0.08
        else:
            # high d runs the rect bucket (== Laplace kernel family): the
            # like-for-like claim is estimator-tracks-its-own-exact-kernel
            ok = ok and r["wlsh"] < 1.3 * r["laplace"] + 0.02
    emit("table1_gp", 0.0, f"wlsh_competitive={ok}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.25)
    ap.add_argument("--m", type=int, default=300)
    a = ap.parse_args()
    main(scale=a.scale, m=a.m)
