"""Shared benchmark utilities: wall-clock timing + CSV emission.

Timing goes through ``repro.obs`` spans so every benchmark sample also lands
in the span buffers and the ``bench_us`` histogram — the benchmarks and the
live /metrics endpoint report from the SAME clock and recording path, and a
profiler trace of a bench run shows each sample as a named annotation.
"""
from __future__ import annotations

from typing import Callable

import jax

from repro import obs


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3,
            stat: str = "median", span: str = "bench.time_fn") -> float:
    """Wall time (seconds) of fn(*args) after warmup (jit-friendly).

    ``stat='median'`` is the honest trajectory statistic; ``stat='min'`` is
    the noise-robust one for regression gating — on shared CPU containers
    the timing distribution is bimodal (noisy-neighbor bursts 2-3x the quiet
    mode), and only the minimum is reproducible run to run.

    Each timed iteration is recorded as an obs span named ``span``
    (block_until_ready INSIDE the span, so the sample covers device work);
    callers can pull the full sample set back via
    ``obs.span_samples_us(span)`` instead of re-timing.
    """
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    hist = obs.histogram("bench_us", "benchmark sample wall time",
                         labels=("name",)).labels(span)
    for _ in range(iters):
        with obs.span(span, to_histogram=hist):
            jax.block_until_ready(fn(*args))
    times = [s / 1e6 for s in obs.span_samples_us(span)[-iters:]]
    if stat == "min":
        return min(times)
    if stat == "median":
        return sorted(times)[len(times) // 2]
    raise ValueError(f"unknown stat {stat!r}")


def emit(name: str, seconds: float, derived: str = "") -> None:
    print(f"{name},{seconds * 1e6:.1f},{derived}")
