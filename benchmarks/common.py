"""Shared benchmark utilities: wall-clock timing + CSV emission."""
from __future__ import annotations

import time
from typing import Callable

import jax


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3,
            stat: str = "median") -> float:
    """Wall time (seconds) of fn(*args) after warmup (jit-friendly).

    ``stat='median'`` is the honest trajectory statistic; ``stat='min'`` is
    the noise-robust one for regression gating — on shared CPU containers
    the timing distribution is bimodal (noisy-neighbor bursts 2-3x the quiet
    mode), and only the minimum is reproducible run to run.
    """
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    if stat == "min":
        return min(times)
    if stat == "median":
        return sorted(times)[len(times) // 2]
    raise ValueError(f"unknown stat {stat!r}")


def emit(name: str, seconds: float, derived: str = "") -> None:
    print(f"{name},{seconds * 1e6:.1f},{derived}")
