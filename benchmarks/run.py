"""Run every benchmark at CPU-friendly scale.  One section per paper
table/figure; each emits ``name,us_per_call,derived`` CSV lines plus its own
detail table.  The matvec section also writes ``BENCH_matvec.json`` — the
per-(n, backend) split/fused operator timings that accumulate the perf
trajectory across PRs; ``benchmarks/check_regression.py`` (also a --runslow
pytest) gates reference_us/fused_us against the committed file.

    PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import time
import traceback

MATVEC_JSON = "BENCH_matvec.json"
SERVING_JSON = "BENCH_serving.json"


def main() -> None:
    from . import bench_matvec, bench_ose, bench_serving, table1_gp, table2_krr
    sections = [
        ("Table 1 (GP regression RMSE)", lambda: table1_gp.main(scale=0.15,
                                                                m=280)),
        ("Table 2 (large-scale KRR)", table2_krr.main),
        ("Matvec O(n) scaling (paper §4)",
         lambda: bench_matvec.main(json_path=MATVEC_JSON)),
        ("Serving latency tiers (DESIGN §8)",
         lambda: bench_serving.main(json_path=SERVING_JSON)),
        ("OSE eps vs m (Thm 11/12)", bench_ose.main),
    ]
    failures = 0
    for name, fn in sections:
        print(f"\n=== {name} ===")
        t0 = time.time()
        try:
            fn()
        except Exception:
            failures += 1
            traceback.print_exc()
        print(f"=== done in {time.time() - t0:.1f}s ===")
    if failures:
        raise SystemExit(f"{failures} benchmark section(s) failed")


if __name__ == "__main__":
    main()
