"""Serving-path latency/throughput benchmark -> BENCH_serving.json.

Measures the three serving tiers the subsystem exists for, on one fitted
model (rect bucket, serving-scale m):

* **cold**   — a FRESH predictor's first single-query call, compile included:
  what a replica pays right after loading an artifact with no warmup.
* **warm**   — the steady-state single-query featurize+readout path (padding
  bucket already compiled, cache off): p50/p99 over many calls.
* **cached** — the same query answered by the bucket-exact cache (key memo +
  LRU probe, no jit entry): p50/p99.

plus the micro-batcher under several offered loads (paced submit loop ->
achieved QPS, latency percentiles, mean coalesced batch size), and a
**sharded** section: ShardedPredictor warm batch-``MAX_BATCH`` p50/p99 on a
fake-CPU 2x2 mesh, measured in a subprocess (the fake device count must be
set before jax initializes) TOGETHER with the single-host warm p50 at the
same batch in the same child, so ``ratio_vs_single`` compares like with
like.  That ratio is the sharded-serving acceptance pin (warm p50 within
3x of single-host) gated by ``check_regression --sharded``.

A **lifecycle** section measures the self-healing runtime (DESIGN.md §12):
single-query p50 before vs immediately after a live version swap
(``swap_p50_ratio``), the jit-cache growth across the swap
(``swap_compile_delta`` — pinned to 0 by ``check_regression --lifecycle``:
swaps must not recompile warm buckets), and forced-rollback
time-to-first-healthy-prediction (``rollback_to_healthy_us``).

The committed BENCH_serving.json is the regression baseline:
``benchmarks/check_regression.py`` gates warm_p50_us and cached_p50_us
against it (same platform only, machine-speed normalized via the shared
calibration workload).  The two structural claims — warm >= 5x faster than
cold, cache hit >= 10x faster than warm — are asserted by
tests/test_bench_regression.py --runslow off this module's ``run()``.

    PYTHONPATH=src python -m benchmarks.bench_serving [--json PATH] [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys
import tempfile

import numpy as np

import jax

from repro import obs
from repro.serve import Predictor, bucket_sizes
from repro.serve.batcher import percentile

from .common import emit

# serving-scale model: m matches the quickstart fit; n only shapes the tables
MODEL_N = 2048
MODEL_D = 8
MODEL_M = 256
SEED = 0

OFFERED_QPS = (2000.0, 8000.0, 0.0)          # 0 = unthrottled
BATCH_REQUESTS = 2000
MAX_BATCH = 64
MAX_WAIT_US = 1000
DUP_FRAC = 0.5

SHARDED_MESH = (2, 2)                        # (model_shards, data_shards)


def _span_lat_us(fn, iters: int, span: str = "serve.predict"):
    """Sorted per-call latencies in us, read back from the predictor's own
    ``serve.predict`` spans — the benchmark reports the SAME samples the
    live /metrics histogram records, not a second ad-hoc clock."""
    obs.clear_span_samples(span)
    for _ in range(iters):
        fn()
    lat = obs.span_samples_us(span)
    assert len(lat) == iters, (len(lat), iters)
    return sorted(lat)


def run(*, iters: int = 300, batch_requests: int = BATCH_REQUESTS,
        offered_qps=OFFERED_QPS, repeats: int = 1) -> dict:
    """Returns the JSON-able result dict (stable schema: every key always
    present).  ``iters`` is the single-query sample count for the warm and
    cached percentiles; ``repeats`` re-runs only those measurement sections
    (min-of-N per percentile) so the regression gate can sample over minutes
    without re-paying the model fit / export / predictor compile."""
    from repro.launch.krr_serve import (_fit_and_export, _synthetic_stream,
                                        serve_stream)

    out = {"bench": "serving", "platform": jax.default_backend(),
           "model": {"n": MODEL_N, "d": MODEL_D, "m": MODEL_M},
           "max_batch": MAX_BATCH, "max_wait_us": MAX_WAIT_US,
           "dup_frac": DUP_FRAC}
    with tempfile.TemporaryDirectory() as tmp:
        art_dir = tmp + "/artifact"
        # one canonical serving fit, shared with the krr_serve selftest
        _fit_and_export(art_dir, n=MODEL_N, d=MODEL_D, m=MODEL_M, seed=SEED)
        q = (np.random.default_rng(SEED)
             .uniform(0.0, 2.0, size=(1, MODEL_D)).astype(np.float32))

        # cold: fresh predictor, first call pays tracing + compile
        cold_pred = Predictor(cache_entries=0)
        cold_pred.load(art_dir)
        out["cold_first_call_us"] = _span_lat_us(
            lambda: cold_pred.predict(q), 1)[0]

        # warm: steady-state single-query jit path (bucket compiled, no cache)
        pred = Predictor(cache_entries=65536)
        pred.load(art_dir)
        pred.warmup(sizes=bucket_sizes(MAX_BATCH))
        pred.predict(q)          # cached: first call inserts, later replay
        for key in ("warm_p50_us", "warm_p99_us",
                    "cached_p50_us", "cached_p99_us"):
            out[key] = float("inf")
        for _ in range(max(repeats, 1)):
            warm = _span_lat_us(lambda: pred.predict(q, use_cache=False),
                                iters)
            cached = _span_lat_us(lambda: pred.predict(q), iters)
            out["warm_p50_us"] = min(out["warm_p50_us"],
                                     percentile(warm, 50))
            out["warm_p99_us"] = min(out["warm_p99_us"],
                                     percentile(warm, 99))
            out["cached_p50_us"] = min(out["cached_p50_us"],
                                       percentile(cached, 50))
            out["cached_p99_us"] = min(out["cached_p99_us"],
                                       percentile(cached, 99))

        out["warm_speedup_vs_cold"] = \
            out["cold_first_call_us"] / out["warm_p50_us"]
        out["cache_speedup_vs_warm"] = \
            out["warm_p50_us"] / out["cached_p50_us"]

        # batcher tiers: same request stream at increasing offered load
        stream = _synthetic_stream(MODEL_D, batch_requests, DUP_FRAC,
                                   SEED + 1)
        rows = []
        for qps in offered_qps:
            # tier isolation: each offered load starts from a cold cache so
            # only the stream's own dup_frac produces hits
            pred.clear_cache()
            stats = serve_stream(pred, stream, max_batch=MAX_BATCH,
                                 max_wait_us=MAX_WAIT_US, target_qps=qps)
            rows.append({"offered_qps": qps or None,   # None = unthrottled
                         "achieved_qps": stats["qps"],
                         "p50_us": stats["p50_us"],
                         "p99_us": stats["p99_us"],
                         "mean_batch": stats["mean_batch"],
                         "batches": stats["batches"],
                         "requests": stats["served"]})
        out["batcher_rows"] = rows
    return out


# ---------------------------------------------------------------------------
# lifecycle section: swap disturbance + rollback time-to-healthy
# ---------------------------------------------------------------------------

def lifecycle_section(*, iters: int = 200, repeats: int = 3) -> dict:
    """Self-healing runtime costs (DESIGN.md §12), measured in-process:

    * ``steady_p50_us``    — single-query warm p50 through the runtime's
      version-resolving predict (the active-tuple read is the only cost the
      lifecycle layer adds to the predictor's own path);
    * ``post_swap_p50_us`` / ``swap_p50_ratio`` — the same measurement
      immediately after a live version swap: the disturbance pin (the
      candidate pre-warms before the flip, so the ratio should be ~1);
    * ``swap_compile_delta`` — jit-cache growth of the active model across
      the swap; MUST be 0 (a swap that recompiles warm buckets stalls every
      in-flight bucket on real accelerators);
    * ``rollback_to_healthy_us`` — forced rollback to the retained version
      through to the first healthy prediction, min over ``repeats``
      publish->swap->rollback cycles: the recovery-time budget.

    Failure yields an explicit ``{"error": ...}`` marker instead of raising,
    matching the sharded section's stable-schema contract.
    """
    try:
        return _lifecycle_measure(iters=iters, repeats=repeats)
    except Exception as e:  # noqa: BLE001 — marker, not silence
        return {"error": f"{type(e).__name__}: {e}"}


def _lifecycle_measure(*, iters: int, repeats: int) -> dict:
    from repro.launch.krr_serve import _fit
    from repro.serve import (LifecycleConfig, ServingRuntime,
                             export_artifact, version_dir)
    from time import perf_counter

    with tempfile.TemporaryDirectory() as tmp:
        root = tmp + "/versions"
        model, _ = _fit(n=MODEL_N, d=MODEL_D, m=MODEL_M, seed=SEED)
        export_artifact(version_dir(root, 1), model)
        cfg = LifecycleConfig(probation_s=0.0, retain=2, warm_sizes=(1,))
        rt = ServingRuntime(root, cache_entries=0, config=cfg)
        rt.poll_once()
        q = (np.random.default_rng(SEED)
             .uniform(0.0, 2.0, size=(1, MODEL_D)).astype(np.float32))
        rt.predict(q)
        res = {"steady_p50_us": float("inf"),
               "post_swap_p50_us": float("inf")}
        for _ in range(max(repeats, 1)):
            lat = _span_lat_us(lambda: rt.predict(q), iters)
            res["steady_p50_us"] = min(res["steady_p50_us"],
                                       percentile(lat, 50))
        c0 = rt.compile_count()
        export_artifact(version_dir(root, 2), model)
        report = rt.poll_once()
        assert report["action"] == "swap", report
        res["swap_compile_delta"] = rt.compile_count() - c0
        for _ in range(max(repeats, 1)):
            lat = _span_lat_us(lambda: rt.predict(q), iters)
            res["post_swap_p50_us"] = min(res["post_swap_p50_us"],
                                          percentile(lat, 50))
        res["swap_p50_ratio"] = (res["post_swap_p50_us"]
                                 / res["steady_p50_us"])
        heal = float("inf")
        ver = 2
        for _ in range(max(repeats, 1)):
            ver += 1
            export_artifact(version_dir(root, ver), model)
            report = rt.poll_once()
            assert report["action"] == "swap", report
            t0 = perf_counter()
            assert rt.rollback("bench: forced")
            rt.predict(q)        # first healthy answer post-rollback
            heal = min(heal, (perf_counter() - t0) * 1e6)
        res["rollback_to_healthy_us"] = heal
    return res


# ---------------------------------------------------------------------------
# sharded section: ShardedPredictor vs single-host warm path on a fake mesh
# ---------------------------------------------------------------------------

_SHARDED_SCRIPT = r"""
import json, sys, tempfile
import numpy as np
import jax
from repro import obs
from repro.launch.krr_serve import _fit_and_export
from repro.serve import Predictor, ShardedPredictor
from repro.serve.batcher import percentile

mm, nd = (int(v) for v in sys.argv[1].split("x"))
iters, repeats, batch = (int(v) for v in sys.argv[2:5])
n, d, m = (int(v) for v in sys.argv[5:8])
assert len(jax.devices()) >= mm * nd, jax.devices()


def lat_us(fn, iters):
    # read the predictors' own serve.predict spans back instead of timing
    # around the call — same samples the /metrics histogram sees
    obs.clear_span_samples("serve.predict")
    for _ in range(iters):
        fn()
    return sorted(obs.span_samples_us("serve.predict"))


with tempfile.TemporaryDirectory() as tmp:
    # one fit, two exports: the single-host artifact is the same model, so
    # the latency ratio below is apples to apples
    model, _ = _fit_and_export(tmp + "/single", n=n, d=d, m=m, seed=0)
    _fit_and_export(tmp + "/sharded", n=n, d=d, m=m, seed=0,
                    mesh_shape=(mm, nd))
    single = Predictor(cache_entries=0)
    single.load(tmp + "/single")
    single.warmup(sizes=(batch,))
    sharded = ShardedPredictor(mesh_shape=(mm, nd), cache_entries=0)
    sharded.load(tmp + "/sharded")
    sharded.warmup(sizes=(batch,))
    q = (np.random.default_rng(0).uniform(0.0, 2.0, size=(batch, d))
         .astype(np.float32))
    res = {k: float("inf") for k in ("warm_p50_us", "warm_p99_us",
                                     "single_warm_p50_us")}
    for _ in range(repeats):
        s = lat_us(lambda: sharded.predict(q, use_cache=False), iters)
        u = lat_us(lambda: single.predict(q, use_cache=False), iters)
        res["warm_p50_us"] = min(res["warm_p50_us"], percentile(s, 50))
        res["warm_p99_us"] = min(res["warm_p99_us"], percentile(s, 99))
        res["single_warm_p50_us"] = min(res["single_warm_p50_us"],
                                        percentile(u, 50))
res["mesh"] = f"{mm}x{nd}"
res["batch"] = batch
res["ratio_vs_single"] = res["warm_p50_us"] / res["single_warm_p50_us"]
print("SHARDED:" + json.dumps(res))
"""


def sharded_section(*, mesh=SHARDED_MESH, iters: int = 100,
                    repeats: int = 3, batch: int = MAX_BATCH,
                    timeout: float = 900.0) -> dict:
    """Warm sharded-serving latencies at batch ``batch`` on a fake-CPU
    ``mesh``, measured in a subprocess (the fake device count must be set
    before jax initializes, which this process already did).  The child
    fits ONE model, serves it both ways, and reports sharded warm
    p50/p99 plus the single-host warm p50 from the same process —
    ``ratio_vs_single`` is the <=3x acceptance pin.  dedup=False broadcast
    wire (the ShardedPredictor interactive default).  Failure yields an
    explicit {"error": ...} marker instead of raising: a runner that cannot
    spawn fake devices says nothing about the code."""
    root = pathlib.Path(__file__).resolve().parent.parent
    need = mesh[0] * mesh[1]
    env = {"PYTHONPATH": str(root / "src"), "JAX_PLATFORMS": "cpu",
           "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
           "XLA_FLAGS": f"--xla_force_host_platform_device_count={need}"}
    try:
        proc = subprocess.run(
            [sys.executable, "-c", _SHARDED_SCRIPT,
             f"{mesh[0]}x{mesh[1]}", str(iters), str(repeats), str(batch),
             str(MODEL_N), str(MODEL_D), str(MODEL_M)],
            env=env, capture_output=True, text=True, cwd=str(root),
            timeout=timeout)
    except subprocess.TimeoutExpired:
        return {"mesh": f"{mesh[0]}x{mesh[1]}", "error": "timeout"}
    line = next((ln for ln in proc.stdout.splitlines()
                 if ln.startswith("SHARDED:")), None)
    if proc.returncode != 0 or line is None:
        return {"mesh": f"{mesh[0]}x{mesh[1]}",
                "error": (proc.stderr or "no output")[-500:]}
    return json.loads(line[len("SHARDED:"):])


def main(json_path: str | None = None, *, quick: bool = False) -> dict:
    from . import bench_matvec

    res = run(iters=100 if quick else 300,
              batch_requests=500 if quick else BATCH_REQUESTS,
              offered_qps=(0.0,) if quick else OFFERED_QPS)
    res["sharded"] = sharded_section(iters=50 if quick else 100,
                                     repeats=1 if quick else 3)
    res["lifecycle"] = lifecycle_section(iters=50 if quick else 200,
                                         repeats=1 if quick else 3)
    res["calib_us"] = bench_matvec.calibration_us()
    print(f"[bench_serving] cold first call {res['cold_first_call_us']:.0f}us "
          f"(compile included)")
    print(f"[bench_serving] warm single query p50 {res['warm_p50_us']:.0f}us "
          f"p99 {res['warm_p99_us']:.0f}us "
          f"({res['warm_speedup_vs_cold']:.0f}x vs cold)")
    print(f"[bench_serving] cached hit p50 {res['cached_p50_us']:.0f}us "
          f"p99 {res['cached_p99_us']:.0f}us "
          f"({res['cache_speedup_vs_warm']:.1f}x vs warm)")
    for row in res["batcher_rows"]:
        offered = ("unthrottled" if row["offered_qps"] is None
                   else f"{row['offered_qps']:.0f} offered")
        print(f"[bench_serving] batcher {offered}: "
              f"{row['achieved_qps']:.0f} QPS, p50 {row['p50_us']:.0f}us "
              f"p99 {row['p99_us']:.0f}us, "
              f"mean batch {row['mean_batch']:.1f}")
    sh = res["sharded"]
    if "error" in sh:
        print(f"[bench_serving] sharded {sh.get('mesh', '?')}: measurement "
              f"FAILED {sh['error'][:120]}")
    else:
        print(f"[bench_serving] sharded {sh['mesh']} batch {sh['batch']}: "
              f"warm p50 {sh['warm_p50_us']:.0f}us "
              f"p99 {sh['warm_p99_us']:.0f}us "
              f"({sh['ratio_vs_single']:.2f}x single-host warm "
              f"{sh['single_warm_p50_us']:.0f}us)")
    lc = res["lifecycle"]
    if "error" in lc:
        print(f"[bench_serving] lifecycle: measurement FAILED "
              f"{lc['error'][:120]}")
    else:
        print(f"[bench_serving] lifecycle: steady p50 "
              f"{lc['steady_p50_us']:.0f}us, post-swap p50 "
              f"{lc['post_swap_p50_us']:.0f}us "
              f"(ratio {lc['swap_p50_ratio']:.2f}, "
              f"compile delta {lc['swap_compile_delta']}), "
              f"rollback-to-healthy {lc['rollback_to_healthy_us']:.0f}us")
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(res, fh, indent=2)
        print(f"[bench_serving] wrote {json_path}")
    emit("bench_serving", res["warm_p50_us"] * 1e-6,
         f"cache_speedup={res['cache_speedup_vs_warm']:.1f}x "
         f"warm_speedup_vs_cold={res['warm_speedup_vs_cold']:.0f}x")
    return res


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default="BENCH_serving.json")
    ap.add_argument("--quick", action="store_true",
                    help="fewer samples + one batcher tier (CI artifact run)")
    args = ap.parse_args()
    main(args.json, quick=args.quick)
