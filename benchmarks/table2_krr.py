"""Paper Table 2: large-scale KRR — RMSE + fit time for Exact KRR vs Random
Fourier Features vs WLSH, on synthetic stand-ins matching the UCI datasets'
dimensionality (offline container; see repro/data/regression.py).

The paper's qualitative claims reproduced here:
  * WLSH ~ exact-KRR accuracy at a fraction of the time on mid-size data;
  * exact KRR is infeasible at Forest-Cover scale while WLSH still runs;
  * WLSH beats RFF accuracy when RFF's feature budget is memory-capped.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import (WLSHKernelSpec, exact_krr_fit, exact_krr_predict,
                        get_bucket_fn, laplace_kernel, rff_krr_fit,
                        rff_krr_predict, wlsh_krr_fit, wlsh_krr_predict)
from repro.data import make_regression_dataset

from .common import emit

# (dataset, scale, m, D_rff, exact feasible at this scale?)
DEFAULT_GRID = [
    ("wine", 0.25, 450, 1024, True),
    ("insurance", 0.25, 250, 1024, True),
    ("ct_slices", 0.03, 64, 512, False),
    ("forest", 0.004, 64, 256, False),
]


def _rmse(a, b):
    return float(jnp.sqrt(jnp.mean((a - b) ** 2)))


def _median_dists(x, key, k=256):
    """Median L1 and L2 pairwise distance on a subsample — the standard
    'median heuristic' anchors each kernel's lengthscale to ITS geometry
    (Laplace/WLSH live on L1, the RFF Gaussian on L2)."""
    idx = jax.random.choice(key, x.shape[0], (min(k, x.shape[0]),),
                            replace=False)
    xs = x[idx]
    diff = xs[:, None, :] - xs[None, :, :]
    l1 = jnp.median(jnp.sum(jnp.abs(diff), -1))
    l2 = jnp.median(jnp.sqrt(jnp.sum(diff * diff, -1)))
    return float(l1), float(l2)


def run(grid=DEFAULT_GRID, lam: float = 0.5, seed: int = 0):
    rows = []
    for name, scale, m, d_rff, exact_ok in grid:
        xtr, ytr, xte, yte = make_regression_dataset(name, seed, scale=scale)
        row = {"dataset": name, "n": int(xtr.shape[0]), "d": int(xtr.shape[1])}
        l1, l2 = _median_dists(xtr, jax.random.PRNGKey(seed + 3))
        ell1, ell2 = l1 / 2.0, l2  # e^{-L1/ell}: ~e^-2 at median; RFF ~e^-1

        if exact_ok:
            t0 = time.perf_counter()
            kern = lambda a, b: laplace_kernel(a, b, ell1)
            beta = exact_krr_fit(kern, xtr, ytr, lam)
            jax.block_until_ready(beta)
            row["exact_time"] = time.perf_counter() - t0
            row["exact_rmse"] = _rmse(exact_krr_predict(kern, xtr, beta, xte),
                                      yte)
        else:
            row["exact_time"] = float("nan")
            row["exact_rmse"] = float("nan")

        t0 = time.perf_counter()
        rmod = rff_krr_fit(jax.random.PRNGKey(seed + 1), xtr, ytr,
                           n_features=d_rff, lam=lam, lengthscale=ell2)
        jax.block_until_ready(rmod.alpha)
        row["rff_time"] = time.perf_counter() - t0
        row["rff_rmse"] = _rmse(rff_krr_predict(rmod, xte), yte)

        t0 = time.perf_counter()
        spec = WLSHKernelSpec(bucket=get_bucket_fn("rect"), lengthscale=ell1)
        wmod = wlsh_krr_fit(jax.random.PRNGKey(seed + 2), xtr, ytr, spec,
                            m=m, lam=lam)
        jax.block_until_ready(wmod.beta)
        row["wlsh_time"] = time.perf_counter() - t0
        row["wlsh_rmse"] = _rmse(wlsh_krr_predict(wmod, xte), yte)
        rows.append(row)
    return rows


def main(grid=DEFAULT_GRID) -> None:
    rows = run(grid)
    print("dataset,n,d,exact_rmse,exact_s,rff_rmse,rff_s,wlsh_rmse,wlsh_s")
    for r in rows:
        print(f"{r['dataset']},{r['n']},{r['d']},{r['exact_rmse']:.4f},"
              f"{r['exact_time']:.2f},{r['rff_rmse']:.4f},{r['rff_time']:.2f},"
              f"{r['wlsh_rmse']:.4f},{r['wlsh_time']:.2f}")
    emit("table2_krr", 0.0, f"datasets={len(rows)}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="larger scales (minutes on CPU)")
    a = ap.parse_args()
    grid = DEFAULT_GRID
    if a.full:
        grid = [(n, min(1.0, s * 10), m * 2, d * 2, ok)
                for n, s, m, d, ok in DEFAULT_GRID]
    main(grid)
