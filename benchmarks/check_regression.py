"""Perf regression gates: matvec + serving + hash-join distributed +
sharded serving + self-healing lifecycle.

Reruns the matvec benchmark section at the sizes recorded in the committed
``BENCH_matvec.json`` and fails when ``reference_us`` or ``fused_us``
regresses more than ``factor`` (default 1.3x) against the baseline row for
the same n; likewise reruns the serving warm/cached single-query sections
against ``BENCH_serving.json`` (``warm_p50_us``, ``cached_p50_us``).
Exposed two ways:

    PYTHONPATH=src python -m benchmarks.check_regression [--baseline PATH]
        [--serving-baseline PATH]
    PYTHONPATH=src python -m pytest tests/test_bench_regression.py --runslow

Comparisons are skipped (not failed) when the baseline was recorded on a
different platform — a CPU-committed baseline says nothing about TPU timings.
On the same platform, baseline timings are rescaled by the ratio of a fixed
calibration workload (``bench_matvec.calibration_us``, stored in the
baseline) measured fresh vs at commit time, so a uniformly slower/faster
machine does not trip (or mask) the gate.
"""
from __future__ import annotations

import argparse
import json
import pathlib

DEFAULT_BASELINE = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_matvec.json"
DEFAULT_SERVING_BASELINE = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_serving.json"
DEFAULT_FACTOR = 1.3
# serving latencies are single-digit-us dict probes and sub-ms jit dispatch:
# proportionally noisier than the matvec timing loops, so the gate is looser
SERVING_FACTOR = 2.0
# distributed timings come from subprocess fake-CPU meshes (noisier still)
DIST_FACTOR = 2.0
# sharded serving: subprocess fake-CPU mesh, same noise class as distributed
SHARDED_FACTOR = 2.0
# lifecycle: in-process single-query loops, same noise class as serving
LIFECYCLE_FACTOR = 2.0
# acceptance pin (DESIGN.md §12): post-swap p50 vs steady p50 — a pure
# ratio measured back-to-back in the same process, so machine speed cancels
SWAP_RATIO_MAX = 2.0
# acceptance pin (DESIGN.md §10): sharded warm p50 vs single-host warm p50
# AT THE SAME BATCH IN THE SAME CHILD — a ratio, so machine speed cancels
SHARDED_RATIO_MAX = 3.0
CHECKED_KEYS = ("reference_us", "fused_us")
SERVING_KEYS = ("warm_p50_us", "cached_p50_us")


def check(baseline_path=DEFAULT_BASELINE, factor: float = DEFAULT_FACTOR,
          repeats: int = 3):
    """Returns (failures, fresh_rows).  Empty failures == no regression."""
    import jax

    from . import bench_matvec

    with open(baseline_path) as fh:
        base = json.load(fh)
    if base.get("platform") != jax.default_backend():
        return [], []  # cross-platform baseline: nothing comparable
    base_rows = {r["n"]: r for r in base["rows"]}
    # machine-speed normalization, loosening only: a slower checking host
    # scales the committed timings up; a transiently "fast" calibration must
    # never tighten the gate (on shared containers noise is bursty, and the
    # calibration and the timings can land in different bursts)
    scale = 1.0
    if base.get("calib_us"):
        scale = max(1.0, bench_matvec.calibration_us() / base["calib_us"])
    # min over several well-separated passes, gated keys only: noise bursts
    # on shared CPU containers last seconds — longer than one timing loop —
    # so a single min-of-N can be entirely burst-contaminated; repeats
    # spread the samples over minutes
    ns = tuple(sorted(base_rows))
    best: dict = {}
    rows = []
    for _ in range(repeats):
        rows = bench_matvec.run(ns=ns, timing_iters=10, timing_stat="min",
                                with_dense=False, with_pallas=False,
                                with_pcg=False)
        for row in rows:
            for key in CHECKED_KEYS:
                if row.get(key):
                    cur = best.get((row["n"], key))
                    best[(row["n"], key)] = (row[key] if cur is None
                                             else min(cur, row[key]))
    failures = []
    for (n, key), new in sorted(best.items()):
        old = base_rows[n].get(key)
        if not old:
            continue  # key absent/None in the baseline: nothing to compare
        if new > factor * old * scale:
            failures.append(
                f"n={n}: {key} {new:.0f}us > {factor:.2f}x "
                f"baseline {old:.0f}us (machine scale {scale:.2f})")
    for row in rows:  # report the best-of-passes numbers
        for key in CHECKED_KEYS:
            if (row["n"], key) in best:
                row[key] = best[(row["n"], key)]
    return failures, rows


def check_serving(baseline_path=DEFAULT_SERVING_BASELINE,
                  factor: float = SERVING_FACTOR, repeats: int = 3):
    """Serving-latency gate: (failures, fresh) where ``fresh`` maps each of
    SERVING_KEYS to the best-of-``repeats`` remeasurement.  Same platform
    skip + calibration scaling as the matvec gate; the batcher tiers are NOT
    re-run (offered-load QPS on a shared runner is weather, not signal)."""
    import jax

    from . import bench_matvec, bench_serving

    with open(baseline_path) as fh:
        base = json.load(fh)
    if base.get("platform") != jax.default_backend():
        return [], {}
    scale = 1.0
    if base.get("calib_us"):
        scale = max(1.0, bench_matvec.calibration_us() / base["calib_us"])
    # one fit/export/compile, ``repeats`` interleaved measurement passes
    res = bench_serving.run(iters=100, batch_requests=0, offered_qps=(),
                            repeats=repeats)
    best = {key: res[key] for key in SERVING_KEYS}
    failures = []
    for key, new in sorted(best.items()):
        old = base.get(key)
        if not old:
            continue
        if new > factor * old * scale:
            failures.append(f"serving {key} {new:.0f}us > {factor:.2f}x "
                            f"baseline {old:.0f}us (machine scale "
                            f"{scale:.2f})")
    return failures, best


def check_distributed(baseline_path=DEFAULT_BASELINE,
                      factor: float = DIST_FACTOR):
    """Hash-join fast-path gate: (failures, fresh_rows).

    Reruns the distributed benchmark section (subprocess fake-CPU meshes) at
    the (n, shards) cells recorded in the committed baseline and fails when:

    * ``hashjoin_iter_us`` regresses more than ``factor`` against the
      baseline cell (calibration-rescaled, like the matvec gate), or
    * a baseline cell carries ``hashjoin_prefuse_iter_us`` (the pre-fusion
      routing cost carried forward at the fusion PR) and the fresh time is
      not at least 2x below it — the fused route kernels' floor, or
    * ``hashjoin_k8_percol_ratio`` >= 2.0 — a k=8 RHS block must cost less
      than 2x a single-RHS iteration per column (the multi-RHS payload
      amortization contract).

    Subprocess timings on shared runners are noisier than in-process loops,
    hence the looser default factor.  Error-marker baseline rows and rows
    missing from the fresh run are skipped, not failed (a runner that
    cannot spawn N fake devices says nothing about the code)."""
    import jax

    from . import bench_matvec

    with open(baseline_path) as fh:
        base = json.load(fh)
    if base.get("platform") != jax.default_backend():
        return [], []
    base_cells = {(r["n"], r["shards"]): r
                  for r in base.get("distributed", []) if "error" not in r}
    if not base_cells:
        return [], []
    scale = 1.0
    if base.get("calib_us"):
        scale = max(1.0, bench_matvec.calibration_us() / base["calib_us"])
    ns = tuple(sorted({n for n, _ in base_cells}))
    shard_counts = tuple(sorted({s for _, s in base_cells}))
    fresh = bench_matvec.distributed_rows(ns=ns, shard_counts=shard_counts)
    failures = []
    for r in fresh:
        if "error" in r:
            continue
        cell = base_cells.get((r["n"], r["shards"]))
        if cell is None:
            continue
        old = cell.get("hashjoin_iter_us")
        new = r.get("hashjoin_iter_us")
        if old and new and new > factor * old * scale:
            failures.append(
                f"dist n={r['n']} shards={r['shards']}: hashjoin_iter_us "
                f"{new:.0f}us > {factor:.2f}x baseline {old:.0f}us "
                f"(machine scale {scale:.2f})")
        prefuse = cell.get("hashjoin_prefuse_iter_us")
        if prefuse and new and new > prefuse * scale / 2.0:
            failures.append(
                f"dist n={r['n']} shards={r['shards']}: hashjoin_iter_us "
                f"{new:.0f}us not >= 2x below pre-fusion "
                f"{prefuse:.0f}us (machine scale {scale:.2f})")
        ratio = r.get("hashjoin_k8_percol_ratio")
        if ratio is not None and ratio >= 2.0:
            failures.append(
                f"dist n={r['n']} shards={r['shards']}: k=8 per-column "
                f"cost {ratio:.2f}x single-RHS (must be < 2x)")
    return failures, fresh


def check_sharded_serving(baseline_path=DEFAULT_SERVING_BASELINE,
                          factor: float = SHARDED_FACTOR,
                          repeats: int = 3):
    """Sharded-serving gate (serving-multidevice CI job): (failures, fresh).

    Re-measures the sharded section (ShardedPredictor on a fake-CPU 2x2
    mesh, subprocess) against the committed ``BENCH_serving.json``
    ``"sharded"`` block and fails when:

    * ``warm_p50_us`` regresses more than ``factor`` against the baseline
      (calibration-rescaled, like every other gate), or
    * ``ratio_vs_single`` exceeds ``SHARDED_RATIO_MAX`` — the sharded tier's
      structural acceptance pin: batch-64 warm p50 must stay within 3x of
      the single-host warm p50 measured in the SAME child process (a pure
      ratio, immune to machine speed).

    Skipped (not failed) on a cross-platform baseline, a baseline recorded
    with an error marker, or a fresh measurement whose subprocess could not
    spawn the fake mesh — none of those say anything about the code."""
    import jax

    from . import bench_matvec, bench_serving

    with open(baseline_path) as fh:
        base = json.load(fh)
    if base.get("platform") != jax.default_backend():
        return [], {}
    cell = base.get("sharded") or {}
    if not cell or "error" in cell:
        return [], {}
    scale = 1.0
    if base.get("calib_us"):
        scale = max(1.0, bench_matvec.calibration_us() / base["calib_us"])
    fresh = bench_serving.sharded_section(repeats=repeats)
    if "error" in fresh:
        return [], fresh
    failures = []
    old, new = cell.get("warm_p50_us"), fresh.get("warm_p50_us")
    if old and new and new > factor * old * scale:
        failures.append(
            f"sharded warm_p50_us {new:.0f}us > {factor:.2f}x baseline "
            f"{old:.0f}us (machine scale {scale:.2f})")
    ratio = fresh.get("ratio_vs_single")
    if ratio is not None and ratio > SHARDED_RATIO_MAX:
        failures.append(
            f"sharded warm p50 {ratio:.2f}x single-host warm p50 "
            f"(must be <= {SHARDED_RATIO_MAX:.1f}x; sharded "
            f"{fresh['warm_p50_us']:.0f}us vs single "
            f"{fresh['single_warm_p50_us']:.0f}us)")
    return failures, fresh


def check_lifecycle(baseline_path=DEFAULT_SERVING_BASELINE,
                    factor: float = LIFECYCLE_FACTOR,
                    repeats: int = 3):
    """Self-healing-runtime gate (CI chaos job): (failures, fresh).

    Re-measures the lifecycle section (live swap + forced rollback on a
    flat version root, in-process) against the committed
    ``BENCH_serving.json`` ``"lifecycle"`` block and fails when:

    * ``swap_compile_delta`` != 0 — the hard structural pin: a live version
      swap must reuse the warm jit caches, never recompile serving buckets
      (an exact integer, immune to machine speed), or
    * ``swap_p50_ratio`` exceeds ``SWAP_RATIO_MAX`` — post-swap single-query
      p50 vs steady p50 measured back-to-back in the same process (a pure
      ratio), or
    * ``rollback_to_healthy_us`` regresses more than ``factor`` against the
      baseline (calibration-rescaled, like every other timing gate).

    Skipped (not failed) on a cross-platform baseline, an error-marker
    baseline cell, or a fresh measurement that errored."""
    import jax

    from . import bench_matvec, bench_serving

    with open(baseline_path) as fh:
        base = json.load(fh)
    if base.get("platform") != jax.default_backend():
        return [], {}
    cell = base.get("lifecycle") or {}
    if not cell or "error" in cell:
        return [], {}
    scale = 1.0
    if base.get("calib_us"):
        scale = max(1.0, bench_matvec.calibration_us() / base["calib_us"])
    fresh = bench_serving.lifecycle_section(repeats=repeats)
    if "error" in fresh:
        return [], fresh
    failures = []
    delta = fresh.get("swap_compile_delta")
    if delta:
        failures.append(
            f"lifecycle swap_compile_delta {delta} != 0 — a live swap "
            f"recompiled warm serving buckets")
    ratio = fresh.get("swap_p50_ratio")
    if ratio is not None and ratio > SWAP_RATIO_MAX:
        failures.append(
            f"lifecycle post-swap p50 {ratio:.2f}x steady p50 (must be <= "
            f"{SWAP_RATIO_MAX:.1f}x; post-swap "
            f"{fresh['post_swap_p50_us']:.0f}us vs steady "
            f"{fresh['steady_p50_us']:.0f}us)")
    old = cell.get("rollback_to_healthy_us")
    new = fresh.get("rollback_to_healthy_us")
    if old and new and new > factor * old * scale:
        failures.append(
            f"lifecycle rollback_to_healthy_us {new:.0f}us > {factor:.2f}x "
            f"baseline {old:.0f}us (machine scale {scale:.2f})")
    return failures, fresh


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default=str(DEFAULT_BASELINE))
    ap.add_argument("--serving-baseline", default=str(DEFAULT_SERVING_BASELINE))
    ap.add_argument("--factor", type=float, default=DEFAULT_FACTOR)
    ap.add_argument("--serving-factor", type=float, default=SERVING_FACTOR)
    ap.add_argument("--distributed", action="store_true",
                    help="also gate the hash-join distributed section "
                         "(spawns fake-CPU-mesh subprocesses; minutes-scale)")
    ap.add_argument("--distributed-only", action="store_true",
                    help="run ONLY the distributed gate (CI multidevice job)")
    ap.add_argument("--distributed-factor", type=float, default=DIST_FACTOR)
    ap.add_argument("--sharded", action="store_true",
                    help="also gate the sharded-serving section (spawns a "
                         "fake-CPU-mesh subprocess; minutes-scale)")
    ap.add_argument("--sharded-only", action="store_true",
                    help="run ONLY the sharded-serving gate (CI "
                         "serving-multidevice job)")
    ap.add_argument("--sharded-factor", type=float, default=SHARDED_FACTOR)
    ap.add_argument("--lifecycle", action="store_true",
                    help="also gate the self-healing lifecycle section "
                         "(in-process swap + rollback measurement)")
    ap.add_argument("--lifecycle-only", action="store_true",
                    help="run ONLY the lifecycle gate (CI chaos job)")
    ap.add_argument("--lifecycle-factor", type=float,
                    default=LIFECYCLE_FACTOR)
    args = ap.parse_args(argv)
    only = (args.distributed_only or args.sharded_only
            or args.lifecycle_only)
    failures = []
    rows = []
    if not only:
        failures, rows = check(args.baseline, args.factor)
        if not rows:
            print("[check_regression] matvec baseline platform differs — "
                  "skipped")
    for row in rows:
        print(f"[check_regression] n={row['n']}: "
              f"reference_us={row['reference_us']:.0f} "
              f"fused_us={row['fused_us']:.0f}")
    if ((args.distributed or args.distributed_only)
            and not args.sharded_only and not args.lifecycle_only):
        dfail, dfresh = check_distributed(args.baseline,
                                          args.distributed_factor)
        failures += dfail
        if not dfresh:
            print("[check_regression] distributed baseline absent or "
                  "platform differs — skipped")
        for r in dfresh:
            if "error" in r:
                print(f"[check_regression] dist shards={r['shards']}: "
                      f"measurement FAILED {r['error'][:120]}")
            else:
                print(f"[check_regression] dist n={r['n']} "
                      f"shards={r['shards']}: "
                      f"hashjoin_iter_us={r['hashjoin_iter_us']:.0f} "
                      f"psum_iter_us={r['psum_iter_us']:.0f}")
    if not only and pathlib.Path(args.serving_baseline).exists():
        sfail, sbest = check_serving(args.serving_baseline,
                                     args.serving_factor)
        failures += sfail
        if not sbest:
            print("[check_regression] serving baseline platform differs — "
                  "skipped")
        else:
            print("[check_regression] serving: " +
                  " ".join(f"{k}={v:.0f}us" for k, v in sorted(sbest.items())))
    if ((args.sharded or args.sharded_only) and not args.lifecycle_only
            and pathlib.Path(args.serving_baseline).exists()):
        shfail, shfresh = check_sharded_serving(args.serving_baseline,
                                                args.sharded_factor)
        failures += shfail
        if not shfresh:
            print("[check_regression] sharded baseline absent or platform "
                  "differs — skipped")
        elif "error" in shfresh:
            print(f"[check_regression] sharded measurement FAILED "
                  f"{shfresh['error'][:120]} — skipped")
        else:
            print(f"[check_regression] sharded {shfresh['mesh']}: "
                  f"warm_p50_us={shfresh['warm_p50_us']:.0f} "
                  f"ratio_vs_single={shfresh['ratio_vs_single']:.2f}")
    if ((args.lifecycle or args.lifecycle_only) and not args.sharded_only
            and pathlib.Path(args.serving_baseline).exists()):
        lfail, lfresh = check_lifecycle(args.serving_baseline,
                                        args.lifecycle_factor)
        failures += lfail
        if not lfresh:
            print("[check_regression] lifecycle baseline absent or platform "
                  "differs — skipped")
        elif "error" in lfresh:
            print(f"[check_regression] lifecycle measurement FAILED "
                  f"{lfresh['error'][:120]} — skipped")
        else:
            print(f"[check_regression] lifecycle: "
                  f"swap_compile_delta={lfresh['swap_compile_delta']} "
                  f"swap_p50_ratio={lfresh['swap_p50_ratio']:.2f} "
                  f"rollback_to_healthy_us="
                  f"{lfresh['rollback_to_healthy_us']:.0f}")
    if failures:
        for f in failures:
            print(f"[check_regression] REGRESSION {f}")
        return 1
    print("[check_regression] ok")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
