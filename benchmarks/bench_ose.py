"""Thm 11 / Thm 12: OSE spectral error vs the number of WLSH instances m.

Measures eps(m) = ||(K+lam I)^{-1/2}(K~+lam I)(K+lam I)^{-1/2} - I||_2 on
(a) a generic uniform dataset and (b) the Thm-12 adversarial two-cluster
dataset (x = +-lam/n e_1), confirming eps ~ c / sqrt(m) and that the
adversarial set needs ~n/lam more instances (the lower bound's content)."""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import (GammaPDF, featurize, get_bucket_fn,
                        laplace_kernel, make_wlsh_kernel, sample_lsh_params)
from repro.core.wlsh import exact_kernel_matrix

from .common import emit


def spectral_eps(k_true, k_est, lam):
    n = k_true.shape[0]
    evals, evecs = np.linalg.eigh(k_true + lam * np.eye(n))
    zinv = evecs @ np.diag(evals ** -0.5) @ evecs.T
    mat = zinv @ (np.asarray(k_est) + lam * np.eye(n)) @ zinv - np.eye(n)
    return float(np.linalg.norm(mat, 2))


def eps_curve(x, lam, ms, seed=0):
    d = x.shape[1]
    f = get_bucket_fn("rect")
    k_true = np.asarray(laplace_kernel(x, x))
    out = []
    for m in ms:
        params = sample_lsh_params(jax.random.PRNGKey(seed + m), m, d,
                                   GammaPDF(2.0, 1.0))
        k_est = exact_kernel_matrix(featurize(params, f, x))
        out.append(spectral_eps(k_true, k_est, lam))
    return out


def run(n: int = 128, lam: float = 1.0, ms=(32, 128, 512, 2048), seed=0):
    key = jax.random.PRNGKey(seed)
    x_gen = jax.random.uniform(key, (n, 3)) * 2.0
    gen = eps_curve(x_gen, lam, ms, seed)

    # Thm 12 adversarial dataset: two clusters at +-lam/n on coordinate 1
    x_adv = jnp.zeros((n, 3)).at[: n // 2, 0].set(-lam / n).at[n // 2:, 0].set(
        lam / n)
    adv = eps_curve(x_adv, lam, ms, seed + 1)
    return ms, gen, adv


def main() -> None:
    ms, gen, adv = run()
    print("m,eps_generic,eps_adversarial")
    for m, g, a in zip(ms, gen, adv):
        print(f"{m},{g:.4f},{a:.4f}")
    # eps should decay ~ 1/sqrt(m): check exponent on the generic set
    slope = np.polyfit(np.log(ms), np.log(gen), 1)[0]
    emit("bench_ose", 0.0,
         f"generic_decay_exponent={slope:.2f} (-0.5 = matrix-Chernoff rate);"
         f" adversarial/generic_eps_at_max_m={adv[-1] / max(gen[-1], 1e-9):.1f}x")


if __name__ == "__main__":
    main()
