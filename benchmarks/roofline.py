"""Render the §Roofline table from dry-run JSONL reports.

    PYTHONPATH=src python -m repro.launch.dryrun --mesh pod --out reports/pod.jsonl
    PYTHONPATH=src python -m benchmarks.roofline reports/pod.jsonl
"""
from __future__ import annotations

import json
import sys


def load(path: str) -> list[dict]:
    rows = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if line:
                rows.append(json.loads(line))
    # keep the newest entry per cell name
    by_name = {}
    for r in rows:
        by_name[r["name"]] = r
    return list(by_name.values())


def render(rows: list[dict]) -> str:
    hdr = ("| cell | chips | t_compute | t_memory | t_collective | dominant | "
           "GB/dev | useful/HLO | roofline |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in sorted(rows, key=lambda r: r["name"]):
        gb = (r.get("arg_bytes_per_device", 0) +
              r.get("temp_bytes_per_device", 0)) / 1e9
        lines.append(
            f"| {r['name']} | {r['chips']} | {r['t_compute_s']:.3e} | "
            f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | "
            f"{r['dominant']} | {gb:.1f} | {r['useful_flop_frac']:.2f} | "
            f"{r['roofline_frac']:.3f} |")
    return "\n".join(lines)


def main() -> None:
    paths = sys.argv[1:] or ["reports/pod.jsonl"]
    for p in paths:
        try:
            print(render(load(p)))
        except FileNotFoundError:
            print(f"(no report at {p} — run repro.launch.dryrun with --out)")


if __name__ == "__main__":
    main()
