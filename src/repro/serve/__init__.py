"""Online serving for fitted WLSH-KRR models (DESIGN.md §8).

Layered as artifact (disk format) -> predictor (warm jit path + bucket-exact
cache) -> batcher (request coalescing); ``repro.launch.krr_serve`` is the
driver that strings them together.  Degraded-mode behavior (shedding,
deadlines, worker-crash propagation, health) is in DESIGN.md §9; the
structured serving errors re-export here for callers.
"""
from ..errors import (DeadlineExceeded, InvalidRequest, Overloaded,
                      ServingError, WorkerCrashed)
from .artifact import (ARTIFACT_FORMAT, LoadedArtifact,
                       LoadedShardedArtifact, Normalization, export_artifact,
                       export_artifact_sharded, load_artifact,
                       load_artifact_sharded)
from .batcher import MicroBatcher
from .cache import BucketKeyFn, PredictionCache
from .predictor import Predictor, bucket_sizes, padding_bucket
from .sharded import ShardedPredictor, parse_mesh_shape
