"""Online serving for fitted WLSH-KRR models (DESIGN.md §8).

Layered as artifact (disk format) -> predictor (warm jit path + bucket-exact
cache) -> batcher (request coalescing) -> lifecycle (version watching, canary
swap, rollback, worker supervision); ``repro.launch.krr_serve`` is the driver
that strings them together.  Degraded-mode behavior (shedding, deadlines,
worker-crash propagation, health) is in DESIGN.md §9, the self-healing loop in
§12; the structured serving errors re-export here for callers.
"""
from ..errors import (CircuitOpen, DeadlineExceeded, InvalidRequest,
                      Overloaded, ServingError, WorkerCrashed)
from .artifact import (ARTIFACT_FORMAT, GOLDEN_QUERIES, GOLDEN_TOL,
                       LoadedArtifact, LoadedShardedArtifact, Normalization,
                       export_artifact, export_artifact_sharded,
                       load_artifact, load_artifact_sharded)
from .batcher import MicroBatcher
from .cache import BucketKeyFn, PredictionCache
from .lifecycle import (CircuitBreaker, LifecycleConfig, ServingRuntime,
                        SupervisedBatcher, discover_versions, version_dir)
from .predictor import Predictor, bucket_sizes, padding_bucket
from .sharded import ShardedPredictor, parse_mesh_shape
