"""Serving artifacts: a fitted WLSH-KRR model as an on-disk, versioned thing.

Export writes everything prediction needs — the m LSH instances (widths,
offsets, hash coefficients), the bucket-load tables, the bucket-fn name and
table geometry, optional input/output normalization stats, and the fit
provenance (backend, preconditioner, CG stats) — through the checkpoint
store's atomic tmp-dir + rename layout, so a crash mid-export can never leave
a loadable half-artifact.  The checkpoint "step" slot carries the artifact
FORMAT version: ``latest_step`` discovery then naturally picks the newest
format a writer produced, and a loader refuses formats newer than it knows.

Load rebuilds the exact ``WLSHKRRModel`` plus its operator on any backend
(all backends read the same tables — see core/operator.py), after validating
every array shape against the metadata manifest and the metadata against
itself (bucket fn exists, table_size is a power of two and matches the
tables, LSH arrays agree on (m, d)).  Round-trip is bitwise: arrays go
through npz untouched.
"""
from __future__ import annotations

import os
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from .. import obs
from ..checkpoint import restore_checkpoint, save_checkpoint
from ..checkpoint.store import atomic_write_json, latest_step
from ..core.bucket_fns import BUCKET_FNS
from ..core.krr import WLSHKRRModel, model_operator
from ..core.lsh import LSHParams
from ..core.operator import WLSHOperator

ARTIFACT_FORMAT = 1          # bump on any layout/meta change
_DTYPES = {"lsh_w": np.float32, "lsh_z": np.float32,
           "lsh_r1": np.uint32, "lsh_r2": np.uint32,
           "beta": np.float32, "tables": np.float32,
           "x_mean": np.float32, "x_std": np.float32,
           "y_mean": np.float32, "y_std": np.float32}


class Normalization(NamedTuple):
    """Optional request/response normalization baked into an artifact.

    The predictor applies ``(x - x_mean) / x_std`` before featurization and
    ``yhat * y_std + y_mean`` after readout — the stats travel with the model
    so every replica serves identically without a side channel.
    """

    x_mean: np.ndarray   # (d,)
    x_std: np.ndarray    # (d,)
    y_mean: float
    y_std: float


class LoadedArtifact(NamedTuple):
    artifact_id: str
    model: WLSHKRRModel
    operator: WLSHOperator   # rebuilt on the requested (or recorded) backend
    norm: Normalization | None
    meta: dict


def _model_arrays(model: WLSHKRRModel, *,
                  include_beta: bool) -> dict[str, np.ndarray]:
    tables = np.asarray(model.tables, np.float32)
    # prediction never reads beta (readout is lsh params + tables only); it
    # is O(n_train * k) — the one artifact array that scales with the
    # TRAINING set — so serving replicas can drop it.  A zero-row stand-in
    # keeps the manifest/validation shape contract (column count must still
    # match the tables' RHS block).
    beta = (np.asarray(model.beta, np.float32) if include_beta
            else np.zeros((0,) + tables.shape[2:], np.float32))
    return {"lsh_w": np.asarray(model.lsh.w, np.float32),
            "lsh_z": np.asarray(model.lsh.z, np.float32),
            "lsh_r1": np.asarray(model.lsh.r1, np.uint32),
            "lsh_r2": np.asarray(model.lsh.r2, np.uint32),
            "beta": beta,
            "tables": tables}


GOLDEN_QUERIES = 16          # default canary set size captured at export
GOLDEN_TOL = 1e-4            # default agreement tolerance (covers backend /
                             # mesh reassociation; real corruption is O(0.1))
_GOLDEN_SEED = 1053


def _golden_block(model: WLSHKRRModel, norm: Normalization | None, *,
                  k: int, x=None, tol: float) -> dict:
    """Canary golden set: ``k`` query points + the model's own predictions.

    Captured at EXPORT time so canary validation at serve time needs no
    training data: a reloading runtime replays ``x`` through the candidate
    and rejects it unless the predictions agree with ``y`` within ``tol``
    and are finite.  ``x`` defaults to synthetic points in the repo's
    canonical [0, 2) box from a fixed seed — the canary checks artifact
    INTEGRITY (bitrot, torn/mixed pieces, wrong-backend numerics), which any
    deterministic query set witnesses; pass training rows for a
    distribution-faithful set.  Outputs go through the same normalize ->
    featurize/readout -> denormalize pipeline the predictor serves."""
    d = int(model.lsh.d)
    if x is None:
        rng = np.random.default_rng(_GOLDEN_SEED)
        x = rng.uniform(0.0, 2.0, size=(k, d)).astype(np.float32)
    else:
        x = np.asarray(x, np.float32)
        if x.ndim != 2 or x.shape[1] != d:
            raise ValueError(f"golden_x must be (k, {d}), got {x.shape}")
        x = x[:k] if k else x
    xq = x
    if norm is not None:
        xq = ((x - np.asarray(norm.x_mean, np.float32))
              / np.asarray(norm.x_std, np.float32)).astype(np.float32)
    op = model_operator(model)
    y = np.asarray(op.predict_from_buckets(
        op.featurize_buckets(jnp.asarray(xq)), model.tables))
    if norm is not None:
        y = y * np.float32(norm.y_std) + np.float32(norm.y_mean)
    return {"x": x.tolist(), "y": np.asarray(y, np.float32).tolist(),
            "tol": float(tol)}


def export_artifact(directory: str, model: WLSHKRRModel, *,
                    artifact_id: str | None = None,
                    norm: Normalization | None = None,
                    extra_meta: dict | None = None,
                    include_beta: bool = True,
                    golden_queries: int = GOLDEN_QUERIES,
                    golden_x=None, golden_tol: float = GOLDEN_TOL) -> str:
    """Atomically write ``model`` (+ optional normalization) to ``directory``.

    Returns the artifact id (defaults to the directory basename).  The write
    goes through ``checkpoint.save_checkpoint`` at step ``ARTIFACT_FORMAT``.
    ``include_beta=False`` drops the training solution from the artifact —
    serving needs only the LSH params and tables, and beta is the one array
    that scales with the training-set size.

    ``golden_queries`` canary points + their predictions ride the meta (see
    ``_golden_block``); ``golden_queries=0`` opts out.  The meta also carries
    a monotonically increasing ``export_version`` (previous export's + 1) so
    a reload watcher can tell a re-publish from the version it already
    serves.
    """
    with obs.span("io.export_artifact",
                  to_histogram=obs.histogram(
                      "io_artifact_export_us",
                      "artifact export wall time")):
        return _export_artifact(directory, model, artifact_id=artifact_id,
                                norm=norm, extra_meta=extra_meta,
                                include_beta=include_beta,
                                golden_queries=golden_queries,
                                golden_x=golden_x, golden_tol=golden_tol)


def _export_artifact(directory: str, model: WLSHKRRModel, *,
                     artifact_id: str | None, norm: Normalization | None,
                     extra_meta: dict | None, include_beta: bool,
                     golden_queries: int = GOLDEN_QUERIES,
                     golden_x=None, golden_tol: float = GOLDEN_TOL) -> str:
    arrays = _model_arrays(model, include_beta=include_beta)
    if norm is not None:
        arrays["x_mean"] = np.asarray(norm.x_mean, np.float32).reshape(-1)
        arrays["x_std"] = np.asarray(norm.x_std, np.float32).reshape(-1)
        arrays["y_mean"] = np.asarray(norm.y_mean, np.float32).reshape(())
        arrays["y_std"] = np.asarray(norm.y_std, np.float32).reshape(())
    artifact_id = artifact_id or os.path.basename(os.path.normpath(directory))
    prev_step = latest_step(directory)
    prev_version = 0
    if prev_step is not None:
        try:
            prev_version = int(_read_meta(directory, prev_step)
                               .get("export_version", 0))
        except (OSError, ValueError):
            prev_version = 0
    meta = {"kind": "wlsh_krr_artifact",
            "format": ARTIFACT_FORMAT,
            "artifact_id": artifact_id,
            "export_version": prev_version + 1,
            "bucket_name": model.bucket_name,
            "table_size": int(model.table_size),
            "backend": model.backend,
            "precond": model.precond,
            "cg_iters": int(np.asarray(model.cg_iters)),
            "cg_resnorm": np.asarray(model.cg_resnorm).tolist(),
            "has_norm": norm is not None,
            "has_beta": include_beta,
            "arrays": {k: list(v.shape) for k, v in arrays.items()},
            **(extra_meta or {})}
    if golden_queries > 0 or golden_x is not None:
        meta["golden"] = _golden_block(model, norm, k=golden_queries,
                                       x=golden_x, tol=golden_tol)
    save_checkpoint(directory, ARTIFACT_FORMAT, arrays, meta)
    obs.counter("io_artifact_exports_total", "artifacts exported",
                labels=("kind",)).labels("single").inc()
    return artifact_id


def _validate(meta: dict, arrays: dict[str, np.ndarray]) -> None:
    if meta.get("kind") != "wlsh_krr_artifact":
        raise ValueError(f"not a serving artifact: kind={meta.get('kind')!r}")
    bucket = meta.get("bucket_name")
    if bucket not in BUCKET_FNS:
        raise ValueError(f"artifact bucket fn {bucket!r} unknown to this "
                         f"build; have {sorted(BUCKET_FNS)}")
    table_size = int(meta.get("table_size", 0))
    if table_size <= 0 or table_size & (table_size - 1):
        raise ValueError(f"table_size must be a positive power of two, "
                         f"got {table_size}")
    m, d = arrays["lsh_w"].shape
    for name in ("lsh_z", "lsh_r1", "lsh_r2"):
        if arrays[name].shape != (m, d):
            raise ValueError(f"{name}: shape {arrays[name].shape} != "
                             f"lsh_w shape {(m, d)}")
    tables = arrays["tables"]
    if tables.ndim not in (2, 3) or tables.shape[:2] != (m, table_size):
        raise ValueError(f"tables: shape {tables.shape} inconsistent with "
                         f"m={m}, table_size={table_size}")
    beta = arrays["beta"]
    if beta.shape[1:] != tables.shape[2:]:
        raise ValueError(f"beta RHS block {beta.shape} vs tables "
                         f"{tables.shape}: column counts differ")
    if not np.isfinite(tables).all():
        bad = int(np.sum(~np.isfinite(tables)))
        raise ValueError(f"tables contain {bad} non-finite entries — a "
                         f"poisoned artifact must be rejected at load, not "
                         f"served as silent NaN predictions")
    if meta.get("has_norm"):
        for name in ("x_mean", "x_std", "y_mean", "y_std"):
            if name not in arrays:
                raise ValueError(f"has_norm set but {name} missing")
        if arrays["x_mean"].shape != (d,) or arrays["x_std"].shape != (d,):
            raise ValueError(f"normalization stats shaped "
                             f"{arrays['x_mean'].shape}, expected ({d},)")


def load_artifact(directory: str, *, backend: str | None = None,
                  artifact_id: str | None = None, retries: int = 0,
                  retry_backoff_s: float = 0.05) -> LoadedArtifact:
    """Load + validate an artifact and rebuild its operator.

    ``backend`` overrides the recorded fit backend ('reference' | 'pallas' |
    'auto'); every backend reads the same tables, so a model fit on a TPU pod
    serves from a CPU replica unchanged.  Raises ``ValueError`` on any
    shape/metadata inconsistency and on artifact formats newer than this
    build understands.

    ``retries`` retries TRANSIENT failures only — OSError / short-read zip
    corruption from a racing writer or flaky filesystem, with exponential
    backoff starting at ``retry_backoff_s``.  Validation failures raise
    immediately: re-reading a malformed artifact cannot fix it.
    """
    import time
    import zipfile
    attempt = 0
    with obs.span("io.load_artifact",
                  to_histogram=obs.histogram(
                      "io_artifact_load_us",
                      "artifact load wall time (incl. retries)")):
        while True:
            try:
                loaded = _load_artifact_once(directory, backend=backend,
                                             artifact_id=artifact_id)
                obs.counter("io_artifact_loads_total", "artifacts loaded",
                            labels=("kind",)).labels("single").inc()
                return loaded
            except (OSError, zipfile.BadZipFile) as e:
                if attempt >= retries:
                    raise
                obs.counter("io_artifact_load_retries_total",
                            "transient artifact-load failures retried").inc()
                time.sleep(retry_backoff_s * (2 ** attempt))
                attempt += 1


def _load_artifact_once(directory: str, *, backend: str | None = None,
                        artifact_id: str | None = None) -> LoadedArtifact:
    step = latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no artifact under {directory}")
    if step > ARTIFACT_FORMAT:
        raise ValueError(f"artifact format {step} is newer than this build's "
                         f"reader (supports <= {ARTIFACT_FORMAT})")
    # template shapes come from the meta manifest; restore_checkpoint then
    # cross-checks every stored array against it
    meta = _read_meta(directory, step)
    manifest = meta.get("arrays")
    if not isinstance(manifest, dict) or "lsh_w" not in manifest:
        raise ValueError("artifact meta has no array manifest")
    template = {name: np.zeros(tuple(shape), _DTYPES.get(name, np.float32))
                for name, shape in manifest.items()}
    arrays, _, meta = restore_checkpoint(directory, template, step)
    _validate(meta, arrays)

    lsh = LSHParams(w=jnp.asarray(arrays["lsh_w"]),
                    z=jnp.asarray(arrays["lsh_z"]),
                    r1=jnp.asarray(arrays["lsh_r1"]),
                    r2=jnp.asarray(arrays["lsh_r2"]))
    model = WLSHKRRModel(lsh=lsh, bucket_name=meta["bucket_name"],
                         beta=jnp.asarray(arrays["beta"]),
                         tables=jnp.asarray(arrays["tables"]),
                         table_size=int(meta["table_size"]),
                         cg_iters=jnp.asarray(meta.get("cg_iters", 0)),
                         cg_resnorm=jnp.asarray(meta.get("cg_resnorm", 0.0)),
                         backend=meta.get("backend", "reference"),
                         precond=meta.get("precond", "none"))
    norm = None
    if meta.get("has_norm"):
        norm = Normalization(x_mean=arrays["x_mean"], x_std=arrays["x_std"],
                             y_mean=float(arrays["y_mean"]),
                             y_std=float(arrays["y_std"]))
    op = model_operator(model, backend=backend)
    return LoadedArtifact(
        artifact_id=artifact_id or meta.get("artifact_id")
        or os.path.basename(os.path.normpath(directory)),
        model=model, operator=op, norm=norm, meta=meta)


def _read_meta(directory: str, step: int) -> dict:
    import json
    with open(os.path.join(directory, f"step_{step}", "meta.json")) as fh:
        return json.load(fh)


# ---------------------------------------------------------------------------
# Sharded artifacts: one piece per (model-shard, data-shard) mesh cell
# ---------------------------------------------------------------------------
#
# A model too big for one host is exported as a GRID of pieces matching the
# serving mesh: piece (i, j) holds model-shard i's LSH slice (m_loc, d) and
# its slot-range slice of the bucket tables (m_loc, spp[, k]) with
# spp = table_size / data_shards — exactly the shard layout
# ``make_krr_step_hashjoin`` leaves the table in (P(model, data): owner j
# holds slots [j*spp, (j+1)*spp)), so a serving host only ever loads its own
# piece.  Every piece is an independent atomic checkpoint
# (checkpoint/store.py tmp+rename); the manifest.json is written LAST, also
# atomically, so a torn export (some pieces written, writer killed) is
# invisible — the loader starts from the manifest and a piece's torn
# ``step_N.tmp`` is ignored by ``latest_step`` exactly as for single-host
# artifacts.

MANIFEST_NAME = "manifest.json"


class LoadedShardedArtifact(NamedTuple):
    artifact_id: str
    model: WLSHKRRModel          # reassembled full model (beta dropped)
    operator: WLSHOperator       # rebuilt on the requested backend
    norm: Normalization | None
    mesh_shape: tuple[int, int]  # (model_shards, data_shards) of the export
    manifest: dict


def _piece_name(i: int, j: int) -> str:
    return f"shard_{i}_{j}"


def export_artifact_sharded(directory: str, model: WLSHKRRModel, *,
                            mesh_shape: tuple[int, int],
                            artifact_id: str | None = None,
                            norm: Normalization | None = None,
                            extra_meta: dict | None = None,
                            golden_queries: int = GOLDEN_QUERIES,
                            golden_x=None,
                            golden_tol: float = GOLDEN_TOL) -> str:
    """Atomically export ``model`` as a (model_shards, data_shards) piece
    grid for a sharded serving mesh.  Returns the artifact id.

    Requires ``m % model_shards == 0`` and ``table_size % data_shards == 0``.
    ``beta`` is always dropped (the serving tier never reads it — see
    ``export_artifact(include_beta=False)``); normalization stats and the
    canary golden set (``golden_queries`` points + the FULL model's
    predictions, ``golden_queries=0`` opts out) are tiny and travel in the
    manifest.  Pieces are written first (each through the
    checkpoint store's tmp+rename), the manifest last via its own atomic
    rename — a crash at ANY point leaves either the previous complete
    export or nothing loadable, never a mixed one (the manifest carries a
    per-export version cross-checked against every piece's meta).
    """
    mm, nd = int(mesh_shape[0]), int(mesh_shape[1])
    if mm <= 0 or nd <= 0:
        raise ValueError(f"mesh_shape must be positive, got {mesh_shape}")
    tables = np.asarray(model.tables, np.float32)
    m, table_size = tables.shape[:2]
    if m % mm:
        raise ValueError(f"m={m} not divisible by model_shards={mm}")
    if table_size % nd:
        raise ValueError(f"table_size={table_size} not divisible by "
                         f"data_shards={nd}")
    m_loc, spp = m // mm, table_size // nd
    artifact_id = artifact_id or os.path.basename(os.path.normpath(directory))
    prev = _read_manifest(directory)
    version = int(prev.get("export_version", 0)) + 1 if prev else 1

    lsh = {name: np.asarray(arr, _DTYPES[f"lsh_{name}"])
           for name, arr in (("w", model.lsh.w), ("z", model.lsh.z),
                             ("r1", model.lsh.r1), ("r2", model.lsh.r2))}
    common = {"kind": "wlsh_krr_sharded_piece",
              "format": ARTIFACT_FORMAT,
              "artifact_id": artifact_id,
              "export_version": version,
              "mesh_shape": [mm, nd],
              "bucket_name": model.bucket_name,
              "table_size": int(table_size),
              "m": int(m)}
    pieces = {}
    for i in range(mm):
        for j in range(nd):
            arrays = {f"lsh_{k}": v[i * m_loc:(i + 1) * m_loc]
                      for k, v in lsh.items()}
            arrays["tables"] = np.ascontiguousarray(
                tables[i * m_loc:(i + 1) * m_loc, j * spp:(j + 1) * spp])
            name = _piece_name(i, j)
            save_checkpoint(os.path.join(directory, name), ARTIFACT_FORMAT,
                            arrays,
                            {**common, "piece": [i, j],
                             "arrays": {k: list(v.shape)
                                        for k, v in arrays.items()}})
            pieces[f"{i},{j}"] = name
    manifest = {"kind": "wlsh_krr_sharded_artifact",
                "format": ARTIFACT_FORMAT,
                "artifact_id": artifact_id,
                "export_version": version,
                "mesh_shape": [mm, nd],
                "m": int(m), "table_size": int(table_size),
                "k": int(tables.shape[2]) if tables.ndim == 3 else 0,
                "bucket_name": model.bucket_name,
                "backend": model.backend,
                "precond": model.precond,
                "cg_iters": int(np.asarray(model.cg_iters)),
                "pieces": pieces,
                "has_norm": norm is not None,
                **(extra_meta or {})}
    if golden_queries > 0 or golden_x is not None:
        manifest["golden"] = _golden_block(model, norm, k=golden_queries,
                                           x=golden_x, tol=golden_tol)
    if norm is not None:
        manifest["norm"] = {
            "x_mean": np.asarray(norm.x_mean, np.float32).reshape(-1).tolist(),
            "x_std": np.asarray(norm.x_std, np.float32).reshape(-1).tolist(),
            "y_mean": float(np.float32(norm.y_mean)),
            "y_std": float(np.float32(norm.y_std))}
    _write_manifest(directory, manifest)
    obs.counter("io_artifact_exports_total", "artifacts exported",
                labels=("kind",)).labels("sharded").inc()
    return artifact_id


def _write_manifest(directory: str, manifest: dict) -> None:
    os.makedirs(directory, exist_ok=True)
    atomic_write_json(os.path.join(directory, MANIFEST_NAME), manifest)


def _read_manifest(directory: str) -> dict | None:
    import json
    path = os.path.join(directory, MANIFEST_NAME)
    if not os.path.exists(path):
        return None
    with open(path) as fh:
        return json.load(fh)


def load_artifact_sharded(directory: str, *, mesh_shape: tuple[int, int],
                          backend: str | None = None,
                          artifact_id: str | None = None, retries: int = 0,
                          retry_backoff_s: float = 0.05
                          ) -> LoadedShardedArtifact:
    """Load + validate a sharded artifact for a TARGET serving mesh.

    ``mesh_shape`` is the (model_shards, data_shards) grid the caller will
    serve on; a manifest recording a different grid is REFUSED — the piece
    slot ranges are baked into the export, so serving a 2x4 export on a 2x2
    mesh would silently merge the wrong slot ranges.  (Re-export for the new
    mesh instead; the pieces are cheap.)  Every piece's meta is cross-checked
    against the manifest (format, export version, geometry), so a torn or
    mixed export can never assemble: a piece whose atomic save was killed
    mid-write is invisible to ``latest_step`` and surfaces as a missing
    piece, and a piece from a DIFFERENT export generation fails the version
    cross-check.

    ``retries`` retries TRANSIENT failures — a missing manifest or piece
    checkpoint (a concurrent publisher still mid-export), short-read zip
    corruption — with exponential backoff from ``retry_backoff_s``, same
    contract as ``load_artifact``.  Validation failures (mixed generations,
    bad geometry, poisoned tables) raise immediately: re-reading a malformed
    export cannot fix it.
    """
    import time
    import zipfile
    attempt = 0
    while True:
        try:
            return _load_artifact_sharded_once(
                directory, mesh_shape=mesh_shape, backend=backend,
                artifact_id=artifact_id)
        except (OSError, zipfile.BadZipFile):
            if attempt >= retries:
                raise
            obs.counter("io_artifact_load_retries_total",
                        "transient artifact-load failures retried").inc()
            time.sleep(retry_backoff_s * (2 ** attempt))
            attempt += 1


def _load_artifact_sharded_once(directory: str, *,
                                mesh_shape: tuple[int, int],
                                backend: str | None = None,
                                artifact_id: str | None = None
                                ) -> LoadedShardedArtifact:
    manifest = _read_manifest(directory)
    if manifest is None:
        raise FileNotFoundError(f"no sharded artifact manifest under "
                                f"{directory}")
    if manifest.get("kind") != "wlsh_krr_sharded_artifact":
        raise ValueError(f"not a sharded artifact: "
                         f"kind={manifest.get('kind')!r}")
    fmt = int(manifest.get("format", 0))
    if fmt > ARTIFACT_FORMAT:
        raise ValueError(f"sharded artifact format {fmt} is newer than this "
                         f"build's reader (supports <= {ARTIFACT_FORMAT})")
    rec = tuple(manifest.get("mesh_shape", ()))
    want = (int(mesh_shape[0]), int(mesh_shape[1]))
    if rec != want:
        raise ValueError(
            f"sharded artifact was exported for mesh {rec}, target mesh is "
            f"{want}: piece slot ranges do not line up — re-export for the "
            f"target mesh")
    mm, nd = want
    m, table_size = int(manifest["m"]), int(manifest["table_size"])
    k = int(manifest.get("k", 0))
    m_loc, spp = m // mm, table_size // nd
    piece_shape = (m_loc, spp) + ((k,) if k else ())
    version = int(manifest.get("export_version", 1))

    lsh_parts = {name: [None] * mm for name in ("w", "z", "r1", "r2")}
    table_rows = []
    for i in range(mm):
        row = []
        for j in range(nd):
            name = manifest["pieces"].get(f"{i},{j}")
            if name is None:
                raise ValueError(f"manifest missing piece ({i},{j})")
            pdir = os.path.join(directory, name)
            step = latest_step(pdir)
            if step is None:
                raise FileNotFoundError(
                    f"sharded artifact piece ({i},{j}) has no complete "
                    f"checkpoint under {pdir} (torn export?)")
            meta = _read_meta(pdir, step)
            if (meta.get("kind") != "wlsh_krr_sharded_piece"
                    or meta.get("piece") != [i, j]
                    or int(meta.get("export_version", -1)) != version
                    or tuple(meta.get("mesh_shape", ())) != want):
                raise ValueError(
                    f"piece ({i},{j}) meta disagrees with the manifest "
                    f"(version {meta.get('export_version')} vs {version}, "
                    f"mesh {meta.get('mesh_shape')} vs {list(want)}) — "
                    f"mixed or torn export")
            d = int(meta["arrays"]["lsh_w"][1])
            template = {f"lsh_{n}": np.zeros((m_loc, d),
                                             _DTYPES[f"lsh_{n}"])
                        for n in ("w", "z", "r1", "r2")}
            template["tables"] = np.zeros(piece_shape, np.float32)
            arrays, _, _ = restore_checkpoint(pdir, template, step)
            if not np.isfinite(arrays["tables"]).all():
                raise ValueError(f"piece ({i},{j}) tables contain non-finite "
                                 f"entries — poisoned piece rejected at load")
            if j == 0:
                for n in ("w", "z", "r1", "r2"):
                    lsh_parts[n][i] = arrays[f"lsh_{n}"]
            row.append(arrays["tables"])
        table_rows.append(np.concatenate(row, axis=1))
    tables = np.concatenate(table_rows, axis=0)

    bucket = manifest.get("bucket_name")
    if bucket not in BUCKET_FNS:
        raise ValueError(f"artifact bucket fn {bucket!r} unknown to this "
                         f"build; have {sorted(BUCKET_FNS)}")
    lsh = LSHParams(w=jnp.asarray(np.concatenate(lsh_parts["w"])),
                    z=jnp.asarray(np.concatenate(lsh_parts["z"])),
                    r1=jnp.asarray(np.concatenate(lsh_parts["r1"])),
                    r2=jnp.asarray(np.concatenate(lsh_parts["r2"])))
    beta = np.zeros((0, k) if k else (0,), np.float32)
    model = WLSHKRRModel(lsh=lsh, bucket_name=bucket,
                         beta=jnp.asarray(beta), tables=jnp.asarray(tables),
                         table_size=table_size,
                         cg_iters=jnp.asarray(manifest.get("cg_iters", 0)),
                         cg_resnorm=jnp.asarray(0.0),
                         backend=manifest.get("backend", "reference"),
                         precond=manifest.get("precond", "none"))
    norm = None
    if manifest.get("has_norm"):
        nm = manifest["norm"]
        norm = Normalization(
            x_mean=np.asarray(nm["x_mean"], np.float32),
            x_std=np.asarray(nm["x_std"], np.float32),
            y_mean=float(nm["y_mean"]), y_std=float(nm["y_std"]))
    op = model_operator(model, backend=backend)
    obs.counter("io_artifact_loads_total", "artifacts loaded",
                labels=("kind",)).labels("sharded").inc()
    return LoadedShardedArtifact(
        artifact_id=artifact_id or manifest.get("artifact_id")
        or os.path.basename(os.path.normpath(directory)),
        model=model, operator=op, norm=norm, mesh_shape=want,
        manifest=manifest)
