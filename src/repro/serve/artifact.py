"""Serving artifacts: a fitted WLSH-KRR model as an on-disk, versioned thing.

Export writes everything prediction needs — the m LSH instances (widths,
offsets, hash coefficients), the bucket-load tables, the bucket-fn name and
table geometry, optional input/output normalization stats, and the fit
provenance (backend, preconditioner, CG stats) — through the checkpoint
store's atomic tmp-dir + rename layout, so a crash mid-export can never leave
a loadable half-artifact.  The checkpoint "step" slot carries the artifact
FORMAT version: ``latest_step`` discovery then naturally picks the newest
format a writer produced, and a loader refuses formats newer than it knows.

Load rebuilds the exact ``WLSHKRRModel`` plus its operator on any backend
(all backends read the same tables — see core/operator.py), after validating
every array shape against the metadata manifest and the metadata against
itself (bucket fn exists, table_size is a power of two and matches the
tables, LSH arrays agree on (m, d)).  Round-trip is bitwise: arrays go
through npz untouched.
"""
from __future__ import annotations

import os
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from ..checkpoint import restore_checkpoint, save_checkpoint
from ..checkpoint.store import latest_step
from ..core.bucket_fns import BUCKET_FNS
from ..core.krr import WLSHKRRModel, model_operator
from ..core.lsh import LSHParams
from ..core.operator import WLSHOperator

ARTIFACT_FORMAT = 1          # bump on any layout/meta change
_DTYPES = {"lsh_w": np.float32, "lsh_z": np.float32,
           "lsh_r1": np.uint32, "lsh_r2": np.uint32,
           "beta": np.float32, "tables": np.float32,
           "x_mean": np.float32, "x_std": np.float32,
           "y_mean": np.float32, "y_std": np.float32}


class Normalization(NamedTuple):
    """Optional request/response normalization baked into an artifact.

    The predictor applies ``(x - x_mean) / x_std`` before featurization and
    ``yhat * y_std + y_mean`` after readout — the stats travel with the model
    so every replica serves identically without a side channel.
    """

    x_mean: np.ndarray   # (d,)
    x_std: np.ndarray    # (d,)
    y_mean: float
    y_std: float


class LoadedArtifact(NamedTuple):
    artifact_id: str
    model: WLSHKRRModel
    operator: WLSHOperator   # rebuilt on the requested (or recorded) backend
    norm: Normalization | None
    meta: dict


def _model_arrays(model: WLSHKRRModel, *,
                  include_beta: bool) -> dict[str, np.ndarray]:
    tables = np.asarray(model.tables, np.float32)
    # prediction never reads beta (readout is lsh params + tables only); it
    # is O(n_train * k) — the one artifact array that scales with the
    # TRAINING set — so serving replicas can drop it.  A zero-row stand-in
    # keeps the manifest/validation shape contract (column count must still
    # match the tables' RHS block).
    beta = (np.asarray(model.beta, np.float32) if include_beta
            else np.zeros((0,) + tables.shape[2:], np.float32))
    return {"lsh_w": np.asarray(model.lsh.w, np.float32),
            "lsh_z": np.asarray(model.lsh.z, np.float32),
            "lsh_r1": np.asarray(model.lsh.r1, np.uint32),
            "lsh_r2": np.asarray(model.lsh.r2, np.uint32),
            "beta": beta,
            "tables": tables}


def export_artifact(directory: str, model: WLSHKRRModel, *,
                    artifact_id: str | None = None,
                    norm: Normalization | None = None,
                    extra_meta: dict | None = None,
                    include_beta: bool = True) -> str:
    """Atomically write ``model`` (+ optional normalization) to ``directory``.

    Returns the artifact id (defaults to the directory basename).  The write
    goes through ``checkpoint.save_checkpoint`` at step ``ARTIFACT_FORMAT``.
    ``include_beta=False`` drops the training solution from the artifact —
    serving needs only the LSH params and tables, and beta is the one array
    that scales with the training-set size.
    """
    arrays = _model_arrays(model, include_beta=include_beta)
    if norm is not None:
        arrays["x_mean"] = np.asarray(norm.x_mean, np.float32).reshape(-1)
        arrays["x_std"] = np.asarray(norm.x_std, np.float32).reshape(-1)
        arrays["y_mean"] = np.asarray(norm.y_mean, np.float32).reshape(())
        arrays["y_std"] = np.asarray(norm.y_std, np.float32).reshape(())
    artifact_id = artifact_id or os.path.basename(os.path.normpath(directory))
    meta = {"kind": "wlsh_krr_artifact",
            "format": ARTIFACT_FORMAT,
            "artifact_id": artifact_id,
            "bucket_name": model.bucket_name,
            "table_size": int(model.table_size),
            "backend": model.backend,
            "precond": model.precond,
            "cg_iters": int(np.asarray(model.cg_iters)),
            "cg_resnorm": np.asarray(model.cg_resnorm).tolist(),
            "has_norm": norm is not None,
            "has_beta": include_beta,
            "arrays": {k: list(v.shape) for k, v in arrays.items()},
            **(extra_meta or {})}
    save_checkpoint(directory, ARTIFACT_FORMAT, arrays, meta)
    return artifact_id


def _validate(meta: dict, arrays: dict[str, np.ndarray]) -> None:
    if meta.get("kind") != "wlsh_krr_artifact":
        raise ValueError(f"not a serving artifact: kind={meta.get('kind')!r}")
    bucket = meta.get("bucket_name")
    if bucket not in BUCKET_FNS:
        raise ValueError(f"artifact bucket fn {bucket!r} unknown to this "
                         f"build; have {sorted(BUCKET_FNS)}")
    table_size = int(meta.get("table_size", 0))
    if table_size <= 0 or table_size & (table_size - 1):
        raise ValueError(f"table_size must be a positive power of two, "
                         f"got {table_size}")
    m, d = arrays["lsh_w"].shape
    for name in ("lsh_z", "lsh_r1", "lsh_r2"):
        if arrays[name].shape != (m, d):
            raise ValueError(f"{name}: shape {arrays[name].shape} != "
                             f"lsh_w shape {(m, d)}")
    tables = arrays["tables"]
    if tables.ndim not in (2, 3) or tables.shape[:2] != (m, table_size):
        raise ValueError(f"tables: shape {tables.shape} inconsistent with "
                         f"m={m}, table_size={table_size}")
    beta = arrays["beta"]
    if beta.shape[1:] != tables.shape[2:]:
        raise ValueError(f"beta RHS block {beta.shape} vs tables "
                         f"{tables.shape}: column counts differ")
    if not np.isfinite(tables).all():
        bad = int(np.sum(~np.isfinite(tables)))
        raise ValueError(f"tables contain {bad} non-finite entries — a "
                         f"poisoned artifact must be rejected at load, not "
                         f"served as silent NaN predictions")
    if meta.get("has_norm"):
        for name in ("x_mean", "x_std", "y_mean", "y_std"):
            if name not in arrays:
                raise ValueError(f"has_norm set but {name} missing")
        if arrays["x_mean"].shape != (d,) or arrays["x_std"].shape != (d,):
            raise ValueError(f"normalization stats shaped "
                             f"{arrays['x_mean'].shape}, expected ({d},)")


def load_artifact(directory: str, *, backend: str | None = None,
                  artifact_id: str | None = None, retries: int = 0,
                  retry_backoff_s: float = 0.05) -> LoadedArtifact:
    """Load + validate an artifact and rebuild its operator.

    ``backend`` overrides the recorded fit backend ('reference' | 'pallas' |
    'auto'); every backend reads the same tables, so a model fit on a TPU pod
    serves from a CPU replica unchanged.  Raises ``ValueError`` on any
    shape/metadata inconsistency and on artifact formats newer than this
    build understands.

    ``retries`` retries TRANSIENT failures only — OSError / short-read zip
    corruption from a racing writer or flaky filesystem, with exponential
    backoff starting at ``retry_backoff_s``.  Validation failures raise
    immediately: re-reading a malformed artifact cannot fix it.
    """
    import time
    import zipfile
    attempt = 0
    while True:
        try:
            return _load_artifact_once(directory, backend=backend,
                                       artifact_id=artifact_id)
        except (OSError, zipfile.BadZipFile) as e:
            if attempt >= retries:
                raise
            time.sleep(retry_backoff_s * (2 ** attempt))
            attempt += 1


def _load_artifact_once(directory: str, *, backend: str | None = None,
                        artifact_id: str | None = None) -> LoadedArtifact:
    step = latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no artifact under {directory}")
    if step > ARTIFACT_FORMAT:
        raise ValueError(f"artifact format {step} is newer than this build's "
                         f"reader (supports <= {ARTIFACT_FORMAT})")
    # template shapes come from the meta manifest; restore_checkpoint then
    # cross-checks every stored array against it
    meta = _read_meta(directory, step)
    manifest = meta.get("arrays")
    if not isinstance(manifest, dict) or "lsh_w" not in manifest:
        raise ValueError("artifact meta has no array manifest")
    template = {name: np.zeros(tuple(shape), _DTYPES.get(name, np.float32))
                for name, shape in manifest.items()}
    arrays, _, meta = restore_checkpoint(directory, template, step)
    _validate(meta, arrays)

    lsh = LSHParams(w=jnp.asarray(arrays["lsh_w"]),
                    z=jnp.asarray(arrays["lsh_z"]),
                    r1=jnp.asarray(arrays["lsh_r1"]),
                    r2=jnp.asarray(arrays["lsh_r2"]))
    model = WLSHKRRModel(lsh=lsh, bucket_name=meta["bucket_name"],
                         beta=jnp.asarray(arrays["beta"]),
                         tables=jnp.asarray(arrays["tables"]),
                         table_size=int(meta["table_size"]),
                         cg_iters=jnp.asarray(meta.get("cg_iters", 0)),
                         cg_resnorm=jnp.asarray(meta.get("cg_resnorm", 0.0)),
                         backend=meta.get("backend", "reference"),
                         precond=meta.get("precond", "none"))
    norm = None
    if meta.get("has_norm"):
        norm = Normalization(x_mean=arrays["x_mean"], x_std=arrays["x_std"],
                             y_mean=float(arrays["y_mean"]),
                             y_std=float(arrays["y_std"]))
    op = model_operator(model, backend=backend)
    return LoadedArtifact(
        artifact_id=artifact_id or meta.get("artifact_id")
        or os.path.basename(os.path.normpath(directory)),
        model=model, operator=op, norm=norm, meta=meta)


def _read_meta(directory: str, step: int) -> dict:
    import json
    with open(os.path.join(directory, f"step_{step}", "meta.json")) as fh:
        return json.load(fh)
