"""Self-healing serving runtime: reload, canary, rollback, supervision.

Closes the loop from the obs layer's signals (PR 9) to recovery actions
(DESIGN.md §12).  Three cooperating pieces:

* ``ServingRuntime`` — a version WATCHER over a directory of published
  artifact versions (``<root>/v1``, ``<root>/v2``, ... — each a complete
  flat or sharded artifact).  ``poll_once`` discovers the newest published
  version, loads it ALONGSIDE the serving one (transient-retrying torn
  reads), pre-compiles its padding buckets, CANARY-validates it against the
  golden query set captured at export time (predictions must agree with the
  recorded outputs within the pinned tolerance and be finite), and only then
  atomically swaps the active version — a single tuple flip, so a concurrent
  ``predict`` sees exactly the old or the new version, never a mix, and warm
  buckets never recompile across a swap.  The previous N versions stay
  hosted for INSTANT rollback: when post-swap health regresses within the
  probation window (model-error rate over threshold, or any non-finite
  prediction), the runtime flips back and quarantines the bad version.
  Torn publishes are invisible (a flat version with no completed checkpoint
  step / a sharded one with no manifest is skipped, exactly like a torn
  single artifact); canary-rejected and structurally-invalid versions are
  remembered and never re-tried.

* ``SupervisedBatcher`` — a MicroBatcher under supervision: a worker crash
  is no longer terminal.  The crash fails the in-flight batch (WorkerCrashed,
  as before), the supervisor restarts a fresh worker with exponential
  backoff, and a per-model ``CircuitBreaker`` converts repeated failures
  into fast ``CircuitOpen`` (an ``Overloaded`` subclass) rejections instead
  of piling callers onto a sick model.

* ``CircuitBreaker`` — classic closed -> open -> half-open machine: opens
  after ``failure_threshold`` consecutive failures, admits
  ``half_open_probes`` probe requests after ``cooldown_s``, re-closes when
  they succeed, re-opens when one fails.

Every transition is an obs series (``lifecycle_*`` / ``breaker_*``) and
surfaces in ``health()`` — a runtime registered as a health provider turns
``/healthz`` into a live view of active version, retained rollback targets,
probation state, and breaker state.
"""
from __future__ import annotations

import os
import re
import threading
import time
from typing import NamedTuple

import numpy as np

from .. import obs
from ..checkpoint.store import latest_step
from ..errors import (CircuitOpen, DeadlineExceeded, InvalidRequest,
                      Overloaded, ServingError, WorkerCrashed)
from .artifact import MANIFEST_NAME, load_artifact, load_artifact_sharded
from .batcher import MicroBatcher
from .predictor import DEFAULT_MAX_BATCH, Predictor
from .sharded import ShardedPredictor

_VERSION_RE = re.compile(r"^v(\d+)$")

CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"
_STATE_CODE = {CLOSED: 0, OPEN: 1, HALF_OPEN: 2}


# ---------------------------------------------------------------------------
# version discovery
# ---------------------------------------------------------------------------

def version_dir(root: str, version: int) -> str:
    """``<root>/v<version>`` — the publish convention the watcher polls."""
    return os.path.join(root, f"v{int(version)}")


def discover_versions(root: str, *, sharded: bool = False
                      ) -> list[tuple[int, str]]:
    """Sorted ``[(version, path)]`` of PUBLISHED versions under ``root``.

    A version is published once its artifact is loadable at all: a flat
    version needs a completed checkpoint step (a ``step_N.tmp`` left by a
    killed writer is invisible, as everywhere else), a sharded one needs its
    manifest (written LAST by ``export_artifact_sharded``, so pieces without
    a manifest are a torn publish in progress).  Non-``v<N>`` entries are
    ignored — exporters may keep scratch space next to the versions.
    """
    try:
        names = os.listdir(root)
    except OSError:
        return []
    out = []
    for name in names:
        m = _VERSION_RE.match(name)
        if not m:
            continue
        path = os.path.join(root, name)
        if not os.path.isdir(path):
            continue
        if sharded:
            if not os.path.exists(os.path.join(path, MANIFEST_NAME)):
                continue
        elif latest_step(path) is None:
            continue
        out.append((int(m.group(1)), path))
    return sorted(out)


# ---------------------------------------------------------------------------
# circuit breaker
# ---------------------------------------------------------------------------

class CircuitBreaker:
    """closed -> open -> half-open request gate, per model.

    ``admit()`` raises ``CircuitOpen`` while open (and past the half-open
    probe quota); callers report outcomes with ``record_success`` /
    ``record_failure`` (``record_neutral`` returns an admitted probe's slot
    when the request died of a NON-model condition — shed, deadline — so a
    starved probe can't wedge the half-open state).  State and transitions
    are obs series labeled by the breaker name.
    """

    def __init__(self, *, name: str = "default", failure_threshold: int = 3,
                 cooldown_s: float = 0.25, half_open_probes: int = 1,
                 clock=time.monotonic):
        if failure_threshold < 1 or half_open_probes < 1:
            raise ValueError("failure_threshold and half_open_probes must "
                             "be >= 1")
        self.name = str(name)
        self.failure_threshold = int(failure_threshold)
        self.cooldown_s = float(cooldown_s)
        self.half_open_probes = int(half_open_probes)
        self._clock = clock
        self._lock = threading.Lock()
        self._state = CLOSED
        self._consecutive = 0
        self._opened_at = 0.0
        self._probes_issued = 0
        self._probe_successes = 0
        self._n_rejected = 0
        self._m_state = obs.gauge(
            "breaker_state", "circuit state (0 closed, 1 open, 2 half-open)",
            labels=("model",)).labels(self.name)
        self._m_transitions = obs.counter(
            "breaker_transitions_total", "circuit state transitions",
            labels=("model", "to"))
        self._m_rejections = obs.counter(
            "breaker_rejections_total",
            "submits rejected fast while the circuit is open",
            labels=("model",)).labels(self.name)
        self._m_state.set(0)

    @property
    def state(self) -> str:
        with self._lock:
            return self._state

    def _to(self, state: str) -> None:
        # lock held by caller
        if state == self._state:
            return
        self._state = state
        if state == HALF_OPEN:
            self._probes_issued = 0
            self._probe_successes = 0
        elif state == OPEN:
            self._opened_at = self._clock()
        else:
            self._consecutive = 0
        self._m_state.set(_STATE_CODE[state])
        self._m_transitions.labels(self.name, state).inc()

    def admit(self) -> None:
        """Gate one request; raises ``CircuitOpen`` instead of letting it
        reach a sick model."""
        with self._lock:
            if self._state == CLOSED:
                return
            if self._state == OPEN:
                waited = self._clock() - self._opened_at
                if waited < self.cooldown_s:
                    self._n_rejected += 1
                    self._m_rejections.inc()
                    raise CircuitOpen(
                        f"breaker {self.name!r} open "
                        f"({self._consecutive} consecutive failures)",
                        retry_after_s=self.cooldown_s - waited)
                self._to(HALF_OPEN)
            if self._probes_issued >= self.half_open_probes:
                self._n_rejected += 1
                self._m_rejections.inc()
                raise CircuitOpen(
                    f"breaker {self.name!r} half-open: probe quota "
                    f"({self.half_open_probes}) already in flight")
            self._probes_issued += 1

    def record_success(self) -> None:
        with self._lock:
            if self._state == HALF_OPEN:
                self._probe_successes += 1
                if self._probe_successes >= self.half_open_probes:
                    self._to(CLOSED)
            else:
                self._consecutive = 0

    def record_failure(self) -> None:
        with self._lock:
            if self._state == HALF_OPEN:
                self._to(OPEN)
                return
            self._consecutive += 1
            if self._state == CLOSED \
                    and self._consecutive >= self.failure_threshold:
                self._to(OPEN)

    def record_neutral(self) -> None:
        """An admitted request resolved without indicting the model (shed,
        deadline-expired, invalid input): hand a half-open probe slot back."""
        with self._lock:
            if self._state == HALF_OPEN and self._probes_issued > 0:
                self._probes_issued -= 1

    def stats(self) -> dict:
        with self._lock:
            return {"state": self._state,
                    "consecutive_failures": self._consecutive,
                    "rejected": self._n_rejected}


# ---------------------------------------------------------------------------
# supervised batcher
# ---------------------------------------------------------------------------

class SupervisedBatcher:
    """A MicroBatcher whose worker crashes are recovered, not terminal.

    The in-flight batch of a crashing worker still fails with
    ``WorkerCrashed`` (nothing can finish it), but the NEXT submit restarts
    a fresh worker after an exponential backoff instead of failing fast
    forever.  Every crash (and every model-error batch outcome) feeds the
    per-model circuit breaker, so sustained sickness turns into fast
    ``CircuitOpen`` rejections and a half-open probe is what re-admits
    traffic after the cooldown.  API-compatible with ``MicroBatcher`` where
    the serving drivers touch it (submit / predict / stats / close /
    context manager).
    """

    def __init__(self, predict_fn, *, name: str = "default",
                 breaker: CircuitBreaker | None = None,
                 failure_threshold: int = 3, cooldown_s: float = 0.25,
                 half_open_probes: int = 1,
                 restart_backoff_s: float = 0.02,
                 restart_backoff_max_s: float = 1.0,
                 max_restarts: int = 0, **batcher_kwargs):
        self.predict_fn = predict_fn
        self.name = str(name)
        self._kw = dict(batcher_kwargs)
        self.breaker = breaker or CircuitBreaker(
            name=name, failure_threshold=failure_threshold,
            cooldown_s=cooldown_s, half_open_probes=half_open_probes)
        self._b0 = float(restart_backoff_s)
        self._bmax = float(restart_backoff_max_s)
        self._backoff = self._b0
        self.max_restarts = int(max_restarts)    # 0 = unbounded
        self._restarts = 0
        self._crashes = 0
        self._restart_at = 0.0
        self._closed = False
        self._lock = threading.Lock()
        self._worker_fault_hook = None   # armed on every fresh worker (tests)
        self._m_restarts = obs.counter(
            "lifecycle_worker_restarts_total",
            "batcher workers restarted after a crash").labels()
        self._m_crashes = obs.counter(
            "lifecycle_worker_crashes_total",
            "batcher worker crashes observed by the supervisor").labels()
        self._mb = self._spawn()

    def _spawn(self) -> MicroBatcher:
        mb = MicroBatcher(self.predict_fn, on_crash=self._on_crash,
                          **self._kw)
        if self._worker_fault_hook is not None:
            mb._fault_hook = self._worker_fault_hook
        return mb

    def _on_crash(self, exc: BaseException) -> None:
        # runs on the dying worker thread, BEFORE the crash fails any future
        # (batcher._crash ordering) — so a caller that sees WorkerCrashed and
        # immediately resubmits finds the breaker already informed
        with self._lock:
            self._crashes += 1
            self._restart_at = time.monotonic() + self._backoff
            self._backoff = min(self._backoff * 2.0, self._bmax)
        self._m_crashes.inc()
        self.breaker.record_failure()

    def _ensure_worker(self) -> MicroBatcher:
        with self._lock:
            if self._closed:
                raise RuntimeError("supervised batcher is closed")
            mb = self._mb
            if mb._crashed is None and not mb._closed:
                return mb
            if self.max_restarts and self._restarts >= self.max_restarts:
                raise WorkerCrashed(
                    f"supervised batcher {self.name!r}: restart budget "
                    f"({self.max_restarts}) exhausted")
            delay = self._restart_at - time.monotonic()
        if delay > 0:
            time.sleep(delay)       # bounded by restart_backoff_max_s
        with self._lock:
            if self._closed:
                raise RuntimeError("supervised batcher is closed")
            mb = self._mb
            if mb._crashed is None and not mb._closed:
                return mb           # another submitter restarted meanwhile
            self._mb = mb = self._spawn()
            self._restarts += 1
        self._m_restarts.inc()
        return mb

    def submit(self, x_row, *, deadline_us: int | None = None):
        """Breaker-gated enqueue; returns a Future.  Raises ``CircuitOpen``
        fast while the breaker is open; a submit racing a crash retries once
        on a freshly restarted worker."""
        self.breaker.admit()
        try:
            try:
                fut = self._ensure_worker().submit(x_row,
                                                   deadline_us=deadline_us)
            except WorkerCrashed:
                fut = self._ensure_worker().submit(x_row,
                                                   deadline_us=deadline_us)
        except BaseException:
            # the admit may have consumed a half-open probe slot — a submit
            # that never produced a future must not wedge the breaker
            self.breaker.record_neutral()
            raise
        fut.add_done_callback(self._settle)
        return fut

    def _settle(self, fut) -> None:
        e = fut.exception()
        if e is None:
            with self._lock:
                self._backoff = self._b0    # healthy again: backoff resets
            self.breaker.record_success()
        elif isinstance(e, WorkerCrashed):
            pass    # the crash itself was recorded in _on_crash
        elif isinstance(e, (Overloaded, DeadlineExceeded, InvalidRequest)):
            self.breaker.record_neutral()   # load/client, not model sickness
        else:
            self.breaker.record_failure()   # model error (batch-wide)

    def predict(self, x_row, *, timeout: float | None = None,
                deadline_us: int | None = None):
        return self.submit(x_row, deadline_us=deadline_us).result(timeout)

    def close(self, timeout: float | None = None) -> None:
        with self._lock:
            self._closed = True
            mb = self._mb
        mb.close(timeout)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def stats(self) -> dict:
        """Current worker's stats plus supervision state.  Counters reset
        across a restart (they are the CURRENT worker's); the supervisor's
        own ``crashes``/``restarts`` are cumulative."""
        with self._lock:
            mb = self._mb
            snap = {"crashes": self._crashes, "restarts": self._restarts,
                    "restart_backoff_s": self._backoff}
        out = mb.stats()
        out.update(snap)
        out["breaker"] = self.breaker.stats()
        return out


# ---------------------------------------------------------------------------
# serving runtime: watch -> canary -> swap -> probation -> rollback
# ---------------------------------------------------------------------------

class LifecycleConfig(NamedTuple):
    """Knobs for the self-healing runtime; all thresholds deterministic so
    chaos tests pin exact behavior."""

    poll_interval_s: float = 0.5       # watcher cadence (start())
    canary_enabled: bool = True        # False: swap without validation
    canary_tol: float | None = None    # None -> the artifact's recorded tol
    require_golden: bool = False       # reject candidates with no golden set
    retain: int = 2                    # previous versions kept for rollback
    probation_s: float = 5.0           # post-swap health watch (0 = off)
    probation_min_requests: int = 20   # error-rate needs a denominator
    probation_max_error_rate: float = 0.1
    load_retries: int = 2              # transient-read retries per reload
    load_retry_backoff_s: float = 0.05
    warm_sizes: tuple[int, ...] | None = None  # buckets to precompile
                                               # (None = all up to max_batch)


class _Probation(NamedTuple):
    until: float          # monotonic deadline of the watch window
    req0: int             # runtime counters at swap time
    err0: int
    nonfinite0: int


class ServingRuntime:
    """Version-watching, canary-validating, self-rolling-back serving tier.

    Owns one ``Predictor`` (or ``ShardedPredictor`` when ``mesh_shape`` is
    given) and hosts every live version inside it under artifact id
    ``v<N>`` — the active version is one tuple attribute, so ``predict``
    resolves it in a single atomic read and a swap/rollback can never hand a
    request a mix of versions.  ``poll_once`` is the deterministic unit the
    tests drive; ``start()`` runs it on a daemon thread every
    ``poll_interval_s``.
    """

    def __init__(self, root: str, *, predictor=None,
                 mesh_shape: tuple[int, int] | None = None,
                 backend: str | None = None,
                 max_batch: int = DEFAULT_MAX_BATCH, cache_entries: int = 0,
                 config: LifecycleConfig = LifecycleConfig(),
                 name: str = "default"):
        self.root = str(root)
        self.config = config
        self.name = str(name)
        if predictor is not None:
            self.predictor = predictor
            self.sharded = isinstance(predictor, ShardedPredictor)
        elif mesh_shape is not None:
            self.predictor = ShardedPredictor(
                mesh_shape=mesh_shape, backend=backend, max_batch=max_batch,
                cache_entries=cache_entries)
            self.sharded = True
        else:
            self.predictor = Predictor(backend=backend, max_batch=max_batch,
                                       cache_entries=cache_entries)
            self.sharded = False
        self._lock = threading.RLock()
        self._active: tuple[int, str] | None = None   # (version, artifact id)
        self._history: list[tuple[int, str]] = []     # oldest .. newest
        self._rejected: dict[int, str] = {}           # version -> reason
        self._probation: _Probation | None = None
        self._n_requests = 0
        self._n_model_errors = 0       # errors that indict the MODEL
        self._n_nonfinite = 0
        self._last_canary: dict | None = None
        self._canary_hook = None       # tests (faults.canary_poison)
        self._batcher: SupervisedBatcher | None = None
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()
        # families + hot children bound once; every family is created here so
        # the series EXIST (at 0) from runtime construction — an alerting
        # rule must distinguish "no rollbacks yet" from "no runtime"
        self._m_reloads = obs.counter(
            "lifecycle_reloads_total",
            "reload attempts by outcome", labels=("result",))
        self._m_canary = obs.counter(
            "lifecycle_canary_total",
            "canary validations by verdict", labels=("verdict",))
        self._m_swaps = obs.counter(
            "lifecycle_swaps_total", "versions atomically swapped live").labels()
        self._m_rollbacks = obs.counter(
            "lifecycle_rollbacks_total",
            "instant rollbacks to a retained version").labels()
        self._m_rollback_exhausted = obs.counter(
            "lifecycle_rollback_exhausted_total",
            "rollbacks requested with no retained version left").labels()
        self._m_probation = obs.counter(
            "lifecycle_probation_total",
            "probation windows by outcome", labels=("outcome",))
        self._m_nonfinite = obs.counter(
            "lifecycle_nonfinite_predictions_total",
            "served predictions containing non-finite values").labels()
        self._g_active = obs.gauge(
            "lifecycle_active_version", "currently serving version",
            labels=("model",)).labels(self.name)
        self._g_retained = obs.gauge(
            "lifecycle_versions_retained",
            "previous versions retained for rollback",
            labels=("model",)).labels(self.name)
        self._g_active.set(0)
        self._g_retained.set(0)

    # -- serving ------------------------------------------------------------

    @property
    def active_version(self) -> int | None:
        act = self._active
        return act[0] if act is not None else None

    def predict(self, x, *, use_cache: bool = True, validate: bool = True):
        """Serve against the ACTIVE version.  The version resolves in one
        atomic read — a concurrent swap/rollback gives this request exactly
        the old or the new version, never a mix.  Outcomes feed the
        probation health check (model errors and non-finite predictions
        count against the freshly swapped version; client errors and load
        shedding do not)."""
        act = self._active
        if act is None:
            raise ServingError(
                f"no published version active under {self.root}")
        try:
            out = self.predictor.predict(x, artifact_id=act[1],
                                         use_cache=use_cache,
                                         validate=validate)
        except (InvalidRequest, Overloaded, DeadlineExceeded):
            raise
        except BaseException:
            with self._lock:
                self._n_requests += 1
                self._n_model_errors += 1
            self._maybe_autoroll()
            raise
        finite = bool(np.isfinite(out).all())
        with self._lock:
            self._n_requests += 1
            if not finite:
                self._n_nonfinite += 1
        if not finite:
            self._m_nonfinite.inc()
            self._maybe_autoroll()
        elif self._probation is not None:
            self._maybe_autoroll()
        return out

    def make_batcher(self, **kwargs) -> SupervisedBatcher:
        """A ``SupervisedBatcher`` fronting this runtime's ``predict`` (one
        breaker named after the runtime); attached for ``health()``."""
        sup = SupervisedBatcher(lambda xb: self.predict(xb), name=self.name,
                                **kwargs)
        self.predictor.attach_batcher(sup)
        self._batcher = sup
        return sup

    # -- watcher ------------------------------------------------------------

    def poll_once(self) -> dict:
        """One watcher tick: discover -> load -> warm -> canary -> swap.
        Returns an action report (``action`` in none / load_error /
        load_rejected / canary_reject / swap).  Also expires/trips the
        probation window, so a thread-less runtime still self-heals as long
        as something polls."""
        self._maybe_autoroll()
        with self._lock:
            active_version = self._active[0] if self._active else 0
            rejected = set(self._rejected)
        cands = [(v, p) for v, p in
                 discover_versions(self.root, sharded=self.sharded)
                 if v > active_version and v not in rejected]
        if not cands:
            return {"action": "none", "active": self.active_version}
        version, path = cands[-1]
        aid = f"v{version}"
        cfg = self.config
        try:
            if self.sharded:
                loaded = load_artifact_sharded(
                    path, mesh_shape=self.predictor.mesh_shape,
                    backend=self.predictor.backend, artifact_id=aid,
                    retries=cfg.load_retries,
                    retry_backoff_s=cfg.load_retry_backoff_s)
                golden = loaded.manifest.get("golden")
                self.predictor.add_model(loaded)
            else:
                loaded = load_artifact(
                    path, backend=self.predictor.backend, artifact_id=aid,
                    retries=cfg.load_retries,
                    retry_backoff_s=cfg.load_retry_backoff_s)
                golden = loaded.meta.get("golden")
                self.predictor.add_model(loaded)
        except (ValueError, KeyError) as e:
            # structural: re-reading cannot fix it — quarantine the version
            with self._lock:
                self._rejected[version] = f"load: {e!r}"
            self._m_reloads.labels("load_rejected").inc()
            return {"action": "load_rejected", "version": version,
                    "error": repr(e)}
        except Exception as e:
            # transient (a publisher may still be writing): retry next tick
            self._m_reloads.labels("load_error").inc()
            return {"action": "load_error", "version": version,
                    "error": repr(e)}
        # candidate warms BEFORE it takes traffic: the swap itself then
        # compiles nothing (pinned by the selftest/bench compile counts)
        self.predictor.warmup(artifact_id=aid, sizes=cfg.warm_sizes)
        verdict, detail = self._canary(aid, golden)
        self._m_canary.labels(verdict).inc()
        with self._lock:
            self._last_canary = {"version": version, "verdict": verdict,
                                 **detail}
        if verdict == "reject":
            with self._lock:
                self._rejected[version] = f"canary: {detail}"
            self.predictor.unload(aid)
            self._m_reloads.labels("canary_reject").inc()
            return {"action": "canary_reject", "version": version, **detail}
        self._swap(version, aid)
        self._m_reloads.labels("swap").inc()
        return {"action": "swap", "version": version, "canary": verdict,
                **detail}

    def _canary(self, aid: str, golden: dict | None) -> tuple[str, dict]:
        """Validate a loaded candidate against its recorded golden set.
        Verdicts: pass / absent (no golden set recorded) / reject."""
        cfg = self.config
        if not cfg.canary_enabled:
            return "absent", {"reason": "canary disabled"}
        if not golden:
            if cfg.require_golden:
                return "reject", {"reason": "no golden queries recorded and "
                                            "require_golden is set"}
            return "absent", {"reason": "no golden queries recorded"}
        try:
            x = np.asarray(golden["x"], np.float32)
            want = np.asarray(golden["y"], np.float32)
            tol = float(cfg.canary_tol if cfg.canary_tol is not None
                        else golden.get("tol", 1e-4))
            got = self.predictor.predict(x, artifact_id=aid, use_cache=False)
            hook = self._canary_hook
            if hook is not None:
                got = hook(np.array(got))
            got = np.asarray(got, np.float32)
        except Exception as e:
            return "reject", {"reason": f"canary predict failed: {e!r}"}
        if got.shape != want.shape:
            return "reject", {"reason": f"canary shape {got.shape} != "
                                        f"recorded {want.shape}"}
        if not np.isfinite(got).all():
            return "reject", {"reason": "non-finite canary predictions"}
        err = float(np.max(np.abs(got - want))) if want.size else 0.0
        if err > tol:
            return "reject", {"reason": f"canary disagreement {err:.3e} > "
                                        f"tol {tol:.1e}",
                              "max_abs_err": err}
        return "pass", {"max_abs_err": err}

    def _swap(self, version: int, aid: str) -> None:
        cfg = self.config
        evicted = []
        with self._lock:
            prev = self._active
            self._active = (version, aid)   # the atomic flip
            if prev is not None:
                self._history.append(prev)
            while len(self._history) > max(int(cfg.retain), 0):
                evicted.append(self._history.pop(0))
            if cfg.probation_s > 0 and prev is not None:
                self._probation = _Probation(
                    until=time.monotonic() + cfg.probation_s,
                    req0=self._n_requests, err0=self._n_model_errors,
                    nonfinite0=self._n_nonfinite)
            self._g_active.set(version)
            self._g_retained.set(len(self._history))
        self._m_swaps.inc()
        for _, old_aid in evicted:
            self.predictor.unload(old_aid)

    # -- rollback -----------------------------------------------------------

    def rollback(self, reason: str = "manual") -> bool:
        """Instant flip back to the most recently retained version; the
        rolled-away version is quarantined (never re-adopted by the
        watcher).  Returns False — and counts it — when nothing is retained."""
        with self._lock:
            return self._rollback_locked(reason)

    def _rollback_locked(self, reason: str) -> bool:
        if not self._history:
            self._m_rollback_exhausted.inc()
            return False
        bad = self._active
        self._active = self._history.pop()
        self._probation = None
        self._g_active.set(self._active[0])
        self._g_retained.set(len(self._history))
        self._m_rollbacks.inc()
        if bad is not None:
            self._rejected[bad[0]] = reason
            self.predictor.unload(bad[1])
        return True

    def _maybe_autoroll(self) -> None:
        cfg = self.config
        with self._lock:
            p = self._probation
            if p is None:
                return
            req = self._n_requests - p.req0
            err = self._n_model_errors - p.err0
            nonf = self._n_nonfinite - p.nonfinite0
            trip = nonf > 0 or (
                req >= cfg.probation_min_requests
                and err / max(req, 1) > cfg.probation_max_error_rate)
            if trip:
                self._probation = None
                self._m_probation.labels("rolled_back").inc()
                self._rollback_locked(
                    f"health regression within probation: {err}/{req} model "
                    f"errors, {nonf} non-finite predictions")
            elif time.monotonic() > p.until:
                self._probation = None
                self._m_probation.labels("passed").inc()

    # -- background watcher -------------------------------------------------

    def start(self, interval_s: float | None = None) -> None:
        """Poll on a daemon thread every ``interval_s`` (default from the
        config).  The watcher never dies: a poll raising (disk flake,
        publisher race) is counted and the next tick runs."""
        if self._thread is not None:
            return
        iv = float(interval_s if interval_s is not None
                   else self.config.poll_interval_s)
        self._stop.clear()

        def loop() -> None:
            while not self._stop.wait(iv):
                try:
                    self.poll_once()
                except Exception:
                    self._m_reloads.labels("load_error").inc()

        self._thread = threading.Thread(target=loop,
                                        name="lifecycle-watcher", daemon=True)
        self._thread.start()

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join(timeout=5.0)
        self._thread = None

    # -- delegation + health ------------------------------------------------

    def warmup(self, *, sizes=None) -> int:
        act = self._require_active()
        return self.predictor.warmup(artifact_id=act[1],
                                     sizes=sizes or self.config.warm_sizes)

    def compile_count(self) -> int:
        return self.predictor.compile_count(
            artifact_id=self._require_active()[1])

    def cache_stats(self) -> dict | None:
        return self.predictor.cache_stats(
            artifact_id=self._require_active()[1])

    def attach_batcher(self, batcher) -> None:
        self.predictor.attach_batcher(batcher)

    def _require_active(self) -> tuple[int, str]:
        act = self._active
        if act is None:
            raise ServingError(
                f"no published version active under {self.root}")
        return act

    def _hosted(self, aid=None):
        # krr_serve's driver peeks at the hosted model for its dimensions
        return self.predictor._hosted(aid or self._require_active()[1])

    def health(self) -> dict:
        """Lifecycle view for ``/healthz``: active/retained/rejected
        versions, probation and last canary verdict, runtime counters, the
        wrapped predictor's own health, and — when a supervised batcher is
        attached — its breaker and restart state."""
        with self._lock:
            snap = {
                "active_version": self.active_version,
                "retained_versions": [v for v, _ in self._history],
                "rejected_versions": sorted(self._rejected),
                "probation": self._probation is not None,
                "last_canary": self._last_canary,
                "requests": self._n_requests,
                "model_errors": self._n_model_errors,
                "nonfinite": self._n_nonfinite,
            }
        snap["predictor"] = self.predictor.health()
        batcher = self._batcher
        if batcher is not None:
            snap["breaker"] = batcher.breaker.stats()
            snap["worker"] = {"crashes": batcher.stats()["crashes"],
                              "restarts": batcher.stats()["restarts"]}
        snap["ok"] = bool(snap["active_version"] is not None
                          and snap["predictor"]["ok"])
        return snap
