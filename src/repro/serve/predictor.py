"""Warm-path predictor: multi-model hosting with padding-bucket compilation.

One hosted model = one jitted ``featurize_buckets -> predict_from_buckets``
program (normalization folded in) whose compilation is keyed on the request
shape.  Ragged request sizes would retrace per size, so every batch is padded
up to a power-of-two PADDING BUCKET (1, 2, 4, ... max_batch) before entering
jit: the jit cache then holds at most log2(max_batch)+1 entries per model and
a new request size within an existing bucket NEVER recompiles (pinned by
tests via the jit cache-miss count).  Batches above ``max_batch`` are served
in max_batch-sized chunks — compile cost stays bounded no matter what the
batcher coalesces.

The predictor optionally fronts the jit path with the bucket-exact cache
(serve/cache.py): rows whose bucket key is cached skip featurize+readout
entirely; the remaining rows run the warm path and their results are
inserted.  Hits are exact — the cache stores the warm path's own output.
"""
from __future__ import annotations

import threading
from time import perf_counter
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .artifact import LoadedArtifact, load_artifact
from .. import obs
from ..core.bucket_fns import get_bucket_fn
from ..errors import InvalidRequest
from ..testing.faults import FaultPlan, serve_fault
from .cache import BucketKeyFn, PredictionCache

DEFAULT_MAX_BATCH = 1024


def padding_bucket(n: int, max_batch: int) -> int:
    """Smallest power of two >= n, capped at max_batch (callers chunk above
    the cap)."""
    if n <= 0:
        raise ValueError(f"need a positive batch, got {n}")
    return min(1 << (n - 1).bit_length(), max_batch)


def bucket_sizes(limit: int) -> tuple[int, ...]:
    """Every padding bucket up to ``limit``: (1, 2, 4, ..., >= limit).  Feed
    to ``Predictor.warmup`` so a batcher bounded by ``limit`` never hits a
    compile mid-traffic."""
    if limit <= 0:
        raise ValueError(f"need a positive limit, got {limit}")
    return tuple(1 << p for p in range((limit - 1).bit_length() + 1))


class _HostedModel(NamedTuple):
    loaded: LoadedArtifact
    predict_fn: object       # jitted (tables, x_padded) -> yhat_padded
    keyfn: BucketKeyFn
    cache: PredictionCache | None
    keymemo: PredictionCache | None   # raw query bytes -> bucket key: skips
                                      # the numpy hash for repeat queries


class Predictor:
    """Hosts fitted models keyed by artifact id and serves point predictions.

    ``predict`` accepts a (b, d) request batch (or a single (d,) point) and
    returns numpy predictions: (b,) for a single-target model, (b, k) for a
    multi-RHS fit.  ``cache_entries > 0`` enables the bucket-exact cache per
    model; ``backend`` overrides the recorded fit backend at load time.
    """

    def __init__(self, *, backend: str | None = None,
                 max_batch: int = DEFAULT_MAX_BATCH,
                 cache_entries: int = 0,
                 fault_plan: FaultPlan | None = None):
        if max_batch & (max_batch - 1) or max_batch <= 0:
            raise ValueError(f"max_batch must be a power of two, "
                             f"got {max_batch}")
        self.backend = backend
        self.max_batch = int(max_batch)
        self.cache_entries = int(cache_entries)
        self.fault_plan = fault_plan    # chaos tests: warm-path stall/fail
        self._models: dict[str, _HostedModel] = {}
        self._default_id: str | None = None
        self._lock = threading.Lock()
        self._n_predicts = 0            # warm-path calls (drives serve_fault)
        self._n_requests = 0
        self._n_errors = 0
        self._last_error: str | None = None
        self._batcher = None            # attached MicroBatcher, for health()
        # registry children resolved once; health() keeps reading the
        # per-instance counters above (API-stable exact values), the global
        # registry gets the same increments for scraping
        self._m_requests = obs.counter(
            "serve_requests_total", "predict() calls accepted").labels()
        self._m_errors = obs.counter(
            "serve_errors_total", "predict() calls that raised").labels()
        self._m_predict_us = obs.histogram(
            "serve_predict_us", "end-to-end predict() wall time").labels()
        self._m_warm_us = obs.histogram(
            "serve_warm_compute_us",
            "jitted warm-path wall time per call").labels()
        self._m_probe_us = obs.histogram(
            "serve_cache_probe_us",
            "bucket-key + cache probe wall time").labels()
        self._m_hits = obs.counter(
            "serve_cache_hits_total",
            "query rows served from the cache").labels()
        self._m_misses = obs.counter(
            "serve_cache_misses_total",
            "query rows that ran the warm path").labels()
        self._m_bucket = obs.counter(
            "serve_padding_bucket_total",
            "batches served per power-of-two padding bucket",
            labels=("bucket",))
        self._bucket_children: dict = {}   # bucket -> bound counter child
        # flat pre-bound timers, not full spans: these are the per-request
        # sites that pay the metrics-on/off <=1.05x p50 pin
        self._t_predict = obs.timer("serve.predict",
                                    to_histogram=self._m_predict_us)
        self._t_warm = obs.timer("serve.warm_compute",
                                 to_histogram=self._m_warm_us)

    # -- model hosting ------------------------------------------------------

    def load(self, directory: str, *, artifact_id: str | None = None,
             retries: int = 0, retry_backoff_s: float = 0.05) -> str:
        """Load an artifact from disk and host it; returns its id.

        ``retries`` re-attempts transient I/O failures (flaky NFS, an
        exporter's rename racing the read) with exponential backoff —
        validation errors are never retried, a malformed artifact stays
        malformed."""
        loaded = load_artifact(directory, backend=self.backend,
                               artifact_id=artifact_id, retries=retries,
                               retry_backoff_s=retry_backoff_s)
        return self.add_model(loaded)

    def add_model(self, loaded: LoadedArtifact) -> str:
        """Host an already-loaded artifact (id from the artifact)."""
        op, norm = loaded.operator, loaded.norm

        def fn(tables, x):
            x = jnp.asarray(x, jnp.float32)
            if norm is not None:
                x = (x - jnp.asarray(norm.x_mean)) / jnp.asarray(norm.x_std)
            out = op.predict_from_buckets(op.featurize_buckets(x), tables)
            if norm is not None:
                out = out * jnp.float32(norm.y_std) + jnp.float32(norm.y_mean)
            return out

        hosted = _HostedModel(
            loaded=loaded, predict_fn=jax.jit(fn),
            keyfn=BucketKeyFn(loaded.model.lsh,
                              get_bucket_fn(loaded.model.bucket_name)),
            cache=(PredictionCache(self.cache_entries)
                   if self.cache_entries > 0 else None),
            keymemo=(PredictionCache(self.cache_entries)
                     if self.cache_entries > 0 else None))
        with self._lock:
            self._models[loaded.artifact_id] = hosted
            if self._default_id is None:
                self._default_id = loaded.artifact_id
        obs.counter("serve_models_loaded_total",
                    "artifacts hosted over the process lifetime").inc()
        if hosted.cache is not None:
            # pull-time gauges: cache state is read only when scraped, so
            # hosting a model adds zero per-request cost
            cache = hosted.cache
            obs.gauge("serve_cache_entries", "live prediction-cache entries",
                      labels=("model",)).labels(loaded.artifact_id).set_fn(
                lambda cache=cache: cache.stats()["entries"])
            obs.gauge("serve_cache_evictions",
                      "prediction-cache evictions to date",
                      labels=("model",)).labels(loaded.artifact_id).set_fn(
                lambda cache=cache: cache.stats()["evictions"])
        return loaded.artifact_id

    def _hosted(self, artifact_id: str | None) -> _HostedModel:
        with self._lock:
            aid = artifact_id or self._default_id
            if aid is None or aid not in self._models:
                raise KeyError(f"no hosted model {aid!r}; "
                               f"have {sorted(self._models)}")
            return self._models[aid]

    def unload(self, artifact_id: str) -> bool:
        """Drop a hosted model (its jit cache, caches, and key memo go with
        it).  In-flight predicts that already resolved the hosted entry
        finish on it; new requests for the id get KeyError.  Returns whether
        the id was hosted."""
        with self._lock:
            hosted = self._models.pop(artifact_id, None)
            if self._default_id == artifact_id:
                self._default_id = min(self._models, default=None)
        return hosted is not None

    @property
    def artifact_ids(self) -> list[str]:
        with self._lock:
            return sorted(self._models)

    # -- warm path ----------------------------------------------------------

    def _predict_padded(self, hosted: _HostedModel, x: np.ndarray):
        """Pad to the power-of-two bucket, run the jitted program, trim."""
        b = x.shape[0]
        bucket = padding_bucket(b, self.max_batch)
        ch = self._bucket_children.get(bucket)
        if ch is None:       # bind the labeled child once per padding bucket
            ch = self._bucket_children[bucket] = self._m_bucket.labels(bucket)
        ch.inc()
        xp = np.zeros((bucket, x.shape[1]), np.float32)
        xp[:b] = x
        out = hosted.predict_fn(hosted.loaded.model.tables, xp)
        return np.asarray(out)[:b]

    def _predict_warm(self, hosted: _HostedModel, x: np.ndarray):
        with self._lock:
            self._n_predicts += 1
            call_idx = self._n_predicts
        serve_fault(self.fault_plan, call_idx)
        with self._t_warm():
            chunks = [self._predict_padded(hosted, x[i:i + self.max_batch])
                      for i in range(0, x.shape[0], self.max_batch)]
            return chunks[0] if len(chunks) == 1 else np.concatenate(chunks)

    def predict(self, x, *, artifact_id: str | None = None,
                use_cache: bool = True, validate: bool = True) -> np.ndarray:
        """Serve a (d,) point or (b, d) batch.

        ``validate`` rejects non-finite query rows with ``InvalidRequest``
        BEFORE they reach the model — a NaN query must surface as a
        structured error, never as a silently-NaN prediction (and never as a
        poisoned cache entry served to later callers)."""
        try:
            with self._t_predict():
                return self._predict(x, artifact_id=artifact_id,
                                     use_cache=use_cache, validate=validate)
        except BaseException as e:
            with self._lock:
                self._n_errors += 1
                self._last_error = repr(e)
            self._m_errors.inc()
            raise

    def _predict(self, x, *, artifact_id, use_cache, validate) -> np.ndarray:
        hosted = self._hosted(artifact_id)
        with self._lock:
            self._n_requests += 1
        self._m_requests.inc()
        x = np.asarray(x, np.float32)
        single = x.ndim == 1
        if single:
            x = x[None, :]
        if validate and not np.isfinite(x).all():
            bad = np.flatnonzero(~np.isfinite(x).all(axis=1))
            raise InvalidRequest(
                f"non-finite query row(s) {bad[:8].tolist()} "
                f"({len(bad)} of {x.shape[0]})")
        if hosted.cache is None or not use_cache:
            out = self._predict_warm(hosted, x)
            return out[0] if single else out

        t0 = perf_counter()
        keys = self._bucket_keys(hosted, x)
        found = hosted.cache.get_many(keys)
        self._m_probe_us.observe((perf_counter() - t0) * 1e6)
        if single and found[0] is not None:       # all-hit serving fast path
            self._m_hits.inc()
            v = found[0]
            # hand out a copy, never the stored row: an in-place caller
            # mutation must not rewrite the cache (np scalars are immutable)
            return v.copy() if isinstance(v, np.ndarray) else v
        miss = [i for i, v in enumerate(found) if v is None]
        if len(found) > len(miss):
            self._m_hits.inc(len(found) - len(miss))
        if miss:
            self._m_misses.inc(len(miss))
            fresh = self._predict_warm(hosted, x[miss])
            hosted.cache.put_many([keys[i] for i in miss], list(fresh))
            for j, i in enumerate(miss):
                found[i] = fresh[j]
        out = np.stack(found)
        return out[0] if single else out

    def _bucket_keys(self, hosted: _HostedModel, x: np.ndarray) -> list[bytes]:
        """Bucket key per query row, through a raw-bytes -> key memo.

        The bucket key itself is deterministic in the raw row (normalization
        + hash pipeline are pure), so memoizing it is exact; a repeat query
        costs one ``tobytes`` and two dict probes instead of the ~12-op numpy
        hash — that gap is most of the cache path's >=10x over the warm path.
        Keys are computed on what the jit path actually featurizes: the
        NORMALIZED query (numpy f32 mirrors the jitted f32 normalization
        bitwise — both are IEEE sub/div).
        """
        raw = [row.tobytes() for row in x]
        memo = hosted.keymemo.get_many(raw)
        miss = [i for i, k in enumerate(memo) if k is None]
        if miss:
            norm = hosted.loaded.norm
            xm = x[miss]
            if norm is not None:
                xm = ((xm - np.asarray(norm.x_mean, np.float32))
                      / np.asarray(norm.x_std, np.float32)).astype(np.float32)
            fresh = hosted.keyfn(xm)
            hosted.keymemo.put_many([raw[i] for i in miss], fresh)
            for j, i in enumerate(miss):
                memo[i] = fresh[j]
        return memo

    # -- compile management -------------------------------------------------

    def warmup(self, *, artifact_id: str | None = None,
               sizes: tuple[int, ...] | None = None) -> int:
        """Pre-compile every padding bucket (or just ``sizes``' buckets) so
        the first real request never pays the compile.  Returns the jit cache
        size afterwards."""
        hosted = self._hosted(artifact_id)
        d = hosted.loaded.model.lsh.d
        buckets = sorted({padding_bucket(s, self.max_batch)
                          for s in (sizes or self._all_buckets())})
        for b in buckets:
            np.asarray(hosted.predict_fn(hosted.loaded.model.tables,
                                         np.zeros((b, d), np.float32)))
        return self.compile_count(artifact_id=artifact_id)

    def _all_buckets(self) -> list[int]:
        return [1 << p for p in range(self.max_batch.bit_length())]

    def compile_count(self, *, artifact_id: str | None = None) -> int:
        """Number of compiled entries in the hosted model's jit cache — the
        no-recompile-within-a-bucket property is pinned by asserting this
        stays flat across ragged request sizes."""
        return self._hosted(artifact_id).predict_fn._cache_size()

    def cache_stats(self, *, artifact_id: str | None = None) -> dict | None:
        hosted = self._hosted(artifact_id)
        return None if hosted.cache is None else hosted.cache.stats()

    def clear_cache(self, *, artifact_id: str | None = None) -> None:
        """Drop the model's cached predictions AND key memo (benchmark tier
        isolation; stats keep accumulating)."""
        hosted = self._hosted(artifact_id)
        if hosted.cache is not None:
            hosted.cache.clear()
        if hosted.keymemo is not None:
            hosted.keymemo.clear()

    # -- health -------------------------------------------------------------

    def attach_batcher(self, batcher) -> None:
        """Fold an attached MicroBatcher's stats into ``health()``."""
        self._batcher = batcher

    def health(self) -> dict:
        """One-call serving health snapshot: hosted models, request/error
        counters, last error, and — when a batcher is attached — its queue
        depth, shed rate, p99 and crash state.  Cheap enough to poll."""
        with self._lock:
            snap = {
                "models": sorted(self._models),
                "requests": self._n_requests,
                "warm_calls": self._n_predicts,
                "errors": self._n_errors,
                "last_error": self._last_error,
            }
        batcher = self._batcher
        if batcher is not None:
            b = batcher.stats()
            snap["batcher"] = {k: b[k] for k in
                               ("queue_depth", "queue_depth_hwm", "shed",
                                "shed_rate", "deadline_expired", "p99_us",
                                "crashed", "last_error")}
        snap["ok"] = bool(snap["models"]) and not (
            batcher is not None and snap["batcher"]["crashed"])
        return snap
