"""Sharded serving tier: multi-shard hash-join prediction behind the batcher.

``ShardedPredictor`` is the multi-device sibling of ``Predictor``: it hosts
models whose (m, B[, k]) bucket tables are SHARDED over a
(model_shards, data_shards) device mesh — the P(model, data) layout
``make_krr_step_hashjoin`` trains into and ``export_artifact_sharded``
ships — so models too big for one host still serve point predictions.

Per hosted model there is ONE jitted route→serve→readout program per
padding bucket, built on ``make_krr_predict_hashjoin``'s routing: queries
are padded to a power-of-two bucket (>= data_shards so every shard gets
rows), their (instance, slot) requests all_to_all to the owner shards, the
owners serve their table slices, and one value exchange + model psum
assembles the predictions.  The default is the factory's ``dedup=False``
interactive mode (raw requests on the wire — no layout sort, no routing
scatters, no overflow) which keeps warm p50 within a small factor of the
single-host path; ``dedup=True`` selects the training routing's
deduplicated wire for bulk scoring.  The wire payload is float32 here (not
the training default bf16): serving parity with the single-host path is
pinned bitwise on an unsharded (1x1) mesh and <= 1e-5 on sharded meshes
(collectives reassociate f32 sums), and a serving tier must not trade
accuracy for wire bytes it can afford at batch sizes.

The bucket-exact LRU cache (serve/cache.py) becomes PER-SHARD-AWARE: a
query's prediction depends only on the data shards its m slots touch
(owner = slot // spp), so the cache key folds in exactly that touch set
plus those shards' table-piece versions.  A hit skips the route/all_to_all
path entirely, and hot-swapping one shard's piece
(``bump_shard_version``) invalidates only the entries touching it.

Multi-model placement: several smaller models co-serve on one mesh by
assigning each a contiguous MODEL-AXIS row slice (``placement=(lo, hi)``);
each placement gets its own submesh, and ``health()`` reports per-shard
overflow counters (from the routing's dropped-bucket accounting, PR 7's
StepStats plumbing) next to the attached batcher's queue depth.
"""
from __future__ import annotations

import threading
from time import perf_counter
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .. import obs
from ..core.bucket_fns import get_bucket_fn
from ..core.distributed import KRRStepConfig, make_krr_predict_hashjoin
from ..errors import InvalidRequest
from .artifact import LoadedShardedArtifact, load_artifact_sharded
from .cache import BucketKeyFn, PredictionCache
from .predictor import DEFAULT_MAX_BATCH, padding_bucket

MODEL_AXIS = "model"
DATA_AXIS = "data"


def parse_mesh_shape(spec: str) -> tuple[int, int]:
    """'2x2' -> (2, 2): (model_shards, data_shards)."""
    try:
        mm, nd = spec.lower().split("x")
        shape = (int(mm), int(nd))
    except ValueError:
        raise ValueError(f"mesh spec must look like '2x2', got {spec!r}")
    if shape[0] <= 0 or shape[1] <= 0:
        raise ValueError(f"mesh shape must be positive, got {spec!r}")
    return shape


class _ShardedModel(NamedTuple):
    loaded: LoadedShardedArtifact
    placement: tuple[int, int]   # [lo, hi) model-axis rows of the host mesh
    submesh: Mesh
    predict_fn: object           # jitted (x, lsh, table) -> (yhat, dropped)
    lsh_dev: object              # LSHParams device_put P(model, None)
    table_dev: object            # (m, B[, k]) device_put P(model, data)
    keyfn: BucketKeyFn
    cache: PredictionCache | None
    keymemo: PredictionCache | None  # raw bytes -> (base key, touch tuple)
    shard_versions: np.ndarray   # (data_shards,) int64, bumped on hot-swap
    overflow: np.ndarray         # (data_shards,) int64 dropped-bucket counts


class ShardedPredictor:
    """Hosts sharded models on a (model_shards, data_shards) mesh and serves
    point predictions with the same API surface as ``Predictor`` (predict /
    warmup / compile_count / cache_stats / attach_batcher / health), so the
    MicroBatcher and launch/krr_serve.py front either interchangeably.
    """

    def __init__(self, *, mesh_shape: tuple[int, int] = (1, 1),
                 backend: str | None = None,
                 max_batch: int = DEFAULT_MAX_BATCH,
                 cache_entries: int = 0,
                 cap_factor: float = 4.0,
                 dedup: bool = False,
                 devices=None):
        mm, nd = int(mesh_shape[0]), int(mesh_shape[1])
        if nd & (nd - 1):
            raise ValueError(f"data_shards must be a power of two, got {nd}")
        if max_batch & (max_batch - 1) or max_batch < nd:
            raise ValueError(f"max_batch must be a power of two >= "
                             f"data_shards, got {max_batch} vs {nd}")
        devices = list(devices if devices is not None else jax.devices())
        if mm * nd > len(devices):
            raise ValueError(f"mesh {mm}x{nd} needs {mm * nd} devices, "
                             f"have {len(devices)}")
        self.mesh_shape = (mm, nd)
        self._devices = np.asarray(devices[:mm * nd]).reshape(mm, nd)
        self.mesh = Mesh(self._devices, (MODEL_AXIS, DATA_AXIS))
        self.backend = backend
        self.max_batch = int(max_batch)
        self.cache_entries = int(cache_entries)
        # dedup=False is the interactive default: the broadcast route has no
        # layout sort / routing scatters, which at padded serving batches is
        # several times lower latency than the dedup pack (and can never
        # overflow).  dedup=True switches to the training routing's
        # deduplicated wire for bulk scoring; cap_factor then defaults to
        # headroom-first 4.0, 2x the training default (small batches
        # concentrate on few owners; overflow drops mass).
        self.dedup = bool(dedup)
        self.cap_factor = float(cap_factor)
        self._models: dict[str, _ShardedModel] = {}
        self._default_id: str | None = None
        self._lock = threading.Lock()
        self._n_requests = 0
        self._n_predicts = 0
        self._n_errors = 0
        self._last_error: str | None = None
        self._batcher = None
        # same metric families as the single-host Predictor — one schema
        # across serving tiers, aggregated in the shared registry
        self._m_requests = obs.counter(
            "serve_requests_total", "predict() calls accepted").labels()
        self._m_errors = obs.counter(
            "serve_errors_total", "predict() calls that raised").labels()
        self._m_predict_us = obs.histogram(
            "serve_predict_us", "end-to-end predict() wall time").labels()
        self._m_warm_us = obs.histogram(
            "serve_warm_compute_us",
            "jitted warm-path wall time per call").labels()
        self._m_probe_us = obs.histogram(
            "serve_cache_probe_us",
            "bucket-key + cache probe wall time").labels()
        self._m_hits = obs.counter(
            "serve_cache_hits_total",
            "query rows served from the cache").labels()
        self._m_misses = obs.counter(
            "serve_cache_misses_total",
            "query rows that ran the warm path").labels()
        self._m_bucket = obs.counter(
            "serve_padding_bucket_total",
            "batches served per power-of-two padding bucket",
            labels=("bucket",))
        self._bucket_children: dict = {}   # bucket -> bound counter child
        # flat pre-bound timers (see Predictor): the per-request sites
        self._t_predict = obs.timer("serve.predict",
                                    to_histogram=self._m_predict_us)
        self._t_warm = obs.timer("serve.warm_compute",
                                 to_histogram=self._m_warm_us)

    # -- model hosting ------------------------------------------------------

    def load(self, directory: str, *, artifact_id: str | None = None,
             placement: tuple[int, int] | None = None, retries: int = 0,
             retry_backoff_s: float = 0.05) -> str:
        """Load a sharded artifact and host it on model rows
        ``placement=[lo, hi)`` (default: the whole model axis).  The
        artifact must have been exported for exactly the
        (hi-lo, data_shards) grid — ``load_artifact_sharded`` refuses a
        mismatched manifest.  ``retries`` re-attempts transient piece/manifest
        read failures with exponential backoff (same contract as
        ``Predictor.load``)."""
        lo, hi = placement or (0, self.mesh_shape[0])
        loaded = load_artifact_sharded(
            directory, mesh_shape=(hi - lo, self.mesh_shape[1]),
            backend=self.backend, artifact_id=artifact_id, retries=retries,
            retry_backoff_s=retry_backoff_s)
        return self.add_model(loaded, placement=(lo, hi))

    def add_model(self, loaded: LoadedShardedArtifact, *,
                  placement: tuple[int, int] | None = None) -> str:
        mm, nd = self.mesh_shape
        lo, hi = placement or (0, mm)
        if not (0 <= lo < hi <= mm):
            raise ValueError(f"placement {lo, hi} outside model axis "
                             f"[0, {mm})")
        if loaded.mesh_shape != (hi - lo, nd):
            raise ValueError(f"artifact sharded for mesh "
                             f"{loaded.mesh_shape}, placement {lo, hi} on a "
                             f"{mm}x{nd} mesh wants {(hi - lo, nd)}")
        model = loaded.model
        if model.tables.shape[0] % (hi - lo):
            raise ValueError(f"m={model.tables.shape[0]} not divisible by "
                             f"placement span {hi - lo}")
        submesh = (self.mesh if (lo, hi) == (0, mm) else
                   Mesh(self._devices[lo:hi], (MODEL_AXIS, DATA_AXIS)))
        cfg = KRRStepConfig(
            m=int(model.tables.shape[0]), table_size=int(model.table_size),
            lam=0.0, cg_iters=0, data_axes=(DATA_AXIS,),
            model_axis=MODEL_AXIS,
            backend=self.backend or model.backend)
        f = get_bucket_fn(model.bucket_name)
        lsh_sharding = jax.tree.map(
            lambda _: NamedSharding(submesh, P(MODEL_AXIS, None)), model.lsh)
        table_sharding = NamedSharding(submesh, P(MODEL_AXIS, DATA_AXIS))
        # in_shardings lets the warm path hand the jit a HOST array: the
        # query's host->device split runs on the C++ dispatch path instead
        # of a per-call python device_put, which at serving batches is a
        # large fraction of end-to-end latency on small meshes
        predict_fn = jax.jit(
            make_krr_predict_hashjoin(
                submesh, cfg, f, cap_factor=self.cap_factor,
                payload_dtype=jnp.float32, with_stats=True,
                dedup=self.dedup),
            in_shardings=(NamedSharding(submesh, P(DATA_AXIS, None)),
                          lsh_sharding, table_sharding))
        lsh_dev = jax.device_put(model.lsh, lsh_sharding)
        table_dev = jax.device_put(model.tables, table_sharding)
        hosted = _ShardedModel(
            loaded=loaded, placement=(lo, hi), submesh=submesh,
            predict_fn=predict_fn, lsh_dev=lsh_dev, table_dev=table_dev,
            keyfn=BucketKeyFn(model.lsh, f),
            cache=(PredictionCache(self.cache_entries)
                   if self.cache_entries > 0 else None),
            keymemo=(PredictionCache(self.cache_entries)
                     if self.cache_entries > 0 else None),
            shard_versions=np.zeros(nd, np.int64),
            overflow=np.zeros(nd, np.int64))
        with self._lock:
            self._models[loaded.artifact_id] = hosted
            if self._default_id is None:
                self._default_id = loaded.artifact_id
        obs.counter("serve_models_loaded_total",
                    "artifacts hosted over the process lifetime").inc()
        if hosted.cache is not None:
            # same pull-time cache gauges as the single-host Predictor — a
            # sharded-only process must expose the full serving contract
            cache = hosted.cache
            obs.gauge("serve_cache_entries", "live prediction-cache entries",
                      labels=("model",)).labels(loaded.artifact_id).set_fn(
                lambda cache=cache: cache.stats()["entries"])
            obs.gauge("serve_cache_evictions",
                      "prediction-cache evictions to date",
                      labels=("model",)).labels(loaded.artifact_id).set_fn(
                lambda cache=cache: cache.stats()["evictions"])
        # per-shard pull-time gauges, registered at hosting time so the
        # series EXIST (at 0) even in broadcast mode where overflow is
        # structurally impossible — an absent series and a zero series mean
        # different things to an alerting rule
        ovf = obs.gauge("serve_shard_overflow_dropped",
                        "distinct buckets dropped past routing capacity, "
                        "per data shard", labels=("model", "shard"))
        ver = obs.gauge("serve_shard_piece_version",
                        "hot-swap version of each data shard's table piece",
                        labels=("model", "shard"))
        for j in range(nd):
            ovf.labels(loaded.artifact_id, j).set_fn(
                lambda h=hosted, j=j: int(h.overflow[j]))
            ver.labels(loaded.artifact_id, j).set_fn(
                lambda h=hosted, j=j: int(h.shard_versions[j]))
        return loaded.artifact_id

    def _hosted(self, artifact_id: str | None) -> _ShardedModel:
        with self._lock:
            aid = artifact_id or self._default_id
            if aid is None or aid not in self._models:
                raise KeyError(f"no hosted model {aid!r}; "
                               f"have {sorted(self._models)}")
            return self._models[aid]

    def unload(self, artifact_id: str) -> bool:
        """Drop a hosted model (jitted programs, device-placed tables,
        caches).  Same contract as ``Predictor.unload``."""
        with self._lock:
            hosted = self._models.pop(artifact_id, None)
            if self._default_id == artifact_id:
                self._default_id = min(self._models, default=None)
        return hosted is not None

    @property
    def artifact_ids(self) -> list[str]:
        with self._lock:
            return sorted(self._models)

    def bump_shard_version(self, shard: int, *,
                           artifact_id: str | None = None) -> None:
        """Record that data shard ``shard``'s table piece changed (hot swap):
        cached entries whose slot set touches it stop matching, everything
        else keeps hitting."""
        hosted = self._hosted(artifact_id)
        if not 0 <= shard < self.mesh_shape[1]:
            raise ValueError(f"shard {shard} outside [0, "
                             f"{self.mesh_shape[1]})")
        with self._lock:
            hosted.shard_versions[shard] += 1

    # -- warm (sharded) path ------------------------------------------------

    def _bucket(self, n: int) -> int:
        # every data shard must receive rows: bucket >= data_shards
        return max(self.mesh_shape[1], padding_bucket(n, self.max_batch))

    def _predict_padded(self, hosted: _ShardedModel, x: np.ndarray):
        b = x.shape[0]
        bucket = self._bucket(b)
        ch = self._bucket_children.get(bucket)
        if ch is None:       # bind the labeled child once per padding bucket
            ch = self._bucket_children[bucket] = self._m_bucket.labels(bucket)
        ch.inc()
        if b == bucket and x.dtype == np.float32:
            xp = np.ascontiguousarray(x)   # already bucket-sized: no copy
        else:
            xp = np.zeros((bucket, x.shape[1]), np.float32)
            xp[:b] = x
        # host array straight in: in_shardings (add_model) places it
        out, dropped = hosted.predict_fn(xp, hosted.lsh_dev,
                                         hosted.table_dev)
        if self.dedup:
            # broadcast mode can't overflow (stats are structurally zero);
            # skipping the transfer keeps it off the warm critical path
            with self._lock:
                hosted.overflow[:] += np.asarray(dropped, np.int64)
        return np.asarray(out)[:b]

    def _predict_warm(self, hosted: _ShardedModel, x: np.ndarray):
        with self._lock:
            self._n_predicts += 1
        norm = hosted.loaded.norm
        with self._t_warm():
            if norm is not None:
                # host-side f32 normalization mirrors the single-host in-jit
                # one bitwise (both IEEE sub/div) — and matches the cache keys
                x = ((x - norm.x_mean) / norm.x_std).astype(np.float32)
            chunks = [self._predict_padded(hosted, x[i:i + self.max_batch])
                      for i in range(0, x.shape[0], self.max_batch)]
            out = chunks[0] if len(chunks) == 1 else np.concatenate(chunks)
            if norm is not None:
                out = (out * np.float32(norm.y_std)
                       + np.float32(norm.y_mean)).astype(out.dtype)
            return out

    def predict(self, x, *, artifact_id: str | None = None,
                use_cache: bool = True, validate: bool = True) -> np.ndarray:
        """Serve a (d,) point or (b, d) batch against the sharded table."""
        try:
            with self._t_predict():
                return self._predict(x, artifact_id=artifact_id,
                                     use_cache=use_cache, validate=validate)
        except BaseException as e:
            with self._lock:
                self._n_errors += 1
                self._last_error = repr(e)
            self._m_errors.inc()
            raise

    def _predict(self, x, *, artifact_id, use_cache, validate) -> np.ndarray:
        hosted = self._hosted(artifact_id)
        with self._lock:
            self._n_requests += 1
        self._m_requests.inc()
        x = np.asarray(x, np.float32)
        single = x.ndim == 1
        if single:
            x = x[None, :]
        if validate and not np.isfinite(x).all():
            bad = np.flatnonzero(~np.isfinite(x).all(axis=1))
            raise InvalidRequest(
                f"non-finite query row(s) {bad[:8].tolist()} "
                f"({len(bad)} of {x.shape[0]})")
        if hosted.cache is None or not use_cache:
            out = self._predict_warm(hosted, x)
            return out[0] if single else out

        t0 = perf_counter()
        keys = self._sharded_keys(hosted, x)
        found = hosted.cache.get_many(keys)
        self._m_probe_us.observe((perf_counter() - t0) * 1e6)
        miss = [i for i, v in enumerate(found) if v is None]
        if len(found) > len(miss):
            self._m_hits.inc(len(found) - len(miss))
        if miss:
            self._m_misses.inc(len(miss))
            fresh = self._predict_warm(hosted, x[miss])
            hosted.cache.put_many([keys[i] for i in miss], list(fresh))
            for j, i in enumerate(miss):
                found[i] = fresh[j]
        out = np.stack([v.copy() if isinstance(v, np.ndarray) else v
                        for v in found])
        return out[0] if single else out

    def _sharded_keys(self, hosted: _ShardedModel, x: np.ndarray
                      ) -> list[bytes]:
        """Per-row sharded cache key: bucket key + the touched shards' ids
        AND current piece versions.  The (base key, touch set) pair is
        deterministic in the raw row, so it memoizes exactly (as in
        ``Predictor._bucket_keys``); the version suffix is applied per
        lookup so a ``bump_shard_version`` takes effect immediately."""
        raw = [row.tobytes() for row in x]
        memo = (hosted.keymemo.get_many(raw) if hosted.keymemo is not None
                else [None] * len(raw))
        miss = [i for i, k in enumerate(memo) if k is None]
        if miss:
            norm = hosted.loaded.norm
            xm = x[miss]
            if norm is not None:
                xm = ((xm - norm.x_mean) / norm.x_std).astype(np.float32)
            fresh = hosted.keyfn.keys_with_touch(
                xm, table_size=int(hosted.loaded.model.table_size),
                n_shards=self.mesh_shape[1])
            if hosted.keymemo is not None:
                hosted.keymemo.put_many([raw[i] for i in miss], fresh)
            for j, i in enumerate(miss):
                memo[i] = fresh[j]
        with self._lock:
            versions = hosted.shard_versions.copy()
        out = []
        for base, touched in memo:
            tv = np.asarray([(j, versions[j]) for j in touched], np.int64)
            out.append(base + b"|shards" + tv.tobytes())
        return out

    # -- compile management -------------------------------------------------

    def warmup(self, *, artifact_id: str | None = None,
               sizes: tuple[int, ...] | None = None) -> int:
        """Pre-compile every padding bucket's route→serve→readout program
        (sharded compiles are the expensive ones — they lower collectives),
        so the first real request never pays one."""
        hosted = self._hosted(artifact_id)
        d = hosted.loaded.model.lsh.d
        buckets = sorted({self._bucket(s) for s in
                          (sizes or self._all_buckets())})
        for b in buckets:
            self._predict_padded(hosted, np.zeros((b, d), np.float32))
        return self.compile_count(artifact_id=artifact_id)

    def _all_buckets(self) -> list[int]:
        return [1 << p for p in range(self.max_batch.bit_length())]

    def compile_count(self, *, artifact_id: str | None = None) -> int:
        return self._hosted(artifact_id).predict_fn._cache_size()

    def cache_stats(self, *, artifact_id: str | None = None) -> dict | None:
        hosted = self._hosted(artifact_id)
        return None if hosted.cache is None else hosted.cache.stats()

    def clear_cache(self, *, artifact_id: str | None = None) -> None:
        hosted = self._hosted(artifact_id)
        if hosted.cache is not None:
            hosted.cache.clear()
        if hosted.keymemo is not None:
            hosted.keymemo.clear()

    # -- health -------------------------------------------------------------

    def attach_batcher(self, batcher) -> None:
        self._batcher = batcher

    def health(self) -> dict:
        """Serving health incl. the sharded tier's observables: mesh shape,
        per-model placement + per-data-shard overflow counters (distinct
        buckets dropped past routing capacity — nonzero means cap_factor
        needs headroom) and piece versions, plus the attached batcher's
        queue depth."""
        with self._lock:
            snap = {
                "models": sorted(self._models),
                "mesh": {"model": self.mesh_shape[0],
                         "data": self.mesh_shape[1]},
                "requests": self._n_requests,
                "warm_calls": self._n_predicts,
                "errors": self._n_errors,
                "last_error": self._last_error,
                "shards": {
                    aid: {"placement": list(h.placement),
                          "overflow": h.overflow.tolist(),
                          "piece_versions": h.shard_versions.tolist()}
                    for aid, h in self._models.items()},
            }
        batcher = self._batcher
        if batcher is not None:
            b = batcher.stats()
            snap["batcher"] = {k: b[k] for k in
                               ("queue_depth", "queue_depth_hwm", "shed",
                                "shed_rate", "deadline_expired", "p99_us",
                                "crashed", "last_error")}
        snap["ok"] = bool(snap["models"]) and not (
            batcher is not None and snap["batcher"]["crashed"])
        return snap
