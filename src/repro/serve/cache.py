"""Bucket-exact prediction cache.

A WLSH prediction depends on a query point only through its per-instance
bucket structure: readout is ``(1/m) sum_s coeff[s] * tables[s, slot[s]]``
with ``slot``/``sign`` pure functions of the m bucket ids ``(key1, key2)``
and — for the rect bucket fn (random binning, the paper's §5 serving choice)
— ``weight ≡ 1``, so ``coeff = sign`` is too.  Caching on the m-tuple of
bucket ids is therefore EXACT for rect: any two queries landing in the same
m buckets have bitwise-identical predictions, so near-duplicate traffic hits
without approximation.  For the smooth bucket fns the weight varies inside a
bucket, so the key additionally folds in the residual bytes — hits then
require an identical featurization (still exact, just only for repeated
points).

The key is computed HOST-SIDE in numpy, replicating core/lsh.featurize's
integer pipeline bit-for-bit (float32 IEEE sub/div/round, uint32 wraparound
linear hash + murmur3 finalizer — pinned against the jax path by
tests/test_serving.py).  That is the entire point: a cache hit costs one
small numpy evaluation plus a dict probe (microseconds) and never enters the
jit runtime, which is where the >=10x over the warm featurize+readout path
comes from.
"""
from __future__ import annotations

import threading
from collections import OrderedDict

import numpy as np

from ..core.bucket_fns import BucketFn
from ..core.lsh import LSHParams

# murmur3 finalizer constants — must match core/lsh._fmix32
_C1 = np.uint32(0x85EB_CA6B)
_C2 = np.uint32(0xC2B2_AE35)


def _fmix32_np(x: np.ndarray) -> np.ndarray:
    x = x ^ (x >> np.uint32(16))
    x = x * _C1
    x = x ^ (x >> np.uint32(13))
    x = x * _C2
    x = x ^ (x >> np.uint32(16))
    return x


class BucketKeyFn:
    """Host-side bucket-id keys for query rows, one opaque ``bytes`` per row.

    ``exact_within_bucket`` is True for rect (weight constant inside a
    bucket): keys are then purely the m (key1, key2) pairs and any
    same-bucket query hits.  Otherwise the float32 residual bytes ride along
    in the key, restricting hits to bitwise-identical featurizations.
    """

    def __init__(self, lsh: LSHParams, bucket: BucketFn):
        self.w = np.ascontiguousarray(lsh.w, np.float32)   # (m, d)
        self.z = np.ascontiguousarray(lsh.z, np.float32)
        # both universal-hash coefficient sets stacked: one multiply + one
        # wrapping sum + one fmix sweep produce key1 AND key2 (the hit path
        # is numpy-dispatch-bound, so op count is latency)
        self.r12 = np.stack([np.asarray(lsh.r1, np.uint32),
                             np.asarray(lsh.r2, np.uint32)])  # (2, m, d)
        self.exact_within_bucket = bucket.name == "rect"

    def bucket_ids(self, x: np.ndarray):
        """(keys, h, t): keys is (2, n, m) uint32 — [key1; key2] — plus the
        (n, m, d) rounded buckets / scaled positions.  A numpy mirror of
        core/lsh.featurize's hash pipeline (same IEEE f32 sub/div/round, same
        uint32 wraparound), so ids agree bitwise with the jit path."""
        x = np.asarray(x, np.float32)
        # NaN/inf queries reach the f32->int32 cast below; the resulting
        # rows are keyed by raw identity in __call__, so silence the cast's
        # RuntimeWarning here instead of spamming (or, under -W error,
        # crashing) the serving path
        with np.errstate(invalid="ignore"):
            t = (x[:, None, :] - self.z) / self.w      # (n, m, d) f32
            h = np.rint(t)                             # round-half-even, f32
            hi = h.astype(np.int32).view(np.uint32)    # same bits, no copy
        keys = _fmix32_np((hi[None] * self.r12[:, None]).sum(
            axis=-1, dtype=np.uint32))                 # (2, n, m)
        return keys, h, t

    def __call__(self, x: np.ndarray) -> list[bytes]:
        x = np.asarray(x, np.float32)
        keys, h, t = self.bucket_ids(x)
        n = keys.shape[1]
        # rows whose bucket coordinate leaves the well-defined f32->int32
        # range (NaN/inf or |h| >= 2^31) hash DIFFERENTLY in numpy vs XLA
        # (numpy collapses them all to 0x80000000; XLA saturates), so two
        # distinct garbage queries could alias one numpy key — such rows are
        # keyed by raw row identity instead: identical queries still hit,
        # distinct ones can never collide
        with np.errstate(invalid="ignore"):
            ok = (np.isfinite(h).all(axis=(1, 2))
                  & (np.abs(h) < 2147483648.0).all(axis=(1, 2)))
        if self.exact_within_bucket:
            if n == 1 and ok[0]:                       # serving fast path
                return [keys.tobytes()]
            out = [keys[:, i, :].tobytes() for i in range(n)]
        else:
            resid = h - t                              # weight varies in-bucket
            out = [keys[:, i, :].tobytes() + resid[i].tobytes()
                   for i in range(n)]
        for i in np.nonzero(~ok)[0]:
            out[i] = b"!raw" + x[i].tobytes()
        return out

    def keys_with_touch(self, x: np.ndarray, *, table_size: int,
                        n_shards: int):
        """Per-row ``(bucket key, touched-shard tuple)`` in ONE hash pass.

        The touched shards are the owners of the row's m table slots
        (``slot = key1 & (table_size-1)``, owner ``slot // spp`` — the
        hash-join layout of core/distributed.py): a sharded prediction
        depends on nothing else, so the sharded cache key only needs to
        change when one of THOSE shards' table pieces changes.  Rows whose
        bucket coordinates leave the well-defined f32->int32 range are keyed
        by raw identity (as in ``__call__``) and conservatively touch every
        shard."""
        x = np.asarray(x, np.float32)
        keys, h, t = self.bucket_ids(x)
        n = keys.shape[1]
        with np.errstate(invalid="ignore"):
            ok = (np.isfinite(h).all(axis=(1, 2))
                  & (np.abs(h) < 2147483648.0).all(axis=(1, 2)))
        if self.exact_within_bucket:
            out = [keys[:, i, :].tobytes() for i in range(n)]
        else:
            resid = h - t
            out = [keys[:, i, :].tobytes() + resid[i].tobytes()
                   for i in range(n)]
        owners = (keys[0] & np.uint32(table_size - 1)) \
            // np.uint32(table_size // n_shards)            # (n, m)
        every = tuple(range(n_shards))
        touched = [every if not ok[i]
                   else tuple(np.unique(owners[i]).tolist())
                   for i in range(n)]
        for i in np.nonzero(~ok)[0]:
            out[i] = b"!raw" + x[i].tobytes()
        return list(zip(out, touched))


class PredictionCache:
    """Thread-safe LRU from bucket key -> stored prediction row.

    Values are whatever the cold path produced (numpy scalars or (k,) rows,
    already denormalized) — a hit replays them verbatim, which is what the
    bitwise cache == cold-path test pins.  ``max_entries`` bounds memory;
    eviction is least-recently-USED (hits refresh recency).
    """

    def __init__(self, max_entries: int = 65536):
        if max_entries <= 0:
            raise ValueError("max_entries must be positive")
        self.max_entries = int(max_entries)
        self._data: OrderedDict[bytes, np.ndarray] = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get_many(self, keys: list[bytes]) -> list[np.ndarray | None]:
        """One locked pass: per-key value or None (miss)."""
        out: list[np.ndarray | None] = []
        with self._lock:
            for key in keys:
                val = self._data.get(key)
                if val is None:
                    self.misses += 1
                else:
                    self._data.move_to_end(key)
                    self.hits += 1
                out.append(val)
        return out

    def put_many(self, keys: list[bytes], values) -> None:
        with self._lock:
            for key, val in zip(keys, values):
                self._data[key] = val
                self._data.move_to_end(key)
            while len(self._data) > self.max_entries:
                self._data.popitem(last=False)
                self.evictions += 1

    def clear(self) -> None:
        """Drop every entry (stat counters keep accumulating)."""
        with self._lock:
            self._data.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)

    def stats(self) -> dict:
        with self._lock:
            total = self.hits + self.misses
            return {"entries": len(self._data), "hits": self.hits,
                    "misses": self.misses, "evictions": self.evictions,
                    "hit_rate": self.hits / total if total else 0.0}
