"""Micro-batching engine: coalesce single-point requests under a deadline.

State machine (one worker thread):

    IDLE     -- blocked on the queue; a request arrives -> FILLING and the
                flush deadline is armed at t_arrival + max_wait_us
    FILLING  -- drain further requests; flush when the batch hits max_batch
                or the deadline expires, whichever first
    FLUSH    -- stack the pending rows, run predict_fn once, resolve every
                request's future (or fail them all with the raised
                exception) -> IDLE

max_batch bounds tail latency under load (a full batch flushes immediately);
max_wait_us bounds it when idle (a lone request waits at most one deadline).
Each request costs its queue wait plus a 1/batch share of one warm-path call
— which is how single-point traffic gets batched-throughput economics.

``submit`` returns a ``concurrent.futures.Future``; the caller's thread never
blocks unless it asks for ``.result()``.  Stats are collected continuously
(served counts, batch-size histogram summary, latency percentiles over a
sliding window, queue depth) and read with ``stats()``.

Degraded-mode contract (DESIGN.md §9): every failure is a STRUCTURED result
on the request's future, never a hang —

* queue full (``max_queue``)      -> ``Overloaded``, failed at submit
* deadline elapsed in queue       -> ``DeadlineExceeded``, failed at flush
* predict_fn raised               -> that exception, batch-wide
* worker thread died              -> ``WorkerCrashed`` on every in-flight and
                                     queued future; later submits fail fast
"""
from __future__ import annotations

import collections
import math
import queue
import threading
import time
from concurrent.futures import Future

import numpy as np

from .. import obs
from ..errors import DeadlineExceeded, Overloaded, WorkerCrashed


class _Request:
    __slots__ = ("x", "future", "t_submit", "deadline")

    def __init__(self, x: np.ndarray, deadline: float | None = None):
        self.x = x
        self.future: Future = Future()
        self.t_submit = time.perf_counter()
        # absolute perf_counter time after which serving is pointless
        self.deadline = deadline


def percentile(sorted_vals, q: float) -> float:
    """Nearest-rank percentile of an already-sorted sequence: the
    ceil(q/100 * n)-th smallest value (so q=99 over 100 samples is the
    99th-smallest, not the maximum)."""
    if not sorted_vals:
        return float("nan")
    n = len(sorted_vals)
    rank = max(0, min(n - 1, math.ceil(q / 100.0 * n) - 1))
    return float(sorted_vals[rank])


class MicroBatcher:
    """Thread-safe request queue in front of a batch predict function.

    ``predict_fn`` maps a (b, d) float32 batch to per-row predictions; it is
    only ever called from the single worker thread, so it needs no locking of
    its own (the Predictor's jit path and cache are thread-safe anyway).
    """

    def __init__(self, predict_fn, *, max_batch: int = 64,
                 max_wait_us: int = 2000, latency_window: int = 4096,
                 dim: int | None = None, max_queue: int = 0,
                 deadline_us: int | None = None, on_crash=None):
        if max_batch <= 0:
            raise ValueError("max_batch must be positive")
        if max_queue < 0:
            raise ValueError("max_queue must be >= 0 (0 = unbounded)")
        self.predict_fn = predict_fn
        self.max_batch = int(max_batch)
        # load shedding: submits past this queue depth fail with Overloaded
        # instead of growing an unbounded backlog (0 disables)
        self.max_queue = int(max_queue)
        # default per-request deadline budget; a request still queued when
        # its budget elapses fails with DeadlineExceeded at flush time
        # (before predict — an expired request never costs model work)
        self.deadline_s = (None if deadline_us is None
                           else max(int(deadline_us), 0) * 1e-6)
        # one batcher fronts one model, so every row must share one d —
        # checked at submit() so a malformed request is rejected at ITS
        # call site instead of blowing up np.stack in _flush and failing
        # every innocent request coalesced into the same batch.  None =
        # locked in from the first accepted request.
        self._dim = int(dim) if dim is not None else None
        self.max_wait_s = max(int(max_wait_us), 0) * 1e-6
        self._queue: queue.Queue[_Request | None] = queue.Queue()
        self._latencies = collections.deque(maxlen=latency_window)
        self._lock = threading.Lock()
        self._n_requests = 0
        self._n_served = 0
        self._n_batches = 0
        self._batch_rows = 0
        self._t_first: float | None = None
        self._t_last: float | None = None
        self._n_shed = 0
        self._n_expired = 0
        self._queue_hwm = 0
        self._last_error: str | None = None
        # registry children resolved once (name->family lookups off the
        # submit/flush paths); instance stats() stays per-batcher exact,
        # the global registry aggregates across batchers
        self._m_queue_wait = obs.histogram(
            "serve_queue_wait_us", "request wait from submit to flush").labels()
        self._m_predict = obs.histogram(
            "serve_batch_predict_us", "predict_fn wall time per batch").labels()
        self._m_batch_size = obs.histogram(
            "serve_batch_size", "rows coalesced per flushed batch",
            buckets=obs.COUNT_BUCKETS).labels()
        self._m_requests = obs.counter(
            "serve_batcher_requests_total", "requests submitted").labels()
        self._m_served = obs.counter(
            "serve_batcher_served_total", "requests served successfully").labels()
        self._m_batches = obs.counter(
            "serve_batcher_batches_total", "batches flushed").labels()
        self._m_shed = obs.counter(
            "serve_batcher_shed_total", "requests shed at max_queue").labels()
        self._m_expired = obs.counter(
            "serve_batcher_deadline_expired_total",
            "requests expired in queue before predict").labels()
        self._m_hwm = obs.gauge(
            "serve_queue_depth_hwm", "high-water mark of the request queue").labels()
        # flat pre-bound timer: one per flushed batch on the worker thread
        self._t_batch = obs.timer("serve.batch_predict",
                                  to_histogram=self._m_predict)
        self._closed = False
        self._crashed: BaseException | None = None
        self._inflight: list[_Request] | None = None
        self._fault_hook = None         # test injection (faults.crash_worker)
        # supervision hook (lifecycle.SupervisedBatcher): called with the
        # fatal exception AFTER the crash state is set but BEFORE any future
        # fails, so by the time a caller observes a WorkerCrashed result the
        # supervisor has already recorded the crash (breaker trip, restart
        # scheduling) — no window where a fast retry misses the breaker
        self._on_crash = on_crash
        self._worker = threading.Thread(target=self._run, daemon=True,
                                        name="microbatcher")
        self._worker.start()

    # -- client side --------------------------------------------------------

    def submit(self, x_row, *, deadline_us: int | None = None) -> Future:
        """Enqueue one d-dimensional point; resolves to its prediction.

        ``deadline_us`` overrides the batcher's default budget for this
        request.  A shed/expired/crashed request still gets a future — one
        already failed with the structured error."""
        req = _Request(np.asarray(x_row, np.float32).reshape(-1))
        budget = (deadline_us * 1e-6 if deadline_us is not None
                  else self.deadline_s)
        if budget is not None:
            req.deadline = req.t_submit + budget
        # the closed-check and the enqueue are one atomic step: close() flips
        # the flag and enqueues its sentinel under the same lock, so either
        # this request lands BEFORE the sentinel (and is served/drained) or
        # the submit raises — a request can never slip in behind the drain
        # and leave its future forever unresolved
        with self._lock:
            if self._crashed is not None:
                raise WorkerCrashed(
                    f"batcher worker died: {self._crashed!r}")
            if self._closed:
                raise RuntimeError("batcher is closed")
            if self._dim is None:
                self._dim = req.x.shape[0]
            elif req.x.shape[0] != self._dim:
                raise ValueError(f"request has {req.x.shape[0]} features, "
                                 f"batcher serves d={self._dim}")
            self._n_requests += 1
            if self.max_queue and self._queue.qsize() >= self.max_queue:
                self._n_shed += 1
                depth = self._queue.qsize()
                self._m_requests.inc()
                self._m_shed.inc()
                req.future.set_exception(Overloaded(
                    f"request shed: queue depth {depth} >= "
                    f"max_queue {self.max_queue}", queue_depth=depth))
                return req.future
            self._queue.put(req)
            depth = self._queue.qsize()
            if depth > self._queue_hwm:
                self._queue_hwm = depth
                self._m_hwm.set(depth)
        # accepted requests hit serve_batcher_requests_total at FLUSH time
        # (one inc per batch, not per submit) — only sheds inc here
        return req.future

    def predict(self, x_row, *, timeout: float | None = None,
                deadline_us: int | None = None):
        """Synchronous submit + wait.  ``timeout`` bounds the caller's wait
        (``concurrent.futures.TimeoutError``); structured serving errors
        (Overloaded, DeadlineExceeded, WorkerCrashed) re-raise here."""
        return self.submit(x_row, deadline_us=deadline_us).result(timeout)

    def close(self, timeout: float | None = None) -> None:
        """Stop the worker.  Everything already submitted is served first:
        submit() and close() serialize on one lock, so every accepted
        request sits FIFO-ahead of the stop sentinel and the worker flushes
        them all before it exits."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            self._queue.put(None)                   # wake + stop sentinel
        self._worker.join(timeout)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -- worker side --------------------------------------------------------

    def _run(self) -> None:
        try:
            while True:
                req = self._queue.get()             # IDLE
                if req is None:
                    return
                batch = [req]                       # FILLING
                deadline = time.perf_counter() + self.max_wait_s
                stop = False
                while len(batch) < self.max_batch:
                    try:
                        # anything ALREADY queued joins the batch at once —
                        # under backlog the deadline never delays (or
                        # starves) coalescing, it only bounds the wait for
                        # new arrivals
                        nxt = self._queue.get_nowait()
                    except queue.Empty:
                        timeout = deadline - time.perf_counter()
                        if timeout <= 0:
                            break
                        try:
                            nxt = self._queue.get(timeout=timeout)
                        except queue.Empty:
                            break
                    if nxt is None:
                        stop = True
                        break
                    batch.append(nxt)
                self._dispatch(batch)               # FLUSH -> IDLE
                if stop:
                    return
        except BaseException as e:
            # a genuine worker death (not a predict_fn error — _flush
            # already contains those batch-wide): fail everything, fast
            self._crash(e)

    def _dispatch(self, batch: list[_Request]) -> None:
        # _inflight is what _crash fails if anything below dies; the fault
        # hook fires OUTSIDE _flush's predict try/except on purpose — it
        # simulates the worker thread itself dying, not a model error
        self._inflight = batch
        hook = self._fault_hook
        if hook is not None:
            hook(batch)
        self._flush(batch)
        self._inflight = None

    def _crash(self, e: BaseException) -> None:
        with self._lock:
            self._crashed = e
            self._closed = True
            self._last_error = repr(e)
        if self._on_crash is not None:
            try:
                self._on_crash(e)
            except Exception:
                pass    # supervision must never mask the crash drain below
        err = WorkerCrashed(f"batcher worker died: {e!r}")
        err.__cause__ = e
        for r in self._inflight or []:
            if not r.future.done():
                r.future.set_exception(err)
        # drain everything queued behind the death; submit() checks
        # _crashed under the same lock BEFORE enqueueing, so nothing can
        # land after this drain and hang forever
        while True:
            try:
                nxt = self._queue.get_nowait()
            except queue.Empty:
                return
            if nxt is not None and not nxt.future.done():
                nxt.future.set_exception(err)

    def _flush(self, batch: list[_Request]) -> None:
        now = time.perf_counter()
        live = []
        expired = 0
        waits = []
        for r in batch:
            waits.append((now - r.t_submit) * 1e6)
            if r.deadline is not None and now > r.deadline:
                waited = now - r.t_submit
                r.future.set_exception(DeadlineExceeded(
                    f"deadline elapsed after {waited * 1e6:.0f}us in queue",
                    waited_s=waited))
                expired += 1
            else:
                live.append(r)
        if live:
            try:
                with self._t_batch():
                    out = self.predict_fn(np.stack([r.x for r in live]))
            except BaseException as e:
                with self._lock:
                    self._last_error = repr(e)
                for r in live:
                    r.future.set_exception(e)
                self._record_flush(waits, expired, served=None)
                return
            now = time.perf_counter()
            with self._lock:
                if self._t_first is None:
                    self._t_first = live[0].t_submit
                self._t_last = now
                self._n_batches += 1
                self._batch_rows += len(live)
                self._n_served += len(live)
                for r in live:
                    self._latencies.append(now - r.t_submit)
            for r, row in zip(live, np.asarray(out)):
                r.future.set_result(row)
        # registry recording runs AFTER every future is resolved: metrics
        # must never sit on the response critical path (they only eat
        # worker headroom between batches)
        self._record_flush(waits, expired, served=len(live) if live else None)

    def _record_flush(self, waits, expired: int, served: int | None) -> None:
        self._m_queue_wait.observe_many(waits)   # one lock for the batch
        self._m_requests.inc(len(waits))         # accepted-request count,
        if expired:                              # batched off the submit path
            self._m_expired.inc(expired)
            with self._lock:
                self._n_expired += expired
        if served is not None:
            self._m_batch_size.observe(served)
            self._m_batches.inc()
            self._m_served.inc(served)

    # -- observability ------------------------------------------------------

    def stats(self) -> dict:
        """Snapshot: served/batch counts, mean coalesced batch size, sliding-
        window latency percentiles (us), achieved QPS, live queue depth and
        its high-water mark, plus the degraded-mode counters (shed,
        deadline-expired, crash state)."""
        with self._lock:
            lat = sorted(self._latencies)
            span = (self._t_last - self._t_first) \
                if self._t_first is not None and self._t_last is not None \
                else 0.0
            return {
                "requests": self._n_requests,
                "served": self._n_served,
                "batches": self._n_batches,
                "mean_batch": (self._batch_rows / self._n_batches
                               if self._n_batches else 0.0),
                "queue_depth": self._queue.qsize(),
                "queue_depth_hwm": self._queue_hwm,
                "p50_us": percentile(lat, 50) * 1e6,
                "p99_us": percentile(lat, 99) * 1e6,
                "qps": self._n_served / span if span > 0 else 0.0,
                "shed": self._n_shed,
                "shed_rate": (self._n_shed / self._n_requests
                              if self._n_requests else 0.0),
                "deadline_expired": self._n_expired,
                "crashed": self._crashed is not None,
                "last_error": self._last_error,
            }
