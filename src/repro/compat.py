"""JAX version shims.

The codebase targets modern JAX (``jax.shard_map``, ``jax.sharding.AxisType``,
``check_vma``), but CI containers may pin 0.4.x where shard_map still lives in
``jax.experimental.shard_map`` with the ``check_rep`` keyword and meshes have
no axis_types.  All mesh/shard_map construction goes through these two
helpers so the rest of the code is version-agnostic.
"""
from __future__ import annotations

import jax


def shard_map(fn, *, mesh, in_specs, out_specs):
    """jax.shard_map with replication/VMA checking off, any jax version."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)


def make_mesh(shape, axes):
    """jax.make_mesh with Auto axis types where the API supports them."""
    if not hasattr(jax, "make_mesh"):        # jax < 0.4.35
        from jax.experimental import mesh_utils
        return jax.sharding.Mesh(mesh_utils.create_device_mesh(shape), axes)
    try:
        axis_types = (jax.sharding.AxisType.Auto,) * len(axes)
    except AttributeError:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=axis_types)
