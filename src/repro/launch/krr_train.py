"""Distributed WLSH-KRR driver — the paper's own workload on a jax mesh.

    PYTHONPATH=src python -m repro.launch.krr_train --dataset forest \
        --scale 0.01 --m 64 --lam 0.5

On this CPU container the mesh is whatever devices exist (1 by default; use
XLA_FLAGS=--xla_force_host_platform_device_count=8 to exercise the collective
paths).  On a real fleet the same code runs on the production mesh — the step
function is the one the multi-pod dry-run lowers (launch/dryrun.py --cells krr).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from .. import obs
from ..core.bucket_fns import get_bucket_fn
from ..core.distributed import (KRRStepConfig, OVERFLOW_POLICIES,
                                make_krr_predict, make_krr_predict_hashjoin,
                                make_krr_step, run_krr_step_resilient,
                                sample_sharded_lsh)
from ..core.precond import DEFAULT_NYSTROM_RANK
from ..core.lsh import GammaPDF
from ..data import make_regression_dataset
from .mesh import make_host_mesh

# hashjoin all_to_all payload dtypes (configs.wlsh_krr.wire_dtype mirrors)
WIRE_DTYPES = {"f32": jnp.float32, "bf16": jnp.bfloat16}


def _pad_to(x, mult):
    n = x.shape[0]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, widths), n


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="wine",
                    choices=["wine", "insurance", "ct_slices", "forest"])
    ap.add_argument("--scale", type=float, default=0.1,
                    help="dataset size fraction (CPU-friendly)")
    ap.add_argument("--m", type=int, default=128)
    ap.add_argument("--lam", type=float, default=0.5)
    ap.add_argument("--bucket", default="rect", choices=["rect", "tent", "smooth"])
    ap.add_argument("--lengthscale", type=float, default=4.0)
    ap.add_argument("--cg-iters", type=int, default=50)
    ap.add_argument("--table-size", type=int, default=0)
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "reference", "pallas"],
                    help="WLSH operator backend inside each shard "
                         "(auto = pallas on TPU, reference elsewhere)")
    ap.add_argument("--fused", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="one-pass slot-blocked matvec for the CG solve "
                         "(used when the data axes are unsharded; --no-fused "
                         "forces the split scatter->gather path for A/B runs)")
    ap.add_argument("--blocked-split", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="visit-list split kernels for the sharded psum "
                         "path (pallas backend): scatter/gather walk only "
                         "real (point block, table tile) collisions while "
                         "the (m, B) tables stay psum-able; "
                         "--no-blocked-split keeps the cross-product grid "
                         "for A/B runs")
    ap.add_argument("--precond", default="none",
                    choices=["none", "jacobi", "nystrom"],
                    help="PCG preconditioner (core/precond.py): jacobi works "
                         "on any mesh; nystrom needs unsharded data axes "
                         "(single data shard) — it cuts ill-conditioned "
                         "(small --lam) iteration counts by >3x")
    ap.add_argument("--precond-rank", type=int, default=DEFAULT_NYSTROM_RANK,
                    help="Nyström pivot rank (ignored by none/jacobi)")
    ap.add_argument("--table-mode", default="psum",
                    choices=["psum", "hashjoin"],
                    help="bucket-table merge strategy: psum keeps the dense "
                         "(m, B) tables (paper-faithful); hashjoin shards "
                         "the table over the data axes and all_to_all-routes "
                         "only the nonzeros (DESIGN.md §6) — prediction "
                         "consumes the sharded table directly")
    ap.add_argument("--cap-factor", type=float, default=2.0,
                    help="hashjoin per-destination routing capacity factor "
                         "(cap ~ cap_factor·e/n_shards; overflow buckets "
                         "are dropped — tests pin the behavior)")
    ap.add_argument("--overflow", default="warn",
                    choices=list(OVERFLOW_POLICIES),
                    help="hashjoin capacity-overflow policy (DESIGN.md §9): "
                         "raise = fail the step with WireOverflowError, "
                         "warn = log and continue, allow = silent but still "
                         "counted")
    ap.add_argument("--wire-dtype", default="bf16",
                    choices=sorted(WIRE_DTYPES),
                    help="hashjoin all_to_all payload dtype: bf16 halves "
                         "the wire bytes (f32 accumulate, accuracy pinned); "
                         "f32 gives exact psum parity")
    ap.add_argument("--num-rhs", type=int, default=1,
                    help="solve an (n, k) RHS block: column 0 is y, the "
                         "rest are unit-normal probes — demonstrates the "
                         "multi-RHS matvec amortization (fit time is far "
                         "below k single solves)")
    ap.add_argument("--trace-dir", default=None, metavar="DIR",
                    help="capture a jax.profiler trace of the solve into DIR "
                         "(view with TensorBoard); also turns obs spans into "
                         "TraceAnnotations so fit/dist phases show up named "
                         "on the trace timeline")
    ap.add_argument("--metrics-dump", default=None, metavar="PATH",
                    help="append a JSONL metrics snapshot to PATH on exit")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    xtr, ytr, xte, yte = make_regression_dataset(args.dataset, args.seed,
                                                 scale=args.scale)
    mesh = make_host_mesh()
    n_shards = mesh.devices.size
    xtr, n_tr = _pad_to(xtr, n_shards)
    ytr, _ = _pad_to(ytr, n_shards)          # padded rows: y=0 -> beta ~ 0
    xte_p, n_te = _pad_to(xte, n_shards)
    d = xtr.shape[1]
    table = args.table_size or (1 << max(10, (4 * xtr.shape[0] - 1).bit_length()))

    cfg = KRRStepConfig(m=args.m, table_size=table, lam=args.lam,
                        cg_iters=args.cg_iters, data_axes=("data",),
                        model_axis="model", backend=args.backend,
                        fused=args.fused, blocked_split=args.blocked_split,
                        precond=args.precond,
                        precond_rank=args.precond_rank,
                        overflow=args.overflow)
    f = get_bucket_fn(args.bucket)
    lsh = sample_sharded_lsh(jax.random.PRNGKey(args.seed + 1), args.m, d,
                             GammaPDF(2.0, 1.0), args.lengthscale)

    if args.num_rhs > 1:
        # column 0 is the real target; the probe columns ride the same
        # matvecs/collectives, so fit time shows the block amortization
        probes = jax.random.normal(jax.random.PRNGKey(args.seed + 2),
                                   (ytr.shape[0], args.num_rhs - 1))
        ytr = jnp.concatenate([ytr[:, None], probes], axis=1)

    if args.trace_dir:
        if not obs.start_trace(args.trace_dir):
            print("[krr] --trace-dir ignored: jax.profiler unavailable")
    if args.table_mode == "hashjoin":
        # the resilient runner applies --overflow to the step's fault
        # counters and retries a non-finite solve once on an f32 wire
        wire = WIRE_DTYPES[args.wire_dtype]
        predict = jax.jit(make_krr_predict_hashjoin(
            mesh, cfg, f, cap_factor=args.cap_factor, payload_dtype=wire))
        t0 = time.time()
        beta, resnorm, tables, stats = run_krr_step_resilient(
            mesh, cfg, f, xtr, ytr, lsh, cap_factor=args.cap_factor,
            payload_dtype=wire)
        jax.block_until_ready(beta)
        t_fit = time.time() - t0
        dropped = int(stats.overflow_dropped)
        if dropped:
            print(f"[krr] hashjoin dropped {dropped} bucket(s) past "
                  f"capacity (policy={args.overflow})")
    else:
        step = jax.jit(make_krr_step(mesh, cfg, f))
        predict = jax.jit(make_krr_predict(mesh, cfg, f))
        t0 = time.time()
        with obs.span("train.solve", {"table_mode": args.table_mode}):
            beta, resnorm, tables = step(xtr, ytr, lsh)
            jax.block_until_ready(beta)
        t_fit = time.time() - t0
    if args.trace_dir and obs.stop_trace():
        print(f"[krr] profiler trace -> {args.trace_dir} "
              f"(tensorboard --logdir {args.trace_dir})")
    yhat = predict(xte_p, lsh, tables)[:n_te]
    if args.num_rhs > 1:
        yhat, resnorm = yhat[:, 0], resnorm[0]
    rmse = float(jnp.sqrt(jnp.mean((yhat - yte) ** 2)))
    print(f"[krr] {args.dataset} scale={args.scale}: n={n_tr} d={d} "
          f"m={args.m} B={table} backend={args.backend} fused={args.fused} "
          f"precond={args.precond} num_rhs={args.num_rhs} "
          f"table_mode={args.table_mode} wire={args.wire_dtype}")
    print(f"[krr] fit {t_fit:.2f}s on {n_shards} shard(s); "
          f"CG residual {float(resnorm):.2e}; test RMSE {rmse:.4f} "
          f"(label std = 1.0)")
    _print_solve_metrics(args)
    if args.metrics_dump:
        obs.REGISTRY.write_jsonl(args.metrics_dump,
                                 extra={"driver": "krr_train",
                                        "dataset": args.dataset})
        print(f"[krr] metrics snapshot -> {args.metrics_dump}")
    return 0


def _print_solve_metrics(args) -> None:
    """Per-solve telemetry summary off the obs registry/spans — the same
    numbers /metrics would export, for headless runs with no scraper."""
    span = ("dist.krr_step" if args.table_mode == "hashjoin"
            else "train.solve")
    st = obs.span_stats(span)
    if st["count"]:
        print(f"[krr] obs: span {span} x{st['count']} "
              f"p50 {st['p50_us']/1e3:.1f}ms max {st['max_us']/1e3:.1f}ms")
    if args.table_mode == "hashjoin":
        snap = obs.REGISTRY.snapshot()

        def _val(name, default=0.0):
            fam = snap.get(name)
            if not fam or not fam.get("series"):
                return default
            return fam["series"][0].get("value", default)

        print(f"[krr] obs: hashjoin routing builds "
              f"{_val('hashjoin_routing_builds_total'):.0f}, route cap "
              f"{_val('hashjoin_route_cap'):.0f} (owner max "
              f"{_val('hashjoin_route_owner_max'):.0f}), a2a payload "
              f"{_val('hashjoin_a2a_payload_bytes')/1e6:.2f} MB, overflow "
              f"dropped {_val('hashjoin_overflow_dropped_total'):.0f}, wire "
              f"retries {_val('dist_wire_retry_total'):.0f}")


if __name__ == "__main__":
    raise SystemExit(main())
