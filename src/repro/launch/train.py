"""End-to-end LM training driver: data pipeline -> jit train step -> fault-
tolerant loop with async checkpointing.

    PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b --smoke \
        --steps 200 --batch 8 --seq 256 --ckpt-dir /tmp/run1

``--smoke`` uses the reduced same-family config (CPU-trainable ~100M-and-below
scale); omit it on real hardware to train the full config.  The loop resumes
from the newest complete checkpoint automatically, so rerunning the same
command after a crash continues the run (examples/train_lm.py demonstrates
an injected-failure restart).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import registry
from ..data import synthetic_lm_batch
from ..models import model
from ..optim import AdamWConfig, adamw_init
from ..runtime import FailureInjector, RestartableLoop, StragglerWatchdog
from .steps import make_train_step


def build(arch: str, *, smoke: bool, steps: int, lr: float, dtype,
          num_microbatches: int = 1):
    cfg = registry.smoke_config(arch) if smoke else registry.get_config(arch)
    opt_cfg = AdamWConfig(lr=lr, total_steps=steps,
                          warmup_steps=max(10, steps // 20))
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, dtype=dtype,
                                      num_microbatches=num_microbatches))
    return cfg, step_fn


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=registry.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU scale)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--micro", type=int, default=1)
    ap.add_argument("--fail-at", type=int, nargs="*", default=(),
                    help="inject failures at these steps (FT demo)")
    ap.add_argument("--f32", action="store_true")
    args = ap.parse_args()

    dtype = jnp.float32 if args.f32 else jnp.bfloat16
    cfg, step_fn = build(args.arch, smoke=args.smoke, steps=args.steps,
                         lr=args.lr, dtype=dtype,
                         num_microbatches=args.micro)
    print(f"[train] {cfg.name}: {model.count_params(cfg):,} params "
          f"(family={cfg.family})")

    key = jax.random.PRNGKey(args.seed)
    params = model.init(cfg, key)
    state0 = {"params": params, "opt": adamw_init(params)}

    def data_for(step: int):
        batch = synthetic_lm_batch(args.seed, step, batch=args.batch,
                                   seq=args.seq, vocab=cfg.vocab_size)
        if cfg.encoder is not None:
            batch["frames"] = 0.02 * jax.random.normal(
                jax.random.fold_in(key, step),
                (args.batch, cfg.encoder.n_frames, cfg.d_model), dtype)
        elif cfg.cross_attn_source_len:
            batch["patches"] = 0.02 * jax.random.normal(
                jax.random.fold_in(key, step),
                (args.batch, cfg.cross_attn_source_len, cfg.d_model), dtype)
        return batch

    losses = []

    def loop_step(state, step):
        p, o, metrics = step_fn(state["params"], state["opt"], data_for(step))
        return {"params": p, "opt": o}, metrics

    def on_metrics(step, metrics):
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % 10 == 0 or step <= 3:
            print(f"[train] step {step:5d} loss {loss:8.4f} "
                  f"gnorm {float(metrics['grad_norm']):7.3f} "
                  f"lr {float(metrics['lr']):.2e}")

    loop = RestartableLoop(
        loop_step, args.ckpt_dir, checkpoint_every=args.ckpt_every,
        watchdog=StragglerWatchdog(),
        injector=FailureInjector(at_steps=tuple(args.fail_at)))
    t0 = time.time()
    result = loop.run(state0, args.steps, on_metrics=on_metrics)
    dt = time.time() - t0
    first = sum(losses[:10]) / max(len(losses[:10]), 1)
    last = sum(losses[-10:]) / max(len(losses[-10:]), 1)
    print(f"[train] done: {result.step} steps in {dt:.1f}s; "
          f"loss {first:.4f} -> {last:.4f} "
          f"(restarts={loop.restarts}, stragglers={len(loop.watchdog.stragglers)})")
    return 0 if last < first else 2


if __name__ == "__main__":
    raise SystemExit(main())
