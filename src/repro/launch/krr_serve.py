"""Online KRR serving driver: load an exported artifact, serve a request
stream through the micro-batcher, report latency/QPS/cache stats.

    # export first (examples/quickstart.py --export /tmp/krr_artifact), then:
    PYTHONPATH=src python -m repro.launch.krr_serve --artifact /tmp/krr_artifact \
        --requests 2000 --dup-frac 0.5

    # self-contained smoke (fit -> export -> serve -> verify; used by CI):
    PYTHONPATH=src python -m repro.launch.krr_serve --selftest

    # live observability: Prometheus /metrics + JSON /healthz on a local
    # port (DESIGN.md §11); --metrics-dump appends a JSONL snapshot on exit:
    PYTHONPATH=src python -m repro.launch.krr_serve --selftest \
        --metrics-port 9100 --metrics-dump /tmp/krr_metrics.jsonl

    # SHARDED serving on a (model x data) device mesh (table pieces sharded
    # P(model, data), hash-join routing — DESIGN.md §10); 4 fake CPU devices:
    XLA_FLAGS=--xla_force_host_platform_device_count=4 PYTHONPATH=src \
        python -m repro.launch.krr_serve --selftest --mesh 2x2

The request stream is synthetic by default (uniform points in the training
box, with ``--dup-frac`` of requests replaying earlier queries — that is the
traffic the bucket-exact cache exists for) or file-driven via ``--input``
pointing at an (n, d) ``.npy``.  Every request goes through submit -> coalesce
-> padded warm path (or cache hit) -> future, i.e. the exact production path.
"""
from __future__ import annotations

import argparse
import contextlib
import sys
import tempfile
import time

import numpy as np

from .. import obs
from ..serve import (DeadlineExceeded, LifecycleConfig, MicroBatcher,
                     Overloaded, Predictor, ServingRuntime, ShardedPredictor,
                     WorkerCrashed, bucket_sizes, parse_mesh_shape,
                     version_dir)

# series the live endpoint must expose once the selftest traffic has run —
# the CI serving job scrapes /metrics and fails if any are absent
_REQUIRED_SERIES = (
    "serve_requests_total", "serve_predict_us", "serve_warm_compute_us",
    "serve_padding_bucket_total", "serve_cache_hits_total",
    "serve_cache_misses_total", "serve_cache_entries",
    "serve_models_loaded_total", "serve_batcher_requests_total",
    "serve_batcher_served_total", "serve_queue_wait_us", "serve_batch_size",
    "serve_batch_predict_us", "serve_queue_depth_hwm",
)
# extra series that must exist under --mesh (registered per shard at load,
# so an alerting rule can tell "zero overflow" from "not sharded")
_SHARDED_SERIES = ("serve_shard_overflow_dropped", "serve_shard_piece_version")
# extra series under --watch: every lifecycle transition and breaker state
# change must be scrapeable, or the self-healing loop is invisible to ops
_LIFECYCLE_SERIES = (
    "lifecycle_reloads_total", "lifecycle_canary_total",
    "lifecycle_swaps_total", "lifecycle_rollbacks_total",
    "lifecycle_rollback_exhausted_total", "lifecycle_probation_total",
    "lifecycle_nonfinite_predictions_total", "lifecycle_active_version",
    "lifecycle_versions_retained", "lifecycle_worker_crashes_total",
    "lifecycle_worker_restarts_total", "breaker_state",
    "breaker_transitions_total", "breaker_rejections_total",
)


def _verify_metrics(url: str, predictor, *, sharded: bool,
                    lifecycle: bool = False) -> str | None:
    """Scrape the live endpoint and check the contract: every required
    series present on /metrics, /healthz green with the predictor component.
    Returns an error string, or None when the endpoint checks out."""
    import json
    import urllib.request

    obs.add_health_provider("predictor", predictor.health)
    try:
        with urllib.request.urlopen(f"{url}/metrics", timeout=10) as resp:
            text = resp.read().decode()
        need = (_REQUIRED_SERIES + (_SHARDED_SERIES if sharded else ())
                + (_LIFECYCLE_SERIES if lifecycle else ()))
        missing = [n for n in need if f"# TYPE {n} " not in text]
        if missing:
            return f"/metrics missing series: {missing}"
        with urllib.request.urlopen(f"{url}/healthz", timeout=10) as resp:
            doc = json.loads(resp.read().decode())
        if doc.get("status") != "ok":
            return f"/healthz degraded: {doc}"
        if "predictor" not in doc.get("components", {}):
            return "/healthz missing the predictor component"
        return None
    finally:
        obs.remove_health_provider("predictor")


def _synthetic_stream(d: int, n_requests: int, dup_frac: float,
                      seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    fresh = (rng.uniform(0.0, 2.0, size=(n_requests, d))
             .astype(np.float32))
    out = fresh.copy()
    # the first request can never be a replay, so a "fraction" of 1.0 means
    # every row after it
    n_dup = min(int(dup_frac * n_requests), max(n_requests - 1, 0))
    if n_dup:
        # replay earlier rows: repeats arrive interleaved, like real traffic.
        # ascending order matters — processing position i only after every
        # j < i is final keeps each copied row actually present earlier in
        # the stream (unsorted, ~18% of the dups silently went unique)
        dup_pos = rng.choice(n_requests - 1, size=n_dup, replace=False) + 1
        for i in np.sort(dup_pos):
            out[i] = out[rng.integers(0, i)]
    return out


def serve_stream(predictor: Predictor, stream: np.ndarray, *,
                 max_batch: int, max_wait_us: int,
                 target_qps: float = 0.0, max_queue: int = 0,
                 deadline_us: int | None = None,
                 runtime: ServingRuntime | None = None) -> dict:
    """Push every row of ``stream`` through a MicroBatcher; returns the
    batcher stats plus end-to-end wall clock.  ``target_qps`` paces the
    offered load (0 = as fast as the submit loop goes).  Shed (Overloaded)
    and expired (DeadlineExceeded) requests are counted in
    ``stats['rejected']`` — degraded mode answers structurally, it never
    hangs or crashes the driver.  With ``runtime`` the stream runs through
    its SupervisedBatcher against the ACTIVE version instead (worker
    crashes restart, repeated failures trip the breaker; ``CircuitOpen``
    rejections count as shed)."""
    gap = 1.0 / target_qps if target_qps > 0 else 0.0
    kw = dict(max_batch=max_batch, max_wait_us=max_wait_us,
              dim=stream.shape[1], max_queue=max_queue,
              deadline_us=deadline_us)
    with (runtime.make_batcher(**kw) if runtime is not None else
          MicroBatcher(lambda xb: predictor.predict(xb), **kw)) as mb:
        predictor.attach_batcher(mb)
        t0 = time.perf_counter()
        futures = []
        for i, row in enumerate(stream):
            if gap:
                # sleep-based pacing: a busy-wait would pin the GIL and
                # starve the batcher's worker thread
                while True:
                    rem = t0 + i * gap - time.perf_counter()
                    if rem <= 0:
                        break
                    time.sleep(min(rem, 5e-4))
            futures.append(mb.submit(row))
        rows, rejected, crashed = [], 0, 0
        for f in futures:
            try:
                rows.append(f.result(timeout=60.0))
            except (Overloaded, DeadlineExceeded):
                rejected += 1
            except WorkerCrashed:
                crashed += 1    # supervised mode: the batch died, not the run
        wall = time.perf_counter() - t0
        stats = mb.stats()
    stats["wall_s"] = wall
    stats["offered_qps"] = target_qps or float("inf")
    stats["results"] = (np.stack(rows) if rows
                        else np.zeros((0,), np.float32))
    stats["rejected"] = rejected
    stats["crashed_requests"] = crashed
    return stats


def _fit(*, n: int = 1024, d: int = 8, m: int = 128, seed: int = 0):
    """Tiny in-process fit for --selftest and missing --artifact runs.
    Returns (model, x_train)."""
    import jax

    from ..core import WLSHKernelSpec, get_bucket_fn, wlsh_krr_fit

    key = jax.random.PRNGKey(seed)
    x = jax.random.uniform(key, (n, d)) * 2.0
    y = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    spec = WLSHKernelSpec(bucket=get_bucket_fn("rect"))
    model = wlsh_krr_fit(jax.random.fold_in(key, 2), x, y, spec, m=m,
                         lam=0.5, backend="reference")
    return model, np.asarray(x, np.float32)


def _export(directory: str, model, *,
            mesh_shape: tuple[int, int] | None = None,
            artifact_id: str = "selftest") -> None:
    """Publish ``model`` flat or (``mesh_shape``) as a sharded piece grid."""
    from ..serve import export_artifact, export_artifact_sharded

    if mesh_shape is None:
        export_artifact(directory, model, artifact_id=artifact_id)
    else:
        export_artifact_sharded(directory, model, mesh_shape=mesh_shape,
                                artifact_id=artifact_id)


def _fit_and_export(directory: str, *, n: int = 1024, d: int = 8,
                    m: int = 128, seed: int = 0,
                    mesh_shape: tuple[int, int] | None = None):
    """``_fit`` + ``_export`` in one call.  Returns (model, x_train)."""
    model, x = _fit(n=n, d=d, m=m, seed=seed)
    _export(directory, model, mesh_shape=mesh_shape)
    return model, x


def selftest(metrics_url: str | None = None) -> int:
    """Export a small artifact, serve 100 requests through the in-process
    batcher, and verify every response against the library predict path —
    the CI serving smoke.  With ``metrics_url`` (set by --metrics-port) the
    selftest also scrapes its own live endpoint and fails if any required
    series is missing."""
    import jax.numpy as jnp

    from ..core import wlsh_krr_predict

    with tempfile.TemporaryDirectory() as tmp:
        model, xtr = _fit_and_export(tmp + "/artifact")
        predictor = Predictor(cache_entries=4096)
        predictor.load(tmp + "/artifact")
        predictor.warmup(sizes=bucket_sizes(16))
        stream = _synthetic_stream(xtr.shape[1], 100, dup_frac=0.3, seed=1)
        stats = serve_stream(predictor, stream, max_batch=16,
                             max_wait_us=1000)
        expect = np.asarray(wlsh_krr_predict(model, jnp.asarray(stream)))
        if stats["served"] != 100:
            print(f"[krr_serve] SELFTEST FAIL: served {stats['served']}/100")
            return 1
        # coalescing pads each micro-batch to its power-of-two bucket and XLA
        # tiles the instance-mean per shape, so cross-shape agreement is
        # ~1 ulp, not bitwise (bitwise is pinned per-path by tests)
        if not np.allclose(stats["results"], expect, atol=1e-6):
            print("[krr_serve] SELFTEST FAIL: batched serving != library "
                  "predictions")
            return 1
        # exactness of the serving path itself: replaying the same stream
        # must reproduce the first pass bit-for-bit (cache hits replay the
        # stored cold-path rows; repeated warm rows hit identical programs)
        replay = serve_stream(predictor, stream, max_batch=16,
                              max_wait_us=1000)
        if not np.array_equal(replay["results"], stats["results"]):
            print("[krr_serve] SELFTEST FAIL: replayed stream not bitwise "
                  "reproducible")
            return 1
        if metrics_url is not None:
            err = _verify_metrics(metrics_url, predictor, sharded=False)
            if err is not None:
                print(f"[krr_serve] SELFTEST FAIL: {err}")
                return 1
        cache = predictor.cache_stats()
        print(f"[krr_serve] selftest ok: 100/100 round-tripped (<=1e-6 of "
              f"the library path, replay bitwise); "
              f"{stats['batches']} batches (mean {stats['mean_batch']:.1f} "
              f"rows), p50 {stats['p50_us']:.0f}us p99 {stats['p99_us']:.0f}us, "
              f"cache hit rate {cache['hit_rate']:.2f}"
              + ("; metrics endpoint verified" if metrics_url else ""))
    return 0


def selftest_sharded(mesh_shape: tuple[int, int],
                     metrics_url: str | None = None) -> int:
    """Sharded-serving smoke for the serving-multidevice CI job: fit, export
    the piece grid, host it on a (model, data) mesh behind the batcher,
    serve 100 queries, and verify <=1e-5 against the single-host Predictor
    on the SAME model (plus a bitwise stream replay — cache hits and repeat
    warm rows must reproduce exactly whatever the mesh is)."""
    import jax

    from ..serve import Predictor, ShardedPredictor, export_artifact

    need = mesh_shape[0] * mesh_shape[1]
    if len(jax.devices()) < need:
        print(f"[krr_serve] SELFTEST FAIL: mesh "
              f"{mesh_shape[0]}x{mesh_shape[1]} needs {need} devices, have "
              f"{len(jax.devices())} (set "
              f"XLA_FLAGS=--xla_force_host_platform_device_count={need})")
        return 1
    with tempfile.TemporaryDirectory() as tmp:
        model, xtr = _fit_and_export(tmp + "/sharded", mesh_shape=mesh_shape)
        export_artifact(tmp + "/single", model, artifact_id="selftest")
        single = Predictor(cache_entries=4096)
        single.load(tmp + "/single")
        predictor = ShardedPredictor(mesh_shape=mesh_shape,
                                     cache_entries=4096)
        predictor.load(tmp + "/sharded")
        n_compiled = predictor.warmup(sizes=bucket_sizes(16))
        stream = _synthetic_stream(xtr.shape[1], 100, dup_frac=0.3, seed=1)
        stats = serve_stream(predictor, stream, max_batch=16,
                             max_wait_us=1000)
        if stats["served"] != 100:
            print(f"[krr_serve] SELFTEST FAIL: served {stats['served']}/100")
            return 1
        expect = single.predict(stream, use_cache=False)
        err = float(np.abs(stats["results"] - expect).max())
        if err > 1e-5:
            print(f"[krr_serve] SELFTEST FAIL: sharded serving off the "
                  f"single-host path by {err:.2e} (> 1e-5)")
            return 1
        replay = serve_stream(predictor, stream, max_batch=16,
                              max_wait_us=1000)
        if not np.array_equal(replay["results"], stats["results"]):
            print("[krr_serve] SELFTEST FAIL: replayed stream not bitwise "
                  "reproducible")
            return 1
        health = predictor.health()
        overflow = health["shards"]["selftest"]["overflow"]
        if any(overflow):
            print(f"[krr_serve] SELFTEST FAIL: routing overflow dropped "
                  f"buckets: {overflow}")
            return 1
        if metrics_url is not None:
            merr = _verify_metrics(metrics_url, predictor, sharded=True)
            if merr is not None:
                print(f"[krr_serve] SELFTEST FAIL: {merr}")
                return 1
        cache = predictor.cache_stats()
        print(f"[krr_serve] sharded selftest ok "
              f"(mesh {mesh_shape[0]}x{mesh_shape[1]}): 100/100 within "
              f"{err:.1e} of single-host (replay bitwise, overflow 0); "
              f"{n_compiled} buckets compiled, {stats['batches']} batches, "
              f"p50 {stats['p50_us']:.0f}us p99 {stats['p99_us']:.0f}us, "
              f"cache hit rate {cache['hit_rate']:.2f}")
    return 0


def selftest_lifecycle(metrics_url: str | None = None,
                       mesh_shape: tuple[int, int] | None = None) -> int:
    """Self-healing smoke for the CI serving/chaos jobs (--selftest --watch).

    Drives the full recovery loop against a real version root: v1 serves a
    stream clean; a POISONED v2 (tables corrupted on disk after export) is
    canary-rejected with zero failed requests on v1; a good v3 swaps in
    mid-stream with no dropped request and no new compile on the warm
    buckets; a forced post-swap health regression auto-rolls back to v1
    (mesh variant: operator rollback — the sharded predictor has no fault
    plan); and a crashed batcher worker recovers through the breaker's
    half-open probe instead of staying dead.  With ``metrics_url`` the
    lifecycle_*/breaker_* series are asserted on the live endpoint.
    """
    import threading

    from ..errors import CircuitOpen, FaultInjected
    from ..testing.faults import (FaultPlan, crash_supervised_workers,
                                  poison_artifact_tables)

    if mesh_shape is not None:
        import jax
        need = mesh_shape[0] * mesh_shape[1]
        if len(jax.devices()) < need:
            print(f"[krr_serve] SELFTEST FAIL: mesh needs {need} devices, "
                  f"have {len(jax.devices())}")
            return 1
    with tempfile.TemporaryDirectory() as tmp:
        root = tmp + "/versions"
        model, xtr = _fit()
        d = xtr.shape[1]
        _export(version_dir(root, 1), model, mesh_shape=mesh_shape)
        cfg = LifecycleConfig(probation_s=30.0, probation_min_requests=20,
                              probation_max_error_rate=0.1, retain=2,
                              load_retries=2, warm_sizes=bucket_sizes(16))
        rt = ServingRuntime(root, mesh_shape=mesh_shape, cache_entries=4096,
                            config=cfg)
        r = rt.poll_once()
        if r["action"] != "swap" or rt.active_version != 1:
            print(f"[krr_serve] SELFTEST FAIL: v1 not adopted: {r}")
            return 1
        stream = _synthetic_stream(d, 100, dup_frac=0.3, seed=1)
        stats = serve_stream(rt.predictor, stream, max_batch=16,
                             max_wait_us=1000, runtime=rt)
        if stats["served"] != 100 or stats["rejected"] \
                or stats["crashed_requests"]:
            print(f"[krr_serve] SELFTEST FAIL: v1 stream "
                  f"{stats['served']}/100 served, "
                  f"{stats['rejected']} rejected")
            return 1
        base = stats["results"]
        c0 = rt.compile_count()

        # poisoned v2: published complete, then corrupted on disk — the
        # shape of damage only the canary catches (validation passes)
        _export(version_dir(root, 2), model, mesh_shape=mesh_shape)
        poison_artifact_tables(version_dir(root, 2), scale=3.0)
        r = rt.poll_once()
        if r["action"] != "canary_reject" or rt.active_version != 1:
            print(f"[krr_serve] SELFTEST FAIL: poisoned v2 not rejected: "
                  f"{r}")
            return 1
        stats = serve_stream(rt.predictor, stream, max_batch=16,
                             max_wait_us=1000, runtime=rt)
        if stats["served"] != 100 or stats["rejected"] \
                or stats["crashed_requests"] \
                or not np.array_equal(stats["results"], base):
            print("[krr_serve] SELFTEST FAIL: v1 service disturbed by the "
                  "rejected candidate")
            return 1

        # good v3: swap mid-stream — zero downtime, zero new compiles
        _export(version_dir(root, 3), model, mesh_shape=mesh_shape)
        swap_report = {}

        def mid_stream_poll():
            time.sleep(0.01)
            swap_report.update(rt.poll_once())

        poller = threading.Thread(target=mid_stream_poll)
        poller.start()
        stats = serve_stream(rt.predictor, stream, max_batch=16,
                             max_wait_us=1000, target_qps=2000.0, runtime=rt)
        poller.join()
        if swap_report.get("action") != "swap" or rt.active_version != 3:
            print(f"[krr_serve] SELFTEST FAIL: v3 not swapped mid-stream: "
                  f"{swap_report}")
            return 1
        if stats["served"] != 100 or stats["rejected"] \
                or stats["crashed_requests"]:
            print(f"[krr_serve] SELFTEST FAIL: swap dropped requests "
                  f"({stats['served']}/100)")
            return 1
        if not np.allclose(stats["results"], base, atol=1e-6):
            print("[krr_serve] SELFTEST FAIL: post-swap results diverged")
            return 1
        c1 = rt.compile_count()
        if c1 != c0:
            print(f"[krr_serve] SELFTEST FAIL: swap recompiled warm "
                  f"buckets ({c0} -> {c1})")
            return 1

        # forced health regression inside the probation window -> rollback
        if mesh_shape is None:
            rt.predictor.fault_plan = FaultPlan(serve_fail_every=1)
            probe = stream[:1]
            for _ in range(cfg.probation_min_requests * 3):
                try:
                    rt.predict(probe, use_cache=False)
                except FaultInjected:
                    pass
                if rt.active_version == 1:
                    break
            rt.predictor.fault_plan = None
            rolled = "auto"
        else:
            rt.rollback("forced regression (selftest)")
            rolled = "operator"
        if rt.active_version != 1:
            print(f"[krr_serve] SELFTEST FAIL: no rollback to v1 "
                  f"(active v{rt.active_version})")
            return 1
        out = rt.predict(np.asarray(stream[:4]), use_cache=False)
        if not np.allclose(out, base[:4], atol=1e-6):
            print("[krr_serve] SELFTEST FAIL: rolled-back v1 not serving")
            return 1

        # worker crash -> breaker opens -> half-open probe recovers
        sup = rt.make_batcher(failure_threshold=1, cooldown_s=0.2,
                              restart_backoff_s=0.01, max_batch=8,
                              max_wait_us=500, dim=d)
        try:
            sup.predict(stream[0], timeout=30.0)
            crash_supervised_workers(sup, crashes=1)
            try:
                sup.predict(stream[0], timeout=30.0)
                print("[krr_serve] SELFTEST FAIL: crashed worker answered")
                return 1
            except WorkerCrashed:
                pass
            try:
                sup.predict(stream[0], timeout=30.0)
                print("[krr_serve] SELFTEST FAIL: open breaker admitted")
                return 1
            except CircuitOpen:
                pass
            time.sleep(0.25)    # past the cooldown: half-open probe window
            out = sup.predict(stream[0], timeout=30.0)
            st = sup.stats()
            if st["breaker"]["state"] != "closed" or st["restarts"] != 1:
                print(f"[krr_serve] SELFTEST FAIL: breaker not recovered: "
                      f"{st['breaker']}, restarts {st['restarts']}")
                return 1
        finally:
            sup.close()
        if metrics_url is not None:
            err = _verify_metrics(metrics_url, rt,
                                  sharded=mesh_shape is not None,
                                  lifecycle=True)
            if err is not None:
                print(f"[krr_serve] SELFTEST FAIL: {err}")
                return 1
        h = rt.health()
        print(f"[krr_serve] lifecycle selftest ok"
              + (f" (mesh {mesh_shape[0]}x{mesh_shape[1]})"
                 if mesh_shape else "")
              + f": poisoned v2 canary-rejected with zero failed requests, "
              f"v3 swapped live (compiles {c0}->{c1}), {rolled} rollback "
              f"to v1, breaker reopened after worker crash; "
              f"rejected versions {h['rejected_versions']}"
              + ("; metrics endpoint verified" if metrics_url else ""))
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--artifact", default=None,
                    help="artifact directory (from quickstart --export); "
                         "omitted -> fit+export a small model in-process")
    ap.add_argument("--selftest", action="store_true",
                    help="fit -> export -> serve 100 requests -> verify "
                         "bitwise (CI smoke); ignores the traffic flags")
    ap.add_argument("--watch", action="store_true",
                    help="treat --artifact as a VERSION ROOT (v1/, v2/, "
                         "...) and self-heal: poll for new versions, "
                         "canary-validate, swap atomically, auto-rollback "
                         "on post-swap regression, restart crashed batcher "
                         "workers behind a circuit breaker (with "
                         "--selftest: run the lifecycle chaos smoke)")
    ap.add_argument("--watch-interval", type=float, default=0.5,
                    metavar="S", help="version-poll cadence under --watch")
    ap.add_argument("--no-canary", action="store_true",
                    help="skip golden-query validation before a swap "
                         "(--watch; accepts any loadable version)")
    ap.add_argument("--rollback-window", type=float, default=5.0,
                    metavar="S",
                    help="probation: watch post-swap health for S seconds "
                         "and auto-rollback on regression (0 disables)")
    ap.add_argument("--retain", type=int, default=2,
                    help="previous versions kept hosted as rollback "
                         "targets under --watch")
    ap.add_argument("--backend", default=None,
                    choices=["auto", "reference", "pallas"],
                    help="override the artifact's recorded backend")
    ap.add_argument("--input", default=None,
                    help=".npy of (n, d) request points (default: synthetic)")
    ap.add_argument("--requests", type=int, default=2000)
    ap.add_argument("--dup-frac", type=float, default=0.5,
                    help="fraction of synthetic requests replaying earlier "
                         "ones (the bucket-exact cache's traffic)")
    ap.add_argument("--target-qps", type=float, default=0.0,
                    help="paced offered load; 0 = unthrottled")
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--max-wait-us", type=int, default=2000)
    ap.add_argument("--max-queue", type=int, default=0,
                    help="load shedding: submits past this queue depth fail "
                         "fast with Overloaded (0 = unbounded)")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="per-request deadline budget; a request still "
                         "queued past it fails with DeadlineExceeded "
                         "(0 = no deadline)")
    ap.add_argument("--cache-entries", type=int, default=65536,
                    help="bucket-exact cache size; 0 disables")
    ap.add_argument("--mesh", default=None, metavar="MxN",
                    help="serve SHARDED on a (model_shards M x data_shards "
                         "N) device mesh, e.g. --mesh 2x2; the artifact "
                         "must be a matching export_artifact_sharded piece "
                         "grid (omitted -> single-host Predictor)")
    ap.add_argument("--placement", default=None, metavar="LO:HI",
                    help="host the model on model-axis rows [LO, HI) of the "
                         "--mesh so several models co-serve (default: the "
                         "whole model axis)")
    ap.add_argument("--metrics-port", type=int, default=None, metavar="PORT",
                    help="expose /metrics (Prometheus text) + /healthz on "
                         "127.0.0.1:PORT for the lifetime of the run "
                         "(0 = OS-picked port, printed at startup)")
    ap.add_argument("--metrics-dump", default=None, metavar="PATH",
                    help="append a JSONL metrics snapshot to PATH on exit "
                         "(headless runs: scrape-free flight recorder)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    mesh_shape = parse_mesh_shape(args.mesh) if args.mesh else None
    server = None
    if args.metrics_port is not None:
        server = obs.serve_metrics(args.metrics_port)
        print(f"[krr_serve] metrics: {server.url}/metrics  "
              f"health: {server.url}/healthz")
    try:
        rc = _dispatch(args, mesh_shape, server)
    finally:
        if args.metrics_dump:
            obs.REGISTRY.write_jsonl(args.metrics_dump,
                                     extra={"driver": "krr_serve"})
            print(f"[krr_serve] metrics snapshot -> {args.metrics_dump}")
        if server is not None:
            server.close()
    return rc


def _dispatch(args, mesh_shape, server) -> int:
    if args.selftest:
        url = server.url if server is not None else None
        if args.watch:
            return selftest_lifecycle(metrics_url=url, mesh_shape=mesh_shape)
        return (selftest_sharded(mesh_shape, metrics_url=url)
                if mesh_shape else selftest(metrics_url=url))
    if args.watch:
        return _watch_main(args, mesh_shape, server)

    placement = None
    if args.placement:
        lo, hi = args.placement.split(":")
        placement = (int(lo), int(hi))
    if mesh_shape is not None:
        predictor = ShardedPredictor(mesh_shape=mesh_shape,
                                     backend=args.backend,
                                     cache_entries=args.cache_entries)
    else:
        predictor = Predictor(backend=args.backend,
                              cache_entries=args.cache_entries)
    if server is not None:
        obs.add_health_provider("predictor", predictor.health)
    with contextlib.ExitStack() as stack:
        if args.artifact:
            aid = (predictor.load(args.artifact, placement=placement)
                   if mesh_shape is not None else
                   predictor.load(args.artifact))
        else:
            # demo artifact lives only for this run — cleaned up on exit
            tmp = stack.enter_context(
                tempfile.TemporaryDirectory(prefix="krr_serve_"))
            print(f"[krr_serve] no --artifact: fitting a demo model "
                  f"-> {tmp}/artifact")
            span = ((placement[1] - placement[0], mesh_shape[1])
                    if mesh_shape and placement else mesh_shape)
            _fit_and_export(tmp + "/artifact", mesh_shape=span)
            aid = (predictor.load(tmp + "/artifact", placement=placement)
                   if mesh_shape is not None else
                   predictor.load(tmp + "/artifact"))
        return _serve_main(predictor, aid, args)


def _watch_main(args, mesh_shape, server) -> int:
    """--watch without --selftest: host a version root with the live
    watcher running (reload/canary/rollback on a daemon thread), serve the
    synthetic/file stream through the supervised batcher, report lifecycle
    health.  Publish a new ``v<N>`` under the root while this runs and it
    swaps in live (see the README runbook)."""
    cfg = LifecycleConfig(poll_interval_s=args.watch_interval,
                          canary_enabled=not args.no_canary,
                          probation_s=args.rollback_window,
                          retain=args.retain, load_retries=2,
                          warm_sizes=bucket_sizes(args.max_batch))
    with contextlib.ExitStack() as stack:
        root = args.artifact
        if root is None:
            tmp = stack.enter_context(
                tempfile.TemporaryDirectory(prefix="krr_serve_"))
            root = tmp + "/versions"
            print(f"[krr_serve] no --artifact: fitting a demo model "
                  f"-> {version_dir(root, 1)}")
            model, _ = _fit()
            _export(version_dir(root, 1), model, mesh_shape=mesh_shape)
        rt = ServingRuntime(root, mesh_shape=mesh_shape,
                            backend=args.backend,
                            cache_entries=args.cache_entries, config=cfg)
        if server is not None:
            obs.add_health_provider("lifecycle", rt.health)
            stack.callback(obs.remove_health_provider, "lifecycle")
        rt.poll_once()
        if rt.active_version is None:
            print(f"[krr_serve] no published version under {root} "
                  f"(expected {version_dir(root, 1)} etc.)",
                  file=sys.stderr)
            return 2
        rt.start()
        stack.callback(rt.stop)
        d = rt._hosted().loaded.model.lsh.d
        print(f"[krr_serve] watching {root}: serving v{rt.active_version} "
              f"(poll every {cfg.poll_interval_s}s, canary "
              f"{'on' if cfg.canary_enabled else 'OFF'}, rollback window "
              f"{cfg.probation_s}s, retain {cfg.retain})")
        if args.input:
            stream = np.load(args.input).astype(np.float32)
            if stream.ndim != 2 or stream.shape[1] != d:
                print(f"[krr_serve] --input must be (n, {d}), "
                      f"got {stream.shape}", file=sys.stderr)
                return 2
        else:
            stream = _synthetic_stream(d, args.requests, args.dup_frac,
                                       args.seed)
        stats = serve_stream(rt.predictor, stream, max_batch=args.max_batch,
                             max_wait_us=args.max_wait_us,
                             target_qps=args.target_qps,
                             max_queue=args.max_queue,
                             deadline_us=(int(args.deadline_ms * 1000)
                                          if args.deadline_ms > 0 else None),
                             runtime=rt)
        h = rt.health()
        print(f"[krr_serve] {stats['served']} requests in "
              f"{stats['wall_s']:.2f}s -> {stats['qps']:.0f} QPS "
              f"(p50 {stats['p50_us']:.0f}us p99 {stats['p99_us']:.0f}us, "
              f"{stats['crashes']} worker crashes / {stats['restarts']} "
              f"restarts, breaker {stats['breaker']['state']})")
        print(f"[krr_serve] lifecycle: active v{h['active_version']}, "
              f"retained {h['retained_versions']}, rejected "
              f"{h['rejected_versions']}, ok={h['ok']}")
        return 0


def _serve_main(predictor: Predictor, aid: str, args) -> int:
    d = predictor._hosted(aid).loaded.model.lsh.d
    n_compiled = predictor.warmup(artifact_id=aid,
                                  sizes=bucket_sizes(args.max_batch))
    print(f"[krr_serve] hosting {aid!r} (d={d}, backend="
          f"{predictor._hosted(aid).loaded.operator.backend}); "
          f"{n_compiled} padding buckets compiled")

    if args.input:
        stream = np.load(args.input).astype(np.float32)
        if stream.ndim != 2 or stream.shape[1] != d:
            print(f"[krr_serve] --input must be (n, {d}), "
                  f"got {stream.shape}", file=sys.stderr)
            return 2
    else:
        stream = _synthetic_stream(d, args.requests, args.dup_frac, args.seed)

    stats = serve_stream(predictor, stream, max_batch=args.max_batch,
                         max_wait_us=args.max_wait_us,
                         target_qps=args.target_qps,
                         max_queue=args.max_queue,
                         deadline_us=(int(args.deadline_ms * 1000)
                                      if args.deadline_ms > 0 else None))
    print(f"[krr_serve] {stats['served']} requests in {stats['wall_s']:.2f}s "
          f"-> {stats['qps']:.0f} QPS achieved "
          f"({stats['batches']} batches, mean {stats['mean_batch']:.1f} "
          f"rows/batch)")
    print(f"[krr_serve] latency p50 {stats['p50_us']:.0f}us  "
          f"p99 {stats['p99_us']:.0f}us  (max_batch={args.max_batch}, "
          f"max_wait={args.max_wait_us}us)")
    if stats["rejected"]:
        print(f"[krr_serve] degraded mode: {stats['shed']} shed, "
              f"{stats['deadline_expired']} deadline-expired "
              f"({stats['rejected']} rejected total, shed rate "
              f"{stats['shed_rate']:.2f})")
    cache = predictor.cache_stats(artifact_id=aid)
    if cache is not None:
        print(f"[krr_serve] cache: {cache['entries']} entries, "
              f"hit rate {cache['hit_rate']:.2f} "
              f"({cache['hits']} hits / {cache['misses']} misses)")
    health = predictor.health()
    print(f"[krr_serve] health: ok={health['ok']} "
          f"requests={health['requests']} errors={health['errors']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
