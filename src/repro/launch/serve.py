"""Batched serving driver: prefill a prompt batch, then decode tokens
autoregressively against the ring-buffer caches.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --smoke \
        --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import registry
from ..models import model
from .steps import make_decode_step, make_prefill_step


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=registry.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = registry.smoke_config(args.arch) if args.smoke else \
        registry.get_config(args.arch)
    dtype = jnp.float32
    key = jax.random.PRNGKey(args.seed)
    params = model.init(cfg, key, dtype)
    max_len = args.prompt_len + args.gen

    prefill = jax.jit(make_prefill_step(cfg, max_cache_len=max_len,
                                        dtype=dtype))
    decode = jax.jit(make_decode_step(cfg, dtype=dtype))

    batch = {"tokens": jax.random.randint(key, (args.batch, args.prompt_len),
                                          0, cfg.vocab_size)}
    if cfg.encoder is not None:
        batch["frames"] = 0.02 * jax.random.normal(
            key, (args.batch, cfg.encoder.n_frames, cfg.d_model), dtype)
    elif cfg.cross_attn_source_len:
        batch["patches"] = 0.02 * jax.random.normal(
            key, (args.batch, cfg.cross_attn_source_len, cfg.d_model), dtype)

    t0 = time.time()
    logits, cache, pos = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    def sample(lg, k):
        if args.temperature <= 0:
            return jnp.argmax(lg, -1).astype(jnp.int32)
        return jax.random.categorical(k, lg / args.temperature).astype(jnp.int32)

    tok = sample(logits, key)[:, None]
    out = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = decode(params, cache, tok, pos)
        tok = sample(logits, jax.random.fold_in(key, i))[:, None]
        pos = pos + 1
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    tps = args.batch * (args.gen - 1) / max(t_decode, 1e-9)
    print(f"[serve] {cfg.name}: prefill {args.batch}x{args.prompt_len} "
          f"in {t_prefill*1e3:.1f} ms; decode {args.gen-1} steps "
          f"-> {tps:.1f} tok/s")
    print(f"[serve] sample generations (token ids):")
    for row in gen[: min(2, args.batch)]:
        print("   ", " ".join(str(int(t)) for t in row[:16]), "...")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
