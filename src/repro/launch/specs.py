"""Input ShapeDtypeStructs, shardings, and useful-FLOP accounting for every
(architecture x shape) dry-run cell."""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from ..configs.base import ModelConfig, ShapeSpec
from ..models import model
from ..models.params import ParamSpec
from ..sharding import spec_for, tree_shardings

Array = jnp.ndarray
SDS = jax.ShapeDtypeStruct


# ---------------------------------------------------------------------------
# abstract inputs
# ---------------------------------------------------------------------------

def batch_specs(cfg: ModelConfig, shape: ShapeSpec, dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStructs for a train/prefill batch."""
    b, s = shape.global_batch, shape.seq_len
    specs = {"tokens": SDS((b, s), jnp.int32)}
    if shape.kind == "train":
        specs["labels"] = SDS((b, s), jnp.int32)
    if cfg.encoder is not None:
        specs["frames"] = SDS((b, cfg.encoder.n_frames, cfg.d_model), dtype)
    elif cfg.cross_attn_source_len:
        specs["patches"] = SDS((b, cfg.cross_attn_source_len, cfg.d_model), dtype)
    return specs


def batch_axes(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    axes = {"tokens": ("batch", None)}
    if shape.kind == "train":
        axes["labels"] = ("batch", None)
    if cfg.encoder is not None:
        axes["frames"] = ("batch", None, None)
    elif cfg.cross_attn_source_len:
        axes["patches"] = ("batch", None, None)
    return axes


def decode_specs(cfg: ModelConfig, shape: ShapeSpec, dtype=jnp.bfloat16):
    """(cache, tokens, pos) ShapeDtypeStructs for a serve_step cell."""
    b, s = shape.global_batch, shape.seq_len
    cache = model.abstract_cache(cfg, b, s, dtype)
    return cache, SDS((b, 1), jnp.int32), SDS((b,), jnp.int32)


def batch_shardings(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                    dtype=jnp.bfloat16):
    return tree_shardings(batch_axes(cfg, shape), batch_specs(cfg, shape, dtype),
                          mesh)


def param_shardings(cfg: ModelConfig, mesh: Mesh, dtype=jnp.float32):
    return tree_shardings(model.param_axes(cfg), model.abstract_params(cfg, dtype),
                          mesh)


def cache_shardings(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                    dtype=jnp.bfloat16):
    b, s = shape.global_batch, shape.seq_len
    return tree_shardings(model.cache_axes(cfg, b, s),
                          model.abstract_cache(cfg, b, s, dtype), mesh)


def scalar_sharding(mesh: Mesh, axes=()):
    return NamedSharding(mesh, spec_for(axes, (1,) * len(axes), mesh)
                         if axes else spec_for((), (), mesh))


def vec_sharding(mesh: Mesh, shape, axes):
    return NamedSharding(mesh, spec_for(axes, shape, mesh))


# ---------------------------------------------------------------------------
# useful-FLOP accounting (MODEL_FLOPS for the roofline ratio)
# ---------------------------------------------------------------------------

def _count(specs: Any, pred) -> int:
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(
            specs, is_leaf=lambda x: isinstance(x, ParamSpec))[0]:
        if pred(jax.tree_util.keystr(path)):
            total += math.prod(leaf.shape)
    return total


def matmul_params(cfg: ModelConfig) -> tuple[int, int]:
    """(active, total) matmul parameters — embedding/unembedding tables and
    norm scales excluded; MoE experts scaled by top_k/n_experts for 'active'."""
    specs = param_specs_cached(cfg)
    is_table = lambda k: ("embed" in k and "table" in k) or \
        ("unembed" in k and "table" in k)
    is_norm = lambda k: "norm" in k or "ln_x" in k or k.endswith("scale']")
    total_all = _count(specs, lambda k: not (is_table(k) or is_norm(k)))
    moe_w = _count(specs, lambda k: ("w_gate" in k or "w_up" in k or
                                     "w_down" in k) and "ffn" in k)
    if cfg.moe is not None and moe_w:
        active = total_all - moe_w + moe_w * cfg.moe.top_k // cfg.moe.n_experts
    else:
        active = total_all
    return active, total_all


_SPEC_CACHE: dict[str, Any] = {}


def param_specs_cached(cfg: ModelConfig):
    key = cfg.name + str(cfg.n_layers) + str(cfg.d_model)
    if key not in _SPEC_CACHE:
        _SPEC_CACHE[key] = model.param_specs(cfg)
    return _SPEC_CACHE[key]


def _attn_flops_token(cfg: ModelConfig, t_ctx: float) -> float:
    """Score+value FLOPs for ONE query token against t_ctx keys, all layers."""
    per_layer = 4.0 * t_ctx * cfg.n_heads * cfg.head_dim   # 2 matmuls x 2 flop
    n_attn = sum(1 for s in cfg.layer_pattern
                 if s.kind == "attn") * cfg.n_groups
    n_shared = sum(1 for s in cfg.layer_pattern if s.shared_attn) * cfg.n_groups
    n_cross = sum(1 for s in cfg.layer_pattern if s.cross_attn) * cfg.n_groups
    total = 0.0
    for s in cfg.layer_pattern:
        reps = cfg.n_groups
        if s.kind == "attn":
            eff = min(t_ctx, s.window) if s.window else t_ctx
            total += per_layer / t_ctx * eff * reps
        if s.shared_attn:
            win = s.window or 4096
            total += per_layer / t_ctx * min(t_ctx, win) * reps
        if s.cross_attn:
            total += 4.0 * cfg.cross_attn_source_len * cfg.n_heads * \
                cfg.head_dim * reps
    del n_attn, n_shared, n_cross
    return total


def _ssm_flops_token(cfg: ModelConfig) -> float:
    if cfg.ssm is None:
        return 0.0
    total = 0.0
    for s in cfg.layer_pattern:
        if s.kind == "mamba2":
            from ..models.mamba2 import mamba2_dims
            dims = mamba2_dims(cfg.d_model, cfg.ssm)
            total += 4.0 * dims.n_heads * dims.head_dim * dims.state * \
                cfg.n_groups
        elif s.kind == "rwkv6":
            total += 4.0 * cfg.n_heads * cfg.head_dim ** 2 * cfg.n_groups
    return total


def useful_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """MODEL_FLOPS for the cell: 6*N_active*tokens for train (2 fwd + 4 bwd),
    2*N_active per token for prefill/decode, plus attention / SSM / logits
    terms.  This is the 'useful work' numerator of the roofline fraction."""
    b, s = shape.global_batch, shape.seq_len
    n_active, _ = matmul_params(cfg)
    logits_flops_tok = 2.0 * cfg.vocab_size * cfg.d_model
    if shape.kind == "train":
        tokens = b * s
        avg_ctx = s / 2.0   # causal average context
        fwd_tok = 2.0 * n_active + _attn_flops_token(cfg, avg_ctx) + \
            _ssm_flops_token(cfg) + logits_flops_tok
        flops = 3.0 * fwd_tok * tokens          # bwd = 2x fwd
        if cfg.encoder is not None:
            enc_params = _count(param_specs_cached(cfg),
                                lambda k: "encoder" in k and "norm" not in k)
            flops += 3.0 * 2.0 * enc_params * b * cfg.encoder.n_frames
        return flops
    if shape.kind == "prefill":
        tokens = b * s
        avg_ctx = s / 2.0
        fwd_tok = 2.0 * n_active + _attn_flops_token(cfg, avg_ctx) + \
            _ssm_flops_token(cfg)
        flops = fwd_tok * tokens + logits_flops_tok * b   # last-token logits
        if cfg.encoder is not None:
            enc_params = _count(param_specs_cached(cfg),
                                lambda k: "encoder" in k and "norm" not in k)
            flops += 2.0 * enc_params * b * cfg.encoder.n_frames
        return flops
    # decode: one token per sequence against a seq_len cache
    fwd_tok = 2.0 * n_active + _attn_flops_token(cfg, float(s)) + \
        _ssm_flops_token(cfg) + logits_flops_tok
    return fwd_tok * b
