"""Production mesh construction.

Defined as FUNCTIONS so importing this module never touches jax device state
(the dry-run must set XLA_FLAGS before the first device query; smoke tests
must keep seeing 1 device).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

from ..compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256-chip pod ('data', 'model'), or 2 pods = 512 chips with a
    leading 'pod' axis.  Batch shards over ('pod', 'data'); tensor/expert
    parallelism over 'model'; FSDP parameter sharding over 'data' (intra-pod
    all-gathers stay on ICI, only gradient reductions cross the pod axis)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1) -> Mesh:
    """Tiny mesh over whatever devices exist (tests / examples on CPU)."""
    n = len(jax.devices())
    data = n // model_parallel
    return make_mesh((data, model_parallel), ("data", "model"))
