"""Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
against the production mesh and report memory / cost / roofline terms.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --cells lm --mesh pod
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-1b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --cells krr --mesh multipod

The first two lines below MUST run before any other import: jax locks the
device count at first init, and the dry-run needs 512 placeholder CPU devices
to build the 2x16x16 production mesh.  (Do NOT copy this into tests or
benchmarks — they are supposed to see 1 device.)
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import registry
from ..configs.base import SHAPES
from ..configs.wlsh_krr import CONFIG as KRR_CONFIG, KRR_SHAPES
from ..core.bucket_fns import get_bucket_fn
from ..core.distributed import KRRStepConfig, make_krr_step
from ..core.lsh import LSHParams
from ..hlo_analysis import analyze_compiled
from ..models import model
from ..optim import AdamWConfig
from ..optim.adamw import AdamWState
from .mesh import make_production_mesh
from .specs import (batch_shardings, batch_specs, cache_shardings,
                    decode_specs, param_shardings, useful_flops)
from .steps import make_decode_step, make_prefill_step, make_train_step

SDS = jax.ShapeDtypeStruct


def _opt_abstract(cfg):
    ps = model.abstract_params(cfg, jnp.float32)
    return AdamWState(step=SDS((), jnp.int32), m=ps, v=ps)


def _opt_shardings(cfg, mesh):
    pshard = param_shardings(cfg, mesh)
    return AdamWState(step=NamedSharding(mesh, P()), m=pshard, v=pshard)


# microbatch count per arch for train cells (activation-memory lever; chosen
# so temp bytes/device fit the 16 GB v5e HBM — see EXPERIMENTS.md §Dry-run)
TRAIN_MICROBATCHES: dict[str, int] = {
    "phi3-mini-3.8b": 1, "qwen3-14b": 1, "gemma3-1b": 1,
    "command-r-plus-104b": 4, "llama4-scout-17b-a16e": 2, "mixtral-8x22b": 4,
    "zamba2-7b": 2, "rwkv6-1.6b": 1, "llama-3.2-vision-90b": 4,
    "whisper-large-v3": 1,
}
# NOTE: these are the POST-hillclimb shipping values (EXPERIMENTS.md §Perf);
# the frozen baseline grid in reports/pod.jsonl used 8 for the big models.


def lower_lm_cell(arch: str, shape_name: str, mesh, micro: int | None = None):
    """Returns (lowered, compiled, model_flops) for one LM cell."""
    cfg = registry.get_config(arch)
    shape = SHAPES[shape_name]
    mf = useful_flops(cfg, shape)

    if shape.kind == "train":
        nm = micro if micro is not None else TRAIN_MICROBATCHES.get(arch, 1)
        step = make_train_step(cfg, AdamWConfig(), num_microbatches=nm)
        args = (model.abstract_params(cfg, jnp.float32), _opt_abstract(cfg),
                batch_specs(cfg, shape))
        in_sh = (param_shardings(cfg, mesh), _opt_shardings(cfg, mesh),
                 batch_shardings(cfg, shape, mesh))
        out_sh = (in_sh[0], in_sh[1], None)
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=(0, 1))
    elif shape.kind == "prefill":
        step = make_prefill_step(cfg, max_cache_len=shape.seq_len)
        args = (model.abstract_params(cfg, jnp.bfloat16),
                batch_specs(cfg, shape))
        in_sh = (param_shardings(cfg, mesh, jnp.bfloat16),
                 batch_shardings(cfg, shape, mesh))
        out_sh = (None, cache_shardings(cfg, shape, mesh), None)
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
    else:  # decode
        from ..sharding import spec_for
        step = make_decode_step(cfg)
        cache, tok, pos = decode_specs(cfg, shape)
        args = (model.abstract_params(cfg, jnp.bfloat16), cache, tok, pos)
        csh = cache_shardings(cfg, shape, mesh)
        b = shape.global_batch
        tok_sh = NamedSharding(mesh, spec_for(("batch", None), (b, 1), mesh))
        pos_sh = NamedSharding(mesh, spec_for(("batch",), (b,), mesh))
        in_sh = (param_shardings(cfg, mesh, jnp.bfloat16), csh, tok_sh, pos_sh)
        out_sh = (None, csh)
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=(1,))

    from ..sharding import use_rules_mesh
    with use_rules_mesh(mesh):
        lowered = jitted.lower(*args)
    compiled = lowered.compile()
    return lowered, compiled, mf


def lower_krr_cell(shape_name: str, mesh, variant: str = "psum"):
    """Lower the paper's own distributed KRR step.

    variant 'psum' is the paper-faithful baseline (dense CountSketch table
    merged with a psum); 'hashjoin' is the beyond-paper optimized version
    (sharded table + nonzero routing via all_to_all) — see §Perf.
    """
    from ..core.distributed import make_krr_step_hashjoin
    spec = KRR_SHAPES[shape_name]
    n, m, b = spec["n_points"], spec["m"], spec["table_size"]
    d = KRR_CONFIG.dim
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    cfg = KRRStepConfig(m=m, table_size=b, lam=KRR_CONFIG.lam,
                        cg_iters=KRR_CONFIG.cg_iters, data_axes=data_axes,
                        model_axis="model", backend=KRR_CONFIG.backend,
                        fused=KRR_CONFIG.fused)
    f = get_bucket_fn(KRR_CONFIG.bucket)
    # cap_factor 1.25: at krr_4m the per-destination load is 65536 +- 248
    # (binomial), so 1.25x mean is a +66-sigma overflow margin — free traffic
    # reduction vs the conservative 2.0 default.  Wire dtype follows the
    # config ('bf16' default halves the all_to_all bytes again).
    wire = jnp.bfloat16 if KRR_CONFIG.wire_dtype == "bf16" else jnp.float32
    step = (make_krr_step_hashjoin(mesh, cfg, f, cap_factor=1.25,
                                   payload_dtype=wire)
            if variant == "hashjoin" else make_krr_step(mesh, cfg, f))
    lsh = LSHParams(w=SDS((m, d), jnp.float32), z=SDS((m, d), jnp.float32),
                    r1=SDS((m, d), jnp.uint32), r2=SDS((m, d), jnp.uint32))
    jitted = jax.jit(step)
    lowered = jitted.lower(SDS((n, d), jnp.float32), SDS((n,), jnp.float32),
                           lsh)
    compiled = lowered.compile()
    # useful FLOPs: per CG iter, featurized matvec = scatter + gather + dots:
    # ~6 flops per (instance, point) plus table psum is comms, not flops.
    mf = (cfg.cg_iters + 2) * (6.0 * m * n) + 10.0 * m * n  # featurize ~10/pt
    return lowered, compiled, mf


def run_cell(kind: str, arch: str, shape_name: str, mesh, mesh_name: str,
             micro: int | None = None, krr_variant: str = "psum"):
    t0 = time.time()
    if kind == "krr":
        lowered, compiled, mf = lower_krr_cell(shape_name, mesh, krr_variant)
        suffix = "" if krr_variant == "psum" else f"+{krr_variant}"
        name = f"wlsh_krr{suffix}/{shape_name}/{mesh_name}"
    else:
        lowered, compiled, mf = lower_lm_cell(arch, shape_name, mesh, micro)
        name = f"{arch}/{shape_name}/{mesh_name}"
    dt = time.time() - t0
    chips = mesh.devices.size
    roof = analyze_compiled(name, compiled, chips=chips, model_flops=mf)
    mem = compiled.memory_analysis()
    row = roof.row()
    row.update({
        "compile_s": round(dt, 1),
        "arg_bytes_per_device": int(getattr(mem, "argument_size_in_bytes", 0)),
        "temp_bytes_per_device": int(getattr(mem, "temp_size_in_bytes", 0)),
        "output_bytes_per_device": int(getattr(mem, "output_size_in_bytes", 0)),
        "collective_counts": dict(roof.stats.collective_counts),
        "collective_bytes_by_op": {k: int(v) for k, v in
                                   roof.stats.collective_bytes_by_op.items()},
        "xla_flops_per_dev": roof.stats.xla_flops,
    })
    print(f"[dryrun] {name}: compile {dt:.1f}s  "
          f"args/dev {row['arg_bytes_per_device']/1e9:.2f} GB  "
          f"temp/dev {row['temp_bytes_per_device']/1e9:.2f} GB  "
          f"flops {roof.hlo_flops:.3e}  coll {roof.collective_bytes/1e9:.3f} GB  "
          f"dominant={roof.dominant}  roofline_frac={roof.roofline_frac:.3f}")
    return row


def iter_cells(cells: str, arch: str | None, shape: str | None):
    if cells in ("lm", "all"):
        for a in registry.ARCH_IDS:
            if arch and a != arch:
                continue
            for s in SHAPES:
                if shape and s != shape:
                    continue
                if not registry.runs_shape(a, s):
                    continue
                yield ("lm", a, s)
    if cells in ("krr", "all") and (arch in (None, "wlsh_krr")):
        for s in KRR_SHAPES:
            if shape and s != shape:
                continue
            yield ("krr", "wlsh_krr", s)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--cells", default="all", choices=["lm", "krr", "all"])
    ap.add_argument("--out", default=None, help="append-mode JSONL report")
    ap.add_argument("--micro", type=int, default=None,
                    help="override train microbatch count")
    ap.add_argument("--krr-variant", default="psum",
                    choices=["psum", "hashjoin"])
    ap.add_argument("--unroll", action="store_true",
                    help="unroll the layer-group scan (perf experiment)")
    args = ap.parse_args()
    if args.unroll:
        model.UNROLL_GROUPS = True

    meshes = []
    if args.mesh in ("pod", "both"):
        meshes.append(("pod16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multipod", "both"):
        meshes.append(("multipod2x16x16", make_production_mesh(multi_pod=True)))

    failures = []
    for mesh_name, mesh in meshes:
        for kind, a, s in iter_cells(args.cells, args.arch, args.shape):
            try:
                row = run_cell(kind, a, s, mesh, mesh_name, args.micro,
                               args.krr_variant)
                if args.out:
                    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
                    with open(args.out, "a") as fh:
                        fh.write(json.dumps(row) + "\n")
            except Exception:
                failures.append((mesh_name, a, s))
                print(f"[dryrun] FAILED {a}/{s}/{mesh_name}")
                traceback.print_exc()
    if failures:
        print(f"[dryrun] {len(failures)} FAILED cells: {failures}")
        return 1
    print("[dryrun] all requested cells lowered + compiled OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
