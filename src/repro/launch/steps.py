"""Step-function builders shared by the dry-run, the trainer, and the server.
Mesh-independent pure functions; shardings are applied by the caller's jit."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models import model
from ..optim import AdamWConfig, adamw_init, adamw_update

Array = jnp.ndarray


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, dtype=jnp.bfloat16,
                    num_microbatches: int = 1):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    num_microbatches > 1 splits the global batch and accumulates gradients
    with a lax.scan — activation memory scales down ~linearly while FLOPs and
    the final gradient are unchanged (the standard big-model memory lever).
    """
    from ..sharding import constrain_tree
    grad_axes = model.param_axes(cfg)

    def grad_fn(p, b):
        out, g = jax.value_and_grad(
            lambda pp: model.loss_fn(cfg, pp, b, dtype=dtype),
            has_aux=True)(p)
        # pin gradient shardings to the parameter shardings: without this,
        # GSPMD materializes FULL f32 per-group gradients (tuple all-reduce +
        # slice) inside the layer scan — reduce-scatter is 16x cheaper.
        return out, constrain_tree(g, grad_axes)

    def step(params, opt_state, batch):
        if num_microbatches == 1:
            (_, metrics), grads = grad_fn(params, batch)
        else:
            nm = num_microbatches
            micro = jax.tree.map(
                lambda a: a.reshape((nm, a.shape[0] // nm) + a.shape[1:]),
                batch)

            def body(carry, mb):
                gsum, lsum, asum = carry
                (_, m), g = grad_fn(params, mb)
                gsum = jax.tree.map(jnp.add, gsum, g)
                return (gsum, lsum + m["loss"], asum + m["aux_loss"]), None

            zeros = constrain_tree(
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params), grad_axes)
            (gsum, lsum, asum), _ = jax.lax.scan(
                body, (zeros, jnp.zeros(()), jnp.zeros(())), micro)
            grads = jax.tree.map(lambda g: g / nm, gsum)
            metrics = {"loss": lsum / nm, "aux_loss": asum / nm}
        params, opt_state, opt_metrics = adamw_update(opt_cfg, grads,
                                                      opt_state, params)
        return params, opt_state, {**metrics, **opt_metrics}

    return step


def make_eval_step(cfg: ModelConfig, dtype=jnp.bfloat16):
    def step(params, batch):
        _, metrics = model.loss_fn(cfg, params, batch, dtype=dtype)
        return metrics
    return step


def make_prefill_step(cfg: ModelConfig, max_cache_len: int = 0,
                      dtype=jnp.bfloat16):
    """(params, batch) -> (last-token logits, cache, pos)."""
    def step(params, batch):
        return model.prefill(cfg, params, batch, max_cache_len=max_cache_len,
                             dtype=dtype)
    return step


def make_decode_step(cfg: ModelConfig, dtype=jnp.bfloat16):
    """(params, cache, tokens (B,1), pos (B,)) -> (logits, new_cache)."""
    def step(params, cache, tokens, pos):
        return model.decode_step(cfg, params, cache, tokens, pos, dtype=dtype)
    return step


def init_train_state(cfg: ModelConfig, key: jax.Array, dtype=jnp.float32):
    params = model.init(cfg, key, dtype)
    return params, adamw_init(params)
