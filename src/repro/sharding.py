"""Logical-axis sharding rules with divisibility fallback.

Every parameter / activation is annotated with *logical* axis names; this
module resolves them to a ``PartitionSpec`` against the production mesh.  A
rule is dropped (with the decision recorded) when the dim is not divisible by
the mesh-axis product or the mesh axis is already taken by another dim of the
same tensor — e.g. qwen3's 40 heads are not divisible by model=16, so the
``heads`` rule falls through and the `head_dim` storage rule picks up `model`.

This is what keeps every (arch × shape × mesh) dry-run cell lowerable with one
fixed production mesh (DESIGN.md §6).
"""
from __future__ import annotations

import math
from typing import Any, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Rule priority order: earlier rules grab mesh axes first.
# logical name -> candidate mesh-axis assignments (each a tuple of mesh axes).
RULES: tuple[tuple[str, tuple[tuple[str, ...], ...]], ...] = (
    ("batch", (("pod", "data"), ("data",))),
    ("act_seq", (("model",),)),                    # sequence parallelism (SP):
    # activations at layer boundaries shard their seq dim over 'model', which
    # shrinks the remat-saved carry stacks 16x; GSPMD re-gathers inside layers
    ("experts", (("model",),)),
    ("vocab", (("model",),)),
    ("mlp", (("model",),)),
    ("heads", (("model",),)),
    # kv_heads/head_dim take 'model' BEFORE seq_shard can: a ring-cache write
    # (.at[b, pos % Tc].set) along a model-sharded seq dim forces GSPMD to
    # gather the whole layer cache per step ("involuntary full remat"); head
    # dims shard the cache just as well and keep the scatter shard-local.
    ("kv_heads", (("model",),)),
    ("ssm_heads", (("model",),)),
    ("head_dim", (("model",),)),                   # also storage fallback when
    #                                                heads %% model != 0 (qwen3)
    # long-context / decode KV-cache seq dim: the data axes when batch leaves
    # them free (long_500k, batch=1), else 'model' as last resort (whisper
    # kv=20 with head_dim 64 taken, etc.)
    ("seq_shard", (("pod", "data"), ("data",), ("model",))),
    ("embed", (("data",),)),                        # FSDP param sharding
    ("ssm_state", ()),
    ("seq", ()),
    ("frames", ()),
    (None, ()),
)

_RULE_INDEX = {name: i for i, (name, _) in enumerate(RULES)}
_RULE_MAP = dict(RULES)


def _mesh_sizes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def spec_for(axes: Sequence[str | None], shape: Sequence[int], mesh: Mesh,
             decisions: list[str] | None = None) -> P:
    """Resolve logical axes -> PartitionSpec for one tensor.

    Dims are processed in rule-priority order so higher-priority logical axes
    win contended mesh axes; within a tensor each mesh axis is used at most
    once (a PartitionSpec invariant).
    """
    if len(axes) != len(shape):
        raise ValueError(f"axes {axes} vs shape {shape} rank mismatch")
    sizes = _mesh_sizes(mesh)
    assignment: dict[int, tuple[str, ...]] = {}
    used: set[str] = set()
    order = sorted(range(len(axes)),
                   key=lambda i: _RULE_INDEX.get(axes[i], len(RULES)))
    for i in order:
        name = axes[i]
        candidates = _RULE_MAP.get(name, ())
        for cand in candidates:
            cand = tuple(a for a in cand if a in sizes)
            if not cand:
                continue
            prod = math.prod(sizes[a] for a in cand)
            if prod <= 1:
                continue
            if any(a in used for a in cand):
                continue
            if shape[i] % prod != 0:
                if decisions is not None:
                    decisions.append(f"skip {name}->{cand}: {shape[i]} % {prod} != 0")
                continue
            assignment[i] = cand
            used.update(cand)
            break
    entries = []
    for i in range(len(axes)):
        a = assignment.get(i)
        if a is None:
            entries.append(None)
        elif len(a) == 1:
            entries.append(a[0])
        else:
            entries.append(a)
    return P(*entries)


def tree_shardings(axes_tree: Any, shape_tree: Any, mesh: Mesh,
                   decisions: list[str] | None = None) -> Any:
    """Map (axes, shapes) pytrees -> NamedSharding pytree. ``axes_tree`` leaves
    are tuples of logical names; ``shape_tree`` leaves expose ``.shape``."""
    def one(axes, shaped):
        return NamedSharding(mesh, spec_for(axes, shaped.shape, mesh, decisions))
    return jax.tree.map(one, axes_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))


# Trace-time mesh used by ``constrain``.  Set by the launcher (dryrun/train)
# before tracing; None (the default) makes ``constrain`` a no-op so smoke tests
# and single-device benchmarks never touch device state.
_CURRENT_MESH: Mesh | None = None


def set_mesh(mesh: Mesh | None) -> None:
    global _CURRENT_MESH
    _CURRENT_MESH = mesh


class use_rules_mesh:
    """Context manager: activate ``constrain`` against ``mesh`` while tracing."""

    def __init__(self, mesh: Mesh | None):
        self.mesh = mesh
        self.prev: Mesh | None = None

    def __enter__(self):
        self.prev = get_mesh()
        set_mesh(self.mesh)
        return self.mesh

    def __exit__(self, *exc):
        set_mesh(self.prev)
        return False


def get_mesh() -> Mesh | None:
    return _CURRENT_MESH


def constrain(x, axes: Sequence[str | None]):
    """with_sharding_constraint by logical axes — no-op when no mesh is set."""
    if _CURRENT_MESH is None:
        return x
    spec = spec_for(axes, x.shape, _CURRENT_MESH)
    return jax.lax.with_sharding_constraint(x, NamedSharding(_CURRENT_MESH, spec))


def constrain_tree(tree: Any, axes_tree: Any):
    """constrain() over a pytree of tensors + matching tree of axis tuples
    (axis tuples are leaves of ``axes_tree``, hence the is_leaf)."""
    if _CURRENT_MESH is None:
        return tree
    return jax.tree.map(
        lambda x, ax: constrain(x, ax), tree, axes_tree,
        is_leaf=lambda a: isinstance(a, tuple) and all(
            isinstance(e, (str, type(None))) for e in a))
