"""mixtral-8x22b — MoE 8 experts top-2, sliding-window attention. [arXiv:2401.04088]"""
from .base import LayerSpec, ModelConfig, MoESpec

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    layer_pattern=(LayerSpec(kind="attn", window=4096, moe=True),),
    moe=MoESpec(n_experts=8, top_k=2, d_ff=16384),
    rope_theta=1000000.0,
    notes="8 experts top-2, SWA window 4096 -> sub-quadratic, runs long_500k",
)
