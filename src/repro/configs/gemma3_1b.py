"""gemma3-1b — dense, 5:1 local:global attention, 262k vocab, head_dim=256.
[hf:google/gemma-3-1b-pt]

26 layers = 4 x (5 local + 1 global) + 2 local; the pattern is written out
explicitly (period 26, one scan group).  Local window = 512.  Sub-quadratic
for long-context decode except the 4 global layers; long_500k decode reads the
global layers' full cache (O(T) per step) and window-masks the local ones.
"""
from .base import LayerSpec, ModelConfig

_LOCAL = LayerSpec(kind="attn", window=512)
_GLOBAL = LayerSpec(kind="attn", window=0)
_PATTERN = (tuple([_LOCAL] * 5 + [_GLOBAL]) * 4) + (_LOCAL, _LOCAL)

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    layer_pattern=_PATTERN,
    rope_theta=1000000.0,
    tie_embeddings=True,
    notes="5:1 local:global (window 512), 128k context",
)
