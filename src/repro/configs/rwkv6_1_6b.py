"""rwkv6-1.6b (Finch) — attention-free, data-dependent decay. [arXiv:2404.05892]"""
from .base import LayerSpec, ModelConfig, SSMSpec

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,           # time-mix heads of head_dim 64
    n_kv_heads=32,
    head_dim=64,
    d_ff=7168,
    vocab_size=65536,
    layer_pattern=(LayerSpec(kind="rwkv6"),),
    ssm=SSMSpec(kind="rwkv6", state_dim=64, head_dim=64),
    notes="Finch: data-dependent decay; O(1) state decode -> runs long_500k",
)
