"""qwen3-14b — dense, qk_norm, GQA kv=8. [hf:Qwen/Qwen3-14B]

40 heads is NOT divisible by the 16-way model axis: the sharding rules engine
falls back (heads unsharded in compute; head_dim sharded for param storage) —
see repro/sharding.py and DESIGN.md §6.
"""
from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    vocab_size=151936,
    layer_pattern=(LayerSpec(kind="attn"),),
    use_qk_norm=True,
    rope_theta=1000000.0,
    notes="qk_norm, GQA kv=8",
)
