"""Architecture registry: ``--arch <id>`` resolution + reduced smoke configs."""
from __future__ import annotations

import dataclasses

from .base import EncoderSpec, ModelConfig, MoESpec

from . import (command_r_plus_104b, gemma3_1b, llama4_scout_17b_a16e,
               llama_3_2_vision_90b, mixtral_8x22b, phi3_mini_3_8b, qwen3_14b,
               rwkv6_1_6b, whisper_large_v3, zamba2_7b)

_MODULES = (phi3_mini_3_8b, qwen3_14b, gemma3_1b, command_r_plus_104b,
            llama4_scout_17b_a16e, mixtral_8x22b, zamba2_7b, rwkv6_1_6b,
            llama_3_2_vision_90b, whisper_large_v3)

CONFIGS: dict[str, ModelConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}
ARCH_IDS = tuple(CONFIGS)

# Archs that run the long_500k cell (sub-quadratic or mostly-local attention;
# see DESIGN.md §Arch-applicability for the per-arch rationale and skips).
LONG_CONTEXT_OK = frozenset({
    "gemma3-1b",      # 5:1 local:global; global layers are O(T)-per-step decode
    "mixtral-8x22b",  # SWA window 4096
    "zamba2-7b",      # Mamba2 state + windowed shared attention
    "rwkv6-1.6b",     # O(1) state
})


def runs_shape(arch: str, shape_name: str) -> bool:
    return shape_name != "long_500k" or arch in LONG_CONTEXT_OK


def get_config(name: str) -> ModelConfig:
    try:
        return CONFIGS[name]
    except KeyError:
        raise ValueError(f"unknown arch {name!r}; have {sorted(CONFIGS)}") from None


def smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config: small widths/depths, tiny vocab; preserves
    the layer pattern structure (local:global, MoE, hybrid, cross-attn)."""
    cfg = get_config(name)
    period = cfg.period
    # keep 1-2 pattern periods; gemma3's explicit 26-pattern is trimmed to 6.
    if period > 8:
        pattern = cfg.layer_pattern[:6]
        n_layers = 6
    else:
        pattern = cfg.layer_pattern
        n_layers = period * min(2, cfg.n_groups)
    kw: dict = dict(
        n_layers=n_layers,
        layer_pattern=pattern,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=16,
        d_ff=96,
        vocab_size=512,
    )
    if cfg.moe is not None:
        kw["moe"] = MoESpec(n_experts=4, top_k=cfgg_topk(cfg), d_ff=96)
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(cfg.ssm, state_dim=8, head_dim=16,
                                        expand=2, conv_width=4)
    if cfg.encoder is not None:
        kw["encoder"] = EncoderSpec(n_layers=2, n_frames=12)
        kw["cross_attn_source_len"] = 12
    if cfg.cross_attn_source_len and cfg.encoder is None:
        kw["cross_attn_source_len"] = 12
    # shrink windows so local attention is exercised at tiny seq lens
    new_pat = tuple(dataclasses.replace(s, window=4 if s.window else 0)
                    for s in kw["layer_pattern"])
    kw["layer_pattern"] = new_pat
    return cfg.scaled(**kw)


def cfgg_topk(cfg: ModelConfig) -> int:
    return min(cfg.moe.top_k, 2)
