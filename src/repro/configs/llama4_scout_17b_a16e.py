"""llama4-scout-17b-a16e — MoE 16 experts top-1. [hf:meta-llama/Llama-4-Scout-17B-16E]

Early-fusion multimodality is out of scope for the LM backbone cells (text
tokens only); noted in DESIGN.md.  16 experts divide the 16-way model axis
exactly -> expert-parallel sharding.
"""
from .base import LayerSpec, ModelConfig, MoESpec

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    layer_pattern=(LayerSpec(kind="attn", moe=True),),
    moe=MoESpec(n_experts=16, top_k=1, d_ff=8192),
    rope_theta=500000.0,
    notes="MoE 16e top-1; early fusion frontend out of scope (text backbone)",
)
