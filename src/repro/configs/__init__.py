from .base import EncoderSpec, LayerSpec, ModelConfig, MoESpec, SHAPES, ShapeSpec, SSMSpec
from .registry import ARCH_IDS, CONFIGS, get_config, smoke_config
