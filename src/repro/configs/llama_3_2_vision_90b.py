"""llama-3.2-vision-90b — VLM: text backbone w/ gated cross-attn image layers
every 5th layer. [hf:meta-llama/Llama-3.2-90B-Vision]

Spec: the modality frontend is a STUB — input_specs() provides precomputed
image-patch embeddings (batch, 1024, d_model); only the transformer backbone
is modeled.
"""
from .base import LayerSpec, ModelConfig

_SELF = LayerSpec(kind="attn")
_CROSS = LayerSpec(kind="attn", cross_attn=True)

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    layer_pattern=(_SELF, _SELF, _SELF, _SELF, _CROSS),
    cross_attn_source_len=1024,
    rope_theta=500000.0,
    notes="gated cross-attn image layers every 5th layer; vision tower stubbed",
)
