"""zamba2-7b — hybrid: Mamba2 backbone + SHARED attention block. [arXiv:2411.15242]

81 layers, period-3 pattern: (mamba2, mamba2, mamba2 + shared attn).  The
shared attention block has ONE global parameter set reused at all 27
applications (zamba's hallmark).  We window the shared attention (4096) so the
hybrid stays sub-quadratic for long_500k (adaptation noted in DESIGN.md).
"""
from .base import LayerSpec, ModelConfig, SSMSpec

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    layer_pattern=(
        LayerSpec(kind="mamba2"),
        LayerSpec(kind="mamba2"),
        LayerSpec(kind="mamba2", shared_attn=True, window=4096),
    ),
    ssm=SSMSpec(kind="mamba2", state_dim=64, head_dim=64, expand=2, conv_width=4),
    notes="Mamba2 + shared windowed attn blocks (window 4096), ssm_state=64",
)
