"""The paper's own workload as a dry-run config: distributed WLSH-KRR.

Sized like the paper's largest experiment scaled to a 256-chip pod:
Forest-Cover-scale n with m instances, CountSketch table mode (the only mode
whose bucket merge is a psum — see DESIGN.md §3).
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class WLSHKRRConfig:
    name: str = "wlsh_krr"
    family: str = "krr"
    n_points: int = 4_194_304     # 2^22 training points (Forest Cover x ~7)
    dim: int = 64                 # feature dimension
    m: int = 64                   # independent WLSH instances
    table_size: int = 1 << 23     # CountSketch table (2 x n)
    bucket: str = "rect"
    pdf_shape: float = 2.0        # p(w) = w e^{-w}
    lam: float = 1.0
    cg_iters: int = 32            # iterations fused into one lowered step
    backend: str = "auto"         # WLSH operator backend (core/operator.py):
                                  # auto = Pallas kernels on TPU,
                                  # jnp reference elsewhere
    fused: bool = True            # one-pass slot-blocked matvec where legal
                                  # (unsharded data axes); split otherwise
    blocked_split: bool = True    # sharded psum path: visit-list split
                                  # kernels off the same slot-blocked layout
                                  # (pallas backend; tables stay psum-able)
    precond: str = "none"         # PCG preconditioner (core/precond.py):
                                  # none | jacobi (any mesh) | nystrom
                                  # (unsharded data axes only)
    precond_rank: int = 128       # Nyström pivot rank (mirrors
                                  # core.precond.DEFAULT_NYSTROM_RANK)
    num_rhs: int = 1              # RHS block width k: batched KRR targets /
                                  # GP posterior samples per solve
    table_mode: str = "psum"      # bucket-table merge strategy:
                                  # psum (dense (m, B) tables; paper-faithful)
                                  # | hashjoin (table sharded over data,
                                  # all_to_all nonzero routing — DESIGN.md §6)
    cap_factor: float = 2.0       # hashjoin per-destination capacity factor
                                  # (cap ~ cap_factor·e/n_shards; overflow
                                  # buckets are dropped)
    wire_dtype: str = "bf16"      # hashjoin all_to_all payload dtype:
                                  # bf16 (half the bytes, f32 accumulate,
                                  # accuracy pinned by tests) | f32 (exact)
    overflow: str = "warn"        # hashjoin capacity-overflow policy
                                  # (DESIGN.md §9): raise | warn | allow —
                                  # dropped-bucket counts are always
                                  # accounted, never silent
    solve_checkpoint_every: int = 0  # persist PCG SolveState every N
                                  # iterations (0 = off); a preempted fit
                                  # resumes from the last saved chunk
    serve_mesh: str = "8x32"      # sharded SERVING grid "MxN" (model_shards
                                  # x data_shards) for export_artifact_sharded
                                  # / ShardedPredictor; the table piece (i, j)
                                  # holds slots [j·B/N, (j+1)·B/N) of instance
                                  # rows [i·m/M, (i+1)·m/M) — DESIGN.md §10
    serve_max_batch: int = 1024   # serving padding-bucket cap (power of two,
                                  # >= data_shards; requests above it chunk)
    serve_dedup: bool = False     # serving wire mode: False = broadcast
                                  # route (lowest latency, can't overflow);
                                  # True = training routing's deduplicated
                                  # cells (bulk scoring)
    notes: str = "paper's technique; data-sharded PCG step over the mesh"


CONFIG = WLSHKRRConfig()

# Shape cells for the dry-run grid: (name, n_points, m).
KRR_SHAPES = {
    "krr_4m": dict(n_points=4_194_304, m=64, table_size=1 << 23),
    "krr_32m": dict(n_points=33_554_432, m=32, table_size=1 << 26),
}
