"""Architecture config dataclasses.

A ``ModelConfig`` fully determines parameters, sharding and step functions.
``layer_pattern`` is a tuple of per-layer ``LayerSpec``s repeated cyclically
(`n_layers % len(layer_pattern) == 0`); heterogeneous stacks (gemma3 5:1
local:global, zamba2 mamba+shared-attn, VLM cross-attn every 5) are expressed
as patterns so the layer stack lowers to one `lax.scan` over pattern groups.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    d_ff: int                   # per-expert hidden dim
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMSpec:
    kind: str                   # 'mamba2' | 'rwkv6'
    state_dim: int = 64         # N (mamba2) / head_dim (rwkv6)
    head_dim: int = 64
    expand: int = 2             # d_inner = expand * d_model (mamba2)
    conv_width: int = 4


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: str = "attn"          # 'attn' | 'mamba2' | 'rwkv6'
    window: int = 0             # 0 = global attention; >0 = sliding window
    moe: bool = False           # MoE FFN instead of dense
    cross_attn: bool = False    # cross-attention sublayer (VLM / whisper dec)
    shared_attn: bool = False   # zamba2: run the global shared attn block here


@dataclasses.dataclass(frozen=True)
class EncoderSpec:
    """Whisper-style encoder; the conv/mel frontend is a STUB — inputs are
    precomputed frame embeddings of shape (batch, n_frames, d_model)."""
    n_layers: int
    n_frames: int = 1500


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    layer_pattern: tuple[LayerSpec, ...] = (LayerSpec(),)
    moe: Optional[MoESpec] = None
    ssm: Optional[SSMSpec] = None
    encoder: Optional[EncoderSpec] = None
    cross_attn_source_len: int = 0   # image tokens (vlm) / enc frames (audio)
    use_qk_norm: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    attn_logit_softcap: float = 0.0
    notes: str = ""

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.n_layers % len(self.layer_pattern) != 0:
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} not divisible by "
                f"pattern period {len(self.layer_pattern)}")

    @property
    def period(self) -> int:
        return len(self.layer_pattern)

    @property
    def n_groups(self) -> int:
        return self.n_layers // self.period

    @property
    def has_shared_attn(self) -> bool:
        return any(s.shared_attn for s in self.layer_pattern)

    @property
    def is_sub_quadratic(self) -> bool:
        """True iff no layer does unbounded-window softmax attention over the
        full sequence (criterion for running the long_500k shape)."""
        for s in self.layer_pattern:
            if s.kind == "attn" and s.window == 0:
                return False
        return True

    def scaled(self, **overrides) -> "ModelConfig":
        """Reduced copy for smoke tests."""
        return dataclasses.replace(self, **overrides)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""
    name: str                   # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                   # 'train' | 'prefill' | 'decode'


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}
