"""whisper-large-v3 — encoder-decoder; conv/mel frontend STUB. [arXiv:2212.04356]

input_specs() provides precomputed frame embeddings (batch, 1500, d_model) in
place of the conv frontend.  Decoder: causal self-attn + cross-attn to the
encoder states in every layer.  Sinusoidal positions on both sides (adaptation:
the real decoder uses a 448-entry learned table, too small for the assigned
32k-decode shapes — noted in DESIGN.md).
"""
from .base import EncoderSpec, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    layer_pattern=(LayerSpec(kind="attn", cross_attn=True),),
    encoder=EncoderSpec(n_layers=32, n_frames=1500),
    cross_attn_source_len=1500,
    notes="enc-dec; conv frontend stubbed as precomputed frame embeddings",
)
