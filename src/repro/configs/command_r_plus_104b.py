"""command-r-plus-104b — dense, GQA kv=8, no biases. [hf:CohereForAI/c4ai-command-r-plus]"""
from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=33792,
    vocab_size=256000,
    layer_pattern=(LayerSpec(kind="attn"),),
    rope_theta=75000000.0,
    notes="GQA kv=8, no-bias",
)
