"""repro — WLSH kernel ridge regression framework (JAX, multi-pod)."""
__version__ = "0.1.0"
