"""Backend selection for the WLSH operator stack.

Three backends implement the same operator contract (see core/operator.py):

* ``reference`` — pure jnp (core/lsh.py + core/wlsh.py).  Always available,
  always correct; the oracle every other backend is tested against.
* ``pallas``    — the fused TPU kernels (kernels/featurize + kernels/binning).
  On a real TPU they run compiled; elsewhere they fall back to Pallas
  interpret mode (Python emulation — correctness only, not speed).
* ``auto``      — platform-based choice: ``pallas`` when the default JAX
  backend is a TPU, ``reference`` otherwise.  This is the default everywhere
  so that laptops/CI get the fast jnp path and pods get the fused kernels
  without any config change.

The environment variable ``REPRO_WLSH_BACKEND`` overrides ``auto`` (useful for
forcing the kernel path through CI parity runs).
"""
from __future__ import annotations

import os

import jax

BACKENDS = ("reference", "pallas", "auto")

_ENV_VAR = "REPRO_WLSH_BACKEND"


def default_interpret() -> bool:
    """Pallas interpret mode: only compile for real on TPU."""
    return jax.default_backend() != "tpu"


def resolve_backend(name: str | None = None) -> str:
    """Resolve a backend name to a concrete one ('reference' or 'pallas').

    ``None`` and ``'auto'`` pick per platform (TPU -> pallas, else reference),
    unless ``REPRO_WLSH_BACKEND`` forces a concrete choice.
    """
    if name is None:
        name = "auto"
    if name not in BACKENDS:
        raise ValueError(f"unknown backend {name!r}; expected one of {BACKENDS}")
    if name == "auto":
        env = os.environ.get(_ENV_VAR, "").strip().lower()
        if env:
            if env not in BACKENDS or env == "auto":
                raise ValueError(
                    f"{_ENV_VAR}={env!r} must be 'reference' or 'pallas'")
            return env
        return "pallas" if jax.default_backend() == "tpu" else "reference"
    return name
