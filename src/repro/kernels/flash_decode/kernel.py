"""Pallas TPU kernel: flash decode attention (one query token vs a long KV
cache, online softmax over KV blocks).

serve_step's bottleneck at decode_32k/long_500k is reading the KV cache; the
jnp path materializes (B, H, 1, T) scores in HBM.  This kernel streams KV
blocks through VMEM keeping only the (G, D) accumulator and (G, 1) running
max/sum statistics per (batch, kv-head) — the classic flash-decoding scheme
adapted to GQA: all G = H/KV query heads that share a kv head are processed
together, so each cache block is read exactly once.

Grid: (B, KV, T / BLOCK_T); the trailing grid axis is sequential, carrying the
online-softmax state in VMEM scratch across KV blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_T = 512
NEG_INF = -1e30


def _decode_body(q_ref, k_ref, v_ref, valid_ref, o_ref, m_ref, l_ref, acc_ref):
    tb = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(tb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...][0, 0]                                    # (G, D) f32
    k = k_ref[...][0, :, 0, :]                              # (BT, D)
    v = v_ref[...][0, :, 0, :]                              # (BT, D)
    valid = valid_ref[...][0]                               # (BT,) int32

    d = q.shape[-1]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s * (1.0 / (d ** 0.5))                              # (G, BT)
    s = jnp.where(valid[None, :] > 0, s, NEG_INF)

    m_prev = m_ref[...]                                     # (G, 1)
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur)                                  # (G, BT)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v.astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_cur

    @pl.when(tb == nt - 1)
    def _finish():
        o_ref[...] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30))[None, None]


@functools.partial(jax.jit, static_argnames=("interpret", "block_t"))
def flash_decode_pallas(q, k, v, valid, *, interpret: bool = True,
                        block_t: int = BLOCK_T):
    """q (B, KV, G, D) f32; k, v (B, T, KV, D); valid (B, T) int32 (1 = row
    holds a real key).  Returns out (B, KV, G, D) f32."""
    b, kv, g, d = q.shape
    t = k.shape[1]
    bt = min(block_t, t)
    if t % bt:
        raise ValueError(f"T={t} must be a multiple of block_t={bt}")
    grid = (b, kv, t // bt)
    return pl.pallas_call(
        _decode_body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda i, h, tb: (i, h, 0, 0)),
            pl.BlockSpec((1, bt, 1, d), lambda i, h, tb: (i, tb, h, 0)),
            pl.BlockSpec((1, bt, 1, d), lambda i, h, tb: (i, tb, h, 0)),
            pl.BlockSpec((1, bt), lambda i, h, tb: (i, tb)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda i, h, tb: (i, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kv, g, d), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),    # running max
            pltpu.VMEM((g, 1), jnp.float32),    # running sum
            pltpu.VMEM((g, d), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q.astype(jnp.float32), k, v, valid.astype(jnp.int32))
