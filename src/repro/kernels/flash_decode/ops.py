"""Public op: GQA flash decode with (B, H, D) <-> (B, KV, G, D) plumbing."""
from __future__ import annotations

from .kernel import flash_decode_pallas
from .ref import flash_decode_ref


def decode_attend_op(q, cache_k, cache_v, valid, *, use_kernel: bool = True,
                     interpret: bool = True):
    """q (B, H, D); cache_{k,v} (B, T, KV, D); valid (B, T) -> (B, H, D).
    H must be a multiple of KV (GQA)."""
    b, h, d = q.shape
    kv = cache_k.shape[2]
    g = h // kv
    qg = q.reshape(b, kv, g, d)
    fn = flash_decode_pallas if use_kernel else flash_decode_ref
    kwargs = {"interpret": interpret} if use_kernel else {}
    out = fn(qg, cache_k, cache_v, valid, **kwargs)
    return out.reshape(b, h, d)
