from .ops import decode_attend_op
from .kernel import flash_decode_pallas
from .ref import flash_decode_ref
