"""Pure-jnp oracle for flash decode: masked softmax over the full cache."""
from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def flash_decode_ref(q, k, v, valid):
    """q (B, KV, G, D); k, v (B, T, KV, D); valid (B, T).  -> (B, KV, G, D)."""
    d = q.shape[-1]
    s = jnp.einsum("bkgd,btkd->bkgt", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (d ** 0.5)
    s = jnp.where(valid[:, None, None, :] > 0, s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("bkgt,btkd->bkgd", p, v.astype(jnp.float32))
