"""Pallas TPU kernels: CountSketch bucket scatter/gather as one-hot MXU matmuls.

TPUs have no scatter atomics; the paper's bucket-load accumulation
(B_j += beta_i * weight_i) is re-expressed as a systolic matmul:

    table_tile (1, BT) += contrib_block (1, BN) @ onehot(slot - tile_lo) (BN, BT)

and the readout gather (out_i = table[slot_i]) as the transposed product.
The one-hot matrices never touch HBM — they are built in VMEM per grid step
from an iota compare.  Grid iterates the reduction dimension (point blocks for
scatter, table tiles for gather) in the trailing, sequential position so the
output tile accumulates in place across steps (standard Pallas revisiting
pattern).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 1024       # points per block
BLOCK_T = 512        # table slots per tile


def _scatter_body(slot_ref, contrib_ref, table_ref):
    nb = pl.program_id(2)

    @pl.when(nb == 0)
    def _init():
        table_ref[...] = jnp.zeros_like(table_ref)

    bt = table_ref.shape[1]
    tile_lo = pl.program_id(1) * bt
    slot = slot_ref[...][0]                                  # (bn,) int32
    contrib = contrib_ref[...]                               # (1, bn) f32
    col = jax.lax.broadcasted_iota(jnp.int32, (slot.shape[0], bt), 1)
    onehot = (slot[:, None] - tile_lo == col).astype(jnp.float32)
    table_ref[...] += jax.lax.dot_general(
        contrib, onehot, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def _gather_body(slot_ref, table_ref, out_ref):
    tb = pl.program_id(2)

    @pl.when(tb == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    bt = table_ref.shape[1]
    tile_lo = tb * bt
    slot = slot_ref[...][0]                                  # (bn,)
    col = jax.lax.broadcasted_iota(jnp.int32, (slot.shape[0], bt), 1)
    onehot = (slot[:, None] - tile_lo == col).astype(jnp.float32)
    # out (1, bn) += table (1, bt) @ onehot^T (bt, bn)
    out_ref[...] += jax.lax.dot_general(
        table_ref[...], onehot, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("table_size", "interpret",
                                             "block_n", "block_t"))
def bin_scatter_pallas(slot, contrib, *, table_size: int, interpret: bool = True,
                       block_n: int = BLOCK_N, block_t: int = BLOCK_T):
    """slot (m, n) int32 in [0, table_size); contrib (m, n) f32.
    Returns tables (m, table_size) f32 with tables[s, j] = sum_{slot==j} contrib."""
    m, n = slot.shape
    bn, bt = min(block_n, n), min(block_t, table_size)
    if n % bn or table_size % bt:
        raise ValueError("n and table_size must divide their block sizes")
    grid = (m, table_size // bt, n // bn)
    return pl.pallas_call(
        _scatter_body,
        grid=grid,
        in_specs=[pl.BlockSpec((1, bn), lambda i, t, j: (i, j)),
                  pl.BlockSpec((1, bn), lambda i, t, j: (i, j))],
        out_specs=pl.BlockSpec((1, bt), lambda i, t, j: (i, t)),
        out_shape=jax.ShapeDtypeStruct((m, table_size), jnp.float32),
        interpret=interpret,
    )(slot, contrib)


@functools.partial(jax.jit, static_argnames=("interpret", "block_n", "block_t"))
def bin_gather_pallas(slot, tables, *, interpret: bool = True,
                      block_n: int = BLOCK_N, block_t: int = BLOCK_T):
    """slot (m, n) int32; tables (m, B) f32.  Returns out (m, n) f32 with
    out[s, i] = tables[s, slot[s, i]]."""
    m, n = slot.shape
    table_size = tables.shape[1]
    bn, bt = min(block_n, n), min(block_t, table_size)
    if n % bn or table_size % bt:
        raise ValueError("n and table_size must divide their block sizes")
    grid = (m, n // bn, table_size // bt)
    return pl.pallas_call(
        _gather_body,
        grid=grid,
        in_specs=[pl.BlockSpec((1, bn), lambda i, j, t: (i, j)),
                  pl.BlockSpec((1, bt), lambda i, j, t: (i, t))],
        out_specs=pl.BlockSpec((1, bn), lambda i, j, t: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(slot, tables)
