"""Pallas TPU kernels: CountSketch bucket scatter/gather as one-hot MXU matmuls.

TPUs have no scatter atomics; the paper's bucket-load accumulation
(B_j += beta_i * weight_i) is re-expressed as a systolic matmul:

    table_tile (1, BT) += contrib_block (1, BN) @ onehot(slot - tile_lo) (BN, BT)

and the readout gather (out_i = table[slot_i]) as the transposed product.
The one-hot matrices never touch HBM — they are built in VMEM per grid step
from an iota compare.  Grid iterates the reduction dimension (point blocks for
scatter, table tiles for gather) in the trailing, sequential position so the
output tile accumulates in place across steps (standard Pallas revisiting
pattern).

Two kernel families:

* **split** (``bin_scatter_pallas`` / ``bin_gather_pallas``) — iterate the
  full (point-block × table-tile) cross product and materialize the (m, B)
  table in HBM between the two calls.  O(n·B) MXU work, but the table is a
  psum-able array — this is what the distributed data-shard merge needs.
* **fused** (``bin_fused_matvec_pallas``) — one ``pallas_call`` drives both
  products off a slot-blocked layout (``core.wlsh.BlockedLayout``): points
  are pre-sorted so each grid visit pairs one point block with the ONE table
  tile it collides with, the visit list is scalar-prefetched into SMEM so
  the BlockSpec index maps can follow the data-dependent schedule, and the
  table tile lives in a VMEM scratch for both the scatter and the gather
  pass — the (m, B) table never exists in HBM.  O(n/bn + B/bt) visits per
  instance: genuinely linear when B = Θ(n).
* **blocked split** (``bin_scatter_blocked_pallas`` /
  ``bin_gather_blocked_pallas``) — the split contract (tables in HBM, so
  the distributed data-axis psum can merge them between the two calls) on
  the fused kernel's visit schedule: per pass, a scalar-prefetched
  per-instance list walks only the O(n/bn + B/bt) real (point block, table
  tile) collisions of the slot-blocked layout.  The scatter schedule visits
  every tile at least once (empty tiles against an all-padding block), so
  the HBM output table is explicitly zeroed tile by tile — no tile is left
  uninitialized by the data-dependent grid.  Multi-RHS is native: the k
  columns share each one-hot via (k, bn)×(bn, bt) products against
  (1, k, bt) table blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_N = 1024       # points per block
BLOCK_T = 512        # table slots per tile


def _scatter_body(slot_ref, contrib_ref, table_ref):
    nb = pl.program_id(2)

    @pl.when(nb == 0)
    def _init():
        table_ref[...] = jnp.zeros_like(table_ref)

    bt = table_ref.shape[1]
    tile_lo = pl.program_id(1) * bt
    slot = slot_ref[...][0]                                  # (bn,) int32
    contrib = contrib_ref[...]                               # (1, bn) f32
    col = jax.lax.broadcasted_iota(jnp.int32, (slot.shape[0], bt), 1)
    onehot = (slot[:, None] - tile_lo == col).astype(jnp.float32)
    table_ref[...] += jax.lax.dot_general(
        contrib, onehot, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def _gather_body(slot_ref, table_ref, out_ref):
    tb = pl.program_id(2)

    @pl.when(tb == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    bt = table_ref.shape[1]
    tile_lo = tb * bt
    slot = slot_ref[...][0]                                  # (bn,)
    col = jax.lax.broadcasted_iota(jnp.int32, (slot.shape[0], bt), 1)
    onehot = (slot[:, None] - tile_lo == col).astype(jnp.float32)
    # out (1, bn) += table (1, bt) @ onehot^T (bt, bn)
    out_ref[...] += jax.lax.dot_general(
        table_ref[...], onehot, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("table_size", "interpret",
                                             "block_n", "block_t"))
def bin_scatter_pallas(slot, contrib, *, table_size: int, interpret: bool = True,
                       block_n: int = BLOCK_N, block_t: int = BLOCK_T):
    """slot (m, n) int32 in [0, table_size); contrib (m, n) f32.
    Returns tables (m, table_size) f32 with tables[s, j] = sum_{slot==j} contrib."""
    m, n = slot.shape
    bn, bt = min(block_n, n), min(block_t, table_size)
    if n % bn or table_size % bt:
        raise ValueError("n and table_size must divide their block sizes")
    grid = (m, table_size // bt, n // bn)
    return pl.pallas_call(
        _scatter_body,
        grid=grid,
        in_specs=[pl.BlockSpec((1, bn), lambda i, t, j: (i, j)),
                  pl.BlockSpec((1, bn), lambda i, t, j: (i, j))],
        out_specs=pl.BlockSpec((1, bt), lambda i, t, j: (i, t)),
        out_shape=jax.ShapeDtypeStruct((m, table_size), jnp.float32),
        interpret=interpret,
    )(slot, contrib)


def _fused_body(v_block_ref, v_tile_ref, v_phase_ref, slot_ref, coeff_ref,
                beta_ref, out_ref, table_ref):
    """One visit: (point block, table tile, phase) from the prefetched lists.

    Tiles arrive in ascending order with all scatter visits before any gather
    visit, so ``table_ref`` (VMEM scratch) is zeroed exactly once per tile,
    accumulated over the tile's scatter visits, and then read by its gather
    visits — it never round-trips through HBM.  Padding visits re-gather the
    last real block against the unchanged tile (idempotent full overwrite).
    """
    i, j = pl.program_id(0), pl.program_id(1)
    tile = v_tile_ref[i, j]
    phase = v_phase_ref[i, j]
    prev_tile = v_tile_ref[i, jnp.maximum(j - 1, 0)]
    new_tile = (j == 0) | (tile != prev_tile)

    @pl.when(new_tile)
    def _zero():
        table_ref[...] = jnp.zeros_like(table_ref)

    bt = table_ref.shape[1]
    slot = slot_ref[...][0]                                  # (bn,) int32
    col = jax.lax.broadcasted_iota(jnp.int32, (slot.shape[0], bt), 1)
    onehot = (slot[:, None] - tile * bt == col).astype(jnp.float32)

    @pl.when(phase == 0)
    def _scatter():
        contrib = coeff_ref[...] * beta_ref[...]             # (1, bn)
        table_ref[...] += jax.lax.dot_general(
            contrib, onehot, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(phase == 1)
    def _gather():
        out_ref[...] = coeff_ref[...] * jax.lax.dot_general(
            table_ref[...], onehot, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)


def _fused_body_mrhs(v_block_ref, v_tile_ref, v_phase_ref, slot_ref, coeff_ref,
                     beta_ref, out_ref, table_ref):
    """Multi-RHS variant of ``_fused_body``: the k RHS columns share every
    one-hot matrix, so the tile products widen from (1, bn)×(bn, bt) to
    (k, bn)×(bn, bt) and the VMEM table tile to (k, bt) — same visit
    schedule, same HBM traffic for slots/coeffs, k× the MXU work."""
    i, j = pl.program_id(0), pl.program_id(1)
    tile = v_tile_ref[i, j]
    phase = v_phase_ref[i, j]
    prev_tile = v_tile_ref[i, jnp.maximum(j - 1, 0)]
    new_tile = (j == 0) | (tile != prev_tile)

    @pl.when(new_tile)
    def _zero():
        table_ref[...] = jnp.zeros_like(table_ref)

    bt = table_ref.shape[1]
    slot = slot_ref[...][0]                                  # (bn,) int32
    col = jax.lax.broadcasted_iota(jnp.int32, (slot.shape[0], bt), 1)
    onehot = (slot[:, None] - tile * bt == col).astype(jnp.float32)

    @pl.when(phase == 0)
    def _scatter():
        contrib = coeff_ref[...] * beta_ref[...][0]          # (k, bn)
        table_ref[...] += jax.lax.dot_general(
            contrib, onehot, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(phase == 1)
    def _gather():
        out_ref[...] = (coeff_ref[...] * jax.lax.dot_general(
            table_ref[...], onehot, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32))[None]


@functools.partial(jax.jit, static_argnames=("block_n", "block_t", "interpret"))
def bin_fused_matvec_pallas(v_block, v_tile, v_phase, slot_lay, coeff_lay,
                            beta_lay, *, block_n: int, block_t: int,
                            interpret: bool = True):
    """Fused scatter→gather over a slot-blocked layout (one kernel call).

    v_block/v_tile/v_phase (m, V) int32 — the per-instance visit schedule
    (scalar-prefetched; the index maps select layout block ``v_block[i, j]``
    at visit j).  slot_lay/coeff_lay (m, L) — the blocked layout arrays with
    L a multiple of ``block_n``.  ``beta_lay`` is (m, L) for one RHS or
    (m, k, L) for a k-column RHS block laid out along the same permutation.
    Returns out_lay of ``beta_lay``'s shape, f32, with
    ``out_lay[..., p] = coeff_lay[p] * table[slot_lay[p]]`` at every real
    layout position (padding positions have coeff 0).  The (m, B[, k]) table
    exists only as a (1|k, block_t) VMEM scratch tile — the k columns ride
    the same one-hot products, so the extra HBM traffic over single-RHS is
    just beta/out themselves.
    """
    m, layout_len = slot_lay.shape
    if layout_len % block_n:
        raise ValueError("layout length must be a multiple of block_n")
    n_vis = v_block.shape[1]
    lay_spec = pl.BlockSpec((1, block_n), lambda i, j, vb, vt, vp: (i, vb[i, j]))
    if beta_lay.ndim == 2:
        beta_spec, scratch_rows = lay_spec, 1
        body = _fused_body
    else:
        k = beta_lay.shape[1]
        beta_spec = pl.BlockSpec((1, k, block_n),
                                 lambda i, j, vb, vt, vp: (i, 0, vb[i, j]))
        scratch_rows, body = k, _fused_body_mrhs
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(m, n_vis),
        in_specs=[lay_spec, lay_spec, beta_spec],
        out_specs=beta_spec,
        scratch_shapes=[pltpu.VMEM((scratch_rows, block_t), jnp.float32)],
    )
    return pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(beta_lay.shape, jnp.float32),
        interpret=interpret,
    )(v_block, v_tile, v_phase, slot_lay, coeff_lay, beta_lay)


def _tile_onehot(slot_ref, tile, bt):
    """(bn, bt) one-hot of this block's slots against table tile ``tile``
    (slots outside the tile produce all-zero rows)."""
    slot = slot_ref[...][0]                                  # (bn,) int32
    col = jax.lax.broadcasted_iota(jnp.int32, (slot.shape[0], bt), 1)
    return (slot[:, None] - tile * bt == col).astype(jnp.float32)


def _scatter_blocked_body(vs_block_ref, vs_tile_ref, slot_ref, contrib_ref,
                          table_ref, *, multi: bool):
    """One scatter visit of the blocked split schedule: layout block
    ``vs_block[i, j]`` accumulates into HBM table tile ``vs_tile[i, j]``.

    A tile's visits are contiguous with tiles ascending, so the revisited
    output tile stays resident between them and is zeroed exactly once, on
    its first visit — including tiles no point hashes into, which get one
    visit against the all-padding layout block (coeff 0 ⇒ adds nothing).
    ``multi`` selects the multi-RHS blocks: the k columns share each
    one-hot — (k, bn)×(bn, bt) per visit against a (1, k, bt) table block.
    """
    i, j = pl.program_id(0), pl.program_id(1)
    tile = vs_tile_ref[i, j]
    prev_tile = vs_tile_ref[i, jnp.maximum(j - 1, 0)]

    @pl.when((j == 0) | (tile != prev_tile))
    def _zero():
        table_ref[...] = jnp.zeros_like(table_ref)

    onehot = _tile_onehot(slot_ref, tile, table_ref.shape[-1])
    contrib = contrib_ref[...][0] if multi else contrib_ref[...]
    upd = jax.lax.dot_general(contrib, onehot, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    table_ref[...] += upd[None] if multi else upd


def _gather_blocked_body(vg_tile_ref, slot_ref, table_ref, out_ref, *,
                         multi: bool):
    """One gather visit: layout block j reads the ONE tile it addresses.
    Every block is written exactly once, so no accumulation or init pass."""
    i, j = pl.program_id(0), pl.program_id(1)
    tile = vg_tile_ref[i, j]
    onehot = _tile_onehot(slot_ref, tile, table_ref.shape[-1])
    table = table_ref[...][0] if multi else table_ref[...]
    out = jax.lax.dot_general(table, onehot, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    out_ref[...] = out[None] if multi else out


@functools.partial(jax.jit, static_argnames=("num_tiles", "block_n",
                                             "block_t", "interpret"))
def bin_scatter_blocked_pallas(vs_block, vs_tile, slot_lay, contrib_lay, *,
                               num_tiles: int, block_n: int, block_t: int,
                               interpret: bool = True):
    """Visit-list scatter over the slot-blocked layout — the split contract
    (the (m, B) table lands in HBM, psum-able) at the fused kernel's
    O(n/bn + B/bt) grid cost.

    vs_block/vs_tile (m, NB) int32 — the scatter schedule (scalar-prefetched;
    every tile visited at least once, tiles ascending and contiguous).
    slot_lay (m, L) int32 with L a multiple of ``block_n``; ``contrib_lay``
    is (m, L) for one RHS or (m, k, L) for a k-column block laid out along
    the same permutation (padding positions carry contribution 0).  Returns
    tables (m, num_tiles·block_t) f32 — or (m, k, num_tiles·block_t) — with
    tables[s, ..., b] = sum over layout positions p with slot_lay[s, p] == b
    of contrib_lay[s, ..., p].
    """
    m = slot_lay.shape[0]
    n_vis = vs_block.shape[1]
    lay_spec = pl.BlockSpec((1, block_n), lambda i, j, vb, vt: (i, vb[i, j]))
    multi = contrib_lay.ndim == 3
    body = functools.partial(_scatter_blocked_body, multi=multi)
    if not multi:
        contrib_spec = lay_spec
        out_spec = pl.BlockSpec((1, block_t),
                                lambda i, j, vb, vt: (i, vt[i, j]))
        out_shape = (m, num_tiles * block_t)
    else:
        k = contrib_lay.shape[1]
        contrib_spec = pl.BlockSpec((1, k, block_n),
                                    lambda i, j, vb, vt: (i, 0, vb[i, j]))
        out_spec = pl.BlockSpec((1, k, block_t),
                                lambda i, j, vb, vt: (i, 0, vt[i, j]))
        out_shape = (m, k, num_tiles * block_t)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(m, n_vis),
        in_specs=[lay_spec, contrib_spec],
        out_specs=out_spec,
    )
    return pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(out_shape, jnp.float32),
        interpret=interpret,
    )(vs_block, vs_tile, slot_lay, contrib_lay)


@functools.partial(jax.jit, static_argnames=("block_n", "block_t",
                                             "interpret"))
def bin_gather_blocked_pallas(vg_tile, slot_lay, tables, *, block_n: int,
                              block_t: int, interpret: bool = True):
    """Visit-list gather over the slot-blocked layout: layout block j reads
    only the ONE table tile ``vg_tile[i, j]`` it addresses — NB grid steps
    per instance instead of the (L/bn)·(B/bt) cross product.

    tables (m, T·bt) f32 — or (m, k, T·bt) for a k-column RHS block.
    Returns out_lay of shape (m, L) — or (m, k, L) — with
    ``out_lay[s, ..., p] = tables[s, ..., slot_lay[s, p]]``.
    """
    m, layout_len = slot_lay.shape
    n_vis = vg_tile.shape[1]
    if layout_len != n_vis * block_n:
        raise ValueError("layout length must equal visits * block_n")
    lay_spec = pl.BlockSpec((1, block_n), lambda i, j, vt: (i, j))
    multi = tables.ndim == 3
    body = functools.partial(_gather_blocked_body, multi=multi)
    if not multi:
        table_spec = pl.BlockSpec((1, block_t),
                                  lambda i, j, vt: (i, vt[i, j]))
        out_spec = lay_spec
        out_shape = (m, layout_len)
    else:
        k = tables.shape[1]
        table_spec = pl.BlockSpec((1, k, block_t),
                                  lambda i, j, vt: (i, 0, vt[i, j]))
        out_spec = pl.BlockSpec((1, k, block_n),
                                lambda i, j, vt: (i, 0, j))
        out_shape = (m, k, layout_len)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(m, n_vis),
        in_specs=[lay_spec, table_spec],
        out_specs=out_spec,
    )
    return pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(out_shape, jnp.float32),
        interpret=interpret,
    )(vg_tile, slot_lay, tables)


def _route_pack_body(inst_ref, blk_ref, tile_ref, flag_ref, cell_ref,
                     contrib_ref, out_ref, *, multi: bool):
    """One visit of the hash-join route-pack schedule (flat grid).

    The output is the flat all_to_all send buffer — ONE buffer shared by
    every instance, so the schedule is segmented by destination-cell tile
    rather than per instance: visits to a tile are contiguous in grid order,
    each tile's segment opens with a mandatory zero visit (flag 1), real
    visits (flag 0) accumulate one layout block's per-point contributions
    into the tile via the one-hot MXU product (duplicate (instance, slot)
    points hit the same cell row — the bucket segment-sum happens inside the
    dot), and trailing no-ops (flag 2) re-target the last tile so the final
    writebacks are idempotent.  Dropped / padding layout positions carry the
    out-of-range sentinel cell and produce all-zero one-hot rows.
    """
    j = pl.program_id(0)
    flag = flag_ref[j]

    @pl.when(flag == 1)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(flag == 0)
    def _add():
        onehot = _tile_onehot(cell_ref, tile_ref[j], out_ref.shape[-1])
        contrib = contrib_ref[...][0] if multi else contrib_ref[...]
        out_ref[...] += jax.lax.dot_general(
            contrib, onehot, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)


def _route_unpack_body(blk_ref, tile_ref, flag_ref, cell_ref, coeff_ref,
                       back_ref, out_ref, *, multi: bool):
    """One visit of the hash-join route-unpack schedule (per-instance grid).

    Reads the received wire values back through each layout block's cell
    tile: out_lay[..., p] = coeff_lay[p] · back[cell_lay[p]].  The output is
    per-instance, so the schedule is the familiar per-instance visit list —
    a block spanning several cell tiles gets consecutive visits (zeroed on
    the first), every block is visited at least once (empty blocks against
    tile 0: all-sentinel cells gather zero), and per-instance padding visits
    (flag 2) repeat the last block so the writeback is idempotent.
    """
    i, j = pl.program_id(0), pl.program_id(1)
    flag = flag_ref[i, j]
    first = (j == 0) | (blk_ref[i, j] != blk_ref[i, jnp.maximum(j - 1, 0)])

    @pl.when((flag == 0) & first)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(flag == 0)
    def _acc():
        onehot = _tile_onehot(cell_ref, tile_ref[i, j], back_ref.shape[-1])
        vals = jax.lax.dot_general(                  # (1|k, bn)
            back_ref[...], onehot, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        upd = coeff_ref[...] * vals
        out_ref[...] += upd[None] if multi else upd


@functools.partial(jax.jit, static_argnames=("num_cell_tiles", "block_n",
                                             "block_t", "interpret"))
def route_pack_pallas(p_inst, p_block, p_tile, p_flag, cell_lay, contrib_lay,
                      *, num_cell_tiles: int, block_n: int, block_t: int,
                      interpret: bool = True):
    """Hash-join route pack: per-point contributions -> flat send cells.

    p_inst/p_block/p_tile/p_flag (V,) int32 — the flat tile-segmented
    schedule (scalar-prefetched; see ``_route_pack_body``).  cell_lay (m, L)
    int32 destination cells along the slot-blocked layout (sentinel
    ``num_cell_tiles·block_t`` for dropped/padding positions); contrib_lay
    (m, L) f32 — or (m, k, L) for a k-column RHS block.  Returns the send
    buffer (1, num_cell_tiles·block_t) — or (k, ·) — with
    buffer[..., c] = sum over layout positions p with cell_lay[p] == c.
    """
    multi = contrib_lay.ndim == 3
    lay_spec = pl.BlockSpec((1, block_n),
                            lambda j, pi, pb, pt, pf: (pi[j], pb[j]))
    if multi:
        k = contrib_lay.shape[1]
        contrib_spec = pl.BlockSpec(
            (1, k, block_n), lambda j, pi, pb, pt, pf: (pi[j], 0, pb[j]))
        out_rows = k
    else:
        contrib_spec = lay_spec
        out_rows = 1
    out_spec = pl.BlockSpec((out_rows, block_t),
                            lambda j, pi, pb, pt, pf: (0, pt[j]))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(p_inst.shape[0],),
        in_specs=[lay_spec, contrib_spec],
        out_specs=out_spec,
    )
    return pl.pallas_call(
        functools.partial(_route_pack_body, multi=multi),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((out_rows, num_cell_tiles * block_t),
                                       jnp.float32),
        interpret=interpret,
    )(p_inst, p_block, p_tile, p_flag, cell_lay, contrib_lay)


@functools.partial(jax.jit, static_argnames=("block_n", "block_t",
                                             "interpret"))
def route_unpack_pallas(u_block, u_tile, u_flag, cell_lay, coeff_lay, back, *,
                        block_n: int, block_t: int, interpret: bool = True):
    """Hash-join route unpack: received wire values -> coeff-weighted layout.

    u_block/u_tile/u_flag (m, VB) int32 — the per-instance visit schedule;
    cell_lay (m, L) as in ``route_pack_pallas``; coeff_lay (m, L); ``back``
    is the padded receive buffer (1, T·block_t) f32 — or (k, T·block_t) for
    a k-column block.  Returns out_lay (m, L) — or (m, k, L) — with
    out_lay[s, ..., p] = coeff_lay[s, p] · back[..., cell_lay[s, p]]
    (sentinel cells gather 0).
    """
    m = cell_lay.shape[0]
    n_vis = u_block.shape[1]
    multi = back.shape[0] > 1
    lay_spec = pl.BlockSpec((1, block_n),
                            lambda i, j, ub, ut, uf: (i, ub[i, j]))
    back_spec = pl.BlockSpec((back.shape[0], block_t),
                             lambda i, j, ub, ut, uf: (0, ut[i, j]))
    if multi:
        k = back.shape[0]
        out_spec = pl.BlockSpec((1, k, block_n),
                                lambda i, j, ub, ut, uf: (i, 0, ub[i, j]))
        out_shape = (m, k, cell_lay.shape[1])
    else:
        out_spec = lay_spec
        out_shape = (m, cell_lay.shape[1])
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(m, n_vis),
        in_specs=[lay_spec, lay_spec, back_spec],
        out_specs=out_spec,
    )
    return pl.pallas_call(
        functools.partial(_route_unpack_body, multi=multi),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(out_shape, jnp.float32),
        interpret=interpret,
    )(u_block, u_tile, u_flag, cell_lay, coeff_lay, back)


@functools.partial(jax.jit, static_argnames=("interpret", "block_n", "block_t"))
def bin_gather_pallas(slot, tables, *, interpret: bool = True,
                      block_n: int = BLOCK_N, block_t: int = BLOCK_T):
    """slot (m, n) int32; tables (m, B) f32.  Returns out (m, n) f32 with
    out[s, i] = tables[s, slot[s, i]]."""
    m, n = slot.shape
    table_size = tables.shape[1]
    bn, bt = min(block_n, n), min(block_t, table_size)
    if n % bn or table_size % bt:
        raise ValueError("n and table_size must divide their block sizes")
    grid = (m, n // bn, table_size // bt)
    return pl.pallas_call(
        _gather_body,
        grid=grid,
        in_specs=[pl.BlockSpec((1, bn), lambda i, j, t: (i, j)),
                  pl.BlockSpec((1, bt), lambda i, j, t: (i, t))],
        out_specs=pl.BlockSpec((1, bn), lambda i, j, t: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(slot, tables)
