from .kernel import bin_gather_pallas, bin_scatter_pallas
from .ops import bin_loads_op, bin_readout_op, table_matvec_op
from .ref import bin_gather_ref, bin_scatter_ref
