from .kernel import (bin_fused_matvec_pallas, bin_gather_blocked_pallas,
                     bin_gather_pallas, bin_scatter_blocked_pallas,
                     bin_scatter_pallas, route_pack_pallas,
                     route_unpack_pallas)
from .ops import (bin_fused_matvec_op, bin_loads_blocked_op, bin_loads_op,
                  bin_readout_blocked_op, bin_readout_op, table_matvec_op)
from .ref import bin_gather_ref, bin_scatter_ref
