from .ops import table_matvec_op
from .kernel import bin_gather_pallas, bin_scatter_pallas
from .ref import bin_gather_ref, bin_scatter_ref
