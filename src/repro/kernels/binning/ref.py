"""Pure-jnp oracles for the binning kernels (CountSketch table mode of
repro.core.wlsh, restated on raw slot/contrib arrays)."""
from __future__ import annotations

import jax.numpy as jnp


def bin_scatter_ref(slot, contrib, *, table_size: int):
    m = slot.shape[0]
    rows = jnp.arange(m, dtype=jnp.int32)[:, None]
    tables = jnp.zeros((m, table_size), jnp.float32)
    return tables.at[rows, slot].add(contrib.astype(jnp.float32))


def bin_gather_ref(slot, tables):
    rows = jnp.arange(slot.shape[0], dtype=jnp.int32)[:, None]
    return tables[rows, slot]
