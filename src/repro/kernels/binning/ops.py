"""Public ops: CountSketch scatter/readout built on the binning kernels.

These are the kernel-backed equivalents of the reference table primitives in
``repro.core.wlsh``:

* ``bin_loads_op``   ~ ``table_loads``   — scatter signed, weighted beta into
  the (m, B) CountSketch tables.
* ``bin_readout_op`` ~ ``table_readout`` — gather every point's bucket load
  back out and combine over instances.
* ``table_matvec_op`` ~ ``table_matvec`` — the composition of the two (the
  *split* path: the (m, B) table round-trips through HBM between the calls,
  which is what makes it psum-able in the distributed step).
* ``bin_fused_matvec_op`` ~ ``table_matvec_fused`` — one kernel invocation
  driven by the slot-blocked layout (``TableIndex.blocked``): scatter and
  gather share a VMEM-resident table tile, and only O(n/bn + B/bt) visits
  are scheduled per instance instead of the (n/bn)·(B/bt) cross product.

Shapes are padded internally: ``n`` (points) is padded to the block size with
an always-zero contribution in slot 0, and ``table_size`` is padded up to a
multiple of the table tile (padded slots are never addressed, so results are
exact).  Callers never see padding — outputs are trimmed to logical shapes.
``interpret=None`` auto-selects Pallas interpret mode from the platform
(compiled on TPU, interpreted elsewhere).
"""
from __future__ import annotations

import jax.numpy as jnp

from ...backend import default_interpret
from ...core.wlsh import (TableIndex, table_loads, table_matvec_fused,
                          table_readout)
from .kernel import (BLOCK_N, BLOCK_T, bin_fused_matvec_pallas,
                     bin_gather_blocked_pallas, bin_gather_pallas,
                     bin_scatter_blocked_pallas, bin_scatter_pallas)
from .ref import bin_gather_ref, bin_scatter_ref


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def _pad_points(a, bn: int, value=0):
    n = a.shape[1]
    return jnp.pad(a, ((0, 0), (0, _round_up(n, bn) - n)),
                   constant_values=value), n


def _block_sizes(n: int, table_size: int, block_n: int, block_t: int):
    bn = min(block_n, max(128, _round_up(n, 128)))
    bt = min(block_t, table_size)
    return bn, bt


def _split_layout(index: TableIndex):
    """The slot-blocked layout when it carries the split-kernel visit
    schedules (pallas group), else None."""
    lay = getattr(index, "blocked", None)
    return lay if lay is not None and lay.vs_block is not None else None


def _beta_to_layout(lay, beta):
    """Lay beta (n,[ k]) out along the slot permutation: (m, L) or (m, k, L)
    (padding positions read the appended zero row)."""
    pad = jnp.zeros((1,) + beta.shape[1:], jnp.float32)
    beta_lay = jnp.concatenate([jnp.asarray(beta, jnp.float32), pad])[lay.src]
    return jnp.swapaxes(beta_lay, 1, 2) if beta.ndim == 2 else beta_lay


def bin_loads_blocked_op(index: TableIndex, beta, *,
                         interpret: bool | None = None):
    """Visit-list split scatter: same (m, B[, k]) psum-able tables as
    ``bin_loads_op`` at the blocked layout's O(n/bn + B/bt) grid cost.
    Multi-RHS is native — the k columns share every one-hot tile product
    instead of re-running the kernel per column."""
    lay = _split_layout(index)
    if lay is None:
        raise ValueError("blocked split scatter needs a slot-blocked index "
                         "with the pallas group; build it with "
                         "build_blocked_layout(parts='pallas'|'both') / a "
                         "pallas-backend build_index(blocked=True)")
    if interpret is None:
        interpret = default_interpret()
    beta_lay = _beta_to_layout(lay, beta)                    # (m,[ k,] L)
    coeff = lay.coeff_lay if beta.ndim == 1 else lay.coeff_lay[:, None, :]
    tables = bin_scatter_blocked_pallas(
        lay.vs_block, lay.vs_tile, lay.slot_lay, coeff * beta_lay,
        num_tiles=lay.num_tiles, block_n=lay.block_n, block_t=lay.block_t,
        interpret=interpret)[..., :index.table_size]
    return jnp.swapaxes(tables, 1, 2) if beta.ndim == 2 else tables


def bin_readout_blocked_op(index: TableIndex, tables, *, average: bool = True,
                           interpret: bool | None = None):
    """Visit-list split gather of (possibly psum-merged) tables: each layout
    block reads only the ONE tile it addresses; results map back to point
    order through the layout's ``inv_pos``."""
    lay = _split_layout(index)
    if lay is None:
        raise ValueError("blocked split gather needs a slot-blocked index "
                         "with the pallas group; build it with "
                         "build_blocked_layout(parts='pallas'|'both') / a "
                         "pallas-backend build_index(blocked=True)")
    if interpret is None:
        interpret = default_interpret()
    multi = tables.ndim == 3
    bp = lay.num_tiles * lay.block_t
    t = jnp.swapaxes(tables, 1, 2) if multi else tables      # (m,[ k,] B)
    t = jnp.pad(t.astype(jnp.float32),
                ((0, 0),) * (t.ndim - 1) + ((0, bp - index.table_size),))
    out_lay = bin_gather_blocked_pallas(
        lay.vg_tile, lay.slot_lay, t, block_n=lay.block_n,
        block_t=lay.block_t, interpret=interpret)
    rows = jnp.arange(index.slot.shape[0], dtype=jnp.int32)[:, None]
    if multi:
        vals = jnp.swapaxes(out_lay, 1, 2)[rows, lay.inv_pos]  # (m, n, k)
        signed = vals * index.coeff[:, :, None]
    else:
        signed = out_lay[rows, lay.inv_pos] * index.coeff      # (m, n)
    return jnp.mean(signed, axis=0) if average else jnp.sum(signed, axis=0)


def bin_loads_op(index: TableIndex, beta, *, use_kernel: bool = True,
                 interpret: bool | None = None, block_n: int = BLOCK_N,
                 block_t: int = BLOCK_T):
    """Kernel-backed ``table_loads``: (m, B) bucket-load tables for beta (n,),
    or (m, B, k) for a (n, k) RHS block.  An index carrying the slot-blocked
    layout takes the visit-list kernels (``bin_loads_blocked_op`` — multi-RHS
    native) at the LAYOUT'S geometry — ``block_n``/``block_t`` here only
    shape the cross-product fallback (geometry A/B runs rebuild the layout
    via ``build_blocked_layout``); otherwise the cross-product scatter runs
    per column — either way the split path stays psum-able."""
    if use_kernel and _split_layout(index) is not None:
        return bin_loads_blocked_op(index, beta, interpret=interpret)
    if beta.ndim == 2:
        cols = [bin_loads_op(index, beta[:, j], use_kernel=use_kernel,
                             interpret=interpret, block_n=block_n,
                             block_t=block_t)
                for j in range(beta.shape[1])]
        return jnp.stack(cols, axis=-1)
    contrib = (beta[None, :] * index.coeff).astype(jnp.float32)
    if not use_kernel:
        return bin_scatter_ref(index.slot, contrib, table_size=index.table_size)
    if interpret is None:
        interpret = default_interpret()
    bn, bt = _block_sizes(index.slot.shape[1], index.table_size, block_n,
                          block_t)
    # pad points into slot 0 with zero contribution (cannot perturb loads)
    slot_p, _ = _pad_points(index.slot, bn, value=0)
    contrib_p, _ = _pad_points(contrib, bn, value=0.0)
    bp = _round_up(index.table_size, bt)
    tables = bin_scatter_pallas(slot_p, contrib_p, table_size=bp,
                                interpret=interpret, block_n=bn, block_t=bt)
    return tables[:, :index.table_size]


def bin_readout_op(index: TableIndex, tables, *, average: bool = True,
                   use_kernel: bool = True, interpret: bool | None = None,
                   block_n: int = BLOCK_N, block_t: int = BLOCK_T):
    """Kernel-backed ``table_readout``: per-point loads combined over the m
    instances (mean when ``average``, else sum — the distributed path sums
    locally and divides by the global m after its psum).  ``tables`` is
    (m, B) -> (n,) out, or (m, B, k) -> (n, k).  An index carrying the
    slot-blocked layout takes the visit-list gather
    (``bin_readout_blocked_op``) at the layout's own geometry
    (``block_n``/``block_t`` here shape only the cross-product fallback);
    otherwise the cross-product kernel runs per column."""
    if use_kernel and _split_layout(index) is not None:
        return bin_readout_blocked_op(index, tables, average=average,
                                      interpret=interpret)
    if tables.ndim == 3:
        cols = [bin_readout_op(index, tables[..., j], average=average,
                               use_kernel=use_kernel, interpret=interpret,
                               block_n=block_n, block_t=block_t)
                for j in range(tables.shape[-1])]
        return jnp.stack(cols, axis=-1)
    if not use_kernel:
        vals = bin_gather_ref(index.slot, tables)
    else:
        if interpret is None:
            interpret = default_interpret()
        n = index.slot.shape[1]
        bn, bt = _block_sizes(n, index.table_size, block_n, block_t)
        slot_p, _ = _pad_points(index.slot, bn, value=0)
        bp = _round_up(index.table_size, bt)
        tables_p = jnp.pad(tables.astype(jnp.float32),
                           ((0, 0), (0, bp - index.table_size)))
        vals = bin_gather_pallas(slot_p, tables_p, interpret=interpret,
                                 block_n=bn, block_t=bt)[:, :n]
    signed = vals * index.coeff
    return jnp.mean(signed, axis=0) if average else jnp.sum(signed, axis=0)


def table_matvec_op(index: TableIndex, beta, *, use_kernel: bool = True,
                    interpret: bool | None = None):
    """Scatter then gather: the kernel-backed split WLSH table matvec."""
    tables = bin_loads_op(index, beta, use_kernel=use_kernel,
                          interpret=interpret)
    return bin_readout_op(index, tables, use_kernel=use_kernel,
                          interpret=interpret)


def bin_fused_matvec_op(index: TableIndex, beta, *, average: bool = True,
                        use_kernel: bool = True,
                        interpret: bool | None = None):
    """Fused one-pass WLSH table matvec off the slot-blocked layout.

    Requires ``index.blocked`` (see ``core.wlsh.build_blocked_layout``).  The
    per-iteration jnp work is one gather (``beta`` into the sorted layout)
    and one gather back (``inv_pos``) — everything between runs inside a
    single Pallas kernel whose table tile never leaves VMEM.

    ``beta`` is (n,) or (n, k): a RHS block is laid out as (m, k, L) along
    the same slot permutation and the k columns share every one-hot tile
    product inside the kernel (see ``bin_fused_matvec_pallas``).
    """
    lay = index.blocked
    if lay is None or lay.src is None:
        raise ValueError("fused matvec needs a slot-blocked index with the "
                         "pallas group; build it with build_blocked_layout"
                         "(parts='pallas'|'both') / a pallas-backend "
                         "build_index(blocked=True)")
    if not use_kernel:
        # pallas-built indexes don't carry the reference segment group;
        # degrade to the split composition rather than refuse
        if lay.perm is not None:
            return table_matvec_fused(index, beta, average=average)
        return table_readout(index, table_loads(index, beta), average=average)
    if interpret is None:
        interpret = default_interpret()
    m = index.slot.shape[0]
    multi = beta.ndim == 2
    pad = jnp.zeros((1,) + beta.shape[1:], jnp.float32)
    beta_pad = jnp.concatenate([jnp.asarray(beta, jnp.float32), pad])
    beta_lay = beta_pad[lay.src]               # (m, L) | (m, L, k)
    if multi:
        beta_lay = jnp.swapaxes(beta_lay, 1, 2)              # (m, k, L)
    out_lay = bin_fused_matvec_pallas(
        lay.v_block, lay.v_tile, lay.v_phase, lay.slot_lay, lay.coeff_lay,
        beta_lay, block_n=lay.block_n, block_t=lay.block_t,
        interpret=interpret)
    rows = jnp.arange(m, dtype=jnp.int32)[:, None]
    if multi:
        # (m, k, L) -> (m, n, k), coeff already applied inside the kernel
        vals = jnp.swapaxes(out_lay, 1, 2)[rows, lay.inv_pos]
    else:
        vals = out_lay[rows, lay.inv_pos]      # (m, n)
    return jnp.mean(vals, axis=0) if average else jnp.sum(vals, axis=0)
