"""Public ops: CountSketch scatter/readout built on the binning kernels.

These are the kernel-backed equivalents of the reference table primitives in
``repro.core.wlsh``:

* ``bin_loads_op``   ~ ``table_loads``   — scatter signed, weighted beta into
  the (m, B) CountSketch tables.
* ``bin_readout_op`` ~ ``table_readout`` — gather every point's bucket load
  back out and combine over instances.
* ``table_matvec_op`` ~ ``table_matvec`` — the composition of the two.

Shapes are padded internally: ``n`` (points) is padded to the block size with
an always-zero contribution in slot 0, and ``table_size`` is padded up to a
multiple of the table tile (padded slots are never addressed, so results are
exact).  Callers never see padding — outputs are trimmed to logical shapes.
``interpret=None`` auto-selects Pallas interpret mode from the platform
(compiled on TPU, interpreted elsewhere).
"""
from __future__ import annotations

import jax.numpy as jnp

from ...backend import default_interpret
from ...core.wlsh import TableIndex
from .kernel import BLOCK_N, BLOCK_T, bin_gather_pallas, bin_scatter_pallas
from .ref import bin_gather_ref, bin_scatter_ref


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def _pad_points(a, bn: int, value=0):
    n = a.shape[1]
    return jnp.pad(a, ((0, 0), (0, _round_up(n, bn) - n)),
                   constant_values=value), n


def _block_sizes(n: int, table_size: int, block_n: int, block_t: int):
    bn = min(block_n, max(128, _round_up(n, 128)))
    bt = min(block_t, table_size)
    return bn, bt


def bin_loads_op(index: TableIndex, beta, *, use_kernel: bool = True,
                 interpret: bool | None = None, block_n: int = BLOCK_N,
                 block_t: int = BLOCK_T):
    """Kernel-backed ``table_loads``: (m, B) bucket-load tables for beta."""
    contrib = (beta[None, :] * index.weight * index.sign).astype(jnp.float32)
    if not use_kernel:
        return bin_scatter_ref(index.slot, contrib, table_size=index.table_size)
    if interpret is None:
        interpret = default_interpret()
    bn, bt = _block_sizes(index.slot.shape[1], index.table_size, block_n,
                          block_t)
    # pad points into slot 0 with zero contribution (cannot perturb loads)
    slot_p, _ = _pad_points(index.slot, bn, value=0)
    contrib_p, _ = _pad_points(contrib, bn, value=0.0)
    bp = _round_up(index.table_size, bt)
    tables = bin_scatter_pallas(slot_p, contrib_p, table_size=bp,
                                interpret=interpret, block_n=bn, block_t=bt)
    return tables[:, :index.table_size]


def bin_readout_op(index: TableIndex, tables, *, average: bool = True,
                   use_kernel: bool = True, interpret: bool | None = None,
                   block_n: int = BLOCK_N, block_t: int = BLOCK_T):
    """Kernel-backed ``table_readout``: per-point loads combined over the m
    instances (mean when ``average``, else sum — the distributed path sums
    locally and divides by the global m after its psum)."""
    if not use_kernel:
        vals = bin_gather_ref(index.slot, tables)
    else:
        if interpret is None:
            interpret = default_interpret()
        n = index.slot.shape[1]
        bn, bt = _block_sizes(n, index.table_size, block_n, block_t)
        slot_p, _ = _pad_points(index.slot, bn, value=0)
        bp = _round_up(index.table_size, bt)
        tables_p = jnp.pad(tables.astype(jnp.float32),
                           ((0, 0), (0, bp - index.table_size)))
        vals = bin_gather_pallas(slot_p, tables_p, interpret=interpret,
                                 block_n=bn, block_t=bt)[:, :n]
    signed = vals * index.sign * index.weight
    return jnp.mean(signed, axis=0) if average else jnp.sum(signed, axis=0)


def table_matvec_op(index: TableIndex, beta, *, use_kernel: bool = True,
                    interpret: bool | None = None):
    """Scatter then gather: the kernel-backed WLSH table matvec."""
    tables = bin_loads_op(index, beta, use_kernel=use_kernel,
                          interpret=interpret)
    return bin_readout_op(index, tables, use_kernel=use_kernel,
                          interpret=interpret)
