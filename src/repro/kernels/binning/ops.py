"""Public ops: WLSH table matvec built on the binning kernels.

``table_matvec_op`` is the kernel-backed equivalent of
repro.core.wlsh.table_matvec: scatter the signed, weighted beta into the
CountSketch tables, then gather every point's bucket load back out.
"""
from __future__ import annotations

import jax.numpy as jnp

from ...core.wlsh import TableIndex
from .kernel import bin_gather_pallas, bin_scatter_pallas
from .ref import bin_gather_ref, bin_scatter_ref


def _pad_points(a, bn: int, value=0):
    n = a.shape[1]
    np_ = -(-n // bn) * bn
    return jnp.pad(a, ((0, 0), (0, np_ - n)), constant_values=value), n


def table_matvec_op(index: TableIndex, beta, *, use_kernel: bool = True,
                    interpret: bool = True):
    contrib = (beta[None, :] * index.weight * index.sign).astype(jnp.float32)
    if not use_kernel:
        tables = bin_scatter_ref(index.slot, contrib, table_size=index.table_size)
        vals = bin_gather_ref(index.slot, tables)
        return jnp.mean(vals * index.sign * index.weight, axis=0)
    bn = min(1024, max(128, index.slot.shape[1]))
    # pad points into an always-zero overflow slot so they cannot perturb loads
    slot_p, n = _pad_points(index.slot, bn, value=0)
    contrib_p, _ = _pad_points(contrib, bn, value=0.0)
    tables = bin_scatter_pallas(slot_p, contrib_p, table_size=index.table_size,
                                interpret=interpret, block_n=bn)
    vals = bin_gather_pallas(slot_p, tables, interpret=interpret, block_n=bn)
    return jnp.mean(vals[:, :n] * index.sign * index.weight, axis=0)
