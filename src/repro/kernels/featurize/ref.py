"""Pure-jnp oracle for the fused featurize kernel — delegates to the reference
implementation in repro.core.lsh (the paper's Def. 6 verbatim)."""
from __future__ import annotations

from ...core.bucket_fns import BucketFn
from ...core.lsh import LSHParams, featurize


def featurize_ref(x, w, z, r1, r2, *, f: BucketFn):
    feats = featurize(LSHParams(w=w, z=z, r1=r1, r2=r2), f, x)
    return feats.key1, feats.key2, feats.weight, feats.sign
