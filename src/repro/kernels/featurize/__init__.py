from .ops import featurize_op
from .kernel import featurize_pallas
from .ref import featurize_ref
