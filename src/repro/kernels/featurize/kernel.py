"""Pallas TPU kernel: fused WLSH featurization (hash + weight + sign).

The naive jnp path (repro.core.lsh.featurize) materializes six (m, n, d)
intermediates in HBM; at production scale (n = 4M, m = 64, d = 64) that is
~100 GB of traffic for a computation whose true output is 4 * (m, n) vectors.
This kernel fuses the whole per-(instance, point-block) pipeline in VMEM:

    t = (x - z) / w;  h = round(t);  u = h - t
    weight = prod_d f(u_d)          (closed-form piecewise polynomial f)
    key1/key2 = fmix32(sum_d uint32(h_d) * r_d)   (universal hashes)
    sign = 1 - 2*(key2 >> 31)

Grid: (m, n / BLOCK_N); one (BLOCK_N, d_pad) tile of points and one (1, d_pad)
row of instance parameters live in VMEM per step.  Feature dims beyond the
real d are masked (weight contribution 1, hash contribution 0), so d can be
padded to the 128-lane boundary without changing results.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...core.bucket_fns import BucketFn

BLOCK_N = 1024


def _fmix32(x):
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EB_CA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2_AE35)
    x = x ^ (x >> 16)
    return x


def _featurize_body(x_ref, w_ref, z_ref, r1_ref, r2_ref,
                    key1_ref, key2_ref, wt_ref, sign_ref, *, f: BucketFn,
                    d_real: int):
    x = x_ref[...]                               # (bn, dp) f32
    w = w_ref[...]                               # (1, dp)
    z = z_ref[...]
    t = (x - z) / w
    h = jnp.round(t)
    u = h - t                                    # residual in [-1/2, 1/2]

    col = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    valid = col < d_real
    fu = jnp.where(valid, f(u), 1.0)
    weight = jnp.prod(fu, axis=1)                # (bn,)

    hi = jnp.where(valid, h, 0.0).astype(jnp.int32).astype(jnp.uint32)
    k1 = _fmix32(jnp.sum(hi * r1_ref[...], axis=1, dtype=jnp.uint32))
    k2 = _fmix32(jnp.sum(hi * r2_ref[...], axis=1, dtype=jnp.uint32))

    key1_ref[...] = k1[None, :]
    key2_ref[...] = k2[None, :]
    wt_ref[...] = weight.astype(jnp.float32)[None, :]
    sign_ref[...] = (1.0 - 2.0 * (k2 >> 31).astype(jnp.float32))[None, :]


@functools.partial(jax.jit, static_argnames=("f", "interpret", "block_n"))
def featurize_pallas(x, w, z, r1, r2, *, f: BucketFn, interpret: bool = True,
                     block_n: int = BLOCK_N):
    """x (n, d) f32; w, z (m, d) f32; r1, r2 (m, d) uint32.
    Returns (key1, key2, weight, sign), each (m, n)."""
    n, d = x.shape
    m = w.shape[0]
    dp = max(128, -(-d // 128) * 128)
    bn = min(block_n, n)
    if n % bn:
        raise ValueError(f"n={n} must be a multiple of block_n={bn}")

    xp = jnp.pad(x.astype(jnp.float32), ((0, 0), (0, dp - d)))
    wp = jnp.pad(w.astype(jnp.float32), ((0, 0), (0, dp - d)),
                 constant_values=1.0)
    zp = jnp.pad(z.astype(jnp.float32), ((0, 0), (0, dp - d)))
    r1p = jnp.pad(r1, ((0, 0), (0, dp - d)))
    r2p = jnp.pad(r2, ((0, 0), (0, dp - d)))

    grid = (m, n // bn)
    point_spec = pl.BlockSpec((bn, dp), lambda i, j: (j, 0))
    inst_spec = pl.BlockSpec((1, dp), lambda i, j: (i, 0))
    out_spec = pl.BlockSpec((1, bn), lambda i, j: (i, j))

    out_shapes = (
        jax.ShapeDtypeStruct((m, n), jnp.uint32),
        jax.ShapeDtypeStruct((m, n), jnp.uint32),
        jax.ShapeDtypeStruct((m, n), jnp.float32),
        jax.ShapeDtypeStruct((m, n), jnp.float32),
    )
    return pl.pallas_call(
        functools.partial(_featurize_body, f=f, d_real=d),
        grid=grid,
        in_specs=[point_spec, inst_spec, inst_spec, inst_spec, inst_spec],
        out_specs=[out_spec, out_spec, out_spec, out_spec],
        out_shape=out_shapes,
        interpret=interpret,
    )(xp, wp, zp, r1p, r2p)
