"""Public op: WLSH featurization with automatic padding + kernel/ref dispatch."""
from __future__ import annotations

import jax.numpy as jnp

from ...backend import default_interpret
from ...core.bucket_fns import BucketFn
from ...core.lsh import Features, LSHParams
from .kernel import BLOCK_N, featurize_pallas
from .ref import featurize_ref


def featurize_op(params: LSHParams, f: BucketFn, x, *, use_kernel: bool = True,
                 interpret: bool | None = None) -> Features:
    """Drop-in replacement for repro.core.lsh.featurize backed by the Pallas
    kernel.  Points are padded to the kernel block size and trimmed after;
    ``interpret=None`` auto-selects from the platform (compiled on TPU)."""
    if not use_kernel:
        k1, k2, wt, sg = featurize_ref(x, params.w, params.z, params.r1,
                                       params.r2, f=f)
        return Features(key1=k1, key2=k2, weight=wt, sign=sg)
    if interpret is None:
        interpret = default_interpret()
    n = x.shape[0]
    bn = min(BLOCK_N, max(128, -(-n // 128) * 128))
    np_ = -(-n // bn) * bn
    xp = jnp.pad(jnp.asarray(x, jnp.float32), ((0, np_ - n), (0, 0)))
    k1, k2, wt, sg = featurize_pallas(xp, params.w, params.z, params.r1,
                                      params.r2, f=f, interpret=interpret,
                                      block_n=bn)
    return Features(key1=k1[:, :n], key2=k2[:, :n], weight=wt[:, :n],
                    sign=sg[:, :n])
