from .store import (CheckpointManager, latest_step, restore_checkpoint,
                    restore_resharded, save_checkpoint)
