"""Checkpointing: atomic on-disk snapshots with async writes, latest-complete
discovery, and elastic (mesh-changing) restore.

Layout:  <dir>/step_<n>/arrays.npz + meta.json, written to step_<n>.tmp and
atomically renamed — a crash mid-write can never produce a half checkpoint
that restore() would pick up.  Arrays are stored UNSHARDED (gathered to host),
so a checkpoint saved on mesh A restores onto any mesh B by resharding at
load ("elastic restore"): pass target shardings to ``restore_resharded``.

Async mode snapshots to host memory on the training thread (cheap device->host
copy) and runs the file write on a worker thread, keeping serialization off
the step critical path.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np

from .. import obs

_STEP_RE = re.compile(r"^step_(\d+)$")

# Test-injection point (repro.testing.faults.killed_checkpoint_writer): when
# set, called with the tmp path after arrays.npz is written but before the
# atomic rename — raising here simulates a writer killed mid-save.  The tmp
# dir is left behind exactly as a SIGKILL would leave it: full payload,
# invisible to latest_step, swept later by CheckpointManager._gc.
_crash_mid_save = None


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(path): np.asarray(leaf)
            for path, leaf in flat}


def _unflatten(template: Any, arrays: dict[str, np.ndarray]) -> Any:
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, tmpl in flat:
        key = jax.tree_util.keystr(path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing array for {key}")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != "
                             f"expected {tmpl.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, [l for _, l in
                                                  zip(flat, leaves)])


def save_checkpoint(directory: str, step: int, state: Any,
                    meta: dict | None = None) -> str:
    """Blocking atomic save.  Returns the final checkpoint path."""
    with obs.span("io.checkpoint_save", {"step": step},
                  to_histogram=obs.histogram(
                      "io_checkpoint_save_us",
                      "blocking checkpoint save wall time")):
        os.makedirs(directory, exist_ok=True)
        final = os.path.join(directory, f"step_{step}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten(state)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        if _crash_mid_save is not None:
            _crash_mid_save(tmp)
        with open(os.path.join(tmp, "meta.json"), "w") as fh:
            json.dump({"step": step, **(meta or {})}, fh)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        obs.counter("io_checkpoint_saves_total",
                    "checkpoints written to disk").inc()
        obs.counter("io_checkpoint_bytes_total",
                    "uncompressed array bytes written to checkpoints"
                    ).inc(sum(v.nbytes for v in flat.values()))
        return final


def atomic_write_json(path: str, obj: dict) -> None:
    """Write ``obj`` to ``path`` via temp file + ``os.replace``: readers see
    either the previous complete document or the new one, never a torn
    write.  Used for the sharded-artifact manifest (serve/artifact.py),
    which must flip a whole piece GRID from one export generation to the
    next in one rename."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as fh:
        json.dump(obj, fh)
    os.replace(tmp, path)


def latest_step(directory: str) -> int | None:
    """Largest step with a COMPLETE checkpoint (tmp dirs are ignored)."""
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        m = _STEP_RE.match(name)
        if m and os.path.exists(os.path.join(directory, name, "meta.json")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore_checkpoint(directory: str, template: Any, step: int | None = None):
    """Returns (state, step, meta); state leaves are numpy (device_put by the
    caller with whatever shardings the current mesh wants)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint under {directory}")
    with obs.span("io.checkpoint_restore", {"step": step},
                  to_histogram=obs.histogram(
                      "io_checkpoint_restore_us",
                      "checkpoint restore wall time")):
        path = os.path.join(directory, f"step_{step}")
        with np.load(os.path.join(path, "arrays.npz")) as npz:
            arrays = {k: npz[k] for k in npz.files}
        with open(os.path.join(path, "meta.json")) as fh:
            meta = json.load(fh)
        obs.counter("io_checkpoint_restores_total",
                    "checkpoints restored from disk").inc()
        return _unflatten(template, arrays), step, meta


def restore_resharded(directory: str, template: Any, shardings: Any,
                      step: int | None = None):
    """Elastic restore: place every leaf with the TARGET mesh's sharding —
    the checkpoint may have been written from a different mesh entirely."""
    state, step, meta = restore_checkpoint(directory, template, step)
    state = jax.tree.map(jax.device_put, state, shardings)
    return state, step, meta


class CheckpointManager:
    """Async checkpointing with bounded retention.

    save() snapshots device arrays to host and hands the file write to a
    worker thread; wait()/flush() joins the in-flight write (call before exit
    and in tests) and re-raises any exception the background write hit — an
    async save failure must not be silently swallowed by a daemon thread.
    Keeps the newest ``keep`` checkpoints and sweeps crash-window ``.tmp``
    dirs left behind by a killed writer (they are invisible to
    ``latest_step`` either way, but they pin disk).
    """

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def save(self, step: int, state: Any, meta: dict | None = None,
             blocking: bool = False) -> None:
        host_state = jax.tree.map(np.asarray, state)   # device -> host now
        self.wait()

        def _write():
            try:
                save_checkpoint(self.directory, step, host_state, meta)
                self._gc()
            except BaseException as e:      # surfaced by the next wait()
                self._error = e

        if blocking:
            _write()
            self.wait()                     # raise immediately when blocking
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        """Join the in-flight write; re-raise its exception, if any."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # flush == wait: both names exist because callers that treat the manager
    # as a sink (serving exporters, shutdown hooks) look for flush()
    flush = wait

    # a .tmp dir this old cannot be an in-flight write (writes take seconds);
    # younger ones are left alone in case ANOTHER writer shares the directory
    # (this manager's own saves are serialized through wait(), but
    # save_checkpoint is also called directly, e.g. by serve/artifact.py)
    STALE_TMP_SECONDS = 600.0

    def _gc(self) -> None:
        if not os.path.isdir(self.directory):
            return
        now = time.time()
        for name in os.listdir(self.directory):
            if not (name.endswith(".tmp") and _STEP_RE.match(name[:-4])):
                continue
            path = os.path.join(self.directory, name)
            try:
                age = now - os.path.getmtime(path)
            except OSError:
                continue          # raced with its writer's rename/cleanup
            if age > self.STALE_TMP_SECONDS:
                shutil.rmtree(path, ignore_errors=True)
        steps = sorted(s for s in (
            int(m.group(1)) for m in (_STEP_RE.match(n) for n in
                                      os.listdir(self.directory)) if m))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)
