"""Analytic kernel functions.

Includes the classical shift-invariant kernels used in the paper's experiments
(Laplace, squared exponential, Matérn-5/2) and the *analytic* WLSH kernel
family of Def. 8:

    k_{f,p}(x) = prod_l  E_{w ~ p} [ (f*f)(x_l / w) ]

which we tabulate once (numpy quadrature over w against the tabulated
autocorrelation f*f) and evaluate with jnp.interp.  With f = rect and
p = Gamma(2,1) this reduces exactly to the Laplace kernel e^{-|x|_1}, which we
use as a correctness anchor for the quadrature pipeline (tests).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp
import numpy as np

from .bucket_fns import BucketFn
from .lsh import GammaPDF

Array = jnp.ndarray


def _pairwise_dists(x: Array, y: Array, ord_: int) -> Array:
    diff = x[:, None, :] - y[None, :, :]
    if ord_ == 1:
        return jnp.sum(jnp.abs(diff), axis=-1)
    return jnp.sqrt(jnp.maximum(jnp.sum(diff * diff, axis=-1), 0.0))


def laplace_kernel(x: Array, y: Array, lengthscale: float = 1.0) -> Array:
    """k(x,y) = exp(-||x-y||_1 / ell)."""
    return jnp.exp(-_pairwise_dists(x, y, 1) / lengthscale)


def gaussian_kernel(x: Array, y: Array, lengthscale: float = 1.0) -> Array:
    """Squared exponential, paper's convention: exp(-||x-y||_2^2 / ell^2)."""
    d = _pairwise_dists(x, y, 2)
    return jnp.exp(-(d / lengthscale) ** 2)


def matern52_kernel(x: Array, y: Array, lengthscale: float = 1.0) -> Array:
    """C_{5/2}(r) = (1 + r + r^2/3) exp(-r), r = ||x-y||_2 / ell."""
    r = _pairwise_dists(x, y, 2) / lengthscale
    return (1.0 + r + r * r / 3.0) * jnp.exp(-r)


# ---------------------------------------------------------------------------
# Analytic WLSH kernel (Def. 8)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class WLSHKernelSpec:
    """The (f, p) pair that defines a WLSH kernel k_{f,p} and its estimator."""

    bucket: BucketFn
    pdf: GammaPDF = GammaPDF(2.0, 1.0)
    lengthscale: float = 1.0


def _gamma_pdf_np(w: np.ndarray, pdf: GammaPDF) -> np.ndarray:
    from math import gamma as _g
    sh, sc = pdf.shape, pdf.scale
    w = np.maximum(w, 1e-300)
    return w ** (sh - 1.0) * np.exp(-w / sc) / (_g(sh) * sc ** sh)


def tabulate_wlsh_k1d(spec: WLSHKernelSpec, x_max: float = 40.0,
                      n_x: int = 4096, n_w: int = 20000) -> tuple[np.ndarray, np.ndarray]:
    """k1d(x) = int_0^inf p(w) (f*f)(x/w) dw on a grid of |x| values.

    (f*f) has support [-1,1], so the integrand vanishes for w < |x| — we start
    the w-grid at |x| (vectorized via masking on a shared log-spaced grid).
    """
    xs = np.linspace(0.0, x_max, n_x)
    # Shared w grid covering (0, W]; Gamma(shape<=9) mass above 60 is ~1e-20.
    w_hi = spec.pdf.scale * (spec.pdf.shape + 40.0 * np.sqrt(spec.pdf.shape) + 40.0)
    w = np.concatenate([np.linspace(1e-6, 1.0, n_w // 2, endpoint=False),
                        np.geomspace(1.0, w_hi, n_w // 2)])
    pw = _gamma_pdf_np(w, spec.pdf)
    # integrand[i, j] = p(w_j) * (f*f)(x_i / w_j); mask w < x.
    ratio = xs[:, None] / np.maximum(w[None, :], 1e-30)
    vals = spec.bucket.acorr(ratio) * pw[None, :]
    vals[ratio > 1.0] = 0.0
    k = np.trapezoid(vals, w, axis=1)
    # normalize so k(0) == 1 exactly (||f||_2 = 1 guarantees k(0)=1 in theory;
    # quadrature error is ~1e-5, we pin it).
    return xs, k / max(k[0], 1e-30)


@dataclasses.dataclass(frozen=True)
class WLSHKernel:
    """Evaluatable analytic WLSH kernel (product over dimensions)."""

    spec: WLSHKernelSpec
    table_x: np.ndarray
    table_y: np.ndarray

    def k1d(self, t: Array) -> Array:
        tx = jnp.asarray(self.table_x)
        ty = jnp.asarray(self.table_y)
        return jnp.interp(jnp.abs(t) / self.spec.lengthscale, tx, ty, left=1.0, right=0.0)

    def __call__(self, x: Array, y: Array) -> Array:
        diff = x[:, None, :] - y[None, :, :]
        return jnp.prod(self.k1d(diff), axis=-1)


def make_wlsh_kernel(spec: WLSHKernelSpec) -> WLSHKernel:
    xs, ys = tabulate_wlsh_k1d(spec)
    return WLSHKernel(spec=spec, table_x=xs, table_y=ys)


KERNELS: dict[str, Callable[..., Array]] = {
    "laplace": laplace_kernel,
    "gaussian": gaussian_kernel,
    "matern52": matern52_kernel,
}
