"""Gaussian-process sampling utilities for the paper's Table-1 experiment."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jnp.ndarray


def sample_gp(key: jax.Array, x: Array, kernel_fn, jitter: float = 1e-6) -> Array:
    """One sample path of GP(0, k) evaluated at the rows of x.

    Uses an eigendecomposition with clamped eigenvalues rather than Cholesky:
    smooth kernels (squared exponential) are numerically rank-deficient on
    dense point sets and Cholesky NaNs out."""
    k = kernel_fn(x, x).astype(jnp.float64 if jax.config.jax_enable_x64
                               else jnp.float32)
    evals, evecs = jnp.linalg.eigh(k)
    root = evecs * jnp.sqrt(jnp.maximum(evals, jitter))[None, :]
    return (root @ jax.random.normal(key, (x.shape[0],), k.dtype)).astype(
        jnp.float32)


def gp_regression_dataset(key: jax.Array, kernel_fn, *, n: int, d: int,
                          noise: float = 0.05):
    """Points uniform on [0,1]^d, labels = GP sample + N(0, noise^2)."""
    kx, kf, kn = jax.random.split(key, 3)
    x = jax.random.uniform(kx, (n, d))
    f = sample_gp(kf, x, kernel_fn)
    y = f + noise * jax.random.normal(kn, (n,))
    return x, y, f
