"""Gaussian-process sampling utilities for the paper's Table-1 experiment,
plus batched posterior sampling through the multi-RHS KRR solver.

Posterior samples use pathwise conditioning (Matheron's rule):

    f_post = f_prior + K(·, X) (K + σ²I)⁻¹ (y − f_prior(X) − ε)

so drawing S samples plus the posterior mean needs S+1 solves against the
SAME operator — exactly the shape the multi-RHS block-CG solve amortizes
(``wlsh_krr_fit`` with an (n, S+1) target block; one index build, one
matvec per iteration for all columns)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jnp.ndarray


def sample_gp(key: jax.Array, x: Array, kernel_fn, jitter: float = 1e-6) -> Array:
    """One sample path of GP(0, k) evaluated at the rows of x.

    Uses an eigendecomposition with clamped eigenvalues rather than Cholesky:
    smooth kernels (squared exponential) are numerically rank-deficient on
    dense point sets and Cholesky NaNs out."""
    k = kernel_fn(x, x).astype(jnp.float64 if jax.config.jax_enable_x64
                               else jnp.float32)
    evals, evecs = jnp.linalg.eigh(k)
    root = evecs * jnp.sqrt(jnp.maximum(evals, jitter))[None, :]
    return (root @ jax.random.normal(key, (x.shape[0],), k.dtype)).astype(
        jnp.float32)


def gp_regression_dataset(key: jax.Array, kernel_fn, *, n: int, d: int,
                          noise: float = 0.05):
    """Points uniform on [0,1]^d, labels = GP sample + N(0, noise^2)."""
    kx, kf, kn = jax.random.split(key, 3)
    x = jax.random.uniform(kx, (n, d))
    f = sample_gp(kf, x, kernel_fn)
    y = f + noise * jax.random.normal(kn, (n,))
    return x, y, f


def sample_gp_batch(key: jax.Array, x: Array, kernel_fn, n_samples: int,
                    jitter: float = 1e-6) -> Array:
    """(n, n_samples) independent GP(0, k) sample paths at the rows of x —
    one eigendecomposition shared by all draws."""
    k = kernel_fn(x, x).astype(jnp.float64 if jax.config.jax_enable_x64
                               else jnp.float32)
    evals, evecs = jnp.linalg.eigh(k)
    root = evecs * jnp.sqrt(jnp.maximum(evals, jitter))[None, :]
    eps = jax.random.normal(key, (x.shape[0], n_samples), k.dtype)
    return (root @ eps).astype(jnp.float32)


def gp_posterior_rhs(key: jax.Array, x_all: Array, y: Array, kernel_fn, *,
                     n_train: int, n_samples: int,
                     noise: float) -> tuple[Array, Array]:
    """Build the (n_train, 1 + n_samples) RHS block for pathwise posterior
    sampling.  Column 0 is y (its solve gives the posterior mean); column j
    is ``y - f_j(X) - eps_j`` for a joint train+test prior draw f_j and
    observation noise eps_j ~ N(0, noise²).  Returns (rhs, f_prior_all)
    where ``f_prior_all`` is (n_all, n_samples) — the posterior sample at
    any of the jointly-sampled points is ``f_j + K(·, X) v_j`` with v_j the
    solve of column j (e.g. via wlsh_krr_predict on a model fit with this
    block)."""
    kf, kn = jax.random.split(key)
    f_all = sample_gp_batch(kf, x_all, kernel_fn, n_samples)   # (n_all, S)
    eps = noise * jax.random.normal(kn, (n_train, n_samples))
    rhs = jnp.concatenate([y[:, None],
                           y[:, None] - f_all[:n_train] - eps], axis=1)
    return rhs, f_all
