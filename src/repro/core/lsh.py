"""The LSH family H (paper Def. 5) and WLSH featurization (Def. 6).

An LSH function h_{w,z}(x)_l = round((x_l - z_l) / w_l) with w_l ~ p(·) iid and
z ~ Unif[0, w].  We draw ``m`` independent instances at once.

TPU adaptation (see DESIGN.md §3): bucket identity in Z^d is reduced to two
independent 32-bit universal hashes (exact mode — pair-collision probability
~ n^2 / 2^64) plus a CountSketch (slot, sign) pair for the distributed dense
table mode.  All arithmetic is uint32 with wraparound (well-defined in XLA).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .bucket_fns import BucketFn

Array = jnp.ndarray


class GammaPDF(NamedTuple):
    """p(w) = w^{shape-1} e^{-w/scale} / (Gamma(shape) scale^shape).

    Paper's Laplace-kernel choice: shape=2, scale=1 (p(w) = w e^{-w}).
    Paper's Table-1 smooth choice: shape=7, scale=1 (p(w) = w^6 e^{-w} / 6!).
    """

    shape: float = 2.0
    scale: float = 1.0


class LSHParams(NamedTuple):
    """Parameters of m independent LSH instances over R^d."""

    w: Array          # (m, d) bucket widths, w ~ Gamma(shape, scale)
    z: Array          # (m, d) offsets, z ~ Unif[0, w]
    r1: Array         # (m, d) uint32 universal-hash coefficients (key 1)
    r2: Array         # (m, d) uint32 universal-hash coefficients (key 2)

    @property
    def m(self) -> int:
        return self.w.shape[0]

    @property
    def d(self) -> int:
        return self.w.shape[1]


class Features(NamedTuple):
    """Featurization of a point set under m LSH instances.

    ``key1``/``key2`` identify the bucket (exact mode); ``slot``/``sign`` are the
    CountSketch coordinates for the dense-table mode; ``weight`` is
    f^{⊗d}(h(x) + (z - x)/w) — the WLSH weight of each point.
    """

    key1: Array    # (m, n) uint32
    key2: Array    # (m, n) uint32
    weight: Array  # (m, n) float32
    sign: Array    # (m, n) float32 in {-1, +1}


def sample_lsh_params(key: jax.Array, m: int, d: int, pdf: GammaPDF,
                      lengthscale: float = 1.0) -> LSHParams:
    """Draw m iid LSH instances.  ``lengthscale`` rescales the kernel: hashing
    x/ell with widths w is identical to widths ell*w, so we fold it into w."""
    kw, kz, k1, k2 = jax.random.split(key, 4)
    w = jax.random.gamma(kw, pdf.shape, (m, d), dtype=jnp.float32) * pdf.scale
    w = w * jnp.asarray(lengthscale, jnp.float32)
    z = jax.random.uniform(kz, (m, d), dtype=jnp.float32) * w
    # Odd multipliers give a 2^32-universal-ish linear hash of the int vector.
    r1 = jax.random.randint(k1, (m, d), 0, jnp.iinfo(jnp.int32).max, dtype=jnp.int32)
    r2 = jax.random.randint(k2, (m, d), 0, jnp.iinfo(jnp.int32).max, dtype=jnp.int32)
    r1 = (r1.astype(jnp.uint32) << 1) | jnp.uint32(1)
    r2 = (r2.astype(jnp.uint32) << 1) | jnp.uint32(1)
    return LSHParams(w=w, z=z, r1=r1, r2=r2)


def _fmix32(x: Array) -> Array:
    """murmur3 finalizer — decorrelates low/high bits of the linear hash."""
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EB_CA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2_AE35)
    x = x ^ (x >> 16)
    return x


def featurize(params: LSHParams, f: BucketFn, x: Array) -> Features:
    """Hash + weight a point set x (n, d) under all m instances.

    Memory: O(m*n).  The Pallas kernel ``repro.kernels.featurize`` implements a
    fused version of this function; this is the reference path.
    """
    x = jnp.asarray(x, jnp.float32)
    if x.ndim != 2:
        raise ValueError(f"x must be (n, d), got {x.shape}")
    n, d = x.shape
    if d != params.d:
        raise ValueError(f"dim mismatch: points {d} vs params {params.d}")

    # t: (m, n, d)
    t = (x[None, :, :] - params.z[:, None, :]) / params.w[:, None, :]
    h = jnp.round(t)
    u = h - t  # residual in [-1/2, 1/2]
    weight = jnp.prod(f(u), axis=-1)  # (m, n)

    hi = h.astype(jnp.int32).astype(jnp.uint32)
    key1 = _fmix32(jnp.sum(hi * params.r1[:, None, :].astype(jnp.uint32), axis=-1,
                           dtype=jnp.uint32))
    key2 = _fmix32(jnp.sum(hi * params.r2[:, None, :].astype(jnp.uint32), axis=-1,
                           dtype=jnp.uint32))
    # CountSketch sign from a key2 bit that the slot (low bits of key1) ignores.
    sign = 1.0 - 2.0 * (key2 >> 31).astype(jnp.float32)
    return Features(key1=key1, key2=key2, weight=weight.astype(jnp.float32), sign=sign)


def slots_from_features(feats: Features, table_size: int) -> Array:
    """CountSketch slot per (instance, point): low bits of key1. table_size must
    be a power of two."""
    if table_size & (table_size - 1):
        raise ValueError(f"table_size must be a power of 2, got {table_size}")
    return (feats.key1 & jnp.uint32(table_size - 1)).astype(jnp.int32)
