"""Paper core: WLSH estimators, kernels, and KRR (Kapralov et al., AISTATS'20)."""
from .bucket_fns import BUCKET_FNS, RECT, SMOOTH, TENT, BucketFn, get_bucket_fn
from .kernels import (WLSHKernel, WLSHKernelSpec, gaussian_kernel, laplace_kernel,
                      make_wlsh_kernel, matern52_kernel)
from .krr import (CGResult, PCGResult, WLSHKRRModel, cg_solve, exact_krr_fit,
                  exact_krr_predict, model_operator, pcg_solve, wlsh_krr_fit,
                  wlsh_krr_predict)
from .lsh import Features, GammaPDF, LSHParams, featurize, sample_lsh_params
from .operator import WLSHOperator, default_table_size, make_operator
from .precond import (PRECOND_NAMES, Preconditioner, identity_precond,
                      jacobi_precond, make_preconditioner, nystrom_precond,
                      table_diag)
from .rff import rff_krr_fit, rff_krr_predict
from .wlsh import (BlockedLayout, build_blocked_layout, build_exact_index,
                   build_table_index, exact_kernel_matrix, exact_matvec,
                   make_matvec, table_kernel_matrix, table_matvec,
                   table_matvec_fused)
