"""Bucket-shaping functions f for the WLSH estimator (paper Def. 6/8).

Every f is even, supported on [-1/2, 1/2], and normalized so that ||f||_2 = 1.
We provide closed-form piecewise-polynomial evaluation (TPU-friendly: no gathers,
pure VPU arithmetic) plus numerically tabulated autocorrelation (f*f) used by the
analytic kernel (Def. 8).

Provided shapes:
  * ``rect``   — paper's Section-5 choice; recovers Rahimi–Recht random binning.
  * ``tent``   — C^0: (rect * rect)(2x), one bounded derivative.
  * ``smooth`` — paper's Table-1 choice (rect * rect_{1/4} * rect_{1/4})(2x),
                 continuous derivative + bounded second derivative.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray

# Fine grid used to tabulate autocorrelations (f*f); construction is numpy-only
# and happens once per BucketFn instance.
_ACORR_GRID = 8192


@dataclasses.dataclass(frozen=True, eq=False)  # identity hash: instances are
class BucketFn:                                # module-level singletons and
    """A bucket-shaping function with the metadata the theory needs."""  # jit-static args

    name: str
    # Closed-form evaluation of f at arbitrary points (vectorized, jittable).
    eval_fn: Callable[[Array], Array]
    # ||f||_inf — appears in the OSE sample-count m = Ω(||f^{⊗d}||_inf^2 ...).
    f_inf: float
    # smoothness order: number of bounded derivatives of f (0 for rect).
    smoothness: int
    # Tabulated autocorrelation (f*f) on [-1, 1] (numpy arrays; used for the
    # analytic kernel and for unbiasedness tests).
    acorr_x: np.ndarray = dataclasses.field(repr=False, default=None)
    acorr_y: np.ndarray = dataclasses.field(repr=False, default=None)

    def __call__(self, x: Array) -> Array:
        return self.eval_fn(x)

    def acorr(self, t: np.ndarray) -> np.ndarray:
        """(f*f)(t) via the precomputed table (numpy; analysis/tests only)."""
        return np.interp(np.abs(np.asarray(t)), self.acorr_x, self.acorr_y,
                         left=0.0, right=0.0)


def _tabulate_acorr(eval_np: Callable[[np.ndarray], np.ndarray]) -> tuple[np.ndarray, np.ndarray]:
    """Autocorrelation of f on a fine grid. (f even => f*f even; table on [0,1])."""
    n = _ACORR_GRID
    xs = np.linspace(-0.5, 0.5, n + 1)
    dx = xs[1] - xs[0]
    fx = eval_np(xs)
    # full autocorrelation: support [-1, 1]; np.convolve(f, f) * dx
    ac = np.convolve(fx, fx[::-1]) * dx  # length 2n+1, centered at index n
    ts = (np.arange(2 * n + 1) - n) * dx
    keep = ts >= 0.0
    return ts[keep], ac[keep]


# ---------------------------------------------------------------------------
# rect: f(x) = 1 on [-1/2, 1/2].  ||f||_2 = 1 already.
# ---------------------------------------------------------------------------

def _rect_eval(x: Array) -> Array:
    return jnp.where(jnp.abs(x) <= 0.5, 1.0, 0.0).astype(jnp.result_type(x, jnp.float32))


def _rect_np(x: np.ndarray) -> np.ndarray:
    return np.where(np.abs(x) <= 0.5, 1.0, 0.0)


# ---------------------------------------------------------------------------
# tent: f(x) = sqrt(3) * (1 - 2|x|) on [-1/2, 1/2].
#   ||f||_2^2 = 3 * 2*int_0^{1/2} (1-2x)^2 dx = 3 * (1/3) = 1.
# ---------------------------------------------------------------------------

_SQRT3 = float(np.sqrt(3.0))


def _tent_eval(x: Array) -> Array:
    ax = jnp.abs(x)
    return jnp.where(ax <= 0.5, _SQRT3 * (1.0 - 2.0 * ax), 0.0)


def _tent_np(x: np.ndarray) -> np.ndarray:
    ax = np.abs(x)
    return np.where(ax <= 0.5, _SQRT3 * (1.0 - 2.0 * ax), 0.0)


# ---------------------------------------------------------------------------
# smooth: the paper's f(x) = c * (rect * rect_{1/4} * rect_{1/4})(2x).
#
# With G = rect * rect_{1/4} * rect_{1/4} (support [-3/4, 3/4]), for t = |2x|:
#   G(t) = 1/16                      for 0   <= t <= 1/4
#   G(t) = -t^2/2 + t/4 + 1/32       for 1/4 <= t <= 1/2
#   G(t) = (3/4 - t)^2 / 2           for 1/2 <= t <= 3/4
#   G(t) = 0                         otherwise.
# f has support [-3/8, 3/8] ⊂ [-1/2, 1/2]; continuous first derivative,
# bounded second derivative — exactly the smoothness class used for the
# Matérn-5/2 comparison in the paper's Table 1.
# ---------------------------------------------------------------------------

def _smooth_G_np(t: np.ndarray) -> np.ndarray:
    t = np.abs(t)
    out = np.zeros_like(t, dtype=np.float64)
    m1 = t <= 0.25
    m2 = (t > 0.25) & (t <= 0.5)
    m3 = (t > 0.5) & (t <= 0.75)
    out[m1] = 1.0 / 16.0
    out[m2] = -0.5 * t[m2] ** 2 + 0.25 * t[m2] + 1.0 / 32.0
    out[m3] = 0.5 * (0.75 - t[m3]) ** 2
    return out


def _smooth_norm_const() -> float:
    # ||G(2x)||_2^2 = int_0^{3/4} G(t)^2 dt ; computed with dense quadrature of
    # the exact piecewise polynomial (error ~1e-12).
    ts = np.linspace(0.0, 0.75, 200001)
    val = np.trapezoid(_smooth_G_np(ts) ** 2, ts)
    return float(1.0 / np.sqrt(val))


_SMOOTH_C = _smooth_norm_const()


def _smooth_eval(x: Array) -> Array:
    t = jnp.abs(2.0 * x)
    p1 = jnp.full_like(t, 1.0 / 16.0)
    p2 = -0.5 * t * t + 0.25 * t + 1.0 / 32.0
    p3 = 0.5 * (0.75 - t) ** 2
    out = jnp.where(t <= 0.25, p1, jnp.where(t <= 0.5, p2, jnp.where(t <= 0.75, p3, 0.0)))
    return _SMOOTH_C * out


def _smooth_np(x: np.ndarray) -> np.ndarray:
    return _SMOOTH_C * _smooth_G_np(2.0 * np.asarray(x, dtype=np.float64))


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def _make(name: str, eval_fn, eval_np, f_inf: float, smoothness: int) -> BucketFn:
    ax, ay = _tabulate_acorr(eval_np)
    return BucketFn(name=name, eval_fn=eval_fn, f_inf=f_inf, smoothness=smoothness,
                    acorr_x=ax, acorr_y=ay)


RECT = _make("rect", _rect_eval, _rect_np, f_inf=1.0, smoothness=0)
TENT = _make("tent", _tent_eval, _tent_np, f_inf=_SQRT3, smoothness=1)
SMOOTH = _make("smooth", _smooth_eval, _smooth_np, f_inf=_SMOOTH_C / 16.0, smoothness=2)

BUCKET_FNS = {"rect": RECT, "tent": TENT, "smooth": SMOOTH}


def get_bucket_fn(name: str) -> BucketFn:
    try:
        return BUCKET_FNS[name]
    except KeyError:
        raise ValueError(f"unknown bucket fn {name!r}; have {sorted(BUCKET_FNS)}") from None
