"""Preconditioners for the WLSH-KRR PCG solve (DESIGN.md §5).

The fused matvec (PR 2) made each CG iteration cheap, so iteration *count*
is the dominant solve cost — exactly the regime Avron et al. (1804.09893)
analyze, where preconditioning decides end-to-end KRR time.  Two
preconditioners live behind one interface:

* **jacobi** — the exact diagonal of the CountSketch operator.  Scattering
  e_i puts ``coeff[s, i]`` in slot ``slot[s, i]`` and the readout at i
  multiplies by ``coeff[s, i]`` again, so ``diag(K̃)_i = mean_s coeff²[s,i]``
  — a column sum over the hoisted coefficients of the existing TableIndex;
  the (m, B) table is never materialized.  O(mn) once, O(n) per apply.

* **nystrom** — a rank-r pivoted Nyström approximation of the WLSH gram:
  pivot columns ``C = K̃[:, piv]`` come from ONE multi-RHS matvec on r
  one-hot columns (the same batched matvec CG uses), pivots are the r
  largest diagonal entries.  With ``A = C L⁻ᵀ`` (L = chol of the pivot
  block) the preconditioner is P = A Aᵀ + λI ⪯ K̃ + λI, inverted by
  Woodbury:

      P⁻¹ r = (r − A u) / λ,   (λ I_r + AᵀA) u = Aᵀ r

  where u comes from two small (r, r) triangular solves against the cached
  Cholesky factor of λI + AᵀA.  Build cost is one k=r matvec + O(n r²);
  each apply is two (n, r) matmuls + the triangular solves — negligible
  next to a matvec.  Because A Aᵀ is the exact Schur-complement part of K̃
  on the pivot block, the preconditioned spectrum clusters at 1 wherever
  the gram's tail is captured, which is what collapses the iteration count
  on ill-conditioned (small-λ) problems.

``Preconditioner.apply`` takes r of shape (n,) or (n, k) — the whole stack
is RHS-blocked, so preconditioned block-CG applies P⁻¹ to all columns at
once.  Everything is pure jnp: builders and applies trace under jit and
inside shard_map (the distributed step builds jacobi from its local index
plus a model-axis psum — see core/distributed.py).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jnp.ndarray

PRECOND_NAMES = ("none", "jacobi", "nystrom")

# shared Nyström rank default across every surface (wlsh_krr_fit,
# KRRStepConfig, CLI flags, the committed benchmark): the test-pinned ≥3x
# iteration reduction is measured at this rank
DEFAULT_NYSTROM_RANK = 128


class Preconditioner(NamedTuple):
    """z = apply(r) ≈ (K̃ + λI)⁻¹ r, for r of shape (n,) or (n, k)."""

    name: str
    apply: Callable[[Array], Array]


def _colwise_div(r: Array, d: Array) -> Array:
    return r / d if r.ndim == 1 else r / d[:, None]


def identity_precond() -> Preconditioner:
    return Preconditioner(name="none", apply=lambda r: r)


def table_diag(coeff: Array, *, average: bool = True) -> Array:
    """diag(K̃) from a TableIndex's hoisted coeff (m, n): mean_s coeff².
    ``average=False`` gives the instance sum (the distributed path psums the
    local sums over the model axis and divides by the global m)."""
    sq = coeff * coeff
    return jnp.mean(sq, axis=0) if average else jnp.sum(sq, axis=0)


def jacobi_precond(diag: Array, lam: float) -> Preconditioner:
    """Diagonal (Jacobi) preconditioner for (K̃ + λI) from diag(K̃)."""
    d = diag + jnp.asarray(lam, diag.dtype)
    return Preconditioner(name="jacobi", apply=lambda r: _colwise_div(r, d))


class NystromFactors(NamedTuple):
    """Cached factorization P = A Aᵀ + λI of the rank-r pivoted Nyström
    approximation (exposed for tests; ``apply`` closes over it)."""

    pivots: Array   # (r,) int32 — pivot point indices (largest diag first)
    a: Array        # (n, r) — C W with W W ᵀ = K̃[piv, piv]⁺ (whitened columns)
    chol_small: Array  # (r, r) lower Cholesky of λ I_r + AᵀA
    lam: Array      # scalar


def nystrom_factors(matvec: Callable[[Array], Array], diag: Array,
                    lam: float, rank: int, *,
                    jitter: float = 1e-6) -> NystromFactors:
    """One multi-RHS matvec + two small factorizations; O(n r²) flops.

    The pivot block is whitened through its eigendecomposition with a
    relative eigenvalue floor rather than a Cholesky: smooth kernels make
    K̃[piv, piv] numerically rank-deficient in f32, where a jittered chol
    either NaNs or amplifies noise past λ (directions below the floor are
    dropped — the preconditioner just loses the rank they carried).  λI +
    AᵀA is then safely SPD, and its Cholesky is what the two triangular
    solves in ``apply`` run against.
    """
    n = diag.shape[0]
    r = min(int(rank), n)
    _, pivots = jax.lax.top_k(diag, r)
    pivots = pivots.astype(jnp.int32)
    onehot = jnp.zeros((n, r), jnp.float32).at[
        pivots, jnp.arange(r, dtype=jnp.int32)].set(1.0)
    cols = matvec(onehot)                                    # (n, r) = K̃[:, piv]
    small = cols[pivots]                                     # (r, r) pivot block
    small = 0.5 * (small + small.T)
    evals, evecs = jnp.linalg.eigh(small)
    floor = jnp.maximum(jnp.max(evals), 0.0) * jitter + 1e-30
    inv_sqrt = jnp.where(evals > floor, 1.0 / jnp.sqrt(
        jnp.maximum(evals, floor)), 0.0)
    a = cols @ (evecs * inv_sqrt[None, :])                   # (n, r)
    lam_arr = jnp.asarray(lam, a.dtype)
    eye = jnp.eye(r, dtype=a.dtype)
    chol_small = jnp.linalg.cholesky(lam_arr * eye + a.T @ a)
    return NystromFactors(pivots=pivots, a=a, chol_small=chol_small,
                          lam=lam_arr)


def nystrom_precond(matvec: Callable[[Array], Array], diag: Array,
                    lam: float, rank: int, *,
                    jitter: float = 1e-6) -> Preconditioner:
    """Randomized/pivoted Nyström preconditioner for (K̃ + λI)."""
    fac = nystrom_factors(matvec, diag, lam, rank, jitter=jitter)

    def apply(rhs: Array) -> Array:
        vec = rhs.ndim == 1
        rr = rhs[:, None] if vec else rhs
        t = fac.a.T @ rr                                     # (r, k)
        u = jax.scipy.linalg.solve_triangular(
            fac.chol_small.T,
            jax.scipy.linalg.solve_triangular(fac.chol_small, t, lower=True),
            lower=False)
        z = (rr - fac.a @ u) / fac.lam
        return z[:, 0] if vec else z

    return Preconditioner(name="nystrom", apply=apply)


def make_preconditioner(name: str, *, matvec=None, diag=None,
                        lam: float = 0.0, rank: int = DEFAULT_NYSTROM_RANK,
                        jitter: float = 1e-6) -> Preconditioner:
    """Factory keyed on the CLI names: 'none' | 'jacobi' | 'nystrom'.
    'jacobi' needs ``diag``; 'nystrom' needs ``diag`` (pivot scores) and
    ``matvec`` (the K̃ operator, multi-RHS capable)."""
    if name == "none" or name is None:
        return identity_precond()
    if name == "jacobi":
        if diag is None:
            raise ValueError("jacobi preconditioner needs diag")
        return jacobi_precond(diag, lam)
    if name == "nystrom":
        if diag is None or matvec is None:
            raise ValueError("nystrom preconditioner needs diag and matvec")
        return nystrom_precond(matvec, diag, lam, rank, jitter=jitter)
    raise ValueError(f"unknown preconditioner {name!r}; "
                     f"expected one of {PRECOND_NAMES}")
