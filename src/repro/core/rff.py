"""Random Fourier Features baseline (Rahimi & Recht 2007), as compared against
in the paper's Table 2.

Approximates the squared-exponential kernel exp(-||x-y||^2 / ell^2) with
phi(x) = sqrt(2/D) cos(W x + b), W ~ N(0, 2/ell^2 I), b ~ Unif[0, 2pi].
KRR is solved in the primal: (Phi^T Phi + lam I_D) alpha = Phi^T y  — O(n D^2).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jnp.ndarray


class RFFModel(NamedTuple):
    w: Array      # (d, D)
    b: Array      # (D,)
    alpha: Array  # (D,)


def rff_features(w: Array, b: Array, x: Array) -> Array:
    d_feat = w.shape[1]
    return jnp.sqrt(2.0 / d_feat) * jnp.cos(x @ w + b)


def rff_krr_fit(key: jax.Array, x: Array, y: Array, *, n_features: int,
                lam: float, lengthscale: float = 1.0) -> RFFModel:
    n, d = x.shape
    kw, kb = jax.random.split(key)
    # Var chosen so E[phi(x)phi(y)] = exp(-||x-y||^2/ell^2):
    # k(delta)=exp(-||delta||^2/ell^2) has spectral density N(0, 2/ell^2).
    w = jax.random.normal(kw, (d, n_features)) * jnp.sqrt(2.0) / lengthscale
    b = jax.random.uniform(kb, (n_features,), maxval=2.0 * jnp.pi)
    phi = rff_features(w, b, x)  # (n, D)
    gram = phi.T @ phi + lam * jnp.eye(n_features, dtype=phi.dtype)
    alpha = jnp.linalg.solve(gram, phi.T @ y)
    return RFFModel(w=w, b=b, alpha=alpha)


def rff_krr_predict(model: RFFModel, x_test: Array) -> Array:
    return rff_features(model.w, model.b, x_test) @ model.alpha
