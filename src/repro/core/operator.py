"""The WLSH operator — one spine for every execution path (DESIGN.md §3).

``WLSHOperator`` bundles the m LSH instances, the bucket-shaping function and
the CountSketch table geometry behind a small primitive set:

    featurize       points -> Features            (hash + weight + sign)
    build_index     Features -> Table/Exact index (per-point-set structure)
    loads           index, beta -> (m, B) tables  (CountSketch scatter)
    readout         index, tables -> per-point    (CountSketch gather)
    matvec          index, beta -> K~ beta        (fused one-pass off the
                    slot-blocked layout, or loads ∘ readout when split)
    featurize_buckets    x_query -> TableIndex    (query hash half of predict)
    predict_from_buckets index, tables -> yhat    (readout half of predict —
                         pure function of the query's bucket structure)
    predict_batched      tables, x_test -> yhat   (streaming, fixed memory;
                         wrapper over the two halves)

Every primitive dispatches on ``backend``:

* ``reference`` — the pure-jnp path (core/lsh.py + core/wlsh.py).
* ``pallas``    — the fused kernels (kernels/featurize + kernels/binning),
  with interpret mode auto-selected from the platform and all shape padding
  handled internally.
* ``auto``      — resolved per platform at construction (see repro.backend).

The solver (core/krr.py), the distributed step (core/distributed.py) and the
benchmarks all talk to this interface only, so swapping kernels or meshes is
a one-file change.  The distributed path constructs an operator *inside*
shard_map from its local LSH shard: ``loads`` then produces local partial
tables (psum-able across data shards) and ``readout(average=False)`` the
local instance-sum (psum-able across the model axis).
"""
from __future__ import annotations

from typing import NamedTuple, Union

import jax
import jax.numpy as jnp

from ..backend import default_interpret, resolve_backend
from .bucket_fns import BucketFn
from .lsh import Features, LSHParams, featurize as featurize_reference
from .wlsh import (BLOCKED_N, BLOCKED_SPLIT_N, BLOCKED_SPLIT_T, BLOCKED_T,
                   ExactIndex, TableIndex, build_blocked_layout,
                   build_exact_index, build_table_index, exact_matvec,
                   table_loads, table_matvec_fused, table_readout)

Array = jnp.ndarray
Index = Union[TableIndex, ExactIndex]


def default_table_size(n: int, *, min_pow: int = 8) -> int:
    """CountSketch table-size heuristic: the smallest power of two >= 4n
    (>= 2^min_pow) keeps same-slot collisions rare."""
    return 1 << max(min_pow, int(4 * max(n, 1) - 1).bit_length())


class WLSHOperator(NamedTuple):
    """Backend-dispatched WLSH primitive set bound to m LSH instances.

    A NamedTuple so it can be built inside jit/shard_map from traced local
    LSH shards and closed over freely; ``backend`` must already be concrete
    ('reference' or 'pallas') — use ``make_operator`` to resolve 'auto'.
    """

    lsh: LSHParams
    bucket: BucketFn
    table_size: int
    backend: str = "reference"
    interpret: bool = True       # Pallas interpret mode (ignored by reference)
    fused: bool = True           # one-pass matvec off the slot-blocked layout

    # -- featurization ------------------------------------------------------

    def featurize(self, x: Array) -> Features:
        if self.backend == "pallas":
            from ..kernels.featurize import featurize_op
            return featurize_op(self.lsh, self.bucket, x,
                                interpret=self.interpret)
        return featurize_reference(self.lsh, self.bucket, x)

    # -- index construction -------------------------------------------------

    def build_index(self, feats: Features, mode: str = "table", *,
                    blocked: bool | None = None,
                    parts: str | None = None) -> Index:
        """'table' -> CountSketch TableIndex (both backends); 'exact' ->
        sorted-bucket ExactIndex (reference-only validation path).

        ``blocked`` attaches the slot-blocked layout (one-off per-instance
        sort + per-tile offsets) consumed by the fused matvec AND by the
        pallas split scatter/gather (``loads``/``readout`` dispatch to the
        visit-list kernels when the layout is present — the distributed
        psum path schedules only real collisions while keeping the
        (m, B[, k]) tables in HBM).  ``None`` follows the operator's
        ``fused`` flag.  Readout-only consumers (prediction) pass
        ``blocked=False`` to skip the sort.  ``parts`` overrides which
        layout array group is materialized (default: this backend's own) —
        the hash-join step passes 'both' on the pallas backend because its
        routing build consumes the reference group (perm/segments) while
        its route kernels consume the pallas group (src/coeff_lay).
        """
        if mode == "table":
            idx = build_table_index(feats, self.table_size)
            want_blocked = self.fused if blocked is None else blocked
            if want_blocked:
                # only materialize the array group this backend's fused
                # matvec consumes (the groups are disjoint and O(mn)-sized).
                # A pallas layout destined for the split kernels (operator
                # not fused — e.g. the data-sharded psum path) takes the
                # split-tuned geometry; the fused kernel keeps its own.
                split_only = self.backend == "pallas" and not self.fused
                bn = BLOCKED_SPLIT_N if split_only else BLOCKED_N
                bt = BLOCKED_SPLIT_T if split_only else BLOCKED_T
                idx = idx._replace(blocked=build_blocked_layout(
                    idx.slot, idx.coeff, self.table_size,
                    block_n=bn, block_t=bt,
                    parts=self.backend if parts is None else parts))
            return idx
        if mode == "exact":
            return build_exact_index(feats)
        raise ValueError(f"unknown mode {mode!r}")

    # -- CountSketch scatter / gather ---------------------------------------

    def loads(self, index: TableIndex, beta: Array) -> Array:
        """Bucket-load tables for beta — the psum-able object.  (m, B) for a
        (n,) beta; (m, B, k) for a (n, k) RHS block (columns independent).
        On the pallas backend an index carrying the slot-blocked layout
        scatters through the visit-list kernel (O(n/bn + B/bt) grid) instead
        of the (n/bn)·(B/bt) cross product — same tables, same psum."""
        if self.backend == "pallas":
            from ..kernels.binning import bin_loads_op
            return bin_loads_op(index, beta, interpret=self.interpret)
        return table_loads(index, beta)

    def readout(self, index: TableIndex, tables: Array, *,
                average: bool = True) -> Array:
        """Per-point readout of (possibly psum-merged) tables.  ``average``
        gives (1/m) sum_s; ``average=False`` gives the plain instance sum
        (the distributed path divides by the global m after its psum)."""
        if self.backend == "pallas":
            from ..kernels.binning import bin_readout_op
            return bin_readout_op(index, tables, average=average,
                                  interpret=self.interpret)
        return table_readout(index, tables, average=average)

    # -- matvec -------------------------------------------------------------

    def matvec(self, index: Index, beta: Array, *,
               average: bool = True) -> Array:
        """K~ beta in O(n m); ``beta`` is (n,) or an (n, k) RHS block.

        The k columns of a block share the index, the slot sort and (on the
        fused paths) every one-hot tile product / segment id — a block-CG
        solve or batched GP-posterior fit costs far less than k single
        solves (see core/krr.py:pcg_solve).

        Table mode dispatches on the index: with a slot-blocked layout (and
        ``fused`` set) the scatter and gather run in one pass — a single
        Pallas kernel whose table tile stays in VMEM, or the reference
        sorted segment-sum — so the (m, B) table is never materialized
        between them.  Without a layout it falls back to the split
        loads → readout composition (the psum-able path).  Exact mode is the
        reference sorted-bucket estimator (``average`` only).
        """
        if isinstance(index, ExactIndex):
            if not average:
                raise ValueError("exact-mode matvec only supports average=True")
            return exact_matvec(index, beta)
        lay = index.blocked
        if self.fused and lay is not None:
            # each backend consumes its own layout group; an index built by
            # the other backend degrades to the split path below
            if self.backend == "pallas" and lay.src is not None:
                from ..kernels.binning import bin_fused_matvec_op
                return bin_fused_matvec_op(index, beta, average=average,
                                           interpret=self.interpret)
            if self.backend != "pallas" and lay.perm is not None:
                return table_matvec_fused(index, beta, average=average)
        return self.readout(index, self.loads(index, beta), average=average)

    # -- streaming prediction -----------------------------------------------

    def featurize_buckets(self, x: Array) -> TableIndex:
        """Query half of the prediction path: featurize ``x`` and build the
        readout-only table index (no slot-blocked layout — prediction never
        scatters).  The result is the per-query bucket structure: its
        (slot, coeff) pairs are everything a prediction depends on, which is
        what makes bucket-keyed caching exact (serve/cache.py) and lets the
        serving layer split the query hash from the table gather."""
        return self.build_index(self.featurize(x), blocked=False)

    def predict_from_buckets(self, index: TableIndex, tables: Array) -> Array:
        """Readout half of the prediction path: predictions for an already
        bucketed query set.  Pure function of (index.slot, index.coeff) and
        ``tables`` — no access to the raw points.  Tables may be (m, B) ->
        (n_query,) predictions, or (m, B, k) -> (n_query, k)."""
        return self.readout(index, tables)

    def predict_batched(self, tables: Array, x_test: Array, *,
                        batch_size: int | None = None) -> Array:
        """Read test-point predictions out of prebuilt bucket-load tables —
        a thin wrapper over ``featurize_buckets`` + ``predict_from_buckets``.

        With ``batch_size`` the test set is processed in fixed-size blocks via
        ``lax.map`` — peak memory is O(batch_size * m) regardless of n_test,
        which is what lets multi-million-point inference stream.  Tables may
        be (m, B) -> (n_test,) predictions, or (m, B, k) -> (n_test, k) (one
        streamed readout serves all k fitted columns)."""
        n = x_test.shape[0]
        if batch_size is None or batch_size >= n:
            return self.predict_from_buckets(self.featurize_buckets(x_test),
                                             tables)
        n_blocks = -(-n // batch_size)
        xp = jnp.pad(jnp.asarray(x_test, jnp.float32),
                     ((0, n_blocks * batch_size - n), (0, 0)))
        blocks = xp.reshape(n_blocks, batch_size, x_test.shape[1])

        def one_block(xb):
            return self.predict_from_buckets(self.featurize_buckets(xb),
                                             tables)

        out = jax.lax.map(one_block, blocks)
        return out.reshape((-1,) + out.shape[2:])[:n]


def make_operator(lsh: LSHParams, bucket: BucketFn, table_size: int, *,
                  backend: str | None = "auto",
                  interpret: bool | None = None,
                  fused: bool = True) -> WLSHOperator:
    """Construct an operator with 'auto' backend/interpret resolved for this
    platform (the only place resolution happens — everything downstream sees
    a concrete backend).  ``fused=False`` keeps the split scatter→gather
    matvec reachable for A/B runs."""
    return WLSHOperator(lsh=lsh, bucket=bucket, table_size=int(table_size),
                        backend=resolve_backend(backend),
                        interpret=default_interpret() if interpret is None
                        else interpret,
                        fused=fused)
