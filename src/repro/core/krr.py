"""Kernel ridge regression solvers.

* ``pcg_solve`` — jittable preconditioned (block-)CG on (A + lam I) with an
  arbitrary matvec (the WLSH O(n) structure, an explicit matrix, or a
  distributed shard_map matvec — the solver only touches the operator
  through ``matvec``).  ``b`` may be (n,) or an (n, k) RHS block: all k
  systems share every matvec/preconditioner application, convergence is
  tracked per column, and converged columns are deflated (frozen) so their
  iterates stop changing while the stragglers finish.
* ``cg_solve`` — the historical single/unpreconditioned entry point, now a
  thin wrapper over ``pcg_solve`` (kept because every caller and test reads
  its scalar ``CGResult``).
* ``exact_krr_fit`` / ``exact_krr_predict`` — Cholesky baseline.
* ``wlsh_krr_fit`` / ``wlsh_krr_predict`` — the paper's §4.2 algorithm: solve
  (K̃ + lam I) beta = y with PCG, predict via bucket loads.

The WLSH path runs entirely through ``core.operator.WLSHOperator``, so the
same solver drives the jnp reference backend, the fused Pallas kernels
(``backend='pallas'``), or platform auto-selection (``backend='auto'``).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from .bucket_fns import get_bucket_fn
from .kernels import WLSHKernelSpec
from .lsh import LSHParams, sample_lsh_params
from .operator import WLSHOperator, default_table_size, make_operator
from .precond import (DEFAULT_NYSTROM_RANK, Preconditioner, identity_precond,
                      make_preconditioner, table_diag)

Array = jnp.ndarray
MatVec = Callable[[Array], Array]


class CGResult(NamedTuple):
    x: Array
    iters: Array
    resnorm: Array


class PCGResult(NamedTuple):
    x: Array          # (n,) or (n, k) — solution block
    iters: Array      # scalar int32 — block iterations run (max over columns)
    col_iters: Array  # (k,) int32 — iteration at which each column converged
    resnorm: Array    # (k,) f32 — final per-column ||r||


def pcg_solve(matvec: MatVec, b: Array, lam: float, *,
              precond: Preconditioner | None = None, tol: float = 1e-6,
              atol: float = 1e-12, maxiter: int = 200,
              x0: Array | None = None) -> PCGResult:
    """Solve (A + lam I) X = B with preconditioned conjugate gradients.

    ``b`` is (n,) for one system or (n, k) for a RHS block; with a block the
    single matvec per iteration covers all k columns (the WLSH multi-RHS
    matvec amortizes the index walk — see WLSHOperator.matvec), and the CG
    recurrences run column-wise, so each column's trajectory is exactly the
    single-RHS trajectory it would have had alone.

    Per-column convergence when ``||r_j|| <= max(tol * ||b_j||, atol)`` —
    the absolute floor makes ``b_j = 0`` (and any exactly-solved system)
    terminate immediately instead of looping ``maxiter`` times on a zero
    threshold.  A converged column is deflated: its search direction is
    zeroed and its step sizes forced to 0, so its (x, r) freeze while the
    remaining columns iterate; the loop ends when every column is converged
    or at ``maxiter``.  All loop invariants (lam broadcast, thresholds,
    breakdown guard, preconditioner factors) are hoisted out of the
    iteration; each step costs one matvec, one preconditioner apply and
    three column-wise reductions.

    For a 1-D ``b`` the user matvec is only ever called with 1-D vectors
    (the block machinery runs on a width-1 column internally), so existing
    single-RHS matvec closures keep working unchanged.
    """
    vec = b.ndim == 1
    inner_mv = (lambda v: matvec(v[:, 0])[:, None]) if vec else matvec
    b2 = b[:, None] if vec else b
    k = b2.shape[1]
    lam = jnp.asarray(lam, b2.dtype)
    eps = jnp.asarray(1e-30, b2.dtype)           # breakdown guard, hoisted
    maxiter = jnp.asarray(maxiter, jnp.int32)
    psolve = (identity_precond() if precond is None else precond).apply

    def amv(v):
        return inner_mv(v) + lam * v

    if x0 is None:
        x = jnp.zeros_like(b2)
    else:
        x = x0[:, None] if vec else x0
    r = b2 - amv(x)
    z = psolve(r)
    rs = jnp.sum(r * r, axis=0)                  # (k,) true residual norms²
    rho = jnp.sum(r * z, axis=0)                 # (k,) M⁻¹-inner products
    bnorm = jnp.sqrt(jnp.sum(b2 * b2, axis=0))
    thresh = jnp.maximum(tol * bnorm, jnp.asarray(atol, b2.dtype)) ** 2
    active = rs > thresh
    p = jnp.where(active[None, :], z, 0.0)
    col_iters = jnp.where(active, maxiter, 0).astype(jnp.int32)

    def cond(state):
        _, _, _, _, _, active, it, _ = state
        return jnp.any(active) & (it < maxiter)

    def body(state):
        x, r, p, rs, rho, active, it, col_iters = state
        ap = amv(p)
        denom = jnp.sum(p * ap, axis=0)
        alpha = jnp.where(active, rho / jnp.maximum(denom, eps), 0.0)
        x = x + alpha[None, :] * p
        r = r - alpha[None, :] * ap
        rs = jnp.sum(r * r, axis=0)
        # a column whose residual goes non-finite (preconditioner breakdown
        # at extreme conditioning) is deactivated instead of burning the
        # remaining iterations on NaNs; its resnorm reports the failure
        still = (rs > thresh) & jnp.isfinite(rs)
        col_iters = jnp.where(active & ~still, it + 1, col_iters)
        active = active & still
        z = psolve(r)
        rho_new = jnp.sum(r * z, axis=0)
        beta = jnp.where(active, rho_new / jnp.maximum(rho, eps), 0.0)
        # deflation: converged columns get p = 0, so alpha·p and alpha·ap
        # vanish and their (x, r) are frozen from here on
        p = jnp.where(active[None, :], z + beta[None, :] * p, 0.0)
        return x, r, p, rs, rho_new, active, it + 1, col_iters

    x, r, p, rs, rho, active, it, col_iters = jax.lax.while_loop(
        cond, body,
        (x, r, p, rs, rho, active, jnp.asarray(0, jnp.int32), col_iters))
    # columns still active at maxiter report maxiter (their init value)
    resnorm = jnp.sqrt(rs)
    return PCGResult(x=x[:, 0] if vec else x, iters=it,
                     col_iters=col_iters, resnorm=resnorm)


def cg_solve(matvec: MatVec, b: Array, lam: float, *, tol: float = 1e-6,
             atol: float = 1e-12, maxiter: int = 200,
             x0: Array | None = None) -> CGResult:
    """Unpreconditioned single-RHS CG — wrapper over ``pcg_solve`` returning
    the scalar-shaped ``CGResult`` the historical callers expect."""
    res = pcg_solve(matvec, b, lam, tol=tol, atol=atol, maxiter=maxiter,
                    x0=x0)
    squeeze = b.ndim == 1
    return CGResult(x=res.x,
                    iters=res.iters if not squeeze else res.col_iters[0],
                    resnorm=res.resnorm[0] if squeeze else res.resnorm)


# ---------------------------------------------------------------------------
# exact KRR (dense baseline)
# ---------------------------------------------------------------------------

def exact_krr_fit(kernel_fn, x: Array, y: Array, lam: float) -> Array:
    k = kernel_fn(x, x)
    n = x.shape[0]
    a = k + lam * jnp.eye(n, dtype=k.dtype)
    return jnp.linalg.solve(a, y)


def exact_krr_predict(kernel_fn, x_train: Array, beta: Array, x_test: Array) -> Array:
    return kernel_fn(x_test, x_train) @ beta


# ---------------------------------------------------------------------------
# WLSH approximate KRR (paper §4.2)
# ---------------------------------------------------------------------------

class WLSHKRRModel(NamedTuple):
    lsh: LSHParams
    bucket_name: str
    beta: Array           # (n,) or (n, k) PCG solution of (K̃ + lam I) b = y
    tables: Array         # (m, B[, k]) bucket loads of beta — all prediction
    table_size: int       # needs (k columns for a multi-RHS fit)
    cg_iters: Array
    cg_resnorm: Array
    backend: str = "reference"   # concrete backend the model was fit with
    precond: str = "none"        # preconditioner the solve used
    cg_col_iters: Array | None = None  # (k,) per-column iteration counts


def model_operator(model: WLSHKRRModel, *,
                   backend: str | None = None) -> WLSHOperator:
    """Rebuild the operator a fitted model was trained with (optionally
    overriding the backend — all backends read the same tables)."""
    return make_operator(model.lsh, get_bucket_fn(model.bucket_name),
                         model.table_size,
                         backend=backend if backend is not None
                         else model.backend)


def wlsh_krr_fit(key: jax.Array, x: Array, y: Array, spec: WLSHKernelSpec, *,
                 m: int, lam: float, mode: str = "table", table_size: int = 0,
                 tol: float = 1e-5, atol: float = 1e-12, maxiter: int = 400,
                 backend: str | None = "auto", fused: bool = True,
                 precond: str = "none",
                 precond_rank: int = DEFAULT_NYSTROM_RANK) -> WLSHKRRModel:
    """``fused`` selects the one-pass slot-blocked matvec for the CG solve
    (default); ``fused=False`` keeps the split scatter→gather path reachable
    for A/B runs.  The fitted model (beta, tables) is identical either way —
    bitwise on the reference backend.  ``tol``/``atol`` are the PCG relative /
    absolute residual thresholds (see ``pcg_solve``).

    ``y`` is (n,) for a plain fit or (n, k) for a batched multi-RHS fit
    (k targets — e.g. the GP posterior-sample block from core/gp.py — share
    the index build and every solver matvec; see ``pcg_solve``).

    ``precond`` selects the solver preconditioner ('none' | 'jacobi' |
    'nystrom', see core/precond.py); 'nystrom' builds its rank-
    ``precond_rank`` pivoted factorization with one extra multi-RHS matvec
    before the solve and typically cuts ill-conditioned (small-lam)
    iteration counts by well over 3x."""
    n, d = x.shape
    if table_size <= 0:
        # heuristic: ~4x points per instance keeps same-slot collisions rare
        table_size = default_table_size(n)
    lsh = sample_lsh_params(key, m, d, spec.pdf, spec.lengthscale)
    op = make_operator(lsh, get_bucket_fn(spec.bucket.name), table_size,
                       backend=backend, fused=fused)
    feats = op.featurize(x)

    # Prediction tables are always CountSketch (exact-mode key lookup for
    # out-of-sample points would need a hash join; the signed table is unbiased
    # and O(1) per query — see DESIGN.md §3).  In table mode the same index
    # drives CG, so it is built exactly once (the CG closure closes over the
    # slot-blocked layout when fused — the sort runs once, not per iteration).
    tidx = op.build_index(feats, mode="table",
                          blocked=fused and mode == "table")
    if mode == "exact":
        eidx = op.build_index(feats, mode="exact")
        mv = lambda v: op.matvec(eidx, v)
        diag = jnp.mean(eidx.weight * eidx.weight, axis=0)
    elif mode == "table":
        mv = lambda v: op.matvec(tidx, v)
        diag = table_diag(tidx.coeff)
    else:
        raise ValueError(f"unknown mode {mode!r}")
    pre = make_preconditioner(precond, matvec=mv, diag=diag, lam=lam,
                              rank=precond_rank)

    res = pcg_solve(mv, y, lam, precond=pre, tol=tol, atol=atol,
                    maxiter=maxiter)
    tables = op.loads(tidx, res.x)
    squeeze = y.ndim == 1
    return WLSHKRRModel(lsh=lsh, bucket_name=spec.bucket.name, beta=res.x,
                        tables=tables, table_size=table_size,
                        cg_iters=res.col_iters[0] if squeeze else res.iters,
                        cg_resnorm=res.resnorm[0] if squeeze
                        else res.resnorm,
                        backend=op.backend, precond=precond,
                        cg_col_iters=res.col_iters)


def wlsh_krr_predict(model: WLSHKRRModel, x_test: Array, *,
                     batch_size: int | None = None,
                     backend: str | None = None) -> Array:
    """Predict at x_test from the model's bucket-load tables.  ``batch_size``
    streams the test set in fixed-memory blocks (multi-million-point
    inference never materializes an (m, n_test) featurization).  A model fit
    on an (n, k) RHS block predicts all k columns at once: (n_test, k)."""
    op = model_operator(model, backend=backend)
    return op.predict_batched(model.tables, x_test, batch_size=batch_size)
