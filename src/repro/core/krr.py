"""Kernel ridge regression solvers.

* ``cg_solve`` — jittable conjugate gradients on (A + lam I) with an arbitrary
  matvec (the WLSH O(n) structure, an explicit matrix, or a distributed
  shard_map matvec — CG only touches the operator through ``matvec``).
* ``exact_krr_fit`` / ``exact_krr_predict`` — Cholesky baseline.
* ``wlsh_krr_fit`` / ``wlsh_krr_predict`` — the paper's §4.2 algorithm: solve
  (K̃ + lam I) beta = y with CG, predict via bucket loads.

The WLSH path runs entirely through ``core.operator.WLSHOperator``, so the
same solver drives the jnp reference backend, the fused Pallas kernels
(``backend='pallas'``), or platform auto-selection (``backend='auto'``).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from .bucket_fns import get_bucket_fn
from .kernels import WLSHKernelSpec
from .lsh import LSHParams, sample_lsh_params
from .operator import WLSHOperator, default_table_size, make_operator

Array = jnp.ndarray
MatVec = Callable[[Array], Array]


class CGResult(NamedTuple):
    x: Array
    iters: Array
    resnorm: Array


def cg_solve(matvec: MatVec, b: Array, lam: float, *, tol: float = 1e-6,
             atol: float = 1e-12, maxiter: int = 200,
             x0: Array | None = None) -> CGResult:
    """Solve (A + lam I) x = b with conjugate gradients (A PSD via matvec).

    Convergence when ``||r|| <= max(tol * ||b||, atol)`` — the absolute floor
    makes ``b = 0`` (and any exactly-solved system) terminate immediately
    instead of looping ``maxiter`` times on a zero threshold.  All loop
    invariants (lam broadcast, threshold, breakdown guard) are hoisted out of
    the iteration; each step costs exactly one matvec and two dot products.
    """
    lam = jnp.asarray(lam, b.dtype)
    eps = jnp.asarray(1e-30, b.dtype)            # breakdown guard, hoisted
    maxiter = jnp.asarray(maxiter, jnp.int32)

    def amv(v):
        return matvec(v) + lam * v

    x = jnp.zeros_like(b) if x0 is None else x0
    r = b - amv(x)
    p = r
    rs = jnp.vdot(r, r)
    bnorm = jnp.sqrt(jnp.vdot(b, b))
    thresh = jnp.maximum(tol * bnorm, jnp.asarray(atol, b.dtype)) ** 2

    def cond(state):
        _, _, _, rs, it = state
        return (rs > thresh) & (it < maxiter)

    def body(state):
        x, r, p, rs, it = state
        ap = amv(p)
        alpha = rs / jnp.maximum(jnp.vdot(p, ap), eps)
        x = x + alpha * p
        r = r - alpha * ap
        rs_new = jnp.vdot(r, r)
        p = r + (rs_new / jnp.maximum(rs, eps)) * p
        return x, r, p, rs_new, it + 1

    x, r, p, rs, it = jax.lax.while_loop(
        cond, body, (x, r, p, rs, jnp.asarray(0, jnp.int32)))
    return CGResult(x=x, iters=it, resnorm=jnp.sqrt(rs))


# ---------------------------------------------------------------------------
# exact KRR (dense baseline)
# ---------------------------------------------------------------------------

def exact_krr_fit(kernel_fn, x: Array, y: Array, lam: float) -> Array:
    k = kernel_fn(x, x)
    n = x.shape[0]
    a = k + lam * jnp.eye(n, dtype=k.dtype)
    return jnp.linalg.solve(a, y)


def exact_krr_predict(kernel_fn, x_train: Array, beta: Array, x_test: Array) -> Array:
    return kernel_fn(x_test, x_train) @ beta


# ---------------------------------------------------------------------------
# WLSH approximate KRR (paper §4.2)
# ---------------------------------------------------------------------------

class WLSHKRRModel(NamedTuple):
    lsh: LSHParams
    bucket_name: str
    beta: Array           # (n,) CG solution of (K̃ + lam I) beta = y
    tables: Array         # (m, B) bucket loads of beta — all prediction needs
    table_size: int
    cg_iters: Array
    cg_resnorm: Array
    backend: str = "reference"   # concrete backend the model was fit with


def model_operator(model: WLSHKRRModel, *,
                   backend: str | None = None) -> WLSHOperator:
    """Rebuild the operator a fitted model was trained with (optionally
    overriding the backend — all backends read the same tables)."""
    return make_operator(model.lsh, get_bucket_fn(model.bucket_name),
                         model.table_size,
                         backend=backend if backend is not None
                         else model.backend)


def wlsh_krr_fit(key: jax.Array, x: Array, y: Array, spec: WLSHKernelSpec, *,
                 m: int, lam: float, mode: str = "table", table_size: int = 0,
                 tol: float = 1e-5, atol: float = 1e-12, maxiter: int = 400,
                 backend: str | None = "auto",
                 fused: bool = True) -> WLSHKRRModel:
    """``fused`` selects the one-pass slot-blocked matvec for the CG solve
    (default); ``fused=False`` keeps the split scatter→gather path reachable
    for A/B runs.  The fitted model (beta, tables) is identical either way —
    bitwise on the reference backend.  ``tol``/``atol`` are the CG relative /
    absolute residual thresholds (see ``cg_solve``)."""
    n, d = x.shape
    if table_size <= 0:
        # heuristic: ~4x points per instance keeps same-slot collisions rare
        table_size = default_table_size(n)
    lsh = sample_lsh_params(key, m, d, spec.pdf, spec.lengthscale)
    op = make_operator(lsh, get_bucket_fn(spec.bucket.name), table_size,
                       backend=backend, fused=fused)
    feats = op.featurize(x)

    # Prediction tables are always CountSketch (exact-mode key lookup for
    # out-of-sample points would need a hash join; the signed table is unbiased
    # and O(1) per query — see DESIGN.md §3).  In table mode the same index
    # drives CG, so it is built exactly once (the CG closure closes over the
    # slot-blocked layout when fused — the sort runs once, not per iteration).
    tidx = op.build_index(feats, mode="table",
                          blocked=fused and mode == "table")
    if mode == "exact":
        eidx = op.build_index(feats, mode="exact")
        mv = lambda v: op.matvec(eidx, v)
    elif mode == "table":
        mv = lambda v: op.matvec(tidx, v)
    else:
        raise ValueError(f"unknown mode {mode!r}")

    res = cg_solve(mv, y, lam, tol=tol, atol=atol, maxiter=maxiter)
    tables = op.loads(tidx, res.x)
    return WLSHKRRModel(lsh=lsh, bucket_name=spec.bucket.name, beta=res.x,
                        tables=tables, table_size=table_size,
                        cg_iters=res.iters, cg_resnorm=res.resnorm,
                        backend=op.backend)


def wlsh_krr_predict(model: WLSHKRRModel, x_test: Array, *,
                     batch_size: int | None = None,
                     backend: str | None = None) -> Array:
    """Predict at x_test from the model's bucket-load tables.  ``batch_size``
    streams the test set in fixed-memory blocks (multi-million-point
    inference never materializes an (m, n_test) featurization)."""
    op = model_operator(model, backend=backend)
    return op.predict_batched(model.tables, x_test, batch_size=batch_size)
