"""Kernel ridge regression solvers.

* ``pcg_solve`` — jittable preconditioned (block-)CG on (A + lam I) with an
  arbitrary matvec (the WLSH O(n) structure, an explicit matrix, or a
  distributed shard_map matvec — the solver only touches the operator
  through ``matvec``).  ``b`` may be (n,) or an (n, k) RHS block: all k
  systems share every matvec/preconditioner application, convergence is
  tracked per column, and converged columns are deflated (frozen) so their
  iterates stop changing while the stragglers finish.
* ``cg_solve`` — the historical single/unpreconditioned entry point, now a
  thin wrapper over ``pcg_solve`` (kept because every caller and test reads
  its scalar ``CGResult``).
* ``exact_krr_fit`` / ``exact_krr_predict`` — Cholesky baseline.
* ``wlsh_krr_fit`` / ``wlsh_krr_predict`` — the paper's §4.2 algorithm: solve
  (K̃ + lam I) beta = y with PCG, predict via bucket loads.

The WLSH path runs entirely through ``core.operator.WLSHOperator``, so the
same solver drives the jnp reference backend, the fused Pallas kernels
(``backend='pallas'``), or platform auto-selection (``backend='auto'``).
"""
from __future__ import annotations

import warnings
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .. import obs
from ..errors import NonFiniteError, SolveDivergedError
from .bucket_fns import get_bucket_fn
from .kernels import WLSHKernelSpec
from .lsh import LSHParams, sample_lsh_params
from .operator import WLSHOperator, default_table_size, make_operator
from .precond import (DEFAULT_NYSTROM_RANK, Preconditioner, identity_precond,
                      make_preconditioner, table_diag)

Array = jnp.ndarray
MatVec = Callable[[Array], Array]


class CGResult(NamedTuple):
    x: Array
    iters: Array
    resnorm: Array


class PCGResult(NamedTuple):
    x: Array          # (n,) or (n, k) — solution block
    iters: Array      # scalar int32 — block iterations run (max over columns)
    col_iters: Array  # (k,) int32 — iteration at which each column converged
    resnorm: Array    # (k,) f32 — final per-column ||r||
    # (maxiter+1, k) per-iteration ||r_j||: row 0 is the initial residual,
    # row i the residual after block iteration i.  Rows past the final
    # iteration are NaN (static shape under jit); a deflated column's rows
    # freeze at its converged value, a deactivated column's go NaN.
    resnorm_history: Array | None = None


class SolveState(NamedTuple):
    """Serializable PCG state — everything ``pcg_solve`` needs to continue a
    solve from iteration ``it`` exactly where it left off.  Internals are
    always the 2-D block form ((n, k) even for a 1-D ``b``), so a persisted
    state round-trips through ``checkpoint/store.py`` (npz is bitwise for
    f32/int32/bool) and resumes on either calling convention."""

    x: Array          # (n, k) current iterates
    r: Array          # (n, k) residuals
    p: Array          # (n, k) search directions
    rs: Array         # (k,) ||r||² (NaN = column deactivated by a sentinel)
    rho: Array        # (k,) M⁻¹-inner products
    active: Array     # (k,) bool — still iterating
    it: Array         # scalar int32 — iterations completed
    col_iters: Array  # (k,) int32 — per-column convergence iteration


def solve_state_template(b: Array) -> SolveState:
    """Zero-filled ``SolveState`` shaped for RHS ``b`` — the restore template
    for ``checkpoint.restore_checkpoint``."""
    n = b.shape[0]
    k = 1 if b.ndim == 1 else b.shape[1]
    zk = np.zeros((k,), np.float32)
    znk = np.zeros((n, k), np.float32)
    return SolveState(x=znk, r=znk.copy(), p=znk.copy(), rs=zk,
                      rho=zk.copy(), active=np.zeros((k,), bool),
                      it=np.zeros((), np.int32),
                      col_iters=np.zeros((k,), np.int32))


def load_solve_state(directory: str, b: Array) -> SolveState | None:
    """Latest persisted ``SolveState`` under ``directory`` (None when the
    directory holds no complete checkpoint — a fresh solve)."""
    from ..checkpoint.store import latest_step, restore_checkpoint
    if latest_step(directory) is None:
        return None
    state, _, _ = restore_checkpoint(directory, solve_state_template(b))
    return jax.tree.map(jnp.asarray, state)


def pcg_solve(matvec: MatVec, b: Array, lam: float, *,
              precond: Preconditioner | None = None, tol: float = 1e-6,
              atol: float = 1e-12, maxiter: int = 200,
              x0: Array | None = None, state: SolveState | None = None,
              checkpoint_every: int = 0,
              on_checkpoint: Callable[[SolveState], None] | None = None,
              ) -> PCGResult:
    """Solve (A + lam I) X = B with preconditioned conjugate gradients.

    ``b`` is (n,) for one system or (n, k) for a RHS block; with a block the
    single matvec per iteration covers all k columns (the WLSH multi-RHS
    matvec amortizes the index walk — see WLSHOperator.matvec), and the CG
    recurrences run column-wise, so each column's trajectory is exactly the
    single-RHS trajectory it would have had alone.

    Per-column convergence when ``||r_j|| <= max(tol * ||b_j||, atol)`` —
    the absolute floor makes ``b_j = 0`` (and any exactly-solved system)
    terminate immediately instead of looping ``maxiter`` times on a zero
    threshold.  A converged column is deflated: its search direction is
    zeroed and its step sizes forced to 0, so its (x, r) freeze while the
    remaining columns iterate; the loop ends when every column is converged
    or at ``maxiter``.  All loop invariants (lam broadcast, thresholds,
    breakdown guard, preconditioner factors) are hoisted out of the
    iteration; each step costs one matvec, one preconditioner apply and
    three column-wise reductions.

    For a 1-D ``b`` the user matvec is only ever called with 1-D vectors
    (the block machinery runs on a width-1 column internally), so existing
    single-RHS matvec closures keep working unchanged.

    A column whose step goes non-finite (poisoned matvec, preconditioner
    breakdown) is deactivated BEFORE the bad update lands — its (x, r)
    freeze at the last finite iterate and its resnorm reports NaN, so the
    caller sees a sentinel instead of silent garbage while the healthy
    columns converge untouched.

    ``checkpoint_every > 0`` runs the loop in chunks of that many iterations
    and calls ``on_checkpoint(SolveState)`` after each chunk (eager mode
    only: the host loop syncs the iteration counter).  Pass a persisted
    ``state`` to resume — the trajectory continues bitwise where the saved
    chunk ended, so a preempted solve finishes within float tolerance of an
    uninterrupted one.  ``checkpoint_every = 0`` keeps the historical single
    while_loop (fully jittable).
    """
    vec = b.ndim == 1
    inner_mv = (lambda v: matvec(v[:, 0])[:, None]) if vec else matvec
    b2 = b[:, None] if vec else b
    lam = jnp.asarray(lam, b2.dtype)
    eps = jnp.asarray(1e-30, b2.dtype)           # breakdown guard, hoisted
    maxiter = int(maxiter)
    maxiter_a = jnp.asarray(maxiter, jnp.int32)
    psolve = (identity_precond() if precond is None else precond).apply

    def amv(v):
        return inner_mv(v) + lam * v

    bnorm = jnp.sqrt(jnp.sum(b2 * b2, axis=0))
    thresh = jnp.maximum(tol * bnorm, jnp.asarray(atol, b2.dtype)) ** 2

    # per-iteration residual telemetry: NaN-filled (maxiter+1, k), rows
    # written as the solve progresses — carried OUTSIDE SolveState so
    # persisted checkpoints keep their npz schema (a resumed solve records
    # from its resume row; earlier rows stay NaN)
    hist = jnp.full((maxiter + 1, b2.shape[1]), jnp.nan, b2.dtype)
    if state is None:
        if x0 is None:
            x = jnp.zeros_like(b2)
        else:
            x = x0[:, None] if vec else x0
        r = b2 - amv(x)
        z = psolve(r)
        rs = jnp.sum(r * r, axis=0)              # (k,) true residual norms²
        rho = jnp.sum(r * z, axis=0)             # (k,) M⁻¹-inner products
        active = rs > thresh
        p = jnp.where(active[None, :], z, 0.0)
        col_iters = jnp.where(active, maxiter_a, 0).astype(jnp.int32)
        state = SolveState(x=x, r=r, p=p, rs=rs, rho=rho, active=active,
                           it=jnp.asarray(0, jnp.int32),
                           col_iters=col_iters)
    hist = hist.at[state.it].set(jnp.sqrt(state.rs))
    chunk = int(checkpoint_every) if checkpoint_every > 0 else maxiter

    def cond(carry):
        steps, st, _ = carry
        return jnp.any(st.active) & (st.it < maxiter_a) & (steps < chunk)

    def body(carry):
        steps, st, hist = carry
        x, r, p, rs, rho, active, it, col_iters = st
        ap = amv(p)
        denom = jnp.sum(p * ap, axis=0)
        alpha = rho / jnp.maximum(denom, eps)
        # non-finite sentinel: a NaN/Inf step (poisoned ap, broken psolve)
        # never lands on (x, r) — the column deactivates with rs = NaN
        ok = active & jnp.isfinite(alpha)
        alpha = jnp.where(ok, alpha, 0.0)
        x = x + jnp.where(ok[None, :], alpha[None, :] * p, 0.0)
        r = r - jnp.where(ok[None, :], alpha[None, :] * ap, 0.0)
        rs = jnp.sum(r * r, axis=0)
        rs = jnp.where(active & ~ok, jnp.nan, rs)
        hist = hist.at[it + 1].set(jnp.sqrt(rs))
        # a column whose residual goes non-finite (preconditioner breakdown
        # at extreme conditioning) is deactivated instead of burning the
        # remaining iterations on NaNs; its resnorm reports the failure
        still = (rs > thresh) & jnp.isfinite(rs)
        col_iters = jnp.where(active & ~still, it + 1, col_iters)
        active = active & still
        z = psolve(r)
        rho_new = jnp.sum(r * z, axis=0)
        beta = jnp.where(active, rho_new / jnp.maximum(rho, eps), 0.0)
        # deflation: converged columns get p = 0, so alpha·p and alpha·ap
        # vanish and their (x, r) are frozen from here on
        p = jnp.where(active[None, :], z + beta[None, :] * p, 0.0)
        return steps + 1, SolveState(x, r, p, rs, rho_new, active, it + 1,
                                     col_iters), hist

    def run_chunk(st: SolveState, hist: Array):
        _, st, hist = jax.lax.while_loop(
            cond, body, (jnp.asarray(0, jnp.int32), st, hist))
        return st, hist

    if chunk >= maxiter:                         # historical one-shot path
        state, hist = run_chunk(state, hist)
        if on_checkpoint is not None:
            on_checkpoint(state)
    else:
        while True:                              # eager chunked/checkpointed
            state, hist = run_chunk(state, hist)
            if on_checkpoint is not None:
                on_checkpoint(state)             # may raise (preemption)
            if int(state.it) >= maxiter or not bool(jnp.any(state.active)):
                break
    # columns still active at maxiter report maxiter (their init value)
    resnorm = jnp.sqrt(state.rs)
    return PCGResult(x=state.x[:, 0] if vec else state.x, iters=state.it,
                     col_iters=state.col_iters, resnorm=resnorm,
                     resnorm_history=hist)


def cg_solve(matvec: MatVec, b: Array, lam: float, *, tol: float = 1e-6,
             atol: float = 1e-12, maxiter: int = 200,
             x0: Array | None = None) -> CGResult:
    """Unpreconditioned single-RHS CG — wrapper over ``pcg_solve`` returning
    the scalar-shaped ``CGResult`` the historical callers expect."""
    res = pcg_solve(matvec, b, lam, tol=tol, atol=atol, maxiter=maxiter,
                    x0=x0)
    squeeze = b.ndim == 1
    return CGResult(x=res.x,
                    iters=res.iters if not squeeze else res.col_iters[0],
                    resnorm=res.resnorm[0] if squeeze else res.resnorm)


# ---------------------------------------------------------------------------
# exact KRR (dense baseline)
# ---------------------------------------------------------------------------

def exact_krr_fit(kernel_fn, x: Array, y: Array, lam: float) -> Array:
    k = kernel_fn(x, x)
    n = x.shape[0]
    a = k + lam * jnp.eye(n, dtype=k.dtype)
    return jnp.linalg.solve(a, y)


def exact_krr_predict(kernel_fn, x_train: Array, beta: Array, x_test: Array) -> Array:
    return kernel_fn(x_test, x_train) @ beta


# ---------------------------------------------------------------------------
# WLSH approximate KRR (paper §4.2)
# ---------------------------------------------------------------------------

class WLSHKRRModel(NamedTuple):
    lsh: LSHParams
    bucket_name: str
    beta: Array           # (n,) or (n, k) PCG solution of (K̃ + lam I) b = y
    tables: Array         # (m, B[, k]) bucket loads of beta — all prediction
    table_size: int       # needs (k columns for a multi-RHS fit)
    cg_iters: Array
    cg_resnorm: Array
    backend: str = "reference"   # concrete backend the model was fit with
    precond: str = "none"        # preconditioner the solve used
    cg_col_iters: Array | None = None  # (k,) per-column iteration counts
    solve_fallback: str = ""     # nonempty when a one-shot fallback ran
                                 # (e.g. "precond:jacobi->identity")
    telemetry: dict | None = None
    # Solver telemetry captured at fit time (eager fits only; None under
    # jit and for models restored from pre-telemetry artifacts):
    #   resnorm_history — (iters+1, k) np.float32 per-iteration per-column
    #                     ||r|| (row 0 = initial residual)
    #   col_iters, iters, precond, fallback — solve summary
    # Retrievable WITHOUT refitting: it rides on the model tuple.


def model_operator(model: WLSHKRRModel, *,
                   backend: str | None = None) -> WLSHOperator:
    """Rebuild the operator a fitted model was trained with (optionally
    overriding the backend — all backends read the same tables)."""
    return make_operator(model.lsh, get_bucket_fn(model.bucket_name),
                         model.table_size,
                         backend=backend if backend is not None
                         else model.backend)


def wlsh_krr_fit(key: jax.Array, x: Array, y: Array, spec: WLSHKernelSpec, *,
                 m: int, lam: float, mode: str = "table", table_size: int = 0,
                 tol: float = 1e-5, atol: float = 1e-12, maxiter: int = 400,
                 backend: str | None = "auto", fused: bool = True,
                 precond: str = "none",
                 precond_rank: int = DEFAULT_NYSTROM_RANK,
                 nonfinite_targets: str = "raise",
                 solve_checkpoint_dir: str | None = None,
                 solve_checkpoint_every: int = 0,
                 on_solve_checkpoint=None) -> WLSHKRRModel:
    """``fused`` selects the one-pass slot-blocked matvec for the CG solve
    (default); ``fused=False`` keeps the split scatter→gather path reachable
    for A/B runs.  The fitted model (beta, tables) is identical either way —
    bitwise on the reference backend.  ``tol``/``atol`` are the PCG relative /
    absolute residual thresholds (see ``pcg_solve``).

    ``y`` is (n,) for a plain fit or (n, k) for a batched multi-RHS fit
    (k targets — e.g. the GP posterior-sample block from core/gp.py — share
    the index build and every solver matvec; see ``pcg_solve``).

    ``precond`` selects the solver preconditioner ('none' | 'jacobi' |
    'nystrom', see core/precond.py); 'nystrom' builds its rank-
    ``precond_rank`` pivoted factorization with one extra multi-RHS matvec
    before the solve and typically cuts ill-conditioned (small-lam)
    iteration counts by well over 3x.

    Resilience (DESIGN.md §9): ``nonfinite_targets`` controls what a NaN/Inf
    in ``x``/``y`` does — 'raise' (default) rejects the fit with a structured
    ``NonFiniteError`` before any compute; 'deactivate' lets the solver's
    sentinel logic freeze the poisoned columns (their resnorm reports NaN,
    beta stays finite).  A non-finite PCG residual under a non-identity
    preconditioner triggers ONE restart with the identity preconditioner
    (recorded in ``model.solve_fallback``); if beta is still non-finite the
    fit raises ``SolveDivergedError`` rather than return garbage.

    ``solve_checkpoint_dir`` persists the solver's ``SolveState`` every
    ``solve_checkpoint_every`` iterations (default maxiter//10) through
    ``checkpoint/store.py`` and RESUMES from the newest complete state in
    that directory — a preempted fit restarted with the same arguments
    continues where it left off.  ``on_solve_checkpoint`` (called after each
    persisted state) is the test hook that simulates the preemption."""
    if nonfinite_targets not in ("raise", "deactivate"):
        raise ValueError(f"nonfinite_targets must be 'raise' or "
                         f"'deactivate', got {nonfinite_targets!r}")
    if nonfinite_targets == "raise":
        for name, arr in (("x", x), ("y", y)):
            if isinstance(arr, jax.core.Tracer):
                continue                   # traced fit: host check impossible
            bad = int(jnp.sum(~jnp.isfinite(arr)))
            if bad:
                raise NonFiniteError(
                    f"{bad} non-finite value(s) in training {name}; clean "
                    f"the data or pass nonfinite_targets='deactivate'",
                    where=name, count=bad)
    n, d = x.shape
    if table_size <= 0:
        # heuristic: ~4x points per instance keeps same-slot collisions rare
        table_size = default_table_size(n)
    lsh = sample_lsh_params(key, m, d, spec.pdf, spec.lengthscale)
    op = make_operator(lsh, get_bucket_fn(spec.bucket.name), table_size,
                       backend=backend, fused=fused)
    with obs.span("fit.featurize", {"n": n, "m": m},
                  to_histogram=obs.histogram(
                      "fit_featurize_us", "featurize wall time per fit")):
        feats = op.featurize(x)

    # Prediction tables are always CountSketch (exact-mode key lookup for
    # out-of-sample points would need a hash join; the signed table is unbiased
    # and O(1) per query — see DESIGN.md §3).  In table mode the same index
    # drives CG, so it is built exactly once (the CG closure closes over the
    # slot-blocked layout when fused — the sort runs once, not per iteration).
    with obs.span("fit.build_index", {"mode": mode},
                  to_histogram=obs.histogram(
                      "fit_build_index_us", "index build wall time per fit")):
        tidx = op.build_index(feats, mode="table",
                              blocked=fused and mode == "table")
        if mode == "exact":
            eidx = op.build_index(feats, mode="exact")
    if mode == "exact":
        mv = lambda v: op.matvec(eidx, v)
        diag = jnp.mean(eidx.weight * eidx.weight, axis=0)
    elif mode == "table":
        mv = lambda v: op.matvec(tidx, v)
        diag = table_diag(tidx.coeff)
    else:
        raise ValueError(f"unknown mode {mode!r}")
    pre = make_preconditioner(precond, matvec=mv, diag=diag, lam=lam,
                              rank=precond_rank)

    state = None
    every = int(solve_checkpoint_every)
    on_ck = on_solve_checkpoint if every > 0 else None
    if solve_checkpoint_dir:
        from ..checkpoint.store import CheckpointManager
        if every <= 0:
            every = max(1, maxiter // 10)
        mgr = CheckpointManager(solve_checkpoint_dir, keep=2)
        state = load_solve_state(solve_checkpoint_dir, y)

        def on_ck(st):
            # persist FIRST, then fire the test hook: a preemption injected
            # by the hook leaves this chunk's state already on disk
            mgr.save(int(st.it), st, blocking=True)
            if on_solve_checkpoint is not None:
                on_solve_checkpoint(st)

    with obs.span("fit.pcg_solve", {"precond": precond, "maxiter": maxiter},
                  to_histogram=obs.histogram(
                      "fit_pcg_solve_us", "PCG solve wall time per fit")):
        res = pcg_solve(mv, y, lam, precond=pre, tol=tol, atol=atol,
                        maxiter=maxiter, state=state, checkpoint_every=every,
                        on_checkpoint=on_ck)
    fallback = ""
    eager = not isinstance(res.resnorm, jax.core.Tracer)
    if eager and precond not in ("none", None) \
            and not bool(jnp.all(jnp.isfinite(res.resnorm))):
        # one-shot fallback: a diverged preconditioned solve restarts once
        # with the identity preconditioner before giving up
        warnings.warn(f"PCG with precond={precond!r} went non-finite; "
                      f"restarting once with the identity preconditioner",
                      RuntimeWarning, stacklevel=2)
        obs.counter("fit_precond_fallback_total",
                    "preconditioned solves restarted with identity",
                    labels=("precond",)).labels(precond).inc()
        fallback = f"precond:{precond}->identity"
        res = pcg_solve(mv, y, lam, precond=None, tol=tol, atol=atol,
                        maxiter=maxiter)
    if eager and not bool(jnp.all(jnp.isfinite(res.x))):
        raise SolveDivergedError(
            "PCG iterates are non-finite after all fallbacks",
            resnorm=np.asarray(res.resnorm),
            fallbacks=(fallback,) if fallback else ())
    tables = op.loads(tidx, res.x)
    squeeze = y.ndim == 1
    telemetry = None
    if eager:
        # host-side solve summary + per-iteration residuals, attached to
        # the model so it is retrievable without refitting
        iters = int(res.iters)
        dead = int(jnp.sum(~jnp.isfinite(res.resnorm)))
        obs.counter("fit_solves_total", "wlsh_krr_fit solves completed").inc()
        obs.gauge("fit_pcg_iters",
                  "block iterations of the most recent fit solve").set(iters)
        obs.histogram("fit_pcg_iters_hist",
                      "distribution of PCG iteration counts per solve",
                      buckets=obs.COUNT_BUCKETS).observe(iters)
        if dead:
            obs.counter("fit_col_deactivated_total",
                        "RHS columns deactivated by non-finite sentinels"
                        ).inc(dead)
        telemetry = {
            "resnorm_history": np.asarray(
                res.resnorm_history[: iters + 1], np.float32),
            "col_iters": np.asarray(res.col_iters, np.int32),
            "iters": iters,
            "precond": precond,
            "fallback": fallback,
        }
    return WLSHKRRModel(lsh=lsh, bucket_name=spec.bucket.name, beta=res.x,
                        tables=tables, table_size=table_size,
                        cg_iters=res.col_iters[0] if squeeze else res.iters,
                        cg_resnorm=res.resnorm[0] if squeeze
                        else res.resnorm,
                        backend=op.backend, precond=precond,
                        cg_col_iters=res.col_iters,
                        solve_fallback=fallback,
                        telemetry=telemetry)


def wlsh_krr_predict(model: WLSHKRRModel, x_test: Array, *,
                     batch_size: int | None = None,
                     backend: str | None = None) -> Array:
    """Predict at x_test from the model's bucket-load tables.  ``batch_size``
    streams the test set in fixed-memory blocks (multi-million-point
    inference never materializes an (m, n_test) featurization).  A model fit
    on an (n, k) RHS block predicts all k columns at once: (n_test, k)."""
    op = model_operator(model, backend=backend)
    return op.predict_batched(model.tables, x_test, batch_size=batch_size)
