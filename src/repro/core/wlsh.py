"""WLSH estimator (paper Def. 6) — kernel matvec data structures.

Two execution modes:

* **exact** — groups equal buckets by lexicographic sort of the two 32-bit keys
  and uses ``segment_sum`` for the bucket loads.  This is the paper's estimator
  verbatim (up to 2^-64 hash collisions) and is the validation / small-scale
  path.

* **table** (CountSketch) — scatters signed loads into a dense table of size B.
  Cross-bucket collisions are sign-randomized, so the estimator stays unbiased
  and the implied kernel matrix (S Phi)(S Phi)^T stays PSD.  The dense table is
  ``psum``-able across data shards, which is what makes the method run on a
  512-chip mesh (see core/distributed.py).

Both modes expose ``matvec`` computing (1/m) sum_s K̃^s beta in O(n·m).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .bucket_fns import BucketFn
from .lsh import Features, LSHParams, featurize, slots_from_features

Array = jnp.ndarray


# ---------------------------------------------------------------------------
# exact mode: sort + segment-sum
# ---------------------------------------------------------------------------

class ExactIndex(NamedTuple):
    """Per-instance sorted bucket structure for a fixed point set."""

    perm: Array      # (m, n) int32 — sort order by (key1, key2)
    seg_id: Array    # (m, n) int32 — bucket id of sorted position (0..n-1)
    weight: Array    # (m, n) float32 — WLSH weights (unsorted order)


def build_exact_index(feats: Features) -> ExactIndex:
    def one(key1, key2):
        # lexsort: secondary key first.
        perm = jnp.lexsort((key2, key1))
        k1s, k2s = key1[perm], key2[perm]
        new_seg = jnp.concatenate([
            jnp.zeros((1,), jnp.int32),
            ((k1s[1:] != k1s[:-1]) | (k2s[1:] != k2s[:-1])).astype(jnp.int32),
        ])
        seg_id = jnp.cumsum(new_seg)
        return perm.astype(jnp.int32), seg_id.astype(jnp.int32)

    perm, seg_id = jax.vmap(one)(feats.key1, feats.key2)
    return ExactIndex(perm=perm, seg_id=seg_id, weight=feats.weight)


def _colwise(coeff: Array, v: Array) -> Array:
    """coeff ⊙ v for v of shape (n,) or (n, k) (coeff broadcast over RHS
    columns).  The single place the multi-RHS axis convention lives."""
    return coeff * v if v.ndim == 1 else coeff[:, None] * v


def exact_matvec(index: ExactIndex, beta: Array) -> Array:
    """(1/m) sum_s K̃^s beta — O(m n) (after the one-off O(m n log n) sort).
    ``beta`` is (n,) or (n, k); k right-hand sides share the sort."""
    n = beta.shape[0]

    def one(perm, seg_id, weight):
        contrib = _colwise(weight, beta)[perm]
        loads = jax.ops.segment_sum(contrib, seg_id, num_segments=n)
        out_sorted = _colwise(weight[perm], loads[seg_id])
        return jnp.zeros_like(contrib).at[perm].set(out_sorted)

    outs = jax.vmap(one)(index.perm, index.seg_id, index.weight)
    return jnp.mean(outs, axis=0)


def exact_kernel_matrix(feats: Features) -> Array:
    """Explicit K̃ = (1/m) sum_s K̃^s — O(m n^2); tests/small-n only."""
    eq = (feats.key1[:, :, None] == feats.key1[:, None, :]) & \
         (feats.key2[:, :, None] == feats.key2[:, None, :])
    ww = feats.weight[:, :, None] * feats.weight[:, None, :]
    return jnp.mean(eq * ww, axis=0)


# ---------------------------------------------------------------------------
# table (CountSketch) mode
# ---------------------------------------------------------------------------

# Default fused-kernel geometry: one point block of the sorted layout and one
# table tile.  bn = 128 keeps tile-capacity padding small (a nonempty tile
# wastes at most bn-1 layout slots); bt = 512 matches the split kernels.
BLOCKED_N = 128
BLOCKED_T = 512

# Default geometry when the layout feeds the *split* visit-list kernels
# (distributed psum path).  Their per-step cost is dominated by the one-hot
# materialization plus an HBM table-tile round trip per visit, so a narrower
# point block wins on CPU/interpret (measured 4.3x vs 2.6x at bn=128 over
# the cross-product split, n=1024).  bn = 64 is half an MXU contraction —
# on-device retuning rides the ROADMAP "TPU validation" item.
BLOCKED_SPLIT_N = 64
BLOCKED_SPLIT_T = 512


class BlockedLayout(NamedTuple):
    """Slot-blocked point layout for a fixed (point set, table geometry).

    Points of every instance are stably sorted by CountSketch slot and packed
    into ``block_n``-point blocks such that each block addresses exactly ONE
    ``block_t``-slot table tile.  A Pallas grid over the resulting visit list
    therefore only touches (point-block, table-tile) pairs that actually
    collide — O(n/bn + B/bt) tiles per instance instead of the (n/bn)·(B/bt)
    cross product.  ``L = NB·bn`` with ``NB = n//bn + ceil(B/bt)`` is the
    static layout length (tile-capacity rounding); padding slots carry
    ``coeff = 0`` so they can never perturb loads or readouts.

    Visit v of instance s processes layout block ``v_block[s, v]`` against
    tile ``v_tile[s, v]``; ``v_phase`` is 0 for the scatter pass and 1 for
    the gather pass.  Per tile, all scatter visits precede all gather visits,
    and tiles appear in ascending order, so one VMEM-resident tile serves
    both passes.  Visits past ``n_visits[s]`` re-gather the last real block
    (idempotent no-ops that keep the grid static).

    The **split** kernels (distributed psum path — the (m, B) table must
    round-trip through HBM as the scatter→psum→gather barrier) ride the same
    sort through two per-pass schedules of NB visits each instead of the
    (n/bn)·(B/bt) cross product:

    * ``vs_block``/``vs_tile`` drive ``bin_scatter_blocked_pallas``: every
      table tile is visited at least once (tiles ascending, each tile's
      visits contiguous, so the revisited HBM output tile is zeroed exactly
      once on its first visit) — tiles no point hashes into get one visit
      pairing them with the all-padding layout block, which zeroes them
      explicitly and adds nothing.
    * ``vg_tile[s, j]`` is the one tile layout block j addresses, driving
      ``bin_gather_blocked_pallas`` (every block written exactly once;
      padding blocks carry slot 0 and read tile 0 — positions never mapped
      back through ``inv_pos``).

    Each backend consumes a disjoint array group, so ``build_blocked_layout``
    gates construction on ``parts`` ('reference' | 'pallas' | 'both'); the
    unbuilt group's fields are None.
    """

    # reference (sorted segment-sum) group:
    perm: Array          # (m, n) int32 — stable argsort of slot per instance
    seg_id: Array        # (m, n) int32 — dense rank of each sorted slot
    seg_pt: Array        # (m, n) int32 — segment of original point i
    coeff_sorted: Array  # (m, n) float32 — coeff in sorted order
    # pallas (fused kernel) group:
    inv_pos: Array    # (m, n) int32 — layout position of original point i
    src: Array        # (m, L) int32 — original point per layout slot (n = pad)
    slot_lay: Array   # (m, L) int32 — CountSketch slot per layout position
    coeff_lay: Array  # (m, L) float32 — weight·sign per position (0 = pad)
    v_block: Array    # (m, V) int32 — visit -> layout block
    v_tile: Array     # (m, V) int32 — visit -> table tile
    v_phase: Array    # (m, V) int32 — 0 scatter, 1 gather
    # pallas split-kernel (per-pass) schedules, NB = n//bn + ceil(B/bt):
    vs_block: Array   # (m, NB) int32 — scatter visit -> layout block
    vs_tile: Array    # (m, NB) int32 — scatter visit -> table tile (covers
                      #   every tile at least once; ascending, contiguous)
    vg_tile: Array    # (m, NB) int32 — layout block -> its table tile
    # always present:
    n_visits: Array   # (m,) int32 — real visits (<= V = 2·(n//bn + B/bt))
    block_n: int
    block_t: int
    num_tiles: int


class TableIndex(NamedTuple):
    slot: Array    # (m, n) int32 in [0, B)
    sign: Array    # (m, n) float32
    weight: Array  # (m, n) float32
    coeff: Array   # (m, n) float32 — weight·sign, hoisted out of CG iterations
    table_size: int
    blocked: BlockedLayout | None = None


def build_table_index(feats: Features, table_size: int) -> TableIndex:
    return TableIndex(slot=slots_from_features(feats, table_size),
                      sign=feats.sign, weight=feats.weight,
                      coeff=feats.weight * feats.sign, table_size=table_size)


def build_blocked_layout(slot: Array, coeff: Array, table_size: int, *,
                         block_n: int = BLOCKED_N,
                         block_t: int = BLOCKED_T,
                         parts: str = "both") -> BlockedLayout:
    """One-off O(mn log n) construction of the slot-blocked layout.

    Pure jnp (jit/shard_map safe).  ``table_size`` need not divide
    ``block_t`` — the tile grid covers ceil(table_size / block_t) tiles and
    trailing tiles are simply never populated.  ``parts`` selects which
    backend's array group to materialize ('reference' | 'pallas' | 'both'):
    the groups are disjoint and sized O(mn)–O(mL), so a reference solve
    should not carry the kernel's visit lists through CG (and vice versa).
    """
    if parts not in ("reference", "pallas", "both"):
        raise ValueError(f"unknown parts {parts!r}")
    want_ref = parts in ("reference", "both")
    want_pal = parts in ("pallas", "both")
    m, n = slot.shape
    bn, bt = int(block_n), int(block_t)
    num_tiles = -(-int(table_size) // bt)
    # Static block budget: sum_t ceil(c_t/bn) <= n//bn + num_tiles because
    # sum floor(c_t/bn) <= n//bn and at most one partial block per tile.
    nb = n // bn + num_tiles
    layout_len = nb * bn
    n_vis = 2 * nb

    def one(slot_row, coeff_row):
        order = jnp.argsort(slot_row).astype(jnp.int32)        # stable sort
        ss = slot_row[order]
        tile = ss // bt                                        # (n,) in [0, T)

        ref_group = None
        if want_ref:
            new_seg = jnp.concatenate([
                jnp.zeros((1,), jnp.int32),
                (ss[1:] != ss[:-1]).astype(jnp.int32)])
            seg_id = jnp.cumsum(new_seg).astype(jnp.int32)
            seg_pt = jnp.zeros((n,), jnp.int32).at[order].set(seg_id)
            ref_group = (order, seg_id, seg_pt, coeff_row[order])

        counts = jnp.zeros((num_tiles,), jnp.int32).at[tile].add(1)
        kblocks = -(-counts // bn)                             # blocks per tile
        blk_start = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                     jnp.cumsum(kblocks).astype(jnp.int32)])
        total_blocks = blk_start[-1]

        pal_group = None
        if want_pal:
            # layout position of sorted point r: tile start + within-tile rank
            first_idx = jnp.searchsorted(tile, jnp.arange(num_tiles,
                                                          dtype=tile.dtype))
            rank = jnp.arange(n, dtype=jnp.int32) - \
                first_idx[tile].astype(jnp.int32)
            pos = blk_start[tile] * bn + rank
            src = jnp.full((layout_len,), n, jnp.int32).at[pos].set(order)
            slot_lay = jnp.zeros((layout_len,), jnp.int32).at[pos].set(ss)
            coeff_lay = jnp.zeros((layout_len,), jnp.float32).at[pos].set(
                coeff_row[order])
            inv_pos = jnp.zeros((n,), jnp.int32).at[order].set(pos)

            # visit list: per tile t, scatter its blocks then gather them;
            # tile t's visits fill [2·blk_start[t], 2·blk_start[t+1])
            barange = jnp.arange(nb, dtype=jnp.int32)
            block_tile = jnp.minimum(
                jnp.searchsorted(blk_start[1:], barange, side="right"),
                num_tiles - 1).astype(jnp.int32)
            q = barange - blk_start[block_tile]
            v_s = 2 * blk_start[block_tile] + q
            v_g = v_s + kblocks[block_tile]
            real = barange < total_blocks
            vs_idx = jnp.where(real, v_s, n_vis)               # OOB -> dropped
            vg_idx = jnp.where(real, v_g, n_vis)
            v_block = jnp.zeros((n_vis,), jnp.int32) \
                .at[vs_idx].set(barange, mode="drop") \
                .at[vg_idx].set(barange, mode="drop")
            v_tile = jnp.zeros((n_vis,), jnp.int32) \
                .at[vs_idx].set(block_tile, mode="drop") \
                .at[vg_idx].set(block_tile, mode="drop")
            v_phase = jnp.zeros((n_vis,), jnp.int32) \
                .at[vg_idx].set(1, mode="drop")
            # padding visits: re-gather the last real block against the
            # (still loaded) last tile — rewrites the same values, never
            # zeroes the tile
            last_b = jnp.maximum(total_blocks - 1, 0)
            pad = jnp.arange(n_vis, dtype=jnp.int32) >= 2 * total_blocks
            v_block = jnp.where(pad, last_b, v_block)
            v_tile = jnp.where(pad, block_tile[last_b], v_tile)
            v_phase = jnp.where(pad, 1, v_phase)

            # split-kernel per-pass schedules (NB visits each).  Scatter:
            # tile t owns visits [vstart[t], vstart[t+1]) with at least one
            # visit per tile — empty tiles pair with layout block nb-1,
            # which is all padding (coeff 0) whenever an empty tile exists
            # (total_blocks <= n//bn + #nonempty <= nb-1), so the visit
            # zeroes the tile's HBM output and adds nothing.
            ksched = jnp.maximum(kblocks, 1)
            vstart = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                      jnp.cumsum(ksched).astype(jnp.int32)])
            total_sched = vstart[-1]
            vj = jnp.arange(nb, dtype=jnp.int32)
            s_tile = jnp.minimum(
                jnp.searchsorted(vstart[1:], vj, side="right"),
                num_tiles - 1).astype(jnp.int32)
            q_s = vj - vstart[s_tile]
            s_block = jnp.where(counts[s_tile] > 0,
                                blk_start[s_tile] + q_s, nb - 1)
            # trailing padding visits revisit the last tile (no re-zeroing:
            # same tile as the previous visit) with the all-padding block
            pad_s = vj >= total_sched
            vs_tile = jnp.where(pad_s, num_tiles - 1, s_tile)
            vs_block = jnp.where(pad_s, nb - 1, s_block)
            # gather: block j reads its own tile exactly once; padding
            # blocks (slot_lay 0) read tile 0
            vg_tile = jnp.where(vj < total_blocks, block_tile, 0)
            pal_group = (inv_pos, src, slot_lay, coeff_lay,
                         v_block, v_tile, v_phase,
                         vs_block, vs_tile, vg_tile)
        return ref_group, pal_group, 2 * total_blocks

    ref_group, pal_group, n_visits = jax.vmap(one)(slot, coeff)
    perm, seg_id, seg_pt, coeff_sorted = ref_group or (None,) * 4
    (inv_pos, src, slot_lay, coeff_lay, v_block, v_tile, v_phase,
     vs_block, vs_tile, vg_tile) = pal_group or (None,) * 10
    return BlockedLayout(perm=perm, seg_id=seg_id, seg_pt=seg_pt,
                         coeff_sorted=coeff_sorted, inv_pos=inv_pos, src=src,
                         slot_lay=slot_lay, coeff_lay=coeff_lay,
                         v_block=v_block, v_tile=v_tile, v_phase=v_phase,
                         vs_block=vs_block, vs_tile=vs_tile, vg_tile=vg_tile,
                         n_visits=n_visits.astype(jnp.int32),
                         block_n=bn, block_t=bt, num_tiles=num_tiles)


class RouteSchedule(NamedTuple):
    """Visit schedules for the hash-join route kernels (kernels/binning).

    Built by ``build_route_schedule`` from a per-instance monotone "cell"
    array laid out along the slot-blocked layout — for the hash join the
    cell is a point's destination slot in the flat all_to_all wire buffer.

    * Pack (contributions -> shared wire buffer): the output buffer is
      shared by every instance, so the schedule is FLAT and segmented by
      destination-cell tile — ``p_inst/p_block/p_tile/p_flag`` (V,) visits
      with each tile's segment contiguous, opened by a mandatory zero visit
      (flag 1), followed by every (instance, layout block) that reaches the
      tile (flag 0), with trailing no-ops (flag 2) re-targeting the last
      tile.  Consecutive same-tile visits keep the HBM output tile resident
      (the standard Pallas revisiting contract).
    * Unpack (wire buffer -> per-instance layout): per-instance lists
      ``u_block/u_tile/u_flag`` (m, VB) — every layout block visited at
      least once (blocks with no real cells gather zero against tile 0, so
      the output block is still written), blocks in order, one visit per
      cell tile a block spans, padding flagged 2.

    V = T + m·VB and VB = L/bn + T static (T = num_cell_tiles): per-instance
    cell ranges ascend block to block, so a block spans at most one tile
    boundary more than its predecessor — the same O(n/bn + B/bt) counting
    as the split visit lists.
    """

    p_inst: Array     # (V,) int32 — flat pack schedule: instance,
    p_block: Array    #   layout block,
    p_tile: Array     #   destination cell tile,
    p_flag: Array     #   0 = accumulate, 1 = zero the tile, 2 = no-op
    u_block: Array    # (m, VB) int32 — per-instance unpack schedule
    u_tile: Array
    u_flag: Array     #   0 = compute, 2 = no-op padding
    num_cell_tiles: int
    block_t: int      # cell tile width


def build_route_schedule(cell_lay: Array, *, num_cell_tiles: int,
                         block_n: int, block_t: int) -> RouteSchedule:
    """Pure-jnp (NO sort) construction of both route-kernel schedules.

    ``cell_lay`` (m, L) int32: destination cell per slot-blocked layout
    position, with real cells NON-DECREASING along each instance's layout
    (guaranteed when cells follow the layout's slot sort — the hash-join
    routing's owner·cap + rank cells do) and the out-of-range sentinel
    ``num_cell_tiles·block_t`` on dropped/padding positions (sentinels may
    be interspersed anywhere; they produce all-zero one-hot rows in the
    kernels and are excluded from the tile-range bookkeeping here).
    """
    m, layout_len = cell_lay.shape
    bn, bt = int(block_n), int(block_t)
    lb = layout_len // bn                       # layout blocks per instance
    cb = int(num_cell_tiles)
    sentinel = cb * bt
    cells = cell_lay.reshape(m, lb, bn)
    real = cells < sentinel
    any_real = jnp.any(real, axis=2)                          # (m, LB)
    lo = jnp.min(jnp.where(real, cells, sentinel), axis=2) // bt
    hi = jnp.max(jnp.where(real, cells, -1), axis=2) // bt    # -1 if empty
    c = jnp.where(any_real, hi - lo + 1, 0).astype(jnp.int32)  # tiles/block
    lo = jnp.where(any_real, lo, 0).astype(jnp.int32)
    vb = lb + cb                                # static visits per instance
    rows = jnp.arange(m, dtype=jnp.int32)[:, None]

    def enumerate_visits(c_row, lo_row):
        """(block, tile, valid) of each visit: block b gets c_row[b]
        consecutive visits covering tiles [lo[b], lo[b] + c[b])."""
        start = jnp.cumsum(c_row) - c_row                     # exclusive
        total = start[-1] + c_row[-1]
        v = jnp.arange(vb, dtype=jnp.int32)
        b = jnp.clip(jnp.searchsorted(start, v, side="right") - 1,
                     0, lb - 1).astype(jnp.int32)
        t = (lo_row[b] + v - start[b]).astype(jnp.int32)
        return b, t, v < total

    # -- pack: flat schedule segmented by destination tile ------------------
    pb, pt, pvalid = jax.vmap(enumerate_visits)(c, lo)
    pt = jnp.where(pvalid, pt, cb - 1)          # pads sort after real tiles
    # rank of a visit among its instance's visits to the same tile: visit
    # tiles are non-decreasing per instance, so first occurrences come from
    # searchsorted against the row itself
    first = jax.vmap(lambda t_row: jnp.searchsorted(t_row, t_row,
                                                    side="left"))(pt)
    prank = jnp.arange(vb, dtype=jnp.int32)[None, :] - first.astype(jnp.int32)
    cnt = jnp.zeros((m, cb), jnp.int32).at[rows, pt].add(
        pvalid.astype(jnp.int32))
    tot = jnp.sum(cnt, axis=0)                                # (T,)
    seg_size = 1 + tot                          # zero slot + real visits
    seg_start = jnp.cumsum(seg_size) - seg_size
    inst_off = jnp.cumsum(cnt, axis=0) - cnt                  # (m, T)
    v_cap = cb + m * vb
    fp = jnp.where(pvalid,
                   seg_start[pt] + 1 + inst_off[rows, pt] + prank, v_cap)
    flat = fp.reshape(-1)
    p_inst = jnp.zeros((v_cap,), jnp.int32).at[flat].set(
        jnp.broadcast_to(rows, (m, vb)).reshape(-1), mode="drop")
    p_block = jnp.zeros((v_cap,), jnp.int32).at[flat].set(
        pb.reshape(-1), mode="drop")
    # defaults place the trailing no-ops on the last tile (idempotent)
    p_tile = jnp.full((v_cap,), cb - 1, jnp.int32).at[flat].set(
        pt.reshape(-1), mode="drop")
    p_flag = jnp.full((v_cap,), 2, jnp.int32).at[flat].set(0, mode="drop")
    p_tile = p_tile.at[seg_start].set(jnp.arange(cb, dtype=jnp.int32))
    p_flag = p_flag.at[seg_start].set(1)

    # -- unpack: per-instance, every block visited at least once ------------
    cu = jnp.maximum(c, 1)
    ub, ut, uvalid = jax.vmap(enumerate_visits)(cu, lo)
    last_t = (lo[:, -1] + cu[:, -1] - 1).astype(jnp.int32)
    ub = jnp.where(uvalid, ub, lb - 1).astype(jnp.int32)
    ut = jnp.where(uvalid, ut, last_t[:, None]).astype(jnp.int32)
    u_flag = jnp.where(uvalid, 0, 2).astype(jnp.int32)
    return RouteSchedule(p_inst=p_inst, p_block=p_block, p_tile=p_tile,
                         p_flag=p_flag, u_block=ub, u_tile=ut, u_flag=u_flag,
                         num_cell_tiles=cb, block_t=bt)


def table_loads(index: TableIndex, beta: Array) -> Array:
    """Bucket-load tables for all m instances: (m, B) for beta (n,), or
    (m, B, k) for a (n, k) RHS block (one scatter, k stacked columns)."""
    contrib = jax.vmap(_colwise, in_axes=(0, None))(index.coeff, beta)
    m = index.slot.shape[0]
    tables = jnp.zeros((m, index.table_size) + beta.shape[1:], contrib.dtype)
    rows = jnp.arange(m, dtype=jnp.int32)[:, None]
    return tables.at[rows, index.slot].add(contrib)


def table_readout(index: TableIndex, tables: Array, *,
                  average: bool = True) -> Array:
    """Per-point readout of the (possibly psum-merged) tables: (1/m) sum_s
    when ``average``, else the plain instance sum (distributed shards sum
    locally and divide by the global m after their model-axis psum).
    ``tables`` is (m, B) -> (n,) out, or (m, B, k) -> (n, k)."""
    rows = jnp.arange(index.slot.shape[0], dtype=jnp.int32)[:, None]
    vals = jax.vmap(_colwise)(index.coeff, tables[rows, index.slot])
    return jnp.mean(vals, axis=0) if average else jnp.sum(vals, axis=0)


def table_matvec(index: TableIndex, beta: Array) -> Array:
    return table_readout(index, table_loads(index, beta))


def table_matvec_fused(index: TableIndex, beta: Array, *,
                       average: bool = True) -> Array:
    """Fused table matvec via sorted segment-sum — the reference fast path.

    Reuses the blocked layout's permutation: bucket loads are segment sums
    over the slot-sorted contributions (num_segments = n, not B), so the
    (m, B) table is never materialized and the work is O(nm) independent of
    the table size.  Per iteration this is one permuted gather, one segment
    sum and one gather back through the precomputed per-point segment ids —
    every permutation-derived array (``coeff_sorted``, ``seg_pt``) is hoisted
    into the layout.  The stable sort keeps every slot's contributions in
    original point order, which makes this bitwise-identical to
    ``table_readout(table_loads(beta))`` (both lower to sequential
    scatter-adds over the same per-slot operand order).

    ``beta`` is (n,) or (n, k): a RHS block rides the same permutation and
    segment ids — one segment-sum over (n, k) rows instead of k solves'
    worth of gathers, which is what amortizes multi-RHS CG.
    """
    lay = index.blocked
    if lay is None or lay.perm is None:
        raise ValueError("fused matvec needs a slot-blocked index with the "
                         "reference group; build it with build_blocked_layout"
                         "(parts='reference'|'both') / build_index(blocked=True)")
    n = beta.shape[0]

    def one(perm, seg_id, coeff_sorted, seg_pt, coeff):
        loads = jax.ops.segment_sum(_colwise(coeff_sorted, beta[perm]), seg_id,
                                    num_segments=n)
        return _colwise(coeff, loads[seg_pt])

    outs = jax.vmap(one)(lay.perm, lay.seg_id, lay.coeff_sorted, lay.seg_pt,
                         index.coeff)
    return jnp.mean(outs, axis=0) if average else jnp.sum(outs, axis=0)


def table_kernel_matrix(index: TableIndex) -> Array:
    """Explicit CountSketch kernel matrix (tests only): PSD by construction."""
    eq = index.slot[:, :, None] == index.slot[:, None, :]
    cc = index.coeff[:, :, None] * index.coeff[:, None, :]
    return jnp.mean(eq * cc, axis=0)


# ---------------------------------------------------------------------------
# high-level estimator façade
# ---------------------------------------------------------------------------

class WLSHEstimator(NamedTuple):
    """m independent WLSH instances bound to a bucket fn; the public API."""

    params: LSHParams
    bucket_name: str
    mode: str            # 'exact' | 'table'
    table_size: int

    def featurize(self, f: BucketFn, x: Array) -> Features:
        return featurize(self.params, f, x)


def make_matvec(feats: Features, mode: str = "exact", table_size: int = 0):
    """Returns (matvec_fn, index). matvec_fn is jit-compatible and closes over
    the prebuilt index (the paper's O(dn)-preprocessing / O(n)-matvec split)."""
    if mode == "exact":
        idx = build_exact_index(feats)
        return functools.partial(exact_matvec, idx), idx
    elif mode == "table":
        if table_size <= 0:
            raise ValueError("table mode needs table_size > 0")
        idx = build_table_index(feats, table_size)
        return functools.partial(table_matvec, idx), idx
    raise ValueError(f"unknown mode {mode!r}")
