"""WLSH estimator (paper Def. 6) — kernel matvec data structures.

Two execution modes:

* **exact** — groups equal buckets by lexicographic sort of the two 32-bit keys
  and uses ``segment_sum`` for the bucket loads.  This is the paper's estimator
  verbatim (up to 2^-64 hash collisions) and is the validation / small-scale
  path.

* **table** (CountSketch) — scatters signed loads into a dense table of size B.
  Cross-bucket collisions are sign-randomized, so the estimator stays unbiased
  and the implied kernel matrix (S Phi)(S Phi)^T stays PSD.  The dense table is
  ``psum``-able across data shards, which is what makes the method run on a
  512-chip mesh (see core/distributed.py).

Both modes expose ``matvec`` computing (1/m) sum_s K̃^s beta in O(n·m).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .bucket_fns import BucketFn
from .lsh import Features, LSHParams, featurize, slots_from_features

Array = jnp.ndarray


# ---------------------------------------------------------------------------
# exact mode: sort + segment-sum
# ---------------------------------------------------------------------------

class ExactIndex(NamedTuple):
    """Per-instance sorted bucket structure for a fixed point set."""

    perm: Array      # (m, n) int32 — sort order by (key1, key2)
    seg_id: Array    # (m, n) int32 — bucket id of sorted position (0..n-1)
    weight: Array    # (m, n) float32 — WLSH weights (unsorted order)


def build_exact_index(feats: Features) -> ExactIndex:
    def one(key1, key2):
        # lexsort: secondary key first.
        perm = jnp.lexsort((key2, key1))
        k1s, k2s = key1[perm], key2[perm]
        new_seg = jnp.concatenate([
            jnp.zeros((1,), jnp.int32),
            ((k1s[1:] != k1s[:-1]) | (k2s[1:] != k2s[:-1])).astype(jnp.int32),
        ])
        seg_id = jnp.cumsum(new_seg)
        return perm.astype(jnp.int32), seg_id.astype(jnp.int32)

    perm, seg_id = jax.vmap(one)(feats.key1, feats.key2)
    return ExactIndex(perm=perm, seg_id=seg_id, weight=feats.weight)


def exact_matvec(index: ExactIndex, beta: Array) -> Array:
    """(1/m) sum_s K̃^s beta — O(m n) (after the one-off O(m n log n) sort)."""
    n = beta.shape[0]

    def one(perm, seg_id, weight):
        contrib = (beta * weight)[perm]
        loads = jax.ops.segment_sum(contrib, seg_id, num_segments=n)
        out_sorted = loads[seg_id] * weight[perm]
        return jnp.zeros_like(beta).at[perm].set(out_sorted)

    outs = jax.vmap(one)(index.perm, index.seg_id, index.weight)
    return jnp.mean(outs, axis=0)


def exact_kernel_matrix(feats: Features) -> Array:
    """Explicit K̃ = (1/m) sum_s K̃^s — O(m n^2); tests/small-n only."""
    eq = (feats.key1[:, :, None] == feats.key1[:, None, :]) & \
         (feats.key2[:, :, None] == feats.key2[:, None, :])
    ww = feats.weight[:, :, None] * feats.weight[:, None, :]
    return jnp.mean(eq * ww, axis=0)


# ---------------------------------------------------------------------------
# table (CountSketch) mode
# ---------------------------------------------------------------------------

class TableIndex(NamedTuple):
    slot: Array    # (m, n) int32 in [0, B)
    sign: Array    # (m, n) float32
    weight: Array  # (m, n) float32
    table_size: int


def build_table_index(feats: Features, table_size: int) -> TableIndex:
    return TableIndex(slot=slots_from_features(feats, table_size),
                      sign=feats.sign, weight=feats.weight, table_size=table_size)


def table_loads(index: TableIndex, beta: Array) -> Array:
    """Bucket-load tables for all m instances: (m, B)."""
    contrib = beta[None, :] * index.weight * index.sign  # (m, n)
    m = index.slot.shape[0]
    tables = jnp.zeros((m, index.table_size), contrib.dtype)
    rows = jnp.arange(m, dtype=jnp.int32)[:, None]
    return tables.at[rows, index.slot].add(contrib)


def table_readout(index: TableIndex, tables: Array, *,
                  average: bool = True) -> Array:
    """Per-point readout of the (possibly psum-merged) tables: (1/m) sum_s
    when ``average``, else the plain instance sum (distributed shards sum
    locally and divide by the global m after their model-axis psum)."""
    rows = jnp.arange(index.slot.shape[0], dtype=jnp.int32)[:, None]
    vals = tables[rows, index.slot] * index.sign * index.weight
    return jnp.mean(vals, axis=0) if average else jnp.sum(vals, axis=0)


def table_matvec(index: TableIndex, beta: Array) -> Array:
    return table_readout(index, table_loads(index, beta))


def table_kernel_matrix(index: TableIndex) -> Array:
    """Explicit CountSketch kernel matrix (tests only): PSD by construction."""
    eq = index.slot[:, :, None] == index.slot[:, None, :]
    ss = index.sign[:, :, None] * index.sign[:, None, :]
    ww = index.weight[:, :, None] * index.weight[:, None, :]
    return jnp.mean(eq * ss * ww, axis=0)


# ---------------------------------------------------------------------------
# high-level estimator façade
# ---------------------------------------------------------------------------

class WLSHEstimator(NamedTuple):
    """m independent WLSH instances bound to a bucket fn; the public API."""

    params: LSHParams
    bucket_name: str
    mode: str            # 'exact' | 'table'
    table_size: int

    def featurize(self, f: BucketFn, x: Array) -> Features:
        return featurize(self.params, f, x)


def make_matvec(feats: Features, mode: str = "exact", table_size: int = 0):
    """Returns (matvec_fn, index). matvec_fn is jit-compatible and closes over
    the prebuilt index (the paper's O(dn)-preprocessing / O(n)-matvec split)."""
    if mode == "exact":
        idx = build_exact_index(feats)
        return functools.partial(exact_matvec, idx), idx
    elif mode == "table":
        if table_size <= 0:
            raise ValueError("table mode needs table_size > 0")
        idx = build_table_index(feats, table_size)
        return functools.partial(table_matvec, idx), idx
    raise ValueError(f"unknown mode {mode!r}")
