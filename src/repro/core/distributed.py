"""Distributed WLSH-KRR: the paper's algorithm on a (pod, data, model) mesh.

Parallelization (DESIGN.md §3/§6):

* **points** are sharded over the data axes ('pod', 'data') — featurization is
  embarrassingly parallel (the LSH parameters are replicated, tiny).
* **instances** (the m independent WLSH estimators) are sharded over 'model' —
  they only interact at the final (1/m)-average.
* **bucket tables** are the only cross-shard object: each data shard scatters
  its points' signed loads into a local (m_local, B) CountSketch table, a
  single ``psum`` over the data axes merges them, and every shard reads its
  own points' loads back out.  A dense table is psum-able; the paper's
  per-bucket lists are not — that is the whole reason for the CountSketch
  adaptation.
* **CG** runs on sharded vectors; the two dot products per iteration are
  scalar psums.

All scatter/readout goes through ``core.operator.WLSHOperator`` — this module
adds only the collectives.  Each shard builds an operator from its *local*
LSH shard inside shard_map; ``loads`` produces the psum-able partial tables
and ``readout(average=False)`` the local instance-sum that the model-axis
psum completes.  Everything is expressed with ``jax.shard_map`` + ``jax.lax``
collectives; no host-side communication.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..backend import default_interpret, resolve_backend
from ..compat import shard_map
from .bucket_fns import BucketFn
from .lsh import GammaPDF, LSHParams, sample_lsh_params
from .operator import WLSHOperator
from .wlsh import build_blocked_layout
from .precond import (DEFAULT_NYSTROM_RANK, PRECOND_NAMES, jacobi_precond,
                      nystrom_precond, table_diag)

Array = jnp.ndarray


class KRRStepConfig(NamedTuple):
    m: int                 # total WLSH instances (sharded over 'model')
    table_size: int        # CountSketch table slots (power of two)
    lam: float             # ridge regularizer
    cg_iters: int          # fixed PCG iteration count fused into the step
    data_axes: tuple[str, ...] = ("data",)
    model_axis: str = "model"
    backend: str = "auto"  # operator backend inside each shard
    fused: bool = True     # one-pass local matvec when the data axes are size 1
    blocked_split: bool = True  # visit-list split kernels for the sharded
                                # psum path (pallas backend; the (m, B)
                                # tables stay in HBM so the psum is unchanged)
    precond: str = "none"  # 'none' | 'jacobi' (any mesh) | 'nystrom'
                           # (unsharded data axes only — see make_krr_step)
    precond_rank: int = DEFAULT_NYSTROM_RANK


def _shard_operator(cfg: KRRStepConfig, f: BucketFn, lsh_local: LSHParams,
                    *, fused: bool | None = None) -> WLSHOperator:
    """Per-shard operator over the local LSH slice (backend resolved at
    trace time — shard_map bodies must see a concrete choice).  ``fused``
    overrides cfg.fused: a data-sharded step passes False so a blocked
    index is built with the split kernels' geometry, not the fused one's."""
    return WLSHOperator(lsh=lsh_local, bucket=f, table_size=cfg.table_size,
                        backend=resolve_backend(cfg.backend),
                        interpret=default_interpret(),
                        fused=cfg.fused if fused is None else fused)


def _data_shard_count(mesh: Mesh, cfg: KRRStepConfig) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for a in cfg.data_axes:
        n *= sizes[a]
    return n


def make_distributed_matvec(cfg: KRRStepConfig, op: WLSHOperator, *,
                            n_data_shards: int):
    """Returns matvec(index, beta_local) -> (K~ beta)_local.

    A thin psum wrapper around the operator's local scatter/readout — must be
    called inside shard_map with an index built from the local featurization
    (m_loc, n_loc) and a (n_loc,) or (n_loc, k) beta shard (a RHS block
    rides one scatter/psum/readout round trip: the psum'd object grows to
    (m_loc, B, k) but the collective count per iteration is unchanged).
    ``n_data_shards`` is the product of the mesh's data-axis sizes
    (``_data_shard_count``) — required so a forgotten kwarg cannot silently
    disable the fused path.

    The split loads → psum → readout sandwich is required whenever the data
    axes are sharded: the table psum is the scatter→gather barrier, so the
    (m_loc, B) tables must exist between the two.  With a single data shard
    (model-parallel-only meshes) there is nothing to merge, and the fused
    one-pass matvec (slot-blocked index) runs locally with only the final
    model-axis psum.

    The split sandwich itself is still visit-list scheduled when the index
    carries the slot-blocked layout (``cfg.blocked_split``, pallas backend):
    ``op.loads``/``op.readout`` dispatch to the blocked split kernels, which
    walk only the O(n/bn + B/bt) real collisions per pass while landing the
    same psum-able (m_loc, B[, k]) tables in HBM.
    """
    local_fused = cfg.fused and n_data_shards == 1

    def matvec(index, beta_local):
        if local_fused and getattr(index, "blocked", None) is not None:
            out = op.matvec(index, beta_local, average=False)
        else:
            tables = jax.lax.psum(op.loads(index, beta_local), cfg.data_axes)
            out = op.readout(index, tables, average=False)  # sum over m_loc
        return jax.lax.psum(out, cfg.model_axis) / cfg.m
    return matvec


def _sharded_dot(a: Array, b: Array, axes: Sequence[str]) -> Array:
    """Column-wise sharded inner product: scalar for (n_loc,) operands,
    (k,) for (n_loc, k) RHS blocks — one scalar/vector psum either way."""
    return jax.lax.psum(jnp.sum(a * b, axis=0), axes)


def _bcast(c: Array, v: Array) -> Array:
    """Broadcast a per-column coefficient over v (n,) or (n, k)."""
    return c * v if v.ndim == 1 else c[None, :] * v


def cg_iterations(matvec, y_local: Array, cfg: KRRStepConfig,
                  precond_apply=None):
    """Fixed-iteration PCG on (K~ + lam I) beta = y, vectors data-sharded.
    ``y_local`` is (n_loc,) or an (n_loc, k) RHS block — the recurrences run
    column-wise so every column follows its own single-RHS trajectory while
    sharing each matvec and collective.  ``precond_apply`` (z = P⁻¹ r on
    local shards, e.g. the Jacobi diagonal from ``make_krr_step``) defaults
    to identity, which reduces exactly to plain CG.  Returns
    (beta_local, resnorm) with resnorm per column for a block."""
    lam = jnp.asarray(cfg.lam, jnp.float32)
    identity = precond_apply is None
    psolve = (lambda r: r) if identity else precond_apply

    def amv(v):
        return matvec(v) + lam * v

    def residual_dots(r, z):
        # with the identity preconditioner rho == ||r||², so plain CG keeps
        # its two psums per iteration (no third collective sneaks in)
        rs = _sharded_dot(r, r, cfg.data_axes)
        return (rs, rs) if identity else \
            (_sharded_dot(r, z, cfg.data_axes), rs)

    x = jnp.zeros_like(y_local)
    r = y_local - amv(x)
    z = psolve(r)
    p = z
    rho, rs = residual_dots(r, z)

    def body(_, state):
        x, r, p, rho, rs = state
        ap = amv(p)
        alpha = rho / jnp.maximum(_sharded_dot(p, ap, cfg.data_axes), 1e-30)
        x = x + _bcast(alpha, p)
        r = r - _bcast(alpha, ap)
        z = psolve(r)
        rho_new, rs_new = residual_dots(r, z)
        p = z + _bcast(rho_new / jnp.maximum(rho, 1e-30), p)
        return x, r, p, rho_new, rs_new

    x, r, p, rho, rs = jax.lax.fori_loop(0, cfg.cg_iters, body,
                                         (x, r, p, rho, rs))
    return x, jnp.sqrt(rs)


def _shard_preconditioner(cfg: KRRStepConfig, mv, idx):
    """Build cfg.precond inside shard_map; returns apply(r_local) or None.

    * jacobi — diag(K̃)_i = mean_s coeff²[s, i] is per-point, so the local
      column sums only need the model-axis psum; the apply is elementwise on
      the local shard (no extra collectives per iteration).
    * nystrom — needs K̃-columns for its pivot block, i.e. a global matvec
      with global one-hot columns.  With unsharded data axes the local index
      IS global (only the model psum participates), so the single-host
      factorization from core/precond.py traces directly; with sharded data
      axes pivot selection/column exchange would need a gather we don't
      ship yet, so make_krr_step rejects that combination up front.
    """
    if cfg.precond in ("none", None):
        return None
    diag = jax.lax.psum(table_diag(idx.coeff, average=False),
                        cfg.model_axis) / cfg.m
    if cfg.precond == "jacobi":
        return jacobi_precond(diag, cfg.lam).apply
    if cfg.precond == "nystrom":
        pre = nystrom_precond(lambda v: mv(idx, v), diag, cfg.lam,
                              cfg.precond_rank)
        return pre.apply
    raise ValueError(f"unknown preconditioner {cfg.precond!r}; "
                     f"expected one of {PRECOND_NAMES}")


def make_krr_step(mesh: Mesh, cfg: KRRStepConfig, f: BucketFn):
    """Builds the jit-able distributed KRR training step.

    step(x, y, lsh) -> (beta, resnorm, tables)
      x (n, d) sharded P(data_axes, None); y sharded P(data_axes) — (n,) for
      one target or (n, k) for a RHS block (batched KRR / GP posterior
      samples; the k columns share every matvec and collective)
      lsh: LSHParams with leading m dim sharded P(model_axis)
    The returned beta is sharded like y; tables (m, B[, k]) are the
    prediction data structure (model-sharded, data-replicated).

    ``cfg.precond`` runs the solve as PCG: 'jacobi' works on any mesh (its
    diagonal is a model-axis psum; the apply is shard-local); 'nystrom'
    requires unsharded data axes — its pivot columns come from global
    matvecs — and raises otherwise.
    """
    data_spec = P(cfg.data_axes)
    in_specs = (P(cfg.data_axes, None), data_spec,
                LSHParams(w=P(cfg.model_axis, None), z=P(cfg.model_axis, None),
                          r1=P(cfg.model_axis, None), r2=P(cfg.model_axis, None)))
    out_specs = (data_spec, P(), P(cfg.model_axis, None))
    n_data = _data_shard_count(mesh, cfg)
    local_fused = cfg.fused and n_data == 1
    # sharded data axes keep the split (psum-able) sandwich, but the pallas
    # scatter/gather still follow the slot-blocked visit lists when the
    # index carries the layout — only the reference split path ignores it
    want_blocked = local_fused or (
        cfg.blocked_split and resolve_backend(cfg.backend) == "pallas")
    if cfg.precond == "nystrom" and n_data != 1:
        raise ValueError(
            "precond='nystrom' needs unsharded data axes (its pivot columns "
            "are global K~ matvecs); use 'jacobi' on data-sharded meshes")

    @functools.partial(shard_map, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs)
    def step(x_local, y_local, lsh_local):
        op = _shard_operator(cfg, f, lsh_local, fused=local_fused)
        idx = op.build_index(op.featurize(x_local), blocked=want_blocked)
        mv = make_distributed_matvec(cfg, op, n_data_shards=n_data)
        pre = _shard_preconditioner(cfg, mv, idx)
        beta_local, resnorm = cg_iterations(lambda v: mv(idx, v), y_local,
                                            cfg, precond_apply=pre)
        # final prediction tables for the solved beta
        tables = jax.lax.psum(op.loads(idx, beta_local), cfg.data_axes)
        return beta_local, resnorm, tables

    return step


def make_krr_predict(mesh: Mesh, cfg: KRRStepConfig, f: BucketFn):
    """predict(x_test, lsh, tables) -> yhat; test points data-sharded."""
    in_specs = (P(cfg.data_axes, None),
                LSHParams(w=P(cfg.model_axis, None), z=P(cfg.model_axis, None),
                          r1=P(cfg.model_axis, None), r2=P(cfg.model_axis, None)),
                P(cfg.model_axis, None))
    out_specs = P(cfg.data_axes)

    @functools.partial(shard_map, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs)
    def predict(x_local, lsh_local, tables_local):
        op = _shard_operator(cfg, f, lsh_local)
        idx = op.build_index(op.featurize(x_local), blocked=False)
        out = op.readout(idx, tables_local, average=False)
        return jax.lax.psum(out, cfg.model_axis) / cfg.m

    return predict


def sample_sharded_lsh(key: jax.Array, m: int, d: int, pdf: GammaPDF,
                       lengthscale: float = 1.0) -> LSHParams:
    """Host-side LSH parameter sampling (tiny; replicate then shard)."""
    return sample_lsh_params(key, m, d, pdf, lengthscale)


# ---------------------------------------------------------------------------
# BEYOND-PAPER: hash-join table mode
# ---------------------------------------------------------------------------
#
# The psum of the (m_loc, B) CountSketch tables moves O(B) floats per CG
# iteration per chip even though each shard contributes and reads only
# O(n_local) nonzeros.  The hash join shards the TABLE over the data axes
# (each shard owns B/n_shards slots) and routes only the nonzeros:
#
#   scatter:  (slot, contrib) pairs -> owner shard  (all_to_all, ~n_local f32)
#   readout:  slot requests -> owner -> values back (all_to_all, precomputed
#             routing: slots are fixed for the whole CG solve)
#
# Collective bytes per iteration drop from m_loc*B*4 to ~2*capacity*n_local*4
# — 16x at the krr_4m cell (measured; see EXPERIMENTS.md §Perf).  Entries
# beyond the per-destination capacity are dropped (probability ~0 for
# capacity_factor >= 2 with uniform hashing; the estimator stays unbiased in
# sign expectation, and tests compare against the exact table mode).
#
# The routing is built off the slot-blocked layout's per-instance stable
# slot sort (core/wlsh.py): owner shards are slot//spp, so owner grouping
# falls out of the already-sorted slot order — no second argsort — and
# duplicate (instance, slot) pairs collapse to ONE routed cell per distinct
# bucket (contributions pre-summed by the layout's segment ids before they
# touch the wire; values broadcast back through the same ids).  The wire
# payload is the deduplicated slot set, never more than the owner's
# m_loc·spp table cells.
#
# This path's scatter/readout is NOT the operator's dense-table primitive —
# it is a different algorithm (table sharded over data, all_to_all routing),
# so only featurization/indexing is shared with the operator.

class _Routing(NamedTuple):
    useg_cell: Array   # (E,) destination cell per (instance, bucket) segment,
                       #   indexed by inst·n_loc + seg (sentinel = NB)
    usidx: Array       # (NB,) flat segment id per cell (sentinel = E)
    recv_packed: Array # (NB,) received (inst·spp + slot%spp) ids after a2a
    spp: int           # slots per shard
    cap: int           # bucket capacity per destination shard


def _routing_maps(slot: Array, lay, n_shards: int, table_size: int,
                  cap_factor: float):
    """Pure half of the routing build (no collectives — unit-lowerable):
    derive the segment <-> cell maps and per-destination slot requests from
    the layout's slot sort.  Contains NO sort: owners ascend with the
    already-sorted slots, so group starts come from ``searchsorted`` and
    in-group ranks from the layout's segment ids."""
    m_loc, n_loc = slot.shape
    e = m_loc * n_loc
    spp = table_size // n_shards
    cap = max(8, int(-(-e * cap_factor // n_shards) // 8 * 8))
    # a cell is a distinct (instance, slot) pair at its owner: never more
    # than the owner's m_loc*spp table cells (exact => dedup cannot drop)
    cap = min(cap, m_loc * spp)
    nb = n_shards * cap

    inst = jnp.arange(m_loc, dtype=jnp.int32)[:, None]
    ss = jnp.take_along_axis(slot, lay.perm, axis=1)          # sorted slots
    owner = (ss // spp).astype(jnp.int32)                     # ascending rows
    is_first = jnp.concatenate(
        [jnp.ones((m_loc, 1), bool), ss[:, 1:] != ss[:, :-1]], axis=1)
    # distinct buckets per (instance, owner) and their cross-instance offsets
    ucount = jnp.zeros((m_loc, n_shards), jnp.int32).at[inst, owner].add(
        is_first.astype(jnp.int32))
    off = jnp.cumsum(ucount, axis=0) - ucount                 # exclusive
    # rank of each distinct bucket inside its (instance, owner) group:
    # segment id minus the segment id at the owner group's first position
    fpos = jax.vmap(lambda o: jnp.searchsorted(
        o, jnp.arange(n_shards, dtype=o.dtype)))(owner)
    fpos = jnp.minimum(fpos, n_loc - 1).astype(jnp.int32)
    first_seg = jnp.take_along_axis(lay.seg_id, fpos, axis=1)  # (m, S)
    rank = lay.seg_id - first_seg[inst, owner]
    pos = off[inst, owner] + rank
    keep = is_first & (pos < cap)
    cell = jnp.where(keep, owner * cap + pos, nb)              # (m, n)
    flat_seg = inst * n_loc + lay.seg_id                       # (m, n)
    useg_cell = jnp.full((e,), nb, jnp.int32).at[
        jnp.where(keep, flat_seg, e).reshape(-1)].set(
        cell.reshape(-1), mode="drop")
    usidx = jnp.full((nb,), e, jnp.int32).at[cell.reshape(-1)].set(
        flat_seg.reshape(-1), mode="drop")
    packed = inst * spp + (ss % spp).astype(jnp.int32)
    send_packed = jnp.full((nb,), -1, jnp.int32).at[cell.reshape(-1)].set(
        packed.reshape(-1), mode="drop").reshape(n_shards, cap)
    return useg_cell, usidx, send_packed, spp, cap


def _build_routing(slot: Array, lay, n_shards: int, table_size: int,
                   data_axes, cap_factor: float) -> _Routing:
    """Precompute the segment <-> cell maps and exchange slot requests.
    slot (m_loc, n_loc); ``lay`` is the slot-blocked layout's reference
    group (perm/seg_id/seg_pt).  Runs once per CG solve (slots are fixed)."""
    useg_cell, usidx, send_packed, spp, cap = _routing_maps(
        slot, lay, n_shards, table_size, cap_factor)
    recv_packed = jax.lax.all_to_all(send_packed, data_axes, 0, 0,
                                     tiled=True).reshape(-1)
    return _Routing(useg_cell=useg_cell, usidx=usidx,
                    recv_packed=recv_packed, spp=spp, cap=cap)


def _hashjoin_loads(rt: _Routing, lay, m_loc: int, n_loc: int, data_axes,
                    beta_local: Array, payload_dtype=jnp.float32) -> Array:
    """Route the deduplicated per-bucket contribution sums to their owner
    shards and scatter-add into MY (m_loc·spp,) table shard.  One wire float
    per distinct (instance, slot) pair — the layout's segment sum collapses
    same-bucket points before the all_to_all."""
    n_shards = rt.recv_packed.shape[0] // rt.cap
    nb = n_shards * rt.cap
    contrib_sorted = lay.coeff_sorted * beta_local[lay.perm]   # (m, n)
    usum = jax.vmap(lambda c, s: jax.ops.segment_sum(
        c, s, num_segments=n_loc))(contrib_sorted, lay.seg_id)
    send_c = jnp.zeros((nb,), payload_dtype).at[rt.useg_cell].set(
        usum.reshape(-1).astype(payload_dtype), mode="drop")
    recv_c = jax.lax.all_to_all(send_c.reshape(n_shards, rt.cap), data_axes,
                                0, 0, tiled=True).reshape(-1)
    valid = rt.recv_packed >= 0
    ids = jnp.where(valid, rt.recv_packed, m_loc * rt.spp)
    return jnp.zeros((m_loc * rt.spp,), jnp.float32).at[ids].add(
        recv_c.astype(jnp.float32), mode="drop")


def _hashjoin_matvec(rt: _Routing, lay, coeff: Array, m_total: int,
                     m_loc: int, data_axes, model_axis, beta_local: Array,
                     payload_dtype=jnp.float32):
    """payload_dtype=bfloat16 halves the wire bytes; the per-bucket segment
    sums are computed in f32 and rounded once at the a2a boundary (each
    way), and the owner's cross-shard scatter-add still accumulates in f32
    — so the noise is one bf16 rounding per distinct (instance, slot) per
    hop, not per point (CG tolerates it; tests pin the accuracy).
    ``coeff`` is the index's precomputed weight·sign (m_loc, n_loc); ``lay``
    the slot-blocked layout whose sort/segments route one value per
    distinct bucket each way."""
    n_shards = rt.recv_packed.shape[0] // rt.cap
    n_loc = coeff.shape[1]
    table = _hashjoin_loads(rt, lay, m_loc, n_loc, data_axes, beta_local,
                            payload_dtype)
    # serve the (fixed) readout requests and route values back
    valid = rt.recv_packed >= 0
    vals_serve = jnp.where(valid, table[jnp.clip(rt.recv_packed, 0)],
                           0.0).astype(payload_dtype)
    back = jax.lax.all_to_all(vals_serve.reshape(n_shards, rt.cap), data_axes,
                              0, 0, tiled=True).reshape(-1)
    # one value per distinct bucket, broadcast to its points via seg_pt
    uval = jnp.zeros((coeff.size,), jnp.float32).at[rt.usidx].set(
        back.astype(jnp.float32), mode="drop").reshape(m_loc, n_loc)
    vals = jnp.take_along_axis(uval, lay.seg_pt, axis=1)
    out = jnp.sum(vals * coeff, axis=0)
    return jax.lax.psum(out, model_axis) / m_total


def make_krr_step_hashjoin(mesh: Mesh, cfg: KRRStepConfig, f: BucketFn, *,
                           cap_factor: float = 2.0,
                           payload_dtype=jnp.float32):
    """Hash-join variant of make_krr_step (same signature; returns
    (beta, resnorm, table_shard) with the table left SHARDED over data).

    The routing is derived from the slot-blocked layout's per-instance slot
    sort (owner grouping and per-bucket dedup fall out of the sorted order —
    no second sort; `tests/test_blocked_split.py` pins the op count), and
    the all_to_all payloads carry one float per distinct (instance, slot)
    pair each way.

    Single-RHS, unpreconditioned only: its scatter routes one contribution
    stream per entry, and a silently-dropped cfg.precond would leave the
    fixed cg_iters under-converged — so unsupported configs are rejected
    up front rather than ignored.
    """
    if cfg.precond not in ("none", None):
        raise ValueError("make_krr_step_hashjoin does not support "
                         "preconditioning; use make_krr_step or "
                         "precond='none'")
    n_shards = 1
    for a in cfg.data_axes:
        n_shards *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
    data_spec = P(cfg.data_axes)
    in_specs = (P(cfg.data_axes, None), data_spec,
                LSHParams(w=P(cfg.model_axis, None), z=P(cfg.model_axis, None),
                          r1=P(cfg.model_axis, None), r2=P(cfg.model_axis, None)))
    out_specs = (data_spec, P(), P(cfg.model_axis, cfg.data_axes))

    @functools.partial(shard_map, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs)
    def step(x_local, y_local, lsh_local):
        if y_local.ndim != 1:
            raise ValueError("hash-join step is single-RHS; use "
                             "make_krr_step for (n, k) target blocks")
        op = _shard_operator(cfg, f, lsh_local)
        idx = op.build_index(op.featurize(x_local), blocked=False)
        m_loc, n_loc = idx.slot.shape
        # the routing rides the slot-blocked layout's stable slot sort —
        # the ONLY sort in the step (the old path re-sorted by owner shard)
        lay = build_blocked_layout(idx.slot, idx.coeff, cfg.table_size,
                                   parts="reference")
        rt = _build_routing(idx.slot, lay, n_shards, cfg.table_size,
                            cfg.data_axes, cap_factor)
        mv = lambda v: _hashjoin_matvec(rt, lay, idx.coeff, cfg.m,
                                        m_loc, cfg.data_axes, cfg.model_axis,
                                        v, payload_dtype)
        beta_local, resnorm = cg_iterations(mv, y_local, cfg)
        # final sharded prediction table for the solved beta
        table = _hashjoin_loads(rt, lay, m_loc, n_loc, cfg.data_axes,
                                beta_local)
        return beta_local, resnorm, table.reshape(m_loc, rt.spp)

    return step
