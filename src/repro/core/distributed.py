"""Distributed WLSH-KRR: the paper's algorithm on a (pod, data, model) mesh.

Parallelization (DESIGN.md §3/§6):

* **points** are sharded over the data axes ('pod', 'data') — featurization is
  embarrassingly parallel (the LSH parameters are replicated, tiny).
* **instances** (the m independent WLSH estimators) are sharded over 'model' —
  they only interact at the final (1/m)-average.
* **bucket tables** are the only cross-shard object: each data shard scatters
  its points' signed loads into a local (m_local, B) CountSketch table, a
  single ``psum`` over the data axes merges them, and every shard reads its
  own points' loads back out.  A dense table is psum-able; the paper's
  per-bucket lists are not — that is the whole reason for the CountSketch
  adaptation.
* **CG** runs on sharded vectors; the two dot products per iteration are
  scalar psums.

All scatter/readout goes through ``core.operator.WLSHOperator`` — this module
adds only the collectives.  Each shard builds an operator from its *local*
LSH shard inside shard_map; ``loads`` produces the psum-able partial tables
and ``readout(average=False)`` the local instance-sum that the model-axis
psum completes.  Everything is expressed with ``jax.shard_map`` + ``jax.lax``
collectives; no host-side communication.
"""
from __future__ import annotations

import functools
import logging
import warnings
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from .. import obs
from ..backend import default_interpret, resolve_backend
from ..compat import shard_map
from ..errors import SolveDivergedError, WireOverflowError
from ..testing.faults import FaultPlan, apply_wire_fault, maybe_stall
from .bucket_fns import BucketFn
from .lsh import GammaPDF, LSHParams, sample_lsh_params
from .operator import WLSHOperator
from .wlsh import RouteSchedule, build_route_schedule
from .precond import (DEFAULT_NYSTROM_RANK, PRECOND_NAMES, jacobi_precond,
                      nystrom_precond, table_diag)

Array = jnp.ndarray


class KRRStepConfig(NamedTuple):
    m: int                 # total WLSH instances (sharded over 'model')
    table_size: int        # CountSketch table slots (power of two)
    lam: float             # ridge regularizer
    cg_iters: int          # fixed PCG iteration count fused into the step
    data_axes: tuple[str, ...] = ("data",)
    model_axis: str = "model"
    backend: str = "auto"  # operator backend inside each shard
    fused: bool = True     # one-pass local matvec when the data axes are size 1
    blocked_split: bool = True  # visit-list split kernels for the sharded
                                # psum path (pallas backend; the (m, B)
                                # tables stay in HBM so the psum is unchanged)
    precond: str = "none"  # 'none' | 'jacobi' (any mesh) | 'nystrom'
                           # (unsharded data axes only — see make_krr_step)
    precond_rank: int = DEFAULT_NYSTROM_RANK
    overflow: str = "warn"  # hashjoin capacity-overflow policy, enforced by
                            # check_step_stats: 'raise' | 'warn' | 'allow'
    fault_plan: FaultPlan | None = None  # test-only deterministic fault
                                         # injection (repro.testing.faults)


def _shard_operator(cfg: KRRStepConfig, f: BucketFn, lsh_local: LSHParams,
                    *, fused: bool | None = None) -> WLSHOperator:
    """Per-shard operator over the local LSH slice (backend resolved at
    trace time — shard_map bodies must see a concrete choice).  ``fused``
    overrides cfg.fused: a data-sharded step passes False so a blocked
    index is built with the split kernels' geometry, not the fused one's."""
    return WLSHOperator(lsh=lsh_local, bucket=f, table_size=cfg.table_size,
                        backend=resolve_backend(cfg.backend),
                        interpret=default_interpret(),
                        fused=cfg.fused if fused is None else fused)


def _data_shard_count(mesh: Mesh, cfg: KRRStepConfig) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for a in cfg.data_axes:
        n *= sizes[a]
    return n


def make_distributed_matvec(cfg: KRRStepConfig, op: WLSHOperator, *,
                            n_data_shards: int):
    """Returns matvec(index, beta_local) -> (K~ beta)_local.

    A thin psum wrapper around the operator's local scatter/readout — must be
    called inside shard_map with an index built from the local featurization
    (m_loc, n_loc) and a (n_loc,) or (n_loc, k) beta shard (a RHS block
    rides one scatter/psum/readout round trip: the psum'd object grows to
    (m_loc, B, k) but the collective count per iteration is unchanged).
    ``n_data_shards`` is the product of the mesh's data-axis sizes
    (``_data_shard_count``) — required so a forgotten kwarg cannot silently
    disable the fused path.

    The split loads → psum → readout sandwich is required whenever the data
    axes are sharded: the table psum is the scatter→gather barrier, so the
    (m_loc, B) tables must exist between the two.  With a single data shard
    (model-parallel-only meshes) there is nothing to merge, and the fused
    one-pass matvec (slot-blocked index) runs locally with only the final
    model-axis psum.

    The split sandwich itself is still visit-list scheduled when the index
    carries the slot-blocked layout (``cfg.blocked_split``, pallas backend):
    ``op.loads``/``op.readout`` dispatch to the blocked split kernels, which
    walk only the O(n/bn + B/bt) real collisions per pass while landing the
    same psum-able (m_loc, B[, k]) tables in HBM.
    """
    local_fused = cfg.fused and n_data_shards == 1

    def matvec(index, beta_local):
        if local_fused and getattr(index, "blocked", None) is not None:
            out = op.matvec(index, beta_local, average=False)
        else:
            tables = jax.lax.psum(op.loads(index, beta_local), cfg.data_axes)
            out = op.readout(index, tables, average=False)  # sum over m_loc
        return jax.lax.psum(out, cfg.model_axis) / cfg.m
    return matvec


def _sharded_dot(a: Array, b: Array, axes: Sequence[str]) -> Array:
    """Column-wise sharded inner product: scalar for (n_loc,) operands,
    (k,) for (n_loc, k) RHS blocks — one scalar/vector psum either way."""
    return jax.lax.psum(jnp.sum(a * b, axis=0), axes)


def _bcast(c: Array, v: Array) -> Array:
    """Broadcast a per-column coefficient over v (n,) or (n, k)."""
    return c * v if v.ndim == 1 else c[None, :] * v


def _colmask(c: Array, v: Array) -> Array:
    """Shape a per-column bool mask for a where() over v (n,) or (n, k)."""
    return c if v.ndim == 1 else c[None, :]


def cg_iterations(matvec, y_local: Array, cfg: KRRStepConfig,
                  precond_apply=None):
    """Fixed-iteration PCG on (K~ + lam I) beta = y, vectors data-sharded.
    ``y_local`` is (n_loc,) or an (n_loc, k) RHS block — the recurrences run
    column-wise so every column follows its own single-RHS trajectory while
    sharing each matvec and collective.  ``precond_apply`` (z = P⁻¹ r on
    local shards, e.g. the Jacobi diagonal from ``make_krr_step``) defaults
    to identity, which reduces exactly to plain CG.  Returns
    (beta_local, resnorm) with resnorm per column for a block.

    Non-finite sentinel: a poisoned step (NaN/Inf wire cell reaching the
    matvec, non-finite target column) deactivates its column BEFORE the bad
    update lands — (x, r) freeze at the last finite iterate and the column's
    resnorm reports NaN.  The host-side runner (``run_krr_step_resilient``)
    turns that sentinel into a bf16→f32 wire retry or a structured
    ``SolveDivergedError`` instead of silently-garbage betas."""
    lam = jnp.asarray(cfg.lam, jnp.float32)
    identity = precond_apply is None
    psolve = (lambda r: r) if identity else precond_apply

    def amv(v):
        return matvec(v) + lam * v

    def residual_dots(r, z):
        # with the identity preconditioner rho == ||r||², so plain CG keeps
        # its two psums per iteration (no third collective sneaks in)
        rs = _sharded_dot(r, r, cfg.data_axes)
        return (rs, rs) if identity else \
            (_sharded_dot(r, z, cfg.data_axes), rs)

    x = jnp.zeros_like(y_local)
    r = y_local - amv(x)
    z = psolve(r)
    rho, rs = residual_dots(r, z)
    dead = ~(jnp.isfinite(rho) & jnp.isfinite(rs))
    p = jnp.where(_colmask(~dead, z), z, 0.0)

    def body(_, state):
        x, r, p, rho, rs, dead = state
        ap = amv(p)
        alpha = rho / jnp.maximum(_sharded_dot(p, ap, cfg.data_axes), 1e-30)
        # sentinel: a non-finite step deactivates its column for good — the
        # where() both forces the step to 0 AND blocks 0·NaN from reaching x
        ok = jnp.isfinite(alpha) & ~dead
        dead = dead | ~jnp.isfinite(alpha)
        okb = _colmask(ok, p)
        alpha = jnp.where(ok, alpha, 0.0)
        x = x + jnp.where(okb, _bcast(alpha, p), 0.0)
        r = r - jnp.where(okb, _bcast(alpha, ap), 0.0)
        z = psolve(r)
        rho_new, rs_new = residual_dots(r, z)
        bad = ~(jnp.isfinite(rho_new) & jnp.isfinite(rs_new))
        dead = dead | bad
        live = ~dead
        beta = jnp.where(live, rho_new / jnp.maximum(rho, 1e-30), 0.0)
        p = jnp.where(_colmask(live, p), z + _bcast(beta, p), 0.0)
        rho = jnp.where(live, rho_new, rho)
        rs = jnp.where(live, rs_new, rs)
        return x, r, p, rho, rs, dead

    x, r, p, rho, rs, dead = jax.lax.fori_loop(0, cfg.cg_iters, body,
                                               (x, r, p, rho, rs, dead))
    return x, jnp.where(dead, jnp.nan, jnp.sqrt(rs))


def _shard_preconditioner(cfg: KRRStepConfig, mv, idx):
    """Build cfg.precond inside shard_map; returns apply(r_local) or None.

    ``mv`` may be None when the caller has already rejected 'nystrom'
    (the hash-join step does — jacobi never touches the matvec).

    * jacobi — diag(K̃)_i = mean_s coeff²[s, i] is per-point, so the local
      column sums only need the model-axis psum; the apply is elementwise on
      the local shard (no extra collectives per iteration).
    * nystrom — needs K̃-columns for its pivot block, i.e. a global matvec
      with global one-hot columns.  With unsharded data axes the local index
      IS global (only the model psum participates), so the single-host
      factorization from core/precond.py traces directly; with sharded data
      axes pivot selection/column exchange would need a gather we don't
      ship yet, so make_krr_step rejects that combination up front.
    """
    if cfg.precond in ("none", None):
        return None
    diag = jax.lax.psum(table_diag(idx.coeff, average=False),
                        cfg.model_axis) / cfg.m
    if cfg.precond == "jacobi":
        return jacobi_precond(diag, cfg.lam).apply
    if cfg.precond == "nystrom":
        pre = nystrom_precond(lambda v: mv(idx, v), diag, cfg.lam,
                              cfg.precond_rank)
        return pre.apply
    raise ValueError(f"unknown preconditioner {cfg.precond!r}; "
                     f"expected one of {PRECOND_NAMES}")


def make_krr_step(mesh: Mesh, cfg: KRRStepConfig, f: BucketFn):
    """Builds the jit-able distributed KRR training step.

    step(x, y, lsh) -> (beta, resnorm, tables)
      x (n, d) sharded P(data_axes, None); y sharded P(data_axes) — (n,) for
      one target or (n, k) for a RHS block (batched KRR / GP posterior
      samples; the k columns share every matvec and collective)
      lsh: LSHParams with leading m dim sharded P(model_axis)
    The returned beta is sharded like y; tables (m, B[, k]) are the
    prediction data structure (model-sharded, data-replicated).

    ``cfg.precond`` runs the solve as PCG: 'jacobi' works on any mesh (its
    diagonal is a model-axis psum; the apply is shard-local); 'nystrom'
    requires unsharded data axes — its pivot columns come from global
    matvecs — and raises otherwise.
    """
    data_spec = P(cfg.data_axes)
    in_specs = (P(cfg.data_axes, None), data_spec,
                LSHParams(w=P(cfg.model_axis, None), z=P(cfg.model_axis, None),
                          r1=P(cfg.model_axis, None), r2=P(cfg.model_axis, None)))
    out_specs = (data_spec, P(), P(cfg.model_axis, None))
    n_data = _data_shard_count(mesh, cfg)
    local_fused = cfg.fused and n_data == 1
    # sharded data axes keep the split (psum-able) sandwich, but the pallas
    # scatter/gather still follow the slot-blocked visit lists when the
    # index carries the layout — only the reference split path ignores it
    want_blocked = local_fused or (
        cfg.blocked_split and resolve_backend(cfg.backend) == "pallas")
    if cfg.precond == "nystrom" and n_data != 1:
        raise ValueError(
            "precond='nystrom' needs unsharded data axes (its pivot columns "
            "are global K~ matvecs); use 'jacobi' on data-sharded meshes")

    @functools.partial(shard_map, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs)
    def step(x_local, y_local, lsh_local):
        op = _shard_operator(cfg, f, lsh_local, fused=local_fused)
        idx = op.build_index(op.featurize(x_local), blocked=want_blocked)
        mv = make_distributed_matvec(cfg, op, n_data_shards=n_data)
        pre = _shard_preconditioner(cfg, mv, idx)
        beta_local, resnorm = cg_iterations(lambda v: mv(idx, v), y_local,
                                            cfg, precond_apply=pre)
        # final prediction tables for the solved beta
        tables = jax.lax.psum(op.loads(idx, beta_local), cfg.data_axes)
        return beta_local, resnorm, tables

    return step


def make_krr_predict(mesh: Mesh, cfg: KRRStepConfig, f: BucketFn):
    """predict(x_test, lsh, tables) -> yhat; test points data-sharded.

    The index is built with the same ``want_blocked``/``local_fused`` logic
    as ``make_krr_step`` — a pallas-backend predict gathers through the
    visit-list kernels off the slot-blocked layout instead of falling back
    to the cross-product gather the train step abandoned (the old
    ``blocked=False`` hardcode).  Reference-backend prediction still skips
    the layout: its readout never consults it, so the sort would be wasted.
    """
    n_data = _data_shard_count(mesh, cfg)
    local_fused = cfg.fused and n_data == 1
    want_blocked = (local_fused or cfg.blocked_split) and \
        resolve_backend(cfg.backend) == "pallas"
    in_specs = (P(cfg.data_axes, None),
                LSHParams(w=P(cfg.model_axis, None), z=P(cfg.model_axis, None),
                          r1=P(cfg.model_axis, None), r2=P(cfg.model_axis, None)),
                P(cfg.model_axis, None))
    out_specs = P(cfg.data_axes)

    @functools.partial(shard_map, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs)
    def predict(x_local, lsh_local, tables_local):
        op = _shard_operator(cfg, f, lsh_local, fused=local_fused)
        idx = op.build_index(op.featurize(x_local), blocked=want_blocked)
        out = op.readout(idx, tables_local, average=False)
        return jax.lax.psum(out, cfg.model_axis) / cfg.m

    return predict


def sample_sharded_lsh(key: jax.Array, m: int, d: int, pdf: GammaPDF,
                       lengthscale: float = 1.0) -> LSHParams:
    """Host-side LSH parameter sampling (tiny; replicate then shard)."""
    return sample_lsh_params(key, m, d, pdf, lengthscale)


# ---------------------------------------------------------------------------
# BEYOND-PAPER: hash-join table mode
# ---------------------------------------------------------------------------
#
# The psum of the (m_loc, B) CountSketch tables moves O(B) floats per CG
# iteration per chip even though each shard contributes and reads only
# O(n_local) nonzeros.  The hash join shards the TABLE over the data axes
# (each shard owns B/n_shards slots) and routes only the nonzeros:
#
#   scatter:  (slot, contrib) pairs -> owner shard  (all_to_all, ~n_local f32)
#   readout:  slot requests -> owner -> values back (all_to_all, precomputed
#             routing: slots are fixed for the whole CG solve)
#
# Collective bytes per iteration drop from m_loc*B*4 to ~2*capacity*n_local*4
# — 16x at the krr_4m cell (measured; see EXPERIMENTS.md §Perf).  Entries
# beyond the per-destination capacity are dropped (probability ~0 for
# capacity_factor >= 2 with uniform hashing; the estimator stays unbiased in
# sign expectation, and tests compare against the exact table mode).
#
# The routing is built off the slot-blocked layout's per-instance stable
# slot sort (core/wlsh.py): owner shards are slot//spp, so owner grouping
# falls out of the already-sorted slot order — no second argsort — and
# duplicate (instance, slot) pairs collapse to ONE routed cell per distinct
# bucket (contributions pre-summed by the layout's segment ids before they
# touch the wire; values broadcast back through the same ids).  The wire
# payload is the deduplicated slot set, never more than the owner's
# m_loc·spp table cells.
#
# This path's scatter/readout is NOT the operator's dense-table primitive —
# it is a different algorithm (table sharded over data, all_to_all routing),
# so only featurization/indexing is shared with the operator.

class _RoutePlan(NamedTuple):
    """Pallas route-kernel driver: destination cells along the slot-blocked
    layout plus the pack/unpack visit schedules (core.wlsh.RouteSchedule)."""
    cell_lay: Array    # (m, L) int32 — wire cell per layout position
                       #   (sentinel = num_cell_tiles·block_t)
    sched: RouteSchedule


class _Routing(NamedTuple):
    pt_cell: Array     # (m_loc, n_loc) destination wire cell per point (its
                       #   bucket's cell at the owner; sentinel NB = dropped)
    recv_ids: Array    # (NB,) owner-side (inst·spp + slot%spp) table ids per
                       #   received cell (sentinel m_loc·spp = empty cell)
    serve_map: Array   # (n_shards, NB) flat recv positions holding each wire
                       #   cell's table id in sender run r (sentinel NB =
                       #   absent) — the per-iteration serve is s gathers
                       #   through this map instead of a table scatter+gather
    spp: int           # slots per shard
    cap: int           # bucket capacity per destination shard
    dropped: Array     # scalar int32 — distinct buckets past capacity on
                       #   THIS shard (overflow accounting, same pack pass)
    plan: _RoutePlan | None = None   # pallas backends only


def _routing_maps(slot: Array, lay, n_shards: int, table_size: int,
                  cap_factor: float):
    """Pure half of the routing build (no collectives — unit-lowerable):
    derive the segment <-> cell maps and per-destination slot requests from
    the layout's slot sort.  Contains NO sort: owners ascend with the
    already-sorted slots, so group starts come from ``searchsorted`` and
    in-group ranks from the layout's segment ids."""
    m_loc, n_loc = slot.shape
    e = m_loc * n_loc
    spp = table_size // n_shards
    cap = max(8, int(-(-e * cap_factor // n_shards) // 8 * 8))
    # a cell is a distinct (instance, slot) pair at its owner: never more
    # than the owner's m_loc*spp table cells (exact => dedup cannot drop)
    cap = min(cap, m_loc * spp)
    nb = n_shards * cap

    inst = jnp.arange(m_loc, dtype=jnp.int32)[:, None]
    ss = jnp.take_along_axis(slot, lay.perm, axis=1)          # sorted slots
    owner = (ss // spp).astype(jnp.int32)                     # ascending rows
    is_first = jnp.concatenate(
        [jnp.ones((m_loc, 1), bool), ss[:, 1:] != ss[:, :-1]], axis=1)
    # distinct buckets per (instance, owner) and their cross-instance offsets
    ucount = jnp.zeros((m_loc, n_shards), jnp.int32).at[inst, owner].add(
        is_first.astype(jnp.int32))
    off = jnp.cumsum(ucount, axis=0) - ucount                 # exclusive
    # rank of each distinct bucket inside its (instance, owner) group:
    # segment id minus the segment id at the owner group's first position
    fpos = jax.vmap(lambda o: jnp.searchsorted(
        o, jnp.arange(n_shards, dtype=o.dtype)))(owner)
    fpos = jnp.minimum(fpos, n_loc - 1).astype(jnp.int32)
    first_seg = jnp.take_along_axis(lay.seg_id, fpos, axis=1)  # (m, S)
    rank = lay.seg_id - first_seg[inst, owner]
    pos = off[inst, owner] + rank
    keep = is_first & (pos < cap)
    # overflow accounting rides the SAME pack pass: every distinct bucket
    # whose in-owner rank fell past the capacity is a dropped contribution
    dropped = jnp.sum(is_first & (pos >= cap), dtype=jnp.int32)
    # build-time load observability: distinct cells bound for each owner
    # (summed over my local instances) — max vs cap is the headroom signal
    owner_max = jnp.max(jnp.sum(ucount, axis=0)).astype(jnp.int32)
    cell = jnp.where(keep, owner * cap + pos, nb)              # (m, n)
    flat_seg = inst * n_loc + lay.seg_id                       # (m, n)
    useg_cell = jnp.full((e,), nb, jnp.int32).at[
        jnp.where(keep, flat_seg, e).reshape(-1)].set(
        cell.reshape(-1), mode="drop")
    # broadcast each bucket's cell back to its points: pt_cell is the ONLY
    # per-iteration map — route-pack scatter-adds contributions through it
    # (the bucket segment-sum happens inside the scatter-add) and
    # route-unpack gathers received values back through it
    pt_cell = useg_cell[inst * n_loc + lay.seg_pt]             # (m, n)
    packed = inst * spp + (ss % spp).astype(jnp.int32)
    send_packed = jnp.full((nb,), -1, jnp.int32).at[cell.reshape(-1)].set(
        packed.reshape(-1), mode="drop").reshape(n_shards, cap)
    return pt_cell, send_packed, spp, cap, dropped, owner_max


# destination-cell tile width for the route kernels (matches the table tile
# width of the binning kernels; cells are wire positions, not table slots)
ROUTE_BLOCK_T = 512

_LOG = logging.getLogger("repro.distributed")


def _log_routing_build(owner_max, *, cap: int, n_shards: int) -> None:
    over = int(owner_max) > cap
    _LOG.log(logging.WARNING if over else logging.INFO,
             "hashjoin routing: max %d cells/owner vs capacity %d "
             "(%d shard(s))%s", int(owner_max), cap, n_shards,
             " — OVERFLOW, distinct buckets will be dropped" if over else "")
    # runs via jax.debug.callback with CONCRETE values at execution time —
    # the capacity-headroom signal on the live endpoint, not just the log
    obs.counter("hashjoin_routing_builds_total",
                "hash-join routing tables built").inc()
    obs.gauge("hashjoin_route_cap",
              "per-owner cell capacity of the last routing build").set(cap)
    obs.gauge("hashjoin_route_owner_max",
              "max observed cells/owner in the last routing build"
              ).set(int(owner_max))


def _make_route_plan(pt_cell: Array, lay, nb: int) -> _RoutePlan:
    """Lay the per-point wire cells out along the slot-blocked layout and
    build the pack/unpack visit schedules.  Cells ascend with the layout's
    slot sort (owner, then in-owner rank), which is exactly the monotonicity
    ``build_route_schedule`` needs; dropped points and padding positions map
    to the kernels' out-of-range sentinel."""
    m_loc, n_loc = pt_cell.shape
    cb = -(-nb // ROUTE_BLOCK_T)
    sentinel = cb * ROUTE_BLOCK_T
    rows = jnp.arange(m_loc, dtype=jnp.int32)[:, None]
    ptc_pad = jnp.concatenate(
        [pt_cell, jnp.full((m_loc, 1), nb, jnp.int32)], axis=1)
    cell_lay = ptc_pad[rows, lay.src]                          # (m, L)
    cell_lay = jnp.where(cell_lay < nb, cell_lay, sentinel).astype(jnp.int32)
    sched = build_route_schedule(cell_lay, num_cell_tiles=cb,
                                 block_n=lay.block_n, block_t=ROUTE_BLOCK_T)
    return _RoutePlan(cell_lay=cell_lay, sched=sched)


def _build_routing(slot: Array, lay, n_shards: int, table_size: int,
                   data_axes, cap_factor: float, *,
                   kernels: bool = False) -> _Routing:
    """Precompute the point <-> wire-cell maps and exchange slot requests.
    slot (m_loc, n_loc); ``lay`` is the slot-blocked layout (reference
    group; plus the pallas group when ``kernels`` asks for the route-kernel
    schedules).  Runs once per CG solve (slots are fixed).

    The max observed cells-per-owner is logged at build time (INFO on the
    ``repro.distributed`` logger) — the headroom signal for ``cap_factor``
    tuning, surfaced BEFORE any overflow silently drops mass."""
    pt_cell, send_packed, spp, cap, dropped, owner_max = _routing_maps(
        slot, lay, n_shards, table_size, cap_factor)
    jax.debug.callback(functools.partial(_log_routing_build, cap=cap,
                                         n_shards=n_shards), owner_max)
    recv_packed = jax.lax.all_to_all(send_packed, data_axes, 0, 0,
                                     tiled=True).reshape(-1)
    m_loc = slot.shape[0]
    recv_ids = jnp.where(recv_packed >= 0, recv_packed,
                         m_loc * spp).astype(jnp.int32)
    # serve map: each sender run of recv_ids is sorted (instance-major,
    # slot-ascending pack order; sentinels trail), so the position of any
    # table id inside run r is one searchsorted — NO sort, and the
    # per-iteration segment-sum across runs becomes s vectorized gathers
    # (XLA CPU scatters are scalar loops; this was the iteration hot spot)
    nb = n_shards * cap
    ids2 = recv_ids.reshape(n_shards, cap)
    pos = jax.vmap(lambda row: jnp.searchsorted(row, recv_ids))(ids2)
    pos = jnp.minimum(pos, cap - 1).astype(jnp.int32)
    hit = (jnp.take_along_axis(ids2, pos, axis=1) == recv_ids[None]) \
        & (recv_ids < m_loc * spp)[None]
    serve_map = jnp.where(
        hit, jnp.arange(n_shards, dtype=jnp.int32)[:, None] * cap + pos, nb)
    plan = _make_route_plan(pt_cell, lay, nb) if kernels else None
    return _Routing(pt_cell=pt_cell, recv_ids=recv_ids, serve_map=serve_map,
                    spp=spp, cap=cap, dropped=dropped, plan=plan)


def _hashjoin_send(rt: _Routing, lay, coeff: Array, beta_local: Array,
                   payload_dtype, interpret: bool,
                   plan: FaultPlan | None = None) -> Array:
    """Route pack: per-point contributions -> (n_shards, cap[, k]) payload.

    One flat scatter-add through ``pt_cell`` (flat-XLA fallback) or one
    Pallas route-pack kernel call (``rt.plan``) — the per-bucket segment
    sum happens inside the cell accumulation, so the old per-iteration
    vmap'd ``segment_sum`` + cell scatter pair collapses into one op.
    Cast to the wire dtype happens once, after the f32 accumulation.
    ``plan`` (tests only) drops/poisons wire cells AFTER the cast — the
    fault rides the all_to_all exactly as a flaky link would inject it."""
    multi = beta_local.ndim == 2
    tail = beta_local.shape[1:]
    nb = rt.recv_ids.shape[0]
    n_shards = nb // rt.cap
    if rt.plan is None:
        contrib = (coeff[:, :, None] * beta_local[None] if multi
                   else coeff * beta_local[None, :])
        # dropped/overflow points carry the sentinel cell id nb — out of
        # bounds for the (nb,) buffer, so mode="drop" discards them without
        # the extra sentinel row + [:nb] slice pass over the wire buffer
        send = jnp.zeros((nb,) + tail, jnp.float32).at[
            rt.pt_cell.reshape(-1)].add(
            contrib.reshape((-1,) + tail), mode="drop")
    else:
        from ..kernels.binning import route_pack_pallas
        sched = rt.plan.sched
        # lay.src sentinel (== n_loc) is out of bounds -> pad rows read 0
        beta_lay = jnp.asarray(beta_local, jnp.float32).at[
            lay.src].get(mode="fill", fill_value=0)
        if multi:
            beta_lay = jnp.swapaxes(beta_lay, 1, 2)            # (m, k, L)
            contrib_lay = lay.coeff_lay[:, None, :] * beta_lay
        else:
            contrib_lay = lay.coeff_lay * beta_lay
        packed = route_pack_pallas(
            sched.p_inst, sched.p_block, sched.p_tile, sched.p_flag,
            rt.plan.cell_lay, contrib_lay,
            num_cell_tiles=sched.num_cell_tiles, block_n=lay.block_n,
            block_t=sched.block_t, interpret=interpret)
        send = packed[:, :nb].T if multi else packed[0, :nb]
    wire = send.astype(payload_dtype).reshape((n_shards, rt.cap) + tail)
    return apply_wire_fault(plan, wire)


def _hashjoin_loads(rt: _Routing, lay, coeff: Array, beta_local: Array,
                    data_axes, m_loc: int, payload_dtype,
                    interpret: bool,
                    plan: FaultPlan | None = None) -> tuple[Array, Array]:
    """Pack + all_to_all + owner scatter-add: MY (m_loc·spp[, k]) f32 table
    shard.  One wire value per distinct (instance, slot) pair; empty cells
    carry the sentinel id and are dropped by the scatter.

    Returns ``(table, nonfinite)``: non-finite received cells are ZEROED
    before they can poison a table slot (a NaN slot would NaN every future
    prediction touching it) and counted — the count feeds ``StepStats`` so
    the policy layer can warn/raise instead of serving silently-wrong
    loads."""
    tail = beta_local.shape[1:]
    nb = rt.recv_ids.shape[0]
    send = _hashjoin_send(rt, lay, coeff, beta_local, payload_dtype,
                          interpret, plan)
    recv = jax.lax.all_to_all(send, data_axes, 0, 0, tiled=True)
    recv_flat = recv.reshape((nb,) + tail).astype(jnp.float32)
    finite = jnp.isfinite(recv_flat)
    nonfinite = jnp.sum(~finite, dtype=jnp.int32)
    recv_flat = jnp.where(finite, recv_flat, 0.0)
    table = jnp.zeros((m_loc * rt.spp,) + tail, jnp.float32).at[
        rt.recv_ids].add(recv_flat, mode="drop")
    return table, nonfinite


def _hashjoin_readout(rt: _Routing, lay, coeff: Array, table: Array,
                      data_axes, model_axis, m_total: int, payload_dtype,
                      interpret: bool,
                      plan: FaultPlan | None = None) -> Array:
    """Serve the fixed slot requests from my table shard, all_to_all the
    values back, and unpack (``_hashjoin_return``).  This is the
    materialized-table path — prediction against a stored shard.  The
    return hop sanitizes non-finite wire cells (``sanitize=True``): a
    poisoned prediction exchange degrades to dropped bucket mass, it never
    emits a NaN prediction."""
    # recv_ids sentinel (== m_loc·spp) is out of bounds -> empty wire cells
    # serve 0, with no per-iteration sentinel-row concat over the table
    served = table.at[rt.recv_ids].get(mode="fill", fill_value=0)
    return _hashjoin_return(rt, lay, coeff, served, data_axes, model_axis,
                            m_total, payload_dtype, interpret, plan=plan,
                            sanitize=True)


def _hashjoin_return(rt: _Routing, lay, coeff: Array, served: Array,
                     data_axes, model_axis, m_total: int, payload_dtype,
                     interpret: bool, plan: FaultPlan | None = None,
                     sanitize: bool = False) -> Array:
    """all_to_all the served (NB[, k]) wire-cell values back and unpack:
    out = psum_model(sum_s coeff · back[pt_cell]) / m.  The unpack is one
    flat gather + coeff reduce (flat-XLA) or one Pallas route-unpack kernel
    call; dropped cells gather 0 both ways.

    ``sanitize`` zeroes non-finite received cells (prediction path: a fault
    degrades to dropped mass).  The CG matvec path leaves them in — the
    solver's residual sentinel is the detection signal there, and zeroing
    would hide the divergence."""
    multi = served.ndim == 2
    tail = served.shape[1:]
    nb = rt.recv_ids.shape[0]
    n_shards = nb // rt.cap
    m_loc = coeff.shape[0]
    wire = apply_wire_fault(
        plan, served.astype(payload_dtype).reshape((n_shards, rt.cap) + tail))
    back = jax.lax.all_to_all(wire, data_axes, 0, 0, tiled=True)
    back_flat = back.reshape((nb,) + tail).astype(jnp.float32)
    if sanitize:
        back_flat = jnp.where(jnp.isfinite(back_flat), back_flat, 0.0)
    if rt.plan is None:
        # pt_cell sentinel (== nb) out of bounds -> dropped points read 0
        vals = back_flat.at[rt.pt_cell].get(
            mode="fill", fill_value=0)                         # (m, n[, k])
        contrib = coeff[:, :, None] * vals if multi else coeff * vals
        out = jnp.sum(contrib, axis=0)
    else:
        from ..kernels.binning import route_unpack_pallas
        sched = rt.plan.sched
        cbbt = sched.num_cell_tiles * sched.block_t
        buf = jnp.pad(back_flat, ((0, cbbt - nb),) + ((0, 0),) * len(tail))
        buf = buf.T if multi else buf[None]                    # (1|k, CBbt)
        out_lay = route_unpack_pallas(
            sched.u_block, sched.u_tile, sched.u_flag, rt.plan.cell_lay,
            lay.coeff_lay, buf, block_n=lay.block_n, block_t=sched.block_t,
            interpret=interpret)
        rows = jnp.arange(m_loc, dtype=jnp.int32)[:, None]
        if multi:
            if out_lay.ndim == 2:                              # k == 1
                out_lay = out_lay[:, None, :]
            out = jnp.swapaxes(out_lay, 1, 2)[rows, lay.inv_pos].sum(axis=0)
        else:
            out = out_lay[rows, lay.inv_pos].sum(axis=0)
    return jax.lax.psum(out, model_axis) / m_total


def _hashjoin_matvec(rt: _Routing, lay, coeff: Array, m_total: int,
                     data_axes, model_axis, beta_local: Array,
                     payload_dtype, interpret: bool,
                     plan: FaultPlan | None = None):
    """One hash-join K~ matvec: pack -> a2a -> serve -> a2a -> unpack ->
    model psum.  The serve never materializes the owner's table: each wire
    cell's aggregate is the cross-run segment-sum of the received payloads,
    read through the precomputed ``serve_map`` as s vectorized gathers
    (the table scatter-add runs ONCE per solve, for the returned prediction
    table — not per iteration).  payload_dtype=bfloat16 halves the wire
    bytes; contributions accumulate in f32 and round ONCE at each a2a
    boundary — noise is one bf16 rounding per distinct (instance, slot) per
    hop, not per point (CG tolerates it; tests pin the accuracy).  ``coeff``
    is the index's precomputed weight·sign (m_loc, n_loc)."""
    tail = beta_local.shape[1:]
    nb = rt.recv_ids.shape[0]
    send = _hashjoin_send(rt, lay, coeff, beta_local, payload_dtype,
                          interpret, plan)
    recv = jax.lax.all_to_all(send, data_axes, 0, 0, tiled=True)
    recv_flat = recv.reshape((nb,) + tail).astype(jnp.float32)
    served = recv_flat.at[rt.serve_map[0]].get(mode="fill", fill_value=0)
    for r in range(1, rt.serve_map.shape[0]):
        served = served + recv_flat.at[rt.serve_map[r]].get(
            mode="fill", fill_value=0)
    return _hashjoin_return(rt, lay, coeff, served, data_axes, model_axis,
                            m_total, payload_dtype, interpret)


def _hashjoin_layout_parts(backend: str) -> str:
    """The routing build consumes the layout's reference group; the route
    kernels additionally need the pallas group (src/coeff_lay/inv_pos)."""
    return "both" if backend == "pallas" else "reference"


class StepStats(NamedTuple):
    """Global fault counters from one hash-join step, psum'd over every mesh
    axis (replicated — tiny int32 scalars).  ``check_step_stats`` turns them
    into the configured policy action on the host."""

    overflow_dropped: Array   # distinct buckets dropped past routing capacity
    wire_nonfinite: Array     # non-finite wire cells zeroed in the final
                              # (f32) table exchange


OVERFLOW_POLICIES = ("raise", "warn", "allow")


def check_step_stats(stats: StepStats, *, overflow: str = "warn") -> None:
    """Host-side policy gate for a completed hash-join step (raising inside
    the traced step is impossible — the counters come out as outputs).

    overflow='raise' turns dropped buckets OR zeroed non-finite wire cells
    into a structured ``WireOverflowError``; 'warn' warns once per call;
    'allow' documents that dropped mass is acceptable (the estimator stays
    unbiased in sign expectation — see the hash-join module comment)."""
    if overflow not in OVERFLOW_POLICIES:
        raise ValueError(f"overflow must be one of {OVERFLOW_POLICIES}, "
                         f"got {overflow!r}")
    dropped = int(np.asarray(stats.overflow_dropped))
    nonfinite = int(np.asarray(stats.wire_nonfinite))
    # StepStats re-expressed on the registry: the NamedTuple stays the
    # step's API, the counters make the faults scrapeable across steps
    obs.counter("hashjoin_steps_checked_total",
                "hash-join steps run through the fault-policy gate").inc()
    if dropped:
        obs.counter("hashjoin_overflow_dropped_total",
                    "distinct buckets dropped past routing capacity"
                    ).inc(dropped)
    if nonfinite:
        obs.counter("hashjoin_wire_nonfinite_total",
                    "non-finite wire cells zeroed in table exchanges"
                    ).inc(nonfinite)
    if dropped == 0 and nonfinite == 0:
        return
    msg = (f"hashjoin step dropped {dropped} distinct bucket(s) past the "
           f"routing capacity and zeroed {nonfinite} non-finite wire "
           f"cell(s); raise cap_factor or investigate the payload")
    if overflow == "raise":
        raise WireOverflowError(msg, dropped=dropped)
    if overflow == "warn":
        warnings.warn(msg, RuntimeWarning, stacklevel=2)


def run_krr_step_resilient(mesh: Mesh, cfg: KRRStepConfig, f: BucketFn,
                           x, y, lsh, *, cap_factor: float = 2.0,
                           payload_dtype=jnp.bfloat16):
    """Run the hash-join step with the full recovery ladder (DESIGN.md §9):

    1. execute with the configured wire dtype,
    2. apply the ``cfg.overflow`` policy to the step's fault counters,
    3. on a non-finite solve (NaN resnorm sentinel from ``cg_iterations``)
       retry ONCE with an f32 wire — bf16's coarser grid is the usual
       suspect and the retry costs one extra step execution,
    4. still non-finite → structured ``SolveDivergedError`` (never return
       silently-garbage betas).

    Returns (beta, resnorm, table, stats) like ``make_krr_step_hashjoin``.
    Host-side by construction (the policy check syncs the counters), so use
    it from drivers — not inside jit."""
    step = jax.jit(make_krr_step_hashjoin(mesh, cfg, f,
                                          cap_factor=cap_factor,
                                          payload_dtype=payload_dtype))
    with obs.span("dist.krr_step", {"wire": jnp.dtype(payload_dtype).name},
                  to_histogram=obs.histogram(
                      "dist_krr_step_us",
                      "resilient hash-join step wall time")):
        beta, resnorm, table, stats = step(x, y, lsh)
        jax.block_until_ready(resnorm)
    check_step_stats(stats, overflow=cfg.overflow)
    retried = False
    if not bool(jnp.all(jnp.isfinite(resnorm))):
        if payload_dtype == jnp.bfloat16:
            warnings.warn("non-finite CG residual on the bf16 wire; "
                          "retrying once with an f32 wire",
                          RuntimeWarning, stacklevel=2)
            obs.counter("dist_wire_retry_total",
                        "bf16 wire solves retried on an f32 wire").inc()
            retried = True
            step32 = jax.jit(make_krr_step_hashjoin(
                mesh, cfg, f, cap_factor=cap_factor,
                payload_dtype=jnp.float32))
            with obs.span("dist.krr_step", {"wire": "float32"}):
                beta, resnorm, table, stats = step32(x, y, lsh)
                jax.block_until_ready(resnorm)
            check_step_stats(stats, overflow=cfg.overflow)
        if not bool(jnp.all(jnp.isfinite(resnorm))):
            obs.counter("dist_solve_diverged_total",
                        "distributed solves abandoned after all retries"
                        ).inc()
            raise SolveDivergedError(
                "distributed CG residual non-finite"
                + (" (f32 wire retry included)" if retried else ""),
                resnorm=np.asarray(resnorm),
                fallbacks=("wire:bf16->f32",) if retried else ())
    return beta, resnorm, table, stats


def make_krr_step_hashjoin(mesh: Mesh, cfg: KRRStepConfig, f: BucketFn, *,
                           cap_factor: float = 2.0,
                           payload_dtype=jnp.bfloat16):
    """Hash-join variant of make_krr_step (same signature; returns
    (beta, resnorm, table_shard, stats) with the table SHARDED over data:
    out spec P(model_axis, data_axes), so the assembled global table is the
    standard (m, B[, k]) prediction structure with owner s holding slots
    [s·spp, (s+1)·spp) — ``make_krr_predict_hashjoin`` consumes it without
    ever gathering it to one shard).

    The routing is derived from the slot-blocked layout's per-instance slot
    sort (owner grouping and per-bucket dedup fall out of the sorted order —
    no second sort; `tests/test_blocked_split.py` pins the op count).  Per
    CG iteration the apply is ONE route-pack (flat scatter-add through the
    precomputed point->cell map — or the Pallas route-pack kernel on that
    backend), two all_to_alls, an s-gather cross-run serve (the owner table
    is never materialized inside the loop; see ``_hashjoin_matvec``), and
    ONE route-unpack — the old vmap'd per-bucket segment_sum and the three
    intermediate scatter/gather hops are gone.

    ``y`` may be (n,) or an (n, k) RHS block: the k columns ride
    (cells, k) all_to_all payloads, so one routing build and two
    collectives per iteration amortize over all columns (PR 3's multi-RHS
    contract).  ``cfg.precond='jacobi'`` is supported — the diagonal is a
    model-axis psum and the apply shard-local, adding no per-iteration
    collectives; 'nystrom' still raises (its pivot columns need global
    matvecs).  The wire payload defaults to bfloat16 (accuracy pinned by
    tests); pass ``payload_dtype=jnp.float32`` for exact psum parity.  The
    final prediction table is always built with an f32 wire — it is one
    extra exchange per solve and serves every future prediction.

    The fourth output is a ``StepStats`` (replicated int32 counters):
    distinct buckets dropped past the routing capacity, plus non-finite
    wire cells zeroed in the final table exchange.  Feed it to
    ``check_step_stats`` (or use ``run_krr_step_resilient``) to enforce
    ``cfg.overflow``; ``cfg.fault_plan`` (tests) injects wire faults and
    shard stalls into the compiled step.
    """
    if cfg.precond == "nystrom":
        raise ValueError(
            "precond='nystrom' needs global matvecs for its pivot columns; "
            "the hash-join step supports 'jacobi' (shard-local apply)")
    if cfg.precond not in ("none", None, "jacobi"):
        raise ValueError(f"unknown preconditioner {cfg.precond!r}; "
                         f"expected one of {PRECOND_NAMES}")
    n_shards = _data_shard_count(mesh, cfg)
    if cfg.table_size % n_shards:
        raise ValueError("hash-join needs table_size divisible by the data "
                         f"shard count ({cfg.table_size} % {n_shards})")
    backend = resolve_backend(cfg.backend)
    use_kernels = backend == "pallas"
    data_spec = P(cfg.data_axes)
    in_specs = (P(cfg.data_axes, None), data_spec,
                LSHParams(w=P(cfg.model_axis, None), z=P(cfg.model_axis, None),
                          r1=P(cfg.model_axis, None), r2=P(cfg.model_axis, None)))
    all_axes = tuple(cfg.data_axes) + (cfg.model_axis,)
    out_specs = (data_spec, P(), P(cfg.model_axis, cfg.data_axes),
                 StepStats(P(), P()))

    @functools.partial(shard_map, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs)
    def step(x_local, y_local, lsh_local):
        maybe_stall(cfg.fault_plan, cfg.data_axes)
        op = _shard_operator(cfg, f, lsh_local, fused=False)
        # blocked=True rides the layout's stable slot sort — the ONLY sort
        # in the step; parts='both' adds the route-kernel arrays on pallas
        idx = op.build_index(op.featurize(x_local), blocked=True,
                             parts=_hashjoin_layout_parts(backend))
        lay = idx.blocked
        m_loc = idx.slot.shape[0]
        rt = _build_routing(idx.slot, lay, n_shards, cfg.table_size,
                            cfg.data_axes, cap_factor, kernels=use_kernels)
        # routing geometry is jit-static (rt.cap is a Python int), so the
        # per-iteration all_to_all payload size is known at TRACE time —
        # recorded once per compilation, zero cost inside the loop
        k_cols = 1 if y_local.ndim == 1 else y_local.shape[1]
        obs.gauge(
            "hashjoin_a2a_payload_bytes",
            "per-shard all_to_all payload bytes per CG iteration "
            "(route + serve exchanges)").set(
            2 * n_shards * rt.cap * k_cols
            * jnp.dtype(payload_dtype).itemsize)
        interp = default_interpret()
        mv = lambda v: _hashjoin_matvec(rt, lay, idx.coeff, cfg.m,
                                        cfg.data_axes, cfg.model_axis, v,
                                        payload_dtype, interp,
                                        cfg.fault_plan)
        pre = _shard_preconditioner(cfg, None, idx)
        beta_local, resnorm = cg_iterations(mv, y_local, cfg,
                                            precond_apply=pre)
        # final sharded prediction table for the solved beta (f32 wire)
        table, wire_nf = _hashjoin_loads(rt, lay, idx.coeff, beta_local,
                                         cfg.data_axes, m_loc, jnp.float32,
                                         interp, cfg.fault_plan)
        stats = StepStats(
            overflow_dropped=jax.lax.psum(rt.dropped, all_axes),
            wire_nonfinite=jax.lax.psum(wire_nf, all_axes))
        return beta_local, resnorm, table.reshape(
            (m_loc, rt.spp) + table.shape[1:]), stats

    return step


def _axes_linear_index(axes) -> Array:
    """This shard's linear index along (possibly multiple) mesh axes —
    row-major over ``axes``, matching all_to_all's shard order."""
    if isinstance(axes, str):
        return jax.lax.axis_index(axes)
    idx = jnp.int32(0)
    for a in axes:
        idx = idx * jax.lax.psum(1, a) + jax.lax.axis_index(a)
    return idx


def _broadcast_readout(slot: Array, coeff: Array, table_flat: Array,
                       n_shards: int, spp: int, data_axes, model_axis,
                       m_total: int, payload_dtype) -> Array:
    """Route→serve→readout WITHOUT the dedup pack: every owner receives the
    raw (m_loc, n_loc) slot requests (one int32 all_to_all), serves the ones
    it owns (out-of-range ids gather 0 — each request has exactly ONE
    owner), and the value exchange sums over the owner axis.  No layout
    sort, no routing scatters, no capacity — nothing can overflow.  Wire is
    O(n_shards · m_loc · n_loc) instead of O(distinct cells): the tradeoff
    the SERVING tier wants at interactive batch sizes, where routing-build
    latency dominates the saved bytes (see make_krr_predict_hashjoin's
    ``dedup``).  Non-finite served values are sanitized to dropped mass,
    matching ``_hashjoin_readout``."""
    m_loc, n_loc = slot.shape
    send = jnp.broadcast_to(slot[None], (n_shards, m_loc, n_loc))
    recv = jax.lax.all_to_all(send, data_axes, 0, 0, tiled=True)
    local = recv - _axes_linear_index(data_axes) * spp
    ids = jnp.where((local >= 0) & (local < spp),
                    jnp.arange(m_loc, dtype=jnp.int32)[None, :, None] * spp
                    + local, m_loc * spp)
    served = table_flat.at[ids].get(mode="fill", fill_value=0)
    back = jax.lax.all_to_all(served.astype(payload_dtype), data_axes, 0, 0,
                              tiled=True).astype(jnp.float32)
    back = jnp.where(jnp.isfinite(back), back, 0.0)
    vals = jnp.sum(back, axis=0)                       # (m_loc, n_loc[, k])
    contrib = coeff[:, :, None] * vals if vals.ndim == 3 else coeff * vals
    return jax.lax.psum(jnp.sum(contrib, axis=0), model_axis) / m_total


def make_krr_predict_hashjoin(mesh: Mesh, cfg: KRRStepConfig, f: BucketFn, *,
                              cap_factor: float = 2.0,
                              payload_dtype=jnp.bfloat16,
                              with_stats: bool = False,
                              dedup: bool = True):
    """predict(x_test, lsh, table) -> yhat against a DATA-SHARDED table.

    ``table`` is the (m, B[, k]) structure assembled from
    ``make_krr_step_hashjoin``'s third output (spec
    P(model_axis, data_axes): shard s owns slots [s·spp, (s+1)·spp)).  Test
    points are data-sharded; each shard routes its points' slot requests to
    the owner shards, the owners serve their slices, and one value exchange
    assembles the predictions — the table the step already left sharded is
    consumable without a gather.  Returns (n_test,) or (n_test, k)
    predictions sharded P(data_axes).

    ``dedup=True`` (default — bulk scoring) packs DEDUPLICATED
    (instance, slot) cells through the training routing's slot-sorted
    layout: minimal wire bytes, amortized over large n.  ``dedup=False``
    (the serving tier's interactive mode) routes the raw requests instead —
    no layout sort, no routing scatters, no capacity to overflow — which at
    small padded batches is several times lower latency for strictly more
    wire bytes; the two modes agree bitwise on the reference backend (same
    table values, same coeff reduce, same psum).

    ``with_stats`` additionally returns a (data_shards,) int32 vector of
    distinct buckets dropped past the routing capacity PER SENDING DATA
    SHARD (summed over the model axis) — the serving tier folds this into
    ``health()`` so overflow under a hot query distribution is observable
    per shard instead of one global scalar.  (Always zero for
    ``dedup=False``: the broadcast route has no capacity.)"""
    n_shards = _data_shard_count(mesh, cfg)
    if cfg.table_size % n_shards:
        raise ValueError("hash-join needs table_size divisible by the data "
                         f"shard count ({cfg.table_size} % {n_shards})")
    backend = resolve_backend(cfg.backend)
    use_kernels = backend == "pallas"
    in_specs = (P(cfg.data_axes, None),
                LSHParams(w=P(cfg.model_axis, None), z=P(cfg.model_axis, None),
                          r1=P(cfg.model_axis, None), r2=P(cfg.model_axis, None)),
                P(cfg.model_axis, cfg.data_axes))
    out_specs = ((P(cfg.data_axes), P(cfg.data_axes)) if with_stats
                 else P(cfg.data_axes))

    @functools.partial(shard_map, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs)
    def predict(x_local, lsh_local, table_local):
        op = _shard_operator(cfg, f, lsh_local, fused=False)
        # flatten my (m_loc, spp[, k]) slice to the served id space
        table_flat = table_local.reshape((-1,) + table_local.shape[2:])
        if not dedup:
            idx = op.build_index(op.featurize(x_local), blocked=False)
            out = _broadcast_readout(idx.slot, idx.coeff, table_flat,
                                     n_shards, cfg.table_size // n_shards,
                                     cfg.data_axes, cfg.model_axis, cfg.m,
                                     payload_dtype)
            if with_stats:
                return out, jnp.zeros((1,), jnp.int32)
            return out
        idx = op.build_index(op.featurize(x_local), blocked=True,
                             parts=_hashjoin_layout_parts(backend))
        rt = _build_routing(idx.slot, idx.blocked, n_shards, cfg.table_size,
                            cfg.data_axes, cap_factor, kernels=use_kernels)
        out = _hashjoin_readout(rt, idx.blocked, idx.coeff, table_flat,
                                cfg.data_axes, cfg.model_axis, cfg.m,
                                payload_dtype, default_interpret(),
                                plan=cfg.fault_plan)
        if with_stats:
            # dropped is per (model, data) shard; the model psum leaves one
            # replicated count per data shard -> P(data_axes) over (1,)
            # assembles the global (data_shards,) vector
            return out, jax.lax.psum(rt.dropped, cfg.model_axis)[None]
        return out

    return predict


def query_shard_touch(slots, table_size: int, n_shards: int):
    """(n, m) per-query table slots -> (n, n_shards) bool touch masks.

    Shard j owns slots [j·spp, (j+1)·spp) (spp = table_size / n_shards, the
    hash-join layout above), so a query's prediction depends ONLY on the
    shards its m slots land in.  The serving cache keys fold in exactly this
    touch set (+ per-shard piece versions): reloading one shard's table
    piece then invalidates only the entries whose slots touch it.  Pure
    numpy — the cache path must never enter the jit runtime."""
    slots = np.asarray(slots)
    if table_size % n_shards:
        raise ValueError(f"table_size={table_size} not divisible by "
                         f"n_shards={n_shards}")
    owners = slots // (table_size // n_shards)
    touch = np.zeros((slots.shape[0], n_shards), bool)
    touch[np.arange(slots.shape[0])[:, None], owners] = True
    return touch
