"""Distributed WLSH-KRR: the paper's algorithm on a (pod, data, model) mesh.

Parallelization (DESIGN.md §3/§6):

* **points** are sharded over the data axes ('pod', 'data') — featurization is
  embarrassingly parallel (the LSH parameters are replicated, tiny).
* **instances** (the m independent WLSH estimators) are sharded over 'model' —
  they only interact at the final (1/m)-average.
* **bucket tables** are the only cross-shard object: each data shard scatters
  its points' signed loads into a local (m_local, B) CountSketch table, a
  single ``psum`` over the data axes merges them, and every shard reads its
  own points' loads back out.  A dense table is psum-able; the paper's
  per-bucket lists are not — that is the whole reason for the CountSketch
  adaptation.
* **CG** runs on sharded vectors; the two dot products per iteration are
  scalar psums.

All scatter/readout goes through ``core.operator.WLSHOperator`` — this module
adds only the collectives.  Each shard builds an operator from its *local*
LSH shard inside shard_map; ``loads`` produces the psum-able partial tables
and ``readout(average=False)`` the local instance-sum that the model-axis
psum completes.  Everything is expressed with ``jax.shard_map`` + ``jax.lax``
collectives; no host-side communication.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..backend import default_interpret, resolve_backend
from ..compat import shard_map
from .bucket_fns import BucketFn
from .lsh import GammaPDF, LSHParams, sample_lsh_params
from .operator import WLSHOperator
from .precond import (DEFAULT_NYSTROM_RANK, PRECOND_NAMES, jacobi_precond,
                      nystrom_precond, table_diag)

Array = jnp.ndarray


class KRRStepConfig(NamedTuple):
    m: int                 # total WLSH instances (sharded over 'model')
    table_size: int        # CountSketch table slots (power of two)
    lam: float             # ridge regularizer
    cg_iters: int          # fixed PCG iteration count fused into the step
    data_axes: tuple[str, ...] = ("data",)
    model_axis: str = "model"
    backend: str = "auto"  # operator backend inside each shard
    fused: bool = True     # one-pass local matvec when the data axes are size 1
    precond: str = "none"  # 'none' | 'jacobi' (any mesh) | 'nystrom'
                           # (unsharded data axes only — see make_krr_step)
    precond_rank: int = DEFAULT_NYSTROM_RANK


def _shard_operator(cfg: KRRStepConfig, f: BucketFn,
                    lsh_local: LSHParams) -> WLSHOperator:
    """Per-shard operator over the local LSH slice (backend resolved at
    trace time — shard_map bodies must see a concrete choice)."""
    return WLSHOperator(lsh=lsh_local, bucket=f, table_size=cfg.table_size,
                        backend=resolve_backend(cfg.backend),
                        interpret=default_interpret(), fused=cfg.fused)


def _data_shard_count(mesh: Mesh, cfg: KRRStepConfig) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for a in cfg.data_axes:
        n *= sizes[a]
    return n


def make_distributed_matvec(cfg: KRRStepConfig, op: WLSHOperator, *,
                            n_data_shards: int):
    """Returns matvec(index, beta_local) -> (K~ beta)_local.

    A thin psum wrapper around the operator's local scatter/readout — must be
    called inside shard_map with an index built from the local featurization
    (m_loc, n_loc) and a (n_loc,) or (n_loc, k) beta shard (a RHS block
    rides one scatter/psum/readout round trip: the psum'd object grows to
    (m_loc, B, k) but the collective count per iteration is unchanged).
    ``n_data_shards`` is the product of the mesh's data-axis sizes
    (``_data_shard_count``) — required so a forgotten kwarg cannot silently
    disable the fused path.

    The split loads → psum → readout sandwich is required whenever the data
    axes are sharded: the table psum is the scatter→gather barrier, so the
    (m_loc, B) tables must exist between the two.  With a single data shard
    (model-parallel-only meshes) there is nothing to merge, and the fused
    one-pass matvec (slot-blocked index) runs locally with only the final
    model-axis psum.
    """
    local_fused = cfg.fused and n_data_shards == 1

    def matvec(index, beta_local):
        if local_fused and getattr(index, "blocked", None) is not None:
            out = op.matvec(index, beta_local, average=False)
        else:
            tables = jax.lax.psum(op.loads(index, beta_local), cfg.data_axes)
            out = op.readout(index, tables, average=False)  # sum over m_loc
        return jax.lax.psum(out, cfg.model_axis) / cfg.m
    return matvec


def _sharded_dot(a: Array, b: Array, axes: Sequence[str]) -> Array:
    """Column-wise sharded inner product: scalar for (n_loc,) operands,
    (k,) for (n_loc, k) RHS blocks — one scalar/vector psum either way."""
    return jax.lax.psum(jnp.sum(a * b, axis=0), axes)


def _bcast(c: Array, v: Array) -> Array:
    """Broadcast a per-column coefficient over v (n,) or (n, k)."""
    return c * v if v.ndim == 1 else c[None, :] * v


def cg_iterations(matvec, y_local: Array, cfg: KRRStepConfig,
                  precond_apply=None):
    """Fixed-iteration PCG on (K~ + lam I) beta = y, vectors data-sharded.
    ``y_local`` is (n_loc,) or an (n_loc, k) RHS block — the recurrences run
    column-wise so every column follows its own single-RHS trajectory while
    sharing each matvec and collective.  ``precond_apply`` (z = P⁻¹ r on
    local shards, e.g. the Jacobi diagonal from ``make_krr_step``) defaults
    to identity, which reduces exactly to plain CG.  Returns
    (beta_local, resnorm) with resnorm per column for a block."""
    lam = jnp.asarray(cfg.lam, jnp.float32)
    identity = precond_apply is None
    psolve = (lambda r: r) if identity else precond_apply

    def amv(v):
        return matvec(v) + lam * v

    def residual_dots(r, z):
        # with the identity preconditioner rho == ||r||², so plain CG keeps
        # its two psums per iteration (no third collective sneaks in)
        rs = _sharded_dot(r, r, cfg.data_axes)
        return (rs, rs) if identity else \
            (_sharded_dot(r, z, cfg.data_axes), rs)

    x = jnp.zeros_like(y_local)
    r = y_local - amv(x)
    z = psolve(r)
    p = z
    rho, rs = residual_dots(r, z)

    def body(_, state):
        x, r, p, rho, rs = state
        ap = amv(p)
        alpha = rho / jnp.maximum(_sharded_dot(p, ap, cfg.data_axes), 1e-30)
        x = x + _bcast(alpha, p)
        r = r - _bcast(alpha, ap)
        z = psolve(r)
        rho_new, rs_new = residual_dots(r, z)
        p = z + _bcast(rho_new / jnp.maximum(rho, 1e-30), p)
        return x, r, p, rho_new, rs_new

    x, r, p, rho, rs = jax.lax.fori_loop(0, cfg.cg_iters, body,
                                         (x, r, p, rho, rs))
    return x, jnp.sqrt(rs)


def _shard_preconditioner(cfg: KRRStepConfig, mv, idx):
    """Build cfg.precond inside shard_map; returns apply(r_local) or None.

    * jacobi — diag(K̃)_i = mean_s coeff²[s, i] is per-point, so the local
      column sums only need the model-axis psum; the apply is elementwise on
      the local shard (no extra collectives per iteration).
    * nystrom — needs K̃-columns for its pivot block, i.e. a global matvec
      with global one-hot columns.  With unsharded data axes the local index
      IS global (only the model psum participates), so the single-host
      factorization from core/precond.py traces directly; with sharded data
      axes pivot selection/column exchange would need a gather we don't
      ship yet, so make_krr_step rejects that combination up front.
    """
    if cfg.precond in ("none", None):
        return None
    diag = jax.lax.psum(table_diag(idx.coeff, average=False),
                        cfg.model_axis) / cfg.m
    if cfg.precond == "jacobi":
        return jacobi_precond(diag, cfg.lam).apply
    if cfg.precond == "nystrom":
        pre = nystrom_precond(lambda v: mv(idx, v), diag, cfg.lam,
                              cfg.precond_rank)
        return pre.apply
    raise ValueError(f"unknown preconditioner {cfg.precond!r}; "
                     f"expected one of {PRECOND_NAMES}")


def make_krr_step(mesh: Mesh, cfg: KRRStepConfig, f: BucketFn):
    """Builds the jit-able distributed KRR training step.

    step(x, y, lsh) -> (beta, resnorm, tables)
      x (n, d) sharded P(data_axes, None); y sharded P(data_axes) — (n,) for
      one target or (n, k) for a RHS block (batched KRR / GP posterior
      samples; the k columns share every matvec and collective)
      lsh: LSHParams with leading m dim sharded P(model_axis)
    The returned beta is sharded like y; tables (m, B[, k]) are the
    prediction data structure (model-sharded, data-replicated).

    ``cfg.precond`` runs the solve as PCG: 'jacobi' works on any mesh (its
    diagonal is a model-axis psum; the apply is shard-local); 'nystrom'
    requires unsharded data axes — its pivot columns come from global
    matvecs — and raises otherwise.
    """
    data_spec = P(cfg.data_axes)
    in_specs = (P(cfg.data_axes, None), data_spec,
                LSHParams(w=P(cfg.model_axis, None), z=P(cfg.model_axis, None),
                          r1=P(cfg.model_axis, None), r2=P(cfg.model_axis, None)))
    out_specs = (data_spec, P(), P(cfg.model_axis, None))
    n_data = _data_shard_count(mesh, cfg)
    local_fused = cfg.fused and n_data == 1
    if cfg.precond == "nystrom" and n_data != 1:
        raise ValueError(
            "precond='nystrom' needs unsharded data axes (its pivot columns "
            "are global K~ matvecs); use 'jacobi' on data-sharded meshes")

    @functools.partial(shard_map, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs)
    def step(x_local, y_local, lsh_local):
        op = _shard_operator(cfg, f, lsh_local)
        # the slot-blocked layout is only consumed by the fused local matvec;
        # sharded data axes stay on the split (psum-able) index
        idx = op.build_index(op.featurize(x_local), blocked=local_fused)
        mv = make_distributed_matvec(cfg, op, n_data_shards=n_data)
        pre = _shard_preconditioner(cfg, mv, idx)
        beta_local, resnorm = cg_iterations(lambda v: mv(idx, v), y_local,
                                            cfg, precond_apply=pre)
        # final prediction tables for the solved beta
        tables = jax.lax.psum(op.loads(idx, beta_local), cfg.data_axes)
        return beta_local, resnorm, tables

    return step


def make_krr_predict(mesh: Mesh, cfg: KRRStepConfig, f: BucketFn):
    """predict(x_test, lsh, tables) -> yhat; test points data-sharded."""
    in_specs = (P(cfg.data_axes, None),
                LSHParams(w=P(cfg.model_axis, None), z=P(cfg.model_axis, None),
                          r1=P(cfg.model_axis, None), r2=P(cfg.model_axis, None)),
                P(cfg.model_axis, None))
    out_specs = P(cfg.data_axes)

    @functools.partial(shard_map, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs)
    def predict(x_local, lsh_local, tables_local):
        op = _shard_operator(cfg, f, lsh_local)
        idx = op.build_index(op.featurize(x_local), blocked=False)
        out = op.readout(idx, tables_local, average=False)
        return jax.lax.psum(out, cfg.model_axis) / cfg.m

    return predict


def sample_sharded_lsh(key: jax.Array, m: int, d: int, pdf: GammaPDF,
                       lengthscale: float = 1.0) -> LSHParams:
    """Host-side LSH parameter sampling (tiny; replicate then shard)."""
    return sample_lsh_params(key, m, d, pdf, lengthscale)


# ---------------------------------------------------------------------------
# BEYOND-PAPER: hash-join table mode
# ---------------------------------------------------------------------------
#
# The psum of the (m_loc, B) CountSketch tables moves O(B) floats per CG
# iteration per chip even though each shard contributes and reads only
# O(n_local) nonzeros.  The hash join shards the TABLE over the data axes
# (each shard owns B/n_shards slots) and routes only the nonzeros:
#
#   scatter:  (slot, contrib) pairs -> owner shard  (all_to_all, ~n_local f32)
#   readout:  slot requests -> owner -> values back (all_to_all, precomputed
#             routing: slots are fixed for the whole CG solve)
#
# Collective bytes per iteration drop from m_loc*B*4 to ~2*capacity*n_local*4
# — 16x at the krr_4m cell (measured; see EXPERIMENTS.md §Perf).  Entries
# beyond the per-destination capacity are dropped (probability ~0 for
# capacity_factor >= 2 with uniform hashing; the estimator stays unbiased in
# sign expectation, and tests compare against the exact table mode).
#
# This path's scatter/readout is NOT the operator's dense-table primitive —
# it is a different algorithm (table sharded over data, all_to_all routing),
# so only featurization/indexing is shared with the operator.

class _Routing(NamedTuple):
    bpos: Array        # (E,) destination bucket cell per entry (sentinel = NB)
    sidx: Array        # (NB,) source entry per bucket cell (sentinel = E)
    recv_packed: Array # (NB,) received (m*spp + slot%spp) ids after a2a
    spp: int           # slots per shard
    cap: int           # bucket capacity per destination shard


def _build_routing(slot: Array, n_shards: int, table_size: int,
                   data_axes, cap_factor: float) -> _Routing:
    """Precompute the entry <-> bucket-cell maps and exchange slot requests.
    slot (m_loc, n_loc); runs once per CG solve (slots are fixed)."""
    m_loc, n_loc = slot.shape
    e = m_loc * n_loc
    spp = table_size // n_shards
    cap = max(8, int(-(-e * cap_factor // n_shards) // 8 * 8))
    nb = n_shards * cap

    flat_slot = slot.reshape(-1)
    owner = (flat_slot // spp).astype(jnp.int32)
    packed = (jnp.arange(e, dtype=jnp.int32) // n_loc) * spp + \
        (flat_slot % spp)                                     # m_idx*spp + mod

    order = jnp.argsort(owner)
    so, sidx_entries = owner[order], jnp.arange(e, dtype=jnp.int32)[order]
    start = jnp.searchsorted(so, jnp.arange(n_shards, dtype=so.dtype))
    pos = jnp.arange(e, dtype=jnp.int32) - start[so].astype(jnp.int32)
    keep = pos < cap
    cell = jnp.where(keep, so.astype(jnp.int32) * cap + pos, nb)

    bpos = jnp.full((e,), nb, jnp.int32).at[sidx_entries].set(
        jnp.where(keep, cell, nb), mode="drop")               # entry -> cell
    sidx = jnp.full((nb,), e, jnp.int32).at[cell].set(sidx_entries,
                                                      mode="drop")
    # send each destination the packed ids it must serve (fixed per solve)
    send_packed = jnp.full((nb,), -1, jnp.int32).at[cell].set(
        packed[sidx_entries], mode="drop").reshape(n_shards, cap)
    recv_packed = jax.lax.all_to_all(send_packed, data_axes, 0, 0,
                                     tiled=True).reshape(-1)
    return _Routing(bpos=bpos, sidx=sidx, recv_packed=recv_packed, spp=spp,
                    cap=cap)


def _hashjoin_matvec(rt: _Routing, coeff: Array, m_total: int,
                     m_loc: int, data_axes, model_axis, beta_local: Array,
                     payload_dtype=jnp.float32):
    """payload_dtype=bfloat16 halves bucket/wire bytes; the table scatter-add
    still accumulates in f32, so only individual contributions are rounded
    (CG tolerates the ~0.4% relative matvec noise; tests pin the accuracy).
    ``coeff`` is the index's precomputed weight·sign (m_loc, n_loc)."""
    n_shards = rt.recv_packed.shape[0] // rt.cap
    nb = n_shards * rt.cap
    contrib = (beta_local[None, :] * coeff).reshape(-1)           # (E,)
    # route contributions to slot owners
    send_c = jnp.zeros((nb,), payload_dtype).at[rt.bpos].set(
        contrib.astype(payload_dtype), mode="drop")
    recv_c = jax.lax.all_to_all(send_c.reshape(n_shards, rt.cap), data_axes,
                                0, 0, tiled=True).reshape(-1)
    # local scatter-add into MY table shard (m_loc, spp)
    valid = rt.recv_packed >= 0
    ids = jnp.where(valid, rt.recv_packed, m_loc * rt.spp)
    table = jnp.zeros((m_loc * rt.spp,), jnp.float32).at[ids].add(
        recv_c.astype(jnp.float32), mode="drop")
    # serve the (fixed) readout requests and route values back
    vals_serve = jnp.where(valid, table[jnp.clip(rt.recv_packed, 0)],
                           0.0).astype(payload_dtype)
    back = jax.lax.all_to_all(vals_serve.reshape(n_shards, rt.cap), data_axes,
                              0, 0, tiled=True).reshape(-1)
    vals = jnp.zeros((coeff.size,), jnp.float32).at[rt.sidx].set(
        back.astype(jnp.float32), mode="drop")
    out = jnp.sum(vals.reshape(coeff.shape) * coeff, axis=0)
    return jax.lax.psum(out, model_axis) / m_total


def make_krr_step_hashjoin(mesh: Mesh, cfg: KRRStepConfig, f: BucketFn, *,
                           cap_factor: float = 2.0,
                           payload_dtype=jnp.float32):
    """Hash-join variant of make_krr_step (same signature; returns
    (beta, resnorm, table_shard) with the table left SHARDED over data).

    Single-RHS, unpreconditioned only: its scatter routes one contribution
    stream per entry, and a silently-dropped cfg.precond would leave the
    fixed cg_iters under-converged — so unsupported configs are rejected
    up front rather than ignored.
    """
    if cfg.precond not in ("none", None):
        raise ValueError("make_krr_step_hashjoin does not support "
                         "preconditioning; use make_krr_step or "
                         "precond='none'")
    n_shards = 1
    for a in cfg.data_axes:
        n_shards *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
    data_spec = P(cfg.data_axes)
    in_specs = (P(cfg.data_axes, None), data_spec,
                LSHParams(w=P(cfg.model_axis, None), z=P(cfg.model_axis, None),
                          r1=P(cfg.model_axis, None), r2=P(cfg.model_axis, None)))
    out_specs = (data_spec, P(), P(cfg.model_axis, cfg.data_axes))

    @functools.partial(shard_map, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs)
    def step(x_local, y_local, lsh_local):
        if y_local.ndim != 1:
            raise ValueError("hash-join step is single-RHS; use "
                             "make_krr_step for (n, k) target blocks")
        op = _shard_operator(cfg, f, lsh_local)
        idx = op.build_index(op.featurize(x_local), blocked=False)
        m_loc = idx.slot.shape[0]
        rt = _build_routing(idx.slot, n_shards, cfg.table_size, cfg.data_axes,
                            cap_factor)
        mv = lambda v: _hashjoin_matvec(rt, idx.coeff, cfg.m,
                                        m_loc, cfg.data_axes, cfg.model_axis,
                                        v, payload_dtype)
        beta_local, resnorm = cg_iterations(mv, y_local, cfg)
        # final sharded prediction table for the solved beta
        contrib = (beta_local[None, :] * idx.coeff).reshape(-1)
        send_c = jnp.zeros((n_shards * rt.cap,), jnp.float32).at[rt.bpos].set(
            contrib, mode="drop")
        recv_c = jax.lax.all_to_all(send_c.reshape(n_shards, rt.cap),
                                    cfg.data_axes, 0, 0, tiled=True).reshape(-1)
        valid = rt.recv_packed >= 0
        ids = jnp.where(valid, rt.recv_packed, m_loc * rt.spp)
        table = jnp.zeros((m_loc * rt.spp,), jnp.float32).at[ids].add(
            recv_c, mode="drop")
        return beta_local, resnorm, table.reshape(m_loc, rt.spp)

    return step
