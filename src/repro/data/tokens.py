"""Synthetic token pipeline: deterministic, stateless, shardable.

Batches are a pure function of (seed, step), so any host in a multi-pod job
can materialize its own shard without coordination, and restarts resume at
the exact same data position — the property a real distributed loader needs
and the one our fault-tolerance tests rely on.

The stream is a noisy affine-recurrence language: with probability 1-eps the
next token is (a * prev + c) mod V, else uniform noise.  A model that learns
the recurrence drives loss from ln(V) toward -ln(1-eps) — we use the gap as
the end-to-end "is it actually learning" signal in examples/train_lm.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jnp.ndarray

_A, _C = 4097, 1231  # affine recurrence constants (coprime-ish with any V)


def synthetic_lm_batch(seed: int, step: int, *, batch: int, seq: int,
                       vocab: int, noise: float = 0.1) -> dict[str, Array]:
    """Deterministic (seed, step) -> {tokens, labels} of shape (batch, seq).

    labels[t] = tokens[t + 1] (next-token prediction); the final label column
    is masked with -1 (ignored by chunked_xent).
    """
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    k0, kn, ku = jax.random.split(key, 3)
    start = jax.random.randint(k0, (batch, 1), 0, vocab)
    # unroll the recurrence with scan so the whole batch is one fused kernel
    noise_mask = jax.random.bernoulli(kn, noise, (batch, seq))
    noise_tok = jax.random.randint(ku, (batch, seq), 0, vocab)

    def step_fn(prev, inp):
        nmask, ntok = inp
        nxt = (prev * _A + _C) % vocab
        nxt = jnp.where(nmask, ntok, nxt)
        return nxt, nxt

    _, toks = jax.lax.scan(step_fn, start[:, 0],
                           (noise_mask.T, noise_tok.T))
    tokens = toks.T.astype(jnp.int32)                      # (batch, seq)
    labels = jnp.concatenate([tokens[:, 1:],
                              jnp.full((batch, 1), -1, jnp.int32)], axis=1)
    return {"tokens": tokens, "labels": labels}


def token_stream(seed: int, *, batch: int, seq: int, vocab: int,
                 start_step: int = 0, noise: float = 0.1):
    """Infinite generator over synthetic_lm_batch; resumable at any step."""
    step = start_step
    while True:
        yield step, synthetic_lm_batch(seed, step, batch=batch, seq=seq,
                                       vocab=vocab, noise=noise)
        step += 1
