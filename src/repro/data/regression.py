"""Synthetic stand-ins for the paper's Table-2 regression datasets.

The container is offline, so the UCI sets (Wine Quality d=11, Insurance d=85,
CT Slices d=384, Forest Cover d=54) are replaced by synthetic datasets with
the SAME dimensionality and (scalable) size: targets are smooth + rough
mixtures y = g(x) + laplace-ish component + noise, which exercises exactly
the smooth-vs-nonsmooth kernel trade-off the paper's Table 2 probes.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jnp.ndarray


class RegressionSpec(NamedTuple):
    name: str
    dim: int
    n_train: int
    n_test: int
    rough: float        # weight of the non-smooth (|.|-kink) target component


# paper's Table-2 datasets; n matches the paper (size = train + test)
REGRESSION_DATASETS: dict[str, RegressionSpec] = {
    "wine": RegressionSpec("wine", 11, 4000, 2497, rough=0.3),
    "insurance": RegressionSpec("insurance", 85, 5822, 4000, rough=0.2),
    "ct_slices": RegressionSpec("ct_slices", 384, 35000, 18500, rough=0.4),
    "forest": RegressionSpec("forest", 54, 500000, 81012, rough=0.5),
}


def _target(key: jax.Array, x: Array, rough: float) -> Array:
    """Mixture target: random-feature smooth part + |w.x - b| kinks."""
    d = x.shape[-1]
    k1, k2, k3, k4 = jax.random.split(key, 4)
    w_s = jax.random.normal(k1, (d, 16)) / jnp.sqrt(d)
    b_s = jax.random.uniform(k2, (16,), maxval=2 * jnp.pi)
    smooth = jnp.cos(x @ w_s + b_s) @ jnp.ones((16,)) / 4.0
    w_r = jax.random.normal(k3, (d, 8)) / jnp.sqrt(d)
    b_r = jax.random.normal(k4, (8,)) * 0.3
    kinks = jnp.abs(x @ w_r - b_r) @ jnp.ones((8,)) / 8.0
    return (1.0 - rough) * smooth + rough * kinks


def make_regression_dataset(name: str, seed: int = 0, *, scale: float = 1.0,
                            noise: float = 0.1):
    """Returns (x_train, y_train, x_test, y_test).  ``scale`` < 1 shrinks the
    sizes proportionally (CI-friendly)."""
    spec = REGRESSION_DATASETS[name]
    n_tr = max(64, int(spec.n_train * scale))
    n_te = max(64, int(spec.n_test * scale))
    key = jax.random.PRNGKey(seed)
    kx, kt, kn1, kn2 = jax.random.split(key, 4)
    x = jax.random.uniform(kx, (n_tr + n_te, spec.dim)) * 2.0
    y = _target(kt, x, spec.rough)
    y = y + noise * jax.random.normal(kn1, y.shape)
    # standardize like common KRR practice
    mu, sd = jnp.mean(y[:n_tr]), jnp.std(y[:n_tr]) + 1e-9
    y = (y - mu) / sd
    del kn2
    return x[:n_tr], y[:n_tr], x[n_tr:], y[n_tr:]
