from .tokens import synthetic_lm_batch, token_stream
from .regression import REGRESSION_DATASETS, make_regression_dataset
