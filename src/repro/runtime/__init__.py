from .fault_tolerance import (FailureInjector, RestartableLoop, StepResult,
                              StragglerWatchdog)
