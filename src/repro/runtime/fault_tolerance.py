"""Fault tolerance: restartable training loop, failure injection, straggler
watchdog.

On a 1000+ node fleet the *expected* condition is that something is broken:
a host reboots mid-step, a chip slows down 10x (thermal / ECC retries), a
whole pod disappears.  The contract this module implements:

* every N steps state is checkpointed (async, atomic — see repro.checkpoint);
* any exception in the step function triggers restore-from-latest + replay —
  because the data pipeline is stateless-deterministic (repro.data.tokens),
  replayed steps consume exactly the batches they would have consumed;
* a watchdog tracks per-step wall time against a rolling median; outliers are
  logged (straggler mitigation on real fleets = re-scheduling; here we surface
  the signal and enforce a hard timeout abort so the restart path engages).

``FailureInjector`` deterministically raises at chosen steps to let tests and
examples exercise the whole path on one host.
"""
from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax

from ..checkpoint import CheckpointManager, latest_step, restore_checkpoint

log = logging.getLogger("repro.runtime")


class InjectedFailure(RuntimeError):
    pass


@dataclass
class FailureInjector:
    """Raises InjectedFailure the first time each step in ``at_steps`` runs."""
    at_steps: tuple[int, ...] = ()
    fired: set = field(default_factory=set)

    def maybe_fail(self, step: int) -> None:
        if step in self.at_steps and step not in self.fired:
            self.fired.add(step)
            raise InjectedFailure(f"injected failure at step {step}")


@dataclass
class StragglerWatchdog:
    """Rolling-median step-time monitor with a hard timeout."""
    slow_factor: float = 3.0
    hard_timeout_s: float = 0.0       # 0 disables
    window: int = 32
    times: list = field(default_factory=list)
    stragglers: list = field(default_factory=list)

    def observe(self, step: int, dt: float) -> None:
        self.times.append(dt)
        hist = self.times[-self.window:]
        med = sorted(hist)[len(hist) // 2]
        if len(hist) >= 8 and dt > self.slow_factor * med:
            self.stragglers.append((step, dt, med))
            log.warning("straggler: step %d took %.3fs (median %.3fs)",
                        step, dt, med)
        if self.hard_timeout_s and dt > self.hard_timeout_s:
            raise TimeoutError(
                f"step {step} exceeded hard timeout {self.hard_timeout_s}s "
                f"({dt:.3f}s) — aborting for restart")


@dataclass
class StepResult:
    state: Any
    metrics: dict
    step: int


class RestartableLoop:
    """Checkpointed, crash-tolerant training loop.

    step_fn(state, step) -> (state, metrics) must be a pure function of its
    inputs (the jit'd train step closed over the batch source); state is any
    pytree.  The loop retries from the latest complete checkpoint on any
    exception, up to ``max_restarts`` times.
    """

    def __init__(self, step_fn: Callable[[Any, int], tuple[Any, dict]],
                 ckpt_dir: str, *, checkpoint_every: int = 25, keep: int = 3,
                 max_restarts: int = 8,
                 watchdog: StragglerWatchdog | None = None,
                 injector: FailureInjector | None = None):
        self.step_fn = step_fn
        self.manager = CheckpointManager(ckpt_dir, keep=keep)
        self.ckpt_dir = ckpt_dir
        self.checkpoint_every = checkpoint_every
        self.max_restarts = max_restarts
        self.watchdog = watchdog or StragglerWatchdog()
        self.injector = injector
        self.restarts = 0

    def _resume(self, state: Any) -> tuple[Any, int]:
        step = latest_step(self.ckpt_dir)
        if step is None:
            return state, 0
        restored, step, _ = restore_checkpoint(self.ckpt_dir, state)
        restored = jax.tree.map(
            lambda a, t: jax.device_put(a).astype(t.dtype), restored, state)
        log.info("resumed from checkpoint step %d", step)
        return restored, step

    def run(self, init_state: Any, num_steps: int,
            on_metrics: Callable[[int, dict], None] | None = None) -> StepResult:
        state, start = self._resume(init_state)
        step = start
        metrics: dict = {}
        while step < num_steps:
            try:
                t0 = time.monotonic()
                if self.injector is not None:
                    self.injector.maybe_fail(step)
                state, metrics = self.step_fn(state, step)
                jax.block_until_ready(jax.tree.leaves(state)[0])
                self.watchdog.observe(step, time.monotonic() - t0)
                step += 1
                if step % self.checkpoint_every == 0 or step == num_steps:
                    self.manager.save(step, state, meta={"step": step})
                if on_metrics is not None:
                    on_metrics(step, metrics)
            except Exception as exc:  # noqa: BLE001 — the whole point
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                log.warning("step %d failed (%s); restart %d/%d", step, exc,
                            self.restarts, self.max_restarts)
                self.manager.wait()
                state, step = self._resume(init_state)
        self.manager.wait()
        return StepResult(state=state, metrics=metrics, step=step)
