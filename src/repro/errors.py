"""Structured error taxonomy for the resilience layer (DESIGN.md §9).

Every fault the system can hit — numerical divergence, wire overflow, serving
overload, a crashed worker, an injected test fault — surfaces as one of these
types, so callers can catch precisely (shed vs crash vs retry) instead of
string-matching RuntimeError messages.  The serving errors carry the queue
state they were raised under; the solver errors carry the residuals.
"""
from __future__ import annotations


class ReproError(Exception):
    """Base class for all structured repro errors."""


# -- numerical ---------------------------------------------------------------

class NonFiniteError(ReproError, ValueError):
    """Non-finite values where finite ones are required (NaN training
    target, Inf query row, poisoned table).  ``where`` names the array."""

    def __init__(self, message: str, *, where: str = "", count: int = 0):
        super().__init__(message)
        self.where = where
        self.count = int(count)


class SolveDivergedError(ReproError, ArithmeticError):
    """A solve ended with non-finite iterates/residuals after every
    configured fallback (precond→identity restart, bf16→f32 wire retry)."""

    def __init__(self, message: str, *, resnorm=None, fallbacks=()):
        super().__init__(message)
        self.resnorm = resnorm
        self.fallbacks = tuple(fallbacks)


class WireOverflowError(ReproError, RuntimeError):
    """Hash-join routing dropped distinct buckets past the per-destination
    capacity and the step ran with ``overflow='raise'``."""

    def __init__(self, message: str, *, dropped: int = 0):
        super().__init__(message)
        self.dropped = int(dropped)


# -- serving -----------------------------------------------------------------

class ServingError(ReproError):
    """Base class for request-path failures."""


class Overloaded(ServingError):
    """Request shed by queue-depth load shedding — a structured result the
    client can back off on, never a hang."""

    def __init__(self, message: str = "request shed: queue full", *,
                 queue_depth: int = 0):
        super().__init__(message)
        self.queue_depth = int(queue_depth)


class CircuitOpen(Overloaded):
    """Fast rejection while a circuit breaker is open: the model's worker (or
    the model itself) is failing and callers must back off instead of piling
    on.  Subclasses ``Overloaded`` so existing shed-handling backoff paths
    treat it identically; ``retry_after_s`` hints when the breaker's
    half-open probe window starts."""

    def __init__(self, message: str = "circuit open", *,
                 retry_after_s: float = 0.0):
        super().__init__(message)
        self.retry_after_s = float(retry_after_s)


class DeadlineExceeded(ServingError):
    """The request's deadline budget elapsed before its batch ran."""

    def __init__(self, message: str = "deadline exceeded", *,
                 waited_s: float = 0.0):
        super().__init__(message)
        self.waited_s = float(waited_s)


class WorkerCrashed(ServingError):
    """The batcher worker thread died; all in-flight futures fail with this
    and subsequent submits fail fast instead of hanging forever."""


class InvalidRequest(ServingError, ValueError):
    """Malformed request rejected before it reaches the model (non-finite
    query row, wrong dimensionality)."""


# -- test harness ------------------------------------------------------------

class FaultInjected(ReproError):
    """Raised by repro.testing.faults when an armed fault fires — only ever
    seen under test control."""
