"""Roofline analysis from compiled HLO (no hardware required).

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE, so any
program with a scan (every model here: the layer stack, microbatching, CG
iterations) under-reports FLOPs and bytes by the trip count.  This module
parses the optimized HLO text instead:

* builds the computation call graph (entry -> while bodies / fusions / calls)
  with multiplicities from ``known_trip_count`` backend configs;
* FLOPs: every ``dot`` op contributes 2 * |output| * |contraction| * trips;
* HBM bytes: operand + result bytes of top-level memory ops (fusions, dots,
  copies, dynamic slices, collectives) * trips — fusion-internal ops never
  touch HBM and are excluded;
* collective bytes: moved payload per op class * trips.

Terms (per step, per chip) against TPU v5e constants:

    compute    = FLOPs / (chips * 197e12)        [bf16 MXU peak]
    memory     = bytes / (chips * 819e9)         [HBM bandwidth]
    collective = coll_bytes / (chips * 50e9)     [per-link ICI]

The dominant term approximates the step's lower-bound latency; the roofline
fraction reported for optimization is model_flops_time / dominant_term.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

# TPU v5e, per chip
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# type string may be a long tuple with /*index=N*/ comments (they contain '='),
# so match lazily up to the first "opcode(" token.
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*?)\s?([\w\-]+)\(")
_HEADER_NAME_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# ops whose operands/results move through HBM at computation top level
_MEM_OPS = {"fusion", "dot", "copy", "dynamic-slice", "dynamic-update-slice",
            "convolution", "gather", "scatter", "transpose", "reshape",
            "broadcast", "convert", "reduce", "concatenate", "slice", "sort",
            "iota", "pad", "select-and-scatter", "bitcast-convert"} | \
    set(COLLECTIVES) | {c + "-start" for c in COLLECTIVES}


def _shape_dims(type_str: str) -> list[tuple[str, list[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dtype, dims = m.groups()
        if dtype not in _DTYPE_BYTES:
            continue
        out.append((dtype, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclass
class _Op:
    name: str
    type_str: str
    opcode: str
    line: str


@dataclass
class _Computation:
    name: str
    ops: list = field(default_factory=list)


def _parse_module(hlo_text: str):
    """Returns (computations dict, entry name, name->type symbol table)."""
    comps: dict[str, _Computation] = {}
    symbols: dict[str, str] = {}
    entry = None
    cur: _Computation | None = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # computation headers: "%name (args...) -> type {" at zero indent;
        # robust to tuple-typed params (nested parens break naive regexes)
        if (stripped.endswith("{") and "->" in stripped and "=" not in
                stripped.split("(")[0] and not line.startswith(" ")):
            hm = _HEADER_NAME_RE.match(stripped)
            if hm:
                is_entry, name = hm.groups()
                cur = _Computation(name=name)
                comps[name] = cur
                if is_entry:
                    entry = name
                continue
        dm = _DEF_RE.match(line)
        if dm and cur is not None:
            name, type_str, opcode = dm.groups()
            symbols[name] = type_str.strip()
            cur.ops.append(_Op(name=name, type_str=type_str.strip(),
                               opcode=opcode, line=line))
    return comps, entry, symbols


def _called_comps(op: _Op) -> list[tuple[str, float]]:
    """(computation, multiplicity factor) pairs an op invokes."""
    out = []
    if op.opcode == "while":
        trip = 1.0
        mt = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', op.line)
        if mt:
            trip = float(mt.group(1))
        mb = re.search(r"body=%?([\w.\-]+)", op.line)
        mc = re.search(r"condition=%?([\w.\-]+)", op.line)
        if mb:
            out.append((mb.group(1), trip))
        if mc:
            out.append((mc.group(1), trip + 1))
    else:
        for attr in ("calls", "to_apply"):
            m = re.search(attr + r"=%?([\w.\-]+)", op.line)
            if m:
                out.append((m.group(1), 1.0))
        m = re.search(r"branch_computations=\{([^}]*)\}", op.line)
        if m:
            for b in m.group(1).split(","):
                out.append((b.strip().lstrip("%"), 1.0))
    return out


def _dot_flops(op: _Op, symbols: dict[str, str]) -> float:
    outs = _shape_dims(op.type_str)
    if not outs:
        return 0.0
    out_elems = 1
    for d in outs[0][1]:
        out_elems *= d
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    contraction = 1
    # lhs operand: either "dot(%name, ..." or, in older HLO text,
    # "dot(f32[128,256]{1,0} %name, ..." with the type printed inline.
    md = re.search(r"\bdot\(\s*(?:(\w+\[[\d,]*\])\S*\s+)?%?([\w.\-]+)",
                   op.line)
    if md and mc and mc.group(1):
        lhs_text = md.group(1) or symbols.get(md.group(2), "")
        dims = _shape_dims(lhs_text)
        if dims:
            shape = dims[0][1]
            for idx in mc.group(1).split(","):
                i = int(idx)
                if i < len(shape):
                    contraction *= shape[i]
    return 2.0 * out_elems * contraction


def _collective_kind(opcode: str) -> str | None:
    base = opcode[:-6] if opcode.endswith("-start") else opcode
    return base if base in COLLECTIVES else None


def _collective_bytes(op: _Op, symbols: dict[str, str], kind: str) -> int:
    """Payload bytes moved by one execution of the collective (per device)."""
    if kind == "all-gather":
        return _shape_bytes(op.type_str)            # result = gathered tensor
    # operand bytes (all-reduce/reduce-scatter/all-to-all/permute)
    m = re.search(r"\(\s*%?([\w.\-]+)", op.line[op.line.find(op.opcode):])
    if m and m.group(1) in symbols:
        return _shape_bytes(symbols[m.group(1)])
    return _shape_bytes(op.type_str)


def tensor_shapes(hlo_text: str) -> set:
    """Every (dtype, dims) tensor shape appearing in the module text.

    Set-membership proxy for "does the compiled program materialize a buffer
    of this shape anywhere" — used by the fused-matvec tests to assert the
    (m, B) CountSketch table exists in the split scatter→gather program but
    never in the fused one-pass kernel (where the table lives only as a
    VMEM scratch tile).
    """
    out = set()
    for m in _SHAPE_RE.finditer(hlo_text):
        dtype, dims = m.groups()
        if dtype not in _DTYPE_BYTES:
            continue
        out.add((dtype,
                 tuple(int(d) for d in dims.split(",")) if dims else ()))
    return out


def materializes_shape(hlo_text: str, dims, dtype: str = "f32") -> bool:
    """True when a tensor of exactly this (dtype, dims) appears in the HLO."""
    return (dtype, tuple(int(d) for d in dims)) in tensor_shapes(hlo_text)


def count_ops(hlo_text: str, opcode: str) -> int:
    """Number of ops with this opcode across all computations (no trip
    weighting).  Used by op-count tests — e.g. the hash-join routing build
    must contain zero ``sort`` ops (it rides the blocked layout's one)."""
    comps, _, _ = _parse_module(hlo_text)
    return sum(1 for c in comps.values() for op in c.ops
               if op.opcode == opcode)


@dataclass
class HLOStats:
    flops: float = 0.0                # per-device dot FLOPs, trip-weighted
    mem_bytes: float = 0.0            # per-device HBM traffic estimate
    collective_bytes: float = 0.0     # per-device collective payload
    collective_counts: dict = field(default_factory=dict)
    collective_bytes_by_op: dict = field(default_factory=dict)
    xla_flops: float = 0.0            # cost_analysis flops (no trip counts)
    xla_bytes: float = 0.0


def analyze_hlo_text(hlo_text: str) -> HLOStats:
    comps, entry, symbols = _parse_module(hlo_text)
    if entry is None:
        return HLOStats()

    # multiplicity of each computation (BFS over the call graph)
    mult: dict[str, float] = {entry: 1.0}
    fusion_bodies: set[str] = set()
    order = [entry]
    seen = {entry}
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        cm = mult.get(cname, 0.0)
        for op in comps.get(cname, _Computation(cname)).ops:
            for callee, factor in _called_comps(op):
                if callee not in comps:
                    continue
                mult[callee] = mult.get(callee, 0.0) + cm * factor
                if op.opcode == "fusion":
                    fusion_bodies.add(callee)
                if callee not in seen:
                    seen.add(callee)
                    order.append(callee)

    stats = HLOStats()
    for cname, comp in comps.items():
        cm = mult.get(cname, 0.0)
        if cm == 0.0:
            continue
        in_fusion = cname in fusion_bodies
        for op in comp.ops:
            if op.opcode == "dot":
                stats.flops += cm * _dot_flops(op, symbols)
            kind = _collective_kind(op.opcode)
            if kind is not None and not op.opcode.endswith("-done"):
                nbytes = _collective_bytes(op, symbols, kind)
                stats.collective_counts[kind] = \
                    stats.collective_counts.get(kind, 0) + 1
                stats.collective_bytes_by_op[kind] = \
                    stats.collective_bytes_by_op.get(kind, 0.0) + cm * nbytes
                stats.collective_bytes += cm * nbytes
            if not in_fusion and op.opcode in _MEM_OPS:
                nbytes = _shape_bytes(op.type_str)
                # add operand bytes (resolve names, first 6 operands)
                for mm in re.finditer(r"%([\w.\-]+)", op.line.split("metadata")[0]):
                    if mm.group(1) == op.name:
                        continue
                    t = symbols.get(mm.group(1))
                    if t:
                        nbytes += _shape_bytes(t)
                stats.mem_bytes += cm * nbytes
    return stats


@dataclass
class Roofline:
    name: str
    chips: int
    hlo_flops: float                 # whole-program FLOPs (all chips)
    hbm_bytes: float                 # whole-program HBM bytes (all chips)
    collective_bytes: float          # per-chip collective payload bytes
    model_flops: float               # useful 6*N*D (or analog) FLOPs
    bytes_per_device: float = 0.0    # peak allocation from memory_analysis
    stats: HLOStats | None = None

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_dominant(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flop_frac(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_frac(self) -> float:
        """model-FLOPs ideal time / dominant-term time (MFU-like, derived)."""
        ideal = self.model_flops / (self.chips * PEAK_FLOPS)
        return ideal / self.t_dominant if self.t_dominant else 0.0

    def row(self) -> dict:
        return {
            "name": self.name, "chips": self.chips,
            "hlo_flops": self.hlo_flops, "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "bytes_per_device": self.bytes_per_device,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective, "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_flop_frac": self.useful_flop_frac,
            "roofline_frac": self.roofline_frac,
        }


def analyze_compiled(name: str, compiled, *, chips: int,
                     model_flops: float) -> Roofline:
    """Build a Roofline from a jax Compiled object (SPMD per-device module)."""
    stats = analyze_hlo_text(compiled.as_text())
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        stats.xla_flops = float(cost.get("flops", 0.0))
        stats.xla_bytes = float(cost.get("bytes accessed", 0.0))
    except Exception:
        pass
    peak = 0.0
    try:
        ma = compiled.memory_analysis()
        peak = float(getattr(ma, "temp_size_in_bytes", 0) +
                     getattr(ma, "argument_size_in_bytes", 0) +
                     getattr(ma, "output_size_in_bytes", 0))
    except Exception:
        pass
    return Roofline(name=name, chips=chips, hlo_flops=stats.flops * chips,
                    hbm_bytes=stats.mem_bytes * chips,
                    collective_bytes=stats.collective_bytes,
                    model_flops=model_flops, bytes_per_device=peak,
                    stats=stats)


def model_flops_train(n_params: int, tokens: int) -> float:
    """6*N*D for a dense decoder train step (fwd 2ND + bwd 4ND)."""
    return 6.0 * n_params * tokens


def model_flops_decode(n_params_active: int, batch: int) -> float:
    """2*N per generated token (matmul-dominated decode), times batch."""
    return 2.0 * n_params_active * batch
