"""Span-based tracing: nested timed regions with attributes.

``span("pcg.iter")`` is a context manager that times the enclosed block on
the host clock, nests (thread-local stack; the name you give is the name
you query — nesting is carried via ``parent``/``depth`` and attribute
inheritance rather than path concatenation, so hot-path names stay stable
dict keys), carries attributes (child spans see their ancestors' attrs merged under
theirs), and optionally opens a ``jax.profiler.TraceAnnotation`` with the
same name so host spans line up with device timelines in TensorBoard
profiles captured via ``start_trace``/``stop_trace``.

Every finished span appends its duration (microseconds) to a bounded
per-name sample buffer — that buffer is the single timing source of truth
the benchmarks read (``span_samples_us``/``span_stats``) instead of
keeping their own ``perf_counter`` pairs — and optionally feeds a registry
histogram (``to_histogram=``).

Two weights of timed region share the sample buffers: ``span`` (nesting,
attrs, per-call name resolution — for macro regions like a solve or a
benchmark iteration) and the pre-bound ``timer`` (flat, buffer + histogram
resolved once at construction — for per-request serving sites, where the
metrics-on/off p50 pin holds the budget to <=5%).  With tracing disabled
(``set_tracing(False)``) both return a shared no-op singleton and the cost
is one global load + branch.
"""
from __future__ import annotations

import threading
from collections import deque
from time import perf_counter

from . import registry as _registry

_TRACING = True          # span timing + sample collection
_JAX_ANNOTATIONS = False  # also open jax.profiler.TraceAnnotation regions

_SAMPLE_CAP = 4096  # per-name bounded buffer; old samples fall off the left

_local = threading.local()

_samples_lock = threading.Lock()
_samples: dict[str, deque] = {}


def set_tracing(flag: bool) -> bool:
    """Master switch for span timing; returns the previous value."""
    global _TRACING
    prev = _TRACING
    _TRACING = bool(flag)
    return prev


def set_jax_annotations(flag: bool) -> bool:
    """Also wrap each span in ``jax.profiler.TraceAnnotation`` (off by
    default: it costs a C++ call per span and only matters while a
    profiler trace is being captured).  Returns the previous value."""
    global _JAX_ANNOTATIONS
    prev = _JAX_ANNOTATIONS
    _JAX_ANNOTATIONS = bool(flag)
    return prev


def _stack() -> list:
    st = getattr(_local, "stack", None)
    if st is None:
        st = _local.stack = []
    return st


def current_span():
    """The innermost open span on this thread, or None."""
    st = getattr(_local, "stack", None)
    return st[-1] if st else None


def _record_sample(name: str, us: float) -> None:
    buf = _samples.get(name)
    if buf is None:
        with _samples_lock:
            buf = _samples.setdefault(name, deque(maxlen=_SAMPLE_CAP))
    buf.append(us)


def span_samples_us(name: str) -> list[float]:
    """Duration samples (microseconds) recorded for ``name``, oldest
    first, up to the buffer cap."""
    buf = _samples.get(name)
    return list(buf) if buf else []


def clear_span_samples(name: str | None = None) -> None:
    """Drop collected samples for one span name (or all) — benchmarks call
    this between tiers so each tier reads only its own iterations.  Buffers
    are cleared IN PLACE, never popped: pre-bound ``timer`` sites hold a
    direct reference to their buffer."""
    with _samples_lock:
        if name is None:
            for buf in _samples.values():
                buf.clear()
        else:
            buf = _samples.get(name)
            if buf is not None:
                buf.clear()


def span_stats(name: str) -> dict:
    """{count, mean_us, p50_us, p99_us, min_us, max_us} over the current
    sample buffer (zeros when empty)."""
    xs = sorted(span_samples_us(name))
    if not xs:
        return {"count": 0, "mean_us": 0.0, "p50_us": 0.0, "p99_us": 0.0,
                "min_us": 0.0, "max_us": 0.0}

    def pct(q):
        i = min(len(xs) - 1, max(0, int(round(q / 100 * (len(xs) - 1)))))
        return xs[i]

    return {"count": len(xs), "mean_us": sum(xs) / len(xs),
            "p50_us": pct(50), "p99_us": pct(99),
            "min_us": xs[0], "max_us": xs[-1]}


class _NoopSpan:
    """Shared do-nothing span returned while tracing is disabled."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set_attr(self, key, value):
        return self

    @property
    def attrs(self):
        return {}

    duration_us = 0.0


_NOOP = _NoopSpan()


class Span:
    __slots__ = ("name", "parent", "depth", "_attrs", "_t0", "duration_us",
                 "_hist", "_jax_ctx", "_st")

    def __init__(self, name: str, attrs: dict | None = None, hist=None):
        self.name = name
        self.parent = None
        self.depth = 0
        self._attrs = attrs
        self._t0 = 0.0
        self.duration_us = 0.0
        self._hist = hist
        self._jax_ctx = None
        self._st = None

    @property
    def attrs(self) -> dict:
        """This span's attributes merged over its ancestors' (own keys
        win).  Computed on access — the hot path never pays for it."""
        merged: dict = {}
        chain = []
        node = self
        while node is not None:
            chain.append(node)
            node = node.parent
        for node in reversed(chain):
            if node._attrs:
                merged.update(node._attrs)
        return merged

    def set_attr(self, key: str, value) -> "Span":
        if self._attrs is None:
            self._attrs = {}
        self._attrs[key] = value
        return self

    def __enter__(self) -> "Span":
        st = self._st = _stack()
        if st:
            self.parent = st[-1]
            self.depth = self.parent.depth + 1
        st.append(self)
        if _JAX_ANNOTATIONS:
            try:
                import jax.profiler
                self._jax_ctx = jax.profiler.TraceAnnotation(self.name)
                self._jax_ctx.__enter__()
            except Exception:
                self._jax_ctx = None
        self._t0 = perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        us = (perf_counter() - self._t0) * 1e6
        self.duration_us = us
        if self._jax_ctx is not None:
            try:
                self._jax_ctx.__exit__(*exc)
            except Exception:
                pass
        st = self._st
        if st and st[-1] is self:
            st.pop()
        elif self in st:            # tolerate out-of-order exits
            st.remove(self)
        _record_sample(self.name, us)
        if self._hist is not None:
            self._hist.observe(us)
        return False


def span(name: str, attrs: dict | None = None, *, to_histogram=None):
    """Open a timed span.  ``to_histogram`` takes a registry Histogram (or
    label-less Family) that additionally receives the duration."""
    if not _TRACING:
        return _NOOP
    return Span(name, attrs, to_histogram)


class _TimedSample:
    """One flat timing region opened by a ``Timer``: records into the
    pre-bound sample buffer + histogram, participates in profiler traces
    via TraceAnnotation, but skips the nesting stack and attrs entirely."""

    __slots__ = ("_name", "_buf", "_hist", "_t0", "_jax")

    def __init__(self, name, buf, hist):
        self._name = name
        self._buf = buf
        self._hist = hist
        self._t0 = 0.0
        self._jax = None

    def __enter__(self):
        if _JAX_ANNOTATIONS:
            try:
                import jax.profiler
                self._jax = jax.profiler.TraceAnnotation(self._name)
                self._jax.__enter__()
            except Exception:
                self._jax = None
        self._t0 = perf_counter()
        return self

    def __exit__(self, *exc):
        us = (perf_counter() - self._t0) * 1e6
        if self._jax is not None:
            try:
                self._jax.__exit__(*exc)
            except Exception:
                pass
        self._buf.append(us)
        if self._hist is not None:
            self._hist.observe(us)
        return False


class Timer:
    """Factory for one fixed hot call site — build once, open per call."""

    __slots__ = ("_name", "_buf", "_hist")

    def __init__(self, name, buf, hist):
        self._name = name
        self._buf = buf
        self._hist = hist

    def __call__(self):
        if not _TRACING:
            return _NOOP
        return _TimedSample(self._name, self._buf, self._hist)


def timer(name: str, *, to_histogram=None) -> Timer:
    """Pre-bound flat timer for a FIXED hot call site: resolve the sample
    buffer and histogram child once at construction, then ``with t():`` per
    call costs two ``perf_counter`` reads, one deque append, one histogram
    observe — roughly half a full ``span``.  The duration lands in the same
    per-name buffer ``span_samples_us``/``span_stats`` read, and the region
    still gets a TraceAnnotation during profiler captures; what it gives up
    is nesting (never on the thread-local stack) and attrs.  Use ``span``
    for macro regions (a solve, a benchmark iteration), ``timer`` for
    per-request serving sites."""
    with _samples_lock:
        buf = _samples.setdefault(name, deque(maxlen=_SAMPLE_CAP))
    return Timer(name, buf, to_histogram)


def annotation(name: str):
    """A named ``jax.profiler.TraceAnnotation`` region ONLY while a profiler
    trace is being captured (``start_trace``); the shared no-op otherwise.

    This is the near-free sibling of ``span`` for inner hot-path regions
    that already have their duration recorded some other way (a direct
    histogram observe) and only need a name on the TensorBoard timeline —
    it allocates nothing and records nothing outside a capture."""
    if not _JAX_ANNOTATIONS:
        return _NOOP
    try:
        import jax.profiler
        return jax.profiler.TraceAnnotation(name)
    except Exception:
        return _NOOP


# -- whole-program profiler traces (TensorBoard) -----------------------------

_trace_dir: str | None = None


def start_trace(trace_dir: str) -> bool:
    """Begin a ``jax.profiler`` trace into ``trace_dir`` (view with
    ``tensorboard --logdir``) and turn on per-span TraceAnnotations so the
    host spans appear on the trace timeline.  Returns False (and records
    nothing) if the profiler is unavailable."""
    global _trace_dir
    try:
        import jax.profiler
        jax.profiler.start_trace(trace_dir)
    except Exception:
        return False
    _trace_dir = trace_dir
    set_jax_annotations(True)
    _registry.counter(
        "trace_sessions_total", "profiler trace captures started").inc()
    return True


def stop_trace() -> str | None:
    """End the active profiler trace; returns its directory (or None)."""
    global _trace_dir
    d, _trace_dir = _trace_dir, None
    set_jax_annotations(False)
    if d is not None:
        try:
            import jax.profiler
            jax.profiler.stop_trace()
        except Exception:
            pass
    return d
