"""Low-overhead metrics registry: counters, gauges, fixed-bucket histograms.

One process-global ``REGISTRY`` (module-level helpers ``counter`` /
``gauge`` / ``histogram`` create-or-fetch families on it) plus
instantiable ``MetricsRegistry`` objects for tests that need isolation.

Design constraints (DESIGN.md §11):

* **Hot-path cost is one lock + one float op.**  Every metric child owns a
  plain ``threading.Lock``; ``inc``/``set``/``observe`` are a handful of
  bytecodes under it — ~1us on this container, against a ~400us warm serving
  call (the metrics-on/off p50 ratio is test-pinned <= 1.05x).
* **Thread-safe by construction.**  Serving records from the batcher worker
  thread, client threads, and the driver simultaneously; family creation
  and child creation are locked on the registry, recording on the child.
* **Two export formats from one store.**  ``render()`` emits the
  Prometheus text exposition (``# HELP``/``# TYPE`` + one line per sample,
  histograms as cumulative ``_bucket``/``_sum``/``_count``) for the live
  ``/metrics`` endpoint; ``snapshot()``/``write_jsonl()`` emit the same
  state as one JSON document per call for headless runs and CI artifacts.
* **Global kill switch.**  ``set_enabled(False)`` turns every recording
  call into an immediate return (one module-global load + branch) — the
  overhead-pin test measures exactly this toggle.

Metric naming follows Prometheus conventions: ``<subsystem>_<what>_<unit>``,
counters end in ``_total``, latency histograms in ``_us`` (microseconds —
the natural unit at serving scale).
"""
from __future__ import annotations

import json
import threading
import time
from bisect import bisect_left
from collections import deque

# global recording switch — checked first thing in every record call so the
# disabled path costs one global load + branch (see set_enabled)
_ENABLED = True


def set_enabled(flag: bool) -> bool:
    """Enable/disable ALL metric recording process-wide; returns the
    previous value (so callers can restore)."""
    global _ENABLED
    prev = _ENABLED
    _ENABLED = bool(flag)
    return prev


def enabled() -> bool:
    return _ENABLED


# default latency buckets (microseconds): 10us .. 10s, roughly 1-2-5 per
# decade — covers cache hits (~10us) through cold compiles (~10^7 us)
LATENCY_BUCKETS_US = (
    10.0, 25.0, 50.0, 100.0, 250.0, 500.0,
    1e3, 2.5e3, 5e3, 1e4, 2.5e4, 5e4, 1e5, 2.5e5, 5e5, 1e6, 1e7,
)

# generic small-count buckets (batch sizes, iteration counts)
COUNT_BUCKETS = (1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0,
                 512.0, 1024.0)


class Counter:
    """Monotonically increasing float."""

    __slots__ = ("_lock", "_value")

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if not _ENABLED:
            return
        if v < 0:
            raise ValueError(f"counters only go up, got inc({v})")
        with self._lock:
            self._value += v

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Gauge:
    """Point-in-time value: ``set``/``inc``/``dec``, or a pull-time callback
    (``set_fn``) for values that live elsewhere (cache sizes, queue depths)
    and should be read only when someone actually scrapes."""

    __slots__ = ("_lock", "_value", "_fn")

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0.0
        self._fn = None

    def set(self, v: float) -> None:
        if not _ENABLED:
            return
        with self._lock:
            self._value = float(v)

    def inc(self, v: float = 1.0) -> None:
        if not _ENABLED:
            return
        with self._lock:
            self._value += v

    def dec(self, v: float = 1.0) -> None:
        self.inc(-v)

    def set_fn(self, fn) -> None:
        """Register a zero-arg callable evaluated at collection time (its
        result replaces the stored value; exceptions degrade to the last
        stored value rather than failing the scrape)."""
        with self._lock:
            self._fn = fn

    @property
    def value(self) -> float:
        with self._lock:
            if self._fn is not None:
                try:
                    self._value = float(self._fn())
                except Exception:
                    pass
            return self._value


class Histogram:
    """Fixed-bucket histogram: per-bucket counts + sum + count.

    ``buckets`` are upper bounds (ascending); an implicit +Inf bucket
    catches the tail.  ``observe`` is one bisect + three adds under the
    child lock — no allocation.
    """

    __slots__ = ("_lock", "buckets", "_counts", "_sum", "_count", "_pending")

    # pending-buffer backpressure: past this many unfolded values, the
    # recording thread folds inline instead of deferring further
    PENDING_CAP = 65536

    def __init__(self, buckets=LATENCY_BUCKETS_US):
        b = tuple(float(x) for x in buckets)
        if not b or any(b[i] >= b[i + 1] for i in range(len(b) - 1)):
            raise ValueError(f"buckets must be ascending and non-empty: {b}")
        self._lock = threading.Lock()
        self.buckets = b
        self._counts = [0] * (len(b) + 1)      # last = +Inf
        self._sum = 0.0
        self._count = 0
        self._pending = deque()                # observe_many: fold-on-read

    def observe(self, v: float) -> None:
        if not _ENABLED:
            return
        i = bisect_left(self.buckets, v)
        with self._lock:
            self._counts[i] += 1
            self._sum += v
            self._count += 1

    def observe_many(self, values) -> None:
        """Record a whole batch of values at C speed: one ``deque.extend``,
        with the bucketing deferred to the next read (``state``/``count``/
        ``sum``, i.e. a scrape or a test assert).  The serving flush path
        observes one queue-wait per coalesced row, and per-row bucketing
        there — even batched under one lock — measurably moves the
        metrics-on p50; extend+fold-on-read keeps exact histograms while the
        recording thread pays ~2us for 64 rows.  ``PENDING_CAP`` bounds the
        unfolded backlog (a recorder that outruns every scraper folds
        inline)."""
        if not _ENABLED or not len(values):
            return
        self._pending.extend(values)
        if len(self._pending) > self.PENDING_CAP:
            self._fold()

    def _fold(self) -> None:
        """Drain the pending buffer into the bucket counts.  Concurrent
        ``extend``s during the drain simply land in the next fold —
        ``deque`` append/popleft are individually atomic under CPython."""
        p = self._pending
        if not p:
            return
        b = self.buckets
        with self._lock:
            counts = self._counts
            s = 0.0
            n = 0
            while True:
                try:
                    v = p.popleft()
                except IndexError:
                    break
                counts[bisect_left(b, v)] += 1
                s += v
                n += 1
            self._sum += s
            self._count += n

    @property
    def count(self) -> int:
        self._fold()
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        self._fold()
        with self._lock:
            return self._sum

    def state(self):
        """(cumulative bucket counts incl. +Inf, sum, count) — one lock
        after folding any deferred ``observe_many`` values."""
        self._fold()
        with self._lock:
            cum, acc = [], 0
            for c in self._counts:
                acc += c
                cum.append(acc)
            return cum, self._sum, self._count


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class Family:
    """One named metric + its labeled children.  ``labels(v1, v2, ...)``
    creates/fetches the child for those label VALUES (label names are fixed
    per family); a label-less family has a single default child reachable by
    calling the record methods on the family itself."""

    def __init__(self, name: str, kind: str, help: str = "",
                 labelnames: tuple[str, ...] = (), **kwargs):
        self.name = name
        self.kind = kind
        self.help = help
        self.labelnames = tuple(labelnames)
        self._kwargs = kwargs
        self._lock = threading.Lock()
        self._children: dict[tuple[str, ...], object] = {}
        if not self.labelnames:
            self._children[()] = _KINDS[kind](**kwargs)

    def labels(self, *values) -> Counter | Gauge | Histogram:
        key = tuple(str(v) for v in values)
        if len(key) != len(self.labelnames):
            raise ValueError(f"{self.name} takes labels {self.labelnames}, "
                             f"got {values!r}")
        child = self._children.get(key)
        if child is None:
            with self._lock:
                child = self._children.setdefault(key, _KINDS[self.kind](
                    **self._kwargs))
        return child

    def _default(self):
        if self.labelnames:
            raise ValueError(f"{self.name} has labels {self.labelnames}; "
                             f"call .labels(...) first")
        return self._children[()]

    # label-less convenience: family acts as its single child
    def inc(self, v: float = 1.0) -> None:
        self._default().inc(v)

    def set(self, v: float) -> None:
        self._default().set(v)

    def set_fn(self, fn) -> None:
        self._default().set_fn(fn)

    def observe(self, v: float) -> None:
        self._default().observe(v)

    def observe_many(self, values) -> None:
        self._default().observe_many(values)

    @property
    def value(self):
        return self._default().value

    def state(self):
        return self._default().state()

    def children(self):
        with self._lock:
            return list(self._children.items())


def _fmt(v: float) -> str:
    """Prometheus sample formatting: integers render bare."""
    return str(int(v)) if float(v).is_integer() and abs(v) < 1e15 else repr(v)


def _labelstr(names, values) -> str:
    if not names:
        return ""
    pairs = ",".join(f'{n}="{v}"' for n, v in zip(names, values))
    return "{" + pairs + "}"


class MetricsRegistry:
    """Name -> Family store with the two exporters."""

    def __init__(self):
        self._lock = threading.Lock()
        self._families: dict[str, Family] = {}

    def _family(self, name: str, kind: str, help: str,
                labels: tuple[str, ...], **kwargs) -> Family:
        fam = self._families.get(name)
        if fam is None:
            with self._lock:
                fam = self._families.get(name)
                if fam is None:
                    fam = Family(name, kind, help, labels, **kwargs)
                    self._families[name] = fam
        if fam.kind != kind or fam.labelnames != tuple(labels):
            raise ValueError(
                f"metric {name!r} already registered as {fam.kind} with "
                f"labels {fam.labelnames}, requested {kind}/{tuple(labels)}")
        return fam

    def counter(self, name: str, help: str = "",
                labels: tuple[str, ...] = ()) -> Family:
        return self._family(name, "counter", help, labels)

    def gauge(self, name: str, help: str = "",
              labels: tuple[str, ...] = ()) -> Family:
        return self._family(name, "gauge", help, labels)

    def histogram(self, name: str, help: str = "",
                  labels: tuple[str, ...] = (),
                  buckets=LATENCY_BUCKETS_US) -> Family:
        return self._family(name, "histogram", help, labels, buckets=buckets)

    def reset(self) -> None:
        """Drop every family (tests; the live endpoint never calls this)."""
        with self._lock:
            self._families.clear()

    def _sorted_families(self):
        with self._lock:
            return sorted(self._families.values(), key=lambda f: f.name)

    # -- Prometheus text exposition -----------------------------------------

    def render(self) -> str:
        """Text exposition (version 0.0.4): the /metrics payload."""
        lines: list[str] = []
        for fam in self._sorted_families():
            if fam.help:
                lines.append(f"# HELP {fam.name} {fam.help}")
            lines.append(f"# TYPE {fam.name} {fam.kind}")
            for lv, child in sorted(fam.children()):
                ls = _labelstr(fam.labelnames, lv)
                if fam.kind == "histogram":
                    cum, total, count = child.state()
                    uppers = [*(_fmt(b) for b in child.buckets), "+Inf"]
                    for ub, c in zip(uppers, cum):
                        sep = "," if ls else ""
                        pre = ls[:-1] + sep if ls else "{"
                        lines.append(
                            f'{fam.name}_bucket{pre}le="{ub}"}} {c}')
                    lines.append(f"{fam.name}_sum{ls} {_fmt(total)}")
                    lines.append(f"{fam.name}_count{ls} {count}")
                else:
                    lines.append(f"{fam.name}{ls} {_fmt(child.value)}")
        return "\n".join(lines) + ("\n" if lines else "")

    # -- JSON snapshot -------------------------------------------------------

    def snapshot(self) -> dict:
        """The registry as one JSON-able dict (same data as ``render``)."""
        out: dict = {}
        for fam in self._sorted_families():
            series = []
            for lv, child in sorted(fam.children()):
                labels = dict(zip(fam.labelnames, lv))
                if fam.kind == "histogram":
                    cum, total, count = child.state()
                    series.append({"labels": labels,
                                   "buckets": list(child.buckets),
                                   "cumulative": cum,
                                   "sum": total, "count": count})
                else:
                    series.append({"labels": labels, "value": child.value})
            out[fam.name] = {"kind": fam.kind, "help": fam.help,
                             "series": series}
        return out

    def write_jsonl(self, path: str, extra: dict | None = None) -> dict:
        """Append ONE line — ``{"ts": ..., **extra, "metrics": snapshot}`` —
        to ``path`` (the per-run perf-trajectory format CI uploads next to
        the bench JSONs).  Returns the record."""
        record = {"ts": time.time(), **(extra or {}),
                  "metrics": self.snapshot()}
        with open(path, "a") as fh:
            fh.write(json.dumps(record) + "\n")
        return record


# the process-global registry every instrumented module records into
REGISTRY = MetricsRegistry()


def counter(name: str, help: str = "", labels: tuple[str, ...] = ()) -> Family:
    return REGISTRY.counter(name, help, labels)


def gauge(name: str, help: str = "", labels: tuple[str, ...] = ()) -> Family:
    return REGISTRY.gauge(name, help, labels)


def histogram(name: str, help: str = "", labels: tuple[str, ...] = (),
              buckets=LATENCY_BUCKETS_US) -> Family:
    return REGISTRY.histogram(name, help, labels, buckets=buckets)
