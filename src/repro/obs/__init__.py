"""Unified telemetry: metrics registry, span tracing, live stats endpoint.

Instrumented call sites across the repo do::

    from repro import obs

    obs.counter("serve_cache_hits_total").inc(n)
    with obs.span("serve.predict", to_histogram=obs.histogram(
            "serve_predict_us")):
        ...

and a serving or training process exposes everything via
``obs.serve_metrics(port)`` (live Prometheus text + /healthz) or
``obs.REGISTRY.write_jsonl(path)`` (headless snapshot).  See DESIGN.md
§11 for the signal catalog and the overhead budget.
"""
from .registry import (
    COUNT_BUCKETS,
    LATENCY_BUCKETS_US,
    MetricsRegistry,
    REGISTRY,
    counter,
    enabled,
    gauge,
    histogram,
    set_enabled,
)
from .tracing import (
    annotation,
    clear_span_samples,
    current_span,
    set_jax_annotations,
    set_tracing,
    span,
    span_samples_us,
    span_stats,
    start_trace,
    stop_trace,
    timer,
)
from .http import (
    MetricsServer,
    add_health_provider,
    health_document,
    remove_health_provider,
    serve_metrics,
)

__all__ = [
    "COUNT_BUCKETS",
    "LATENCY_BUCKETS_US",
    "MetricsRegistry",
    "REGISTRY",
    "MetricsServer",
    "add_health_provider",
    "annotation",
    "clear_span_samples",
    "counter",
    "current_span",
    "enabled",
    "gauge",
    "health_document",
    "histogram",
    "remove_health_provider",
    "serve_metrics",
    "set_enabled",
    "set_jax_annotations",
    "set_tracing",
    "span",
    "span_samples_us",
    "span_stats",
    "start_trace",
    "stop_trace",
    "timer",
]
