"""Live stats endpoint: stdlib HTTP server exposing /metrics + /healthz.

``serve_metrics(port)`` starts a daemon ``ThreadingHTTPServer``:

* ``GET /metrics``  -> Prometheus text exposition of the global registry
  (``text/plain; version=0.0.4``) — point a Prometheus scraper or plain
  ``curl`` at it.
* ``GET /healthz``  -> JSON health document.  Callers register named
  health providers (``add_health_provider("predictor", pred.health)``);
  the endpoint runs them at request time and returns 200 only if every
  provider ran AND reported itself healthy — a provider that raises gets
  status "error", one whose dict says ``"ok": False`` (a runtime with no
  active version, a crashed batcher worker) gets status "degraded"; both
  answer 503 so a load balancer pulls the replica without the document
  losing the detail of WHAT degraded.

Everything runs on daemon threads so a serving process exits normally;
``MetricsServer.close()`` shuts the listener down deterministically (the
selftest binds port 0, scrapes itself, then closes).
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

from .registry import REGISTRY

_health_lock = threading.Lock()
_health_providers: dict[str, object] = {}


def add_health_provider(name: str, fn) -> None:
    """Register ``fn()`` (returning a JSON-able dict) under ``name`` in the
    /healthz document; re-registering a name replaces it."""
    with _health_lock:
        _health_providers[name] = fn


def remove_health_provider(name: str) -> None:
    with _health_lock:
        _health_providers.pop(name, None)


def health_document() -> tuple[dict, bool]:
    """(document, ok) — runs every registered provider.

    ``status``: "ok" / "degraded" (a provider's dict reports ``ok: False`` —
    the component answered, and what it said is bad) / "error" (a provider
    raised).  ``ok`` is True only for "ok" — the HTTP layer maps the other
    two to 503/500 so load balancers act on them.
    """
    with _health_lock:
        providers = dict(_health_providers)
    doc: dict = {"status": "ok", "components": {}}
    for name, fn in sorted(providers.items()):
        try:
            snap = fn()
            doc["components"][name] = snap
            if isinstance(snap, dict) and snap.get("ok") is False \
                    and doc["status"] == "ok":
                doc["status"] = "degraded"
        except Exception as e:  # a failing component degrades, not crashes
            doc["status"] = "error"
            doc["components"][name] = {"error": f"{type(e).__name__}: {e}"}
    return doc, doc["status"] == "ok"


class _Handler(BaseHTTPRequestHandler):
    # registry injected per-server via a subclass attribute
    registry = REGISTRY

    def _send(self, code: int, body: bytes, ctype: str) -> None:
        self.send_response(code)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def do_GET(self) -> None:  # noqa: N802 (http.server API)
        path = self.path.split("?", 1)[0]
        if path == "/metrics":
            body = self.registry.render().encode()
            self._send(200, body, "text/plain; version=0.0.4; charset=utf-8")
        elif path == "/healthz":
            doc, ok = health_document()
            code = 200 if ok else (503 if doc["status"] == "degraded"
                                   else 500)
            body = (json.dumps(doc, indent=2, default=str) + "\n").encode()
            self._send(code, body, "application/json")
        else:
            self._send(404, b"not found\n", "text/plain")

    def log_message(self, fmt, *args) -> None:
        pass  # scrapes every few seconds would spam the serving log


class MetricsServer:
    """A running /metrics + /healthz listener.  ``port`` is the BOUND port
    (pass 0 to let the OS pick — the selftest does)."""

    def __init__(self, port: int, host: str = "127.0.0.1", registry=None):
        handler = type("_BoundHandler", (_Handler,),
                       {"registry": registry or REGISTRY})
        self._httpd = ThreadingHTTPServer((host, port), handler)
        self._httpd.daemon_threads = True
        self.host = host
        self.port = self._httpd.server_address[1]
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="metrics-http",
            daemon=True)
        self._thread.start()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5.0)


def serve_metrics(port: int, host: str = "127.0.0.1") -> MetricsServer:
    """Start the endpoint on ``host:port`` (daemon threads; returns the
    server handle — keep it or let it run for the process lifetime)."""
    return MetricsServer(port, host)
