"""Deterministic fault-injection harness (chaos testing — DESIGN.md §9)."""
from .faults import (FaultPlan, apply_wire_fault, crash_worker,
                     killed_checkpoint_writer, maybe_stall, poison_matvec,
                     preempt_after, serve_fault)

__all__ = ["FaultPlan", "apply_wire_fault", "crash_worker",
           "killed_checkpoint_writer", "maybe_stall", "poison_matvec",
           "preempt_after", "serve_fault"]
