"""Deterministic fault injection for the resilience layer (DESIGN.md §9).

A ``FaultPlan`` is a hashable NamedTuple threaded through ``KRRStepConfig``
and ``Predictor`` — trace-time static, so the injected faults are part of the
compiled program and every run with the same plan (and seed) poisons the same
wire cells.  That determinism is the whole point: the chaos tests assert
*exact* recovery behavior, not flaky coin flips.

Injection points:

* wire cells       — ``apply_wire_fault`` in ``_hashjoin_send`` drops or
                     NaN-poisons all_to_all payload cells (Bernoulli masks
                     from a fixed PRNG key, identical on every shard).
* shard stall      — ``maybe_stall`` sleeps inside one shard's step via
                     ``jax.debug.callback`` (detected by wall-clock timeout:
                     the collective can't complete until the straggler does).
* checkpoint write — ``killed_checkpoint_writer`` arms a hook in
                     ``checkpoint.store.save_checkpoint`` that raises between
                     the array write and the atomic rename — the crash window
                     a real SIGKILL would hit.
* batcher worker   — ``crash_worker`` arms the MicroBatcher's fault hook so
                     the worker thread dies OUTSIDE the predict try/except
                     (a predict_fn exception is already handled; a genuine
                     worker crash is not simulable through it).
* solver matvec    — ``poison_matvec`` wraps a matvec to NaN one column.
* predictor        — ``serve_fault`` stalls or fails warm-path calls per the
                     plan (drives load-shedding/deadline tests with real
                     latency, no monkeypatching).
* artifact on disk — ``poison_artifact_tables`` corrupts a PUBLISHED
                     artifact's tables in place (bitrot / bad replication):
                     the model that exported it was healthy, so its recorded
                     golden predictions disagree — the lifecycle canary's
                     bread-and-butter catch.
* canary readout   — ``canary_poison`` arms a ServingRuntime hook that
                     perturbs canary predictions only (serving path clean),
                     isolating the reject logic from real model damage.
* torn publish     — ``torn_publish`` exports a version under a killed
                     checkpoint writer, leaving exactly what a SIGKILL'd
                     publisher leaves; the watcher must not see it.
* supervised worker— ``crash_supervised_workers`` kills the next N workers a
                     SupervisedBatcher spawns (the hook re-arms across
                     restarts), driving breaker-trip + half-open recovery.

Host-side faults raise ``repro.errors.FaultInjected`` so tests can tell an
injected fault from a genuine bug.
"""
from __future__ import annotations

import contextlib
import itertools
import time
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..errors import FaultInjected

Array = jnp.ndarray


class FaultPlan(NamedTuple):
    """Static description of the faults to inject.  All fields default to
    'off'; a plan is hashable so it can ride a NamedTuple config through
    trace-time closures."""

    wire_drop_frac: float = 0.0    # fraction of wire cells zeroed (lost mass)
    wire_nan_frac: float = 0.0     # fraction of wire cells NaN-poisoned
    wire_nan_bf16_only: bool = False  # poison only bf16 payloads — the
                                      # f32-wire retry then runs clean
    seed: int = 0                  # PRNG key for the cell masks
    stall_shard: int = -1          # data-shard index to stall (-1 = off)
    stall_s: float = 0.0           # stall duration (host sleep per step call)
    serve_delay_s: float = 0.0     # predictor warm-path stall per call
    serve_fail_every: int = 0      # raise FaultInjected every Nth warm call

    @property
    def wants_wire(self) -> bool:
        return self.wire_drop_frac > 0.0 or self.wire_nan_frac > 0.0


def apply_wire_fault(plan: FaultPlan | None, payload: Array) -> Array:
    """Drop/poison cells of an all_to_all payload (n_shards, cap[, k]).

    The Bernoulli masks come from ``plan.seed`` only — every shard (and every
    retry with the same plan) poisons the same (destination, cell) pairs, so
    a test can pin exactly what the recovery path must absorb.  NaN poisoning
    can be restricted to bf16 payloads (``wire_nan_bf16_only``) to exercise
    the bf16→f32 wire retry: the retry's f32 exchange runs clean.
    """
    if plan is None or not plan.wants_wire:
        return payload
    nan_frac = plan.wire_nan_frac
    if plan.wire_nan_bf16_only and payload.dtype != jnp.bfloat16:
        nan_frac = 0.0
    if plan.wire_drop_frac <= 0.0 and nan_frac <= 0.0:
        return payload
    cells = payload.shape[:2]
    kd, kn = jax.random.split(jax.random.PRNGKey(plan.seed))
    drop = jax.random.bernoulli(kd, plan.wire_drop_frac, cells)
    nan = jax.random.bernoulli(kn, nan_frac, cells)
    if payload.ndim == 3:
        drop, nan = drop[..., None], nan[..., None]
    out = jnp.where(drop, jnp.zeros((), payload.dtype), payload)
    return jnp.where(nan, jnp.asarray(jnp.nan, payload.dtype), out)


def _stall_cb(shard_idx, *, shard: int, secs: float) -> None:
    if int(shard_idx) == shard:
        time.sleep(secs)


def maybe_stall(plan: FaultPlan | None, data_axes) -> None:
    """Inside shard_map: sleep ``plan.stall_s`` on data shard
    ``plan.stall_shard``.  The straggler holds up every collective it
    participates in — the detection signal is wall-clock (pytest-timeout in
    CI), the recovery is the scheduler's, not ours."""
    if plan is None or plan.stall_s <= 0.0 or plan.stall_shard < 0:
        return
    import functools
    sid = jax.lax.axis_index(data_axes[-1])
    jax.debug.callback(functools.partial(_stall_cb, shard=plan.stall_shard,
                                         secs=plan.stall_s), sid)


@contextlib.contextmanager
def killed_checkpoint_writer(after_saves: int = 0):
    """Arm ``checkpoint.store``'s crash hook: the save that lands after
    ``after_saves`` clean ones raises ``FaultInjected`` between writing
    arrays.npz and the atomic rename — exactly the window a SIGKILL'd writer
    leaves a ``step_N.tmp`` dir with a full payload but no visibility to
    ``latest_step``."""
    from ..checkpoint import store
    counter = itertools.count()

    def boom(tmp_path: str) -> None:
        if next(counter) >= after_saves:
            raise FaultInjected(
                f"checkpoint writer killed mid-save in {tmp_path}")

    prev = store._crash_mid_save
    store._crash_mid_save = boom
    try:
        yield
    finally:
        store._crash_mid_save = prev


def preempt_after(n_checkpoints: int):
    """Returns an ``on_solve_checkpoint`` callback that raises
    ``FaultInjected`` after ``n_checkpoints`` successful checkpoint saves —
    simulates a preemption mid-solve (the state for the last completed
    chunk is already on disk, so the next fit resumes from it)."""
    counter = itertools.count(1)

    def hook(state) -> None:
        if next(counter) >= n_checkpoints:
            raise FaultInjected(
                f"solve preempted after checkpoint at it={int(state.it)}")

    return hook


def crash_worker(batcher, exc: BaseException | None = None) -> None:
    """Arm the MicroBatcher's fault hook so the NEXT batch kills the worker
    thread itself (outside the predict try/except — a real crash, not a
    predict error).  In-flight and queued futures must fail with
    ``WorkerCrashed``; subsequent submits must fail fast."""
    err = exc if exc is not None else FaultInjected("worker thread killed")

    def hook(batch) -> None:
        raise err

    batcher._fault_hook = hook


def serve_fault(plan: FaultPlan | None, call_idx: int) -> None:
    """Predictor warm-path injection: stall ``serve_delay_s`` per call and
    raise ``FaultInjected`` every ``serve_fail_every``-th call (1-based)."""
    if plan is None:
        return
    if plan.serve_delay_s > 0.0:
        time.sleep(plan.serve_delay_s)
    if plan.serve_fail_every > 0 and (call_idx % plan.serve_fail_every) == 0:
        raise FaultInjected(f"injected predict failure (call {call_idx})")


def poison_artifact_tables(directory: str, scale: float = 3.0) -> int:
    """Corrupt a PUBLISHED artifact's hash tables on disk, in place.

    Rewrites every ``arrays.npz`` under ``directory`` (flat artifacts have
    one completed checkpoint step; sharded ones a step per piece) with its
    ``tables`` entry scaled by ``scale`` — finite but WRONG, the shape of
    damage structural validation cannot catch (bitrot, a bad replica, a
    partially-applied rewrite).  The recorded golden predictions were made
    by the healthy pre-poison model, so the lifecycle canary must reject
    the version.  Returns the number of npz payloads rewritten.
    """
    import os

    import numpy as np

    rewritten = 0
    for base, _dirs, files in os.walk(directory):
        if "arrays.npz" not in files or base.endswith(".tmp"):
            continue
        path = os.path.join(base, "arrays.npz")
        with np.load(path) as npz:
            arrays = {k: npz[k] for k in npz.files}
        # checkpoint flattening stringifies the state path, so the tables
        # land under a key like "['tables']" — match by substring
        keys = [k for k in arrays if "tables" in k]
        if not keys:
            continue
        for k in keys:
            arrays[k] = arrays[k] * np.float32(scale)
        np.savez(path, **arrays)
        rewritten += 1
    if rewritten == 0:
        raise FaultInjected(
            f"poison_artifact_tables: no tables payload under {directory}")
    return rewritten


@contextlib.contextmanager
def canary_poison(runtime, mode: str = "offset", magnitude: float = 1.0):
    """Arm a ServingRuntime's canary hook so canary predictions — and ONLY
    canary predictions — come back perturbed (``offset``) or non-finite
    (``nan``).  The hosted model itself is untouched: the serving path would
    answer correctly, which is exactly the point — the test isolates the
    reject/quarantine logic from real model damage."""

    def hook(got):
        if mode == "nan":
            got[..., 0] = float("nan")
            return got
        return got + magnitude

    prev = runtime._canary_hook
    runtime._canary_hook = hook
    try:
        yield
    finally:
        runtime._canary_hook = prev


def torn_publish(directory: str, model, norm=None, *,
                 mesh_shape: tuple[int, int] | None = None,
                 after_saves: int = 0, **export_kwargs) -> None:
    """Publish a version TORN: run the export under a killed checkpoint
    writer (crash after ``after_saves`` clean piece saves), swallowing the
    injected crash.  Leaves what a SIGKILL'd publisher leaves — a flat
    artifact with only a ``step_N.tmp``, or a sharded one with some pieces
    but no manifest (manifest is written LAST).  The lifecycle watcher must
    treat the version as unpublished."""
    from ..serve.artifact import export_artifact, export_artifact_sharded

    with killed_checkpoint_writer(after_saves):
        try:
            if mesh_shape is not None:
                export_artifact_sharded(directory, model, norm=norm,
                                        mesh_shape=mesh_shape,
                                        **export_kwargs)
            else:
                export_artifact(directory, model, norm=norm, **export_kwargs)
        except FaultInjected:
            pass


def crash_supervised_workers(sup, crashes: int = 1,
                             exc: BaseException | None = None) -> None:
    """Arm a SupervisedBatcher so its next ``crashes`` workers die on their
    first batch.  The hook lives on the SUPERVISOR (``_worker_fault_hook``),
    which re-arms it on every fresh worker it spawns — so consecutive
    restarts keep crashing until the countdown runs out, then the next
    worker serves cleanly: the exact sequence that trips a breaker closed ->
    open and recovers it through a half-open probe."""
    err = exc if exc is not None else FaultInjected("worker thread killed")
    remaining = itertools.count(1)

    def hook(batch) -> None:
        n = next(remaining)
        if n <= crashes:
            if n >= crashes:
                sup._worker_fault_hook = None    # countdown spent: disarm
            raise err

    sup._worker_fault_hook = hook
    sup._mb._fault_hook = hook     # current worker too, not just future ones


def poison_matvec(matvec, column: int = 0):
    """Wrap a (n,)/(n, k) matvec so ``column`` of its output is NaN — the
    single-host analogue of a poisoned wire cell.  ``pcg_solve`` must
    deactivate that column (NaN resnorm sentinel) while the others converge
    untouched."""

    def wrapped(v):
        out = matvec(v)
        if out.ndim == 1:
            return out + jnp.nan if column == 0 else out
        return out.at[:, column].set(jnp.nan)

    return wrapped
