"""Deterministic fault injection for the resilience layer (DESIGN.md §9).

A ``FaultPlan`` is a hashable NamedTuple threaded through ``KRRStepConfig``
and ``Predictor`` — trace-time static, so the injected faults are part of the
compiled program and every run with the same plan (and seed) poisons the same
wire cells.  That determinism is the whole point: the chaos tests assert
*exact* recovery behavior, not flaky coin flips.

Injection points:

* wire cells       — ``apply_wire_fault`` in ``_hashjoin_send`` drops or
                     NaN-poisons all_to_all payload cells (Bernoulli masks
                     from a fixed PRNG key, identical on every shard).
* shard stall      — ``maybe_stall`` sleeps inside one shard's step via
                     ``jax.debug.callback`` (detected by wall-clock timeout:
                     the collective can't complete until the straggler does).
* checkpoint write — ``killed_checkpoint_writer`` arms a hook in
                     ``checkpoint.store.save_checkpoint`` that raises between
                     the array write and the atomic rename — the crash window
                     a real SIGKILL would hit.
* batcher worker   — ``crash_worker`` arms the MicroBatcher's fault hook so
                     the worker thread dies OUTSIDE the predict try/except
                     (a predict_fn exception is already handled; a genuine
                     worker crash is not simulable through it).
* solver matvec    — ``poison_matvec`` wraps a matvec to NaN one column.
* predictor        — ``serve_fault`` stalls or fails warm-path calls per the
                     plan (drives load-shedding/deadline tests with real
                     latency, no monkeypatching).

Host-side faults raise ``repro.errors.FaultInjected`` so tests can tell an
injected fault from a genuine bug.
"""
from __future__ import annotations

import contextlib
import itertools
import time
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..errors import FaultInjected

Array = jnp.ndarray


class FaultPlan(NamedTuple):
    """Static description of the faults to inject.  All fields default to
    'off'; a plan is hashable so it can ride a NamedTuple config through
    trace-time closures."""

    wire_drop_frac: float = 0.0    # fraction of wire cells zeroed (lost mass)
    wire_nan_frac: float = 0.0     # fraction of wire cells NaN-poisoned
    wire_nan_bf16_only: bool = False  # poison only bf16 payloads — the
                                      # f32-wire retry then runs clean
    seed: int = 0                  # PRNG key for the cell masks
    stall_shard: int = -1          # data-shard index to stall (-1 = off)
    stall_s: float = 0.0           # stall duration (host sleep per step call)
    serve_delay_s: float = 0.0     # predictor warm-path stall per call
    serve_fail_every: int = 0      # raise FaultInjected every Nth warm call

    @property
    def wants_wire(self) -> bool:
        return self.wire_drop_frac > 0.0 or self.wire_nan_frac > 0.0


def apply_wire_fault(plan: FaultPlan | None, payload: Array) -> Array:
    """Drop/poison cells of an all_to_all payload (n_shards, cap[, k]).

    The Bernoulli masks come from ``plan.seed`` only — every shard (and every
    retry with the same plan) poisons the same (destination, cell) pairs, so
    a test can pin exactly what the recovery path must absorb.  NaN poisoning
    can be restricted to bf16 payloads (``wire_nan_bf16_only``) to exercise
    the bf16→f32 wire retry: the retry's f32 exchange runs clean.
    """
    if plan is None or not plan.wants_wire:
        return payload
    nan_frac = plan.wire_nan_frac
    if plan.wire_nan_bf16_only and payload.dtype != jnp.bfloat16:
        nan_frac = 0.0
    if plan.wire_drop_frac <= 0.0 and nan_frac <= 0.0:
        return payload
    cells = payload.shape[:2]
    kd, kn = jax.random.split(jax.random.PRNGKey(plan.seed))
    drop = jax.random.bernoulli(kd, plan.wire_drop_frac, cells)
    nan = jax.random.bernoulli(kn, nan_frac, cells)
    if payload.ndim == 3:
        drop, nan = drop[..., None], nan[..., None]
    out = jnp.where(drop, jnp.zeros((), payload.dtype), payload)
    return jnp.where(nan, jnp.asarray(jnp.nan, payload.dtype), out)


def _stall_cb(shard_idx, *, shard: int, secs: float) -> None:
    if int(shard_idx) == shard:
        time.sleep(secs)


def maybe_stall(plan: FaultPlan | None, data_axes) -> None:
    """Inside shard_map: sleep ``plan.stall_s`` on data shard
    ``plan.stall_shard``.  The straggler holds up every collective it
    participates in — the detection signal is wall-clock (pytest-timeout in
    CI), the recovery is the scheduler's, not ours."""
    if plan is None or plan.stall_s <= 0.0 or plan.stall_shard < 0:
        return
    import functools
    sid = jax.lax.axis_index(data_axes[-1])
    jax.debug.callback(functools.partial(_stall_cb, shard=plan.stall_shard,
                                         secs=plan.stall_s), sid)


@contextlib.contextmanager
def killed_checkpoint_writer(after_saves: int = 0):
    """Arm ``checkpoint.store``'s crash hook: the save that lands after
    ``after_saves`` clean ones raises ``FaultInjected`` between writing
    arrays.npz and the atomic rename — exactly the window a SIGKILL'd writer
    leaves a ``step_N.tmp`` dir with a full payload but no visibility to
    ``latest_step``."""
    from ..checkpoint import store
    counter = itertools.count()

    def boom(tmp_path: str) -> None:
        if next(counter) >= after_saves:
            raise FaultInjected(
                f"checkpoint writer killed mid-save in {tmp_path}")

    prev = store._crash_mid_save
    store._crash_mid_save = boom
    try:
        yield
    finally:
        store._crash_mid_save = prev


def preempt_after(n_checkpoints: int):
    """Returns an ``on_solve_checkpoint`` callback that raises
    ``FaultInjected`` after ``n_checkpoints`` successful checkpoint saves —
    simulates a preemption mid-solve (the state for the last completed
    chunk is already on disk, so the next fit resumes from it)."""
    counter = itertools.count(1)

    def hook(state) -> None:
        if next(counter) >= n_checkpoints:
            raise FaultInjected(
                f"solve preempted after checkpoint at it={int(state.it)}")

    return hook


def crash_worker(batcher, exc: BaseException | None = None) -> None:
    """Arm the MicroBatcher's fault hook so the NEXT batch kills the worker
    thread itself (outside the predict try/except — a real crash, not a
    predict error).  In-flight and queued futures must fail with
    ``WorkerCrashed``; subsequent submits must fail fast."""
    err = exc if exc is not None else FaultInjected("worker thread killed")

    def hook(batch) -> None:
        raise err

    batcher._fault_hook = hook


def serve_fault(plan: FaultPlan | None, call_idx: int) -> None:
    """Predictor warm-path injection: stall ``serve_delay_s`` per call and
    raise ``FaultInjected`` every ``serve_fail_every``-th call (1-based)."""
    if plan is None:
        return
    if plan.serve_delay_s > 0.0:
        time.sleep(plan.serve_delay_s)
    if plan.serve_fail_every > 0 and (call_idx % plan.serve_fail_every) == 0:
        raise FaultInjected(f"injected predict failure (call {call_idx})")


def poison_matvec(matvec, column: int = 0):
    """Wrap a (n,)/(n, k) matvec so ``column`` of its output is NaN — the
    single-host analogue of a poisoned wire cell.  ``pcg_solve`` must
    deactivate that column (NaN resnorm sentinel) while the others converge
    untouched."""

    def wrapped(v):
        out = matvec(v)
        if out.ndim == 1:
            return out + jnp.nan if column == 0 else out
        return out.at[:, column].set(jnp.nan)

    return wrapped
