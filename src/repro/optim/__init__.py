from .adamw import AdamWConfig, adamw_init, adamw_update, cosine_schedule, \
    global_norm
from .compression import compressed_psum, dequantize_int8, quantize_int8
