"""AdamW with decoupled weight decay, global-norm clipping, and a cosine
learning-rate schedule with linear warmup.  Pure pytree functions — the
optimizer state shards exactly like the parameters (the dry-run relies on
this: m/v inherit each parameter's logical axes)."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Array = jnp.ndarray


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


class AdamWState(NamedTuple):
    step: Array
    m: Any
    v: Any


def adamw_init(params: Any) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def cosine_schedule(cfg: AdamWConfig, step: Array) -> Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    decay_t = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    decay_t = jnp.clip(decay_t, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * decay_t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree: Any) -> Array:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def adamw_update(cfg: AdamWConfig, grads: Any, state: AdamWState, params: Any):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = cosine_schedule(cfg, step)
    b1c = 1 - cfg.beta1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.beta2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.beta1 * m + (1 - cfg.beta1) * g
        v = cfg.beta2 * v + (1 - cfg.beta2) * g * g
        mh, vh = m / b1c, v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step=step, m=new_m, v=new_v), \
        {"grad_norm": gnorm, "lr": lr}
