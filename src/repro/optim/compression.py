"""Int8 stochastic-rounding gradient compression for cross-pod reductions.

On a multi-pod mesh, the intra-pod gradient reduction rides fast ICI while the
cross-pod hop crosses DCN (orders of magnitude less bandwidth) — compressing
only that hop cuts cross-pod gradient bytes 4x at ~1e-3 relative error.
``compressed_psum`` implements it with collectives only:

    per-pod partial gradient -> int8 quantize (stochastic rounding, per-tensor
    scale) -> all_gather over 'pod' (1 byte/param/pod) -> dequantize + sum.

Stochastic rounding keeps the quantizer unbiased, so SGD-style convergence
guarantees survive (variance grows by the quantization noise, bounded by the
per-tensor scale).  Used by the shard_map data-parallel driver in
examples/train_dp_compressed.py and unit-tested for bias in tests/.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jnp.ndarray


def quantize_int8(x: Array, key: jax.Array) -> tuple[Array, Array]:
    """Stochastic-rounding int8 quantization; returns (q int8, scale f32)."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf)) / 127.0 + 1e-30
    scaled = xf / scale
    noise = jax.random.uniform(key, x.shape)
    q = jnp.floor(scaled + noise)          # E[q] = scaled
    return jnp.clip(q, -128, 127).astype(jnp.int8), scale


def dequantize_int8(q: Array, scale: Array) -> Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: Array, axis: str, key: jax.Array) -> Array:
    """psum over ``axis`` with int8-compressed payloads.

    Must run inside shard_map.  Each participant quantizes its partial sum,
    all participants gather everyone's int8 payloads + scales, and the sum is
    reconstructed locally.  Bytes on the wire: 1/4 of a float32 psum (ring
    all-reduce moves ~2x data; gather of int8 moves P x n/4 — for P=2 pods
    that is 4x fewer bytes than the f32 ring).
    """
    idx = jax.lax.axis_index(axis)
    q, scale = quantize_int8(x, jax.random.fold_in(key, idx))
    qs = jax.lax.all_gather(q, axis)                 # (P, ...) int8
    scales = jax.lax.all_gather(scale, axis)         # (P,)
    return jnp.sum(qs.astype(jnp.float32) *
                   scales.reshape((-1,) + (1,) * x.ndim), axis=0)
